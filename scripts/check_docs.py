#!/usr/bin/env python
"""Docs drift check (wired into scripts/ci.sh).

Fails CI when the user-facing docs fall out of sync with the code:

1. every ``LookupStrategy`` registry name (and the ``mixed``/``auto``
   spellings) must appear in both ``README.md`` and
   ``docs/architecture.md`` — a new ``@register_strategy`` class cannot
   ship undocumented;
2. every ``python -m <module> ...`` command in README code fences must be
   ``--help``-valid: the module's ``--help`` exits 0 and mentions every
   ``--flag`` the quickstart uses, so the quickstart can never advertise a
   flag that argparse would reject.

Runs with no arguments from anywhere inside the repo.
"""
from __future__ import annotations

import os
import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

FENCE_RE = re.compile(r"```(?:\w*)\n(.*?)```", re.DOTALL)
CMD_RE = re.compile(r"python\s+-m\s+([\w.]+)((?:\s+\S+)*)")


def fail(msg: str) -> None:
    print(f"check_docs: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def doc_commands(text: str):
    """(module, [flags]) for every ``python -m`` line in a code fence."""
    for fence in FENCE_RE.findall(text):
        fence = fence.replace("\\\n", " ")  # join shell line continuations
        for line in fence.splitlines():
            m = CMD_RE.search(line)
            if not m:
                continue
            module = m.group(1)
            flags = [t.split("=")[0] for t in m.group(2).split()
                     if t.startswith("--")]
            yield module, flags


def main() -> None:
    from repro.engine import AUTO_NAMES, available_strategies

    names = available_strategies() + AUTO_NAMES
    docs = {p: (ROOT / p).read_text()
            for p in ("README.md", "docs/architecture.md")
            if (ROOT / p).exists()}
    for p in ("README.md", "docs/architecture.md"):
        if p not in docs:
            fail(f"{p} is missing")
    for p, text in docs.items():
        missing = [n for n in names if n not in text]
        if missing:
            fail(f"{p} does not mention registry strategies: {missing}")
    print(f"check_docs: all {len(names)} strategy names documented in "
          f"{', '.join(docs)}")

    help_cache: dict = {}
    checked = 0
    for module, flags in doc_commands(docs["README.md"]):
        if not module.startswith(("repro.", "benchmarks.", "pytest")):
            continue
        if module not in help_cache:
            out = subprocess.run(
                [sys.executable, "-m", module, "--help"],
                capture_output=True, text=True, timeout=600,
                cwd=str(ROOT),
                # inherit the environment: --help must be validated under the
                # same env (proxies, JAX_PLATFORMS, caches) the documented
                # command actually runs in
                env={**os.environ,
                     "PYTHONPATH": os.pathsep.join(
                         [str(ROOT / "src")]
                         + ([os.environ["PYTHONPATH"]]
                            if os.environ.get("PYTHONPATH") else []))})
            if out.returncode != 0:
                fail(f"`python -m {module} --help` exited "
                     f"{out.returncode}:\n{out.stderr[-2000:]}")
            help_cache[module] = out.stdout + out.stderr
        for flag in flags:
            if flag not in help_cache[module]:
                fail(f"README quickstart uses {flag} but "
                     f"`python -m {module} --help` does not list it")
        checked += 1
    if checked == 0:
        fail("README.md has no `python -m ...` quickstart commands to validate")
    print(f"check_docs: {checked} README quickstart commands --help-validated "
          f"({len(help_cache)} modules)")


if __name__ == "__main__":
    main()
