import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.packing import make_plan
from repro.data.synthetic import make_batch
from repro.launch.mesh import make_test_mesh
from repro.models.wdl import WDLModel
from repro.train.train_step import TrainConfig, init_state, make_train_step
from repro.dist.sharding import batch_specs, to_named

ARCH = os.environ.get("ARCH", "deepfm")
mesh = make_test_mesh(4, 2)
axes = ("data", "model")
GB = 64  # global batch

cfg = get_config(ARCH, smoke=True)
plan = make_plan(cfg, world=8, per_device_batch=GB // 8, hot_bytes=1 << 14,
                 flush_iters=3, warmup_iters=2, n_interleave=2)
print(f"{ARCH}: {len(plan.groups)} packed groups, caps={plan.capacity}, "
      f"micro={plan.microbatch}, ilv={plan.interleave}, cache={plan.cache_rows}")

model = WDLModel(cfg, plan)
with jax.default_device(jax.devices()[0]):
    pass
state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh, axes=axes)
step_fn, _ = make_train_step(model, plan, mesh, axes, GB, TrainConfig())

rng = np.random.default_rng(0)
t0 = time.time()
for i in range(6):
    batch = make_batch(cfg, GB, rng)
    batch = jax.device_put(batch, to_named(mesh, batch_specs(batch, axes)))
    state, m = step_fn(state, batch)
    print(f"step {int(m['step'])}: loss={float(m['loss']):.4f} "
          f"ovf={int(m['overflow'])} hits={int(m['cache_hits'])}")
print(f"{time.time()-t0:.1f}s; loss finite:", bool(jnp.isfinite(m["loss"])))
