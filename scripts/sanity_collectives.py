import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
import numpy as np

from repro.dist.compat import make_mesh_compat, shard_map

mesh = make_mesh_compat((4, 2), ("data", "model"))
axes = ("data", "model")
WORLD = 8


def f(x):
    # x: [1, cap] per device after sharding [8, cap]
    idx = lax.axis_index(axes)
    send = jnp.tile(idx * 100 + jnp.arange(WORLD)[:, None], (1, 1)).astype(jnp.int32)  # [8,1] msg to each peer
    recv = lax.all_to_all(send, axes, split_axis=0, concat_axis=0, tiled=True)
    return recv.reshape(1, WORLD), idx.reshape(1, 1)


xs = jnp.zeros((WORLD, 4), jnp.int32)
recv, idxs = jax.jit(shard_map(f, mesh=mesh, in_specs=P(axes), out_specs=P(axes)))(xs)
print("axis_index per device:", np.array(idxs).ravel())
print("recv on device 0:", np.array(recv)[0])   # expect [0,100,200,...,700] + 0
print("recv on device 3:", np.array(recv)[3])   # expect j*100+3

# block sharding order: does P(('data','model')) block k go to axis_index k?
w = jnp.arange(WORLD * 2).reshape(WORLD * 2, 1)


def g(wshard):
    idx = lax.axis_index(axes)
    return (wshard[0] == idx * 2).reshape(1, 1)


ok = jax.jit(shard_map(g, mesh=mesh, in_specs=P(axes), out_specs=P(axes)))(w)
print("block order matches axis_index:", np.array(ok).ravel())

# all_gather + psum with tuple axes
def h(x):
    g = lax.all_gather(x, axes, tiled=True)
    s = lax.psum(x.sum(), axes)
    return g.reshape(1, -1), s.reshape(1, 1)


gg, ss = jax.jit(shard_map(h, mesh=mesh, in_specs=P(axes), out_specs=(P(axes), P(axes))))(
    jnp.arange(8.0).reshape(8, 1))
print("all_gather row0:", np.array(gg)[0], "psum:", np.array(ss).ravel()[0])
