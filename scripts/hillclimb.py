"""§Perf hillclimb driver: run tagged dry-run variants of the three chosen
cells and log hypothesis -> change -> before/after into results/perf/.

Usage: PYTHONPATH=src python scripts/hillclimb.py [cellname ...]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=256")

import json
import sys
from pathlib import Path

from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh
from repro.train.train_step import TrainConfig

OUT = Path("results/perf")

# variant name -> (arch, shape, cell_kw, plan_kw)
# v0 baselines are the sweep records in results/dryrun (paper-faithful defaults).
VARIANTS = {
    # ---- cell 1: deepfm x train_batch (paper-representative) --------------
    "deepfm_v1_stale_cache": (
        "deepfm", "train_batch",
        {"tcfg": TrainConfig(cache_update="stale", flush_in_step=False)}, {}),
    "deepfm_v2_stale_bf16psum": (
        "deepfm", "train_batch",
        {"tcfg": TrainConfig(cache_update="stale", flush_in_step=False,
                             grad_compression="bf16")}, {}),
    "deepfm_v3_cap_slack1": (
        "deepfm", "train_batch",
        {"tcfg": TrainConfig(cache_update="stale", flush_in_step=False,
                             grad_compression="bf16")},
        {"hot_bytes": 1 << 26, "capacity_slack": 1.25}),
    # ---- cell 2: mistral-nemo-12b x train_4k (most collective-bound:
    #      contraction-dim FSDP sharding -> activation-sized partial-sum
    #      all-reduces, 1.5TB/step/device) ---------------------------------
    "nemo_v1_zero1": ("mistral-nemo-12b", "train_4k",
                      {"lm_kw": {"shard_mode": "zero1"}}, None),
    "nemo_v2_zero1_chunk1k": ("mistral-nemo-12b", "train_4k",
                              {"lm_kw": {"shard_mode": "zero1",
                                         "attn_chunk": 1024}}, None),
    # ---- cell 3: mixtral-8x22b x train_4k (worst roofline fraction:
    #      GSPMD replicates the MoE dispatch buffers -> TB-scale all-reduce) -
    "mixtral_v1_moe_shard": ("mixtral-8x22b", "train_4k",
                             {"lm_kw": {"moe_shard": True}}, None),
    "mixtral_v2_moeshard_zero1": (
        "mixtral-8x22b", "train_4k",
        {"lm_kw": {"moe_shard": True, "shard_mode": "zero1"}}, None),
}


def main():
    names = sys.argv[1:] or list(VARIANTS)
    mesh = make_production_mesh(multi_pod=False)
    for name in names:
        arch, shape, cell_kw, plan_kw = VARIANTS[name]
        rec = run_cell(arch, shape, False, OUT, mesh=mesh, tag=f"__{name}",
                       plan_kw=plan_kw, cell_kw=cell_kw)
        ok = "OK " if rec.get("ok") else "FAIL"
        print(f"[{ok}] {name}: bound={rec.get('bound')} "
              f"c={rec.get('compute_s', 0):.3e} m={rec.get('memory_s', 0):.3e} "
              f"x={rec.get('collective_s', 0):.3e} step={rec.get('step_s', 0):.3e} "
              f"{rec.get('error', '')[:200]}", flush=True)


if __name__ == "__main__":
    main()
