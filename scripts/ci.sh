#!/usr/bin/env bash
# Tier-1 CI: full test suite + fast benchmark smoke pass.
#
#   ./scripts/ci.sh            # from anywhere; cd's to the repo root
#
# Seed baseline (PR 0, recorded at PR 1 so regressions vs. seed are
# detectable): `PYTHONPATH=src python -m pytest -q` FAILED with
#   - 7 collection errors:
#       tests/test_checkpoint.py    (zstandard not installed)
#       tests/test_engine.py        (hypothesis not installed)
#       tests/test_kernels.py       (hypothesis not installed)
#       tests/test_models_smoke.py  (repro.dist module missing)
#       tests/test_packing.py       (hypothesis not installed)
#       tests/test_system.py        (repro.dist module missing)
#       tests/test_transformer.py   (hypothesis not installed)
#   - tests/test_distributed.py: 5 failed (repro.dist missing in subprocess)
#   - tests/test_grad_compression.py: 2 errors (jax.sharding.AxisType
#     missing on jax 0.4.37)
#   - 11 passed (test_data, test_moe, remaining test_grad_compression-free
#     collectible modules)
# All of the above pass as of PR 1; this script therefore runs strict.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== tier-1: benchmark smoke =="
# the smoke pass must include the 'mixed' per-group assignment row (so the
# repro.core.assign cost-model path is executed on every CI run) and the
# 'picasso_l2' row (so the two-tier L1/L2 cache path is executed end-to-end)
bench_out=$(python -m benchmarks.bench_throughput --smoke | tee /dev/stderr)
echo "$bench_out" | grep -q "/mixed" \
    || { echo "ci.sh: bench smoke missing the 'mixed' strategy row" >&2; exit 1; }
echo "$bench_out" | grep -q "/picasso_l2" \
    || { echo "ci.sh: bench smoke missing the 'picasso_l2' strategy row" >&2; exit 1; }
# the adaptive-replanning row (harvest -> recompile -> migrate -> rebuild)
# must run — and actually migrate — on every CI pass
echo "$bench_out" | grep -q "/auto+replan.*migrated=1" \
    || { echo "ci.sh: bench smoke missing a migrated 'auto+replan' row" >&2; exit 1; }
# the fused sparse hot path must be benched against the reference, and the
# run must land in the repo-root perf trajectory artifact
echo "$bench_out" | grep -q "/picasso+fused" \
    || { echo "ci.sh: bench smoke missing the fused-kernel row" >&2; exit 1; }
# the software-pipelined step and the compressed routed-gradient path must
# both be timed (and land in the artifact) on every CI run
echo "$bench_out" | grep -q "/overlap=on" \
    || { echo "ci.sh: bench smoke missing the 'overlap=on' row" >&2; exit 1; }
echo "$bench_out" | grep -q "/grad_compress=fp16" \
    || { echo "ci.sh: bench smoke missing the 'grad_compress=fp16' row" >&2; exit 1; }
# the frequency-adaptive-dims row must run, and its derived narrow_vs_full
# row must show the >=2x per-group vparam-bytes reduction the narrow master
# is for (d = D // 4 on the smoke model)
echo "$bench_out" | grep -q "/picasso_narrow" \
    || { echo "ci.sh: bench smoke missing the 'picasso_narrow' row" >&2; exit 1; }
echo "$bench_out" | grep -q "/narrow_vs_full.*vparam_bytes x" \
    || { echo "ci.sh: bench smoke missing the 'narrow_vs_full' row" >&2; exit 1; }
# the elastic-reshard cost row (rows/sec migrated + stall walltime of the
# world=8 -> world=4 permutation) must be timed on every CI run
echo "$bench_out" | grep -q "/reshard_8to4.*rows_per_s=.*stall_ms=" \
    || { echo "ci.sh: bench smoke missing the 'reshard_8to4' row" >&2; exit 1; }
# the anomaly-guard cost rows: guarded ips (non-donating step + per-step
# host sync) and the derived guarded/unguarded ratio must be pinned in the
# trajectory on every CI run — the honest price of per-step detection
echo "$bench_out" | grep -q "/guard=on" \
    || { echo "ci.sh: bench smoke missing the 'guard=on' row" >&2; exit 1; }
echo "$bench_out" | grep -q "/guard_overhead.*x" \
    || { echo "ci.sh: bench smoke missing the 'guard_overhead' row" >&2; exit 1; }
test -f BENCH_10.json \
    || { echo "ci.sh: bench smoke did not write BENCH_10.json" >&2; exit 1; }
grep -q "picasso+fused" BENCH_10.json \
    || { echo "ci.sh: BENCH_10.json has no fused-vs-reference rows" >&2; exit 1; }
grep -q "overlap=on" BENCH_10.json \
    || { echo "ci.sh: BENCH_10.json missing the overlap rows" >&2; exit 1; }
grep -q "grad_compress" BENCH_10.json \
    || { echo "ci.sh: BENCH_10.json missing the grad_compress rows" >&2; exit 1; }
grep -q "guard_overhead" BENCH_10.json \
    || { echo "ci.sh: BENCH_10.json missing the guard_overhead row" >&2; exit 1; }
# narrow rows land in the artifact, every row stamped with the backend and
# the interpret flag (interpreter timings must never read as silicon), the
# derived vparam-bytes reduction clears 2x, and derived *ratio* rows whose
# inputs ran the Pallas interpreter carry the honest interpreted=true flag
# (fused_vs_ref forces the fused path on, so its flag must equal the row's
# interpret stamp — true on this CPU rig, false on real silicon)
python - <<'PY'
import json
rows = {r["name"]: r for r in json.load(open("BENCH_10.json"))["rows"]}
nar = [r for n, r in rows.items() if "/picasso_narrow" in n]
assert nar, "BENCH_10.json missing the picasso_narrow rows"
assert all("backend" in r and "interpret" in r for r in rows.values()), \
    "BENCH_10.json rows missing backend/interpret stamps"
nvf = [r for n, r in rows.items() if "/narrow_vs_full" in n]
assert nvf, "BENCH_10.json missing the narrow_vs_full rows"
rsh = [r for n, r in rows.items() if "/reshard_8to4" in n]
assert rsh, "BENCH_10.json missing the reshard_8to4 rows"
assert all("rows_per_s=" in r["derived"] and "stall_ms=" in r["derived"]
           for r in rsh), "reshard rows missing rows_per_s/stall_ms"
for r in nvf:
    x = float(r["derived"].split("x")[1].split(",")[0])
    assert x >= 2.0, f"narrow master reduction below 2x: {r['derived']}"
fvr = [r for n, r in rows.items() if "/fused_vs_ref" in n]
assert fvr, "BENCH_10.json missing the fused_vs_ref rows"
for r in fvr:
    assert r.get("interpreted", False) == r["interpret"], \
        f"fused_vs_ref interpreted flag dishonest: {r}"
print(f"ci.sh: narrow rows ok ({nvf[0]['derived']}, "
      f"backend={nvf[0]['backend']}, interpret={nvf[0]['interpret']}); "
      f"fused_vs_ref interpreted={fvr[0].get('interpreted', False)}")
PY
# isolated fused-vs-reference microbench rows (gather+pool / dedup+adagrad /
# gather+project / tier probe) merge into the same artifact
python -m benchmarks.bench_kernels --smoke
grep -q "kernels/gather_pool" BENCH_10.json \
    || { echo "ci.sh: BENCH_10.json missing the kernel microbench rows" >&2; exit 1; }
grep -q "kernels/gather_project" BENCH_10.json \
    || { echo "ci.sh: BENCH_10.json missing the gather_project rows" >&2; exit 1; }
# the calibration suite merges per-op curve-fit rows (+ the fitted model's
# end-to-end step prediction) into the same artifact
calib_bench=$(mktemp -u)
python -m benchmarks.bench_calibrate --smoke --calib-file "$calib_bench"
test -f "$calib_bench" \
    || { echo "ci.sh: bench_calibrate wrote no calibration file" >&2; exit 1; }
rm -f "$calib_bench"
grep -q "calibrate/gather_pool" BENCH_10.json \
    || { echo "ci.sh: BENCH_10.json missing the calibrate curve rows" >&2; exit 1; }
grep -q "calibrate/predict_step" BENCH_10.json \
    || { echo "ci.sh: BENCH_10.json missing the calibrate/predict_step row" >&2; exit 1; }

echo "== tier-1: fused-kernel interpret soak =="
# every Pallas kernel (sparse + interaction) forced through the interpreter
# against the jnp references: the fused-path test file end to end
REPRO_FORCE_PALLAS_INTERPRET=1 python -m pytest -q tests/test_fused.py

echo "== tier-1: retrieval streaming top-k smoke =="
# n_candidates >> the per-shard score chunk: chunked scoring + the running
# top-k merge (the engine capacity is sized to the 256-id chunk)
python -m repro.launch.serve --arch sasrec --smoke --retrieval \
    --n-candidates 4096 --score-chunk 256

echo "== tier-1: replan smoke =="
# a short training run that triggers >=1 live plan migration (the halved L2
# envelope guarantees a tier resize at the first replan) and keeps learning
# across it: loss must decrease from the first logged window to the last
replan_out=$(python -m repro.launch.train --arch deepfm --smoke --steps 120 \
    --global-batch 64 --strategy picasso_l2 --l2-budget 65536 \
    --replan-iters 40 --replan-l2-bytes 32768 --learnable \
    --lr-emb 0.1 --lr-dense 3e-3 --log-every 1)
echo "$replan_out" | grep -v "^  step" >&2   # replan events, not 120 loss lines
echo "$replan_out" | grep -q "plan rev 0 -> 1" \
    || { echo "ci.sh: replan smoke never migrated (no 'plan rev 0 -> 1' event)" >&2; exit 1; }
REPLAN_OUT="$replan_out" python - <<'PY'
import os, re, statistics as st
losses = [float(m) for m in re.findall(r"loss=([0-9.]+)", os.environ["REPLAN_OUT"])]
assert len(losses) >= 60, f"too few logged losses: {len(losses)}"
# same criterion test_system validated against XLA-CPU run-to-run noise:
# pre-convergence median (steps 1-10) vs the converged tail (last 20)
first, last = st.median(losses[:10]), st.median(losses[-20:])
assert last < first * 0.95, \
    f"loss did not decrease across the replan: {first:.4f} -> {last:.4f}"
print(f"replan smoke: loss {first:.4f} -> {last:.4f} across >=1 migration")
PY

echo "== tier-1: calibration smoke =="
# the measured cost model end to end: force-calibrate a tiny grid, assert the
# stamped calibration file lands, the auto assignment is priced from the
# fitted curves (not the constants), and the Replanner's measured-vs-
# predicted feedback loop fires (corr= on the replan events)
calib_dir=$(mktemp -d)
calib_out=$(python -m repro.launch.train --arch deepfm --smoke --steps 80 \
    --global-batch 64 --strategy auto --calibrate force \
    --calib-file "$calib_dir/calib.json" --l2-budget 65536 --replan-iters 40 \
    --learnable --lr-emb 0.1 --lr-dense 3e-3 --log-every 20)
echo "$calib_out" | grep -v "^  step" >&2
test -f "$calib_dir/calib.json" \
    || { echo "ci.sh: calibration smoke wrote no calib file" >&2; exit 1; }
echo "$calib_out" | grep -q "calib wrote calibration to" \
    || { echo "ci.sh: calibration smoke never wrote the calibration" >&2; exit 1; }
echo "$calib_out" | grep -q "calibrated curves" \
    || { echo "ci.sh: assignment was not priced from the fitted curves" >&2; exit 1; }
echo "$calib_out" | grep -q "corr=" \
    || { echo "ci.sh: cost-model feedback loop never fired (no corr= event)" >&2; exit 1; }
# cached reload: 'auto' must load the backend-stamped file, not re-bench
reload_out=$(python -m repro.launch.train --arch deepfm --smoke --steps 10 \
    --global-batch 64 --strategy auto --calibrate auto \
    --calib-file "$calib_dir/calib.json" --log-every 10)
echo "$reload_out" | grep -v "^  step" >&2
echo "$reload_out" | grep -q "calib loaded calibration from" \
    || { echo "ci.sh: cached calibration was not reloaded" >&2; exit 1; }
! echo "$reload_out" | grep -q "grid points" \
    || { echo "ci.sh: cached reload re-ran the microbenches" >&2; exit 1; }
rm -rf "$calib_dir"

echo "== tier-1: narrow replan smoke =="
# frequency-adaptive dims end to end: train with the narrow cold master
# (d=4 vs D=10 on the smoke model) through >=1 forced replan migration —
# the halved L2 envelope guarantees a tier resize, which re-masters the
# narrow group (re-widen through the learned projection for tier residents,
# projection + FCounter + adagrad carried) — and keep learning across it
narrow_out=$(python -m repro.launch.train --arch deepfm --smoke --steps 120 \
    --global-batch 64 --strategy picasso_narrow --narrow-dim 4 \
    --l2-budget 65536 --replan-iters 40 --replan-l2-bytes 32768 --learnable \
    --lr-emb 0.1 --lr-dense 3e-3 --log-every 1)
echo "$narrow_out" | grep -v "^  step" >&2
echo "$narrow_out" | grep -q "plan rev 0 -> 1" \
    || { echo "ci.sh: narrow smoke never migrated (no 'plan rev 0 -> 1' event)" >&2; exit 1; }
NARROW_OUT="$narrow_out" python - <<'PY'
import os, re, statistics as st
losses = [float(m) for m in re.findall(r"loss=([0-9.]+)", os.environ["NARROW_OUT"])]
assert len(losses) >= 60, f"too few logged losses: {len(losses)}"
first, last = st.median(losses[:10]), st.median(losses[-20:])
assert last < first * 0.95, \
    f"loss did not decrease across the narrow replan: {first:.4f} -> {last:.4f}"
print(f"narrow smoke: loss {first:.4f} -> {last:.4f} across >=1 migration "
      "(narrow master re-widened at replan)")
PY

echo "== tier-1: overlap smoke =="
# the software-pipelined step with fp16 routed-gradient compression must
# still learn: same loss-decrease criterion as the replan smoke, on the
# overlap='on' + grad_compress='fp16' trainer path end to end
overlap_out=$(python -m repro.launch.train --arch deepfm --smoke --steps 60 \
    --global-batch 64 --n-micro 2 --overlap on --grad-compress fp16 \
    --learnable --lr-emb 0.1 --lr-dense 3e-3 --log-every 1)
OVERLAP_OUT="$overlap_out" python - <<'PY'
import os, re, statistics as st
losses = [float(m) for m in re.findall(r"loss=([0-9.]+)", os.environ["OVERLAP_OUT"])]
assert len(losses) >= 40, f"too few logged losses: {len(losses)}"
first, last = st.median(losses[:10]), st.median(losses[-20:])
assert last < first * 0.95, \
    f"loss did not decrease under overlap+fp16: {first:.4f} -> {last:.4f}"
print(f"overlap smoke: loss {first:.4f} -> {last:.4f} (overlap=on, fp16 wire)")
PY

echo "== tier-1: elastic reshard smoke =="
# live world-size change mid-run: train on 8 host devices (4x2), reshard to
# 4 (2x2) at step 30 — the run must log the reshard event and keep learning
# across it (same loss-decrease criterion as the replan smoke)
elastic_out=$(python -m repro.launch.train --arch deepfm --smoke --steps 120 \
    --global-batch 128 --devices 8 --mesh 4x2 --reshard-to 2x2 --reshard-at 60 \
    --strategy picasso_l2 --l2-budget 65536 --learnable \
    --lr-emb 0.1 --lr-dense 3e-3 --log-every 1)
echo "$elastic_out" | grep -v "^  step" >&2
echo "$elastic_out" | grep -q "reshard world 8 -> 4" \
    || { echo "ci.sh: elastic smoke never resharded (no 'reshard world' event)" >&2; exit 1; }
ELASTIC_OUT="$elastic_out" python - <<'PY'
import os, re, statistics as st
losses = [float(m) for m in re.findall(r"loss=([0-9.]+)", os.environ["ELASTIC_OUT"])]
assert len(losses) >= 60, f"too few logged losses: {len(losses)}"
first, last = st.median(losses[:10]), st.median(losses[-20:])
assert last < first * 0.95, \
    f"loss did not decrease across the reshard: {first:.4f} -> {last:.4f}"
print(f"elastic smoke: loss {first:.4f} -> {last:.4f} across a live 8->4 reshard")
PY

echo "== tier-1: streaming driver smoke =="
# the unbounded-stream driver: consume the batch stream in segments,
# checkpoint + publish at every boundary, and apply the pending reshard
# in place at a segment boundary — no restart
stream_dir=$(mktemp -d)
stream_out=$(python -m repro.launch.train --arch deepfm --smoke \
    --global-batch 64 --devices 8 --mesh 4x2 --stream --segment-steps 15 \
    --stream-segments 3 --publish-dir "$stream_dir/pub" \
    --ckpt-dir "$stream_dir/ckpt" --reshard-to 2x2 --reshard-at 15 \
    --learnable --lr-emb 0.1 --lr-dense 3e-3 --log-every 10)
echo "$stream_out" >&2
echo "$stream_out" | grep -q "\[stream\] segment 3/3" \
    || { echo "ci.sh: streaming smoke did not complete 3 segments" >&2; exit 1; }
echo "$stream_out" | grep -q "reshard world 8 -> 4" \
    || { echo "ci.sh: streaming smoke never resharded in place" >&2; exit 1; }
echo "$stream_out" | grep -q "stream done at step 45 (world=4)" \
    || { echo "ci.sh: streaming smoke did not finish at the resharded world" >&2; exit 1; }
test -f "$stream_dir/pub/LATEST" \
    || { echo "ci.sh: streaming smoke published no LATEST pointer" >&2; exit 1; }
# a serve process picks the published delta up (cross-world: server at 1x2)
serve_out=$(python -m repro.launch.serve --arch deepfm --smoke --batch 64 \
    --devices 2 --mesh 1x2 --n-requests 3 --reload-dir "$stream_dir/pub")
echo "$serve_out" >&2
echo "$serve_out" | grep -q "reloaded published step 45" \
    || { echo "ci.sh: serve never picked up the published delta" >&2; exit 1; }

echo "== tier-1: degraded-mode serve smoke =="
# tear the published delta on disk (chaos 'torn@0' truncates a leaf before
# the first request): the poller must detect the checksum mismatch, keep
# the last good state, back off, and the server must keep answering
torn_out=$(python -m repro.launch.serve --arch deepfm --smoke --batch 64 \
    --devices 2 --mesh 1x2 --n-requests 4 --reload-dir "$stream_dir/pub" \
    --chaos "torn@0")
echo "$torn_out" >&2
echo "$torn_out" | grep -q "chaos: tearing published delta" \
    || { echo "ci.sh: torn-delta smoke never tore the delta" >&2; exit 1; }
echo "$torn_out" | grep -q "failed verification.*keeping last good state" \
    || { echo "ci.sh: serve did not degrade on the torn delta" >&2; exit 1; }
echo "$torn_out" | grep -q "p50=" \
    || { echo "ci.sh: serve stopped answering through the torn delta" >&2; exit 1; }
rm -rf "$stream_dir"

echo "== tier-1: chaos recovery smoke =="
# the full failure matrix in one guarded run: a NaN batch (guard rejects,
# state kept, batch skipped), a corrupted checkpoint on disk (restore
# quarantines + falls back), and an injected crash (Supervisor classifies
# transient, restores the last verified checkpoint, rewinds the stream) —
# and the run must still learn end to end. Recovery events log to stderr,
# so capture both streams. Indices: saves land at 20/40/...; ckpt@41
# corrupts step_40 right after it lands, crash@45 forces the restore to
# quarantine step_40 and fall back to step_20.
chaos_dir=$(mktemp -d)
chaos_out=$(python -m repro.launch.train --arch deepfm --smoke --steps 120 \
    --global-batch 64 --guard --chaos "nan@7,ckpt@41,crash@45" \
    --ckpt-dir "$chaos_dir/ckpt" --ckpt-every 20 \
    --learnable --lr-emb 0.1 --lr-dense 3e-3 --log-every 1 2>&1)
echo "$chaos_out" | grep -v "^  step" >&2
echo "$chaos_out" | grep -q "guard: rejected step (nonfinite" \
    || { echo "ci.sh: chaos smoke — guard never rejected the NaN batch" >&2; exit 1; }
echo "$chaos_out" | grep -q "quarantined corrupt checkpoint step 40" \
    || { echo "ci.sh: chaos smoke — corrupt checkpoint was not quarantined" >&2; exit 1; }
echo "$chaos_out" | grep -q "rolled back to step 20" \
    || { echo "ci.sh: chaos smoke — Supervisor never rolled back to step 20" >&2; exit 1; }
test -d "$chaos_dir"/ckpt/step_00000040.corrupt \
    || { echo "ci.sh: chaos smoke — quarantined checkpoint dir missing" >&2; exit 1; }
CHAOS_OUT="$chaos_out" python - <<'PY'
import os, re, statistics as st
losses = [float(m) for m in re.findall(r"loss=([0-9.]+)", os.environ["CHAOS_OUT"])]
assert len(losses) >= 60, f"too few logged losses: {len(losses)}"
first, last = st.median(losses[:10]), st.median(losses[-20:])
assert last < first * 0.95, \
    f"loss did not decrease through the chaos plan: {first:.4f} -> {last:.4f}"
print(f"chaos smoke: loss {first:.4f} -> {last:.4f} through a NaN batch, "
      "a corrupted checkpoint, and an injected crash")
PY

echo "== tier-1: guarded-vs-unguarded parity =="
# the guard's contract on clean data: bitwise-identical training. The
# pytest matrix pins it (tests/test_faults.py::test_guard_clean_parity);
# run that single test here so the CI log states the contract explicitly.
python -m pytest -q tests/test_faults.py::test_guard_clean_parity
rm -rf "$chaos_dir"

echo "== tier-1: docs sync =="
# every registry strategy must be documented in README.md +
# docs/architecture.md, and README quickstart commands must be --help-valid
python scripts/check_docs.py

echo "== ci.sh: all green =="
