#!/usr/bin/env bash
# Tier-1 CI: full test suite + fast benchmark smoke pass.
#
#   ./scripts/ci.sh            # from anywhere; cd's to the repo root
#
# Seed baseline (PR 0, recorded at PR 1 so regressions vs. seed are
# detectable): `PYTHONPATH=src python -m pytest -q` FAILED with
#   - 7 collection errors:
#       tests/test_checkpoint.py    (zstandard not installed)
#       tests/test_engine.py        (hypothesis not installed)
#       tests/test_kernels.py       (hypothesis not installed)
#       tests/test_models_smoke.py  (repro.dist module missing)
#       tests/test_packing.py       (hypothesis not installed)
#       tests/test_system.py        (repro.dist module missing)
#       tests/test_transformer.py   (hypothesis not installed)
#   - tests/test_distributed.py: 5 failed (repro.dist missing in subprocess)
#   - tests/test_grad_compression.py: 2 errors (jax.sharding.AxisType
#     missing on jax 0.4.37)
#   - 11 passed (test_data, test_moe, remaining test_grad_compression-free
#     collectible modules)
# All of the above pass as of PR 1; this script therefore runs strict.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== tier-1: benchmark smoke =="
# the smoke pass must include the 'mixed' per-group assignment row (so the
# repro.core.assign cost-model path is executed on every CI run) and the
# 'picasso_l2' row (so the two-tier L1/L2 cache path is executed end-to-end)
bench_out=$(python -m benchmarks.bench_throughput --smoke | tee /dev/stderr)
echo "$bench_out" | grep -q "/mixed" \
    || { echo "ci.sh: bench smoke missing the 'mixed' strategy row" >&2; exit 1; }
echo "$bench_out" | grep -q "/picasso_l2" \
    || { echo "ci.sh: bench smoke missing the 'picasso_l2' strategy row" >&2; exit 1; }

echo "== tier-1: docs sync =="
# every registry strategy must be documented in README.md +
# docs/architecture.md, and README quickstart commands must be --help-valid
python scripts/check_docs.py

echo "== ci.sh: all green =="
