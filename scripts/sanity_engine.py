"""8-device sanity sweep of the EmbeddingEngine strategy layer.

Exercises PicassoStrategy lookups (with/without the hot cache, with/without
overflow) and the sparse gradient path against dense numpy references.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import packed_embedding as pe
from repro.dist.compat import make_mesh_compat, shard_map
from repro.embedding.state import EmbeddingState
from repro.engine import PicassoStrategy

mesh = make_mesh_compat((4, 2), ("data", "model"))
AXES = ("data", "model")
WORLD = 8
RPS = 16            # rows per shard
ROWS = RPS * WORLD  # 128
D = 5
N = 24              # ids per device

rng = np.random.default_rng(0)
table = jnp.asarray(rng.normal(size=(ROWS, D)).astype(np.float32))
ids = jnp.asarray(rng.integers(0, ROWS, size=(WORLD, N)).astype(np.int32))

# hot cache: rows 3, 7, 11 cached
hot_keys = jnp.asarray(np.array([3, 7, 11] + [ROWS] * 5, np.int32))
hot_rows = jnp.where((hot_keys < ROWS)[:, None], table[jnp.clip(hot_keys, 0, ROWS - 1)], 0.0)


def _state(tsh, acc=None, use_cache=False):
    cache = (pe.CacheState(hot_keys, hot_rows, jnp.zeros((hot_keys.shape[0], 1)))
             if use_cache else pe.init_cache(0, D, ROWS))
    return EmbeddingState(
        w=tsh, acc=acc if acc is not None else jnp.zeros((tsh.shape[0], 1)),
        counts=jnp.zeros((tsh.shape[0],), jnp.int32), cache=cache)


def run(table, ids, cap, use_cache):
    strat = PicassoStrategy(axes=AXES, world=WORLD, capacity={0: cap})

    def f(tsh, ids_l):
        st = _state(tsh, use_cache=use_cache)
        rows_u, ctx = strat.lookup(st, 0, ids_l.reshape(-1), cache_on=use_cache)
        per_id = jnp.take(rows_u, ctx.inv, axis=0)
        return per_id.reshape(1, N, D), ctx.routing.overflow.reshape(1)

    return jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(AXES, None), P(AXES, None)),
        out_specs=(P(AXES, None, None), P(AXES)), check_vma=False))(table, ids)


expected = np.asarray(table)[np.asarray(ids)]

for cap, cache in [(N, False), (N, True), (8, False), (8, True)]:
    got, ovf = run(table, ids, cap, cache)
    ok = np.allclose(np.asarray(got), expected, atol=1e-6)
    print(f"cap={cap:3d} cache={cache}: match={ok} overflow={np.asarray(ovf).sum()}")

# gradient path: g_u routed back == dense scatter reference
strat = PicassoStrategy(axes=AXES, world=WORLD, capacity={0: N}, lr=0.1, eps=1e-8)


def step(tsh, acc, ids_l, g_per_id):
    st = _state(tsh, acc)
    rows_u, ctx = strat.lookup(st, 0, ids_l.reshape(-1))
    # pretend dL/d(per_id) = g_per_id -> accumulate onto unique slots
    g_u = jax.ops.segment_sum(g_per_id.reshape(-1, D), ctx.inv, num_segments=N)
    st2, _, _ = strat.apply_grads(st, 0, ctx, g_u)
    return st2.w, st2.acc


acc0 = jnp.zeros((ROWS, 1), jnp.float32)
g = jnp.asarray(rng.normal(size=(WORLD, N, D)).astype(np.float32))
w2, acc2 = jax.jit(shard_map(
    step, mesh=mesh,
    in_specs=(P(AXES, None), P(AXES, None), P(AXES, None), P(AXES, None, None)),
    out_specs=(P(AXES, None), P(AXES, None)), check_vma=False))(table, acc0, ids, g)

# reference: dense scatter-add + rowwise adagrad
gref = np.zeros((ROWS, D), np.float32)
np.add.at(gref, np.asarray(ids).ravel(), np.asarray(g).reshape(-1, D))
accref = (gref ** 2).mean(-1, keepdims=True)
wref = np.asarray(table) - 0.1 * gref / np.sqrt(accref + 1e-8)
touched = np.abs(gref).max(-1) > 0
print("grad path w match:", np.allclose(np.asarray(w2), wref, atol=1e-5))
print("acc match:", np.allclose(np.asarray(acc2)[touched], accref[touched], atol=1e-6))
