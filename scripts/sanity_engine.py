import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P
from functools import partial

from repro.core import packed_embedding as pe

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
AXES = ("data", "model")
WORLD = 8
RPS = 16            # rows per shard
ROWS = RPS * WORLD  # 128
D = 5
N = 24              # ids per device

rng = np.random.default_rng(0)
table = jnp.asarray(rng.normal(size=(ROWS, D)).astype(np.float32))
ids = jnp.asarray(rng.integers(0, ROWS, size=(WORLD, N)).astype(np.int32))

# hot cache: rows 3, 7, 11 cached
hot_keys = jnp.asarray(np.array([3, 7, 11] + [ROWS] * 5, np.int32))
hot_rows = jnp.where((hot_keys < ROWS)[:, None], table[jnp.clip(hot_keys, 0, ROWS - 1)], 0.0)


def run(table, ids, cap, use_cache):
    def f(tsh, ids_l):
        ids_l = ids_l.reshape(-1)
        hk = hot_keys if use_cache else None
        hr = hot_rows if use_cache else None
        rows_u, ctx = pe.mp_lookup(tsh, ids_l, axes=AXES, world=WORLD, capacity=cap,
                                   hot_keys=hk, hot_rows=hr)
        per_id = jnp.take(rows_u, ctx.inv, axis=0)
        return per_id.reshape(1, N, D), ctx.routing.overflow.reshape(1)

    return jax.jit(jax.shard_map(
        f, mesh=mesh, in_specs=(P(AXES, None), P(AXES, None)),
        out_specs=(P(AXES, None, None), P(AXES))))(table, ids)


expected = np.asarray(table)[np.asarray(ids)]

for cap, cache in [(N, False), (N, True), (8, False), (8, True)]:
    got, ovf = run(table, ids, cap, cache)
    ok = np.allclose(np.asarray(got), expected, atol=1e-6)
    print(f"cap={cap:3d} cache={cache}: match={ok} overflow={np.asarray(ovf).sum()}")

# gradient path: g_u routed back == dense scatter reference
def step(tsh, acc, ids_l, g_per_id):
    ids_l = ids_l.reshape(-1)
    rows_u, ctx = pe.mp_lookup(tsh, ids_l, axes=AXES, world=WORLD, capacity=N)
    # pretend dL/d(per_id) = g_per_id -> accumulate onto unique slots
    g_u = jax.ops.segment_sum(g_per_id.reshape(-1, D), ctx.inv, num_segments=N)
    w2, acc2, _ = pe.apply_sparse_grads(tsh, acc, None, ctx, g_u,
                                        axes=AXES, world=WORLD, lr=0.1, eps=1e-8)
    return w2, acc2


acc0 = jnp.zeros((ROWS, 1), jnp.float32)
g = jnp.asarray(rng.normal(size=(WORLD, N, D)).astype(np.float32))
w2, acc2 = jax.jit(jax.shard_map(
    step, mesh=mesh,
    in_specs=(P(AXES, None), P(AXES, None), P(AXES, None), P(AXES, None, None)),
    out_specs=(P(AXES, None), P(AXES, None))))(table, acc0, ids, g)

# reference: dense scatter-add + rowwise adagrad
gref = np.zeros((ROWS, D), np.float32)
np.add.at(gref, np.asarray(ids).ravel(), np.asarray(g).reshape(-1, D))
accref = (gref ** 2).mean(-1, keepdims=True)
wref = np.asarray(table) - 0.1 * gref / np.sqrt(accref + 1e-8)
touched = np.abs(gref).max(-1) > 0
print("grad path w match:", np.allclose(np.asarray(w2), wref, atol=1e-5))
print("acc match:", np.allclose(np.asarray(acc2)[touched], accref[touched], atol=1e-6))
