"""Render EXPERIMENTS.md tables from results/dryrun/*.json."""
import glob
import json
import sys
from collections import defaultdict


def fmt(x, digits=2):
    if x is None:
        return "-"
    if x == 0:
        return "0"
    return f"{x:.{digits}e}"


def load(dirname="results/dryrun"):
    recs = []
    for f in sorted(glob.glob(f"{dirname}/*.json")):
        r = json.load(open(f))
        if not r.get("tag"):
            recs.append(r)
    return recs


def roofline_table(recs, mesh):
    rows = ["| arch | shape | compute (s) | memory (s) | collective (s) | bound | "
            "MODEL_FLOPs/dev | useful ratio | note |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh or not r.get("ok"):
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt(r.get('compute_s'))} | "
            f"{fmt(r.get('memory_s'))} | {fmt(r.get('collective_s'))} | "
            f"**{r.get('bound')}** | {fmt(r.get('model_flops'))} | "
            f"{fmt(r.get('useful_ratio'), 3)} | {r.get('note','')} |")
    return "\n".join(rows)


def dryrun_table(recs):
    rows = ["| arch | shape | mesh | compile (s) | peak bytes/dev | HLO GFLOPs/dev | "
            "HLO GB/dev | collective GB/dev (wire) | collectives |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if not r.get("ok"):
            continue
        mem = r.get("memory") or {}
        peak = mem.get("peak_bytes") or mem.get("temp_bytes")
        colls = ",".join(f"{k}:{int(v['count'])}" for k, v in
                         sorted((r.get("collectives") or {}).items()))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r.get('compile_s')} | "
            f"{fmt(peak)} | {r['hlo_flops']/1e9:.1f} | {r['hlo_bytes']/1e9:.2f} | "
            f"{r['collective_wire_bytes']/1e9:.3f} | {colls} |")
    return "\n".join(rows)


def failures(recs):
    out = []
    for r in recs:
        if not r.get("ok"):
            out.append(f"- {r['arch']} x {r['shape']} ({r['mesh']}): {r.get('error')}")
    return "\n".join(out) or "(none)"


if __name__ == "__main__":
    recs = load(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    print("## Dry-run (both meshes)\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(recs, "16x16"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(recs, "2x16x16"))
    print("\n## Failures\n")
    print(failures(recs))
