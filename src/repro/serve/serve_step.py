"""Inference steps for WDL models: online (p99) / bulk scoring / retrieval.

Same shard_map program shape as training minus the backward: packed lookups
(with the HybridHash read path) -> interactions -> sigmoid scores. Retrieval
scores one query against 1M candidates: two-tower archs (sasrec / mind) embed
the user once and dot against mesh-sharded candidate item rows with a
distributed top-k; pure-CTR archs (deepfm / dcn-v2) run a bulk forward over
the candidate batch (batched-dot, never a loop).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import packed_embedding as pe
from repro.core.features import PackedBatch, field_index, pack_group
from repro.core.packing import PicassoPlan
from repro.dist.sharding import batch_specs, state_specs
from repro.models.wdl import WDLModel


def _mesh_world(mesh, axes):
    return int(np.prod([mesh.shape[a] for a in axes]))


def make_serve_step(model: WDLModel, plan: PicassoPlan, mesh, axes, global_batch: int,
                    use_cache: bool = True):
    """Forward-only scoring: batch -> sigmoid probabilities [B, n_tasks]."""
    world = _mesh_world(mesh, axes)
    b_local = global_batch // world
    cache_on = use_cache and any(plan.cache_rows.get(g.gid, 0) > 0 for g in plan.groups)

    def local_fn(emb, dense, batch):
        pooled = {}
        for g in plan.groups:
            pb = pack_group(g, batch["fields"])
            st = emb[str(g.gid)]
            rows_u, ctx = pe.mp_lookup(
                st.w, pb.ids, axes=axes, world=world, capacity=plan.capacity[g.gid],
                hot_keys=st.cache.keys if cache_on else None,
                hot_rows=st.cache.rows if cache_on else None)
            p = pe.pool(rows_u, ctx.inv, pb.weights, pb.seg, b_local * g.n_bags)
            pooled[g.gid] = p.reshape(b_local, g.n_bags, g.dim)
        logits = model.apply(dense, pooled, batch)
        return jax.nn.sigmoid(logits)

    def wrapped(state, batch):
        emb_specs = {k: v for k, v in state_specs(plan, axes, state["dense"],
                                                  None)["emb"].items()}
        rep = jax.tree.map(lambda x: P(*((None,) * len(x.shape))), state["dense"])
        f = jax.shard_map(local_fn, mesh=mesh,
                          in_specs=(emb_specs, rep, batch_specs(batch, axes)),
                          out_specs=P(axes, None), check_vma=False)
        return f(state["emb"], state["dense"], batch)

    return jax.jit(wrapped)


def make_retrieval_step(model: WDLModel, plan: PicassoPlan, mesh, axes,
                        n_candidates: int, top_k: int = 100):
    """Two-tower retrieval: one user -> top-k of 1M candidates.

    The user representation is computed from the behaviour sequence
    (self_attn_seq / capsule interaction); candidate ids are mesh-sharded,
    their rows come from the *local* slice of the MP item table via the same
    packed-lookup engine, scores are a batched dot, and top-k is local-top-k
    -> all_gather -> global-top-k.
    """
    world = _mesh_world(mesh, axes)
    cand_local = n_candidates // world
    fidx = field_index(model.plan)
    item_field = next(f.name for f in model.cfg.fields
                      if f.pooling == "none" and f.max_len > 1)
    gid = fidx[item_field].gid
    group = plan.group(gid)

    def local_fn(emb, dense, batch, cand_ids):
        # --- user tower (batch=1, replicated compute) -----------------------
        pooled = {}
        for g in plan.groups:
            pb = pack_group(g, batch["fields"])
            st = emb[str(g.gid)]
            rows_u, ctx = pe.mp_lookup(st.w, pb.ids, axes=axes, world=world,
                                       capacity=plan.capacity[g.gid])
            p = pe.pool(rows_u, ctx.inv, pb.weights, pb.seg, 1 * g.n_bags)
            pooled[g.gid] = p.reshape(1, g.n_bags, g.dim)
        user = model.user_repr(dense, pooled, batch)          # [K, D]

        # --- candidate tower: local chunk of ids via the MP engine ----------
        st = emb[str(gid)]
        cand_rows, ctx = pe.mp_lookup(st.w, cand_ids.reshape(-1), axes=axes,
                                      world=world,
                                      capacity=plan.capacity[gid])
        rows = jnp.take(cand_rows, ctx.inv, axis=0)            # [cand_local, D]
        scores = jnp.max(rows @ user.T, axis=-1).astype(jnp.float32)  # max over interests
        k = min(top_k, cand_local)
        sv, si = lax.top_k(scores, k)
        gv = lax.all_gather(sv, axes, tiled=True)              # [world*k]
        gi = lax.all_gather(cand_ids.reshape(-1)[si], axes, tiled=True)
        fv, fi = lax.top_k(gv, top_k)
        return fv, gi[fi]

    def wrapped(state, batch, cand_ids):
        emb_specs = state_specs(plan, axes, state["dense"], None)["emb"]
        rep = jax.tree.map(lambda x: P(*((None,) * len(x.shape))), state["dense"])
        bspec = jax.tree.map(lambda x: P(*((None,) * len(x.shape))), batch)
        f = jax.shard_map(local_fn, mesh=mesh,
                          in_specs=(emb_specs, rep, bspec, P(axes)),
                          out_specs=(P(), P()), check_vma=False)
        return f(state["emb"], state["dense"], batch, cand_ids)

    return jax.jit(wrapped)
