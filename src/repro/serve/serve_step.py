"""Inference steps for WDL models: online (p99) / bulk scoring / retrieval.

Same shard_map program shape as training minus the backward: the shared
``repro.engine.EmbeddingEngine`` executes the packed lookups (including the
HybridHash read path and K-Interleaving waves) -> interactions -> sigmoid
scores. Retrieval scores one query against 1M candidates: two-tower archs
(sasrec / mind) embed the user once and dot against mesh-sharded candidate
item rows served by the same engine with a widened bucket capacity, with a
distributed top-k; pure-CTR archs (deepfm / dcn-v2) run a bulk forward over
the candidate batch (batched-dot, never a loop).

All sharding specs are built once at trace-construction time — nothing is
recomputed per call. The lookup strategy is selectable per packed group via
``ServeConfig.strategy``: a registry name (``'picasso' | 'hybrid' | 'ps' |
'picasso_l2' | 'mp_nodedup' | 'allgather_rows'``) broadcasts,
``'mixed'``/``'auto'`` or a ``{gid: name}`` dict serves each
group through its own assigned path (see ``repro.core.assign``), so serving
benchmarks can A/B pure against mixed layouts.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.features import field_index, pack_group
from repro.core.packing import PicassoPlan
from repro.dist.compat import shard_map
from repro.dist.sharding import batch_specs, emb_specs, replicated
from repro.engine import EmbeddingEngine
from repro.models.wdl import WDLModel


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Serving-side engine knobs (mirrors TrainConfig for the sparse path)."""

    # registry name, 'mixed'/'auto', {gid: name}, or a StrategyAssignment
    strategy: Any = "picasso"
    use_cache: bool = True
    use_l2: bool = True   # L2 host tier (plan-budgeted, behind L1)
    # fused Pallas sparse kernels: 'auto' (backend default) | 'on' | 'off'
    use_fused_kernels: Any = "auto"


def _mesh_world(mesh, axes):
    return int(np.prod([mesh.shape[a] for a in axes]))


def make_serve_step(model: WDLModel, plan: PicassoPlan, mesh, axes, global_batch: int,
                    use_cache: bool = True, strategy: Any = "picasso",
                    scfg: Optional[ServeConfig] = None):
    """Forward-only scoring: batch -> sigmoid probabilities [B, n_tasks].

    ``scfg`` bundles the engine knobs; the bare ``use_cache``/``strategy``
    kwargs are kept as sugar and ignored when ``scfg`` is given.
    """
    scfg = scfg or ServeConfig(strategy=strategy, use_cache=use_cache)
    world = _mesh_world(mesh, axes)
    engine = EmbeddingEngine(plan, axes, world, strategy=scfg.strategy,
                             use_cache=scfg.use_cache, use_l2=scfg.use_l2,
                             use_fused_kernels=scfg.use_fused_kernels)

    # specs are static per (model, plan): build them once, not per trace call
    especs = emb_specs(plan, axes)
    rep = replicated(jax.eval_shape(lambda k: model.init_dense(k),
                                    jax.random.PRNGKey(0)))

    def local_fn(emb, dense, batch):
        packed = {g.gid: pack_group(g, batch["fields"]) for g in plan.groups}
        pooled, _ctx = engine.forward(emb, packed)
        logits = model.apply(dense, pooled, batch)
        return jax.nn.sigmoid(logits)

    def wrapped(state, batch):
        f = shard_map(local_fn, mesh=mesh,
                      in_specs=(especs, rep, batch_specs(batch, axes)),
                      out_specs=P(axes, None), check_vma=False)
        return f(state["emb"], state["dense"], batch)

    return jax.jit(wrapped)


def make_retrieval_step(model: WDLModel, plan: PicassoPlan, mesh, axes,
                        n_candidates: int, top_k: int = 100,
                        strategy: Any = "picasso",
                        scfg: Optional[ServeConfig] = None,
                        score_chunk: Optional[int] = None):
    """Two-tower retrieval: one user -> top-k of 1M+ candidates.

    The user representation is computed from the behaviour sequence
    (self_attn_seq / capsule interaction); candidate ids are mesh-sharded,
    their rows come from the *local* slice of the MP item table via the same
    packed-lookup engine, scores are a batched dot, and top-k is
    local-top-k -> all_gather -> global-top-k.

    ``score_chunk`` bounds per-shard memory: the local candidate slice is
    scored in fixed-size chunks (``lax.scan`` over ``lax.top_k``-merged
    running bests — a streaming top-k), so the engine's bucket capacity and
    every intermediate scale with the *chunk*, not with ``n_candidates``.
    ``None``/0 scores the whole local slice in one chunk (the old bound).
    The merge keeps the single-chunk tie-break order, so chunked and
    unchunked retrieval return identical results.

    Retrieval always runs uncached: only ``scfg.strategy`` is honoured here;
    ``scfg.use_cache`` is ignored (the candidate chunk has no skew head for
    the hot tier to absorb, and retrieval plans are built cache-free).
    """
    scfg = scfg or ServeConfig(strategy=strategy, use_cache=False)
    world = _mesh_world(mesh, axes)
    cand_local = n_candidates // world
    chunk = int(score_chunk) if score_chunk else cand_local
    chunk = max(1, min(chunk, cand_local))
    n_chunks = -(-cand_local // chunk)
    pad = n_chunks * chunk - cand_local
    fidx = field_index(model.plan)
    item_field = next(f.name for f in model.cfg.fields
                      if f.pooling == "none" and f.max_len > 1)
    gid = fidx[item_field].gid

    engine = EmbeddingEngine(plan, axes, world, strategy=scfg.strategy,
                             use_cache=False,
                             use_fused_kernels=scfg.use_fused_kernels)
    # candidate tower: same assignment, but buckets sized for one score
    # chunk — per-shard memory no longer grows with n_candidates
    cand_engine = EmbeddingEngine(
        plan, axes, world, strategy=scfg.strategy, use_cache=False,
        use_fused_kernels=scfg.use_fused_kernels,
        capacity={**plan.capacity, gid: max(plan.capacity[gid], chunk)})

    especs = emb_specs(plan, axes)
    rep = replicated(jax.eval_shape(lambda k: model.init_dense(k),
                                    jax.random.PRNGKey(0)))

    def local_fn(emb, dense, batch, cand_ids):
        # --- user tower (batch=1, replicated compute) -----------------------
        packed = {g.gid: pack_group(g, batch["fields"]) for g in plan.groups}
        pooled, _ctx = engine.forward(emb, packed)
        user = model.user_repr(dense, pooled, batch)          # [K, D]

        # --- candidate tower: chunked scoring + streaming top-k -------------
        ids_flat = cand_ids.reshape(-1)
        if pad:
            ids_flat = jnp.concatenate(
                [ids_flat, jnp.broadcast_to(ids_flat[:1], (pad,))])
        valid = jnp.arange(n_chunks * chunk, dtype=jnp.int32) < cand_local
        k = min(top_k, cand_local)

        def score_one(carry, x):
            best_v, best_i = carry
            cids, cval = x
            rows = cand_engine.lookup_rows(emb, gid, cids)
            sc = jnp.max(rows @ user.T, axis=-1).astype(jnp.float32)
            sc = jnp.where(cval, sc, -jnp.inf)      # mask the pad tail
            av = jnp.concatenate([best_v, sc])
            ai = jnp.concatenate([best_i, cids])
            nv, nix = lax.top_k(av, k)
            return (nv, jnp.take(ai, nix)), None

        init = (jnp.full((k,), -jnp.inf, jnp.float32),
                jnp.zeros((k,), cand_ids.dtype))
        (sv, s_ids), _ = lax.scan(
            score_one, init, (ids_flat.reshape(n_chunks, chunk),
                              valid.reshape(n_chunks, chunk)))
        gv = lax.all_gather(sv, axes, tiled=True)              # [world*k]
        gi = lax.all_gather(s_ids, axes, tiled=True)
        fv, fi = lax.top_k(gv, top_k)
        return fv, gi[fi]

    def wrapped(state, batch, cand_ids):
        bspec = jax.tree.map(lambda x: P(*((None,) * len(x.shape))), batch)
        f = shard_map(local_fn, mesh=mesh,
                      in_specs=(especs, rep, bspec, P(axes)),
                      out_specs=(P(), P()), check_vma=False)
        return f(state["emb"], state["dense"], batch, cand_ids)

    return jax.jit(wrapped)
