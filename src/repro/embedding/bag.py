"""EmbeddingBag built from JAX primitives (no native op exists).

``jnp.take`` + ``jax.ops.segment_sum`` — this is the pure-jnp oracle the
Pallas kernel in kernels/embedding_bag.py is validated against, and the
single-device fallback path of the MP engine.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def embedding_bag(
    table: jnp.ndarray,       # [V, D]
    ids: jnp.ndarray,         # [N]
    seg: jnp.ndarray,         # [N] bag index, non-decreasing not required
    n_bags: int,
    weights: Optional[jnp.ndarray] = None,  # [N]
) -> jnp.ndarray:
    """sum-pool EmbeddingBag: out[b] = sum_{i: seg[i]==b} w[i] * table[ids[i]]."""
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    return jax.ops.segment_sum(rows, seg, num_segments=n_bags)
