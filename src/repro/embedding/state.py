"""Per-group embedding state (table shard + adagrad acc + FCounter + cache)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.packed_embedding import CacheState, init_cache
from repro.core.packing import PackedGroup, PicassoPlan


class EmbeddingState(NamedTuple):
    w: jnp.ndarray       # [rows, D]   (sharded over the whole mesh)
    acc: jnp.ndarray     # [rows, 1]   adagrad accumulator
    counts: jnp.ndarray  # [rows]      FCounter (warm-up + running stats)
    cache: CacheState    # replicated hot tier


def init_group_state(key: jax.Array, group: PackedGroup, hot_rows: int,
                     dtype=jnp.float32) -> EmbeddingState:
    scale = 1.0 / jnp.sqrt(jnp.asarray(max(group.dim, 1), jnp.float32))
    w = jax.random.normal(key, (group.rows, group.dim), dtype) * scale
    return EmbeddingState(
        w=w,
        acc=jnp.zeros((group.rows, 1), dtype),
        counts=jnp.zeros((group.rows,), jnp.int32),
        cache=init_cache(hot_rows, group.dim, group.rows, dtype),
    )


def init_embedding_state(key: jax.Array, plan: PicassoPlan,
                         dtype=jnp.float32) -> Dict[int, EmbeddingState]:
    keys = jax.random.split(key, len(plan.groups))
    return {
        g.gid: init_group_state(keys[i], g, plan.cache_rows.get(g.gid, 0), dtype)
        for i, g in enumerate(plan.groups)
    }


def abstract_embedding_state(plan: PicassoPlan, dtype=jnp.float32) -> Dict[int, EmbeddingState]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    out = {}
    for g in plan.groups:
        h = plan.cache_rows.get(g.gid, 0)
        out[g.gid] = EmbeddingState(
            w=jax.ShapeDtypeStruct((g.rows, g.dim), dtype),
            acc=jax.ShapeDtypeStruct((g.rows, 1), dtype),
            counts=jax.ShapeDtypeStruct((g.rows,), jnp.int32),
            cache=CacheState(
                keys=jax.ShapeDtypeStruct((h,), jnp.int32),
                rows=jax.ShapeDtypeStruct((h, g.dim), dtype),
                acc=jax.ShapeDtypeStruct((h, 1), dtype),
            ),
        )
    return out
