"""Per-group embedding state (table shard + adagrad acc + FCounter + caches).

``l2`` is the optional host-memory cache tier behind the replicated hot tier
(``cache``): same ``CacheState`` container, more rows, filled by the flush
with the frequency ranks just below the L1 set. It is ``None`` whenever the
plan budgets no L2 rows for the group — ``None`` is an empty pytree node, so
plans without an L2 budget keep the exact pre-L2 state structure (sharding
specs, checkpoints, and donation all line up with older runs).

On a real TPU deployment the L2 leaves are *intended* to live in pinned host
memory (``memory_kind='pinned_host'``): ``pin_l2_to_host`` is the placement
hook, wired into both launchers behind ``--pin-l2``. The jitted step
shardings do not carry memory kinds yet, so the repro keeps the tier as
ordinary replicated arrays — the math is identical, only the placement
differs (see its docstring and ROADMAP for that remaining limitation).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packed_embedding import (CacheState, ProjState, init_cache,
                                         proj_pinv)
from repro.core.packing import PackedGroup, PicassoPlan


class EmbeddingState(NamedTuple):
    w: jnp.ndarray       # [rows, D]   (sharded over the whole mesh; D is the
    #                      group's NARROW width for picasso_narrow groups)
    acc: jnp.ndarray     # [rows, 1]   adagrad accumulator
    counts: jnp.ndarray  # [rows]      FCounter (warm-up + running stats)
    cache: CacheState    # replicated hot tier (L1) — always model width
    l2: Optional[CacheState] = None  # host-memory tier (L2), None = no tier
    proj: Optional[ProjState] = None  # learned [d, D] up-projection; set
    #   exactly when the master is narrow (None keeps the pre-narrow pytree
    #   structure for every other group, like the l2 leaf does)


def _np_proj_kernel(gid: int, nd: int, d: int) -> np.ndarray:
    """Deterministic projection init, shared by the jit init path and the
    host-side migration (a re-widened group must get bit-identical fresh
    projections in both): orthonormal ROWS (QR of a seeded normal), so at
    init ``P @ P^T = I`` — widening is an isometry and the pseudo-inverse is
    exactly ``P^T``. Seeded per (gid, d, D) so groups decorrelate."""
    rng = np.random.default_rng(
        np.random.SeedSequence(entropy=0x91CA550, spawn_key=(gid, nd, d)))
    a = rng.standard_normal((d, nd))
    q, _ = np.linalg.qr(a)            # [D, nd], orthonormal columns
    return np.ascontiguousarray(q.T.astype(np.float32))  # [nd, D]


def init_proj(gid: int, nd: int, d: int, dtype=jnp.float32) -> ProjState:
    return ProjState(kernel=jnp.asarray(_np_proj_kernel(gid, nd, d), dtype),
                     acc=jnp.zeros((nd, 1), dtype))


def init_group_state(key: jax.Array, group: PackedGroup, hot_rows: int,
                     dtype=jnp.float32, l2_rows: int = 0,
                     narrow_dim: Optional[int] = None) -> EmbeddingState:
    """``narrow_dim`` < the group dim makes the MASTER table narrow (cold ids
    live at width ``d`` and are projected up at lookup); the cache tiers stay
    at the full model width — hot ids are always wide on device."""
    nd = group.dim if narrow_dim is None else int(narrow_dim)
    narrow = 0 < nd < group.dim
    width = nd if narrow else group.dim
    scale = 1.0 / jnp.sqrt(jnp.asarray(max(width, 1), jnp.float32))
    w = jax.random.normal(key, (group.rows, width), dtype) * scale
    return EmbeddingState(
        w=w,
        acc=jnp.zeros((group.rows, 1), dtype),
        counts=jnp.zeros((group.rows,), jnp.int32),
        cache=init_cache(hot_rows, group.dim, group.rows, dtype),
        l2=(init_cache(l2_rows, group.dim, group.rows, dtype)
            if l2_rows > 0 else None),
        proj=(init_proj(group.gid, width, group.dim, dtype)
              if narrow else None),
    )


def init_embedding_state(key: jax.Array, plan: PicassoPlan,
                         dtype=jnp.float32) -> Dict[int, EmbeddingState]:
    keys = jax.random.split(key, len(plan.groups))
    return {
        g.gid: init_group_state(keys[i], g, plan.cache_rows.get(g.gid, 0),
                                dtype, l2_rows=plan.l2_rows.get(g.gid, 0),
                                narrow_dim=plan.narrow_width(g.gid))
        for i, g in enumerate(plan.groups)
    }


def abstract_embedding_state(plan: PicassoPlan, dtype=jnp.float32) -> Dict[int, EmbeddingState]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    out = {}
    for g in plan.groups:
        h = plan.cache_rows.get(g.gid, 0)
        h2 = plan.l2_rows.get(g.gid, 0)
        nd = plan.narrow_width(g.gid)
        out[g.gid] = EmbeddingState(
            w=jax.ShapeDtypeStruct((g.rows, nd), dtype),
            acc=jax.ShapeDtypeStruct((g.rows, 1), dtype),
            counts=jax.ShapeDtypeStruct((g.rows,), jnp.int32),
            cache=CacheState(
                keys=jax.ShapeDtypeStruct((h,), jnp.int32),
                rows=jax.ShapeDtypeStruct((h, g.dim), dtype),
                acc=jax.ShapeDtypeStruct((h, 1), dtype),
            ),
            l2=(CacheState(
                keys=jax.ShapeDtypeStruct((h2,), jnp.int32),
                rows=jax.ShapeDtypeStruct((h2, g.dim), dtype),
                acc=jax.ShapeDtypeStruct((h2, 1), dtype),
            ) if h2 > 0 else None),
            proj=(ProjState(
                kernel=jax.ShapeDtypeStruct((nd, g.dim), dtype),
                acc=jax.ShapeDtypeStruct((nd, 1), dtype),
            ) if nd < g.dim else None),
        )
    return out


def pin_l2_to_host(state: Any, mesh=None) -> Any:
    """Best effort: move every L2 tier leaf to pinned host memory.

    Wired into both launchers behind ``--pin-l2`` (the trainer re-applies it
    after every replan migration). On backends that expose
    ``memory_kind='pinned_host'`` the L2
    leaves are re-placed replicated-over-``mesh`` in host memory (so the
    mesh-wide replication the sharding specs declare is preserved — this
    requires ``mesh``; without one, or on backends without host memory kinds
    such as the CPU test rig, the state is returned unchanged). The jitted
    train step keeps the placement across steps via memory-kind-aware
    ``out_shardings`` (``repro.dist.sharding.emb_shardings(pin_l2=True)``),
    so this initial ``device_put`` is the only bulk host copy.
    """
    from jax.sharding import NamedSharding, PartitionSpec

    if mesh is None:
        return state
    try:
        dev = jax.local_devices()[0]
        kind = dev.memory("pinned_host").kind  # raises if unsupported
        host = NamedSharding(mesh, PartitionSpec(), memory_kind=kind)
    except Exception:
        return state

    def move(st):
        if not isinstance(st, EmbeddingState) or st.l2 is None:
            return st
        return st._replace(
            l2=jax.tree.map(lambda x: jax.device_put(x, host), st.l2))

    if isinstance(state, dict) and "emb" in state:
        return {**state, "emb": {k: move(v) for k, v in state["emb"].items()}}
    if isinstance(state, dict):
        return {k: move(v) for k, v in state.items()}
    return move(state)


def l2_pinning_supported() -> bool:
    """True when this backend exposes a ``pinned_host`` memory space (the
    precondition for ``pin_l2_to_host`` to do anything)."""
    try:
        jax.local_devices()[0].memory("pinned_host")
        return True
    except Exception:
        return False


_PIN_L2_WARNED = False


def warn_pin_l2_limits() -> None:
    """One-time ``--pin-l2`` caveat, printed by both launchers.

    On backends that expose ``pinned_host`` the placement is now real across
    steps (memory-kind-aware jit shardings,
    ``repro.dist.sharding.emb_shardings(pin_l2=True)``); on backends without
    such a memory space the flag is a no-op outright — the user asked for
    host residency they are not getting, so say so once."""
    global _PIN_L2_WARNED
    if _PIN_L2_WARNED:
        return
    _PIN_L2_WARNED = True
    if not l2_pinning_supported():
        print("[pin-l2] warning: this backend exposes no 'pinned_host' "
              "memory kind — --pin-l2 is a no-op here (see the --pin-l2 "
              "row in README.md for the flag's documented limits)")


# ---------------------------------------------------------------------------
# plan-revision state migration (repro.runtime replanning loop)
# ---------------------------------------------------------------------------


def tier_gates(plan: PicassoPlan, gid: int, *, use_cache: bool = True,
               use_l2: bool = True) -> Tuple[bool, bool]:
    """(cache_on, l2_on) for one group — the exact gating rule the engine
    applies (strategy class attrs x plan budgets x engine flags), recomputed
    from the plan's recorded assignment. Groups without a recorded strategy
    default to 'picasso', mirroring ``make_flush_fn``'s broadcast default.
    """
    # lazy import: engine.strategies imports this module (EmbeddingState)
    from repro.engine.strategies import get_strategy

    cls = get_strategy(plan.strategy.get(gid, "picasso"))
    cache_on = bool(use_cache and cls.uses_cache
                    and plan.cache_rows.get(gid, 0) > 0)
    l2_on = bool(use_l2 and cache_on and cls.uses_l2
                 and plan.l2_rows.get(gid, 0) > 0)
    return cache_on, l2_on


def _np_tier(st) -> CacheState:
    return CacheState(*(np.asarray(jax.device_get(x)) for x in st))


def _np_write_back(w: np.ndarray, acc: np.ndarray, tier: CacheState) -> None:
    """Owner write-back of a replicated tier into the (host-copy) master
    arrays: authoritative tier rows + optimizer slots land on their row ids.
    Sentinel keys (>= rows_padded, i.e. empty slots) are skipped."""
    keys = np.asarray(tier.keys)
    mine = keys < w.shape[0]
    w[keys[mine]] = np.asarray(tier.rows)[mine].astype(w.dtype)
    acc[keys[mine]] = np.asarray(tier.acc)[mine].astype(acc.dtype)


def _np_empty_tier(h: int, d: int, rows_padded: int, dtype) -> CacheState:
    return CacheState(keys=np.full((h,), rows_padded, np.int32),
                      rows=np.zeros((h, d), dtype),
                      acc=np.zeros((h, 1), dtype))


def _np_load_tier(w: np.ndarray, acc: np.ndarray, keys: np.ndarray,
                  rows_padded: int, dtype) -> CacheState:
    tier = _np_empty_tier(keys.shape[0], w.shape[1], rows_padded, dtype)
    tier.keys[:] = keys
    mine = keys < rows_padded
    tier.rows[mine] = w[keys[mine]].astype(dtype)
    tier.acc[mine] = acc[keys[mine]].astype(dtype)
    return tier


def _rank_tier_keys(counts: np.ndarray, h1: int, h2: int, rows_padded: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Top-(h1+h2) row ids by measured frequency, split hottest-h1 / next-h2
    (the host-side analogue of the two-tier flush ranking). Rows with zero
    counts never enter a tier (sentinel instead), matching ``flush_cache``'s
    ``tvals > 0`` guard."""
    h = h1 + h2
    c = np.asarray(counts).astype(np.int64, copy=False).reshape(-1)
    order = np.argsort(-c, kind="stable")[:h]
    ranked = np.where(c[order] > 0, order, rows_padded)
    if ranked.shape[0] < h:  # tier larger than the table (degenerate)
        ranked = np.concatenate(
            [ranked, np.full((h - ranked.shape[0],), rows_padded, np.int64)])
    keys1 = np.sort(ranked[:h1]).astype(np.int32)
    keys2 = np.sort(ranked[h1:]).astype(np.int32)
    return keys1, keys2


def _np_proj_pinv(kernel: np.ndarray, ridge: float = 1e-6) -> np.ndarray:
    """Host mirror of ``packed_embedding.proj_pinv`` (regularized right
    pseudo-inverse ``P^T (P P^T + lam I)^{-1}``), used when migration must
    narrow wide rows."""
    k = np.asarray(kernel, np.float64)
    gram = k @ k.T
    eye = np.eye(gram.shape[0])
    return (k.T @ np.linalg.solve(gram + ridge * eye, eye)).astype(np.float32)


def _migrate_group(group: PackedGroup, st: EmbeddingState,
                   gates_old: Tuple[bool, bool], gates_new: Tuple[bool, bool],
                   h1_new: int, h2_new: int, cache_update: str,
                   nd_old: int, nd_new: int) -> EmbeddingState:
    """Move one group's live state onto new tier budgets/gating (host numpy).

    1. In 'psum' mode, active tiers are authoritative for their rows between
       flushes: write both back into the master shard first, so no update is
       lost when the tier shrinks or disappears. ('stale' mode: the master
       is already exact; tiers are read-only snapshots — no write-back.)
       Narrow masters (``nd_old < dim``) take the write-back through the
       projection's pseudo-inverse.
    2. Re-rank tier residency from the measured FCounter: the hottest
       ``h1_new`` rows seed the new L1 and the next ``h2_new`` the new L2
       (disjoint, like the two-tier flush), loaded from the just-synced
       master so rows and adagrad slots migrate together. New tiers load
       from a full-width view: ids resident in the old tiers carry their
       EXACT wide rows across (psum mode); everything else is widened
       through the projection.
    3. Width transitions re-master the table: ``nd`` widening re-projects
       every row up (``w @ P``, exact for tier-carried ids), narrowing goes
       through a fresh deterministic projection's pseudo-inverse. An
       unchanged narrow width keeps the learned projection AND the narrow
       master bitwise (no lossy widen/narrow round trip).
    4. Optimizer slots and FCounter mass are preserved exactly for ids that
       don't change tier (``acc`` is per-row and width-independent).
    """
    cache_on_old, l2_on_old = gates_old
    cache_on_new, l2_on_new = gates_new
    dim = group.dim
    w = np.array(jax.device_get(st.w))      # mutable host copies
    acc = np.array(jax.device_get(st.acc))
    counts = np.asarray(jax.device_get(st.counts))
    dtype = w.dtype
    rows_padded = group.rows

    narrow_old = st.proj is not None and w.shape[1] < dim
    proj_old = (np.asarray(jax.device_get(st.proj.kernel), np.float32)
                if narrow_old else None)
    pinv_old = _np_proj_pinv(proj_old) if narrow_old else None

    old_tiers = []
    if cache_on_old:
        old_tiers.append(_np_tier(st.cache))
    if l2_on_old and st.l2 is not None:
        old_tiers.append(_np_tier(st.l2))

    if cache_update == "psum":
        for tier in old_tiers:
            if narrow_old:  # wide tier rows -> narrow master via pinv
                keys = np.asarray(tier.keys)
                mine = keys < rows_padded
                w[keys[mine]] = (np.asarray(tier.rows)[mine].astype(np.float32)
                                 @ pinv_old).astype(dtype)
                acc[keys[mine]] = np.asarray(tier.acc)[mine].astype(acc.dtype)
            else:
                _np_write_back(w, acc, tier)

    # Full-width view used for tier loads and width transitions. For narrow
    # masters the widened rows are approximations — except for ids the old
    # tiers held, whose exact wide rows override (psum mode: the tier was
    # authoritative; stale mode: tiers are snapshots, master wins).
    if narrow_old:
        w_wide = (w.astype(np.float32) @ proj_old).astype(dtype)
        if cache_update == "psum":
            for tier in old_tiers:
                keys = np.asarray(tier.keys)
                mine = keys < rows_padded
                w_wide[keys[mine]] = np.asarray(tier.rows)[mine].astype(dtype)
    else:
        w_wide = w

    proj: Optional[ProjState] = None
    if 0 < nd_new < dim:
        if narrow_old and nd_new == nd_old:
            w_new = w  # exact narrow pass-through; learned projection survives
            proj = ProjState(
                kernel=np.asarray(jax.device_get(st.proj.kernel)),
                acc=np.asarray(jax.device_get(st.proj.acc)))
        else:  # widening round trip or first narrowing: fresh projection
            kern = _np_proj_kernel(group.gid, nd_new, dim)
            w_new = (w_wide.astype(np.float32)
                     @ _np_proj_pinv(kern)).astype(dtype)
            proj = ProjState(kernel=kern.astype(dtype),
                             acc=np.zeros((nd_new, 1), dtype))
    else:
        w_new = w_wide  # re-widened (or was never narrow)

    keys1, keys2 = _rank_tier_keys(counts,
                                   h1_new if cache_on_new else 0,
                                   h2_new if l2_on_new else 0, rows_padded)
    if cache_on_new:
        cache = _np_load_tier(w_wide, acc, keys1, rows_padded, dtype)
    else:  # allocated (plan budgets rows) but inert under the new strategy
        cache = _np_empty_tier(h1_new, group.dim, rows_padded, dtype)
    l2: Optional[CacheState] = None
    if h2_new > 0:
        l2 = (_np_load_tier(w_wide, acc, keys2, rows_padded, dtype)
              if l2_on_new
              else _np_empty_tier(h2_new, group.dim, rows_padded, dtype))
    return EmbeddingState(w=w_new, acc=acc, counts=counts, cache=cache,
                          l2=l2, proj=proj)


def _reshard_group_state(group: PackedGroup, st: EmbeddingState
                         ) -> EmbeddingState:
    """Re-cut one group's state for a new padded row count (host numpy).

    A world-size change re-pads the packed table (``rows = _pad_to(logical,
    world)``) without touching the logical rows, so the migration is a pure
    permutation plus padding surgery:

    - master ``w``/``acc``/FCounter ``counts`` are zero-extended (scale-down
      in world can mean MORE padding) or truncated — only ever padding rows,
      which are never looked up; a nonzero FCounter in the truncated tail
      would mean a real row is about to be dropped, so that raises;
    - tier sentinel keys are remapped: an empty slot holds ``keys ==
      rows_padded``, and every key >= ``min(r_old, r_new)`` is by
      construction a sentinel (valid residents are logical rows, which fit
      under both paddings), so they all move to the NEW sentinel value.
      Resident keys, rows, and adagrad slots carry bitwise.
    - the learned projection (narrow masters) is row-count-independent and
      carries bitwise.
    """
    w = np.array(jax.device_get(st.w))
    acc = np.array(jax.device_get(st.acc))
    counts = np.array(jax.device_get(st.counts))
    r_old, r_new = int(w.shape[0]), int(group.rows)
    dtype = w.dtype
    if r_new > r_old:
        pad = r_new - r_old
        w = np.concatenate([w, np.zeros((pad, w.shape[1]), dtype)])
        acc = np.concatenate([acc, np.zeros((pad, 1), acc.dtype)])
        counts = np.concatenate([counts, np.zeros((pad,), counts.dtype)])
    elif r_new < r_old:
        if np.asarray(counts[r_new:]).any():
            raise ValueError(
                f"g{group.gid}: resharding {r_old} -> {r_new} rows would "
                "drop rows with nonzero FCounter mass — the truncated tail "
                "must be pure padding")
        w, acc, counts = w[:r_new], acc[:r_new], counts[:r_new]
    cut = min(r_old, r_new)

    def remap(tier: Optional[CacheState]) -> Optional[CacheState]:
        if tier is None:
            return None
        keys = np.asarray(jax.device_get(tier.keys))
        keys = np.where(keys >= cut, r_new, keys).astype(np.int32)
        return CacheState(keys=keys,
                          rows=np.asarray(jax.device_get(tier.rows)),
                          acc=np.asarray(jax.device_get(tier.acc)))

    proj = None
    if st.proj is not None:
        proj = ProjState(kernel=np.asarray(jax.device_get(st.proj.kernel)),
                         acc=np.asarray(jax.device_get(st.proj.acc)))
    return EmbeddingState(w=w, acc=acc, counts=counts,
                          cache=remap(st.cache), l2=remap(st.l2), proj=proj)


def reshard_state(new_plan: PicassoPlan, state: Any) -> Any:
    """Re-cut live embedding state onto ``new_plan``'s padded row counts.

    The state-side half of ``core.packing.reshard_plan``: per group, pad or
    truncate the padding rows and remap tier sentinel keys
    (``_reshard_group_state``); groups whose rows already match pass through
    untouched. Accepts the full train/serve state dict (``{"emb": ...}``) or
    the bare per-group emb dict. Returns host (numpy) arrays for resharded
    groups — callers re-place the state under the new mesh's shardings
    (``runtime.elastic.place_state``) before stepping.
    """
    if isinstance(state, dict) and "emb" in state:
        return {**state, "emb": reshard_state(new_plan, state["emb"])}
    out = {}
    for g in new_plan.groups:
        key = str(g.gid) if str(g.gid) in state else g.gid
        st = state[key]
        if int(np.shape(st.w)[0]) == g.rows:
            out[key] = st
        else:
            out[key] = _reshard_group_state(g, st)
    return out


def migrate_state(old_plan: PicassoPlan, new_plan: PicassoPlan, state: Any, *,
                  use_cache: bool = True, use_l2: bool = True,
                  cache_update: str = "psum") -> Any:
    """Carry live embedding state from ``old_plan`` to ``new_plan``.

    The two plans must be revisions of one structural plan (same gids, same
    packed dims — ``revise_plan`` and ``reshard_plan`` guarantee this); what
    may differ is ``cache_rows``/``l2_rows``, the per-group strategy
    assignment, and — across a world-size change (``reshard_plan``) — the
    padded row counts, which are re-cut first via ``_reshard_group_state``
    (a pure padding/sentinel permutation, exact for every logical row).

    Per group:

    - **no-change pass-through** — identical tier shapes *and* identical
      gating return the group's arrays untouched (bitwise: a replan that
      recompiles to the same plan is a no-op);
    - otherwise the group is migrated on host (``_migrate_group``): 'psum'
      tiers are written back so every master row and adagrad slot survives
      exactly, then the new tiers are re-seeded with the measured top-(H1+H2)
      rows split hottest-H1 -> L1 / next-H2 -> L2. Narrow-width changes
      (``plan.narrow_width``) re-master the table across the projection:
      ids heating into a tier re-widen, cooling ids narrow through the
      pseudo-inverse, and ids staying tier-resident carry exact wide rows.

    ``use_cache``/``use_l2``/``cache_update`` MUST mirror the engine flags
    the state was trained under (same contract as ``make_flush_fn``).
    Accepts the full train/serve state dict (``{"emb": ...}``) or the bare
    per-group emb dict; returns the same shape of structure. Migrated groups
    come back as host (numpy) arrays — callers re-place them on the mesh
    (``repro.runtime.Replanner`` does) before stepping.
    """
    if isinstance(state, dict) and "emb" in state:
        return {**state, "emb": migrate_state(
            old_plan, new_plan, state["emb"], use_cache=use_cache,
            use_l2=use_l2, cache_update=cache_update)}

    old_gids = sorted(g.gid for g in old_plan.groups)
    new_gids = sorted(g.gid for g in new_plan.groups)
    if old_gids != new_gids:
        raise ValueError(
            f"migrate_state needs revisions of one structural plan; group "
            f"sets differ: {old_gids} vs {new_gids}")
    out: Dict[str, EmbeddingState] = {}
    for g in new_plan.groups:
        og = old_plan.group(g.gid)
        if og.dim != g.dim:
            raise ValueError(
                f"g{g.gid}: packed dim changed across revisions "
                f"({og.rows}x{og.dim} -> {g.rows}x{g.dim}); only tier "
                "budgets, strategy, and world padding may change")
        h_old = (old_plan.cache_rows.get(g.gid, 0),
                 old_plan.l2_rows.get(g.gid, 0))
        h_new = (new_plan.cache_rows.get(g.gid, 0),
                 new_plan.l2_rows.get(g.gid, 0))
        gates_old = tier_gates(old_plan, g.gid, use_cache=use_cache,
                               use_l2=use_l2)
        gates_new = tier_gates(new_plan, g.gid, use_cache=use_cache,
                               use_l2=use_l2)
        nd_old = old_plan.narrow_width(g.gid)
        nd_new = new_plan.narrow_width(g.gid)
        st = state[str(g.gid)]
        if og.rows != g.rows:  # world resize: recut padding/sentinels first
            st = _reshard_group_state(g, st)
        if h_old == h_new and gates_old == gates_new and nd_old == nd_new:
            out[str(g.gid)] = st  # bitwise pass-through
        else:
            out[str(g.gid)] = _migrate_group(g, st, gates_old, gates_new,
                                             h_new[0], h_new[1], cache_update,
                                             nd_old, nd_new)
    return out
