"""Per-group embedding state (table shard + adagrad acc + FCounter + caches).

``l2`` is the optional host-memory cache tier behind the replicated hot tier
(``cache``): same ``CacheState`` container, more rows, filled by the flush
with the frequency ranks just below the L1 set. It is ``None`` whenever the
plan budgets no L2 rows for the group — ``None`` is an empty pytree node, so
plans without an L2 budget keep the exact pre-L2 state structure (sharding
specs, checkpoints, and donation all line up with older runs).

On a real TPU deployment the L2 leaves are *intended* to live in pinned host
memory (``memory_kind='pinned_host'``): ``pin_l2_to_host`` is the
experimental placement hook, but the jitted step shardings do not carry
memory kinds yet, so the repro keeps the tier as ordinary replicated arrays
— the math is identical, only the placement differs (see its docstring and
ROADMAP for the remaining follow-up).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.packed_embedding import CacheState, init_cache
from repro.core.packing import PackedGroup, PicassoPlan


class EmbeddingState(NamedTuple):
    w: jnp.ndarray       # [rows, D]   (sharded over the whole mesh)
    acc: jnp.ndarray     # [rows, 1]   adagrad accumulator
    counts: jnp.ndarray  # [rows]      FCounter (warm-up + running stats)
    cache: CacheState    # replicated hot tier (L1)
    l2: Optional[CacheState] = None  # host-memory tier (L2), None = no tier


def init_group_state(key: jax.Array, group: PackedGroup, hot_rows: int,
                     dtype=jnp.float32, l2_rows: int = 0) -> EmbeddingState:
    scale = 1.0 / jnp.sqrt(jnp.asarray(max(group.dim, 1), jnp.float32))
    w = jax.random.normal(key, (group.rows, group.dim), dtype) * scale
    return EmbeddingState(
        w=w,
        acc=jnp.zeros((group.rows, 1), dtype),
        counts=jnp.zeros((group.rows,), jnp.int32),
        cache=init_cache(hot_rows, group.dim, group.rows, dtype),
        l2=(init_cache(l2_rows, group.dim, group.rows, dtype)
            if l2_rows > 0 else None),
    )


def init_embedding_state(key: jax.Array, plan: PicassoPlan,
                         dtype=jnp.float32) -> Dict[int, EmbeddingState]:
    keys = jax.random.split(key, len(plan.groups))
    return {
        g.gid: init_group_state(keys[i], g, plan.cache_rows.get(g.gid, 0),
                                dtype, l2_rows=plan.l2_rows.get(g.gid, 0))
        for i, g in enumerate(plan.groups)
    }


def abstract_embedding_state(plan: PicassoPlan, dtype=jnp.float32) -> Dict[int, EmbeddingState]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    out = {}
    for g in plan.groups:
        h = plan.cache_rows.get(g.gid, 0)
        h2 = plan.l2_rows.get(g.gid, 0)
        out[g.gid] = EmbeddingState(
            w=jax.ShapeDtypeStruct((g.rows, g.dim), dtype),
            acc=jax.ShapeDtypeStruct((g.rows, 1), dtype),
            counts=jax.ShapeDtypeStruct((g.rows,), jnp.int32),
            cache=CacheState(
                keys=jax.ShapeDtypeStruct((h,), jnp.int32),
                rows=jax.ShapeDtypeStruct((h, g.dim), dtype),
                acc=jax.ShapeDtypeStruct((h, 1), dtype),
            ),
            l2=(CacheState(
                keys=jax.ShapeDtypeStruct((h2,), jnp.int32),
                rows=jax.ShapeDtypeStruct((h2, g.dim), dtype),
                acc=jax.ShapeDtypeStruct((h2, 1), dtype),
            ) if h2 > 0 else None),
        )
    return out


def pin_l2_to_host(state: Any, mesh=None) -> Any:
    """Best effort: move every L2 tier leaf to pinned host memory.

    EXPERIMENTAL placement utility, not yet wired into the launchers (see
    ROADMAP). On backends that expose ``memory_kind='pinned_host'`` the L2
    leaves are re-placed replicated-over-``mesh`` in host memory (so the
    mesh-wide replication the sharding specs declare is preserved — this
    requires ``mesh``; without one, or on backends without host memory kinds
    such as the CPU test rig, the state is returned unchanged). Caveat: the
    jitted train/serve steps build their in-shardings from
    ``repro.dist.sharding`` specs, which carry no memory kind yet — entering
    a step re-stages the tier into device memory until those specs also
    carry ``pinned_host`` for L2 leaves (the remaining follow-up for true
    host residency on TPU).
    """
    from jax.sharding import NamedSharding, PartitionSpec

    if mesh is None:
        return state
    try:
        dev = jax.local_devices()[0]
        kind = dev.memory("pinned_host").kind  # raises if unsupported
        host = NamedSharding(mesh, PartitionSpec(), memory_kind=kind)
    except Exception:
        return state

    def move(st):
        if not isinstance(st, EmbeddingState) or st.l2 is None:
            return st
        return st._replace(
            l2=jax.tree.map(lambda x: jax.device_put(x, host), st.l2))

    if isinstance(state, dict) and "emb" in state:
        return {**state, "emb": {k: move(v) for k, v in state["emb"].items()}}
    if isinstance(state, dict):
        return {k: move(v) for k, v in state.items()}
    return move(state)
