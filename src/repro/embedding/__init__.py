from repro.embedding.bag import embedding_bag
from repro.embedding.state import EmbeddingState, init_embedding_state

__all__ = ["embedding_bag", "EmbeddingState", "init_embedding_state"]
