"""Calibrated per-op cost curves + the CostModel the assignment queries.

The hand-tuned constants in ``repro.core.assign`` (``ROUTE_OVERHEAD_ELEMS``,
``L2_HOST_FACTOR``, ...) price every candidate strategy in abstract "row
elements", which keeps the *relative* ordering plausible but means the system
cannot know whether its own decisions are right — the gap Lin et al.'s DLRM
performance model closes by predicting per-op kernel times from measured
cost curves. This module is the measured replacement:

* ``CostCurve`` — a monotone piecewise-linear fit over measured
  ``(work, microseconds)`` points for one op. Below the smallest measured
  point the curve clamps to the first measurement (the fixed launch
  overhead); past the largest it extrapolates along the last segment's
  slope. Monotonicity in the work size is *enforced* at fit time
  (``np.maximum.accumulate``), so a noisy microbench can never produce a
  model where more rows×dim is predicted cheaper.
* ``CostModel`` — the per-op curve table (one per priced op: the fused
  sparse kernels, bytes-on-wire collectives, dense matmul) plus the online
  ``correction`` factor the Replanner's feedback loop blends in, and the
  measured ``hit_prior`` that replaces ``DEFAULT_HIT_RATIO`` in the no-stats
  tier estimators. ``score_candidates`` prices exactly the same candidate
  set ``assign._score_group`` builds from constants — same keys, same
  gating inputs — but in *microseconds predicted from calibration* instead
  of abstract elements.

``repro.perf.calibration`` produces fitted models from microbenches (or the
cached, backend-stamped calibration file); ``repro.core.assign`` consumes
them via the optional ``cost_model=`` parameter (``None`` keeps the constant
model byte-for-byte, so current tests stay meaningful).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

# the ops the model prices; every calibration file must cover all of them.
# work units: "elems" ops are sized in rows*dim f32 elements touched, "wire"
# ops in bytes on the wire per shard, dense_matmul in multiply-accumulates.
PRICED_OPS = (
    "gather_pool",    # unique-row gather + segment pooling (fwd path)
    "dedup_adagrad",  # one-pass dedup + adagrad + scatter (sparse update)
    "tier_probe",     # sorted-key binary search + hit-masked row gather
    "gather_project", # narrow-row gather + learned up-projection stitch
    "wire_a2a",       # all_to_all bytes on wire (the Shuffle hops)
    "wire_ag",        # all_gather/psum bytes on wire (PS + tier maintenance)
    "dense_matmul",   # dense MACs (the narrow projection's [d,D] matmul)
)

# EMA weight for the online correction blend: high enough that a persistent
# 2x misprediction is mostly corrected within a handful of replan windows,
# low enough that one noisy window cannot whipsaw the scores.
CORRECTION_ALPHA = 0.3
# sanity clamp: a correction outside this band means the measurement is
# garbage (e.g. a stalled step), not that every kernel is 100x off
CORRECTION_BOUNDS = (0.05, 20.0)

_F32_BYTES = 4.0


@dataclass(frozen=True)
class CostCurve:
    """Monotone piecewise-linear cost fit: work size -> microseconds."""

    xs: np.ndarray  # measured work sizes, strictly increasing
    ys: np.ndarray  # fitted us per call, non-decreasing (enforced)

    @staticmethod
    def fit(samples: Sequence[Tuple[float, float]]) -> "CostCurve":
        """Fit from raw ``(work, us)`` measurements.

        Duplicate work sizes collapse to their median; the fitted values are
        then made non-decreasing (isotonic in the cheap direction: each point
        is raised to the running max), which is what makes downstream strategy
        scores provably monotone in rows and dim."""
        if not samples:
            raise ValueError("CostCurve.fit needs at least one sample")
        by_x: Dict[float, List[float]] = {}
        for x, y in samples:
            by_x.setdefault(float(x), []).append(float(y))
        xs = np.array(sorted(by_x), np.float64)
        ys = np.array([np.median(by_x[x]) for x in xs], np.float64)
        ys = np.maximum.accumulate(np.maximum(ys, 0.0))
        return CostCurve(xs=xs, ys=ys)

    def __call__(self, x: float) -> float:
        """us for ``x`` units of work (clamp left, extrapolate right)."""
        xs, ys = self.xs, self.ys
        x = float(max(x, 0.0))
        if x <= xs[0]:
            return float(ys[0])          # fixed launch overhead floor
        if x >= xs[-1]:
            if len(xs) == 1:
                return float(ys[-1])
            slope = (ys[-1] - ys[-2]) / max(xs[-1] - xs[-2], 1e-12)
            return float(ys[-1] + max(slope, 0.0) * (x - xs[-1]))
        return float(np.interp(x, xs, ys))

    def to_json(self) -> Dict[str, List[float]]:
        return {"xs": [float(v) for v in self.xs],
                "ys_us": [float(v) for v in self.ys]}

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "CostCurve":
        return CostCurve(xs=np.asarray(d["xs"], np.float64),
                         ys=np.asarray(d["ys_us"], np.float64))


@dataclass
class CostModel:
    """Fitted per-op cost curves + the online feedback state.

    ``correction`` is the multiplicative measured-vs-predicted blend the
    Replanner maintains (1.0 = trust the calibration); it scales every
    candidate score uniformly, so a systematic misprediction (untimed dense
    work, a drifted clock) self-corrects without re-ranking ops against each
    other. ``hit_prior`` replaces ``assign.DEFAULT_HIT_RATIO`` in the
    no-stats tier estimators once a measured value exists.
    """

    curves: Dict[str, CostCurve]
    backend: str = "unknown"
    interpret: bool = False
    hit_prior: float = 0.2  # assign.DEFAULT_HIT_RATIO; measured once observed
    correction: float = 1.0
    meta: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        missing = [op for op in PRICED_OPS if op not in self.curves]
        if missing:
            raise ValueError(f"cost model is missing curves for {missing}; "
                             f"priced ops are {list(PRICED_OPS)}")

    # ------------------------------------------------------------- queries
    def op_us(self, op: str, work: float) -> float:
        """Raw (uncorrected) predicted us for ``work`` units of ``op``."""
        return self.curves[op](work)

    def score_candidates(self, *, world: int, n: float, d: float,
                         skew: float = 0.0,
                         l2_rows: int = 0, l2_gain: float = 0.0,
                         narrow_dim: int = 0, narrow_gain: float = 0.0,
                         ) -> Dict[str, float]:
        """Predicted us/step for every candidate strategy of one group.

        Mirrors ``assign._score_group``'s constant formulas term by term —
        same candidate keys under the same conditions (``picasso_l2`` only
        when ``l2_rows > 0``, ``picasso_narrow`` only when
        ``0 < narrow_dim < d``) so the decision logic in ``assign`` is
        identical either way; only the prices change.
        """
        world, n, d = int(max(world, 1)), float(max(n, 1.0)), float(d)
        B = _F32_BYTES
        pool = self.op_us("gather_pool", n * d)
        upd = self.op_us("dedup_adagrad", n * d)
        probe = self.op_us("tier_probe", n * d)

        def miss_wire(frac: float, width: float) -> float:
            # ids out + rows back, fwd + bwd: two all_to_all dispatches
            return 2.0 * self.op_us("wire_a2a", n * frac * (1.0 + width) * B)

        costs: Dict[str, float] = {
            # ps: all_gather n ids from every shard, pool the world*n lookups
            # locally, psum the [world*n, D] partial rows
            "ps": (self.op_us("wire_ag", world * n * B)
                   + self.op_us("gather_pool", world * n * d)
                   + self.op_us("wire_ag", world * n * d * B)
                   + upd),
            "hybrid": pool + miss_wire(1.0, d) + upd,
            "picasso": pool + probe + miss_wire(1.0 - skew, d) + upd,
        }
        l2_maint = 0.0
        if l2_rows > 0:
            # exact-update maintenance: the cheaper of the dense tier psum
            # and the gathered hit-grad update (see apply_sparse_grads_l2)
            l2_maint = min(
                self.op_us("wire_ag", max(world - 1, 0) * n * (1.0 + d) * B),
                self.op_us("dedup_adagrad", float(l2_rows) * d))
            costs["picasso_l2"] = (
                pool + probe
                # the host tier is a second probe + a host-DMA row read,
                # priced by the same probe curve at the L2 hit volume
                + self.op_us("tier_probe", n * l2_gain * d)
                + miss_wire(1.0 - skew - l2_gain, d)
                + l2_maint + upd)
        if 0 < narrow_dim < d:
            nd = float(narrow_dim)
            costs["picasso_narrow"] = (
                pool + probe
                + self.op_us("tier_probe", n * l2_gain * d)
                + miss_wire(narrow_gain, nd)      # cold tail at narrow width
                + l2_maint
                + self.op_us("gather_project", n * d)
                + self.op_us("dense_matmul", n * nd * d)  # projection MACs
                + upd)
        c = self.correction
        return {k: v * c for k, v in costs.items()}

    # ------------------------------------------------------ step prediction
    def predict_step_us(self, plan, stats: Optional[Dict[int, np.ndarray]] = None,
                        *, world: Optional[int] = None,
                        per_device_batch: Optional[int] = None) -> float:
        """Predicted sparse-path us/step under the plan's recorded strategy.

        The Replanner compares this against measured step wall time to blend
        ``correction`` (dense compute and host overhead are deliberately in
        the measured side only — the uniform correction absorbs them)."""
        from repro.core.assign import (estimate_l2_gain, estimate_narrow_gain,
                                       estimate_skew, _ranked)

        world = int(world if world is not None else plan.world)
        batch = int(per_device_batch if per_device_batch is not None
                    else max(plan.microbatch, 1))
        total = 0.0
        for g in plan.groups:
            cache_rows = plan.cache_rows.get(g.gid, 0)
            l2_rows = plan.l2_rows.get(g.gid, 0)
            counts = _ranked(stats.get(g.gid) if stats else None, False)
            skew = estimate_skew(g, cache_rows, counts, ranked=True,
                                 cost_model=self)
            l2_gain = estimate_l2_gain(g, cache_rows, l2_rows, counts,
                                       ranked=True, cost_model=self)
            nd = int(plan.narrow_dim.get(g.gid, g.dim))
            narrow_gain = (estimate_narrow_gain(
                g, cache_rows, l2_rows, counts, ranked=True, cost_model=self)
                if 0 < nd < g.dim else 0.0)
            costs = self.score_candidates(
                world=world, n=batch * g.ids_per_sample, d=g.dim, skew=skew,
                l2_rows=l2_rows, l2_gain=l2_gain,
                narrow_dim=nd if nd < g.dim else 0, narrow_gain=narrow_gain)
            name = plan.strategy.get(g.gid, "picasso")
            total += costs.get(name, min(costs.values()))
        return total

    # ------------------------------------------------------ online feedback
    def observe_measured(self, measured_us: float, predicted_us: float,
                         alpha: float = CORRECTION_ALPHA) -> float:
        """Blend one measured-vs-predicted window into ``correction``.

        ``predicted_us`` is the *corrected* prediction (what the scores used),
        so the update is a geometric EMA toward the fixed point where
        prediction matches measurement:
        ``corr <- corr * (measured / predicted) ** alpha``. Returns the new
        correction. Degenerate inputs (non-positive times) are ignored."""
        if measured_us <= 0.0 or predicted_us <= 0.0:
            return self.correction
        ratio = measured_us / predicted_us
        lo, hi = CORRECTION_BOUNDS
        self.correction = float(np.clip(
            self.correction * ratio ** float(alpha), lo, hi))
        return self.correction

    # -------------------------------------------------------- serialization
    def to_json(self) -> Dict[str, Any]:
        return {
            "backend": self.backend,
            "interpret": bool(self.interpret),
            "hit_prior": float(self.hit_prior),
            "ops": {op: c.to_json() for op, c in self.curves.items()},
            "meta": dict(self.meta),
        }

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "CostModel":
        return CostModel(
            curves={op: CostCurve.from_json(c)
                    for op, c in d.get("ops", {}).items()},
            backend=str(d.get("backend", "unknown")),
            interpret=bool(d.get("interpret", False)),
            hit_prior=float(d.get("hit_prior", 0.2)),
            meta=dict(d.get("meta", {})),
        )


def synthetic_cost_model(per_elem_us: Optional[Mapping[str, float]] = None,
                         fixed_us: float = 1.0, **kw) -> CostModel:
    """A fully-specified linear CostModel for tests and injection.

    Every op gets the curve ``us = fixed_us + per_elem * work`` sampled at
    two points (so interpolation/extrapolation are exact). ``per_elem_us``
    overrides the default 1e-3 us/unit per op — distorting one op's slope is
    how a test flips a known group's strategy choice."""
    per = {op: 1e-3 for op in PRICED_OPS}
    per.update(per_elem_us or {})
    curves = {op: CostCurve.fit([(1.0, fixed_us + s),
                                 (1e6, fixed_us + s * 1e6)])
              for op, s in per.items()}
    return CostModel(curves=curves, backend="synthetic", **kw)
