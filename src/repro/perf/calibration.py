"""Calibration pass: microbench the priced ops, fit curves, cache to disk.

The same ops ``benchmarks/bench_kernels`` times in isolation are timed here
across a small size grid — through the **production dispatchers**
(``repro.kernels.ops`` with the default ``fused='auto'`` resolution), so the
curves price what the engine actually executes on this backend: real Pallas
kernels on TPU, the jnp reference chains on the CPU rig, the interpreter
only under the soak env var (and the calibration file is stamped with that,
so an interpreter-calibrated model is never silently reused on silicon).

Lifecycle (``get_cost_model`` — the single launcher entry point):

``off``   -> ``None``: ``repro.core.assign`` keeps its constant model,
             byte-for-byte today's behavior.
``auto``  -> load ``--calib-file`` if it exists and its backend stamp
             (backend name + interpret flag + format version) matches this
             process; otherwise run the microbenches and write the file.
``force`` -> always re-bench and overwrite the file.

The file keeps the raw ``(work, us)`` samples next to the fitted curves, so
``benchmarks/bench_calibrate`` can report measured-vs-predicted residuals
per op without re-benching.
"""
from __future__ import annotations

import json
import os
import pathlib
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.perf.cost_model import PRICED_OPS, CostCurve, CostModel

CALIB_VERSION = 1
DEFAULT_CALIB_PATH = os.path.join(
    os.path.expanduser("~"), ".cache", "repro", "calibration.json")

# size grids: 'small' is the startup default (a few hundred ms of benching),
# 'tiny' is the smoke/CI grid. ns = ids per call, ds = row dims,
# wire_kb = per-shard payloads, mm = square-matmul sides.
GRIDS: Dict[str, Dict[str, Any]] = {
    "tiny": dict(ns=(32, 128), ds=(8,), wire_kb=(4, 32), mm=(16, 48),
                 iters=1, warmup=1),
    "small": dict(ns=(64, 256, 1024), ds=(8, 32), wire_kb=(4, 64, 512),
                  mm=(32, 64, 128), iters=3, warmup=1),
}

Samples = Dict[str, List[Tuple[float, float]]]


def backend_stamp() -> Dict[str, Any]:
    """What a calibration is valid for: re-fit when any of this changes."""
    import jax

    from repro.kernels import ops

    return {"version": CALIB_VERSION,
            "backend": str(jax.default_backend()),
            "interpret": bool(ops.interpret_mode())}


def _time(fn, *args, iters: int, warmup: int) -> float:
    import jax

    out = None
    for _ in range(max(warmup, 1)):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


# ---------------------------------------------------------------------------
# per-op microbenches (production dispatchers, fused='auto')
# ---------------------------------------------------------------------------


def _bench_gather_pool(n: int, d: int, it: Mapping[str, int]) -> float:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(0)
    n_bags = max(4, n // 8)
    rows_u = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    inv = jnp.asarray(rng.integers(0, n, n).astype(np.int32))
    w = jnp.asarray(rng.normal(size=n).astype(np.float32))
    seg = np.sort(np.concatenate(
        [np.arange(n_bags), rng.integers(0, n_bags, n - n_bags)]))
    seg = jnp.asarray(seg.astype(np.int32))
    fn = jax.jit(lambda r: ops.gather_pool(r, inv, w, seg, n_bags))
    return _time(fn, rows_u, **it)


def _bench_dedup_adagrad(n: int, d: int, it: Mapping[str, int]) -> float:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(1)
    rows, hot = 4 * n, max(8, n // 8)
    w = jnp.asarray(rng.normal(size=(rows, d)).astype(np.float32))
    acc = jnp.asarray(np.abs(rng.normal(size=(rows, 1))).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, hot, n).astype(np.int32))
    g = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    valid = jnp.asarray(rng.random(n) < 0.9)
    fn = jax.jit(lambda w_, a_: ops.dedup_adagrad(w_, a_, idx, g, valid,
                                                  0.05, 1e-8))
    return _time(fn, w, acc, **it)


def _bench_tier_probe(n: int, d: int, it: Mapping[str, int]) -> float:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(2)
    h = max(8, n // 2)
    keys = jnp.asarray(np.sort(rng.choice(10 * h, h, replace=False))
                       .astype(np.int32))
    rows = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))
    uniq = jnp.sort(jnp.asarray(rng.integers(0, 10 * h, n).astype(np.int32)))
    uvalid = jnp.asarray(np.arange(n) < int(0.9 * n))
    fn = jax.jit(lambda u: ops.tier_probe(u, uvalid, keys, rows))
    return _time(fn, uniq, **it)


def _bench_gather_project(n: int, d: int, it: Mapping[str, int]) -> float:
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.default_rng(3)
    nd = max(4, d // 4)
    back = jnp.asarray(rng.normal(size=(n, nd)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, n, n).astype(np.int32))
    kept = jnp.asarray(rng.random(n) < 0.9)
    proj = jnp.asarray(rng.normal(size=(nd, d)).astype(np.float32))
    fn = jax.jit(lambda b, p: ops.gather_project(b, idx, kept, p))
    return _time(fn, back, proj, **it)


def _wire_mesh():
    """1-D mesh over every local device: the wire curves measure the real
    collective fabric of this process (a single-device mesh degenerates to
    the local-copy cost, which is the honest world=1 wire price)."""
    import jax

    from repro.dist.compat import make_submesh_compat

    return make_submesh_compat((len(jax.devices()),), ("wire",))


def _bench_wire(kind: str, per_shard_kb: int, mesh,
                it: Mapping[str, int]) -> Tuple[float, float]:
    """Returns (bytes_on_wire_per_shard, us) for one collective payload."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.dist.compat import shard_map

    world = int(mesh.devices.size)
    m = max(1, (per_shard_kb * 1024 // 4) // max(world, 1))
    if kind == "wire_a2a":
        # global [world*world, m] -> local [world, m]; all_to_all moves
        # ~world*m rows per shard
        x = jnp.zeros((world * world, m), jnp.float32)

        def local(y):
            return jax.lax.all_to_all(y, "wire", 0, 0)
    else:
        # global [world, m] -> local [1, m]; all_gather replicates world*m
        x = jnp.zeros((world, m), jnp.float32)

        def local(y):
            return jax.lax.all_gather(y, "wire", axis=0, tiled=True)

    x = jax.device_put(x, NamedSharding(mesh, P("wire", None)))
    f = jax.jit(shard_map(local, mesh=mesh, in_specs=P("wire", None),
                          out_specs=P("wire", None) if kind == "wire_a2a"
                          else P(None, None), check_vma=False))
    us = _time(f, x, **it)
    return float(world * m * 4), us


def _bench_matmul(k: int, it: Mapping[str, int]) -> float:
    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.normal(size=(k, k)).astype(np.float32))
    b = jnp.asarray(rng.normal(size=(k, k)).astype(np.float32))
    fn = jax.jit(lambda a_, b_: a_ @ b_)
    return _time(fn, a, b, **it)


def run_calibration(grid: str = "small",
                    log: Optional[Callable[[str], None]] = None) -> Samples:
    """Run the microbench grid; returns per-op raw ``(work, us)`` samples."""
    if grid not in GRIDS:
        raise ValueError(f"unknown calibration grid {grid!r}; "
                         f"options: {sorted(GRIDS)}")
    g = GRIDS[grid]
    it = {"iters": g["iters"], "warmup": g["warmup"]}
    t0 = time.perf_counter()
    samples: Samples = {op: [] for op in PRICED_OPS}
    sparse = {"gather_pool": _bench_gather_pool,
              "dedup_adagrad": _bench_dedup_adagrad,
              "tier_probe": _bench_tier_probe,
              "gather_project": _bench_gather_project}
    for op, bench in sparse.items():
        for n in g["ns"]:
            for d in g["ds"]:
                samples[op].append((float(n * d), bench(n, d, it)))
    mesh = _wire_mesh()
    for kind in ("wire_a2a", "wire_ag"):
        for kb in g["wire_kb"]:
            samples[kind].append(_bench_wire(kind, kb, mesh, it))
    for k in g["mm"]:
        samples["dense_matmul"].append((float(k) ** 3, _bench_matmul(k, it)))
    if log:
        n_pts = sum(len(v) for v in samples.values())
        log(f"calibrated {len(samples)} ops / {n_pts} grid points "
            f"(grid={grid}) in {time.perf_counter() - t0:.1f}s")
    return samples


def fit_cost_model(samples: Samples, *,
                   hit_prior: Optional[float] = None) -> CostModel:
    """Fit the monotone curves and stamp the model for this backend."""
    stamp = backend_stamp()
    kw = {} if hit_prior is None else {"hit_prior": float(hit_prior)}
    return CostModel(
        curves={op: CostCurve.fit(pts) for op, pts in samples.items()},
        backend=stamp["backend"], interpret=stamp["interpret"],
        meta={"version": stamp["version"]}, **kw)


# ---------------------------------------------------------------------------
# cache file
# ---------------------------------------------------------------------------


def save_calibration(path: os.PathLike, samples: Samples,
                     model: CostModel) -> pathlib.Path:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    payload = {**backend_stamp(), **model.to_json(),
               "samples": {op: [[float(x), float(y)] for x, y in pts]
                           for op, pts in samples.items()}}
    tmp = p.with_suffix(p.suffix + ".tmp")
    tmp.write_text(json.dumps(payload, indent=1) + "\n")
    tmp.replace(p)  # atomic: a concurrent 'auto' load never sees a torn file
    return p


def load_calibration(path: os.PathLike,
                     log: Optional[Callable[[str], None]] = None
                     ) -> Optional[CostModel]:
    """Load a cached calibration; ``None`` when missing, corrupt, or stamped
    for a different backend/interpret-mode/format (a mismatch must force a
    refit — interpreter curves reused on silicon would mis-rank every op)."""
    p = pathlib.Path(path)
    if not p.exists():
        return None
    try:
        data = json.loads(p.read_text())
    except (json.JSONDecodeError, OSError):
        if log:
            log(f"calibration file {p} unreadable; re-calibrating")
        return None
    stamp = backend_stamp()
    got = {k: data.get(k) for k in stamp}
    if got != stamp:
        if log:
            log(f"calibration stamp mismatch at {p} (file {got}, "
                f"process {stamp}); re-calibrating")
        return None
    try:
        model = CostModel.from_json(data)
    except (KeyError, ValueError, TypeError) as e:
        if log:
            log(f"calibration file {p} invalid ({e}); re-calibrating")
        return None
    return model


def load_samples(path: os.PathLike) -> Optional[Samples]:
    """Raw grid points persisted next to the fit (for residual reporting)."""
    p = pathlib.Path(path)
    if not p.exists():
        return None
    try:
        data = json.loads(p.read_text())
        return {op: [(float(x), float(y)) for x, y in pts]
                for op, pts in data.get("samples", {}).items()}
    except (json.JSONDecodeError, OSError, ValueError, TypeError):
        return None


def get_cost_model(mode: str, path: Optional[os.PathLike] = None, *,
                   grid: str = "small",
                   log: Optional[Callable[[str], None]] = None
                   ) -> Optional[CostModel]:
    """Launcher entry point for ``--calibrate {auto,force,off}``.

    ``off`` returns ``None`` (the constant model). ``auto`` loads the cached,
    backend-stamped file when valid, else benches and writes it. ``force``
    always re-benches. ``path=None`` uses ``DEFAULT_CALIB_PATH``.
    """
    if mode == "off":
        return None
    if mode not in ("auto", "force"):
        raise ValueError(f"--calibrate must be auto/force/off, got {mode!r}")
    p = pathlib.Path(path) if path else pathlib.Path(DEFAULT_CALIB_PATH)
    if mode == "auto":
        model = load_calibration(p, log=log)
        if model is not None:
            if log:
                log(f"loaded calibration from {p} "
                    f"(backend={model.backend}, interpret={model.interpret})")
            return model
    samples = run_calibration(grid, log=log)
    model = fit_cost_model(samples)
    save_calibration(p, samples, model)
    if log:
        log(f"wrote calibration to {p} (backend={model.backend})")
    return model
