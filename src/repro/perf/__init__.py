"""Measured cost model: calibrated per-op curves replacing hand constants.

``CostModel`` prices the same candidate strategies ``repro.core.assign``
scores, but in microseconds from curves fitted to microbenches of the real
dispatched ops (``calibration.run_calibration``). ``get_cost_model`` is the
launcher entry point behind ``--calibrate {auto,force,off}``.
"""
from repro.perf.cost_model import (
    CORRECTION_ALPHA,
    CORRECTION_BOUNDS,
    PRICED_OPS,
    CostCurve,
    CostModel,
    synthetic_cost_model,
)
from repro.perf.calibration import (
    CALIB_VERSION,
    DEFAULT_CALIB_PATH,
    GRIDS,
    backend_stamp,
    fit_cost_model,
    get_cost_model,
    load_calibration,
    load_samples,
    run_calibration,
    save_calibration,
)

__all__ = [
    "CALIB_VERSION",
    "CORRECTION_ALPHA",
    "CORRECTION_BOUNDS",
    "DEFAULT_CALIB_PATH",
    "GRIDS",
    "PRICED_OPS",
    "CostCurve",
    "CostModel",
    "backend_stamp",
    "fit_cost_model",
    "get_cost_model",
    "load_calibration",
    "load_samples",
    "run_calibration",
    "save_calibration",
    "synthetic_cost_model",
]
