import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the device
# count on first init). REPRO_XLA_FLAGS lets tests shrink the device count.

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402

from repro.configs.base import get_shapes, list_archs, skipped_shapes  # noqa: E402
from repro.launch.cells import build_cell                              # noqa: E402
from repro.launch.mesh import make_production_mesh, make_mesh          # noqa: E402
from repro.launch.roofline import collective_bytes, count_ops, roofline_terms  # noqa: E402


def _measure(compiled, world: int) -> dict:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: one dict per program
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    wire, per_op = collective_bytes(hlo, world)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "wire": wire, "per_op": per_op, "hlo": hlo}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             mesh=None, smoke: bool = False, tag: str = "", plan_kw=None,
             save_hlo: bool = False, cell_kw=None) -> dict:
    from repro.launch.cells import arch_kind
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    world = int(mesh.devices.size)
    shape = next(s for s in get_shapes(arch, include_skipped=True) if s.name == shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": "x".join(map(str, mesh.devices.shape)),
           "world": world, "multi_pod": multi_pod, "tag": tag}
    t0 = time.time()
    try:
        kw = dict(cell_kw or {})
        if plan_kw and shape.kind in ("train", "serve", "retrieval"):
            kw["plan_kw"] = plan_kw
        cell = build_cell(arch, shape, mesh, smoke=smoke, **kw)
        lowered = cell.fn.lower(*cell.args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            }
        except Exception as e:  # CPU backend may not support it
            rec["memory"] = {"error": str(e)}

        m = _measure(compiled, world)
        flops, byts, wire, per_op = m["flops"], m["bytes"], m["wire"], m["per_op"]

        # XLA cost_analysis counts a while-loop body ONCE (it does NOT unroll
        # even trip-2 scans — verified empirically), so the LM layer stack's
        # true cost is reconstructed from two *unrolled* compiles at L=2 and
        # L=4: body = (u4-u2)/2, total = u2 + (L-2)*body. Exact for a
        # linear-in-L program. The full scanned compile above remains the
        # memory/compile-proof artifact.
        full_cfg = None
        if arch_kind(arch) == "lm" and not smoke:
            from repro.configs.base import get_config
            full_cfg = get_config(arch)
        if full_cfg is not None and full_cfg.n_layers > 4:
            ms = {}
            ckw = dict(kw)
            ckw["lm_kw"] = {**(kw.get("lm_kw") or {}), "unroll": True}
            for l_ov in (2, 4):
                c2 = build_cell(arch, shape, mesh, smoke=smoke,
                                n_layers_override=l_ov, **ckw)
                ms[l_ov] = _measure(c2.fn.lower(*c2.args).compile(), world)
            L = full_cfg.n_layers
            scale = (L - 2) / 2.0

            def extrap(key):
                return ms[2][key] + scale * (ms[4][key] - ms[2][key])

            rec["loop_corrected"] = True
            rec["uncorrected"] = {"flops": flops, "bytes": byts, "wire": wire}
            flops, byts, wire = extrap("flops"), extrap("bytes"), extrap("wire")
            per_op = {k: {kk: (ms[2]["per_op"].get(k, {}).get(kk, 0)
                              + scale * (ms[4]["per_op"].get(k, {}).get(kk, 0)
                                         - ms[2]["per_op"].get(k, {}).get(kk, 0)))
                          for kk in ("count", "bytes", "wire")}
                      for k in set(ms[2]["per_op"]) | set(ms[4]["per_op"])}

        rec["hlo_flops"] = flops
        rec["hlo_bytes"] = byts
        rec["collective_wire_bytes"] = wire
        rec["collectives"] = per_op
        rec["ops"] = {k: v for k, v in sorted(count_ops(m["hlo"]).items(),
                                              key=lambda kv: -kv[1])[:25]}
        rec.update(roofline_terms(flops, byts, wire))
        rec["model_flops"] = cell.model_flops / world  # per device
        rec["useful_ratio"] = (cell.model_flops / world / flops) if flops else None
        rec["note"] = cell.note
        rec["ok"] = True
        if save_hlo:
            (out_dir / f"{arch}__{shape_name}__{rec['mesh']}{tag}.hlo.txt").write_text(m["hlo"])
    except Exception as e:
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-3000:]
    rec["total_s"] = round(time.time() - t0, 1)
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{rec['mesh']}{tag}.json"
    (out_dir / name).write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run: lower+compile every cell")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true", help="reduced configs (CI)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args()

    out = Path(args.out)
    archs = list_archs() if args.arch == "all" else [args.arch]
    for arch in archs:
        shapes = get_shapes(arch)
        skipped = dict(skipped_shapes(arch))
        names = [s.name for s in shapes] if args.shape == "all" else [args.shape]
        for sn in names:
            if sn in skipped:
                print(f"[skip] {arch} x {sn}: {skipped[sn][:80]}...")
                continue
            rec = run_cell(arch, sn, args.multi_pod, out, smoke=args.smoke,
                           tag=args.tag, save_hlo=args.save_hlo)
            status = "OK " if rec.get("ok") else "FAIL"
            print(f"[{status}] {arch} x {sn} ({rec['mesh']}): "
                  f"lower={rec.get('lower_s')}s compile={rec.get('compile_s')}s "
                  f"bound={rec.get('bound')} step={rec.get('step_s', 0):.2e}s "
                  f"{rec.get('error', '')}", flush=True)


if __name__ == "__main__":
    main()
