"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:
  compute    = HLO_FLOPs_per_device / peak_FLOPs
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / ICI_link_bw

collective bytes are parsed from the post-SPMD optimized HLO (per-device
module): every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute, weighted by its algorithmic bytes-on-wire factor.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12     # bf16
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link

_DT_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?P<res>[^=]*?)\s*(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(fragment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(fragment):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, world: int) -> Tuple[float, Dict[str, Dict[str, float]]]:
    """Sum algorithmic bytes-on-wire per device across collective ops."""
    per_op: Dict[str, Dict[str, float]] = {}
    total = 0.0
    ring = (world - 1) / max(world, 1)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done(" in line:
            continue
        op = m.group("op")
        size = _shape_bytes(m.group("res"))
        if size == 0:
            continue
        if op == "all-reduce":
            wire = 2.0 * ring * size
        elif op == "all-gather":
            wire = ring * size           # result is the gathered buffer
        elif op == "reduce-scatter":
            wire = ring * size * world   # result is the scattered shard
        elif op == "all-to-all":
            wire = ring * size
        else:  # collective-permute
            wire = float(size)
        d = per_op.setdefault(op, {"count": 0, "bytes": 0.0, "wire": 0.0})
        d["count"] += 1
        d["bytes"] += size
        d["wire"] += wire
        total += wire
    return total, per_op


def roofline_terms(flops: float, bytes_accessed: float, coll_wire: float
                   ) -> Dict[str, float]:
    t_c = flops / PEAK_FLOPS
    t_m = bytes_accessed / HBM_BW
    t_x = coll_wire / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda kv: kv[1])
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "bound": dom[0], "step_s": dom[1]}


def count_ops(hlo_text: str) -> Dict[str, int]:
    """Rough op histogram of the optimized module (for the packing table)."""
    counts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w\.\-]+\s*=\s*[^=]*?\s([a-z][a-z0-9\-]*)\(", line)
        if m:
            counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    counts["_total"] = sum(v for k, v in counts.items() if k != "_total")
    return counts
