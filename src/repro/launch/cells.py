"""Dry-run cell builders: (arch x input-shape x mesh) -> lowerable step.

Every cell returns a ``Cell``: a python callable suitable for
``jax.jit(fn, in_shardings=...).lower(*abstract_args)`` plus the abstract
args (ShapeDtypeStruct — no allocation) and metadata for the roofline
(MODEL_FLOPS, dtype, parallelism notes).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import LMConfig, SchNetConfig, ShapeSpec, WDLConfig, get_config, get_shapes
from repro.core.packing import PicassoPlan, make_plan
from repro.data.synthetic import batch_spec
from repro.dist.compat import shard_map
from repro.dist.sharding import batch_specs, state_specs, to_named
from repro.embedding.state import abstract_embedding_state
from repro.layers.transformer import (abstract_kv_cache, abstract_lm_params, lm_decode_step,
                                      lm_loss, lm_param_specs, lm_prefill)
from repro.models.schnet import init_schnet, schnet_loss
from repro.models.wdl import WDLModel
from repro.optim.optimizers import adam_init, adam_update
from repro.serve.serve_step import make_retrieval_step, make_serve_step
from repro.train.train_step import TrainConfig, make_train_step


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    fn: Callable          # already jit-wrapped (or plain fn + shardings)
    args: Tuple[Any, ...]  # abstract args
    model_flops: float     # 6*N*D (or per-kind analytic estimate), fwd+bwd
    note: str = ""


def _abstract(tree, mesh, specs):
    """Attach NamedShardings to ShapeDtypeStructs (no allocation)."""
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, specs, is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct,)))


def _rep_specs(tree):
    return jax.tree.map(lambda x: P(*((None,) * len(x.shape))), tree)


# ---------------------------------------------------------------------------
# recsys cells
# ---------------------------------------------------------------------------


def _wdl_plan(cfg: WDLConfig, world: int, per_dev_batch: int, **kw) -> PicassoPlan:
    return make_plan(cfg, world=world, per_device_batch=max(per_dev_batch, 1),
                     hot_bytes=kw.pop("hot_bytes", 1 << 30), **kw)


def _wdl_flops(cfg: WDLConfig, plan: PicassoPlan, batch: int, train: bool) -> float:
    """Analytic useful-FLOPs: embedding ~0; interactions + MLP dominate."""
    mults = 0.0
    base = sum(f.dim for f in cfg.fields if f.pooling != "none")
    dense_dim = cfg.dense_arch[-1] if cfg.dense_arch else cfg.n_dense
    base += dense_dim
    d = base
    for it in cfg.interactions:
        if it.kind == "cross":
            mults += it.kwargs.get("n_layers", 3) * base * base
        elif it.kind == "self_attn_seq":
            f0 = cfg.field_by_name(it.fields[0])
            L, D = f0.max_len, f0.dim
            mults += it.kwargs.get("n_blocks", 2) * (4 * L * D * D + 2 * L * L * D + 2 * L * D * D)
        elif it.kind == "capsule":
            f0 = cfg.field_by_name(it.fields[0])
            mults += f0.max_len * f0.dim * f0.dim * (1 + it.kwargs.get("routing_iters", 3))
    prev = None
    for h in (cfg.dense_arch or ()):
        mults += (prev or cfg.n_dense) * h
        prev = h
    prev = None
    for h in cfg.mlp_dims:
        mults += (prev or d) * h
        prev = h
    fwd = 2.0 * batch * mults
    return fwd * (3.0 if train else 1.0)


def build_wdl_cell(arch: str, shape: ShapeSpec, mesh, smoke: bool = False,
                   tcfg: Optional[TrainConfig] = None, plan_kw: Optional[dict] = None,
                   strategy: Any = "picasso") -> Cell:
    """``strategy`` selects the EmbeddingEngine lookup path for the
    serve/retrieval cells — a registry name (broadcast), ``'mixed'``/
    ``'auto'`` (per-group cost-model assignment), or a ``{gid: name}`` dict;
    train cells take the same spec from ``tcfg.strategy``."""
    cfg = get_config(arch, smoke=smoke)
    axes = tuple(mesh.axis_names)
    world = int(mesh.devices.size)
    plan_kw = dict(plan_kw or {})

    if shape.kind == "retrieval":
        nc = shape["n_candidates"]
        has_seq = any(f.pooling == "none" and f.max_len > 1 for f in cfg.fields)
        if has_seq:
            # two-tower: encode user once, dot against mesh-sharded candidates
            nc_pad = ((nc + world - 1) // world) * world
            plan = _wdl_plan(cfg, world, 1, **plan_kw)
            model = WDLModel(cfg, plan)
            step = make_retrieval_step(model, plan, mesh, axes, nc_pad,
                                       strategy=strategy)
            state = _abstract_state(model, plan, mesh, axes)
            batch = _abstract(batch_spec(cfg, 1), mesh, _rep_specs(batch_spec(cfg, 1)))
            cand = jax.ShapeDtypeStruct((nc_pad,), jnp.int32,
                                        sharding=NamedSharding(mesh, P(axes)))
            flops = 2.0 * nc_pad * plan.group(next(iter(plan.capacity))).dim
            return Cell(arch, shape.name, step, (state, batch, cand), flops,
                        "two-tower retrieval, distributed top-k")
        # pure-CTR arch: retrieval == bulk forward over the candidate batch
        nc_pad = ((nc + world - 1) // world) * world
        plan = _wdl_plan(cfg, world, max(1, nc_pad // world), **plan_kw)
        model = WDLModel(cfg, plan)
        step = make_serve_step(model, plan, mesh, axes, nc_pad, strategy=strategy)
        state = _abstract_state(model, plan, mesh, axes)
        bsp = batch_spec(cfg, nc_pad)
        batch = _abstract(bsp, mesh, batch_specs(bsp, axes))
        return Cell(arch, shape.name, step, (state, batch),
                    _wdl_flops(cfg, plan, nc_pad, False),
                    "CTR bulk candidate scoring (batched, no loop)")

    gb = shape["batch"]
    per_dev = max(1, gb // world)
    plan = _wdl_plan(cfg, world, per_dev, **plan_kw)
    model = WDLModel(cfg, plan)
    state = _abstract_state(model, plan, mesh, axes)
    bsp = batch_spec(cfg, gb)
    batch = _abstract(bsp, mesh, batch_specs(bsp, axes))

    if shape.kind == "train":
        step, _ = make_train_step(model, plan, mesh, axes, gb, tcfg or TrainConfig())
        return Cell(arch, shape.name, step, (state, batch),
                    _wdl_flops(cfg, plan, gb, True), "hybrid MP/DP train")
    step = make_serve_step(model, plan, mesh, axes, gb, strategy=strategy)
    return Cell(arch, shape.name, step, (state, batch),
                _wdl_flops(cfg, plan, gb, False), "forward scoring")


def _abstract_state(model: WDLModel, plan: PicassoPlan, mesh, axes) -> Dict:
    emb = abstract_embedding_state(plan)
    dense = jax.eval_shape(model.init_dense, jax.random.PRNGKey(0))
    opt = jax.eval_shape(adam_init, dense)
    state = {"emb": {str(g): s for g, s in emb.items()}, "dense": dense,
             "opt": opt, "step": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = state_specs(plan, axes, dense, opt)
    return _abstract(state, mesh, specs)


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_mesh_info(mesh):
    axes = tuple(mesh.axis_names)
    dp_axes = tuple(a for a in axes if a != "model")
    shape = {a: mesh.shape[a] for a in axes}
    return axes, dp_axes, shape


def _moe_exec(cfg, mesh, dp_axes, moe_shard: bool):
    """Token-group MoE dispatch: groups == data shards, buffers pinned
    group-sharded so the dispatch sort/scatter stays shard-local."""
    if cfg.moe is None or not moe_shard:
        return None
    dpn = int(np.prod([mesh.shape[a] for a in dp_axes]))
    if dpn <= 1:
        return None
    sh = NamedSharding(mesh, P(dp_axes, None, None, None))  # [G, E, C, D]
    return (dpn, sh)


def make_lm_train_step(cfg: LMConfig, mesh, attn_chunk=512, loss_chunk=512,
                       remat=True, lr=1e-4, shard_mode: str = "fsdp",
                       unroll: bool = False, moe_shard: bool = False):
    """shard_mode: 'fsdp' (params+moments dp-sharded; per-layer gathers) |
    'zero1' (params dp-replicated, moments dp-sharded: one reduce-scatter +
    all-gather per step instead of 3x per-layer gathers)."""
    axes, dp_axes, mshape = _lm_mesh_info(mesh)
    pspecs = lm_param_specs(cfg, mshape, dp_axes, fsdp=shard_mode == "fsdp")
    mspecs = lm_param_specs(cfg, mshape, dp_axes, fsdp=True)  # moments always sharded
    mexec = _moe_exec(cfg, mesh, dp_axes, moe_shard)

    def step(params, opt, tokens):
        loss, g = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, tokens, attn_chunk=attn_chunk, remat=remat,
                              loss_chunk=loss_chunk, unroll=unroll,
                              moe_exec=mexec))(params)
        params2, opt2 = adam_update(params, g, opt, lr)
        return params2, opt2, loss

    params = abstract_lm_params(cfg)
    opt = jax.eval_shape(adam_init, params)
    ospecs = {"m": mspecs, "v": mspecs, "t": P()}
    in_sh = (to_named(mesh, pspecs), to_named(mesh, ospecs),
             NamedSharding(mesh, P(dp_axes, None)))
    out_sh = (to_named(mesh, pspecs), to_named(mesh, ospecs), NamedSharding(mesh, P()))
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0, 1))
    return fn, params, opt, pspecs


def _cache_specs(cfg: LMConfig, batch: int, dp: Tuple[str, ...], mshape) -> P:
    dpn = int(np.prod([mshape[a] for a in dp]))
    b_ax = dp if batch % dpn == 0 and batch >= dpn else None
    return P(None, b_ax, "model", None, None)  # seq-sharded KV (flash-decode)


def build_lm_cell(arch: str, shape: ShapeSpec, mesh, smoke: bool = False,
                  n_layers_override: Optional[int] = None,
                  lm_kw: Optional[dict] = None) -> Cell:
    cfg = get_config(arch, smoke=smoke)
    if n_layers_override is not None:
        cfg = dataclasses.replace(cfg, n_layers=n_layers_override)
    lm_kw = dict(lm_kw or {})
    axes, dp_axes, mshape = _lm_mesh_info(mesh)
    seq, gb = shape["seq_len"], shape["global_batch"]
    n, na = cfg.param_count(), cfg.active_param_count()

    if shape.kind == "train":
        shard_mode = lm_kw.get("shard_mode", "fsdp")
        fn, params, opt, pspecs = make_lm_train_step(cfg, mesh, **lm_kw)
        mspecs = lm_param_specs(cfg, mshape, dp_axes, fsdp=True)
        toks = jax.ShapeDtypeStruct((gb, seq), jnp.int32,
                                    sharding=NamedSharding(mesh, P(dp_axes, None)))
        args = (_abstract(params, mesh, pspecs),
                _abstract(opt, mesh, {"m": mspecs, "v": mspecs, "t": P()}),
                toks)
        return Cell(arch, shape.name, fn, args, 6.0 * na * gb * seq,
                    f"TP+{shard_mode} train")

    if shape.kind == "prefill":
        pspecs = lm_param_specs(cfg, mshape, dp_axes)
        csp = _cache_specs(cfg, gb, dp_axes, mshape)
        unroll = lm_kw.get("unroll", False)
        mexec = _moe_exec(cfg, mesh, dp_axes, lm_kw.get("moe_shard", False))

        def step(params, tokens):
            return lm_prefill(cfg, params, tokens, attn_chunk=512, unroll=unroll,
                              moe_exec=mexec)

        fn = jax.jit(step,
                     in_shardings=(to_named(mesh, pspecs),
                                   NamedSharding(mesh, P(dp_axes, None))),
                     out_shardings=(NamedSharding(mesh, P(dp_axes, "model")),
                                    jax.tree.map(lambda _: NamedSharding(mesh, csp),
                                                 abstract_kv_cache(cfg, gb, seq))))
        toks = jax.ShapeDtypeStruct((gb, seq), jnp.int32,
                                    sharding=NamedSharding(mesh, P(dp_axes, None)))
        args = (_abstract(abstract_lm_params(cfg), mesh, pspecs), toks)
        return Cell(arch, shape.name, fn, args, 2.0 * na * gb * seq, "prefill")

    # decode: one new token against a KV cache of seq_len (ring-buffer for SWA)
    cache_len = min(seq, cfg.swa_window) if cfg.swa_window else seq
    pspecs = lm_param_specs(cfg, mshape, dp_axes)
    csp = _cache_specs(cfg, gb, dp_axes, mshape)
    cache = abstract_kv_cache(cfg, gb, cache_len)

    unroll = lm_kw.get("unroll", False)

    def step(params, cache, tokens, length):
        slot = length % cache_len
        return lm_decode_step(cfg, params, cache, tokens, slot, unroll=unroll)

    fn = jax.jit(step,
                 in_shardings=(to_named(mesh, pspecs),
                               jax.tree.map(lambda _: NamedSharding(mesh, csp), cache),
                               NamedSharding(mesh, P(None, None)),
                               NamedSharding(mesh, P())),
                 out_shardings=(NamedSharding(mesh, P(None, "model")),
                                jax.tree.map(lambda _: NamedSharding(mesh, csp), cache)),
                 donate_argnums=(1,))
    toks = jax.ShapeDtypeStruct((gb, 1), jnp.int32,
                                sharding=NamedSharding(mesh, P(None, None)))
    ln = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    args = (_abstract(abstract_lm_params(cfg), mesh, pspecs),
            jax.tree.map(lambda c: jax.ShapeDtypeStruct(
                c.shape, c.dtype, sharding=NamedSharding(mesh, csp)), cache),
            toks, ln)
    return Cell(arch, shape.name, fn, args, 2.0 * na * gb,
                f"decode, kv={cache_len}" + (" (SWA ring)" if cfg.swa_window else ""))


# ---------------------------------------------------------------------------
# SchNet cells
# ---------------------------------------------------------------------------


def make_schnet_step(cfg: SchNetConfig, mesh, d_feat: int, batched: bool, lr=1e-3):
    axes = tuple(mesh.axis_names)

    def local_step(params, opt, batch):
        loss, g = jax.value_and_grad(
            lambda p: schnet_loss(cfg, p, batch, axes=axes))(params)
        g = lax.pmean(g, axes)
        loss = lax.pmean(loss, axes)
        params2, opt2 = adam_update(params, g, opt, lr)
        return params2, opt2, loss

    params = jax.eval_shape(functools.partial(init_schnet, cfg, d_feat=d_feat),
                            jax.random.PRNGKey(0))
    opt = jax.eval_shape(adam_init, params)
    rep = _rep_specs(params)
    orep = _rep_specs(opt)

    def batch_spec_fn(batch):
        sh = {}
        for k, v in batch.items():
            if k in ("src", "dst", "dist", "edge_w"):
                sh[k] = P(axes, *((None,) * (len(v.shape) - 1)))
            elif k == "nodes" and not batched:
                sh[k] = P(*((None,) * len(v.shape)))
            else:
                sh[k] = P(*((None,) * len(v.shape)))
        return sh

    def wrapped(params, opt, batch):
        f = shard_map(local_step, mesh=mesh,
                      in_specs=(rep, orep, batch_spec_fn(batch)),
                      out_specs=(rep, orep, P()), check_vma=False)
        return f(params, opt, batch)

    return jax.jit(wrapped, donate_argnums=(0, 1)), params, opt, rep, orep, batch_spec_fn


def _pad(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def build_gnn_cell(arch: str, shape: ShapeSpec, mesh, smoke: bool = False) -> Cell:
    cfg = get_config(arch, smoke=smoke)
    axes = tuple(mesh.axis_names)
    world = int(mesh.devices.size)

    if shape.kind == "graph_minibatch":
        f0, f1, bn = shape["fanout0"], shape["fanout1"], shape["batch_nodes"]
        n_nodes = _pad(bn * (1 + f0 + f0 * f1) + 64, world)
        n_edges = _pad(bn * f0 + bn * f0 * f1, world)
        d_feat = 0
        note = f"sampled subgraph {n_nodes}n/{n_edges}e (fanout {f0}-{f1})"
    elif shape.kind == "graph_batched":
        b, nn, ne = shape["batch"], shape["n_nodes"], shape["n_edges"]
        n_nodes, n_edges, d_feat = _pad(b * nn, world), _pad(b * ne, world), 0
        note = f"{b} molecules batched"
    else:
        n_nodes = shape["n_nodes"]
        n_edges = _pad(shape["n_edges"], world)
        d_feat = shape["d_feat"]
        note = "full-graph"

    batched = shape.kind == "graph_batched"
    fn, params, opt, rep, orep, bspec_fn = make_schnet_step(cfg, mesh, d_feat, batched)

    batch = {
        "nodes": jax.ShapeDtypeStruct((n_nodes, d_feat) if d_feat else (n_nodes,),
                                      jnp.float32 if d_feat else jnp.int32),
        "src": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
        "dst": jax.ShapeDtypeStruct((n_edges,), jnp.int32),
        "dist": jax.ShapeDtypeStruct((n_edges,), jnp.float32),
        "edge_w": jax.ShapeDtypeStruct((n_edges,), jnp.float32),
    }
    if batched:
        ng = shape["batch"]
        batch["graph_ids"] = jax.ShapeDtypeStruct((n_nodes,), jnp.int32)
        batch["target"] = jax.ShapeDtypeStruct((ng,), jnp.float32)
    else:
        batch["target"] = jax.ShapeDtypeStruct((n_nodes,), jnp.float32)
        batch["node_w"] = jax.ShapeDtypeStruct((n_nodes,), jnp.float32)

    args = (_abstract(params, mesh, rep), _abstract(opt, mesh, orep),
            _abstract(batch, mesh, bspec_fn(batch)))
    d = cfg.d_hidden
    flops = 3.0 * 2.0 * (n_edges * (cfg.n_rbf * d + d * d) * cfg.n_interactions
                         + n_nodes * 4 * d * d * cfg.n_interactions)
    return Cell(arch, shape.name, fn, args, flops, note)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


def build_cell(arch: str, shape: ShapeSpec, mesh, smoke: bool = False, **kw) -> Cell:
    cfg = get_config(arch, smoke=True)  # cheap kind probe
    kind = cfg.kind
    if kind == "wdl":
        kw.pop("n_layers_override", None)
        return build_wdl_cell(arch, shape, mesh, smoke=smoke, **kw)
    if kind == "lm":
        return build_lm_cell(arch, shape, mesh, smoke=smoke,
                             n_layers_override=kw.get("n_layers_override"),
                             lm_kw=kw.get("lm_kw"))
    return build_gnn_cell(arch, shape, mesh, smoke=smoke)


def arch_kind(arch: str) -> str:
    return get_config(arch, smoke=True).kind
