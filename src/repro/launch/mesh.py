"""Production mesh builders (functions — importing never touches jax devices)."""
from __future__ import annotations

from typing import Optional, Tuple

from repro.dist.compat import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a 'pod' axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return make_mesh_compat(shape, axes)


def make_test_mesh(n_data: int = 4, n_model: int = 2):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return make_mesh((n_data, n_model), ("data", "model"))


def mesh_axes(mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def mesh_world(mesh) -> int:
    return int(mesh.devices.size)
