"""Serving launcher: batched scoring or two-tower retrieval.

  PYTHONPATH=src python -m repro.launch.serve --arch deepfm --smoke \\
      --batch 512 --devices 8 --mesh 4x2
  PYTHONPATH=src python -m repro.launch.serve --arch sasrec --smoke --retrieval
"""
import argparse
import os


def main():
    # jax-importing but backend-lazy (see launch/train.py)
    from repro.engine import AUTO_NAMES, available_strategies

    names = available_strategies()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepfm")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--n-requests", type=int, default=10)
    ap.add_argument("--retrieval", action="store_true")
    ap.add_argument("--candidates", type=int, default=65536)
    ap.add_argument("--n-candidates", type=int, default=None, metavar="N",
                    help="retrieval candidate count (canonical spelling; "
                         "falls back to --candidates when omitted). With "
                         "--score-chunk, N is no longer bound by per-shard "
                         "memory: chunked scoring streams a running top-k")
    ap.add_argument("--score-chunk", type=int, default=0, metavar="C",
                    help="retrieval: score the local candidate slice in "
                         "fixed chunks of C ids with a streaming top-k "
                         "merge (bounds per-shard memory; 0 = one chunk)")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="")
    ap.add_argument("--strategy", default="picasso",
                    choices=names + AUTO_NAMES,
                    help="EmbeddingEngine lookup strategy: one of "
                         f"{', '.join(names)} (broadcast to every packed "
                         f"group), or {'/'.join(AUTO_NAMES)} for the "
                         "per-group cost-model assignment")
    ap.add_argument("--l2-budget", type=int, default=0, metavar="BYTES",
                    help="host-memory L2 cache budget in bytes (0 disables; "
                         ">0 budgets an L2 tier behind the hot tier for the "
                         "scoring path)")
    ap.add_argument("--narrow-dim", type=int, default=0, metavar="D",
                    help="narrow master width for picasso_narrow groups "
                         "(0 disables): cold ids are stored at D columns and "
                         "up-projected at lookup; takes effect for groups "
                         "assigned 'picasso_narrow' (broadcast it or let "
                         "mixed/auto pick it per group)")
    ap.add_argument("--pin-l2", action="store_true",
                    help="place L2 host-tier leaves in pinned host memory "
                         "(pin_l2_to_host; no-op on backends without "
                         "pinned_host, e.g. the CPU rig)")
    ap.add_argument("--calibrate", default="off",
                    choices=("auto", "force", "off"),
                    help="measured cost model for the mixed/auto assignment: "
                         "'auto' loads the backend-stamped calibration file "
                         "(--calib-file) or benches once and writes it, "
                         "'force' always re-benches, 'off' (default) keeps "
                         "the constant model")
    ap.add_argument("--calib-file", default="", metavar="PATH",
                    help="calibration cache for --calibrate (default: "
                         "~/.cache/repro/calibration.json); reused only when "
                         "its backend stamp matches this process")
    ap.add_argument("--fused-kernels", default="auto",
                    choices=("auto", "on", "off"),
                    help="fused Pallas sparse kernels: 'auto' wherever "
                         "Pallas runs (TPU / REPRO_FORCE_PALLAS_INTERPRET), "
                         "'on' forces them, 'off' forces the jnp reference")
    ap.add_argument("--reload-dir", default="", metavar="DIR",
                    help="pick up model deltas a streaming trainer publishes "
                         "(repro.launch.train --stream --publish-dir DIR): "
                         "before each request, poll DIR/LATEST and hot-swap "
                         "the emb+dense state in place — no restart; deltas "
                         "published at a different world size are resharded "
                         "onto this server's mesh on load")
    ap.add_argument("--chaos", default="", metavar="SPEC",
                    help="fault injection for the serve reload path: "
                         "'torn@i' tears the newest published delta on disk "
                         "before request i (needs --reload-dir) — degraded-"
                         "mode serving must keep answering from the last "
                         "good state instead of crashing")
    args = ap.parse_args()
    if args.chaos and not args.reload_dir:
        ap.error("--chaos needs --reload-dir (faults target published deltas)")

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.core.packing import make_plan
    from repro.engine import maybe_compile, resolve_assignment
    from repro.data.synthetic import make_batch
    from repro.dist.sharding import batch_specs, to_named
    from repro.launch.mesh import make_mesh
    from repro.models.wdl import WDLModel
    from repro.serve.serve_step import ServeConfig, make_retrieval_step, make_serve_step
    from repro.train.train_step import init_state

    nd = len(jax.devices())
    shape = tuple(int(x) for x in args.mesh.split("x")) if args.mesh else (nd, 1)
    axes = ("data", "model")[: len(shape)]
    mesh = make_mesh(shape, axes)
    world = int(np.prod(shape))

    cost_model = None
    if args.calibrate != "off":
        from repro.perf import get_cost_model
        cost_model = get_cost_model(
            args.calibrate, args.calib_file or None,
            grid="tiny" if args.smoke else "small",
            log=lambda s: print(f"[serve] calib {s}", flush=True))

    def serve_cfg(plan, per_dev_batch, use_cache=True):
        # serving has no micro pipeline: the engine issues the full local
        # batch per step, so that is the id volume the cost model sees
        spec = maybe_compile(plan, args.strategy, per_device_batch=per_dev_batch,
                             use_cache=use_cache, cost_model=cost_model,
                             log=lambda s: print(f"[serve] {s}"))
        # record broadcast assignments (notably 'picasso_narrow', which
        # gates the master widths) on the plan before init_state sizes it
        resolve_assignment(plan, spec, world=world, use_cache=use_cache)
        return ServeConfig(strategy=spec, use_cache=use_cache,
                           use_fused_kernels=args.fused_kernels)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.retrieval:
        plan = make_plan(cfg, world=world, per_device_batch=1, enable_cache=False,
                         exact_capacity=True, narrow_dim=args.narrow_dim or None)
        model = WDLModel(cfg, plan)
        n_cand = args.n_candidates or args.candidates
        nc = (n_cand // world) * world
        chunk = args.score_chunk or nc // world
        # the candidate tower dominates retrieval lookups: size the cost
        # model to its per-shard score chunk, not the batch-of-1 user tower
        from repro.core.features import field_index
        item_field = next(f.name for f in cfg.fields
                          if f.pooling == "none" and f.max_len > 1)
        ips = plan.group(field_index(plan)[item_field].gid).ids_per_sample
        proxy_batch = max(1, min(chunk, nc // world) // max(ips, 1))
        # resolve the strategy before init_state: a 'picasso_narrow'
        # assignment is recorded on the plan and gates the master widths
        scfg = serve_cfg(plan, proxy_batch, use_cache=False)
        state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh, axes=axes)
        step = make_retrieval_step(model, plan, mesh, axes, nc, top_k=10,
                                   scfg=scfg, score_chunk=args.score_chunk)
        user = make_batch(cfg, 1, np.random.default_rng(1))
        from jax.sharding import NamedSharding, PartitionSpec as P
        cand = jax.device_put(jnp.arange(nc, dtype=jnp.int32) % cfg.fields[0].vocab,
                              NamedSharding(mesh, P(axes)))
        scores, ids = step(state, user, cand)
        print("top-10:", np.asarray(ids), np.round(np.asarray(scores), 3))
        return

    plan = make_plan(cfg, world=world, per_device_batch=args.batch // world,
                     l2_bytes=args.l2_budget,
                     narrow_dim=args.narrow_dim or None,
                     mesh_shape=shape)
    if args.reload_dir:
        # shape the serve state by the PUBLISHED plan revision (tier budgets,
        # strategy, narrow widths) so hot-swapped deltas drop straight in;
        # rows still follow THIS server's world (deltas reshard on load)
        from repro.runtime import apply_plan_meta
        from repro.train.checkpoint import load_checkpoint_meta
        pub_meta = load_checkpoint_meta(args.reload_dir)
        if pub_meta is not None:
            plan = apply_plan_meta(plan, pub_meta)
            print(f"[serve] following published plan rev {plan.rev} "
                  f"from {args.reload_dir}")
    model = WDLModel(cfg, plan)
    scfg = serve_cfg(plan, args.batch // world)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh, axes=axes)
    if args.pin_l2:
        from repro.embedding.state import pin_l2_to_host, warn_pin_l2_limits
        warn_pin_l2_limits()
        state = pin_l2_to_host(state, mesh)
    serve = make_serve_step(model, plan, mesh, axes, args.batch, scfg=scfg)
    rng = np.random.default_rng(0)
    lat = []
    poller = None
    if args.reload_dir:
        # degraded-mode delta pickup: a torn/corrupt/pruned/mismatched delta
        # is skipped with capped backoff and the server keeps answering from
        # its last good state (PublishPoller only returns verified loads)
        from repro.runtime import PublishPoller, place_state
        poller = PublishPoller(args.reload_dir, plan=plan,
                               log=lambda s: print(s, flush=True))
    chaos_plan = None
    if args.chaos:
        from repro.runtime import parse_fault_plan
        from repro.runtime.chaos import tear_published
        chaos_plan = parse_fault_plan(args.chaos)
        torn_fired = set()
    for i in range(args.n_requests):
        if chaos_plan is not None and i in chaos_plan.torn_publish \
                and i not in torn_fired:
            torn_fired.add(i)
            print(f"[serve] chaos: tearing published delta before request "
                  f"{i}", flush=True)
            tear_published(args.reload_dir)
        if poller is not None:
            out = poller.poll({"emb": state["emb"], "dense": state["dense"]})
            if out is not None:
                loaded, s_pub = out
                state = {**state, **place_state(loaded, plan, mesh, axes)}
                print(f"[serve] reloaded published step {s_pub} "
                      f"from {args.reload_dir}", flush=True)
        b = make_batch(cfg, args.batch, rng)
        b = jax.device_put(b, to_named(mesh, batch_specs(b, axes)))
        t0 = time.perf_counter()
        probs = jax.block_until_ready(serve(state, b))
        lat.append(time.perf_counter() - t0)
    lat = np.array(lat[1:]) * 1e3
    print(f"[serve] {args.arch} B={args.batch}: p50={np.percentile(lat,50):.1f}ms "
          f"p99={np.percentile(lat,99):.1f}ms mean_prob={float(probs.mean()):.3f}")


if __name__ == "__main__":
    main()
