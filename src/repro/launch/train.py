"""Training launcher: PICASSO hybrid training of any WDL arch on the local
device set (or a forced host-device mesh), with checkpointing + fault
tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch deepfm --smoke \\
      --steps 100 --global-batch 256 --devices 8 --mesh 4x2
"""
import argparse
import os


def main():
    # registry import is jax-importing but backend-lazy: XLA_FLAGS set after
    # parsing (for --devices) is still honoured at first device query.
    from repro.engine import AUTO_NAMES, available_strategies

    names = available_strategies()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepfm")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--devices", type=int, default=0, help="force host device count")
    ap.add_argument("--mesh", default="", help="e.g. 4x2 (data x model)")
    ap.add_argument("--strategy", default="picasso",
                    choices=names + AUTO_NAMES,
                    help="EmbeddingEngine lookup strategy: one of "
                         f"{', '.join(names)} (broadcast to every packed "
                         f"group), or {'/'.join(AUTO_NAMES)} for the "
                         "per-group cost-model assignment")
    ap.add_argument("--l2-budget", type=int, default=0, metavar="BYTES",
                    help="host-memory L2 cache budget in bytes (0 disables; "
                         ">0 budgets an L2 tier behind the hot tier, used by "
                         "picasso_l2 and offered to the mixed/auto cost model)")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--no-interleave", action="store_true")
    ap.add_argument("--no-packing", action="store_true")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--lr-emb", type=float, default=0.05)
    ap.add_argument("--lr-dense", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.packing import make_plan
    from repro.data.pipeline import device_put_stream
    from repro.data.synthetic import batch_stream
    from repro.dist.sharding import batch_specs
    from repro.launch.mesh import make_mesh
    from repro.models.wdl import WDLModel
    from repro.train.fault_tolerance import Supervisor
    from repro.train.train_step import TrainConfig, init_state, make_train_step

    nd = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
    else:
        shape = (nd, 1)
    axes = ("data", "model")[: len(shape)]
    mesh = make_mesh(shape, axes)
    world = int(np.prod(shape))

    cfg = get_config(args.arch, smoke=args.smoke)
    plan = make_plan(cfg, world=world, per_device_batch=args.global_batch // world,
                     enable_packing=not args.no_packing,
                     enable_cache=not args.no_cache,
                     n_micro=args.n_micro,
                     hot_bytes=1 << 24 if args.smoke else 1 << 30,
                     l2_bytes=args.l2_budget,
                     flush_iters=20, warmup_iters=10)
    model = WDLModel(cfg, plan)
    from repro.engine import maybe_compile
    # per_device_batch=None: training issues plan.microbatch ids per step
    strategy = maybe_compile(plan, args.strategy, use_cache=not args.no_cache,
                             log=lambda s: print(f"[train] {s}"))
    tcfg = TrainConfig(strategy=strategy, use_cache=not args.no_cache,
                       use_interleave=not args.no_interleave,
                       lr_emb=args.lr_emb, lr_dense=args.lr_dense)
    step_fn, _ = make_train_step(model, plan, mesh, axes, args.global_batch, tcfg)
    state = init_state(model, plan, jax.random.PRNGKey(args.seed), mesh=mesh, axes=axes)

    print(f"[train] {cfg.name}: {len(plan.groups)} packed groups, "
          f"micro={plan.microbatch}, ilv={len(plan.interleave)} waves, world={world}")

    stream = device_put_stream(batch_stream(cfg, args.global_batch, seed=args.seed),
                               mesh, lambda b: batch_specs(b, axes))

    def on_metrics(step, m):
        if step % args.log_every == 0:
            print(f"  step {step:5d} loss={float(m['loss']):.4f} "
                  f"hits={int(m['cache_hits'])} ovf={int(m['overflow'])}", flush=True)

    if args.ckpt_dir:
        sup = Supervisor(args.ckpt_dir, ckpt_every=args.ckpt_every)
        state, start = sup.maybe_restore(state)
        state = sup.run(state, step_fn, stream, args.steps, start_step=start,
                        on_metrics=on_metrics)
    else:
        for i, batch in zip(range(args.steps), stream):
            state, m = step_fn(state, batch)
            on_metrics(i + 1, m)
    print("[train] done")


if __name__ == "__main__":
    main()
