"""Training launcher: PICASSO hybrid training of any WDL arch on the local
device set (or a forced host-device mesh), with checkpointing + fault
tolerance.

  PYTHONPATH=src python -m repro.launch.train --arch deepfm --smoke \\
      --steps 100 --global-batch 256 --devices 8 --mesh 4x2
"""
import argparse
import os
import time


def main():
    # registry import is jax-importing but backend-lazy: XLA_FLAGS set after
    # parsing (for --devices) is still honoured at first device query.
    from repro.engine import AUTO_NAMES, available_strategies

    names = available_strategies()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepfm")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--devices", type=int, default=0, help="force host device count")
    ap.add_argument("--mesh", default="", help="e.g. 4x2 (data x model)")
    ap.add_argument("--strategy", default="picasso",
                    choices=names + AUTO_NAMES,
                    help="EmbeddingEngine lookup strategy: one of "
                         f"{', '.join(names)} (broadcast to every packed "
                         f"group), or {'/'.join(AUTO_NAMES)} for the "
                         "per-group cost-model assignment")
    ap.add_argument("--l2-budget", type=int, default=0, metavar="BYTES",
                    help="host-memory L2 cache budget in bytes (0 disables; "
                         ">0 budgets an L2 tier behind the hot tier, used by "
                         "picasso_l2 and offered to the mixed/auto cost model)")
    ap.add_argument("--narrow-dim", type=int, default=0, metavar="D",
                    help="narrow master width for the picasso_narrow "
                         "hot/cold split (0 disables): cold ids are stored "
                         "and routed at this width and projected up to the "
                         "model dim at lookup, hot ids stay full-width in "
                         "the cache tiers; used by picasso_narrow and "
                         "offered to the mixed/auto cost model")
    ap.add_argument("--replan-iters", type=int, default=0, metavar="N",
                    help="adaptive replanning: every N steps harvest the live "
                         "FCounter, recompile tier budgets + the strategy "
                         "assignment from measured skew, and migrate state to "
                         "the new plan revision (0 disables)")
    ap.add_argument("--replan-hot-bytes", type=int, default=None,
                    metavar="BYTES",
                    help="hot-tier byte envelope for replan re-budgets "
                         "(default: keep the plan's compile-time envelope; "
                         "an explicit value retunes tier capacity at runtime)")
    ap.add_argument("--replan-l2-bytes", type=int, default=None,
                    metavar="BYTES",
                    help="L2 byte envelope for replan re-budgets (default: "
                         "keep the plan's compile-time envelope)")
    ap.add_argument("--pin-l2", action="store_true",
                    help="place L2 host-tier leaves (and narrow masters) in "
                         "pinned host memory, kept there across steps by "
                         "memory-kind-aware jit shardings (no-op on backends "
                         "without pinned_host, e.g. the CPU rig)")
    ap.add_argument("--calibrate", default="off",
                    choices=("auto", "force", "off"),
                    help="measured cost model for mixed/auto assignment and "
                         "replanning: 'auto' loads the backend-stamped "
                         "calibration file (--calib-file) or microbenches "
                         "the priced ops once and writes it, 'force' always "
                         "re-benches, 'off' keeps the hand-tuned constant "
                         "model (the default; bit-identical to previous "
                         "releases)")
    ap.add_argument("--calib-file", default="", metavar="PATH",
                    help="calibration cache location for --calibrate "
                         "(default: ~/.cache/repro/calibration.json); reused "
                         "only when its backend stamp matches this process")
    ap.add_argument("--fused-kernels", default="auto",
                    choices=("auto", "on", "off"),
                    help="fused Pallas sparse kernels (gather+pool custom "
                         "VJP, dedup+adagrad scatter, tier probes): 'auto' "
                         "uses them wherever Pallas runs (TPU, or any "
                         "backend under REPRO_FORCE_PALLAS_INTERPRET=1), "
                         "'on' forces them (interpreted off-TPU, slow), "
                         "'off' forces the reference jnp chains")
    ap.add_argument("--overlap", default="auto",
                    choices=("off", "on", "auto"),
                    help="software-pipelined train step: 'on' double-buffers "
                         "the sparse lookup of micro-batch i+1 behind a "
                         "handoff barrier while the dense stage of i runs, "
                         "'off' keeps the legacy (jaxpr-pinned) loop, 'auto' "
                         "enables overlap whenever the step has >1 "
                         "micro-batch; numerics are identical either way")
    ap.add_argument("--grad-compress", default="none",
                    choices=("none", "fp16", "topk"),
                    help="wire compression of the routed sparse-gradient "
                         "payload (the transposed-Shuffle all_to_all and the "
                         "PS/allgather_rows gradient all_gather): 'fp16' = "
                         "per-row amax-scaled float16 cast, 'topk' = per-row "
                         "magnitude top-(D/4) sparsification, 'none' keeps "
                         "training bitwise-exact")
    ap.add_argument("--reshard-to", default="", metavar="MESH",
                    help="elastic reshard target mesh, e.g. 2x2 or 4: at "
                         "--reshard-at the run recuts the plan for the new "
                         "world size, permutes the live state exactly (every "
                         "master row, adagrad slot, and FCounter survives "
                         "bitwise), rebuilds the jitted step, and continues "
                         "on the first prod(MESH) devices without restart")
    ap.add_argument("--reshard-at", type=int, default=0, metavar="STEP",
                    help="step at which to apply --reshard-to (0 with "
                         "--reshard-to set reshards at the first segment "
                         "boundary)")
    ap.add_argument("--stream", action="store_true",
                    help="streaming driver: consume the unbounded batch "
                         "stream in --stream-segments segments of "
                         "--segment-steps (ignoring --steps), checkpoint "
                         "incrementally per segment, publish model deltas "
                         "to --publish-dir, and apply --reshard-to in place "
                         "at a segment boundary")
    ap.add_argument("--segment-steps", type=int, default=20, metavar="N",
                    help="steps per streaming segment (the checkpoint/"
                         "publish/resize granularity of --stream)")
    ap.add_argument("--stream-segments", type=int, default=3, metavar="K",
                    help="number of streaming segments to run under --stream")
    ap.add_argument("--publish-dir", default="", metavar="DIR",
                    help="streaming mode: publish the serveable state subset "
                         "(emb+dense) here at every segment boundary, with "
                         "an atomic LATEST pointer a running "
                         "repro.launch.serve --reload-dir process picks up "
                         "without restart")
    ap.add_argument("--guard", action="store_true",
                    help="numeric anomaly guard: wrap the jitted step with "
                         "NaN/Inf-loss and grad-norm-spike detection (EMA "
                         "threshold); an anomalous step is rejected in-jit "
                         "(prior state kept bitwise, batch skipped, event "
                         "logged), and K consecutive rejections roll back "
                         "to the last verified checkpoint")
    ap.add_argument("--chaos", default="", metavar="SPEC",
                    help="deterministic fault injection for recovery-path "
                         "testing: comma-separated kind@step tokens, kinds "
                         "nan (poison batch), crash (raise at step), ckpt "
                         "(corrupt newest checkpoint on disk), torn (tear "
                         "the published delta); e.g. 'nan@7,crash@13,"
                         "ckpt@20,torn@45'")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--no-interleave", action="store_true")
    ap.add_argument("--no-packing", action="store_true")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--learnable", action="store_true",
                    help="synthetic stream with a learnable CTR signal "
                         "(default: random labels) — smoke/CI runs assert "
                         "loss decrease on this")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--lr-emb", type=float, default=0.05)
    ap.add_argument("--lr-dense", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.replan_iters < 0:
        ap.error("--replan-iters must be >= 0 (0 disables replanning)")
    if args.reshard_at and not args.reshard_to:
        ap.error("--reshard-at needs --reshard-to")

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import logging

    import jax
    import numpy as np

    # recovery events (rollbacks, quarantines, counter resets) are the
    # operator's window into the fault-tolerance subsystem: surface the
    # repro loggers at INFO without turning every library chatty
    logging.basicConfig(format="[%(name)s] %(levelname)s: %(message)s")
    logging.getLogger("repro").setLevel(logging.INFO)

    from repro.configs import get_config
    from repro.core.packing import make_plan
    from repro.data.pipeline import ReplayableStream, device_put_stream
    from repro.data.synthetic import batch_stream
    from repro.dist.sharding import batch_specs, to_named
    from repro.embedding.state import pin_l2_to_host, warn_pin_l2_limits
    from repro.launch.mesh import make_mesh
    from repro.models.wdl import WDLModel
    from repro.runtime import (AnomalyGuard, ChaosController, Replanner,
                               apply_plan_meta, make_submesh,
                               parse_fault_plan, parse_mesh_shape, plan_meta,
                               publish_state, reshard_live, restore_elastic,
                               run_stream)
    from repro.train.checkpoint import (AsyncCheckpointer, latest_step,
                                        load_checkpoint_meta)
    from repro.train.fault_tolerance import Supervisor
    from repro.train.train_step import TrainConfig, init_state, make_train_step

    nd = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
    else:
        shape = (nd, 1)
    axes = ("data", "model")[: len(shape)]
    mesh = make_mesh(shape, axes)
    world = int(np.prod(shape))

    cost_model = None
    if args.calibrate != "off":
        from repro.perf import get_cost_model
        cost_model = get_cost_model(
            args.calibrate, args.calib_file or None,
            grid="tiny" if args.smoke else "small",
            log=lambda s: print(f"[train] calib {s}", flush=True))

    cfg = get_config(args.arch, smoke=args.smoke)
    plan = make_plan(cfg, world=world, per_device_batch=args.global_batch // world,
                     enable_packing=not args.no_packing,
                     enable_cache=not args.no_cache,
                     n_micro=args.n_micro,
                     hot_bytes=1 << 24 if args.smoke else 1 << 30,
                     l2_bytes=args.l2_budget,
                     narrow_dim=args.narrow_dim or None,
                     flush_iters=20, warmup_iters=10,
                     mesh_shape=shape)
    meta = None
    if args.ckpt_dir:
        # a checkpointed run may have replanned: revise the structural plan
        # back to the checkpointed revision BEFORE shaping state/templates
        meta = load_checkpoint_meta(args.ckpt_dir)
        if meta is not None:
            plan = apply_plan_meta(plan, meta)
            print(f"[train] resumed plan rev {plan.rev} from checkpoint meta "
                  f"(strategy: {sorted(set(plan.strategy.values()))})")
    from repro.engine import maybe_compile
    if plan.strategy:
        # the plan already carries an assignment (checkpoint meta) — 'mixed'
        # makes every engine follow it instead of recompiling from priors
        strategy = "mixed"
    else:
        # per_device_batch=None: training issues plan.microbatch ids per step
        strategy = maybe_compile(plan, args.strategy,
                                 use_cache=not args.no_cache,
                                 cost_model=cost_model,
                                 log=lambda s: print(f"[train] {s}"))

    def wrap_timed(fn):
        """Measured-vs-predicted feedback: time each step (blocking on the
        loss scalar) and feed the wall time to the Replanner. Only wrapped
        when a calibrated cost model is live — the per-step sync it costs is
        exactly what the feedback loop needs to be honest."""
        if cost_model is None:
            return fn

        def timed(state, batch):
            t0 = time.perf_counter()
            out = fn(state, batch)
            jax.block_until_ready(out[1]["loss"])
            if replanner is not None:
                replanner.observe_timing((time.perf_counter() - t0) * 1e6)
            return out
        return timed

    guard = None
    if args.guard:
        guard = AnomalyGuard(log=lambda s: print(f"[train] {s}", flush=True))
    chaos = None
    if args.chaos:
        chaos = ChaosController(parse_fault_plan(args.chaos))
        print(f"[train] chaos plan armed: {args.chaos}", flush=True)

    cur_shardings = None  # NamedShardings of the live step's state output

    def build_step(plan):
        """(Re)build the jitted step against a plan revision. The guard (if
        armed) re-wraps the fresh step, carrying its EMA/event history across
        replan/reshard rebuilds; ``cur_shardings`` tracks the state placement
        so the Supervisor restores onto the correct devices."""
        nonlocal cur_shardings
        model = WDLModel(cfg, plan)
        spec = "mixed" if plan.strategy else strategy
        tcfg = TrainConfig(strategy=spec, use_cache=not args.no_cache,
                           use_interleave=not args.no_interleave,
                           use_fused_kernels=args.fused_kernels,
                           overlap=args.overlap,
                           grad_compress=args.grad_compress,
                           pin_l2=args.pin_l2,
                           lr_emb=args.lr_emb, lr_dense=args.lr_dense)
        # the guard needs the prior state alive to reject a step, so a
        # guarded step is built without buffer donation (bitwise-identical
        # numerics, higher peak memory — see runtime/guard.py)
        raw, sspecs = make_train_step(model, plan, mesh, axes,
                                      args.global_batch, tcfg,
                                      donate=guard is None)
        cur_shardings = to_named(mesh, sspecs)
        fn = guard.rebind(raw) if guard is not None else raw
        return model, tcfg, wrap_timed(fn)

    replanner = None
    model, tcfg, step_fn = build_step(plan)
    state = init_state(model, plan, jax.random.PRNGKey(args.seed), mesh=mesh, axes=axes)
    if args.pin_l2:
        warn_pin_l2_limits()  # one-time: unsupported-backend no-op notice
        state = pin_l2_to_host(state, mesh)

    if args.replan_iters:
        replanner = Replanner(
            plan, mesh, axes, strategy=args.strategy,
            hot_bytes=args.replan_hot_bytes, l2_bytes=args.replan_l2_bytes,
            use_cache=not args.no_cache, cache_update=tcfg.cache_update,
            cost_model=cost_model, pin_l2=args.pin_l2,
            log=lambda s: print(f"[train] replan {s}", flush=True))

    print(f"[train] {cfg.name}: {len(plan.groups)} packed groups, "
          f"micro={plan.microbatch}, ilv={len(plan.interleave)} waves, "
          f"world={world}, plan rev={plan.rev}")

    # positional stream factory: ``make_source(i)`` opens the synthetic
    # stream at absolute batch index ``i`` on the CURRENT mesh (read at call
    # time, so a post-reshard rewrap targets the new device set). The
    # ReplayableStream on top gives the Supervisor an exact rewind after a
    # checkpoint rollback; prefetched-but-unconsumed batches lost when a
    # reshard closes the Prefetcher are simply regenerated, not skipped.
    def make_source(start):
        return device_put_stream(
            batch_stream(cfg, args.global_batch, seed=args.seed,
                         learnable=args.learnable, start=start),
            mesh, lambda b: batch_specs(b, axes))

    stream = ReplayableStream(make_source)
    if chaos is not None:
        stream = chaos.wrap_stream(stream)

    active_ckpt = None  # the live AsyncCheckpointer (chaos ckpt@ targets it)

    def on_metrics(step, m):
        if replanner is not None:
            replanner.observe(m)
        if step % args.log_every == 0:
            print(f"  step {step:5d} loss={float(m['loss']):.4f} "
                  f"hits={int(m['cache_hits'])} ovf={int(m['overflow'])}", flush=True)
        if chaos is not None:
            if args.ckpt_dir:
                chaos.after_checkpoint(step, args.ckpt_dir, active_ckpt)
            # raised here (inside the Supervisor's try block / run_stream's
            # step loop) a crash@ fault exercises the real recovery path in
            # BOTH driver modes: in-process restore+rewind under the
            # Supervisor, process-restart resume under --stream
            chaos.injector(step)

    reshard_pending = bool(args.reshard_to)

    def do_reshard(state, step):
        """In-place elastic reshard to --reshard-to: recut the plan, permute
        the state exactly, re-place it on the sub-mesh, rebuild the jitted
        step, and re-wrap the batch source. One-shot."""
        nonlocal plan, model, tcfg, step_fn, mesh, world, reshard_pending
        new_shape = parse_mesh_shape(args.reshard_to, len(axes))
        new_world = int(np.prod(new_shape))
        reshard_pending = False  # applied (or a no-op) — never re-fires
        if new_world == world:
            return state
        if args.global_batch % new_world:
            raise SystemExit(f"[train] --reshard-to {args.reshard_to}: "
                             f"global batch {args.global_batch} not divisible "
                             f"by new world {new_world}")
        print(f"[train] reshard world {world} -> {new_world} "
              f"(mesh {'x'.join(map(str, new_shape))}) at step {step}",
              flush=True)
        new_mesh = make_submesh(new_shape, axes)
        plan, state = reshard_live(
            plan, state, new_world, args.global_batch // new_world,
            mesh=new_mesh, axes=axes, mesh_shape=new_shape,
            use_cache=not args.no_cache, cache_update=tcfg.cache_update)
        mesh, world = new_mesh, new_world
        model, tcfg, step_fn = build_step(plan)  # build_step reads `mesh`
        # same factory, new mesh (make_source reads `mesh` at call time):
        # the old Prefetcher is closed and the stream reopens at its current
        # position on the new device set
        stream.rewrap(make_source)
        if replanner is not None:
            replanner.plan, replanner.mesh = plan, mesh
        if args.pin_l2:
            state = pin_l2_to_host(state, mesh)
        return state

    def next_boundary(step):
        """Next replan/reshard step strictly after ``step``."""
        ri = args.replan_iters
        b = min(args.steps, (step // ri + 1) * ri) if ri else args.steps
        if reshard_pending and step < args.reshard_at:
            b = min(b, args.reshard_at)
        return b

    def do_replan(state, step):
        """Harvest + recompile; on a real change, migrate + rebuild the step.
        Returns (state, migrated?)."""
        nonlocal plan, model, tcfg, step_fn
        out = replanner.maybe_replan(state, step=step)
        if out is None:
            return state, False
        plan, state = out
        model, tcfg, step_fn = build_step(plan)
        if args.pin_l2:
            state = pin_l2_to_host(state, mesh)
        return state, True

    if args.stream:
        # streaming driver: segments over the unbounded stream (--steps is
        # ignored); each segment boundary checkpoints, publishes, and may
        # apply the in-place reshard — no restart anywhere in the lifecycle
        ckpt = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
        active_ckpt = ckpt
        start = 0
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            state, start = restore_elastic(
                args.ckpt_dir, plan, state, mesh=mesh, axes=axes,
                log=lambda s: print(f"[train] elastic {s}", flush=True))
            stream.seek(start)  # resume replays from the exact batch index
            print(f"[train] stream resumed at step {start}", flush=True)

        publisher = None
        if args.publish_dir:
            def publisher(step, state):
                publish_state(args.publish_dir, step, state,
                              meta=plan_meta(plan))
                print(f"[stream] published step {step} -> {args.publish_dir}",
                      flush=True)
                if chaos is not None:
                    chaos.after_publish(step, args.publish_dir)

        def on_segment(seg, step, state):
            if reshard_pending and step >= args.reshard_at:
                state = do_reshard(state, step)
                return state, step_fn, stream
            return None

        state, last = run_stream(
            state, step_fn, stream,
            segment_steps=args.segment_steps,
            n_segments=args.stream_segments, start_step=start,
            checkpointer=ckpt, meta_fn=lambda: plan_meta(plan),
            publisher=publisher, on_metrics=on_metrics,
            on_segment=on_segment)
        if ckpt is not None:
            ckpt.wait()
        stream.close()
        print(f"[train] stream done at step {last} (world={world})")
        return

    if args.ckpt_dir:
        sup = Supervisor(args.ckpt_dir, ckpt_every=args.ckpt_every,
                         shardings=cur_shardings)
        active_ckpt = sup.ckpt
        # keep the plan sidecar on EVERY checkpoint: it records the world/
        # mesh the state was written under (elastic-restore detection) and —
        # for replanned runs — the plan revision; dropping it would make the
        # NEXT resume restore revision-shaped tiers into the seed-plan
        # template or shape-error on a world change
        sup.meta = plan_meta(plan)
        if meta is not None and int(meta.get("world", world)) != world:
            # checkpoint written at a different world size: route the restore
            # through the exact resharding path instead of the stale template
            state, start = restore_elastic(
                args.ckpt_dir, plan, state, mesh=mesh, axes=axes,
                log=lambda s: print(f"[train] elastic {s}", flush=True))
        else:
            state, start = sup.maybe_restore(state)
        stream.seek(start)  # resume replays from the exact batch index
        step = start
        # known limitation: a failure-restore *inside* a segment replays the
        # restored window without re-hitting an already-passed replan
        # boundary (the plan itself stays consistent — post-migration
        # checkpoints are written eagerly — but the replayed steps are folded
        # into the Replanner's metric window a second time, and the next
        # replan happens at the segment end rather than mid-replay)
        while step < args.steps:
            seg_end = next_boundary(step)
            state = sup.run(state, step_fn, stream, seg_end, start_step=step,
                            on_metrics=on_metrics, shardings=cur_shardings)
            step = seg_end
            if reshard_pending and step >= args.reshard_at \
                    and step < args.steps:
                state = do_reshard(state, step)
                # durable, mesh-consistent restore point: a later failure
                # must restore post-reshard row counts + the new world meta
                sup.meta = plan_meta(plan)
                sup.ckpt.save(step, state, meta=sup.meta)
                sup.ckpt.wait()
            if replanner is not None and step < args.steps:
                state, migrated = do_replan(state, step)
                if migrated:
                    # durable, plan-consistent restore point: a mid-segment
                    # failure must not restore pre-migration tier shapes
                    sup.meta = plan_meta(plan)
                    sup.ckpt.save(step, state, meta=sup.meta)
                    sup.ckpt.wait()
    else:
        it = iter(stream)
        for i in range(1, args.steps + 1):
            try:
                batch = next(it)
            except StopIteration:  # stream ended/stalled: finish gracefully,
                break              # matching the Supervisor path's semantics
            state, m = step_fn(state, batch)
            on_metrics(i, m)
            if reshard_pending and i >= args.reshard_at and i < args.steps:
                state = do_reshard(state, i)
                it = iter(stream)  # the Prefetcher was rebuilt for the new mesh
            if (replanner is not None and i % args.replan_iters == 0
                    and i < args.steps):
                state, _ = do_replan(state, i)
    if replanner is not None:
        n_mig = sum(1 for e in replanner.events if e.migrated)
        print(f"[train] replans: {len(replanner.events)} attempted, "
              f"{n_mig} migrated, final plan rev={plan.rev}")
    print("[train] done")


if __name__ == "__main__":
    main()
