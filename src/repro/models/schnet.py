"""SchNet [arXiv:1706.08566] — continuous-filter convolution GNN.

Message passing is built from the JAX scatter primitives (no sparse formats):
rbf(d_ij) -> filter MLP -> m_ij = x_src * W_ij -> segment_sum into dst.
Distribution: edge-parallel — edge arrays sharded over the whole mesh inside
``shard_map``; per-shard partial node aggregates are psum'd (d_hidden=64 keeps
node features cheap to replicate). PICASSO's embedding technique is
inapplicable here (no categorical tables) — see DESIGN.md §6.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import SchNetConfig
from repro.layers.mlp import init_linear, linear


def ssp(x):  # shifted softplus (SchNet activation)
    return jax.nn.softplus(x) - np.log(2.0)


def rbf_expand(dist: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 10.0 / cutoff
    return jnp.exp(-gamma * (dist[:, None] - centers[None, :]) ** 2)


def init_schnet(cfg: SchNetConfig, key: jax.Array, d_feat: int = 0) -> Dict:
    ks = jax.random.split(key, 4 + 6 * cfg.n_interactions)
    d = cfg.d_hidden
    p: Dict = {}
    if d_feat > 0:
        p["proj"] = init_linear(ks[0], d_feat, d)
    else:
        p["species"] = jax.random.normal(ks[0], (cfg.n_species, d)) * 0.1
    for i in range(cfg.n_interactions):
        k = ks[4 + 6 * i: 10 + 6 * i]
        p[f"int{i}"] = {
            "filt1": init_linear(k[0], cfg.n_rbf, d),
            "filt2": init_linear(k[1], d, d),
            "in": init_linear(k[2], d, d),
            "out1": init_linear(k[3], d, d),
            "out2": init_linear(k[4], d, d),
        }
    p["energy1"] = init_linear(ks[1], d, d // 2)
    p["energy2"] = init_linear(ks[2], d // 2, 1)
    return p


def interaction_block(p: Dict, x: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
                      rbf: jnp.ndarray, edge_w: jnp.ndarray, n_nodes: int,
                      axes: Optional[Tuple[str, ...]]) -> jnp.ndarray:
    """One cfconv + atom-wise block. Edge arrays may be sharded (axes given)."""
    w = linear(p["filt2"], ssp(linear(p["filt1"], rbf)))          # [E, d]
    m = linear(p["in"], x)[src] * w * edge_w[:, None]             # gather + modulate
    agg = jax.ops.segment_sum(m, dst, num_segments=n_nodes)       # scatter-add
    if axes is not None:
        agg = lax.psum(agg, axes)                                  # combine edge shards
    v = linear(p["out2"], ssp(linear(p["out1"], agg)))
    return x + v


def schnet_forward(cfg: SchNetConfig, p: Dict, nodes: jnp.ndarray, src: jnp.ndarray,
                   dst: jnp.ndarray, dist: jnp.ndarray, edge_w: jnp.ndarray,
                   axes: Optional[Tuple[str, ...]] = None) -> jnp.ndarray:
    """nodes: [N, d_feat] float or [N] int32 species; returns per-node energy [N]."""
    if nodes.dtype in (jnp.int32, jnp.int64):
        x = jnp.take(p["species"], nodes, axis=0)
    else:
        x = linear(p["proj"], nodes)
    rbf = rbf_expand(dist, cfg.n_rbf, cfg.cutoff)
    n = x.shape[0]
    for i in range(cfg.n_interactions):
        x = interaction_block(p[f"int{i}"], x, src, dst, rbf, edge_w, n, axes)
    e = linear(p["energy2"], ssp(linear(p["energy1"], x)))
    return e[:, 0]


def schnet_loss(cfg: SchNetConfig, p: Dict, batch: Dict,
                axes: Optional[Tuple[str, ...]] = None) -> jnp.ndarray:
    """Per-node (or per-graph, when graph_ids given) energy regression MSE."""
    e = schnet_forward(cfg, p, batch["nodes"], batch["src"], batch["dst"],
                       batch["dist"], batch["edge_w"], axes=axes)
    if "graph_ids" in batch:
        e = jax.ops.segment_sum(e, batch["graph_ids"], num_segments=batch["target"].shape[0])
    err = (e - batch["target"]) ** 2
    if "node_w" in batch:
        err = err * batch["node_w"]
        return err.sum() / jnp.clip(batch["node_w"].sum(), 1.0)
    return err.mean()
