"""Generic Wide-and-Deep-Learning model (paper Fig. 2).

embedding layer (packed, MP) -> feature-interaction modules -> MLP -> logits.
Covers the four assigned recsys archs (deepfm / dcn-v2 / sasrec / mind) and
the paper's own models (W&D / DLRM / DIN / MMoE / CAN) through the
InteractionSpec wiring in the arch config.

The model consumes the *packed group outputs* of the PICASSO engine:
``pooled[gid]: [B, n_bags_g, D_g]`` plus the raw batch (for masks / dense
features) and produces ``logits [B, n_tasks]``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import WDLConfig
from repro.core.features import FieldView, field_index
from repro.core.packing import PicassoPlan
from repro.layers import interactions as I
from repro.layers.mlp import init_linear, init_mlp, linear, mlp


class WDLModel:
    def __init__(self, cfg: WDLConfig, plan: PicassoPlan):
        self.cfg = cfg
        self.plan = plan
        self.fidx: Dict[str, FieldView] = field_index(plan)
        self.pooled_fields = [f for f in cfg.fields if f.pooling != "none"]
        self.seq_fields = [f for f in cfg.fields if f.pooling == "none"]
        self._wiring = self._plan_wiring()

    # ------------------------------------------------------------------ views
    def field_emb(self, pooled: Dict[int, jnp.ndarray], name: str) -> jnp.ndarray:
        v = self.fidx[name]
        out = pooled[v.gid][:, v.bag_offset:v.bag_offset + v.n_bags, :]
        return out[:, 0, :] if v.n_bags == 1 and self.cfg.field_by_name(name).pooling != "none" else out

    def field_mask(self, batch: Dict, name: str) -> jnp.ndarray:
        return batch["fields"][name]["weights"] > 0

    # ----------------------------------------------------------------- wiring
    def _plan_wiring(self) -> Dict[str, Any]:
        cfg = self.cfg
        base_dim = sum(f.dim for f in self.pooled_fields)
        dense_dim = cfg.dense_arch[-1] if cfg.dense_arch else cfg.n_dense
        base_dim += dense_dim
        deep_dim = 0
        consumed_base = False
        mmoe_spec = None
        for it in cfg.interactions:
            if it.kind == "linear" or it.kind == "fm":
                continue  # logit-like
            elif it.kind == "cross":
                deep_dim += base_dim
                consumed_base = True
            elif it.kind == "dot":
                dims = [f.dim for f in self.pooled_fields]
                d0 = dims[0]
                nf = sum(1 for d in dims if d == d0) + (1 if dense_dim == d0 else 0)
                deep_dim += nf * (nf - 1) // 2
            elif it.kind == "self_attn_seq":
                d = self.cfg.field_by_name(it.fields[0]).dim
                deep_dim += 3 * d
            elif it.kind == "target_attn":
                hists = [f for f in it.fields if self.cfg.field_by_name(f).pooling == "none"]
                d = self.cfg.field_by_name(hists[0]).dim
                deep_dim += len(hists) * d
            elif it.kind == "capsule":
                d = self.cfg.field_by_name(it.fields[0]).dim
                deep_dim += 2 * d
            elif it.kind == "gru":
                deep_dim += self.cfg.field_by_name(it.fields[0]).dim
            elif it.kind == "coaction":
                deep_dim += it.kwargs.get("layers", (4, 4))[-1]
            elif it.kind == "mmoe":
                mmoe_spec = it
            else:
                raise ValueError(f"unknown interaction {it.kind}")
        if not consumed_base:
            deep_dim += base_dim
        return {"base_dim": base_dim, "dense_dim": dense_dim, "deep_dim": deep_dim,
                "mmoe": mmoe_spec, "consumed_base": consumed_base}

    # ------------------------------------------------------------------- init
    def init_dense(self, key: jax.Array) -> Dict:
        cfg, w = self.cfg, self._wiring
        params: Dict[str, Any] = {}
        key, *ks = jax.random.split(key, len(cfg.interactions) + 2)
        ki = iter(ks)
        if cfg.dense_arch:
            params["bottom"] = init_mlp(next(ki), cfg.n_dense, cfg.dense_arch)
        for n, it in enumerate(cfg.interactions):
            name = f"i{n}_{it.kind}"
            if it.kind == "linear":
                k = next(ki)
                params[name] = {
                    f.name: jax.random.normal(jax.random.fold_in(k, i), (f.dim, 1)) * 0.01
                    for i, f in enumerate(self.pooled_fields)}
            elif it.kind == "cross":
                params[name] = I.init_cross(next(ki), w["base_dim"], it.kwargs.get("n_layers", 3))
            elif it.kind == "self_attn_seq":
                d = cfg.field_by_name(it.fields[0]).dim
                params[name] = I.init_self_attn_seq(next(ki), d, it.kwargs.get("n_blocks", 2),
                                                    it.kwargs.get("n_heads", 1))
            elif it.kind == "target_attn":
                d = cfg.field_by_name(it.fields[0]).dim
                params[name] = I.init_target_attn(next(ki), d)
            elif it.kind == "capsule":
                d = cfg.field_by_name(it.fields[0]).dim
                params[name] = I.init_capsule(next(ki), d, it.kwargs.get("n_interests", 4))
            elif it.kind == "gru":
                d = cfg.field_by_name(it.fields[0]).dim
                params[name] = I.init_gru(next(ki), d)
            elif it.kind == "mmoe":
                params[name] = I.init_mmoe(next(ki), w["deep_dim"],
                                           it.kwargs.get("n_experts", 4),
                                           it.kwargs.get("expert_dim", 64),
                                           cfg.n_tasks)
        if w["mmoe"] is not None:
            ed = w["mmoe"].kwargs.get("expert_dim", 64)
            for t in range(cfg.n_tasks):
                key, k2 = jax.random.split(key)
                params[f"task{t}"] = init_mlp(k2, ed, tuple(cfg.mlp_dims) + (1,))
        else:
            key, k2 = jax.random.split(key)
            params["top"] = init_mlp(k2, w["deep_dim"], tuple(cfg.mlp_dims) + (cfg.n_tasks,))
        return params

    # ------------------------------------------------------------------ apply
    def apply(self, params: Dict, pooled: Dict[int, jnp.ndarray], batch: Dict) -> jnp.ndarray:
        cfg, w = self.cfg, self._wiring
        b = next(iter(pooled.values())).shape[0]

        dense_proc = None
        if cfg.n_dense > 0:
            dx = batch["dense"]
            dense_proc = mlp(params["bottom"], dx) if cfg.dense_arch else dx

        base_parts = [self.field_emb(pooled, f.name) for f in self.pooled_fields]
        if dense_proc is not None:
            base_parts.append(dense_proc)
        base = jnp.concatenate(base_parts, axis=-1) if base_parts else jnp.zeros((b, 0))

        wide_logit = jnp.zeros((b, 1))
        deep_parts: List[jnp.ndarray] = []

        for n, it in enumerate(cfg.interactions):
            name = f"i{n}_{it.kind}"
            if it.kind == "linear":
                for f in self.pooled_fields:
                    wide_logit = wide_logit + self.field_emb(pooled, f.name) @ params[name][f.name]
            elif it.kind == "fm":
                by_dim: Dict[int, List[jnp.ndarray]] = {}
                for f in self.pooled_fields:
                    by_dim.setdefault(f.dim, []).append(self.field_emb(pooled, f.name))
                for es in by_dim.values():
                    if len(es) > 1:
                        wide_logit = wide_logit + I.fm_interaction(jnp.stack(es, axis=1))
            elif it.kind == "dot":
                dims = [f.dim for f in self.pooled_fields]
                d0 = dims[0]
                es = [self.field_emb(pooled, f.name) for f in self.pooled_fields if f.dim == d0]
                if dense_proc is not None and dense_proc.shape[-1] == d0:
                    es.append(dense_proc)
                deep_parts.append(I.dot_interaction(jnp.stack(es, axis=1)))
            elif it.kind == "cross":
                deep_parts.append(I.cross_net(params[name], base))
            elif it.kind == "self_attn_seq":
                hist_f, pos_f, tgt_f = it.fields
                seq = self.field_emb(pooled, hist_f) + self.field_emb(pooled, pos_f)
                mask = self.field_mask(batch, hist_f)
                repr_ = I.self_attn_seq(params[name], seq, mask,
                                        n_heads=it.kwargs.get("n_heads", 1))
                tgt = self.field_emb(pooled, tgt_f)
                wide_logit = wide_logit + jnp.sum(repr_ * tgt, axis=-1, keepdims=True)
                deep_parts += [repr_, tgt, repr_ * tgt]
            elif it.kind == "target_attn":
                tgt_name = it.fields[-1]
                tgt = self.field_emb(pooled, tgt_name)
                for fn in it.fields[:-1]:
                    hist = self.field_emb(pooled, fn)
                    deep_parts.append(I.target_attn(params[name], hist, tgt, self.field_mask(batch, fn)))
            elif it.kind == "capsule":
                hist_f, tgt_f = it.fields
                hist = self.field_emb(pooled, hist_f)
                tgt = self.field_emb(pooled, tgt_f)
                caps = I.capsule_routing(params[name], hist, self.field_mask(batch, hist_f),
                                         it.kwargs.get("routing_iters", 3),
                                         jax.random.PRNGKey(17),
                                         n_interests=it.kwargs.get("n_interests", 4))
                deep_parts += [I.label_aware_attn(caps, tgt), tgt]
            elif it.kind == "gru":
                fn = it.fields[0]
                deep_parts.append(I.gru(params[name], self.field_emb(pooled, fn),
                                        self.field_mask(batch, fn)))
            elif it.kind == "coaction":
                hist_f, tgt_f = it.fields
                deep_parts.append(I.coaction(self.field_emb(pooled, hist_f),
                                             self.field_emb(pooled, tgt_f),
                                             self.field_mask(batch, hist_f),
                                             it.kwargs.get("layers", (4, 4))))
            elif it.kind == "mmoe":
                pass  # handled below

        if not w["consumed_base"]:
            deep_parts = [base] + deep_parts
        deep_in = jnp.concatenate(deep_parts, axis=-1)

        if w["mmoe"] is not None:
            n = list(cfg.interactions).index(w["mmoe"])
            towers = I.mmoe(params[f"i{n}_mmoe"], deep_in)
            logits = jnp.concatenate(
                [mlp(params[f"task{t}"], towers[t], final_act=False) for t in range(cfg.n_tasks)],
                axis=-1)
        else:
            logits = mlp(params["top"], deep_in, final_act=False)
        return logits + wide_logit

    # -------------------------------------------------------------- retrieval
    def user_repr(self, params: Dict, pooled: Dict[int, jnp.ndarray], batch: Dict
                  ) -> jnp.ndarray:
        """User-tower vectors [K, D] for two-tower retrieval (K>1: MIND)."""
        cfg = self.cfg
        for n, it in enumerate(cfg.interactions):
            name = f"i{n}_{it.kind}"
            if it.kind == "self_attn_seq":
                hist_f, pos_f, _ = it.fields
                seq = self.field_emb(pooled, hist_f) + self.field_emb(pooled, pos_f)
                r = I.self_attn_seq(params[name], seq, self.field_mask(batch, hist_f),
                                    n_heads=it.kwargs.get("n_heads", 1))
                return r  # [1, D]
            if it.kind == "capsule":
                hist_f, _ = it.fields
                hist = self.field_emb(pooled, hist_f)
                caps = I.capsule_routing(params[name], hist,
                                         self.field_mask(batch, hist_f),
                                         it.kwargs.get("routing_iters", 3),
                                         jax.random.PRNGKey(17),
                                         n_interests=it.kwargs.get("n_interests", 4))
                return caps[0]  # [K, D]
        # CTR fallback: mean of pooled embeddings of the first dim-group
        embs = [self.field_emb(pooled, f.name) for f in self.pooled_fields]
        return jnp.mean(jnp.stack(embs, 0), 0)

    # ------------------------------------------------------------------- loss
    def loss(self, params: Dict, pooled: Dict[int, jnp.ndarray], batch: Dict
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        logits = self.apply(params, pooled, batch)
        labels = batch["labels"]
        if labels.ndim == 1:
            labels = labels[:, None]
        labels = jnp.broadcast_to(labels, logits.shape).astype(logits.dtype)
        ls = jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
        return ls.sum(), logits
