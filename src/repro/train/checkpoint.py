"""Checkpoint save/restore: atomic, async-capable, elastic across mesh sizes.

Layout: <dir>/step_<n>/ manifest.json + one .npy per leaf (zstd-compressed).
Embedding tables are stored *logically* (gathered, world-size padding kept but
recorded), so a checkpoint written on 512 chips restores onto any mesh: the
row space is world-independent (scramble + offsets derive from raw vocabs;
only the tail padding differs). A world-size mismatch is *detected* here
(``on_row_mismatch``) and re-cut by the elastic path
(``runtime.elastic.restore_elastic``), which remaps tier sentinel keys.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:  # optional: plain .npy files when the container lacks zstandard
    import zstandard
except ImportError:
    zstandard = None

_SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def rec(prefix, node):
        if node is None:  # optional subtree (e.g. a group without an L2 tier)
            return
        if isinstance(node, dict):
            for k, v in node.items():
                rec(f"{prefix}{_SEP}{k}" if prefix else str(k), v)
        elif hasattr(node, "_fields"):  # NamedTuple
            for k in node._fields:
                rec(f"{prefix}{_SEP}{k}" if prefix else str(k), getattr(node, k))
        else:
            flat[prefix] = node

    rec("", tree)
    return flat


def _unflatten_into(template, flat: Dict[str, Any]):
    def rec(prefix, node):
        if node is None:
            return None
        if isinstance(node, dict):
            return {k: rec(f"{prefix}{_SEP}{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if hasattr(node, "_fields"):
            vals = {k: rec(f"{prefix}{_SEP}{k}" if prefix else str(k), getattr(node, k))
                    for k in node._fields}
            return type(node)(**vals)
        return flat[prefix]

    return rec("", template)


def save_checkpoint(ckpt_dir: str, step: int, state: Any, keep: int = 3,
                    meta: Optional[Dict[str, Any]] = None) -> str:
    """Atomic checkpoint: write to tmp, fsync, rename.

    ``meta`` is an optional JSON-serializable sidecar stored in the manifest
    — the trainer records the live plan revision there
    (``repro.runtime.plan_meta``) so a resume can rebuild the *current*
    (possibly replanned) plan before shaping the restore template, instead
    of the seed plan the run started from.
    """
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
    flat = _flatten(jax.device_get(state))
    manifest = {}
    cctx = zstandard.ZstdCompressor(level=3) if zstandard is not None else None
    for name, arr in flat.items():
        arr = np.asarray(arr)
        fn = name.replace(_SEP, "__") + (".npy.zst" if cctx else ".npy")
        payload = _np_bytes(arr)
        with open(tmp / fn, "wb") as f:
            f.write(cctx.compress(payload) if cctx else payload)
        manifest[name] = {"file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    doc = {"step": step, "leaves": manifest}
    if meta is not None:
        doc["meta"] = meta
    (tmp / "manifest.json").write_text(json.dumps(doc))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc_checkpoints(ckpt_dir, keep)
    return str(final)


def _np_bytes(arr: np.ndarray) -> bytes:
    import io
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _np_from_bytes(b: bytes) -> np.ndarray:
    import io
    return np.load(io.BytesIO(b), allow_pickle=False)


def _gc_checkpoints(ckpt_dir: Path, keep: int) -> None:
    steps = sorted(p for p in ckpt_dir.iterdir() if p.name.startswith("step_"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.iterdir()
                   if p.name.startswith("step_") and (p / "manifest.json").exists())
    return steps[-1] if steps else None


def load_checkpoint_meta(ckpt_dir: str, step: Optional[int] = None
                         ) -> Optional[Dict[str, Any]]:
    """The ``meta`` sidecar of a checkpoint (``None`` if absent — e.g. a
    checkpoint written before replanning existed, or with replanning off).

    Callers that revise the plan from it must do so *before* building the
    restore template: tier shapes in the stored state follow the plan
    revision recorded here, not the seed plan.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None
    d = Path(ckpt_dir) / f"step_{step:08d}"
    return json.loads((d / "manifest.json").read_text()).get("meta")


def restore_checkpoint(ckpt_dir: str, template: Any, step: Optional[int] = None,
                       shardings: Any = None,
                       on_row_mismatch: str = "error") -> Tuple[Any, int]:
    """Restore into ``template`` (abstract or concrete pytree).

    ``on_row_mismatch`` decides what happens when a stored leaf's leading dim
    (world-padding) differs from the template's:

    - ``"error"`` (default): raise with the leaf name and both shapes, plus
      the elastic-restore pointer. A row mismatch means the checkpoint was
      written at a different world size, and blindly re-padding corrupts
      tier sentinel keys (an old-sentinel ``rows_padded_old`` entry becomes
      a valid-looking key into a padding row) — the caller must go through
      ``runtime.elastic.restore_elastic`` / ``embedding.state.reshard_state``
      instead, which remap the sentinels.
    - ``"keep"``: return the leaf at its STORED leading dim (the template's
      trailing dims must match). The elastic restore path uses this to pull
      the world-W state out before resharding it properly.
    - ``"repad"``: legacy behavior — zero-extend / truncate to the
      template's rows. Only safe for states without cache tiers (no
      sentinel keys), e.g. dense-only models.
    """
    if on_row_mismatch not in ("error", "keep", "repad"):
        raise ValueError(f"on_row_mismatch must be 'error', 'keep', or "
                         f"'repad', got {on_row_mismatch!r}")
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())["leaves"]
    dctx = zstandard.ZstdDecompressor() if zstandard is not None else None
    tflat = _flatten(template)
    out = {}
    for name, t in tflat.items():
        info = manifest.get(name)
        if info is None:
            raise KeyError(
                f"checkpoint step_{step:08d} has no leaf {name!r} — the "
                "template enables state the run that wrote it did not "
                "(e.g. an L2 tier turned on after checkpointing)")
        raw = (d / info["file"]).read_bytes()
        if info["file"].endswith(".zst"):
            if dctx is None:
                raise ImportError(
                    f"checkpoint leaf {info['file']} is zstd-compressed but "
                    "zstandard is not installed")
            raw = dctx.decompress(raw)
        arr = _np_from_bytes(raw)
        tshape = tuple(t.shape)
        if tuple(arr.shape) != tshape:
            if not (arr.ndim >= 1 and arr.shape[1:] == tshape[1:]):
                raise ValueError(f"{name}: stored {arr.shape} vs template {tshape}")
            if on_row_mismatch == "error":
                raise ValueError(
                    f"{name}: stored {arr.shape} vs template {tshape} — row "
                    "count (world padding) differs, so this checkpoint was "
                    "written at a different world size. Restore through the "
                    "elastic path (runtime.elastic.restore_elastic / "
                    "embedding.state.reshard_state), which remaps tier "
                    "sentinel keys; a blind re-pad would corrupt them.")
            if on_row_mismatch == "repad":
                new = np.zeros(tshape, arr.dtype)
                n = min(arr.shape[0], tshape[0])
                new[:n] = arr[:n]
                arr = new  # legacy elastic re-pad (no-tier states only)
            # 'keep': hand back the stored rows untouched for resharding
        out[name] = arr.astype(t.dtype)
    state = _unflatten_into(template, out)
    if shardings is not None:
        state = jax.device_put(state, shardings)
    return state, step


class AsyncCheckpointer:
    """Snapshot-to-host then write in a background thread (training continues)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, state: Any,
             meta: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        host_state = jax.device_get(state)  # synchronous snapshot, async write

        def work():
            self.last_path = save_checkpoint(self.ckpt_dir, step, host_state,
                                             self.keep, meta=meta)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
