"""Checkpoint save/restore: atomic, async-capable, verified, elastic.

Layout: <dir>/step_<n>/ manifest.json + one .npy per leaf (zstd-compressed).
Embedding tables are stored *logically* (gathered, world-size padding kept but
recorded), so a checkpoint written on 512 chips restores onto any mesh: the
row space is world-independent (scramble + offsets derive from raw vocabs;
only the tail padding differs). A world-size mismatch is *detected* here
(``on_row_mismatch``) and re-cut by the elastic path
(``runtime.elastic.restore_elastic``), which remaps tier sentinel keys.

Integrity: every leaf's on-disk bytes are checksummed (crc32) into the
manifest at save time, and restore verifies them by default — a torn write,
a bad disk, or an injected fault (``runtime.chaos``) raises
``CheckpointCorrupt`` instead of silently loading poisoned state.
``restore_verified`` is the failover entry: it walks the available steps
newest-first, *quarantines* a corrupt checkpoint (``step_<n>`` ->
``step_<n>.corrupt``, kept for forensics, invisible to ``latest_step``/GC)
and falls back to the previous good one, so one bad snapshot never takes
down a resume.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:  # optional: plain .npy files when the container lacks zstandard
    import zstandard
except ImportError:
    zstandard = None

_SEP = "/"
_CORRUPT_SUFFIX = ".corrupt"


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed integrity verification (checksum mismatch, torn or
    missing leaf file, unreadable manifest). Distinct from a shape/world
    mismatch (``ValueError``): corruption means the *bytes* are wrong, and
    the recovery is to quarantine + fall back (``restore_verified``), not to
    reshard."""

    def __init__(self, msg: str, step: Optional[int] = None,
                 leaf: Optional[str] = None):
        super().__init__(msg)
        self.step = step
        self.leaf = leaf


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def rec(prefix, node):
        if node is None:  # optional subtree (e.g. a group without an L2 tier)
            return
        if isinstance(node, dict):
            for k, v in node.items():
                rec(f"{prefix}{_SEP}{k}" if prefix else str(k), v)
        elif hasattr(node, "_fields"):  # NamedTuple
            for k in node._fields:
                rec(f"{prefix}{_SEP}{k}" if prefix else str(k), getattr(node, k))
        else:
            flat[prefix] = node

    rec("", tree)
    return flat


def _unflatten_into(template, flat: Dict[str, Any]):
    def rec(prefix, node):
        if node is None:
            return None
        if isinstance(node, dict):
            return {k: rec(f"{prefix}{_SEP}{k}" if prefix else str(k), v)
                    for k, v in node.items()}
        if hasattr(node, "_fields"):
            vals = {k: rec(f"{prefix}{_SEP}{k}" if prefix else str(k), getattr(node, k))
                    for k in node._fields}
            return type(node)(**vals)
        return flat[prefix]

    return rec("", template)


def save_checkpoint(ckpt_dir: str, step: int, state: Any, keep: int = 3,
                    meta: Optional[Dict[str, Any]] = None) -> str:
    """Atomic checkpoint: write to tmp, fsync, rename.

    ``meta`` is an optional JSON-serializable sidecar stored in the manifest
    — the trainer records the live plan revision there
    (``repro.runtime.plan_meta``) so a resume can rebuild the *current*
    (possibly replanned) plan before shaping the restore template, instead
    of the seed plan the run started from.
    """
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
    flat = _flatten(jax.device_get(state))
    manifest = {}
    cctx = zstandard.ZstdCompressor(level=3) if zstandard is not None else None
    for name, arr in flat.items():
        arr = np.asarray(arr)
        fn = name.replace(_SEP, "__") + (".npy.zst" if cctx else ".npy")
        payload = _np_bytes(arr)
        data = cctx.compress(payload) if cctx else payload
        with open(tmp / fn, "wb") as f:
            f.write(data)
        # checksum of the bytes as they sit ON DISK (post-compression):
        # restore re-hashes exactly what it read, so any torn/corrupted
        # file is caught before a single byte is decompressed or parsed
        manifest[name] = {"file": fn, "shape": list(arr.shape),
                          "dtype": str(arr.dtype),
                          "crc32": zlib.crc32(data) & 0xFFFFFFFF}
    doc = {"step": step, "leaves": manifest}
    if meta is not None:
        doc["meta"] = meta
    (tmp / "manifest.json").write_text(json.dumps(doc))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc_checkpoints(ckpt_dir, keep)
    return str(final)


def _np_bytes(arr: np.ndarray) -> bytes:
    import io
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _np_from_bytes(b: bytes) -> np.ndarray:
    import io
    return np.load(io.BytesIO(b), allow_pickle=False)


def _parse_step_dir(p: Path) -> Optional[int]:
    """``step_00000040`` -> 40; quarantined (``.corrupt``) or otherwise
    unparseable entries -> None (skipped everywhere)."""
    if not p.name.startswith("step_") or p.name.endswith(_CORRUPT_SUFFIX):
        return None
    try:
        return int(p.name.split("_")[1])
    except (IndexError, ValueError):
        return None


def _gc_checkpoints(ckpt_dir: Path, keep: int) -> None:
    # quarantined checkpoints are forensic evidence, never GC'd here
    steps = sorted(p for p in ckpt_dir.iterdir()
                   if _parse_step_dir(p) is not None)
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def available_steps(ckpt_dir: str) -> List[int]:
    """Steps with a manifest on disk, ascending (quarantined dirs excluded)."""
    d = Path(ckpt_dir)
    if not d.exists():
        return []
    out = []
    for p in d.iterdir():
        s = _parse_step_dir(p)
        if s is not None and (p / "manifest.json").exists():
            out.append(s)
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def quarantine_checkpoint(ckpt_dir: str, step: int) -> Optional[str]:
    """Rename ``step_<n>`` -> ``step_<n>.corrupt`` so every reader
    (``latest_step``/``available_steps``/GC/restore) stops seeing it, while
    the bytes stay on disk for postmortem. Returns the quarantine path, or
    ``None`` if the directory had already vanished (lost a prune race)."""
    src = Path(ckpt_dir) / f"step_{step:08d}"
    if not src.exists():
        return None
    dst = src.with_name(src.name + _CORRUPT_SUFFIX)
    if dst.exists():  # re-quarantine of a rewritten step: keep both
        n = 1
        while dst.with_name(f"{src.name}{_CORRUPT_SUFFIX}.{n}").exists():
            n += 1
        dst = dst.with_name(f"{src.name}{_CORRUPT_SUFFIX}.{n}")
    os.rename(src, dst)
    return str(dst)


def _read_manifest(ckpt_dir: str, step: int) -> Dict[str, Any]:
    """Manifest of one step; unreadable/unparseable -> CheckpointCorrupt,
    a missing directory -> FileNotFoundError (pruned, not corrupt)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    if not d.exists():
        raise FileNotFoundError(f"no checkpoint step_{step:08d} under {ckpt_dir}")
    try:
        return json.loads((d / "manifest.json").read_text())
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointCorrupt(
            f"checkpoint step_{step:08d}: manifest unreadable ({e})",
            step=step) from e


def load_checkpoint_meta(ckpt_dir: str, step: Optional[int] = None
                         ) -> Optional[Dict[str, Any]]:
    """The ``meta`` sidecar of a checkpoint (``None`` if absent — e.g. a
    checkpoint written before replanning existed, or with replanning off).

    Callers that revise the plan from it must do so *before* building the
    restore template: tier shapes in the stored state follow the plan
    revision recorded here, not the seed plan. With ``step=None`` this walks
    back from the newest checkpoint past any with an unreadable manifest —
    a corrupt newest snapshot must not crash a resume before
    ``restore_verified`` even gets the chance to quarantine it.
    """
    if step is not None:
        return _read_manifest(ckpt_dir, step).get("meta")
    for s in reversed(available_steps(ckpt_dir)):
        try:
            return _read_manifest(ckpt_dir, s).get("meta")
        except CheckpointCorrupt:
            continue  # restore_verified will quarantine it
    return None


def restore_checkpoint(ckpt_dir: str, template: Any, step: Optional[int] = None,
                       shardings: Any = None,
                       on_row_mismatch: str = "error",
                       verify: bool = True) -> Tuple[Any, int]:
    """Restore into ``template`` (abstract or concrete pytree).

    ``verify`` (default on) re-hashes every leaf's on-disk bytes against the
    manifest's crc32 and raises ``CheckpointCorrupt`` on any mismatch,
    missing leaf file, or unreadable manifest — corruption is *detected*
    here; the quarantine + fallback policy lives in ``restore_verified``.
    Checkpoints written before checksums existed verify trivially (no crc32
    recorded -> nothing to check).

    ``on_row_mismatch`` decides what happens when a stored leaf's leading dim
    (world-padding) differs from the template's:

    - ``"error"`` (default): raise with the leaf name and both shapes, plus
      the elastic-restore pointer. A row mismatch means the checkpoint was
      written at a different world size, and blindly re-padding corrupts
      tier sentinel keys (an old-sentinel ``rows_padded_old`` entry becomes
      a valid-looking key into a padding row) — the caller must go through
      ``runtime.elastic.restore_elastic`` / ``embedding.state.reshard_state``
      instead, which remap the sentinels.
    - ``"keep"``: return the leaf at its STORED leading dim (the template's
      trailing dims must match). The elastic restore path uses this to pull
      the world-W state out before resharding it properly.
    - ``"repad"``: legacy behavior — zero-extend / truncate to the
      template's rows. Only safe for states without cache tiers (no
      sentinel keys), e.g. dense-only models.
    """
    if on_row_mismatch not in ("error", "keep", "repad"):
        raise ValueError(f"on_row_mismatch must be 'error', 'keep', or "
                         f"'repad', got {on_row_mismatch!r}")
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = _read_manifest(ckpt_dir, step)["leaves"]
    dctx = zstandard.ZstdDecompressor() if zstandard is not None else None
    tflat = _flatten(template)
    out = {}
    for name, t in tflat.items():
        info = manifest.get(name)
        if info is None:
            raise KeyError(
                f"checkpoint step_{step:08d} has no leaf {name!r} — the "
                "template enables state the run that wrote it did not "
                "(e.g. an L2 tier turned on after checkpointing)")
        try:
            raw = (d / info["file"]).read_bytes()
        except OSError as e:
            raise CheckpointCorrupt(
                f"checkpoint step_{step:08d}: leaf file {info['file']} "
                f"unreadable ({e})", step=step, leaf=name) from e
        if verify and "crc32" in info:
            crc = zlib.crc32(raw) & 0xFFFFFFFF
            if crc != info["crc32"]:
                raise CheckpointCorrupt(
                    f"checkpoint step_{step:08d}: leaf {name!r} checksum "
                    f"mismatch (stored {info['crc32']:#010x}, on-disk "
                    f"{crc:#010x}) — torn write or disk corruption",
                    step=step, leaf=name)
        if info["file"].endswith(".zst"):
            if dctx is None:
                raise ImportError(
                    f"checkpoint leaf {info['file']} is zstd-compressed but "
                    "zstandard is not installed")
            try:
                raw = dctx.decompress(raw)
            except zstandard.ZstdError as e:
                # pre-checksum checkpoint with damaged bytes (crc32 would
                # have caught this above): still classified as corruption
                raise CheckpointCorrupt(
                    f"checkpoint step_{step:08d}: leaf {name!r} failed to "
                    f"decompress ({e})", step=step, leaf=name) from e
        try:
            arr = _np_from_bytes(raw)
        except ValueError as e:
            raise CheckpointCorrupt(
                f"checkpoint step_{step:08d}: leaf {name!r} is not a valid "
                f".npy payload ({e})", step=step, leaf=name) from e
        tshape = tuple(t.shape)
        if tuple(arr.shape) != tshape:
            if not (arr.ndim >= 1 and arr.shape[1:] == tshape[1:]):
                raise ValueError(f"{name}: stored {arr.shape} vs template {tshape}")
            if on_row_mismatch == "error":
                raise ValueError(
                    f"{name}: stored {arr.shape} vs template {tshape} — row "
                    "count (world padding) differs, so this checkpoint was "
                    "written at a different world size. Restore through the "
                    "elastic path (runtime.elastic.restore_elastic / "
                    "embedding.state.reshard_state), which remaps tier "
                    "sentinel keys; a blind re-pad would corrupt them.")
            if on_row_mismatch == "repad":
                new = np.zeros(tshape, arr.dtype)
                n = min(arr.shape[0], tshape[0])
                new[:n] = arr[:n]
                arr = new  # legacy elastic re-pad (no-tier states only)
            # 'keep': hand back the stored rows untouched for resharding
        out[name] = arr.astype(t.dtype)
    state = _unflatten_into(template, out)
    if shardings is not None:
        state = jax.device_put(state, shardings)
    return state, step


def restore_verified(ckpt_dir: str, template: Any, *,
                     step: Optional[int] = None, shardings: Any = None,
                     on_row_mismatch: str = "error",
                     quarantine: bool = True,
                     log: Optional[Callable[[str], None]] = None
                     ) -> Tuple[Any, int]:
    """Restore the newest checkpoint that passes integrity verification.

    Walks the available steps newest-first (or starts at ``step``); a
    checkpoint that raises ``CheckpointCorrupt`` is quarantined
    (``step_<n>`` -> ``step_<n>.corrupt``) and the walk falls back to the
    previous good one. Shape/world mismatches (``ValueError``) propagate —
    those are elastic-restore business, not corruption. Raises
    ``FileNotFoundError`` when no verifiable checkpoint remains.
    """
    log = log or (lambda s: None)
    steps = [s for s in reversed(available_steps(ckpt_dir))
             if step is None or s <= step]
    if not steps:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    for s in steps:
        try:
            return restore_checkpoint(ckpt_dir, template, step=s,
                                      shardings=shardings,
                                      on_row_mismatch=on_row_mismatch,
                                      verify=True)
        except CheckpointCorrupt as e:
            if quarantine:
                q = quarantine_checkpoint(ckpt_dir, s)
                log(f"quarantined corrupt checkpoint step {s}"
                    f"{' -> ' + q if q else ''} ({e}); falling back")
            else:
                log(f"corrupt checkpoint step {s} ({e}); falling back")
    raise FileNotFoundError(
        f"no verifiable checkpoint under {ckpt_dir}: all "
        f"{len(steps)} candidate(s) failed integrity checks")


class AsyncCheckpointer:
    """Snapshot-to-host then write in a background thread (training continues)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save(self, step: int, state: Any,
             meta: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        host_state = jax.device_get(state)  # synchronous snapshot, async write

        def work():
            self.last_path = save_checkpoint(self.ckpt_dir, step, host_state,
                                             self.keep, meta=meta)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
