"""Hybrid MP/DP train step for WDL models (paper §III-A + Fig. 6).

One SPMD program under ``shard_map`` over the full mesh:

  pack (D-Packing) -> EmbeddingEngine.forward (K-Packing + K-Interleaving)
  -> micro-batch pipeline (D-Interleaving): dense fwd/bwd of chunk i overlaps
     the Shuffle of chunk i+1
  -> dense grads psum (DP) + Adam ; EmbeddingEngine.backward routes sparse
     grads (MP) + row-wise Adagrad ; HybridHash hit grads psum'd into the
     replicated hot tier
  -> FCounter update ; periodic HybridHash flush (EmbeddingEngine.flush).

The D-Interleaving pipeline has two strengths, both static knobs:

``pipeline_micro`` (legacy order) issues chunk i+1's Shuffle before chunk
i's dense compute and trusts XLA's latency-hiding scheduler to interleave
them. ``overlap`` ('off' | 'on' | 'auto', the *software-pipelined* step)
additionally double-buffers the prefetch: the lookup of chunk i+1 and the
consumed outputs of chunk i pass through one ``optimization_barrier``
(``pipeline_handoff``), which pins the two-slot schedule — the compiler can
neither sink the in-flight Shuffle below the dense stage nor collapse the
two buffers. Barriers are value-identity, so 'on' and 'off' compute
bit-identical numbers; 'off' is byte-for-byte the legacy step (a regression
test pins its jaxpr), and 'auto' turns overlap on exactly when the step has
more than one micro-batch to pipeline.

The whole sparse path lives in ``repro.engine.EmbeddingEngine``; this module
only owns the micro-batch pipeline, the dense optimizer, and metric psums.
Strategies (paper §II-C / §IV baselines) are selected per packed group via
``TrainConfig.strategy``:
  'picasso' — the full system (packed + interleaved + HybridHash);
  'picasso_l2' — picasso plus an L2 host-memory cache tier behind the hot
      tier (requires a plan built with ``l2_bytes > 0``; emits per-tier
      ``cache_hits/l1`` / ``cache_hits/l2`` counters);
  'picasso_narrow' — picasso_l2 with frequency-adaptive widths: hot ids
      full-width in the tiers, the cold master narrow (requires a plan
      built with ``narrow_dim``; cold rows are projected up at lookup);
  'hybrid'  — MP all_to_all per group but no HybridHash tier;
  'ps'      — PS-style all_gather+psum lookups (the fragmentary baseline);
  'mixed'/'auto' — per-group assignment from the plan (or compiled by the
      ``repro.core.assign`` cost model), also spellable as a {gid: name}
      dict / ``StrategyAssignment``. Mixed runs emit per-strategy-class
      ``overflow/<name>`` / ``cache_hits/<name>`` metric breakdowns.
Unknown names raise at trace-construction time with the registry's menu.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.features import PackedBatch, pack_group
from repro.core.interleaving import pipeline_handoff, resolve_overlap
from repro.core.packing import PicassoPlan
from repro.dist.compat import shard_map
from repro.dist.sharding import batch_specs, emb_specs, state_specs, to_named
from repro.embedding.state import EmbeddingState
from repro.engine import EmbeddingEngine
from repro.models.wdl import WDLModel
from repro.optim.optimizers import adam_init, adam_update, lamb_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr_emb: float = 0.05
    lr_dense: float = 1e-3
    optimizer: str = "adam"        # 'adam' | 'lamb'
    # registry name ('picasso' | 'hybrid' | 'ps'), 'mixed'/'auto' (per-group
    # assignment from the plan / cost model), {gid: name}, or a
    # StrategyAssignment — anything repro.core.assign.resolve_assignment takes
    strategy: Any = "picasso"
    pipeline_micro: bool = True    # D-Interleaving pipeline order
    # software-pipelined step: 'off' = the legacy (jaxpr-pinned) loop,
    # 'on' = double-buffered prefetch behind a pipeline_handoff barrier,
    # 'auto' = on exactly when n_micro > 1 (bools accepted too)
    overlap: Any = "auto"
    use_cache: bool = True
    use_l2: bool = True            # L2 host tier (only where the plan
                                   # budgets l2_rows AND L1 is active)
    use_interleave: bool = True    # K-Interleaving waves (False: one wave)
    # fused Pallas sparse kernels (gather+pool VJP, dedup+adagrad scatter,
    # tier probes): 'auto' = on where Pallas runs (TPU / interpret soak),
    # True/'on' force, False/'off' force the jnp reference chains
    use_fused_kernels: Any = "auto"
    cache_update: str = "psum"     # 'psum' (exact) | 'stale' (Algorithm 1)
    flush_in_step: bool = True     # False: host calls make_flush_fn() instead
    grad_compression: str = "none"  # 'none' | 'bf16' | 'f8' (dense DP psum)
    # wire compression of the ROUTED sparse-gradient payload ('none' |
    # 'fp16' | 'topk'; repro.optim.grad_compression.ROUTED_MODES) — applied
    # inside every strategy's backward collective
    grad_compress: str = "none"
    # mirror of the launcher's --pin-l2: the jitted step's out_shardings pin
    # the L2 tier (and narrow masters) to pinned_host memory so the initial
    # pin_l2_to_host placement survives across steps. Inert on backends
    # without a host memory kind (the CPU rig) — the step is byte-identical.
    pin_l2: bool = False
    eps: float = 1e-8


def _mesh_world(mesh, axes) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def _slice_micro(x, i, micro):
    return lax.dynamic_slice_in_dim(x, i * micro, micro, axis=0)


def make_train_step(model: WDLModel, plan: PicassoPlan, mesh, axes: Tuple[str, ...],
                    global_batch: int, tcfg: TrainConfig = TrainConfig(),
                    donate: bool = True):
    """Returns (jitted_step, state_specs_pytree). step(state, batch) -> (state, metrics).

    ``donate=False`` keeps the input state buffers alive across the call —
    required by the anomaly guard, which must be able to *reject* a step by
    returning the prior state (donation would have freed it). Donation only
    affects buffer aliasing, never the computed values, so a non-donating
    step is bitwise identical to the donating one at higher peak memory.
    """
    world = _mesh_world(mesh, axes)
    assert global_batch % world == 0, (global_batch, world)
    b_local = global_batch // world
    micro = plan.microbatch if plan.microbatch <= b_local else b_local
    n_micro = max(1, b_local // micro)

    # The engine owns lookups, pooling, sparse updates, and the flush;
    # the strategy name is validated against the registry right here.
    engine = EmbeddingEngine(
        plan, axes, world, strategy=tcfg.strategy, use_cache=tcfg.use_cache,
        use_l2=tcfg.use_l2, use_interleave=tcfg.use_interleave,
        lr_emb=tcfg.lr_emb, eps=tcfg.eps, cache_update=tcfg.cache_update,
        use_fused_kernels=tcfg.use_fused_kernels,
        grad_compress=tcfg.grad_compress)
    # static resolution: the traced loop below has no overlap branches left
    use_overlap = resolve_overlap(tcfg.overlap, n_micro)

    # -------------------------------------------------------- loss closure
    def micro_loss(dense, pooled, mb):
        loss_sum, logits = model.loss(dense, pooled, mb)
        return loss_sum / global_batch, logits

    # --------------------------------------------------------------- step
    def local_step(state, batch):
        emb: Dict[str, EmbeddingState] = dict(state["emb"])
        dense, opt, step = state["dense"], state["opt"], state["step"]

        packed_full = {g.gid: pack_group(g, batch["fields"]) for g in plan.groups}

        def packed_micro(i):
            out = {}
            for gid, pb in packed_full.items():
                g = plan.group(gid)
                ips = g.ids_per_sample
                ids = _slice_micro(pb.ids.reshape(b_local, ips), i, micro).reshape(-1)
                wts = _slice_micro(pb.weights.reshape(b_local, ips), i, micro).reshape(-1)
                seg = pb.seg[: micro * ips]  # per-sample pattern repeats
                out[gid] = PackedBatch(ids=ids, weights=wts, seg=seg, n_bags=g.n_bags)
            return out

        def batch_micro(i):
            mb = {"fields": {n: {k: _slice_micro(v, i, micro) for k, v in f.items()}
                             for n, f in batch["fields"].items()},
                  "labels": _slice_micro(batch["labels"], i, micro)}
            if "dense" in batch:
                mb["dense"] = _slice_micro(batch["dense"], i, micro)
            return mb

        grad_fn = jax.value_and_grad(micro_loss, argnums=(0, 1), has_aux=True)

        loss_acc = jnp.zeros(())
        g_dense_acc = jax.tree.map(jnp.zeros_like, dense)
        em_acc = {k: jnp.zeros((), jnp.int32) for k in engine.metric_keys}

        pending = (engine.forward(emb, packed_micro(0)), batch_micro(0))
        for i in range(n_micro):
            (pooled, ectx), mb = pending
            if use_overlap and i + 1 < n_micro:
                # software pipeline: the prefetch of chunk i+1 and the
                # consumed outputs of chunk i cross one handoff barrier, so
                # the in-flight Shuffle is pinned *beside* (not after) the
                # dense stage and the two buffer slots stay distinct
                nxt = engine.forward(emb, packed_micro(i + 1))
                (pooled, ectx), nxt = pipeline_handoff((pooled, ectx), nxt)
                pending = (nxt, batch_micro(i + 1))
            elif tcfg.pipeline_micro and i + 1 < n_micro:
                # D-Interleaving: issue Shuffle of chunk i+1 before dense of i
                pending = (engine.forward(emb, packed_micro(i + 1)),
                           batch_micro(i + 1))
            (loss, _logits), (g_dense, g_pooled) = grad_fn(dense, pooled, mb)
            loss_acc = loss_acc + loss
            g_dense_acc = jax.tree.map(jnp.add, g_dense_acc, g_dense)
            emb, em = engine.backward(emb, ectx, g_pooled)
            em_acc = {k: em_acc[k] + em[k] for k in em_acc}
            if not use_overlap and not (tcfg.pipeline_micro) and i + 1 < n_micro:
                pending = (engine.forward(emb, packed_micro(i + 1)),
                           batch_micro(i + 1))

        # ---- dense DP: psum grads over the whole mesh ----------------------
        if tcfg.grad_compression != "none":
            from repro.optim.grad_compression import compressed_psum
            g_dense_acc, _ = compressed_psum(g_dense_acc, axes,
                                             mode=tcfg.grad_compression)
        else:
            g_dense_acc = lax.psum(g_dense_acc, axes)
        loss_glob = lax.psum(loss_acc, axes)
        upd = adam_update if tcfg.optimizer == "adam" else lamb_update
        dense2, opt2 = upd(dense, g_dense_acc, opt, tcfg.lr_dense)

        # ---- HybridHash flush (Algorithm 1 L23-26) -------------------------
        step2 = step + 1
        if engine.any_cache and tcfg.flush_in_step:
            do_flush = (step2 >= plan.warmup_iters) & (step2 % plan.flush_iters == 0)
            emb = lax.cond(do_flush, engine.flush, lambda e: e, emb)

        new_state = {"emb": emb, "dense": dense2, "opt": opt2, "step": step2}
        # global dense-gradient norm (g_dense_acc is already psum'd): the
        # numeric health signal runtime.guard thresholds for spike rejection
        grad_norm = jnp.sqrt(sum(jnp.vdot(g, g)
                                 for g in jax.tree.leaves(g_dense_acc)))
        metrics = {"loss": loss_glob, "step": step2, "grad_norm": grad_norm,
                   **{k: lax.psum(em_acc[k], axes) for k in engine.metric_keys}}
        return new_state, metrics

    # ---------------------------------------------------------------- wrap
    dense0 = jax.eval_shape(lambda k: model.init_dense(k), jax.random.PRNGKey(0))
    opt0 = jax.eval_shape(adam_init, dense0)
    sspecs = state_specs(plan, axes, dense0, opt0)
    mspecs = {"loss": P(), "step": P(), "grad_norm": P(),
              **{k: P() for k in engine.metric_keys}}

    def wrapped(state, batch):
        bspecs = batch_specs(batch, axes)
        f = shard_map(local_step, mesh=mesh,
                      in_specs=(sspecs, bspecs),
                      out_specs=(sspecs, mspecs),
                      check_vma=False)
        return f(state, batch)

    jit_kw = {}
    if tcfg.pin_l2:
        from repro.dist.sharding import host_memory_kind, state_shardings
        if host_memory_kind() is not None:
            # memory-kind-aware out shardings: without these the first step
            # would return the L2 tier / narrow masters in device memory and
            # the --pin-l2 placement would silently evaporate
            jit_kw["out_shardings"] = (
                state_shardings(plan, mesh, axes, dense0, opt0, pin_l2=True),
                to_named(mesh, mspecs))
    if donate:
        jit_kw["donate_argnums"] = (0,)
    step_fn = jax.jit(wrapped, **jit_kw)
    return step_fn, sspecs


def make_flush_fn(plan: PicassoPlan, mesh, axes: Tuple[str, ...],
                  cache_update: str = "psum", strategy: Any = None,
                  use_cache: bool = True, use_l2: bool = True):
    """Host-scheduled HybridHash flush: jitted state -> state (called every
    ``plan.flush_iters`` steps by the trainer when flush_in_step=False).
    Keeps the flush collectives OUT of the hot train step.

    ``strategy=None`` follows the plan: a recorded per-group assignment
    (``plan.strategy``) gates the flush exactly like the train engine —
    groups with a budgeted-but-unused cache (e.g. PS-assigned) are skipped,
    not clobbered with stale hot rows — and unassigned plans keep the
    original broadcast-'picasso' gating. Pass the training spec explicitly
    only when it was never recorded on the plan.

    ``use_cache``/``use_l2`` MUST mirror the TrainConfig flags the train
    engine ran with: a flush engine gating a tier ON that training gated OFF
    would write a never-updated (stale) tier snapshot back over master rows
    the training path has been updating directly."""
    world = _mesh_world(mesh, axes)
    if strategy is None:
        strategy = "mixed" if plan.strategy else "picasso"
    engine = EmbeddingEngine(plan, axes, world, cache_update=cache_update,
                             strategy=strategy, use_cache=use_cache,
                             use_l2=use_l2)
    especs = emb_specs(plan, axes)

    def wrapped(state):
        f = shard_map(engine.flush, mesh=mesh, in_specs=(especs,),
                      out_specs=especs, check_vma=False)
        return {**state, "emb": f(state["emb"])}

    return jax.jit(wrapped, donate_argnums=(0,))


def init_state(model: WDLModel, plan: PicassoPlan, key, mesh=None, axes=None):
    """Initialize a TrainState; with mesh given, tables come out pre-sharded."""
    from repro.embedding.state import init_embedding_state

    def build(k):
        k1, k2 = jax.random.split(k)
        emb = init_embedding_state(k1, plan)
        dense = model.init_dense(k2)
        return {"emb": {str(g): s for g, s in emb.items()},
                "dense": dense, "opt": adam_init(dense),
                "step": jnp.zeros((), jnp.int32)}

    if mesh is None:
        return build(key)
    dense0 = jax.eval_shape(lambda k: model.init_dense(k), key)
    sspecs = state_specs(plan, axes, dense0, jax.eval_shape(adam_init, dense0))
    shardings = to_named(mesh, sspecs)
    return jax.jit(build, out_shardings=shardings)(key)
