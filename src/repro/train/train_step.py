"""Hybrid MP/DP train step for WDL models (paper §III-A + Fig. 6).

One SPMD program under ``shard_map`` over the full mesh:

  pack (D-Packing) -> wave lookups (K-Packing + K-Interleaving)
  -> micro-batch pipeline (D-Interleaving): dense fwd/bwd of chunk i overlaps
     the Shuffle of chunk i+1
  -> dense grads psum (DP) + Adam ; sparse grads routed back (MP) + row-wise
     Adagrad ; HybridHash hit grads psum'd into the replicated hot tier
  -> FCounter update ; periodic HybridHash flush.

Strategies (paper §II-C / §IV baselines):
  'picasso' — the full system;
  'hybrid'  — MP all_to_all per group but plan built without packing/cache;
  'ps'      — PS-style all_gather+psum lookups (the fragmentary baseline).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import packed_embedding as pe
from repro.core.features import PackedBatch, pack_group
from repro.core.interleaving import wave_barrier
from repro.core.packing import PicassoPlan
from repro.dist.sharding import batch_specs, state_specs, to_named
from repro.embedding.state import EmbeddingState
from repro.models.wdl import WDLModel
from repro.optim.optimizers import adam_init, adam_update, lamb_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr_emb: float = 0.05
    lr_dense: float = 1e-3
    optimizer: str = "adam"        # 'adam' | 'lamb'
    strategy: str = "picasso"      # 'picasso' | 'ps'
    pipeline_micro: bool = True    # D-Interleaving pipeline order
    use_cache: bool = True
    use_interleave: bool = True    # K-Interleaving waves (False: one wave)
    cache_update: str = "psum"     # 'psum' (exact) | 'stale' (Algorithm 1)
    flush_in_step: bool = True     # False: host calls make_flush_fn() instead
    grad_compression: str = "none"  # 'none' | 'bf16' | 'f8' (dense DP psum)
    eps: float = 1e-8


def _mesh_world(mesh, axes) -> int:
    return int(np.prod([mesh.shape[a] for a in axes]))


def _slice_micro(x, i, micro):
    return lax.dynamic_slice_in_dim(x, i * micro, micro, axis=0)


def make_train_step(model: WDLModel, plan: PicassoPlan, mesh, axes: Tuple[str, ...],
                    global_batch: int, tcfg: TrainConfig = TrainConfig()):
    """Returns (jitted_step, state_specs_pytree). step(state, batch) -> (state, metrics)."""
    world = _mesh_world(mesh, axes)
    assert global_batch % world == 0, (global_batch, world)
    b_local = global_batch // world
    micro = plan.microbatch if plan.microbatch <= b_local else b_local
    n_micro = max(1, b_local // micro)
    waves = plan.interleave if tcfg.use_interleave else [[g.gid for g in plan.groups]]
    cache_on = tcfg.use_cache and any(plan.cache_rows.get(g.gid, 0) > 0 for g in plan.groups)

    # ------------------------------------------------------------- lookups
    def lookups(emb: Dict[str, EmbeddingState], packed: Dict[int, PackedBatch]):
        rows, ctxs = {}, {}
        ids_in = {g.gid: packed[g.gid].ids for g in plan.groups}
        for wi, wave in enumerate(waves):
            if wi > 0:
                # K-Interleaving (Fig. 8c): wave wi's inputs pass through one
                # barrier with wave wi-1's outputs -> a real control boundary.
                prev = waves[wi - 1]
                flat = wave_barrier([rows[g] for g in prev] + [ids_in[g] for g in wave])
                for g, v in zip(prev, flat[: len(prev)]):
                    rows[g] = v
                for j, g in enumerate(wave):
                    ids_in[g] = flat[len(prev) + j]
            for gid in wave:
                st = emb[str(gid)]
                hk = st.cache.keys if cache_on else None
                hr = st.cache.rows if cache_on else None
                if tcfg.strategy == "ps":
                    per_id = pe.ps_lookup(st.w, ids_in[gid], axes=axes, world=world)
                    rows[gid], ctxs[gid] = per_id, None
                else:
                    rows[gid], ctxs[gid] = pe.mp_lookup(
                        st.w, ids_in[gid], axes=axes, world=world,
                        capacity=plan.capacity[gid], hot_keys=hk, hot_rows=hr)
        return rows, ctxs

    # -------------------------------------------------------- loss closure
    def micro_loss(dense, rows, ctxs, packed, mb):
        pooled = {}
        for gid, pb in packed.items():
            g = plan.group(gid)
            if tcfg.strategy == "ps":
                per_id = rows[gid] * pb.weights[:, None]
                p = jax.ops.segment_sum(per_id, pb.seg, num_segments=micro * g.n_bags)
            else:
                p = pe.pool(rows[gid], ctxs[gid].inv, pb.weights, pb.seg, micro * g.n_bags)
            pooled[gid] = p.reshape(micro, g.n_bags, g.dim)
        loss_sum, logits = model.loss(dense, pooled, mb)
        return loss_sum / global_batch, logits

    # ------------------------------------------------------------ updates
    def apply_updates(emb, rows_g, ctxs, pm):
        ovf = jnp.zeros((), jnp.int32)
        hits = jnp.zeros((), jnp.int32)
        for gid, g_u in rows_g.items():
            st = emb[str(gid)]
            if tcfg.strategy == "ps":
                # PS baseline: dense-ish scatter via all_gather of per-id grads
                w2, acc2 = _ps_apply(st.w, st.acc, g_u, pm[gid].ids)
                emb[str(gid)] = st._replace(w=w2, acc=acc2)
                continue
            ctx = ctxs[gid]
            cache = st.cache if cache_on else None
            w2, acc2, cache2 = pe.apply_sparse_grads(
                st.w, st.acc, cache, ctx, g_u, axes=axes, world=world,
                lr=tcfg.lr_emb, eps=tcfg.eps, cache_update=tcfg.cache_update)
            counts2 = pe.count_frequencies(st.counts, ctx)
            emb[str(gid)] = EmbeddingState(w=w2, acc=acc2, counts=counts2,
                                           cache=cache2 if cache2 is not None else st.cache)
            ovf = ovf + ctx.routing.overflow.astype(jnp.int32)
            hits = hits + pe.cache_hit_count(ctx).astype(jnp.int32)
        return emb, ovf, hits

    def _ps_apply(w_shard, acc_shard, g_per_id, ids):
        rps = w_shard.shape[0]
        my = lax.axis_index(axes).astype(jnp.int32)
        base = my * rps
        all_ids = lax.all_gather(ids, axes, tiled=True)
        all_g = lax.all_gather(g_per_id, axes, tiled=True)
        local = all_ids - base
        ok = (local >= 0) & (local < rps)
        return pe._dedup_apply(w_shard, acc_shard, jnp.clip(local, 0, rps - 1),
                               all_g, ok, tcfg.lr_emb, tcfg.eps)

    # --------------------------------------------------------------- step
    def local_step(state, batch):
        emb: Dict[str, EmbeddingState] = dict(state["emb"])
        dense, opt, step = state["dense"], state["opt"], state["step"]

        packed_full = {g.gid: pack_group(g, batch["fields"]) for g in plan.groups}

        def packed_micro(i):
            out = {}
            for gid, pb in packed_full.items():
                g = plan.group(gid)
                ips = g.ids_per_sample
                ids = _slice_micro(pb.ids.reshape(b_local, ips), i, micro).reshape(-1)
                wts = _slice_micro(pb.weights.reshape(b_local, ips), i, micro).reshape(-1)
                seg = pb.seg[: micro * ips]  # per-sample pattern repeats
                out[gid] = PackedBatch(ids=ids, weights=wts, seg=seg, n_bags=g.n_bags)
            return out

        def batch_micro(i):
            mb = {"fields": {n: {k: _slice_micro(v, i, micro) for k, v in f.items()}
                             for n, f in batch["fields"].items()},
                  "labels": _slice_micro(batch["labels"], i, micro)}
            if "dense" in batch:
                mb["dense"] = _slice_micro(batch["dense"], i, micro)
            return mb

        grad_fn = jax.value_and_grad(micro_loss, argnums=(0, 1), has_aux=True)

        loss_acc = jnp.zeros(())
        g_dense_acc = jax.tree.map(jnp.zeros_like, dense)
        ovf_acc = jnp.zeros((), jnp.int32)
        hit_acc = jnp.zeros((), jnp.int32)

        pm0 = packed_micro(0)
        pending = (lookups(emb, pm0), pm0, batch_micro(0))
        for i in range(n_micro):
            (rows, ctxs), pm, mb = pending
            if tcfg.pipeline_micro and i + 1 < n_micro:
                # D-Interleaving: issue Shuffle of chunk i+1 before dense of i
                pm_next = packed_micro(i + 1)
                pending = (lookups(emb, pm_next), pm_next, batch_micro(i + 1))
            (loss, _logits), (g_dense, g_rows) = grad_fn(dense, rows, ctxs, pm, mb)
            loss_acc = loss_acc + loss
            g_dense_acc = jax.tree.map(jnp.add, g_dense_acc, g_dense)
            emb, ovf, hits = apply_updates(emb, g_rows, ctxs, pm)
            ovf_acc, hit_acc = ovf_acc + ovf, hit_acc + hits
            if not (tcfg.pipeline_micro) and i + 1 < n_micro:
                pm_next = packed_micro(i + 1)
                pending = (lookups(emb, pm_next), pm_next, batch_micro(i + 1))

        # ---- dense DP: psum grads over the whole mesh ----------------------
        if tcfg.grad_compression != "none":
            from repro.optim.grad_compression import compressed_psum
            g_dense_acc, _ = compressed_psum(g_dense_acc, axes,
                                             mode=tcfg.grad_compression)
        else:
            g_dense_acc = lax.psum(g_dense_acc, axes)
        loss_glob = lax.psum(loss_acc, axes)
        upd = adam_update if tcfg.optimizer == "adam" else lamb_update
        dense2, opt2 = upd(dense, g_dense_acc, opt, tcfg.lr_dense)

        # ---- HybridHash flush (Algorithm 1 L23-26) -------------------------
        step2 = step + 1
        if cache_on and tcfg.strategy != "ps" and tcfg.flush_in_step:
            do_flush = (step2 >= plan.warmup_iters) & (step2 % plan.flush_iters == 0)

            def flush_all(emb_in):
                out = dict(emb_in)
                for g in plan.groups:
                    st = out[str(g.gid)]
                    if plan.cache_rows.get(g.gid, 0) == 0:
                        continue
                    w2, acc2, counts2, cache2 = pe.flush_cache(
                        st.w, st.acc, st.counts, st.cache, axes=axes, world=world,
                        write_back=tcfg.cache_update == "psum")
                    out[str(g.gid)] = EmbeddingState(w2, acc2, counts2, cache2)
                return out

            emb = lax.cond(do_flush, flush_all, lambda e: e, emb)

        new_state = {"emb": emb, "dense": dense2, "opt": opt2, "step": step2}
        metrics = {"loss": loss_glob,
                   "overflow": lax.psum(ovf_acc, axes),
                   "cache_hits": lax.psum(hit_acc, axes),
                   "step": step2}
        return new_state, metrics

    # ---------------------------------------------------------------- wrap
    dense0 = jax.eval_shape(lambda k: model.init_dense(k), jax.random.PRNGKey(0))
    opt0 = jax.eval_shape(adam_init, dense0)
    sspecs = state_specs(plan, axes, dense0, opt0)

    def wrapped(state, batch):
        bspecs = batch_specs(batch, axes)
        f = jax.shard_map(local_step, mesh=mesh,
                          in_specs=(sspecs, bspecs),
                          out_specs=(sspecs, {"loss": P(), "overflow": P(),
                                              "cache_hits": P(), "step": P()}),
                          check_vma=False)
        return f(state, batch)

    step_fn = jax.jit(wrapped, donate_argnums=(0,))
    return step_fn, sspecs


def make_flush_fn(plan: PicassoPlan, mesh, axes: Tuple[str, ...],
                  cache_update: str = "psum"):
    """Host-scheduled HybridHash flush: jitted state -> state (called every
    ``plan.flush_iters`` steps by the trainer when flush_in_step=False).
    Keeps the flush collectives OUT of the hot train step."""
    world = _mesh_world(mesh, axes)

    def local_flush(emb):
        out = dict(emb)
        for g in plan.groups:
            st = out[str(g.gid)]
            if plan.cache_rows.get(g.gid, 0) == 0:
                continue
            w2, acc2, counts2, cache2 = pe.flush_cache(
                st.w, st.acc, st.counts, st.cache, axes=axes, world=world,
                write_back=cache_update == "psum")
            out[str(g.gid)] = EmbeddingState(w2, acc2, counts2, cache2)
        return out

    especs = {str(g.gid): __import__("repro.dist.sharding", fromlist=["emb_state_specs"]
                                     ).emb_state_specs(axes) for g in plan.groups}

    def wrapped(state):
        f = jax.shard_map(local_flush, mesh=mesh, in_specs=(especs,),
                          out_specs=especs, check_vma=False)
        return {**state, "emb": f(state["emb"])}

    return jax.jit(wrapped, donate_argnums=(0,))


def init_state(model: WDLModel, plan: PicassoPlan, key, mesh=None, axes=None):
    """Initialize a TrainState; with mesh given, tables come out pre-sharded."""
    from repro.embedding.state import init_embedding_state

    def build(k):
        k1, k2 = jax.random.split(k)
        emb = init_embedding_state(k1, plan)
        dense = model.init_dense(k2)
        return {"emb": {str(g): s for g, s in emb.items()},
                "dense": dense, "opt": adam_init(dense),
                "step": jnp.zeros((), jnp.int32)}

    if mesh is None:
        return build(key)
    dense0 = jax.eval_shape(lambda k: model.init_dense(k), key)
    sspecs = state_specs(plan, axes, dense0, jax.eval_shape(adam_init, dense0))
    shardings = to_named(mesh, sspecs)
    return jax.jit(build, out_shardings=shardings)(key)
