"""Fault tolerance for the training loop (designed for 1000+ nodes).

Mechanisms (paper §V notes Alibaba runs separate in-house failover [44,45];
here we build the framework-level pieces a deployment needs):

1. *Checkpoint/restart*: AsyncCheckpointer snapshots every N steps; on any
   step failure the supervisor restores the last durable checkpoint and
   replays the data stream from the recorded offset (the synthetic stream is
   seeded+counted, so replay is exact).
2. *Elastic re-mesh*: checkpoints are world-size independent (see
   checkpoint.py); ``Supervisor.remesh`` rebuilds plan/step for a new device
   count and reloads — scale-down on failure, scale-up on recovery.
3. *Straggler mitigation*: SPMD sync training has no PS-side stragglers; the
   residual risk is the input pipeline, handled by Prefetcher backup batches
   (data/pipeline.py). Cross-pod collectives use the hierarchical schedule
   planned by the mesh (pod axis outermost) so one slow DCI link bounds only
   the pod-level phase.
"""
from __future__ import annotations

import logging
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax

from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint

log = logging.getLogger("repro.ft")


class StepFailure(RuntimeError):
    pass


class Supervisor:
    """Wraps a train loop with checkpoint/restart + bounded retries."""

    def __init__(self, ckpt_dir: str, ckpt_every: int = 100, max_retries: int = 3,
                 keep: int = 3):
        self.ckpt = AsyncCheckpointer(ckpt_dir, keep=keep)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.failures = 0
        # JSON sidecar written with every checkpoint (the trainer keeps this
        # pointing at the live plan revision — repro.runtime.plan_meta — and
        # refreshes it after each replan/migration)
        self.meta: Optional[Dict[str, Any]] = None

    def maybe_restore(self, template: Any, shardings: Any = None
                      ) -> Tuple[Any, int]:
        step = latest_step(self.ckpt_dir)
        if step is None:
            return template, 0
        state, step = restore_checkpoint(self.ckpt_dir, template, shardings=shardings)
        log.info("restored checkpoint at step %d", step)
        return state, step

    def run(self, state: Any, step_fn: Callable, batches: Iterator,
            n_steps: int, start_step: int = 0,
            on_metrics: Optional[Callable[[int, Dict], None]] = None,
            fail_injector: Optional[Callable[[int], None]] = None) -> Any:
        """Run ``n_steps``; on failure restore + replay. ``fail_injector`` is
        the test hook that raises inside the loop to simulate node loss."""
        template = jax.tree.map(lambda x: x, state)
        step = start_step
        stream = enumerate(batches)
        pending = []
        while step < n_steps:
            try:
                if fail_injector is not None:
                    fail_injector(step)
                _, batch = next(stream)
                state, metrics = step_fn(state, batch)
                step += 1
                if on_metrics is not None:
                    on_metrics(step, metrics)
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state, meta=self.meta)
            except StopIteration:
                break
            except Exception as e:  # noqa: BLE001 — anything = node failure
                self.failures += 1
                if self.failures > self.max_retries:
                    raise
                log.warning("step %d failed (%s); restoring", step, e)
                self.ckpt.wait()
                if latest_step(self.ckpt_dir) is not None:
                    state, step = restore_checkpoint(self.ckpt_dir, template)
                # else: restart from the in-memory state (no ckpt yet)
        self.ckpt.wait()
        return state
