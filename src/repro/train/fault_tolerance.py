"""Fault tolerance for the training loop (designed for 1000+ nodes).

Mechanisms (paper §V notes Alibaba runs separate in-house failover [44,45];
here we build the framework-level pieces a deployment needs):

1. *Checkpoint/restart*: AsyncCheckpointer snapshots every N steps; on a
   transient step failure the supervisor restores the last *verified*
   checkpoint (per-leaf checksums; corrupt snapshots are quarantined and the
   chain falls back — see checkpoint.restore_verified) and rewinds the data
   stream to the restored step (ReplayableStream + per-index batch seeding),
   so replay is exact.
2. *Failure classification*: not every exception deserves a retry. Transient
   faults (node loss, I/O, numeric rollback requests) restore + replay under
   capped exponential backoff; fatal faults (shape/type/tracing errors,
   OOM of the host process, import breakage) re-raise immediately — retrying
   a deterministic bug burns the retry budget and hides the stack trace.
3. *Elastic re-mesh*: checkpoints are world-size independent (see
   checkpoint.py); ``Supervisor.remesh`` rebuilds plan/step for a new device
   count and reloads — scale-down on failure, scale-up on recovery.
4. *Straggler mitigation*: SPMD sync training has no PS-side stragglers; the
   residual risk is the input pipeline, handled by Prefetcher backup batches
   (data/pipeline.py). Cross-pod collectives use the hierarchical schedule
   planned by the mesh (pod axis outermost) so one slow DCI link bounds only
   the pod-level phase.
"""
from __future__ import annotations

import logging
import time
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax

from repro.train.checkpoint import AsyncCheckpointer, latest_step, restore_verified

log = logging.getLogger("repro.ft")


class StepFailure(RuntimeError):
    pass


#: exception types where a restore-and-replay retry cannot help: the same
#: code will deterministically fail again (tracing/shape/type bugs, broken
#: imports) or the process itself is compromised (host OOM).
FATAL_TYPES = (TypeError, AttributeError, ImportError, NameError, MemoryError)


def classify_failure(e: BaseException) -> str:
    """'transient' (restore + replay may succeed) or 'fatal' (re-raise).

    Transient is the default: node loss, filesystem hiccups, injected chaos,
    and guard rollback requests all surface as RuntimeError/OSError
    subclasses. ``AnomalyRollback`` is transient by construction — the whole
    point of raising it is to trigger the restore path.
    """
    return "fatal" if isinstance(e, FATAL_TYPES) else "transient"


class Supervisor:
    """Wraps a train loop with checkpoint/restart + classified, bounded
    retries.

    ``shardings`` (settable at construction, via ``maybe_restore``/``run``,
    or directly after a reshard) are used for every restore so recovered
    state lands on the correct devices — the old retry path restored onto
    host-default placement and then trained cross-device.

    ``reset_after`` successful consecutive steps clear the failure counter:
    the retry budget bounds *failure density*, not total failures over an
    arbitrarily long run (three transient faults a day apart should never
    exhaust ``max_retries=3``). Default: two checkpoint intervals.
    """

    def __init__(self, ckpt_dir: str, ckpt_every: int = 100, max_retries: int = 3,
                 keep: int = 3, backoff_s: float = 0.5, backoff_cap_s: float = 30.0,
                 reset_after: Optional[int] = None, shardings: Any = None):
        self.ckpt = AsyncCheckpointer(ckpt_dir, keep=keep)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_cap_s = backoff_cap_s
        self.reset_after = reset_after if reset_after is not None else 2 * ckpt_every
        self.failures = 0        # current failure density (resets on progress)
        self.total_failures = 0  # monotonic, for observability
        self.shardings = shardings
        # JSON sidecar written with every checkpoint (the trainer keeps this
        # pointing at the live plan revision — repro.runtime.plan_meta — and
        # refreshes it after each replan/migration)
        self.meta: Optional[Dict[str, Any]] = None

    def maybe_restore(self, template: Any, shardings: Any = None
                      ) -> Tuple[Any, int]:
        if shardings is not None:
            self.shardings = shardings
        try:
            state, step = restore_verified(self.ckpt_dir, template,
                                           shardings=self.shardings,
                                           log=log.warning)
        except FileNotFoundError:
            return template, 0
        log.info("restored checkpoint at step %d", step)
        return state, step

    def run(self, state: Any, step_fn: Callable, batches: Iterator,
            n_steps: int, start_step: int = 0,
            on_metrics: Optional[Callable[[int, Dict], None]] = None,
            fail_injector: Optional[Callable[[int], None]] = None,
            shardings: Any = None) -> Any:
        """Run ``n_steps``; on transient failure restore + replay, on fatal
        failure re-raise. ``fail_injector`` is the test hook that raises
        inside the loop to simulate node loss. If ``batches`` has a
        ``seek(step)`` method (ReplayableStream) the stream is rewound to
        the restored step so replay is exact; otherwise a warning notes the
        skipped batches."""
        if shardings is not None:
            self.shardings = shardings
        template = jax.tree.map(lambda x: x, state)
        step = start_step
        stream = iter(batches)
        seekable = hasattr(batches, "seek")
        warned_no_seek = False
        clean = 0  # consecutive successful steps since the last failure
        while step < n_steps:
            try:
                if fail_injector is not None:
                    fail_injector(step)
                batch = next(stream)
                state, metrics = step_fn(state, batch)
                step += 1
                clean += 1
                if self.failures and clean >= self.reset_after:
                    log.info("%d clean steps; resetting failure counter "
                             "(was %d)", clean, self.failures)
                    self.failures = 0
                if on_metrics is not None:
                    on_metrics(step, metrics)
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state, meta=self.meta)
            except StopIteration:
                break
            except Exception as e:  # noqa: BLE001 — classified below
                if classify_failure(e) == "fatal":
                    log.error("step %d failed with fatal %s: %s — not "
                              "retrying", step, type(e).__name__, e)
                    raise
                self.failures += 1
                self.total_failures += 1
                clean = 0
                if self.failures > self.max_retries:
                    raise
                delay = min(self.backoff_s * (2 ** (self.failures - 1)),
                            self.backoff_cap_s)
                log.warning("step %d failed (%s: %s); restoring after %.2fs "
                            "backoff (failure %d/%d)", step,
                            type(e).__name__, e, delay, self.failures,
                            self.max_retries)
                if delay > 0:
                    time.sleep(delay)
                self.ckpt.wait()
                try:
                    state, step = restore_verified(self.ckpt_dir, template,
                                                   shardings=self.shardings,
                                                   log=log.warning)
                    log.info("rolled back to step %d", step)
                except FileNotFoundError:
                    # no verifiable checkpoint yet: restart from in-memory
                    # state. An AnomalyRollback carries the surviving
                    # (rejection-preserved) state — the caller's copy was
                    # donated to the guarded step.
                    recovered = getattr(e, "state", None)
                    if recovered is not None:
                        state = recovered
                    log.warning("no verifiable checkpoint; continuing from "
                                "in-memory state at step %d", step)
                if seekable:
                    batches.seek(step)
                    stream = iter(batches)
                elif not warned_no_seek:
                    warned_no_seek = True
                    log.warning("batch stream is not seekable; batches "
                                "between checkpoint and failure steps will "
                                "be skipped, replay is NOT exact")
        self.ckpt.wait()
        return state
