"""Decoder-only LM stack (covers all five assigned LM-family archs).

Layer params are stacked [L, ...] and the stack runs under ``lax.scan`` so the
HLO holds one layer body (essential: the 512-device dry-run compiles in
minutes, not hours). Sharding is declared per-leaf in ``lm_param_specs``:
Megatron-style TP over the 'model' axis, DP over ('pod','data'); MoE experts
go EP over 'model' when E % tp == 0, else TP inside the expert FFN.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import LMConfig
from repro.layers.attention import apply_rope, chunked_causal_attention, decode_attention
from repro.layers.moe import moe_ffn


def _dt(cfg: LMConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init_lm_params(cfg: LMConfig, key: jax.Array) -> Dict:
    dt = _dt(cfg)
    L, D, H, G = cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.head_dim
    ks = jax.random.split(key, 12)

    def nrm(k, shape, fan_in):
        return (jax.random.normal(k, shape, jnp.float32) / np.sqrt(fan_in)).astype(dt)

    p: Dict[str, Any] = {
        "emb": nrm(ks[0], (cfg.vocab, D), 1.0) * 0.02 * np.sqrt(1.0),
        "ln_f": jnp.ones((D,), dt),
        "layers": {
            "ln1": jnp.ones((L, D), dt),
            "ln2": jnp.ones((L, D), dt),
            "wq": nrm(ks[1], (L, D, H * hd), D),
            "wk": nrm(ks[2], (L, D, G * hd), D),
            "wv": nrm(ks[3], (L, D, G * hd), D),
            "wo": nrm(ks[4], (L, H * hd, D), H * hd),
        },
    }
    if not cfg.tie_embeddings:
        p["head"] = nrm(ks[5], (D, cfg.vocab), D)
    if cfg.moe is not None:
        E, F = cfg.moe.n_experts, cfg.moe.d_ff
        p["layers"].update({
            "router": nrm(ks[6], (L, D, E), D),
            "w1": nrm(ks[7], (L, E, D, F), D),
            "w3": nrm(ks[8], (L, E, D, F), D),
            "w2": nrm(ks[9], (L, E, F, D), F),
        })
    else:
        F = cfg.d_ff
        p["layers"].update({
            "w1": nrm(ks[7], (L, D, F), D),
            "w3": nrm(ks[8], (L, D, F), D),
            "w2": nrm(ks[9], (L, F, D), F),
        })
    return p


def abstract_lm_params(cfg: LMConfig) -> Dict:
    return jax.eval_shape(functools.partial(init_lm_params, cfg), jax.random.PRNGKey(0))


def lm_param_specs(cfg: LMConfig, mesh_shape: Dict[str, int],
                   dp_axes: Tuple[str, ...] = ("data",), tp_axis: str = "model",
                   fsdp: bool = True) -> Dict:
    """PartitionSpecs per leaf: Megatron TP over ``tp_axis`` on the natural
    contraction-free dim + FSDP over ``dp_axes`` on a second dim (gathered
    per-layer inside the scan). Divisibility decides shard-vs-replicate."""
    t = tp_axis
    tp = mesh_shape[t]
    dpn = int(np.prod([mesh_shape[a] for a in dp_axes])) if fsdp else 0
    dp = dp_axes if fsdp else None

    def ok(sz, ways):
        return ways and sz % ways == 0

    def p_tp(sz):  # shard over tp if divisible
        return t if ok(sz, tp) else None

    def p_dp(sz):
        return dp if fsdp and ok(sz, dpn) else None

    D, hd = cfg.d_model, cfg.head_dim
    H, G, V = cfg.n_heads, cfg.n_kv_heads, cfg.vocab
    specs = {
        "emb": P(p_tp(V), p_dp(D)),   # vocab-sharded MP embedding (as in recsys)
        "ln_f": P(None),
        "layers": {
            "ln1": P(None, None), "ln2": P(None, None),
            "wq": P(None, p_dp(D), p_tp(H * hd)),
            "wk": P(None, p_dp(D), p_tp(G * hd)),
            "wv": P(None, p_dp(D), p_tp(G * hd)),
            "wo": P(None, p_tp(H * hd), p_dp(D)),
        },
    }
    if cfg.moe is None:
        F = cfg.d_ff
        specs["layers"].update({"w1": P(None, p_dp(D), p_tp(F)),
                                "w3": P(None, p_dp(D), p_tp(F)),
                                "w2": P(None, p_tp(F), p_dp(D))})
    else:
        E, F = cfg.moe.n_experts, cfg.moe.d_ff
        specs["layers"].update({
            "router": P(None, p_dp(D), None),
            "w1": P(None, None, p_dp(D), p_tp(F)),
            "w3": P(None, None, p_dp(D), p_tp(F)),
            "w2": P(None, None, p_tp(F), p_dp(D)),
        })
    if not cfg.tie_embeddings:
        specs["head"] = P(p_dp(D), p_tp(V))
    return specs


def _rmsnorm(g: jnp.ndarray, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps).astype(x.dtype)) * g


class LayerIO(NamedTuple):
    x: jnp.ndarray
    pos: jnp.ndarray


def _layer(cfg: LMConfig, lp: Dict, x: jnp.ndarray, pos: jnp.ndarray,
           attn_chunk: int, moe_cap: float, moe_exec=None) -> jnp.ndarray:
    b, s, d = x.shape
    hd, h, g = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    hx = _rmsnorm(lp["ln1"], x, cfg.norm_eps)
    q = (hx @ lp["wq"]).reshape(b, s, h, hd)
    k = (hx @ lp["wk"]).reshape(b, s, g, hd)
    v = (hx @ lp["wv"]).reshape(b, s, g, hd)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    o = chunked_causal_attention(q, k, v, chunk=attn_chunk, window=cfg.swa_window)
    x = x + (o.reshape(b, s, h * hd) @ lp["wo"])

    hx = _rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if cfg.moe is not None:
        flat = hx.reshape(b * s, d)
        groups, xe_sh = moe_exec if moe_exec else (1, None)
        y = moe_ffn(flat, lp["router"], lp["w1"], lp["w2"], lp["w3"], cfg.moe.top_k,
                    capacity_factor=moe_cap, groups=groups, xe_sharding=xe_sh)
        x = x + y.reshape(b, s, d)
    else:
        y = (jax.nn.silu(hx @ lp["w3"]) * (hx @ lp["w1"])) @ lp["w2"]
        x = x + y
    return x


def lm_forward(cfg: LMConfig, params: Dict, tokens: jnp.ndarray,
               attn_chunk: int = 512, remat: bool = True,
               moe_cap: float = 1.25, unroll: bool = False) -> jnp.ndarray:
    """tokens [B, S] -> logits [B, S, V]."""
    x = _backbone(cfg, params, tokens, attn_chunk, remat, moe_cap, unroll)
    head = params["emb"].T if cfg.tie_embeddings else params["head"]
    return x @ head


def lm_loss(cfg: LMConfig, params: Dict, tokens: jnp.ndarray,
            attn_chunk: int = 512, remat: bool = True,
            moe_cap: float = 1.25, loss_chunk: int = 0,
            unroll: bool = False, moe_exec=None) -> jnp.ndarray:
    """Next-token CE, mean over tokens.

    ``loss_chunk`` > 0 computes the [B, S, V] logits in sequence chunks under
    a scan so the full-vocab logits tensor never materializes (vital for
    V=131072 at seq 4096).
    """
    b, s = tokens.shape
    x = _backbone(cfg, params, tokens, attn_chunk, remat, moe_cap, unroll, moe_exec)
    head = params["emb"].T if cfg.tie_embeddings else params["head"]

    def ce(xc, tgt, wc):
        lg = (xc @ head).astype(jnp.float32)
        lse = jax.nn.logsumexp(lg, axis=-1)
        true = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
        return ((lse - true) * wc).sum()

    # predict token t+1 from position t; last position has weight 0
    tgt = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    w = jnp.ones((b, s), jnp.float32).at[:, -1].set(0.0)
    if loss_chunk and s % loss_chunk == 0 and s > loss_chunk:
        # unrolled (NOT lax.scan): XLA cost_analysis counts a while body once,
        # which would hide (nc-1)/nc of the CE cost from the roofline terms.
        nc = s // loss_chunk
        ck = jax.checkpoint(ce)
        total = jnp.zeros(())
        for i in range(nc):
            sl = slice(i * loss_chunk, (i + 1) * loss_chunk)
            total = total + ck(x[:, sl], tgt[:, sl], w[:, sl])
    else:
        total = ce(x, tgt, w)
    return total / (b * (s - 1))


def _backbone(cfg: LMConfig, params: Dict, tokens: jnp.ndarray,
              attn_chunk: int, remat: bool, moe_cap: float,
              unroll: bool = False, moe_exec=None) -> jnp.ndarray:
    b, s = tokens.shape
    x = jnp.take(params["emb"], tokens, axis=0)
    pos = jnp.arange(s)

    def body(x, lp):
        return _layer(cfg, lp, x, pos, attn_chunk, moe_cap, moe_exec), None

    if remat:
        body = jax.checkpoint(body)
    # unroll=True is used by the dry-run cost-correction compiles: XLA's
    # cost_analysis counts a while body once, an unrolled stack exactly.
    x, _ = lax.scan(body, x, params["layers"],
                    unroll=cfg.n_layers if unroll else 1)
    return _rmsnorm(params["ln_f"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jnp.ndarray  # [L, B, S, G, hd]
    v: jnp.ndarray


def abstract_kv_cache(cfg: LMConfig, batch: int, seq: int) -> KVCache:
    dt = _dt(cfg)
    sh = (cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jax.ShapeDtypeStruct(sh, dt), jax.ShapeDtypeStruct(sh, dt))


def init_kv_cache(cfg: LMConfig, batch: int, seq: int) -> KVCache:
    dt = _dt(cfg)
    sh = (cfg.n_layers, batch, seq, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(sh, dt), jnp.zeros(sh, dt))


def lm_decode_step(cfg: LMConfig, params: Dict, cache: KVCache,
                   tokens: jnp.ndarray, length: jnp.ndarray,
                   moe_cap: float = 1.25, unroll: bool = False
                   ) -> Tuple[jnp.ndarray, KVCache]:
    """One decode step. tokens [B, 1]; length: current cache fill (scalar).

    The KV cache stays sharded along S over the 'model' axis; the attention
    softmax over the sharded S dim becomes a flash-decoding style split-K
    combine under GSPMD.
    """
    b = tokens.shape[0]
    hd, h, g = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    x = jnp.take(params["emb"], tokens, axis=0)           # [B, 1, D]
    pos = jnp.reshape(length, (1,))                       # position of the new token

    def body(x, lp_cache):
        lp, kc, vc = lp_cache
        hx = _rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q = (hx @ lp["wq"]).reshape(b, 1, h, hd)
        k = (hx @ lp["wk"]).reshape(b, 1, g, hd)
        v = (hx @ lp["wv"]).reshape(b, 1, g, hd)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), length, axis=1)
        vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), length, axis=1)
        o = decode_attention(q, kc, vc, length + 1, window=cfg.swa_window)
        x = x + (o.reshape(b, 1, h * hd) @ lp["wo"])
        hx = _rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            y = moe_ffn(hx.reshape(b, -1), lp["router"], lp["w1"], lp["w2"], lp["w3"],
                        cfg.moe.top_k, capacity_factor=moe_cap).reshape(b, 1, -1)
        else:
            y = (jax.nn.silu(hx @ lp["w3"]) * (hx @ lp["w1"])) @ lp["w2"]
        return x + y, (kc, vc)

    x, (k2, v2) = lax.scan(body, x, (params["layers"], cache.k, cache.v),
                           unroll=cfg.n_layers if unroll else 1)
    x = _rmsnorm(params["ln_f"], x, cfg.norm_eps)
    head = params["emb"].T if cfg.tie_embeddings else params["head"]
    return (x @ head)[:, 0], KVCache(k2, v2)


def lm_prefill(cfg: LMConfig, params: Dict, tokens: jnp.ndarray,
               attn_chunk: int = 512, moe_cap: float = 1.25,
               unroll: bool = False, moe_exec=None) -> Tuple[jnp.ndarray, KVCache]:
    """Prefill: tokens [B, S] -> (last-position logits, filled cache)."""
    b, s = tokens.shape
    hd, h, g = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    x = jnp.take(params["emb"], tokens, axis=0)
    pos = jnp.arange(s)

    def body(x, lp):
        hx = _rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q = (hx @ lp["wq"]).reshape(b, s, h, hd)
        k = (hx @ lp["wk"]).reshape(b, s, g, hd)
        v = (hx @ lp["wv"]).reshape(b, s, g, hd)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        o = chunked_causal_attention(q, k, v, chunk=attn_chunk, window=cfg.swa_window)
        x = x + (o.reshape(b, s, h * hd) @ lp["wo"])
        hx = _rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            groups, xe_sh = moe_exec if moe_exec else (1, None)
            y = moe_ffn(hx.reshape(b * s, -1), lp["router"], lp["w1"], lp["w2"],
                        lp["w3"], cfg.moe.top_k, capacity_factor=moe_cap,
                        groups=groups, xe_sharding=xe_sh).reshape(b, s, -1)
        else:
            y = (jax.nn.silu(hx @ lp["w3"]) * (hx @ lp["w1"])) @ lp["w2"]
        return x + y, (k.astype(x.dtype), v.astype(x.dtype))

    x, (ks, vs) = lax.scan(body, x, params["layers"],
                           unroll=cfg.n_layers if unroll else 1)
    x = _rmsnorm(params["ln_f"], x, cfg.norm_eps)
    head = params["emb"].T if cfg.tie_embeddings else params["head"]
    return (x @ head)[:, -1], KVCache(ks, vs)
