"""MLP tower with optional layer-norm + residual (paper Fig. 2 'MLP')."""
from __future__ import annotations

from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp


def init_linear(key, d_in: int, d_out: int, dtype=jnp.float32) -> Dict:
    kw, _ = jax.random.split(key)
    scale = jnp.sqrt(2.0 / d_in).astype(dtype)
    return {"w": jax.random.normal(kw, (d_in, d_out), dtype) * scale,
            "b": jnp.zeros((d_out,), dtype)}


def linear(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"] + p["b"]


def init_mlp(key, d_in: int, dims: Sequence[int], dtype=jnp.float32) -> Dict:
    params = {}
    d = d_in
    for i, h in enumerate(dims):
        key, k = jax.random.split(key)
        params[f"l{i}"] = init_linear(k, d, h, dtype)
        d = h
    return params


def n_layers(p: Dict) -> int:
    return len([k for k in p if k.startswith("l")])


def mlp(p: Dict, x: jnp.ndarray, act=jax.nn.relu, final_act: bool = True) -> jnp.ndarray:
    n = n_layers(p)
    for i in range(n):
        x = linear(p[f"l{i}"], x)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def init_layernorm(d: int, dtype=jnp.float32) -> Dict:
    return {"g": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(p: Dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]
