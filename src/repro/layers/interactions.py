"""Feature-interaction modules (paper Fig. 2 'Feature Interaction Layer').

Each module is (init_fn, apply_fn) over explicit param pytrees. Inputs are the
per-field embedding views extracted from the packed group outputs:

  pooled fields  -> [B, D]
  sequence fields-> [B, L, D]

The compute-heavy ones (cross / fm / dot) have Pallas TPU kernels in
repro/kernels; apply functions route through kernels.ops which falls back to
the pure-jnp reference on CPU.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.layers.mlp import init_layernorm, init_linear, init_mlp, layernorm, linear, mlp


# ---------------------------------------------------------------------------
# wide / FM family
# ---------------------------------------------------------------------------


def init_linear_terms(key, n_fields: int, dim: int, dtype=jnp.float32) -> Dict:
    return {"w": jax.random.normal(key, (n_fields, dim), dtype) * 0.01}


def linear_terms(p: Dict, fields: jnp.ndarray) -> jnp.ndarray:
    """FM 1st order / wide part: sum_f <w_f, e_f>.  fields: [B, F, D]."""
    return jnp.einsum("bfd,fd->b", fields, p["w"])[:, None]


def fm_interaction(fields: jnp.ndarray) -> jnp.ndarray:
    """FM 2nd order over field embeddings [B, F, D] -> [B, 1].

    0.5 * sum_d ((sum_f v)^2 - sum_f v^2).
    """
    from repro.kernels import ops
    return ops.fm_interaction(fields)


def dot_interaction(fields: jnp.ndarray) -> jnp.ndarray:
    """DLRM pairwise dots [B, F, D] -> [B, F*(F-1)/2]."""
    from repro.kernels import ops
    return ops.dot_interaction(fields)


# ---------------------------------------------------------------------------
# DCN-v2 cross network
# ---------------------------------------------------------------------------


def init_cross(key, d: int, n_layers: int, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, n_layers)
    return {f"l{i}": {"w": jax.random.normal(ks[i], (d, d), dtype) * (1.0 / np.sqrt(d)),
                      "b": jnp.zeros((d,), dtype)} for i in range(n_layers)}


def cross_net(p: Dict, x0: jnp.ndarray) -> jnp.ndarray:
    """x_{l+1} = x0 * (W x_l + b) + x_l   (DCN-v2 full-rank)."""
    from repro.kernels import ops
    x = x0
    for i in range(len(p)):
        x = ops.cross_layer(x0, x, p[f"l{i}"]["w"], p[f"l{i}"]["b"])
    return x


# ---------------------------------------------------------------------------
# sequence attention (SASRec / DIN / AutoInt)
# ---------------------------------------------------------------------------


def init_mha(key, d: int, n_heads: int, dtype=jnp.float32) -> Dict:
    k = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    return {"wq": jax.random.normal(k[0], (d, d), dtype) * s,
            "wk": jax.random.normal(k[1], (d, d), dtype) * s,
            "wv": jax.random.normal(k[2], (d, d), dtype) * s,
            "wo": jax.random.normal(k[3], (d, d), dtype) * s}


def mha(p: Dict, x: jnp.ndarray, mask: jnp.ndarray, n_heads: int, causal: bool = True) -> jnp.ndarray:
    """x: [B, L, D]; mask: [B, L] validity."""
    b, l, d = x.shape
    h = n_heads
    hd = d // h
    q = (x @ p["wq"]).reshape(b, l, h, hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(b, l, h, hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(b, l, h, hd).transpose(0, 2, 1, 3)
    logits = q @ k.transpose(0, 1, 3, 2) / np.sqrt(hd)
    neg = jnp.asarray(-1e9, logits.dtype)
    logits = jnp.where(mask[:, None, None, :], logits, neg)
    if causal:
        cm = jnp.tril(jnp.ones((l, l), bool))
        logits = jnp.where(cm[None, None], logits, neg)
    a = jax.nn.softmax(logits, axis=-1)
    o = (a @ v).transpose(0, 2, 1, 3).reshape(b, l, d)
    return o @ p["wo"]


def init_sasrec_block(key, d: int, n_heads: int, dtype=jnp.float32) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": init_layernorm(d, dtype), "attn": init_mha(k1, d, n_heads, dtype),
            "ln2": init_layernorm(d, dtype),
            "ff1": init_linear(k2, d, d, dtype), "ff2": init_linear(k3, d, d, dtype)}


def sasrec_block(p: Dict, x: jnp.ndarray, mask: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    h = mha(p["attn"], layernorm(p["ln1"], x), mask, n_heads, causal=True)
    x = x + h
    f = linear(p["ff2"], jax.nn.relu(linear(p["ff1"], layernorm(p["ln2"], x))))
    x = (x + f) * mask[..., None].astype(x.dtype)
    return x


def init_self_attn_seq(key, d: int, n_blocks: int, n_heads: int, dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, n_blocks)
    return {**{f"b{i}": init_sasrec_block(ks[i], d, n_heads, dtype) for i in range(n_blocks)},
            "ln_f": init_layernorm(d, dtype)}


def self_attn_seq(p: Dict, seq: jnp.ndarray, mask: jnp.ndarray, n_heads: int = 1) -> jnp.ndarray:
    """SASRec encoder: [B, L, D] -> [B, D] (last valid position)."""
    x = seq
    n_blocks = len([k for k in p if k.startswith("b")])
    for i in range(n_blocks):
        x = sasrec_block(p[f"b{i}"], x, mask, n_heads)
    x = layernorm(p["ln_f"], x)
    # last valid position per sample
    idx = jnp.maximum(mask.sum(axis=1).astype(jnp.int32) - 1, 0)
    return jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]


def init_target_attn(key, d: int, hidden: int = 36, dtype=jnp.float32) -> Dict:
    return {"mlp": init_mlp(key, 4 * d, (hidden, 1), dtype)}


def target_attn(p: Dict, hist: jnp.ndarray, target: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """DIN attention: weight(h) = MLP([h, t, h*t, h-t]); [B,L,D],[B,D] -> [B,D]."""
    b, l, d = hist.shape
    t = jnp.broadcast_to(target[:, None, :], (b, l, d))
    feat = jnp.concatenate([hist, t, hist * t, hist - t], axis=-1)
    w = mlp(p["mlp"], feat, final_act=False)[..., 0]          # [B, L]
    w = jnp.where(mask, w, -1e9)
    w = jax.nn.softmax(w, axis=-1) * mask.astype(w.dtype)
    return jnp.einsum("bl,bld->bd", w, hist)


# ---------------------------------------------------------------------------
# MIND capsule routing
# ---------------------------------------------------------------------------


def init_capsule(key, d: int, n_interests: int, dtype=jnp.float32) -> Dict:
    return {"s": jax.random.normal(key, (d, d), dtype) * (1.0 / np.sqrt(d))}


def _squash(v: jnp.ndarray) -> jnp.ndarray:
    n2 = jnp.sum(v * v, axis=-1, keepdims=True)
    return (n2 / (1.0 + n2)) * v * jax.lax.rsqrt(n2 + 1e-9)


def capsule_routing(p: Dict, hist: jnp.ndarray, mask: jnp.ndarray, iters: int,
                    key: jax.Array, n_interests: int = 4) -> jnp.ndarray:
    """B2I dynamic routing: [B, L, D] -> [B, K, D] interest capsules."""
    b, l, d = hist.shape
    k = n_interests
    low = hist @ p["s"]                                        # [B, L, D]
    logits0 = jax.random.normal(key, (b, k, l)) * 1.0          # fixed random init (paper)
    neg = jnp.asarray(-1e9, low.dtype)

    logits, caps = logits0, None
    for _ in range(iters):  # unrolled: keeps cost_analysis exact (no while)
        w = jax.nn.softmax(jnp.where(mask[:, None, :], logits, neg), axis=-1)
        caps = _squash(jnp.einsum("bkl,bld->bkd", w, low))
        logits = logits + jnp.einsum("bkd,bld->bkl", caps, low)
    return caps


def label_aware_attn(interests: jnp.ndarray, target: jnp.ndarray, pw: float = 2.0) -> jnp.ndarray:
    """MIND label-aware attention: [B,K,D],[B,D] -> [B,D]."""
    s = jnp.einsum("bkd,bd->bk", interests, target)
    w = jax.nn.softmax(pw * s, axis=-1)
    return jnp.einsum("bk,bkd->bd", w, interests)


# ---------------------------------------------------------------------------
# DIEN GRU / MMoE / CAN co-action
# ---------------------------------------------------------------------------


def init_gru(key, d: int, dtype=jnp.float32) -> Dict:
    k1, k2 = jax.random.split(key)
    s = 1.0 / np.sqrt(d)
    return {"wx": jax.random.normal(k1, (d, 3 * d), dtype) * s,
            "wh": jax.random.normal(k2, (d, 3 * d), dtype) * s,
            "b": jnp.zeros((3 * d,), dtype)}


def gru(p: Dict, seq: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """[B, L, D] -> [B, D] final hidden state."""
    b, l, d = seq.shape

    def step(h, xm):
        x, m = xm
        zrs = x @ p["wx"] + h @ p["wh"] + p["b"]
        z, r, s = jnp.split(zrs, 3, axis=-1)
        z, r = jax.nn.sigmoid(z), jax.nn.sigmoid(r)
        n = jnp.tanh(x @ p["wx"][:, :d] + (r * h) @ p["wh"][:, :d])
        h2 = (1 - z) * h + z * n
        h2 = jnp.where(m[:, None], h2, h)
        return h2, None

    h0 = jnp.zeros((b, d), seq.dtype)
    hT, _ = jax.lax.scan(step, h0, (seq.transpose(1, 0, 2), mask.T))
    return hT


def init_mmoe(key, d_in: int, n_experts: int, expert_dim: int, n_tasks: int,
              dtype=jnp.float32) -> Dict:
    ks = jax.random.split(key, n_experts + n_tasks)
    return {**{f"e{i}": init_mlp(ks[i], d_in, (expert_dim, expert_dim), dtype)
               for i in range(n_experts)},
            **{f"g{t}": init_linear(ks[n_experts + t], d_in, n_experts, dtype)
               for t in range(n_tasks)}}


def mmoe(p: Dict, x: jnp.ndarray) -> List[jnp.ndarray]:
    n_e = len([k for k in p if k.startswith("e")])
    n_t = len([k for k in p if k.startswith("g")])
    experts = jnp.stack([mlp(p[f"e{i}"], x) for i in range(n_e)], axis=1)  # [B,E,H]
    outs = []
    for t in range(n_t):
        g = jax.nn.softmax(linear(p[f"g{t}"], x), axis=-1)                     # [B,E]
        outs.append(jnp.einsum("be,beh->bh", g, experts))
    return outs


def coaction(hist: jnp.ndarray, target: jnp.ndarray, mask: jnp.ndarray,
             layers: Tuple[int, ...] = (4, 4)) -> jnp.ndarray:
    """CAN co-action unit: target embedding reshaped into MLP weights applied
    to history embeddings ([B,L,D] x [B,D] -> [B, layers[-1]])."""
    b, l, d = hist.shape
    need = 0
    d_in = d
    shapes = []
    for h in layers:
        shapes.append((d_in, h))
        need += d_in * h
        d_in = h
    reps = int(np.ceil(need / d))
    wflat = jnp.tile(target, (1, reps))[:, :need]
    x = hist
    off = 0
    for (di, do) in shapes:
        w = wflat[:, off:off + di * do].reshape(b, di, do)
        off += di * do
        x = jnp.tanh(jnp.einsum("bld,bdo->blo", x, w))
    x = x * mask[..., None].astype(x.dtype)
    return x.sum(axis=1)
