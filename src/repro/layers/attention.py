"""GQA attention with RoPE, causal/sliding-window masking, chunked prefill
(flash-style static q-chunks with exact per-chunk K ranges) and KV-cache
decode. Pure jnp + sharding-constraint friendly (GSPMD partitions it)."""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, hd]; pos: [S] (or [B, S])."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # [hd/2]
    ang = pos[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == 4 and ang.ndim == 2:                  # [B,S,H,hd] with pos [S]
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    elif x.ndim == 4:                                  # pos [B,S]
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = x[..., ::2], x[..., 1::2]
    xr1 = x1 * cos - x2 * sin
    xr2 = x1 * sin + x2 * cos
    out = jnp.stack([xr1, xr2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def _sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
          mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    """q: [B,Sq,H,hd], k/v: [B,Sk,G,hd] grouped KV; returns [B,Sq,H,hd]."""
    b, sq, h, hd = q.shape
    g = k.shape[2]
    rep = h // g
    qg = q.reshape(b, sq, g, rep, hd)
    logits = jnp.einsum("bsgrd,btgd->bgrst", qg, k).astype(jnp.float32)
    logits = logits / np.sqrt(hd)
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    a = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    o = jnp.einsum("bgrst,btgd->bsgrd", a, v)
    return o.reshape(b, sq, h, hd)


def chunked_causal_attention(
    q: jnp.ndarray,          # [B, S, H, hd]
    k: jnp.ndarray,          # [B, S, G, hd]
    v: jnp.ndarray,          # [B, S, G, hd]
    chunk: int = 512,
    window: Optional[int] = None,   # sliding-window attention width
) -> jnp.ndarray:
    """Causal (optionally sliding-window) attention, python-unrolled q-chunks
    with *static* per-chunk K ranges — no wasted FLOPs on fully-masked blocks,
    and the [S, S] score matrix is never materialized (peak is [chunk, Kspan])."""
    b, s, h, hd = q.shape
    if s <= chunk or s % chunk != 0:
        pos = jnp.arange(s)
        m = pos[:, None] >= pos[None, :]
        if window is not None:
            m &= pos[:, None] - pos[None, :] < window
        return _sdpa(q, k, v, m[None, None, None, :, :])
    assert s % chunk == 0, (s, chunk)
    outs = []
    for i in range(s // chunk):
        q_i = lax.slice_in_dim(q, i * chunk, (i + 1) * chunk, axis=1)
        hi = (i + 1) * chunk
        lo = 0 if window is None else max(0, hi - window - chunk + 1)
        lo = (lo // chunk) * chunk  # align for static shapes
        k_i = lax.slice_in_dim(k, lo, hi, axis=1)
        v_i = lax.slice_in_dim(v, lo, hi, axis=1)
        qpos = i * chunk + jnp.arange(chunk)
        kpos = lo + jnp.arange(hi - lo)
        m = qpos[:, None] >= kpos[None, :]
        if window is not None:
            m &= qpos[:, None] - kpos[None, :] < window
        outs.append(_sdpa(q_i, k_i, v_i, m[None, None, None, :, :]))
    return jnp.concatenate(outs, axis=1)


def decode_attention(
    q: jnp.ndarray,          # [B, 1, H, hd]
    k_cache: jnp.ndarray,    # [B, S, G, hd]
    v_cache: jnp.ndarray,    # [B, S, G, hd]
    length: jnp.ndarray,     # [] or [B] valid cache length
    window: Optional[int] = None,
) -> jnp.ndarray:
    """One-token decode vs. the KV cache. With the cache sharded along S over
    the 'model' axis, GSPMD turns the softmax reductions into the
    flash-decoding split-K combine (psum of partial max/sum)."""
    b, s, g, hd = k_cache.shape
    pos = jnp.arange(s)
    valid = pos[None, :] < jnp.reshape(length, (-1, 1))
    if window is not None:
        valid &= pos[None, :] >= jnp.reshape(length, (-1, 1)) - window
    mask = valid[:, None, None, None, :]                   # [B,1,1,1,S]
    return _sdpa(q, k_cache, v_cache, mask)
