"""Top-k routed MoE (Mixtral / Phi-3.5 style) with sort-based dispatch.

Dispatch reuses the same fixed-capacity partition idiom as the PICASSO
embedding Shuffle: tokens sorted by expert, rank-within-expert = cumsum
difference, scatter into [E, C, D]; per-expert SwiGLU einsum; weighted
scatter back. Exact top-k with capacity-factor dropping (GShard semantics).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def moe_dispatch(x: jnp.ndarray, router_logits: jnp.ndarray, n_experts: int,
                 top_k: int, capacity_factor: float = 1.25
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, int]:
    """x: [N, D]; returns (xe [E, C, D], combine idx info...)."""
    n, d = x.shape
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)  # [N, E]
    gate, expert = lax.top_k(probs, top_k)                              # [N, K]
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)           # renorm (Mixtral)

    cap = int(math.ceil(n * top_k / n_experts * capacity_factor))
    cap = max(8, min(cap, n))

    e_flat = expert.reshape(-1)                                          # [N*K]
    order = jnp.argsort(e_flat)
    e_sorted = e_flat[order]
    # rank within expert among sorted assignment list
    start = jnp.searchsorted(e_sorted, e_sorted, side="left")
    rank = jnp.arange(n * top_k, dtype=jnp.int32) - start.astype(jnp.int32)
    kept = rank < cap
    slot = jnp.where(kept, e_sorted * cap + rank, n_experts * cap)

    tok = (order // top_k).astype(jnp.int32)                             # token of each assignment
    xe = jnp.zeros((n_experts * cap, d), x.dtype).at[slot].set(
        jnp.take(x, tok, axis=0), mode="drop")
    return xe.reshape(n_experts, cap, d), (order, slot, tok, kept), gate, cap


def moe_combine(ye: jnp.ndarray, dispatch_info, gate: jnp.ndarray, n: int,
                top_k: int) -> jnp.ndarray:
    order, slot, tok, kept = dispatch_info
    e, cap, d = ye.shape
    flat = ye.reshape(e * cap, d)
    y_assign = jnp.take(flat, jnp.minimum(slot, e * cap - 1), axis=0)
    y_assign = y_assign * kept[:, None].astype(y_assign.dtype)
    g_sorted = jnp.take(gate.reshape(-1), order)
    contrib = y_assign * g_sorted[:, None].astype(y_assign.dtype)
    return jnp.zeros((n, d), ye.dtype).at[tok].add(contrib)


def moe_ffn(x: jnp.ndarray, router_w: jnp.ndarray, w1: jnp.ndarray,
            w2: jnp.ndarray, w3: jnp.ndarray, top_k: int,
            capacity_factor: float = 1.25, groups: int = 1,
            xe_sharding=None) -> jnp.ndarray:
    """x: [N, D]; router_w: [D, E]; w1/w3: [E, D, F]; w2: [E, F, D].

    ``groups`` > 1 dispatches per token-group (group dim == data shards, so
    the argsort/scatter stay shard-local under GSPMD); ``xe_sharding`` (a
    NamedSharding over [G, E, C, D]) pins the dispatched buffer to
    token-group-sharded layout. Without both, GSPMD replicates the dispatch
    buffers across the data axes (observed: TB-scale all-reduces on mixtral).
    """
    n, d = x.shape
    e = router_w.shape[1]
    if groups <= 1 or n % groups:
        logits = x @ router_w
        xe, info, gate, cap = moe_dispatch(x, logits, e, top_k, capacity_factor)
        h = jnp.einsum("ecd,edf->ecf", xe, w1)
        g = jnp.einsum("ecd,edf->ecf", xe, w3)
        ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, w2)
        return moe_combine(ye, info, gate, n, top_k)

    xg = x.reshape(groups, n // groups, d)

    def one_group(xl):
        logits = xl @ router_w
        return moe_dispatch(xl, logits, e, top_k, capacity_factor)

    xe, info, gate, cap = jax.vmap(one_group)(xg)       # [G, E, C, D]
    if xe_sharding is not None:
        xe = jax.lax.with_sharding_constraint(xe, xe_sharding)
    h = jnp.einsum("gecd,edf->gecf", xe, w1)
    g = jnp.einsum("gecd,edf->gecf", xe, w3)
    ye = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g) * h, w2)
    if xe_sharding is not None:
        ye = jax.lax.with_sharding_constraint(ye, xe_sharding)
    out = jax.vmap(lambda y, i, gt: moe_combine(y, i, gt, n // groups, top_k)
                   )(ye, info, gate)
    return out.reshape(n, d)
