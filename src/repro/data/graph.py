"""Graph data: synthetic power-law graphs, a *real* neighbor sampler for
minibatch training (fanout sampling over CSR), and batched molecule graphs."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


def synthetic_graph(n_nodes: int, n_edges: int, d_feat: int, seed: int = 0,
                    with_feat: bool = True) -> Dict[str, np.ndarray]:
    """Power-law-ish random graph + CSR, small enough to materialize."""
    rng = np.random.default_rng(seed)
    # preferential-attachment-flavoured endpoints (zipf head)
    u = rng.random(n_edges)
    src = np.clip((n_nodes * u ** 2.0).astype(np.int64), 0, n_nodes - 1)
    dst = rng.integers(0, n_nodes, n_edges)
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    out = {
        "src": src.astype(np.int32), "dst": dst.astype(np.int32),
        "indptr": indptr, "indices": dst.astype(np.int32),
        "dist": rng.uniform(0.5, 9.5, n_edges).astype(np.float32),
        "target": rng.normal(size=n_nodes).astype(np.float32),
    }
    if with_feat:
        out["nodes"] = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    else:
        out["nodes"] = rng.integers(0, 90, n_nodes).astype(np.int32)
    return out


def sample_neighbors(graph: Dict[str, np.ndarray], seeds: np.ndarray,
                     fanouts: Tuple[int, ...], rng: np.random.Generator
                     ) -> Dict[str, np.ndarray]:
    """Layer-wise fanout sampling (GraphSAGE style) over CSR.

    Returns a padded subgraph: relabelled nodes, edge list (src, dst) with
    edge weights 0 on padding, seed mask for the loss.
    """
    indptr, indices = graph["indptr"], graph["indices"]
    frontier = np.unique(seeds)
    all_src, all_dst = [], []
    nodes = [frontier]
    for f in fanouts:
        deg = indptr[frontier + 1] - indptr[frontier]
        # sample up to f neighbors per frontier node
        offs = rng.integers(0, np.maximum(deg, 1)[:, None], size=(len(frontier), f))
        has = deg > 0
        nbr = indices[np.minimum(indptr[frontier, None] + offs,
                                 np.maximum(indptr[frontier + 1, None] - 1, 0))]
        src_rep = np.repeat(frontier, f).reshape(len(frontier), f)
        keep = np.broadcast_to(has[:, None], nbr.shape)
        all_src.append(nbr[keep])       # messages flow neighbor -> node
        all_dst.append(src_rep[keep])
        frontier = np.unique(nbr[keep])
        nodes.append(frontier)
    node_ids = np.unique(np.concatenate(nodes))
    relabel = {int(g): i for i, g in enumerate(node_ids)}
    src = np.array([relabel[int(s)] for s in np.concatenate(all_src)], np.int32)
    dst = np.array([relabel[int(d)] for d in np.concatenate(all_dst)], np.int32)
    seed_local = np.array([relabel[int(s)] for s in np.unique(seeds)], np.int32)
    return {"node_ids": node_ids.astype(np.int32), "src": src, "dst": dst,
            "seeds_local": seed_local}


def pad_subgraph(sub: Dict[str, np.ndarray], graph: Dict[str, np.ndarray],
                 max_nodes: int, max_edges: int) -> Dict[str, np.ndarray]:
    """Static-shape padding for jit: node/edge arrays padded with weight 0."""
    n, e = len(sub["node_ids"]), len(sub["src"])
    n_c, e_c = min(n, max_nodes), min(e, max_edges)
    nodes_src = graph["nodes"][sub["node_ids"][:n_c]]
    if nodes_src.ndim == 1:
        nodes = np.zeros(max_nodes, nodes_src.dtype)
        nodes[:n_c] = nodes_src
    else:
        nodes = np.zeros((max_nodes, nodes_src.shape[1]), nodes_src.dtype)
        nodes[:n_c] = nodes_src
    out = {
        "nodes": nodes,
        "src": np.zeros(max_edges, np.int32), "dst": np.zeros(max_edges, np.int32),
        "dist": np.zeros(max_edges, np.float32),
        "edge_w": np.zeros(max_edges, np.float32),
        "target": np.zeros(max_nodes, np.float32),
        "node_w": np.zeros(max_nodes, np.float32),
    }
    out["src"][:e_c] = sub["src"][:e_c]
    out["dst"][:e_c] = sub["dst"][:e_c]
    out["dist"][:e_c] = np.random.default_rng(0).uniform(0.5, 9.5, e_c).astype(np.float32)
    out["edge_w"][:e_c] = 1.0
    out["target"][:n_c] = graph["target"][sub["node_ids"][:n_c]]
    seeds = sub["seeds_local"][sub["seeds_local"] < max_nodes]
    out["node_w"][seeds] = 1.0
    return out


def molecule_batch(batch: int, n_nodes: int, n_edges: int, seed: int = 0) -> Dict:
    """Batched small graphs (flat arrays + graph_ids)."""
    rng = np.random.default_rng(seed)
    tot_n, tot_e = batch * n_nodes, batch * n_edges
    off = (np.arange(batch, dtype=np.int32) * n_nodes)[:, None]
    src = (rng.integers(0, n_nodes, (batch, n_edges)) + off).reshape(-1)
    dst = (rng.integers(0, n_nodes, (batch, n_edges)) + off).reshape(-1)
    return {
        "nodes": rng.integers(0, 90, tot_n).astype(np.int32),
        "src": src.astype(np.int32), "dst": dst.astype(np.int32),
        "dist": rng.uniform(0.5, 9.5, tot_e).astype(np.float32),
        "edge_w": np.ones(tot_e, np.float32),
        "graph_ids": np.repeat(np.arange(batch, dtype=np.int32), n_nodes),
        "target": rng.normal(size=batch).astype(np.float32),
    }
