"""Synthetic skewed WDL data streams (paper §II-B, Fig. 3).

Categorical IDs are drawn zipf-like per field ("20% of IDs cover 70-99% of
the training data"); sequence fields have variable valid lengths. Generation
is host-side numpy (the data-transmission layer of Fig. 2), feeding the
device pipeline in repro/data/pipeline.py.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import WDLConfig


def zipf_ids(rng: np.random.Generator, vocab: int, size, a: float = 1.2) -> np.ndarray:
    """Bounded zipf sampler via inverse-CDF power approximation."""
    u = rng.random(size)
    # id ~ floor(vocab * u^{1/(a-1)}) gives a heavy head at small ids
    expo = 1.0 / max(a - 1.0, 0.05)
    ids = np.floor(vocab * np.power(u, expo)).astype(np.int64)
    return np.clip(ids, 0, vocab - 1).astype(np.int32)


def make_batch(cfg: WDLConfig, batch: int, rng: Optional[np.random.Generator] = None,
               zipf_a: float = 1.2, seed: int = 0, learnable: bool = False) -> Dict:
    rng = rng or np.random.default_rng(seed)
    fields = {}
    for f in cfg.fields:
        if f.name == "pos":  # positional field: ids are positions
            ids = np.tile(np.arange(f.max_len, dtype=np.int32), (batch, 1))
            w = np.ones((batch, f.max_len), np.float32)
        else:
            ids = zipf_ids(rng, f.vocab, (batch, f.max_len), zipf_a)
            if f.max_len > 1:
                # variable-length multi-hot: valid length uniform in [1, L]
                lens = rng.integers(1, f.max_len + 1, size=(batch, 1))
                w = (np.arange(f.max_len)[None, :] < lens).astype(np.float32)
                ids = np.where(w > 0, ids, 0).astype(np.int32)
            else:
                w = np.ones((batch, 1), np.float32)
        fields[f.name] = {"ids": ids, "weights": w}
    if learnable:
        # deterministic function of the categorical ids -> a model CAN fit it
        acc = np.zeros(batch, np.int64)
        for f in cfg.fields[: min(4, len(cfg.fields))]:
            acc = acc + fields[f.name]["ids"][:, 0].astype(np.int64)
        labels = (acc % 2).astype(np.float32)
    else:
        labels = rng.integers(0, 2, size=(batch,)).astype(np.float32)
    out = {"fields": fields, "labels": labels}
    if cfg.n_dense > 0:
        out["dense"] = rng.normal(size=(batch, cfg.n_dense)).astype(np.float32)
    return out


def batch_stream(cfg: WDLConfig, batch: int, seed: int = 0, zipf_a: float = 1.2,
                 learnable: bool = False, start: int = 0) -> Iterator[Dict]:
    """Infinite batch stream, seekable in O(1): batch ``i`` is generated from
    ``SeedSequence((seed, i))`` independent of every other batch, so a stream
    opened at ``start=i`` yields exactly what the original stream yielded at
    position ``i``. This is what makes Supervisor rollback-replay *exact* —
    after a restore to step ``s`` the stream reopens at ``start=s`` instead
    of silently continuing past the skipped batches."""
    i = start
    while True:
        rng = np.random.default_rng(np.random.SeedSequence((seed, i)))
        yield make_batch(cfg, batch, rng, zipf_a, learnable=learnable)
        i += 1


def batch_spec(cfg: WDLConfig, batch: int) -> Dict:
    """ShapeDtypeStruct stand-ins for the dry-run."""
    import jax
    import jax.numpy as jnp
    fields = {
        f.name: {"ids": jax.ShapeDtypeStruct((batch, f.max_len), jnp.int32),
                 "weights": jax.ShapeDtypeStruct((batch, f.max_len), jnp.float32)}
        for f in cfg.fields
    }
    out = {"fields": fields, "labels": jax.ShapeDtypeStruct((batch,), jnp.float32)}
    if cfg.n_dense > 0:
        out["dense"] = jax.ShapeDtypeStruct((batch, cfg.n_dense), jnp.float32)
    return out
