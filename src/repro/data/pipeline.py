"""Host data pipeline: background prefetch + straggler mitigation.

The paper's Fig. 5 shows exposed I/O of ~20% on W&D-class models; the fix is
a deep enough prefetch queue plus *backup batches*: if the generator thread
misses its deadline (slow remote read / skewed shard), the iterator yields
the most recent spare instead of stalling the whole synchronous step.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax


class Prefetcher:
    def __init__(self, gen: Iterator, depth: int = 4, timeout_s: float = 5.0,
                 put_fn: Optional[Callable] = None):
        self.gen = gen
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self.timeout_s = timeout_s
        self.put_fn = put_fn or (lambda x: x)
        self.backup: Any = None
        self.stats = {"produced": 0, "backup_served": 0}
        self._stop = False
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        for item in self.gen:
            if self._stop:
                return
            out = self.put_fn(item)
            # bounded put that stays responsive to close(): a blocking
            # q.put() on a full queue would never observe _stop and the
            # worker thread would hang forever after close()
            while not self._stop:
                try:
                    self.q.put(out, timeout=0.1)
                    break
                except queue.Full:
                    continue
            if self._stop:
                return
            self.stats["produced"] += 1

    def __iter__(self):
        return self

    def __next__(self):
        try:
            item = self.q.get(timeout=self.timeout_s)
            self.backup = item
            return item
        except queue.Empty:
            if self.backup is not None:  # straggler mitigation: serve the spare
                self.stats["backup_served"] += 1
                return self.backup
            raise StopIteration

    def close(self, join_timeout_s: float = 5.0):
        """Stop the worker and reap it: raise the stop flag, then drain the
        queue until the (possibly put-blocked) worker observes the flag and
        exits. Idempotent; the thread is daemonic, so a generator stuck
        inside ``next()`` past the timeout cannot wedge interpreter exit."""
        self._stop = True
        deadline = time.monotonic() + join_timeout_s
        while self._thread.is_alive() and time.monotonic() < deadline:
            try:  # make room so a blocked put() can complete and re-check
                self.q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=0.05)


class ReplayableStream:
    """Seekable wrapper over a positional stream factory.

    ``make_iter(start)`` must return an iterator whose first item is the
    batch at absolute position ``start`` (see ``synthetic.batch_stream``'s
    per-index seeding). The wrapper tracks the current position so a
    supervisor can ``seek(step)`` after a checkpoint rollback and replay the
    exact batches the failed stretch consumed — without it, every batch
    between the checkpoint step and the failure step is silently skipped.

    ``rewrap(make_iter)`` swaps the factory at the current position (e.g.
    re-binding device placement after an elastic reshard changes the mesh).
    Underlying iterators with a ``close()`` (Prefetcher) are closed on
    seek/rewrap/close so their worker threads are reaped.
    """

    def __init__(self, make_iter: Callable[[int], Iterator], start: int = 0):
        self._make = make_iter
        self.pos = start
        self._it: Optional[Iterator] = None

    def _open(self):
        if self._it is None:
            self._it = self._make(self.pos)
        return self._it

    def __iter__(self):
        return self

    def __next__(self):
        item = next(self._open())
        self.pos += 1
        return item

    def seek(self, step: int) -> "ReplayableStream":
        if step != self.pos or self._it is None:
            self.close()
            self.pos = step
        return self

    def rewrap(self, make_iter: Callable[[int], Iterator]) -> "ReplayableStream":
        self.close()
        self._make = make_iter
        return self

    def close(self):
        it, self._it = self._it, None
        if it is not None and hasattr(it, "close"):
            it.close()


def device_put_stream(gen: Iterator, mesh, specs_fn: Callable, depth: int = 2
                      ) -> Iterator:
    """Prefetch + async device_put with the right shardings."""
    from repro.dist.sharding import to_named

    def put(batch):
        return jax.device_put(batch, to_named(mesh, specs_fn(batch)))

    return Prefetcher(gen, depth=depth, put_fn=put)
