"""Pallas TPU kernel: fused Gather + SegmentReduction (EmbeddingBag).

The paper's dominant memory-bound embedding-layer op. One grid step per id:
scalar-prefetched ids drive the table BlockSpec index_map (HBM -> VMEM DMA of
exactly the needed row, double-buffered by the Pallas pipeline), the
scalar-prefetched segment ids drive the *output* index_map. Segments are
sorted, so each output block is revisited while its segment lasts (stays in
VMEM) and flushed exactly once — the classic TPU embedding-gather idiom.

Requires: seg sorted ascending; every bag in [0, n_bags) appears >= once
(guaranteed by the packed batch layout: padding positions carry weight 0 but
still occupy a slot).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, seg_ref, w_ref, table_blk, out_blk):
    i = pl.program_id(0)
    wgt = w_ref[i]
    row = table_blk[...] * wgt

    first = jnp.logical_or(i == 0, seg_ref[i] != seg_ref[jnp.maximum(i - 1, 0)])

    @pl.when(first)
    def _init():
        out_blk[...] = row

    @pl.when(jnp.logical_not(first))
    def _acc():
        out_blk[...] += row


@functools.partial(jax.jit, static_argnames=("n_bags", "interpret"))
def embedding_bag_pallas(
    table: jnp.ndarray,     # [V, D]
    ids: jnp.ndarray,       # [N] int32
    seg: jnp.ndarray,       # [N] int32, sorted ascending, covers [0, n_bags)
    weights: jnp.ndarray,   # [N]
    n_bags: int,
    interpret: bool = False,
) -> jnp.ndarray:
    n = ids.shape[0]
    v, d = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,  # ids, seg, weights
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, ids, seg, w: (ids[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, ids, seg, w: (seg[i], 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, d), table.dtype),
        interpret=interpret,
    )(ids, seg, weights.astype(table.dtype), table)
