"""jit'd dispatch wrappers for the Pallas kernels.

On TPU the Pallas path is used; on CPU (this container) the pure-jnp
reference executes (XLA fuses it well), while tests exercise the kernels in
``interpret=True`` mode against the same references. Set
``REPRO_FORCE_PALLAS_INTERPRET=1`` to route *all* calls through the
interpreted kernels (slow; correctness soak).
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cross_layer import cross_layer_pallas
from repro.kernels.dot_interaction import dot_interaction_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.fm_interaction import fm_interaction_pallas


def _use_pallas() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS_INTERPRET"):
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def embedding_bag(table, ids, seg, n_bags: int, weights: Optional[jnp.ndarray] = None):
    if _use_pallas():
        w = weights if weights is not None else jnp.ones_like(ids, table.dtype)
        return embedding_bag_pallas(table, ids, seg, w, n_bags, interpret=_interpret())
    return ref.embedding_bag_ref(table, ids, seg, n_bags, weights)


def fm_interaction(fields):
    if _use_pallas():
        return fm_interaction_pallas(fields, interpret=_interpret())
    return ref.fm_interaction_ref(fields)


def dot_interaction(fields):
    if _use_pallas():
        return dot_interaction_pallas(fields, interpret=_interpret())
    return ref.dot_interaction_ref(fields)


def cross_layer(x0, x, w, b):
    if _use_pallas():
        return cross_layer_pallas(x0, x, w, b, interpret=_interpret())
    return ref.cross_layer_ref(x0, x, w, b)
