"""jit'd dispatch wrappers for the Pallas kernels.

On TPU the Pallas path is used; on CPU (this container) the pure-jnp
reference executes (XLA fuses it well), while tests exercise the kernels in
``interpret=True`` mode against the same references. Set
``REPRO_FORCE_PALLAS_INTERPRET=1`` to route *all* calls through the
interpreted kernels (slow; correctness soak).

Backend dispatch is resolved ONCE, at the first dispatched call (not inside
every traced call): the env var and ``jax.default_backend()`` are read one
time and cached, so the hot path never re-reads ``os.environ``. Call
``reset_backend_cache()`` after changing either (tests do).

The sparse hot-path ops (``gather_pool`` / ``segment_grad`` /
``dedup_adagrad`` / ``tier_probe``) additionally take an explicit
``fused=`` override: ``None`` follows the backend default above, ``True``
forces the Pallas kernels (interpreted off-TPU), ``False`` forces the jnp
reference. ``resolve_fused`` maps the user-facing
``TrainConfig/ServeConfig.use_fused_kernels`` spelling (``'auto' | bool |
'on' | 'off'``) to that override once, at engine construction —
strategies then carry a plain static bool through their traces.

``gather_pool`` is a ``jax.custom_vjp``: its backward is the fused
``segment_grad`` pass (producing ``[n_rows, D]`` row grads directly), so
neither direction materializes the ``[n, D]`` per-id intermediate when
fused. Pooling weights are treated as non-learnable constants (their
cotangent is zero) — matching the engine, which only ever differentiates
with respect to the looked-up rows.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.cross_layer import cross_layer_pallas
from repro.kernels.dot_interaction import dot_interaction_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.fm_interaction import fm_interaction_pallas
from repro.kernels.fused_embedding import (dedup_adagrad_pallas,
                                           gather_pool_pallas,
                                           gather_project_grad_pallas,
                                           gather_project_pallas,
                                           segment_grad_pallas,
                                           tier_probe_pallas)
from repro.kernels.grad_compress import (fp16_compress_pallas,
                                         fp16_decompress_pallas,
                                         topk_compress_pallas,
                                         topk_decompress_pallas)
from repro.kernels.interaction_bwd import (cross_layer_bwd_pallas,
                                           dot_interaction_bwd_pallas,
                                           fm_interaction_bwd_pallas)

# (use_pallas, interpret), resolved once at first dispatch
_BACKEND: Optional[Tuple[bool, bool]] = None


def _backend() -> Tuple[bool, bool]:
    global _BACKEND
    if _BACKEND is None:
        tpu = jax.default_backend() == "tpu"
        force = bool(os.environ.get("REPRO_FORCE_PALLAS_INTERPRET"))
        # force wins on every backend — a TPU soak must actually interpret,
        # not silently run the compiled kernels
        _BACKEND = (tpu or force, force or not tpu)
    return _BACKEND


# spelling -> resolved bool, memoized per process so repeated engine
# constructions skip the validation/branching. Keyed by the spelling itself;
# the 'auto'/None entries depend on _BACKEND, so the memo MUST die with it
# (reset_backend_cache clears both — an interpret-soak test that flipped the
# env var must not leak its resolved dispatch into later tests).
_RESOLVE_MEMO: dict = {}


def reset_backend_cache() -> None:
    """Forget the cached backend decision (tests that flip the env var) and
    the per-spelling ``resolve_fused`` memo derived from it."""
    global _BACKEND
    _BACKEND = None
    _RESOLVE_MEMO.clear()


def _use_pallas() -> bool:
    return _backend()[0]


def _interpret() -> bool:
    return _backend()[1]


def interpret_mode() -> bool:
    """Whether Pallas kernels run through the interpreter in this process
    (TPU-less backend or the ``REPRO_FORCE_PALLAS_INTERPRET`` soak). Public
    so the bench harness can stamp its rows — interpreter timings must never
    be mistaken for silicon numbers."""
    return _interpret()


def resolve_fused(spec: Union[str, bool, None]) -> bool:
    """Map a ``use_fused_kernels`` spelling to a static bool, once.

    ``'auto'``/``None`` follow the backend (Pallas on TPU or under the
    interpret-soak env var, reference on CPU); booleans and ``'on'``/
    ``'off'`` force it. Raises on anything else so config typos fail at
    construction, not silently at dispatch. Resolutions are memoized per
    spelling; ``reset_backend_cache`` clears the memo together with the
    backend decision it is derived from."""
    try:
        return _RESOLVE_MEMO[spec]
    except KeyError:
        pass
    if spec is None or spec == "auto":
        out = _use_pallas()
    elif isinstance(spec, bool):
        out = spec
    elif spec == "on":
        out = True
    elif spec == "off":
        out = False
    else:
        raise ValueError(
            f"use_fused_kernels must be 'auto', 'on', 'off' or a bool; got {spec!r}")
    _RESOLVE_MEMO[spec] = out
    return out


def _fused(fused: Optional[bool]) -> bool:
    return _use_pallas() if fused is None else bool(fused)


# ---------------------------------------------------------------------------
# dense / interaction kernels (cached backend dispatch + fused VJPs)
#
# ``pallas_call`` defines no VJP, so a bare dispatcher is only differentiable
# on the CPU reference branch — the train step would fail under jax.grad
# anywhere the Pallas branch is live (TPU, or the interpret soak). Each
# dispatcher is therefore a ``jax.custom_vjp``. On the Pallas branch the
# interaction backwards run their own fused kernels
# (``repro.kernels.interaction_bwd``) instead of re-materializing the
# reference transpose's HBM intermediates; on the CPU branch the backward IS
# ``jax.vjp`` of the same reference the forward ran, so CPU grads stay
# bitwise-unchanged. (``embedding_bag`` keeps the reference transpose: the
# engine's production sparse backward is the standalone ``segment_grad``
# pass, not this op's VJP.)
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _embedding_bag(table, ids, seg, w, n_bags: int):
    if _use_pallas():
        # the kernel wants explicit weights; the reference keeps its
        # weightless fast path (no [n, D] multiply by runtime ones)
        wp = w if w is not None else jnp.ones_like(ids, table.dtype)
        return embedding_bag_pallas(table, ids, seg, wp, n_bags,
                                    interpret=_interpret())
    return ref.embedding_bag_ref(table, ids, seg, n_bags, w)


def _embedding_bag_fwd(table, ids, seg, w, n_bags: int):
    return _embedding_bag(table, ids, seg, w, n_bags), (table, ids, seg, w)


def _embedding_bag_bwd(n_bags: int, res, g):
    table, ids, seg, w = res
    if w is None:
        _, vjp = jax.vjp(
            lambda t: ref.embedding_bag_ref(t, ids, seg, n_bags, None), table)
        return vjp(g) + (None, None, None)
    _, vjp = jax.vjp(
        lambda t, w_: ref.embedding_bag_ref(t, ids, seg, n_bags, w_), table, w)
    gt, gw = vjp(g)
    return gt, None, None, gw


_embedding_bag.defvjp(_embedding_bag_fwd, _embedding_bag_bwd)


def embedding_bag(table, ids, seg, n_bags: int, weights: Optional[jnp.ndarray] = None):
    return _embedding_bag(table, ids, seg, weights, int(n_bags))


@jax.custom_vjp
def fm_interaction(fields):
    if _use_pallas():
        return fm_interaction_pallas(fields, interpret=_interpret())
    return ref.fm_interaction_ref(fields)


def _fm_fwd(fields):
    return fm_interaction(fields), fields


def _fm_bwd(fields, g):
    if _use_pallas():
        return (fm_interaction_bwd_pallas(fields, g, interpret=_interpret()),)
    _, vjp = jax.vjp(ref.fm_interaction_ref, fields)
    return vjp(g)


fm_interaction.defvjp(_fm_fwd, _fm_bwd)


@jax.custom_vjp
def dot_interaction(fields):
    if _use_pallas():
        return dot_interaction_pallas(fields, interpret=_interpret())
    return ref.dot_interaction_ref(fields)


def _dot_fwd(fields):
    return dot_interaction(fields), fields


def _dot_bwd(fields, g):
    if _use_pallas():
        return (dot_interaction_bwd_pallas(fields, g, interpret=_interpret()),)
    _, vjp = jax.vjp(ref.dot_interaction_ref, fields)
    return vjp(g)


dot_interaction.defvjp(_dot_fwd, _dot_bwd)


@jax.custom_vjp
def cross_layer(x0, x, w, b):
    if _use_pallas():
        return cross_layer_pallas(x0, x, w, b, interpret=_interpret())
    return ref.cross_layer_ref(x0, x, w, b)


def _cross_fwd(x0, x, w, b):
    return cross_layer(x0, x, w, b), (x0, x, w, b)


def _cross_bwd(res, g):
    if _use_pallas():
        return cross_layer_bwd_pallas(*res, g, interpret=_interpret())
    _, vjp = jax.vjp(ref.cross_layer_ref, *res)
    return vjp(g)


cross_layer.defvjp(_cross_fwd, _cross_bwd)


# ---------------------------------------------------------------------------
# fused sparse hot path: gather+pool (custom VJP), dedup+adagrad, tier probe
# ---------------------------------------------------------------------------


def _gather_pool_impl(rows_u, inv, weights, seg, n_bags: int, fused: bool):
    if fused:
        return gather_pool_pallas(rows_u, inv, weights, seg, n_bags,
                                  interpret=_interpret())
    return ref.gather_pool_ref(rows_u, inv, weights, seg, n_bags)


def _segment_grad_impl(g_bags, seg, weights, inv, n_rows: int, fused: bool):
    if fused:
        return segment_grad_pallas(g_bags, seg, weights, inv, n_rows,
                                   interpret=_interpret())
    return ref.segment_grad_ref(g_bags, seg, weights, inv, n_rows)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _gather_pool(rows_u, inv, weights, seg, n_bags: int, fused: bool):
    return _gather_pool_impl(rows_u, inv, weights, seg, n_bags, fused)


def _gather_pool_fwd(rows_u, inv, weights, seg, n_bags: int, fused: bool):
    out = _gather_pool_impl(rows_u, inv, weights, seg, n_bags, fused)
    return out, (inv, weights, seg, rows_u.shape[0])


def _gather_pool_bwd(n_bags: int, fused: bool, res, g):
    inv, weights, seg, n_rows = res
    g_rows = _segment_grad_impl(g, seg, weights, inv, n_rows, fused)
    # weights are pooling constants (see module docstring): zero cotangent
    return g_rows, None, jnp.zeros_like(weights), None


_gather_pool.defvjp(_gather_pool_fwd, _gather_pool_bwd)


def gather_pool(rows_u, inv, weights, seg, n_bags: int,
                fused: Optional[bool] = None):
    """Fused forward SegmentReduction ``bags[seg] += w * rows_u[inv]`` with a
    fused-transpose custom VJP. Requires ``seg`` sorted ascending and
    covering every bag (the packed-batch layout guarantees it)."""
    return _gather_pool(rows_u, inv, weights, seg, int(n_bags), _fused(fused))


def segment_grad(g_bags, seg, weights, inv, n_rows: int,
                 fused: Optional[bool] = None):
    """Transpose of ``gather_pool`` as a standalone op (the engine's explicit
    backward path): ``g_rows[u] = sum_{inv[i]=u} w[i] * g_bags[seg[i]]``."""
    return _segment_grad_impl(g_bags, seg, weights, inv, int(n_rows),
                              _fused(fused))


def dedup_adagrad(w, acc, idx, g, valid, lr: float, eps: float,
                  fused: Optional[bool] = None):
    """Sum duplicate row grads and apply row-wise adagrad to the touched rows
    of ``(w, acc)`` in one pass (in-place scatter when fused). The fused
    kernel accumulates duplicates in the reference order — untouched rows
    stay bitwise identical, touched rows match to ~1 ULP of XLA-fusion
    reassociation in the adagrad arithmetic."""
    if _fused(fused):
        return dedup_adagrad_pallas(w, acc, idx, g, valid, float(lr),
                                    float(eps), interpret=_interpret())
    return ref.dedup_adagrad_ref(w, acc, idx, g, valid, lr, eps)


def tier_probe(uniq, uvalid, keys, rows, fused: Optional[bool] = None):
    """Probe one sorted-key cache tier: ``(hit, slot, rows)`` with miss rows
    exactly zero. ``slot`` is the clamped searchsorted position (the
    backward scatter reuses it)."""
    if _fused(fused):
        return tier_probe_pallas(uniq, uvalid, keys, rows,
                                 interpret=_interpret())
    return ref.tier_probe_ref(uniq, uvalid, keys, rows)


def _gather_project_impl(back, idx, kept, proj, fused: bool):
    if fused:
        return gather_project_pallas(back, idx, kept, proj,
                                     interpret=_interpret())
    return ref.gather_project_ref(back, idx, kept, proj)


def _gather_project_grad_impl(g_wide, g_narrow, idx, kept, proj, m: int,
                              fused: bool):
    if fused:
        return gather_project_grad_pallas(g_wide, g_narrow, idx, kept, proj,
                                          m, interpret=_interpret())
    return ref.gather_project_grad_ref(g_wide, g_narrow, idx, kept, proj, m)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _gather_project(back, idx, kept, proj, fused: bool):
    return _gather_project_impl(back, idx, kept, proj, fused)


def _gather_project_fwd(back, idx, kept, proj, fused: bool):
    out = _gather_project_impl(back, idx, kept, proj, fused)
    # the narrow residual is already kept-masked, so the projection cotangent
    # below needs no re-mask
    return out, (idx, kept, out[1], proj, back.shape[0])


def _gather_project_bwd(fused: bool, res, g):
    idx, kept, narrow, proj, m = res
    g_wide, g_narrow = g
    g_back = _gather_project_grad_impl(g_wide, g_narrow, idx, kept, proj,
                                       m, fused)
    g_proj = narrow.T @ g_wide          # [d, D], one MXU pass
    return g_back, None, None, g_proj


_gather_project.defvjp(_gather_project_fwd, _gather_project_bwd)


def gather_project(back, idx, kept, proj, fused: Optional[bool] = None):
    """Narrow-row stitch for hot/cold heterogeneous placement: gather
    ``[d]``-narrow rows out of the routed-back buffer and project them up
    through the learned per-group ``[d, D]`` map in one fused pass —
    ``(wide [n, D], narrow [n, d])``, with not-kept positions exact zeros in
    both. A ``jax.custom_vjp``: the backward folds the wide cotangent
    through ``proj^T`` and run-accumulates onto the buffer slots (no
    ``[n, d]``-then-``[n, D]`` chain in either direction), and the
    projection's gradient is one ``narrow^T @ g_wide`` matmul off the
    forward's residual."""
    return _gather_project(back, idx, kept, proj, _fused(fused))


def gather_project_grad(g_wide, g_narrow, idx, kept, proj, m: int,
                        fused: Optional[bool] = None):
    """Transpose of ``gather_project`` w.r.t. the routed buffer, standalone
    (the engine's explicit backward path): ``g_back[j] = sum_{idx[i]=j}
    kept[i] * (g_wide[i] @ proj^T + g_narrow[i])``."""
    return _gather_project_grad_impl(g_wide, g_narrow, idx, kept, proj,
                                     int(m), _fused(fused))


# ---------------------------------------------------------------------------
# routed-gradient wire compression (grad_compress modes; the collective
# wrappers live in repro.optim.grad_compression)
# ---------------------------------------------------------------------------


def compress_fp16(g, fused: Optional[bool] = None):
    """Per-row amax scale + float16 cast: ``(q [m, D] f16, scale [m, 1] f32)``.
    All-zero rows compress to exact zeros (padded bucket slots roundtrip
    bitwise)."""
    if _fused(fused):
        return fp16_compress_pallas(g, interpret=_interpret())
    return ref.fp16_compress_ref(g)


def decompress_fp16(q, scale, fused: Optional[bool] = None):
    if _fused(fused):
        return fp16_decompress_pallas(q, scale, interpret=_interpret())
    return ref.fp16_decompress_ref(q, scale)


def compress_topk(g, k: int, fused: Optional[bool] = None):
    """Per-row magnitude top-k sparsification: ``(vals [m, k], idx [m, k])``,
    descending magnitude, ties toward the lower index."""
    if _fused(fused):
        return topk_compress_pallas(g, int(k), interpret=_interpret())
    return ref.topk_compress_ref(g, int(k))


def decompress_topk(vals, idx, d: int, fused: Optional[bool] = None):
    if _fused(fused):
        return topk_decompress_pallas(vals, idx, int(d),
                                      interpret=_interpret())
    return ref.topk_decompress_ref(vals, idx, int(d))
