"""Pallas TPU kernel: DLRM pairwise dot interaction.

[B, F, D] -> [B, P], P = F(F-1)/2. Computes Z = X X^T on the MXU per batch
tile, then extracts the strict upper triangle with a 0/1 selection matmul
(gathers are hostile to the TPU vector unit; a [F*F, P] selection matrix is
MXU-friendly and fuses in VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _kernel(f_blk, sel_blk, o_blk):
    x = f_blk[...]                                           # [BB, F, D]
    z = jax.lax.dot_general(x, x, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)  # [BB, F, F]
    bb, f, _ = z.shape
    zf = z.reshape(bb, f * f).astype(f_blk.dtype)
    o_blk[...] = jnp.dot(zf, sel_blk[...], preferred_element_type=jnp.float32
                         ).astype(o_blk.dtype)


def _selection_matrix(f: int, dtype) -> np.ndarray:
    iu, ju = np.triu_indices(f, k=1)
    p = len(iu)
    sel = np.zeros((f * f, p), dtype)
    sel[iu * f + ju, np.arange(p)] = 1
    return sel


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def dot_interaction_pallas(fields: jnp.ndarray, block_b: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    b, f, d = fields.shape
    p = f * (f - 1) // 2
    bb = min(block_b, b)
    pad = (-b) % bb
    if pad:
        fields = jnp.pad(fields, ((0, pad), (0, 0), (0, 0)))
    sel = jnp.asarray(_selection_matrix(f, np.float32), fields.dtype)
    nb = fields.shape[0] // bb
    out = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bb, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((f * f, p), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((fields.shape[0], p), fields.dtype),
        interpret=interpret,
    )(fields, sel)
    return out[:b]
