"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def embedding_bag_ref(table: jnp.ndarray, ids: jnp.ndarray, seg: jnp.ndarray,
                      n_bags: int, weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    return jax.ops.segment_sum(rows, seg, num_segments=n_bags)


def gather_pool_ref(rows_u: jnp.ndarray, inv: jnp.ndarray, weights: jnp.ndarray,
                    seg: jnp.ndarray, n_bags: int) -> jnp.ndarray:
    """Unfused SegmentReduction: materializes the [n, D] per-id intermediate."""
    per_id = jnp.take(rows_u, inv, axis=0) * weights[:, None].astype(rows_u.dtype)
    return jax.ops.segment_sum(per_id, seg, num_segments=n_bags)


def segment_grad_ref(g_bags: jnp.ndarray, seg: jnp.ndarray, weights: jnp.ndarray,
                     inv: jnp.ndarray, n_rows: int) -> jnp.ndarray:
    """Transpose of ``gather_pool_ref``: per-position bag-grad gather scaled
    by the pooling weight, scattered back onto the unique-row slots."""
    per_id = jnp.take(g_bags, seg, axis=0) * weights[:, None].astype(g_bags.dtype)
    return jax.ops.segment_sum(per_id, inv, num_segments=n_rows)


def dedup_adagrad_ref(w: jnp.ndarray, acc: jnp.ndarray, idx: jnp.ndarray,
                      g: jnp.ndarray, valid: jnp.ndarray, lr: float,
                      eps: float):
    """Sum duplicate row grads, then row-wise adagrad on touched rows only
    (the original ``packed_embedding._dedup_apply`` chain)."""
    rows = w.shape[0]
    m = idx.shape[0]
    idx = jnp.where(valid, idx, rows).astype(jnp.int32)
    order = jnp.argsort(idx)
    si, sg = idx[order], jnp.take(g, order, axis=0)
    first = jnp.concatenate([jnp.ones((1,), bool), si[1:] != si[:-1]])
    slot = (jnp.cumsum(first) - 1).astype(jnp.int32)
    uidx = jnp.full((m,), rows, jnp.int32).at[slot].set(si)
    gsum = jax.ops.segment_sum(sg, slot, num_segments=m)
    uclip = jnp.minimum(uidx, rows - 1)
    gsq = jnp.mean(jnp.square(gsum), axis=-1, keepdims=True)  # row-wise adagrad
    acc_new = jnp.take(acc, uclip, axis=0) + gsq
    upd = lr * gsum / jnp.sqrt(acc_new + eps)
    w = w.at[uidx].add(-upd.astype(w.dtype), mode="drop")
    acc = acc.at[uidx].set(acc_new.astype(acc.dtype), mode="drop")
    return w, acc


def tier_probe_ref(uniq: jnp.ndarray, uvalid: jnp.ndarray, keys: jnp.ndarray,
                   rows: jnp.ndarray):
    """searchsorted + take + where chain of ``cache_probe`` plus the hit-row
    gather; miss rows are exact zeros (the fused kernel's contract)."""
    p = jnp.searchsorted(keys, uniq).astype(jnp.int32)
    slot = jnp.clip(p, 0, keys.shape[0] - 1)
    hit = (keys[slot] == uniq) & uvalid
    out = jnp.where(hit[:, None], jnp.take(rows, slot, axis=0),
                    jnp.zeros((1, rows.shape[1]), rows.dtype))
    return hit, slot, out


def fm_interaction_ref(fields: jnp.ndarray) -> jnp.ndarray:
    """[B, F, D] -> [B, 1]: 0.5 * sum_d ((sum_f v)^2 - sum_f v^2)."""
    s = fields.sum(axis=1)
    ss = (fields * fields).sum(axis=1)
    return 0.5 * (s * s - ss).sum(axis=-1, keepdims=True)


def dot_interaction_ref(fields: jnp.ndarray) -> jnp.ndarray:
    """[B, F, D] -> [B, F*(F-1)/2] upper-triangle pairwise dots."""
    z = jnp.einsum("bfd,bgd->bfg", fields, fields)
    f = fields.shape[1]
    iu, ju = np.triu_indices(f, k=1)
    return z[:, iu, ju]


def cross_layer_ref(x0: jnp.ndarray, x: jnp.ndarray, w: jnp.ndarray,
                    b: jnp.ndarray) -> jnp.ndarray:
    """DCN-v2: x0 * (x @ w + b) + x."""
    return x0 * (x @ w + b) + x
