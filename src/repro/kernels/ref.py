"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def embedding_bag_ref(table: jnp.ndarray, ids: jnp.ndarray, seg: jnp.ndarray,
                      n_bags: int, weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    return jax.ops.segment_sum(rows, seg, num_segments=n_bags)


def fm_interaction_ref(fields: jnp.ndarray) -> jnp.ndarray:
    """[B, F, D] -> [B, 1]: 0.5 * sum_d ((sum_f v)^2 - sum_f v^2)."""
    s = fields.sum(axis=1)
    ss = (fields * fields).sum(axis=1)
    return 0.5 * (s * s - ss).sum(axis=-1, keepdims=True)


def dot_interaction_ref(fields: jnp.ndarray) -> jnp.ndarray:
    """[B, F, D] -> [B, F*(F-1)/2] upper-triangle pairwise dots."""
    z = jnp.einsum("bfd,bgd->bfg", fields, fields)
    f = fields.shape[1]
    iu, ju = np.triu_indices(f, k=1)
    return z[:, iu, ju]


def cross_layer_ref(x0: jnp.ndarray, x: jnp.ndarray, w: jnp.ndarray,
                    b: jnp.ndarray) -> jnp.ndarray:
    """DCN-v2: x0 * (x @ w + b) + x."""
    return x0 * (x @ w + b) + x
