"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def embedding_bag_ref(table: jnp.ndarray, ids: jnp.ndarray, seg: jnp.ndarray,
                      n_bags: int, weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    rows = jnp.take(table, ids, axis=0)
    if weights is not None:
        rows = rows * weights[:, None].astype(rows.dtype)
    return jax.ops.segment_sum(rows, seg, num_segments=n_bags)


def gather_pool_ref(rows_u: jnp.ndarray, inv: jnp.ndarray, weights: jnp.ndarray,
                    seg: jnp.ndarray, n_bags: int) -> jnp.ndarray:
    """Unfused SegmentReduction: materializes the [n, D] per-id intermediate."""
    per_id = jnp.take(rows_u, inv, axis=0) * weights[:, None].astype(rows_u.dtype)
    return jax.ops.segment_sum(per_id, seg, num_segments=n_bags)


def segment_grad_ref(g_bags: jnp.ndarray, seg: jnp.ndarray, weights: jnp.ndarray,
                     inv: jnp.ndarray, n_rows: int) -> jnp.ndarray:
    """Transpose of ``gather_pool_ref``: per-position bag-grad gather scaled
    by the pooling weight, scattered back onto the unique-row slots."""
    per_id = jnp.take(g_bags, seg, axis=0) * weights[:, None].astype(g_bags.dtype)
    return jax.ops.segment_sum(per_id, inv, num_segments=n_rows)


def dedup_adagrad_ref(w: jnp.ndarray, acc: jnp.ndarray, idx: jnp.ndarray,
                      g: jnp.ndarray, valid: jnp.ndarray, lr: float,
                      eps: float):
    """Sum duplicate row grads, then row-wise adagrad on touched rows only
    (the original ``packed_embedding._dedup_apply`` chain)."""
    rows = w.shape[0]
    m = idx.shape[0]
    idx = jnp.where(valid, idx, rows).astype(jnp.int32)
    order = jnp.argsort(idx)
    si, sg = idx[order], jnp.take(g, order, axis=0)
    first = jnp.concatenate([jnp.ones((1,), bool), si[1:] != si[:-1]])
    slot = (jnp.cumsum(first) - 1).astype(jnp.int32)
    uidx = jnp.full((m,), rows, jnp.int32).at[slot].set(si)
    gsum = jax.ops.segment_sum(sg, slot, num_segments=m)
    uclip = jnp.minimum(uidx, rows - 1)
    gsq = jnp.mean(jnp.square(gsum), axis=-1, keepdims=True)  # row-wise adagrad
    acc_new = jnp.take(acc, uclip, axis=0) + gsq
    upd = lr * gsum / jnp.sqrt(acc_new + eps)
    w = w.at[uidx].add(-upd.astype(w.dtype), mode="drop")
    acc = acc.at[uidx].set(acc_new.astype(acc.dtype), mode="drop")
    return w, acc


def tier_probe_ref(uniq: jnp.ndarray, uvalid: jnp.ndarray, keys: jnp.ndarray,
                   rows: jnp.ndarray):
    """searchsorted + take + where chain of ``cache_probe`` plus the hit-row
    gather; miss rows are exact zeros (the fused kernel's contract)."""
    p = jnp.searchsorted(keys, uniq).astype(jnp.int32)
    slot = jnp.clip(p, 0, keys.shape[0] - 1)
    hit = (keys[slot] == uniq) & uvalid
    out = jnp.where(hit[:, None], jnp.take(rows, slot, axis=0),
                    jnp.zeros((1, rows.shape[1]), rows.dtype))
    return hit, slot, out


def gather_project_ref(back: jnp.ndarray, idx: jnp.ndarray, kept: jnp.ndarray,
                       proj: jnp.ndarray):
    """Unfused narrow-row stitch: gather ``[n, d]`` narrow rows out of the
    routed-back buffer, mask the not-kept (padded / served-above) positions,
    and project up through the learned ``[d, D]`` map. Returns ``(wide
    [n, D], narrow [n, d])`` — the narrow rows are the VJP residual for the
    projection gradient (``g_proj = narrow^T @ g_wide``)."""
    narrow = jnp.take(back, idx, axis=0) * kept[:, None].astype(back.dtype)
    return narrow @ proj, narrow


def gather_project_grad_ref(g_wide: jnp.ndarray, g_narrow: jnp.ndarray,
                            idx: jnp.ndarray, kept: jnp.ndarray,
                            proj: jnp.ndarray, m: int) -> jnp.ndarray:
    """Transpose of ``gather_project_ref`` w.r.t. ``back``: fold the wide
    cotangent back through ``proj`` and scatter-sum onto the routed-buffer
    slots. ``g_narrow`` is the cotangent of the narrow residual output."""
    per = (g_wide @ proj.T + g_narrow) * kept[:, None].astype(g_wide.dtype)
    return jax.ops.segment_sum(per, idx.astype(jnp.int32), num_segments=m)


def fm_interaction_ref(fields: jnp.ndarray) -> jnp.ndarray:
    """[B, F, D] -> [B, 1]: 0.5 * sum_d ((sum_f v)^2 - sum_f v^2)."""
    s = fields.sum(axis=1)
    ss = (fields * fields).sum(axis=1)
    return 0.5 * (s * s - ss).sum(axis=-1, keepdims=True)


def dot_interaction_ref(fields: jnp.ndarray) -> jnp.ndarray:
    """[B, F, D] -> [B, F*(F-1)/2] upper-triangle pairwise dots."""
    z = jnp.einsum("bfd,bgd->bfg", fields, fields)
    f = fields.shape[1]
    iu, ju = np.triu_indices(f, k=1)
    return z[:, iu, ju]


def cross_layer_ref(x0: jnp.ndarray, x: jnp.ndarray, w: jnp.ndarray,
                    b: jnp.ndarray) -> jnp.ndarray:
    """DCN-v2: x0 * (x @ w + b) + x."""
    return x0 * (x @ w + b) + x


# ---------------------------------------------------------------------------
# interaction backwards (explicit transposes of the three refs above; equal
# to jax.vjp of the references — the unit tests pin that equality)
# ---------------------------------------------------------------------------


def fm_interaction_bwd_ref(fields: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """d/dfields of ``fm_interaction_ref``: ``g[b] * (sum_f v - v)``."""
    s = fields.sum(axis=1, keepdims=True)              # [B, 1, D]
    return g[:, :, None] * (s - fields)                # g: [B, 1]


def dot_interaction_bwd_ref(fields: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """d/dfields of ``dot_interaction_ref``: scatter the upper-triangle
    cotangent into gZ and apply ``(gZ + gZ^T) @ x``."""
    b, f, _ = fields.shape
    iu, ju = np.triu_indices(f, k=1)
    gz = jnp.zeros((b, f, f), g.dtype).at[:, iu, ju].set(g)
    gz = gz + jnp.transpose(gz, (0, 2, 1))
    return jnp.einsum("bfg,bgd->bfd", gz, fields)


def cross_layer_bwd_ref(x0: jnp.ndarray, x: jnp.ndarray, w: jnp.ndarray,
                        b: jnp.ndarray, g: jnp.ndarray):
    """d/d(x0, x, w, b) of ``cross_layer_ref`` (recomputes z = x@w + b)."""
    z = x @ w + b
    gz = g * x0
    gx0 = g * z
    gx = gz @ w.T + g
    gw = x.T @ gz
    gb = gz.sum(axis=0)
    return gx0, gx, gw, gb


# ---------------------------------------------------------------------------
# routed-gradient wire compression (grad_compress modes; see
# repro.optim.grad_compression for the collective wrappers)
# ---------------------------------------------------------------------------


def fp16_compress_ref(g: jnp.ndarray):
    """Per-row amax scaling + cast: ``(q float16 in [-1, 1], scale float32)``.

    All-zero rows compress to exact zeros (scale 0), so padded / invalid
    bucket slots survive the roundtrip bitwise.
    """
    scale = jnp.max(jnp.abs(g), axis=-1, keepdims=True).astype(jnp.float32)
    q = (g / jnp.maximum(scale, 1e-30)).astype(jnp.float16)
    return q, scale


def fp16_decompress_ref(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def topk_compress_ref(g: jnp.ndarray, k: int):
    """Keep the k largest-magnitude entries per row: ``(vals, idx int32)``.

    Ties break toward the lower index (``lax.top_k`` order — the Pallas
    kernel's iterative first-argmax matches it).
    """
    _, idx = jax.lax.top_k(jnp.abs(g), k)
    vals = jnp.take_along_axis(g, idx, axis=-1)
    return vals, idx.astype(jnp.int32)


def topk_decompress_ref(vals: jnp.ndarray, idx: jnp.ndarray, d: int) -> jnp.ndarray:
    m = vals.shape[0]
    out = jnp.zeros((m, d), vals.dtype)
    return out.at[jnp.arange(m)[:, None], idx].set(vals)
