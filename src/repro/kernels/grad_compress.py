"""Pallas TPU kernels: routed sparse-gradient wire compression.

The transposed Shuffle sends ``[world*cap, D]`` gradient rows over ICI every
step; these kernels shrink that payload before the ``all_to_all`` and expand
it after (see ``repro.optim.grad_compression.compress_rows``):

``fp16``  — per-row amax scaling + float16 cast (Tensor Casting style): one
            VMEM pass computes the row scale and the scaled cast together, so
            the fp32 payload never round-trips HBM next to its quantized
            copy. Wire bytes: 2/4 of fp32 (+1 fp32 scale per row).
``topk``  — per-row magnitude top-k sparsification: k iterative first-argmax
            selections per row block (k is static and small, the loop is
            unrolled), emitting ``(vals, idx)``; decompress scatters them
            back into a zero row. Wire bytes: ~2k/D of fp32.

Rows that are exactly zero (padded / dropped bucket slots) compress to exact
zeros under both modes, so invalid slots survive the roundtrip bitwise —
the dedup+adagrad scatter behind the all_to_all relies on that.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


# ---------------------------------------------------------------- fp16 pair
def _fp16_c_kernel(g_ref, q_ref, s_ref):
    g = g_ref[...]
    s = jnp.max(jnp.abs(g), axis=-1, keepdims=True).astype(jnp.float32)
    q_ref[...] = (g / jnp.maximum(s, 1e-30)).astype(jnp.float16)
    s_ref[...] = s


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def fp16_compress_pallas(g: jnp.ndarray, block_m: int = 256,
                         interpret: bool = False):
    m, d = g.shape
    bm = min(block_m, m)
    pad = (-m) % bm
    if pad:
        g = jnp.pad(g, ((0, pad), (0, 0)))
    nm = g.shape[0] // bm
    q, s = pl.pallas_call(
        _fp16_c_kernel,
        grid=(nm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((g.shape[0], d), jnp.float16),
                   jax.ShapeDtypeStruct((g.shape[0], 1), jnp.float32)],
        interpret=interpret,
    )(g)
    return q[:m], s[:m]


def _fp16_d_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def fp16_decompress_pallas(q: jnp.ndarray, scale: jnp.ndarray,
                           block_m: int = 256, interpret: bool = False):
    m, d = q.shape
    bm = min(block_m, m)
    pad = (-m) % bm
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        scale = jnp.pad(scale, ((0, pad), (0, 0)))
    nm = q.shape[0] // bm
    out = pl.pallas_call(
        _fp16_d_kernel,
        grid=(nm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((q.shape[0], d), jnp.float32),
        interpret=interpret,
    )(q, scale)
    return out[:m]


# ---------------------------------------------------------------- topk pair
def _topk_c_kernel(g_ref, v_ref, i_ref, *, k: int):
    g = g_ref[...]                                    # [BM, D]
    bm, d = g.shape
    mag = jnp.abs(g)
    iota = lax.broadcasted_iota(jnp.int32, (bm, d), 1)
    active = jnp.ones((bm, d), jnp.bool_)
    vals, idxs = [], []
    for _ in range(k):  # k is static and small: unrolled selection loop
        a = jnp.where(active, mag, -1.0)
        mx = jnp.max(a, axis=-1, keepdims=True)
        # first position achieving the max (lax.top_k tie-break order)
        idx_j = jnp.min(jnp.where(a == mx, iota, d), axis=-1)
        sel = iota == idx_j[:, None]
        vals.append(jnp.sum(jnp.where(sel, g, 0.0), axis=-1))
        idxs.append(idx_j)
        active = active & ~sel
    v_ref[...] = jnp.stack(vals, axis=-1)
    i_ref[...] = jnp.stack(idxs, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "block_m", "interpret"))
def topk_compress_pallas(g: jnp.ndarray, k: int, block_m: int = 256,
                         interpret: bool = False):
    m, d = g.shape
    assert 0 < k <= d, (k, d)
    bm = min(block_m, m)
    pad = (-m) % bm
    if pad:
        g = jnp.pad(g, ((0, pad), (0, 0)))
    nm = g.shape[0] // bm
    v, i = pl.pallas_call(
        functools.partial(_topk_c_kernel, k=k),
        grid=(nm,),
        in_specs=[pl.BlockSpec((bm, d), lambda b: (b, 0))],
        out_specs=[pl.BlockSpec((bm, k), lambda b: (b, 0)),
                   pl.BlockSpec((bm, k), lambda b: (b, 0))],
        out_shape=[jax.ShapeDtypeStruct((g.shape[0], k), g.dtype),
                   jax.ShapeDtypeStruct((g.shape[0], k), jnp.int32)],
        interpret=interpret,
    )(g)
    return v[:m], i[:m]


def _topk_d_kernel(v_ref, i_ref, o_ref, *, d: int):
    v = v_ref[...]                                    # [BM, k]
    ix = i_ref[...]
    bm, k = v.shape
    iota = lax.broadcasted_iota(jnp.int32, (bm, d), 1)
    out = jnp.zeros((bm, d), o_ref.dtype)
    for j in range(k):  # static unrolled scatter-by-select
        out = out + jnp.where(iota == ix[:, j][:, None],
                              v[:, j][:, None].astype(out.dtype), 0.0)
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("d", "block_m", "interpret"))
def topk_decompress_pallas(vals: jnp.ndarray, idx: jnp.ndarray, d: int,
                           block_m: int = 256, interpret: bool = False):
    m, k = vals.shape
    bm = min(block_m, m)
    pad = (-m) % bm
    if pad:
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
    nm = vals.shape[0] // bm
    out = pl.pallas_call(
        functools.partial(_topk_d_kernel, d=d),
        grid=(nm,),
        in_specs=[pl.BlockSpec((bm, k), lambda b: (b, 0)),
                  pl.BlockSpec((bm, k), lambda b: (b, 0))],
        out_specs=pl.BlockSpec((bm, d), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((vals.shape[0], d), vals.dtype),
        interpret=interpret,
    )(vals, idx)
    return out[:m]
