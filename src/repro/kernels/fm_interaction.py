"""Pallas TPU kernel: FM second-order interaction.

[B, F, D] -> [B, 1]  via  0.5 * sum_d((sum_f v)^2 - sum_f (v^2)).
Fused reduce over (F, D) per batch tile — one VMEM pass, no [B,D]
intermediates in HBM (the un-fused HLO materializes both sums).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(f_blk, o_blk):
    x = f_blk[...]                       # [BB, F, D]
    s = jnp.sum(x, axis=1)               # [BB, D]
    ss = jnp.sum(x * x, axis=1)          # [BB, D]
    o_blk[...] = 0.5 * jnp.sum(s * s - ss, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fm_interaction_pallas(fields: jnp.ndarray, block_b: int = 128,
                          interpret: bool = False) -> jnp.ndarray:
    b, f, d = fields.shape
    bb = min(block_b, b)
    # pad batch to a multiple of the block
    pad = (-b) % bb
    if pad:
        fields = jnp.pad(fields, ((0, pad), (0, 0), (0, 0)))
    nb = fields.shape[0] // bb
    out = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((bb, f, d), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((fields.shape[0], 1), fields.dtype),
        interpret=interpret,
    )(fields)
    return out[:b]
