"""Pallas TPU kernel: DCN-v2 cross layer  x0 * (x @ W + b) + x.

Fuses the matmul (MXU) with the elementwise epilogue (VPU) so the [B, d]
intermediate never round-trips HBM. Grid tiles (batch x out-dim); the x tile
is the full row (needed for the contraction), W is tiled along columns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x0_blk, x_blk, w_blk, b_blk, o_blk):
    acc = jnp.dot(x_blk[...], w_blk[...], preferred_element_type=jnp.float32)
    z = acc + b_blk[...]
    o_blk[...] = (x0_blk[...] * z.astype(x0_blk.dtype)
                  + _slice_cols(x_blk[...], x0_blk.shape[1], o_blk))


def _slice_cols(x, width, o_blk):
    # residual term: the columns of x matching this output tile
    j = pl.program_id(1)
    return jax.lax.dynamic_slice_in_dim(x, j * width, width, axis=1)


@functools.partial(jax.jit, static_argnames=("block_b", "block_d", "interpret"))
def cross_layer_pallas(x0: jnp.ndarray, x: jnp.ndarray, w: jnp.ndarray,
                       b: jnp.ndarray, block_b: int = 128, block_d: int = 128,
                       interpret: bool = False) -> jnp.ndarray:
    bsz, d = x.shape
    bb, bd = min(block_b, bsz), min(block_d, d)
    pad_b, pad_d = (-bsz) % bb, (-d) % bd
    if pad_b or pad_d:
        x0 = jnp.pad(x0, ((0, pad_b), (0, pad_d)))
        x = jnp.pad(x, ((0, pad_b), (0, pad_d)))
        w = jnp.pad(w, ((0, pad_d), (0, pad_d)))
        b = jnp.pad(b, ((0, pad_d),))
    bp, dp = x.shape
    b2 = b.reshape(1, dp)
    out = pl.pallas_call(
        _kernel,
        grid=(bp // bb, dp // bd),
        in_specs=[
            pl.BlockSpec((bb, bd), lambda i, j: (i, j)),   # x0 tile
            pl.BlockSpec((bb, dp), lambda i, j: (i, 0)),   # x full row
            pl.BlockSpec((dp, bd), lambda i, j: (0, j)),   # W column tile
            pl.BlockSpec((1, bd), lambda i, j: (0, j)),    # bias tile
        ],
        out_specs=pl.BlockSpec((bb, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, dp), x.dtype),
        interpret=interpret,
    )(x0, x, w, b2)
    return out[:bsz, :d]
