"""Pallas TPU kernels: fused backwards for the dense interaction ops.

The forward kernels (``fm_interaction`` / ``dot_interaction`` /
``cross_layer``) used to fall back to ``jax.vjp`` of the jnp reference on the
backward pass — fine on CPU, but on the Pallas branch it re-materializes the
very HBM intermediates the forward fused away and leaves the dense stage
behind the now-overlapped sparse stage. Each backward here is one fused pass
per batch tile, mirroring its forward's grid:

``fm``    — ``g[b] * (sum_f v - v)``: one reduce + one FMA per tile.
``dot``   — cotangent scatter as an MXU matmul against the transposed 0/1
            selection matrix (the same gather-free trick as the forward),
            then ``(gZ + gZ^T) @ x`` batched on the MXU.
``cross`` — recomputes ``z = x @ W + b`` in VMEM (cheaper than storing it),
            then emits all four cotangents; the weight/bias grads are
            accumulated across batch tiles in the output block (the TPU grid
            is sequential, so revisiting the same block is the canonical
            reduction pattern).

Zero-padded batch rows contribute exactly zero to every cotangent, so the
wrappers only pad/unpad the batch dimension like their forwards do.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl


# ------------------------------------------------------------------- FM bwd
def _fm_bwd_kernel(f_blk, g_blk, o_blk):
    x = f_blk[...]                                    # [BB, F, D]
    g = g_blk[...]                                    # [BB, 1]
    s = jnp.sum(x, axis=1, keepdims=True)             # [BB, 1, D]
    o_blk[...] = g[:, :, None] * (s - x)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def fm_interaction_bwd_pallas(fields: jnp.ndarray, g: jnp.ndarray,
                              block_b: int = 128,
                              interpret: bool = False) -> jnp.ndarray:
    b, f, d = fields.shape
    bb = min(block_b, b)
    pad = (-b) % bb
    if pad:
        fields = jnp.pad(fields, ((0, pad), (0, 0), (0, 0)))
        g = jnp.pad(g, ((0, pad), (0, 0)))
    nb = fields.shape[0] // bb
    out = pl.pallas_call(
        _fm_bwd_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((bb, f, d), lambda i: (i, 0, 0)),
                  pl.BlockSpec((bb, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, f, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(fields.shape, fields.dtype),
        interpret=interpret,
    )(fields, g)
    return out[:b]


# ------------------------------------------------------------------ dot bwd
def _dot_bwd_kernel(f_blk, g_blk, selT_blk, o_blk):
    x = f_blk[...]                                    # [BB, F, D]
    g = g_blk[...]                                    # [BB, P]
    bb, f, _ = x.shape
    gz = jnp.dot(g, selT_blk[...],
                 preferred_element_type=jnp.float32)  # [BB, F*F]
    gz = gz.reshape(bb, f, f)
    gz = gz + jnp.transpose(gz, (0, 2, 1))
    o_blk[...] = lax.dot_general(
        gz.astype(x.dtype), x, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32).astype(o_blk.dtype)


def _selection_matrix_t(f: int, dtype) -> np.ndarray:
    # transpose of the forward's [F*F, P] triangle-selection matrix
    iu, ju = np.triu_indices(f, k=1)
    p = len(iu)
    sel = np.zeros((p, f * f), dtype)
    sel[np.arange(p), iu * f + ju] = 1
    return sel


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def dot_interaction_bwd_pallas(fields: jnp.ndarray, g: jnp.ndarray,
                               block_b: int = 128,
                               interpret: bool = False) -> jnp.ndarray:
    b, f, d = fields.shape
    p = f * (f - 1) // 2
    bb = min(block_b, b)
    pad = (-b) % bb
    if pad:
        fields = jnp.pad(fields, ((0, pad), (0, 0), (0, 0)))
        g = jnp.pad(g, ((0, pad), (0, 0)))
    selt = jnp.asarray(_selection_matrix_t(f, np.float32), fields.dtype)
    nb = fields.shape[0] // bb
    out = pl.pallas_call(
        _dot_bwd_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bb, f, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, p), lambda i: (i, 0)),
            pl.BlockSpec((p, f * f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, f, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(fields.shape, fields.dtype),
        interpret=interpret,
    )(fields, g, selt)
    return out[:b]


# ---------------------------------------------------------------- cross bwd
def _cross_bwd_kernel(x0_blk, x_blk, w_blk, b_blk, g_blk,
                      gx0_blk, gx_blk, gw_blk, gb_blk):
    i = pl.program_id(0)
    x0 = x0_blk[...]                                  # [BB, d]
    x = x_blk[...]
    w = w_blk[...]                                    # [d, d]
    g = g_blk[...]
    z = jnp.dot(x, w, preferred_element_type=jnp.float32) + b_blk[...]
    gz = g * x0                                       # [BB, d]
    gx0_blk[...] = g * z.astype(g.dtype)
    gx_blk[...] = lax.dot_general(
        gz, w, (((1,), (1,)), ((), ())),              # gz @ w^T
        preferred_element_type=jnp.float32).astype(g.dtype) + g
    gw_c = lax.dot_general(
        x, gz, (((0,), (0,)), ((), ())),              # x^T @ gz
        preferred_element_type=jnp.float32)
    gb_c = jnp.sum(gz, axis=0, keepdims=True)

    @pl.when(i == 0)
    def _init():
        gw_blk[...] = gw_c.astype(gw_blk.dtype)
        gb_blk[...] = gb_c.astype(gb_blk.dtype)

    @pl.when(i > 0)
    def _accum():
        gw_blk[...] += gw_c.astype(gw_blk.dtype)
        gb_blk[...] += gb_c.astype(gb_blk.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def cross_layer_bwd_pallas(x0: jnp.ndarray, x: jnp.ndarray, w: jnp.ndarray,
                           b: jnp.ndarray, g: jnp.ndarray,
                           block_b: int = 128, interpret: bool = False):
    bsz, d = x.shape
    bb = min(block_b, bsz)
    pad = (-bsz) % bb
    if pad:
        x0 = jnp.pad(x0, ((0, pad), (0, 0)))
        x = jnp.pad(x, ((0, pad), (0, 0)))
        g = jnp.pad(g, ((0, pad), (0, 0)))
    bp = x.shape[0]
    b2 = b.reshape(1, d)
    gx0, gx, gw, gb = pl.pallas_call(
        _cross_bwd_kernel,
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),   # x0 tile
            pl.BlockSpec((bb, d), lambda i: (i, 0)),   # x tile
            pl.BlockSpec((d, d), lambda i: (0, 0)),    # full W
            pl.BlockSpec((1, d), lambda i: (0, 0)),    # bias
            pl.BlockSpec((bb, d), lambda i: (i, 0)),   # cotangent tile
        ],
        out_specs=[
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((bb, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),    # accumulated over grid
            pl.BlockSpec((1, d), lambda i: (0, 0)),    # accumulated over grid
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, d), x0.dtype),
            jax.ShapeDtypeStruct((bp, d), x.dtype),
            jax.ShapeDtypeStruct((d, d), w.dtype),
            jax.ShapeDtypeStruct((1, d), b.dtype),
        ],
        interpret=interpret,
    )(x0, x, w, b2, g)
    return gx0[:bsz], gx[:bsz], gw, gb.reshape(b.shape)
