"""Pallas TPU kernels for the fused sparse hot path (paper §III profiling).

PICASSO attributes the embedding layer's cost to fragmentary, memory-bound
gather / segment-reduce / scatter ops; HugeCTR and Tensor Casting both ship
the gather-scatter pair as dedicated fused kernels. These are those kernels
for the repro's hot path — each one replaces a take/segment_sum/argsort/
scatter chain in ``repro.core.packed_embedding`` with a single pass that
never materializes the ``[n, D]`` per-id intermediate:

``gather_pool_pallas``
    Forward SegmentReduction ``bags[seg[i]] += w[i] * rows_u[inv[i]]``: the
    ``embedding_bag`` kernel generalized to take an *indirection vector*
    (``inv`` from the fixed-shape unique) instead of raw table ids. One grid
    step per position; the scalar-prefetched ``inv`` drives the row
    BlockSpec (HBM->VMEM DMA of exactly the needed unique row), ``seg``
    drives the output index_map, so each bag block stays in VMEM while its
    (sorted) segment lasts and is flushed exactly once.

``segment_grad_pallas``
    The transpose: ``g_rows[u] = sum_{i: inv[i]=u} w[i] * g_bags[seg[i]]``.
    ``inv`` is *not* sorted, so positions are stably pre-sorted by slot and
    ``n_rows`` zero-weight ghost positions (one per output slot) are merged
    in — every output block is visited at least once, so slots past
    ``n_uniq`` come out exactly zero instead of holding garbage. Backward of
    ``gather_pool`` under ``jax.custom_vjp`` (see ``kernels.ops``), and the
    engine's explicit transposed path.

``dedup_adagrad_pallas``
    Fused dedup + row-wise adagrad + in-place scatter: replaces the
    argsort -> segment_sum -> ``.at[].add`` -> ``.at[].set`` chain of
    ``_dedup_apply``. Grid over sorted positions; duplicate row grads
    accumulate in a VMEM scratch across the run, and the run's *last* step
    applies adagrad and read-modify-writes the touched row through explicit
    HBM DMAs (the table is input_output_aliased, so the update is in-place
    and untouched rows are never copied — they stay bitwise identical). The
    duplicate-accumulation order matches the reference ``segment_sum``
    (stable sort, run-sequential adds), so touched rows agree with
    ``_dedup_apply`` to XLA-fusion reassociation (~1 ULP on the final
    adagrad arithmetic).

``tier_probe_pallas``
    Fused cache-tier probe: sorted-key binary search (rank-by-count over the
    VMEM-resident key vector) + hit-masked row gather in one kernel, for the
    L1 hot tier and L2 host tier probes that ``mp_lookup`` otherwise
    assembles from searchsorted / take / where. Returns ``(hit, slot,
    rows)`` with miss rows exactly zero, so the caller's stitch is a single
    ``where``.

``gather_project_pallas`` / ``gather_project_grad_pallas``
    The narrow-row stitch of ``picasso_narrow``: gather a ``[d]`` narrow row
    from the routed-back buffer and project it up through the learned
    ``[d, D]`` map in one grid step (per-row DMA + a tiny MXU dot), so the
    ``[n, d]`` gather and the ``[n, D]`` projection never exist as separate
    memory-bound XLA ops. The backward folds the wide cotangent through
    ``proj^T`` and run-accumulates onto the routed-buffer slots (positions
    pre-sorted by slot, one zero ghost per slot so every output block is
    written) — again one pass, no per-id intermediate.

All kernels run in ``interpret=True`` on non-TPU backends (the dispatch in
``kernels.ops`` decides); the CI soak forces every call through the
interpreter against the pure-jnp references.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.embedding_bag import embedding_bag_pallas


# ---------------------------------------------------------------------------
# fused gather + pool (forward) and its transpose (backward)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_bags", "interpret"))
def gather_pool_pallas(
    rows_u: jnp.ndarray,    # [n, D] unique rows
    inv: jnp.ndarray,       # [n] indirection: position -> unique slot
    weights: jnp.ndarray,   # [n]
    seg: jnp.ndarray,       # [n] bag index, sorted ascending
    n_bags: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused ``bags[seg[i]] += w[i] * rows_u[inv[i]]`` without the ``[n, D]``
    per-id intermediate: the embedding-bag kernel with ``inv`` as the
    indirection vector (its ``ids`` argument was always an indirection — the
    unique step just makes that explicit). One zero-weight ghost position per
    bag is merged in, so a bag no position maps to comes out zero exactly
    like the reference ``segment_sum`` — never as an unwritten output block
    (the packed layout covers every bag, but this is a public helper and
    silent fused/reference divergence on uncovered bags is a trap)."""
    n = inv.shape[0]
    seg2 = jnp.concatenate([seg.astype(jnp.int32),
                            jnp.arange(n_bags, dtype=jnp.int32)])
    inv2 = jnp.concatenate([inv.astype(jnp.int32),
                            jnp.zeros((n_bags,), jnp.int32)])
    w2 = jnp.concatenate([weights.astype(rows_u.dtype),
                          jnp.zeros((n_bags,), rows_u.dtype)])
    order = jnp.argsort(seg2, stable=True)   # ghosts sort after real positions
    return embedding_bag_pallas(rows_u, jnp.take(inv2, order),
                                jnp.take(seg2, order), jnp.take(w2, order),
                                n_bags, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("n_rows", "interpret"))
def segment_grad_pallas(
    g_bags: jnp.ndarray,    # [n_bags, D] cotangent of the pooled output
    seg: jnp.ndarray,       # [n] bag index per position
    weights: jnp.ndarray,   # [n]
    inv: jnp.ndarray,       # [n] position -> unique slot (NOT sorted)
    n_rows: int,            # number of unique-row slots (== n, fixed shape)
    interpret: bool = False,
) -> jnp.ndarray:
    """Transpose of ``gather_pool``: ``g_rows[u] = sum_{inv[i]=u} w[i] *
    g_bags[seg[i]]`` as one bag-kernel pass over positions stably sorted by
    slot. ``n_rows`` zero-weight ghost positions (slot j, bag 0, weight 0)
    are merged in so every output slot is visited: slots that no real
    position maps to (``>= n_uniq``) come out exactly zero."""
    n = inv.shape[0]
    slots = jnp.concatenate([inv.astype(jnp.int32),
                             jnp.arange(n_rows, dtype=jnp.int32)])
    gat = jnp.concatenate([seg.astype(jnp.int32),
                           jnp.zeros((n_rows,), jnp.int32)])
    wts = jnp.concatenate([weights.astype(g_bags.dtype),
                           jnp.zeros((n_rows,), g_bags.dtype)])
    # stable: real positions keep their original (reference segment_sum)
    # accumulation order within a slot; ghosts sort after them and add 0
    order = jnp.argsort(slots, stable=True).astype(jnp.int32)
    return embedding_bag_pallas(g_bags, jnp.take(gat, order),
                                jnp.take(slots, order), jnp.take(wts, order),
                                n_rows, interpret=interpret)


# ---------------------------------------------------------------------------
# fused dedup + row-wise adagrad + in-place scatter
# ---------------------------------------------------------------------------


def _dedup_kernel(si_ref, g_blk, w_any, acc_any, w_out, acc_out,
                  gsum, row, accrow, sems, *, m, lr, eps, rows):
    i = pl.program_id(0)
    idx = si_ref[i]
    ok = idx < rows
    first = jnp.logical_or(i == 0, idx != si_ref[jnp.maximum(i - 1, 0)])
    last = jnp.logical_or(i == m - 1, idx != si_ref[jnp.minimum(i + 1, m - 1)])
    contrib = g_blk[...] * ok.astype(g_blk.dtype)

    @pl.when(first)
    def _init():
        gsum[...] = contrib

    @pl.when(jnp.logical_not(first))
    def _acc():
        gsum[...] += contrib

    # last step of a valid run: adagrad the accumulated grad into the row.
    # Explicit DMAs keep the update in-place and ordered (grid steps are
    # sequential, each step waits on its own copies) — the blocked-pipeline
    # idiom cannot express this safely because the sentinel run clamps onto
    # a possibly-live row. Reads come from the *input* refs (every row is
    # read at most once, before its own run writes it — runs are unique), as
    # interpret-mode reads of an aliased output ref are unreliable under
    # multi-device shard_map; writes go to the aliased outputs, so untouched
    # rows pass through in place.
    @pl.when(jnp.logical_and(last, ok))
    def _apply():
        rd_w = pltpu.make_async_copy(w_any.at[pl.ds(idx, 1)], row, sems.at[0])
        rd_w.start()
        rd_a = pltpu.make_async_copy(acc_any.at[pl.ds(idx, 1)], accrow,
                                     sems.at[1])
        rd_a.start()
        rd_w.wait()
        rd_a.wait()
        g = gsum[...]
        acc_new = accrow[...] + jnp.mean(jnp.square(g), axis=-1, keepdims=True)
        upd = lr * g / jnp.sqrt(acc_new + eps)
        row[...] = row[...] - upd.astype(row.dtype)
        accrow[...] = acc_new.astype(accrow.dtype)
        wr_w = pltpu.make_async_copy(row, w_out.at[pl.ds(idx, 1)], sems.at[0])
        wr_w.start()
        wr_a = pltpu.make_async_copy(accrow, acc_out.at[pl.ds(idx, 1)],
                                     sems.at[1])
        wr_a.start()
        wr_w.wait()
        wr_a.wait()


@functools.partial(jax.jit, static_argnames=("lr", "eps", "interpret"))
def dedup_adagrad_pallas(
    w: jnp.ndarray,       # [rows, D] table (shard or replicated tier)
    acc: jnp.ndarray,     # [rows, 1] adagrad accumulator
    idx: jnp.ndarray,     # [m] destination row per gradient
    g: jnp.ndarray,       # [m, D] row gradients (duplicates allowed)
    valid: jnp.ndarray,   # [m] mask; invalid grads are dropped
    lr: float,
    eps: float,
    interpret: bool = False,
):
    """One fused pass: run detection over pre-sorted indices, duplicate-grad
    accumulation in VMEM (reference order), row-wise adagrad, in-place
    scatter via ``input_output_aliases``. Untouched rows are bitwise
    untouched; touched rows match ``_dedup_apply`` to ~1 ULP."""
    rows, d = w.shape
    m = idx.shape[0]
    sidx = jnp.where(valid, idx, rows).astype(jnp.int32)
    order = jnp.argsort(sidx)                     # invalid sorts to the end
    si = jnp.take(sidx, order)
    # the sorted grads are materialized once up front ([m, D], same cost the
    # reference chain pays) and streamed through the block pipeline with the
    # identity index map: a prefetch-driven gather map (o[i]) combined with
    # ANY/aliased operands in one pallas_call mis-gathers on devices > 0
    # under multi-device shard_map in interpret mode (jax 0.4.37)
    sg = jnp.take(g, order, axis=0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,   # si
        grid=(m,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, si: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, d), g.dtype),
            pltpu.VMEM((1, d), w.dtype),
            pltpu.VMEM((1, 1), acc.dtype),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    kern = functools.partial(_dedup_kernel, m=m, lr=lr, eps=eps, rows=rows)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(w.shape, w.dtype),
                   jax.ShapeDtypeStruct(acc.shape, acc.dtype)],
        input_output_aliases={2: 0, 3: 1},   # w, acc updated in place
        interpret=interpret,
    )(si, sg, w, acc)


# ---------------------------------------------------------------------------
# fused cache-tier probe (binary search + hit-masked gather)
# ---------------------------------------------------------------------------


def _probe_kernel(uniq_ref, uvalid_ref, keys_blk, rows_any,
                  hit_out, slot_out, rows_out, rowbuf, sem, *, h):
    i = pl.program_id(0)
    u = uniq_ref[i]
    keys = keys_blk[0, :]
    # rank of u among the sorted keys == searchsorted(keys, u, side='left')
    slot = jnp.minimum(jnp.sum((keys < u).astype(jnp.int32)), h - 1)
    kv = jax.lax.dynamic_slice(keys, (slot,), (1,))[0]
    hit = jnp.logical_and(kv == u, uvalid_ref[i] != 0)
    hit_out[0, 0] = hit.astype(jnp.int32)
    slot_out[0, 0] = slot

    @pl.when(hit)
    def _gather():
        cp = pltpu.make_async_copy(rows_any.at[pl.ds(slot, 1)], rowbuf, sem)
        cp.start()
        cp.wait()
        rows_out[...] = rowbuf[...]

    @pl.when(jnp.logical_not(hit))
    def _zero():
        rows_out[...] = jnp.zeros_like(rows_out)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tier_probe_pallas(
    uniq: jnp.ndarray,    # [n] query ids (the fixed-shape unique set)
    uvalid: jnp.ndarray,  # [n] probe mask (slot validity & not-served-above)
    keys: jnp.ndarray,    # [H] sorted tier keys
    rows: jnp.ndarray,    # [H, D] tier rows (may live off-device)
    interpret: bool = False,
):
    """Fused probe of one cache tier: per query, binary search the sorted
    key vector (VMEM-resident) and DMA the hit row; misses produce exact
    zeros. Returns ``(hit [n] bool, slot [n] int32, rows [n, D])`` with
    ``slot`` clamped like ``cache_probe`` (backward reuses it)."""
    n = uniq.shape[0]
    h, d = rows.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,   # uniq, uvalid
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h), lambda i, u, v: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, u, v: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, u, v: (i, 0)),
            pl.BlockSpec((1, d), lambda i, u, v: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, d), rows.dtype),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    kern = functools.partial(_probe_kernel, h=h)
    hit, slot, out_rows = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n, 1), jnp.int32),
                   jax.ShapeDtypeStruct((n, 1), jnp.int32),
                   jax.ShapeDtypeStruct((n, d), rows.dtype)],
        interpret=interpret,
    )(uniq.astype(jnp.int32), uvalid.astype(jnp.int32),
      keys.reshape(1, h).astype(jnp.int32), rows)
    return hit[:, 0].astype(bool), slot[:, 0], out_rows


# ---------------------------------------------------------------------------
# fused narrow-row gather + up-projection (picasso_narrow's stitch) and its
# transpose
# ---------------------------------------------------------------------------


def _gproject_kernel(idx_ref, kept_ref, proj_blk, back_any,
                     wide_out, narrow_out, rowbuf, sem, *, m):
    i = pl.program_id(0)
    j = jnp.minimum(idx_ref[i], m - 1)
    ok = jnp.logical_and(kept_ref[i] != 0, idx_ref[i] < m)

    @pl.when(ok)
    def _hit():
        cp = pltpu.make_async_copy(back_any.at[pl.ds(j, 1)], rowbuf, sem)
        cp.start()
        cp.wait()
        narrow_out[...] = rowbuf[...]
        wide_out[...] = jax.lax.dot_general(
            rowbuf[...], proj_blk[...], (((1,), (0,)), ((), ())),
            preferred_element_type=wide_out.dtype)

    @pl.when(jnp.logical_not(ok))
    def _miss():
        narrow_out[...] = jnp.zeros_like(narrow_out)
        wide_out[...] = jnp.zeros_like(wide_out)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_project_pallas(
    back: jnp.ndarray,   # [m, d] routed-back narrow rows (may live off-device)
    idx: jnp.ndarray,    # [n] routed-buffer slot per position
    kept: jnp.ndarray,   # [n] mask: padded / served-above positions drop out
    proj: jnp.ndarray,   # [d, D] learned up-projection
    interpret: bool = False,
):
    """Fused narrow stitch: per position, DMA the ``[d]`` narrow row out of
    the routed buffer and push it through the VMEM-resident projection on
    the MXU — one grid step per position, no ``[n, d]``-then-``[n, D]``
    op chain. Returns ``(wide [n, D], narrow [n, d])``; not-kept positions
    are exact zeros in both outputs (the caller's where-merge contract, and
    what makes ``narrow`` directly usable as the projection-grad residual)."""
    m, nd = back.shape
    n = idx.shape[0]
    d = proj.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,   # idx, kept
        grid=(n,),
        in_specs=[
            pl.BlockSpec((nd, d), lambda i, ix, k: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i, ix, k: (i, 0)),
            pl.BlockSpec((1, nd), lambda i, ix, k: (i, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, nd), back.dtype),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    kern = functools.partial(_gproject_kernel, m=m)
    wide, narrow = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n, d), back.dtype),
                   jax.ShapeDtypeStruct((n, nd), back.dtype)],
        interpret=interpret,
    )(idx.astype(jnp.int32), kept.astype(jnp.int32), proj, back)
    return wide, narrow


def _gproject_bwd_kernel(si_ref, gw_blk, gn_blk, proj_blk, out_blk):
    i = pl.program_id(0)
    idx = si_ref[i]
    first = jnp.logical_or(i == 0, idx != si_ref[jnp.maximum(i - 1, 0)])
    contrib = jax.lax.dot_general(
        gw_blk[...], proj_blk[...], (((1,), (1,)), ((), ())),
        preferred_element_type=gn_blk.dtype) + gn_blk[...]

    @pl.when(first)
    def _init():
        out_blk[...] = contrib

    @pl.when(jnp.logical_not(first))
    def _acc():
        out_blk[...] += contrib


@functools.partial(jax.jit, static_argnames=("m", "interpret"))
def gather_project_grad_pallas(
    g_wide: jnp.ndarray,    # [n, D] cotangent of the projected rows
    g_narrow: jnp.ndarray,  # [n, d] cotangent of the narrow residual
    idx: jnp.ndarray,       # [n] routed-buffer slot per position
    kept: jnp.ndarray,      # [n] mask
    proj: jnp.ndarray,      # [d, D]
    m: int,                 # routed-buffer rows
    interpret: bool = False,
) -> jnp.ndarray:
    """Transpose of ``gather_project`` w.r.t. the routed buffer:
    ``g_back[j] = sum_{idx[i]=j} kept[i] * (g_wide[i] @ proj^T +
    g_narrow[i])`` — the fold through ``proj^T`` happens per grid step on
    the MXU and duplicate slots run-accumulate in the (sorted-slot) output
    block, so no ``[n, d]`` folded intermediate is materialized. One zero
    ghost position per output slot guarantees every block is written (slots
    nothing routes to come out exactly zero)."""
    n = idx.shape[0]
    nd, d = proj.shape
    keptf = kept.astype(g_wide.dtype)
    # not-kept positions contribute zero; ghosts (one per slot) likewise
    slots = jnp.concatenate([
        jnp.where(kept.astype(bool), idx.astype(jnp.int32), m - 1),
        jnp.arange(m, dtype=jnp.int32)])
    gw = jnp.concatenate([g_wide * keptf[:, None],
                          jnp.zeros((m, g_wide.shape[1]), g_wide.dtype)])
    gn = jnp.concatenate([g_narrow * keptf[:, None],
                          jnp.zeros((m, nd), g_narrow.dtype)])
    order = jnp.argsort(slots, stable=True).astype(jnp.int32)
    si = jnp.take(slots, order)
    sgw = jnp.take(gw, order, axis=0)
    sgn = jnp.take(gn, order, axis=0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,   # si
        grid=(n + m,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, si: (i, 0)),
            pl.BlockSpec((1, nd), lambda i, si: (i, 0)),
            pl.BlockSpec((nd, d), lambda i, si: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, nd), lambda i, si: (si[i], 0)),
    )
    return pl.pallas_call(
        _gproject_bwd_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, nd), g_wide.dtype),
        interpret=interpret,
    )(si, sgw, sgn, proj)
