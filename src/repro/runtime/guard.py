"""Numeric anomaly guard: reject poisoned steps before they become state.

PICASSO's continuous-delivery loop (paper §V) races the clock on 1000+
nodes; a silent-NaN step does not *raise* — it trains the model onto garbage
and then gets checkpointed as "good", costing hours of retrain walltime when
someone finally notices the loss curve. The guard closes that hole at the
step boundary:

1. **Detection** reads the step's own metrics on the host: a non-finite
   loss, a non-finite gradient norm, or a gradient norm above the spike
   threshold marks the step anomalous. This costs one host sync per step —
   the honesty price of detection, the same sync the calibrated-cost-model
   feedback loop already pays.
2. **Rejection** returns the *prior* state: the batch is consumed (skipped),
   training continues on the next one. This requires the wrapped step to be
   built WITHOUT buffer donation (``make_train_step(..., donate=False)``) so
   the prior state's buffers are still alive — the guard trades donation's
   peak-memory saving for the ability to reject. Because donation only
   affects aliasing, never values, a guarded run on clean data is **bitwise
   identical** to an unguarded one (pinned by tests/test_faults.py); the
   guard adds no wrapper jit and runs the exact same executable.
3. **Rollback** is the escalation: ``k_rollback`` *consecutive* rejections
   means the problem is not one bad batch (the state itself may be poisoned,
   or the input stream is down), so the guard raises ``AnomalyRollback`` and
   the ``Supervisor`` restores the last verified checkpoint and replays.

The spike threshold is an EMA over accepted steps' gradient norms
(``spike_factor`` x EMA); during ``warmup_steps`` only the NaN/Inf checks
are armed, so early-training norm swings never false-positive.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np


class AnomalyRollback(RuntimeError):
    """``k_rollback`` consecutive anomalous steps: the guard gives up on
    skip-and-continue and asks the supervisor for a checkpoint rollback.
    Classified transient by ``fault_tolerance.classify_failure``."""

    def __init__(self, msg: str, rejects: int = 0, state: Any = None):
        super().__init__(msg)
        self.rejects = rejects
        # the surviving (rejection-preserved) state rides on the exception,
        # so a supervisor with no checkpoint on disk can resume from it
        self.state = state


@dataclass(frozen=True)
class GuardConfig:
    """Static thresholds of the anomaly guard."""

    spike_factor: float = 10.0   # reject when grad_norm > factor * EMA
    ema_decay: float = 0.95      # EMA over accepted steps' grad norms
    warmup_steps: int = 10       # accepted steps before spike checks arm
    k_rollback: int = 3          # consecutive rejections -> AnomalyRollback
    metric: str = "grad_norm"    # metrics key carrying the norm (optional)


@dataclass
class GuardEvent:
    """One rejected step (kept in ``AnomalyGuard.events``)."""

    step: int            # accepted-step count when the rejection happened
    kind: str            # 'nonfinite' | 'spike'
    value: float         # the offending loss/grad-norm
    threshold: float     # the spike threshold in force (0 = not armed)
    consecutive: int     # consecutive rejections including this one

    def describe(self) -> str:
        return (f"guard: rejected step ({self.kind}: value={self.value:.4g}, "
                f"threshold={self.threshold:.4g}, "
                f"consecutive={self.consecutive})")


class AnomalyGuard:
    """Wrap a **non-donating** jitted ``step(state, batch) -> (state,
    metrics)`` with anomaly detection + rejection. Keeps the step signature,
    so it drops into ``Supervisor.run`` / ``run_stream`` / launcher loops
    unchanged; ``metrics["anomalous"]`` (0/1) is added for observability.

    The wrapped step MUST be built with ``donate=False``: rejection returns
    the input state, and a donating step would have freed those buffers.
    (On a rejected step the discarded new-state buffers are simply dropped.)

    ``rebind(step_fn)`` swaps the wrapped step (after a replan/reshard step
    rebuild) while keeping the EMA, counters, and event history — the
    numeric history of the run survives a plan revision.
    """

    def __init__(self, step_fn: Optional[Callable] = None,
                 cfg: GuardConfig = GuardConfig(),
                 log: Optional[Callable[[str], None]] = None):
        self.cfg = cfg
        self.log = log or (lambda s: None)
        self.ema: Optional[float] = None   # EMA of accepted grad norms
        self.accepted = 0                  # accepted steps (feeds warmup)
        self.rejected = 0                  # total rejections
        self.consecutive = 0               # current rejection streak
        self.events: List[GuardEvent] = []
        self._inner: Optional[Callable] = None
        if step_fn is not None:
            self.rebind(step_fn)

    def rebind(self, step_fn: Callable) -> "AnomalyGuard":
        """(Re)bind the wrapped step; EMA/counters/events carry over.
        Returns self (callable), so ``step = guard.rebind(make_step(...))``
        reads naturally at step-rebuild sites."""
        self._inner = step_fn
        return self

    @property
    def threshold(self) -> float:
        """Spike threshold currently in force (0 = disarmed)."""
        if self.ema is None or self.accepted < self.cfg.warmup_steps:
            return 0.0
        return self.cfg.spike_factor * self.ema

    def __call__(self, state, batch) -> Tuple[Any, Dict[str, Any]]:
        if self._inner is None:
            raise RuntimeError("AnomalyGuard has no step bound; call rebind()")
        new_state, metrics = self._inner(state, batch)
        thr = self.threshold
        loss = float(metrics["loss"])  # host sync: see module docstring
        gn_m = metrics.get(self.cfg.metric)
        gn = float(gn_m) if gn_m is not None else None
        nonfinite = not np.isfinite(loss) or (gn is not None
                                              and not np.isfinite(gn))
        spike = (not nonfinite and gn is not None and thr > 0 and gn > thr)
        if not (nonfinite or spike):
            self.consecutive = 0
            self.accepted += 1
            if gn is not None:
                d = self.cfg.ema_decay
                self.ema = gn if self.ema is None else d * self.ema + (1 - d) * gn
            return new_state, {**metrics, "anomalous": 0}
        # rejected: the new state is discarded, the prior one lives on
        if nonfinite:
            kind = "nonfinite"
            value = loss if not np.isfinite(loss) else gn
        else:
            kind, value = "spike", gn
        self.rejected += 1
        self.consecutive += 1
        ev = GuardEvent(step=self.accepted, kind=kind, value=value,
                        threshold=thr, consecutive=self.consecutive)
        self.events.append(ev)
        self.log(ev.describe())
        if self.consecutive >= self.cfg.k_rollback:
            streak, self.consecutive = self.consecutive, 0
            raise AnomalyRollback(
                f"guard: {streak} consecutive anomalous steps (last: {kind} "
                f"value={value:.4g}) — requesting checkpoint rollback",
                rejects=streak, state=state)
        return state, {**metrics, "anomalous": 1}
