"""Deterministic fault injection: prove the recovery paths, don't hope.

A fault-tolerance subsystem that has never seen a fault is a comment, not a
feature. ``FaultPlan`` schedules four fault species at exact step indices so
CI can drive the *entire* train→checkpoint→publish→serve pipeline through
its failure matrix and assert each recovery end-to-end:

- ``nan@i``   — batch ``i``'s labels/dense features become NaN (the guard
                must reject the step, keep state, continue);
- ``crash@i`` — a ``ChaosFailure`` raised before step ``i`` (the Supervisor
                must classify transient, restore a verified checkpoint, and
                rewind the stream);
- ``ckpt@i``  — the newest checkpoint written at/after step ``i`` gets a
                leaf file truncated on disk (restore must detect the
                checksum mismatch, quarantine, fall back);
- ``torn@i``  — the published delta at/after step ``i`` is torn mid-file
                (the serve poller must keep the last good state).

Every fault is **one-shot**: it fires once at its configured index and never
again, *including after a rollback replays the same index*. That models
transient corruption (a flipped batch, a dying node) rather than a
deterministic poison pill — and it is what makes the recovery contract
testable: a guarded run through a ``FaultPlan`` must converge to the exact
state of a clean run, because every injected fault is either rejected
(state untouched) or rolled back and replayed clean.

Spec syntax (``--chaos``): comma-separated ``kind@step`` tokens, e.g.
``"nan@7,nan@8,crash@13,ckpt@20,torn@45"``.
"""
from __future__ import annotations

import logging
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, FrozenSet, Iterator, Optional, Set

import numpy as np

log = logging.getLogger("repro.chaos")

_KINDS = ("nan", "crash", "ckpt", "torn")


class ChaosFailure(RuntimeError):
    """An injected crash; classified transient by the Supervisor."""


@dataclass(frozen=True)
class FaultPlan:
    """Step indices per fault species (empty plan = no-op)."""

    nan_batch: FrozenSet[int] = frozenset()
    crash: FrozenSet[int] = frozenset()
    corrupt_ckpt: FrozenSet[int] = frozenset()
    torn_publish: FrozenSet[int] = frozenset()

    def __bool__(self) -> bool:
        return bool(self.nan_batch or self.crash or self.corrupt_ckpt
                    or self.torn_publish)


def parse_fault_plan(spec: str) -> FaultPlan:
    """``"nan@7,crash@13,ckpt@20,torn@45"`` -> FaultPlan."""
    sets: Dict[str, Set[int]] = {k: set() for k in _KINDS}
    for tok in spec.split(","):
        tok = tok.strip()
        if not tok:
            continue
        try:
            kind, at = tok.split("@")
            sets[kind].add(int(at))
        except (ValueError, KeyError):
            raise ValueError(
                f"bad chaos token {tok!r}: want kind@step with kind in "
                f"{_KINDS}") from None
    return FaultPlan(nan_batch=frozenset(sets["nan"]),
                     crash=frozenset(sets["crash"]),
                     corrupt_ckpt=frozenset(sets["ckpt"]),
                     torn_publish=frozenset(sets["torn"]))


def poison_batch(batch: Dict) -> Dict:
    """NaN the numeric targets of one batch (labels + dense features).

    Works on host numpy and on-device jax arrays alike — scalar multiply
    preserves placement/sharding and produces fresh buffers, so the poisoned
    batch never aliases the clean one.
    """
    out = dict(batch)
    keys = [k for k in ("labels", "dense") if k in batch]
    if not keys:  # non-WDL batch (toy harnesses): poison every float leaf
        keys = [k for k, v in batch.items()
                if hasattr(v, "dtype") and np.issubdtype(v.dtype, np.floating)]
    for k in keys:
        out[k] = batch[k] * float("nan")
    return out


def corrupt_checkpoint_file(ckpt_dir: str, step: Optional[int] = None) -> Optional[str]:
    """Truncate the first leaf file of a checkpoint to half its bytes —
    guaranteed checksum mismatch, i.e. a torn write / bad disk sector.
    Returns the mangled path, or None if there was nothing to corrupt."""
    from repro.train.checkpoint import available_steps

    steps = available_steps(ckpt_dir)
    if not steps:
        return None
    s = step if step is not None else steps[-1]
    d = Path(ckpt_dir) / f"step_{s:08d}"
    leaves = sorted(p for p in d.iterdir() if p.name != "manifest.json")
    if not leaves:
        return None
    target = leaves[0]
    data = target.read_bytes()
    target.write_bytes(data[: max(1, len(data) // 2)])
    log.warning("[chaos] corrupted checkpoint leaf %s (%d -> %d bytes)",
                target, len(data), len(data) // 2)
    return str(target)


def tear_published(publish_dir: str) -> Optional[str]:
    """Tear the delta the LATEST pointer names (same truncation as
    ``corrupt_checkpoint_file`` but aimed at the publish dir)."""
    p = Path(publish_dir) / "LATEST"
    if not p.exists():
        return None
    try:
        step = int(p.read_text().strip())
    except (ValueError, OSError):
        return None
    return corrupt_checkpoint_file(publish_dir, step=step)


class ChaosStream:
    """Wrap a batch stream, poisoning the configured indices one-shot.

    Forwards ``seek``/``close``/``pos`` so it stacks transparently on a
    ``ReplayableStream`` under a ``Supervisor``. The fired-set is *not*
    reset by seek: a replay after rollback sees the clean batch.
    """

    def __init__(self, inner: Iterator, nan_batch: FrozenSet[int],
                 start: int = 0):
        self.inner = inner
        self.nan_batch = nan_batch
        self.pos = getattr(inner, "pos", start)
        self.fired: Set[int] = set()

    def __iter__(self):
        return self

    def __next__(self):
        i = self.pos
        batch = next(self.inner)
        self.pos = getattr(self.inner, "pos", i + 1)
        if i in self.nan_batch and i not in self.fired:
            self.fired.add(i)
            log.warning("[chaos] poisoning batch %d with NaN", i)
            return poison_batch(batch)
        return batch

    def seek(self, step: int) -> "ChaosStream":
        if hasattr(self.inner, "seek"):
            self.inner.seek(step)
        self.pos = step
        return self

    def rewrap(self, make_iter: Callable[[int], Iterator]) -> "ChaosStream":
        if hasattr(self.inner, "rewrap"):
            self.inner.rewrap(make_iter)
        return self

    def close(self):
        if hasattr(self.inner, "close"):
            self.inner.close()


class ChaosController:
    """One-stop wiring of a ``FaultPlan`` into a training launcher.

    - ``wrap_stream(stream)``: poison NaN-batch indices;
    - ``injector(step)``: raise ``ChaosFailure`` at crash indices (plug
      into ``Supervisor.run(fail_injector=...)``);
    - ``after_checkpoint(step, ckpt_dir, ckpt)``: once per configured
      ``ckpt@c`` with ``step >= c``, flush the async writer and mangle the
      newest checkpoint on disk;
    - ``after_publish(step, publish_dir)``: same pattern for ``torn@t``.

    All one-shot; ``fired`` survives rollback replays (see module doc).
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.fired: Set[str] = set()

    def wrap_stream(self, stream: Iterator) -> Iterator:
        if not self.plan.nan_batch:
            return stream
        return ChaosStream(stream, self.plan.nan_batch)

    def injector(self, step: int) -> None:
        if step in self.plan.crash and f"crash@{step}" not in self.fired:
            self.fired.add(f"crash@{step}")
            log.warning("[chaos] injecting crash at step %d", step)
            raise ChaosFailure(f"injected crash at step {step}")

    def after_checkpoint(self, step: int, ckpt_dir: str, ckpt=None) -> None:
        for c in sorted(self.plan.corrupt_ckpt):
            if step >= c and f"ckpt@{c}" not in self.fired:
                if ckpt is not None:
                    ckpt.wait()  # the file must exist before we can maul it
                # armed until a checkpoint actually lands on disk: a
                # ``ckpt@c`` between two save intervals waits for the next one
                if corrupt_checkpoint_file(ckpt_dir) is not None:
                    self.fired.add(f"ckpt@{c}")

    def after_publish(self, step: int, publish_dir: str) -> None:
        for t in sorted(self.plan.torn_publish):
            if step >= t and f"torn@{t}" not in self.fired:
                log.warning("[chaos] tearing published delta at step %d", step)
                if tear_published(publish_dir) is not None:
                    self.fired.add(f"torn@{t}")
