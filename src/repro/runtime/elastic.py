"""Elastic resharding: move a live run (or a checkpoint) between world sizes.

PICASSO's deployment story is continuous delivery — daily retrains racing the
clock on whatever slice of the fleet is free — so the world size a run
*starts* at is not the world size it finishes (or serves) at. The packed row
space is world-independent by construction (scramble + table offsets derive
from raw vocabs; only the tail padding is ``_pad_to(logical, world)``), which
makes a W -> W' reshard a pure permutation:

1. ``core.packing.reshard_plan`` recuts each group's padded ``rows`` and the
   per-peer all_to_all capacities for the new shard count — every revisable
   decision (tier budgets, strategy mix, narrow widths, ``rev``) carries
   verbatim;
2. ``embedding.state.migrate_state`` (via ``_reshard_group_state``) performs
   the state-side permutation: master ``w``/``acc``/FCounter pad/truncate
   only ever padding rows, tier sentinel keys are remapped to the new
   ``rows_padded`` value, and every resident row / optimizer slot / counter
   survives bitwise;
3. ``place_state`` re-places the full state under the new mesh's
   NamedShardings — the actual all_to_all permutation of shard contents is
   ``jax.device_put`` re-laying out the logical arrays over the new mesh.

``restore_elastic`` is the checkpoint-side entry: ``plan_meta`` records the
world (and mesh shape) a checkpoint was written under, so a restore at a
different world is *detected* and routed through the same permutation instead
of shape-erroring (or worse, silently re-padding tier sentinels) against a
stale template.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple, Union

import jax

from repro.core.packing import PicassoPlan, reshard_plan
from repro.dist.compat import make_submesh_compat
from repro.dist.sharding import emb_specs, replicated, state_specs, to_named
from repro.embedding.state import migrate_state, reshard_state
from repro.train.checkpoint import load_checkpoint_meta, restore_checkpoint


def parse_mesh_shape(spec: Union[str, Sequence[int]], n_axes: int = 2
                     ) -> Tuple[int, ...]:
    """``'4x2'`` -> ``(4, 2)``; a bare ``'4'`` pads with 1s to ``n_axes``."""
    if isinstance(spec, (tuple, list)):
        shape = tuple(int(x) for x in spec)
    else:
        shape = tuple(int(x) for x in str(spec).lower().split("x"))
    if not shape or any(s <= 0 for s in shape):
        raise ValueError(f"mesh shape must be positive ints, got {spec!r}")
    if len(shape) < n_axes:
        shape = shape + (1,) * (n_axes - len(shape))
    return shape


def make_submesh(shape: Sequence[int], axes: Sequence[str]):
    """Mesh over the first ``prod(shape)`` devices (scale-down in-process)."""
    return make_submesh_compat(shape, axes)


def place_state(state: Any, plan: PicassoPlan, mesh, axes) -> Any:
    """``jax.device_put`` a full (or emb-only) state under ``plan``'s specs.

    This is the collective half of a reshard: the host/logical arrays are
    re-laid-out over ``mesh`` (masters row-sharded over the new world, tiers
    and dense replicated). Works for the train state (``emb/dense/opt/step``
    + any extra replicated leaves), the serve subset (``emb/dense``), or a
    bare per-group emb dict.
    """
    if isinstance(state, dict) and "emb" in state:
        specs = state_specs(plan, axes, state.get("dense"),
                            state.get("opt"))
        for k, v in state.items():
            if k not in specs:
                specs[k] = replicated(v)
        specs = {k: specs[k] for k in state}
        return jax.device_put(state, to_named(mesh, specs))
    return jax.device_put(state, to_named(mesh, emb_specs(plan, axes)))


def reshard_live(plan: PicassoPlan, state: Any, new_world: int,
                 per_device_batch: int, *, mesh=None, axes=None,
                 mesh_shape: Optional[Sequence[int]] = None,
                 use_cache: bool = True, use_l2: bool = True,
                 cache_update: str = "psum") -> Tuple[PicassoPlan, Any]:
    """One-call live reshard: recut the plan, permute the state, re-place.

    Returns ``(new_plan, new_state)``; with ``mesh=None`` the state comes
    back as host arrays (checkpoint-portability tests use this), else it is
    placed under ``mesh``'s shardings ready for a rebuilt jitted step.
    ``use_cache``/``use_l2``/``cache_update`` mirror the engine flags, same
    contract as ``migrate_state``.
    """
    new_plan = reshard_plan(plan, new_world, per_device_batch,
                            mesh_shape=mesh_shape)
    migrated = migrate_state(plan, new_plan, state, use_cache=use_cache,
                             use_l2=use_l2, cache_update=cache_update)
    if mesh is not None:
        migrated = place_state(migrated, new_plan, mesh, axes)
    return new_plan, migrated


def restore_elastic(ckpt_dir: str, plan: PicassoPlan, template: Any, *,
                    mesh=None, axes=None, step: Optional[int] = None,
                    log=None) -> Tuple[Any, int]:
    """Restore a checkpoint whose recorded world may differ from ``plan``'s.

    - recorded world matches (or the meta predates world recording): a plain
      ``restore_checkpoint`` — a *stale-meta* checkpoint at a mismatched
      world still fails, but with the row-mismatch diagnosis and the pointer
      here, not a bare shape error;
    - recorded world differs: the stored rows are pulled out as-is
      (``on_row_mismatch='keep'``) and re-cut by ``reshard_state`` — sentinel
      keys remapped, padding re-sliced, every logical row bitwise.

    ``template`` is shaped by the CURRENT plan (after ``apply_plan_meta``,
    so tier shapes already match the checkpointed revision). With ``mesh``
    the restored state is placed under ``plan``'s shardings.
    """
    log = log or (lambda s: None)
    meta = load_checkpoint_meta(ckpt_dir, step)
    world_ckpt = (meta or {}).get("world")
    if world_ckpt is not None and int(world_ckpt) != plan.world:
        state, s = restore_checkpoint(ckpt_dir, template, step=step,
                                      on_row_mismatch="keep")
        state = reshard_state(plan, state)
        log(f"restored world={int(world_ckpt)} checkpoint at "
            f"world={plan.world} (resharded step {s})")
    else:
        state, s = restore_checkpoint(ckpt_dir, template, step=step)
    if mesh is not None:
        state = place_state(state, plan, mesh, axes)
    return state, s
