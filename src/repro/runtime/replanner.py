"""Adaptive replanning runtime: close the measure -> recompile -> migrate loop.

PICASSO's packing/caching decisions (paper §III) are frequency-driven, but a
plan compiled once from the structural warm prior freezes a mis-sized hot
tier or a wrong per-group strategy pick for the whole run — while access
popularity drifts across the training window (Acun et al.) and systems like
HugeCTR treat embedding-cache capacity as a runtime-tuned quantity. This
module makes the plan a *versioned* artifact in motion:

    every --replan-iters steps the trainer calls ``Replanner.maybe_replan``:
      1. **harvest**  — pull the engine's live FCounter counts off-device
         (``repro.engine.export_stats``) plus the window's ``overflow/*`` /
         ``cache_hits/*`` metric sums (``observe``);
      2. **recompile** — ``revise_plan`` re-budgets ``cache_rows``/``l2_rows``
         from the measured mass (``plan_cache``/``plan_l2`` with ``stats=``)
         and ``compile_assignment(plan, stats=...)`` re-mixes the per-group
         strategy against measured skew -> plan revision ``rev+1``;
      3. **migrate**  — if anything changed, ``embedding.state.migrate_state``
         carries the live state across revisions (write-back, measured
         top-(H1+H2) tier re-split, master rows / adagrad slots / FCounter
         preserved exactly) and the state is re-placed on the mesh under the
         new plan's sharding specs.

    The *caller* then rebuilds the jitted step / flush fn against the new
    plan (the Replanner is deliberately jit-free: it owns planning and state,
    not tracing).

A recompile that lands on an identical plan returns ``None`` — no migration,
no rebuild, and training is bitwise-identical to never having replanned
(pinned by tests/test_replan.py).

Checkpoint contract: ``plan_meta(plan)`` is the JSON-serializable revision
record (rev, tier budgets, strategy) the trainer persists next to the state
(``save_checkpoint(..., meta=...)``); on resume ``apply_plan_meta`` revises
the freshly-compiled structural plan back to the checkpointed revision
*before* the state template is built, so restore sees matching tier shapes.
The harvested FCounter itself rides in the state (``counts`` leaves), so a
resumed run replans from exactly the statistics it had measured.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple, Union

import jax
import numpy as np

from repro.core.assign import apply_assignment, compile_assignment, resolve_assignment
from repro.core.packing import PicassoPlan, revise_plan
from repro.dist.sharding import emb_shardings
from repro.embedding.state import migrate_state, tier_gates
from repro.engine.engine import export_stats


# ---------------------------------------------------------------------------
# plan deltas + checkpoint meta
# ---------------------------------------------------------------------------


def plan_delta(old: PicassoPlan, new: PicassoPlan) -> Dict[int, str]:
    """gid -> human-readable description of what changed between revisions.

    Empty dict == the revision is a no-op (same tier budgets, same strategy
    for every group): no migration and no step rebuild are needed.
    """
    changed: Dict[int, str] = {}
    for g in new.groups:
        h1o, h1n = old.cache_rows.get(g.gid, 0), new.cache_rows.get(g.gid, 0)
        h2o, h2n = old.l2_rows.get(g.gid, 0), new.l2_rows.get(g.gid, 0)
        so = old.strategy.get(g.gid, "picasso")
        sn = new.strategy.get(g.gid, "picasso")
        ndo, ndn = old.narrow_width(g.gid), new.narrow_width(g.gid)
        parts = []
        if so != sn:
            parts.append(f"{so}->{sn}")
        if h1o != h1n:
            parts.append(f"L1 {h1o}->{h1n}")
        if h2o != h2n:
            parts.append(f"L2 {h2o}->{h2n}")
        if ndo != ndn:
            # master width changed: migration re-masters the group (narrow
            # rows re-widened through the projection, or wide rows narrowed)
            parts.append(f"narrow {ndo}->{ndn}")
        if parts:
            changed[g.gid] = " ".join(parts)
    return changed


def plan_meta(plan: PicassoPlan) -> Dict[str, Any]:
    """JSON-serializable record of a plan revision (checkpoint sidecar).

    Only the *revisable* decisions are recorded — groups/capacity/interleave
    re-derive deterministically from the config and mesh via ``make_plan``;
    what resume cannot re-derive is which revision the checkpointed state
    was shaped by. ``world``/``mesh_shape`` additionally record the mesh the
    state was written under: a resume at a different world size is detected
    from them (``runtime.elastic.restore_elastic``) and routed through
    resharding instead of shape-erroring against a stale template.
    """
    return {
        "world": int(plan.world),
        "mesh_shape": [int(x) for x in plan.mesh_shape],
        "plan_rev": int(plan.rev),
        "hot_bytes": int(plan.hot_bytes),
        "l2_bytes": int(plan.l2_bytes),
        "cache_rows": {str(gid): int(r) for gid, r in plan.cache_rows.items()},
        "l2_rows": {str(gid): int(r) for gid, r in plan.l2_rows.items()},
        "strategy": {str(gid): name for gid, name in plan.strategy.items()},
        "narrow_dim": {str(gid): int(d) for gid, d in plan.narrow_dim.items()},
    }


def apply_plan_meta(plan: PicassoPlan, meta: Mapping[str, Any]) -> PicassoPlan:
    """Revise a freshly-compiled structural ``plan`` back to a checkpointed
    revision: tier budgets, strategy, and narrow master widths come from
    ``meta``, everything structural from ``plan``. Call *before* building the state template so
    restore sees the tier shapes the checkpoint was written with."""
    gids = {g.gid for g in plan.groups}
    meta_gids = {int(k) for k in meta.get("cache_rows", {})}
    if meta_gids and meta_gids != gids:
        raise ValueError(
            f"checkpoint plan meta covers gids {sorted(meta_gids)} but the "
            f"compiled plan has {sorted(gids)} — config/mesh changed under "
            "a resumed run")
    # dataclasses.replace: future PicassoPlan fields carry over structurally
    return dataclasses.replace(
        plan,
        capacity=dict(plan.capacity),
        interleave=[list(w) for w in plan.interleave],
        cache_rows={int(k): int(v) for k, v in meta["cache_rows"].items()},
        l2_rows={int(k): int(v) for k, v in meta["l2_rows"].items()},
        rev=int(meta.get("plan_rev", 0)),
        hot_bytes=int(meta.get("hot_bytes", plan.hot_bytes)),
        l2_bytes=int(meta.get("l2_bytes", plan.l2_bytes)),
        strategy={int(k): v for k, v in meta.get("strategy", {}).items()},
        narrow_dim=({int(k): int(v) for k, v in meta["narrow_dim"].items()}
                    if "narrow_dim" in meta else dict(plan.narrow_dim)),
    )


# ---------------------------------------------------------------------------
# the Replanner
# ---------------------------------------------------------------------------


@dataclass
class ReplanEvent:
    """One replan attempt (kept in ``Replanner.events``; launchers log it)."""

    step: int
    old_rev: int
    new_rev: int                  # == old_rev when the recompile was a no-op
    changed: Dict[int, str]       # gid -> delta description (empty = no-op)
    window: Dict[str, int]        # metric sums observed since the last replan
    # cost-model feedback for this window (calibrated runs only): the
    # measured-vs-predicted sparse-path ratio and the correction factor the
    # NEXT recompile's scores were blended with (None = no cost model or no
    # timings observed this window)
    measured_us: Optional[float] = None
    predicted_us: Optional[float] = None
    correction: Optional[float] = None

    @property
    def migrated(self) -> bool:
        return bool(self.changed)

    def describe(self) -> str:
        w = " ".join(f"{k}={v}" for k, v in sorted(self.window.items()))
        if self.correction is not None:
            w = (f"measured={self.measured_us:.0f}us "
                 f"predicted={self.predicted_us:.0f}us "
                 f"corr={self.correction:.3f}" + (" " + w if w else ""))
        if not self.changed:
            return (f"step {self.step}: plan rev {self.old_rev} unchanged "
                    f"(recompile is a no-op){'  [' + w + ']' if w else ''}")
        ch = "; ".join(f"g{gid}: {d}" for gid, d in sorted(self.changed.items()))
        return (f"step {self.step}: plan rev {self.old_rev} -> {self.new_rev}, "
                f"migrated {len(self.changed)} group(s) [{ch}]"
                f"{'  [' + w + ']' if w else ''}")


class Replanner:
    """Owns the adaptive replanning loop for one training run.

    Parameters
    ----------
    plan: the live plan (revision the engine currently executes). If it does
        not yet carry a per-group strategy assignment, the ``strategy`` spec
        is resolved and recorded — migration gating needs to know each
        group's strategy class.
    mesh/axes: where migrated state is re-placed (``emb_specs`` sharding).
    strategy: the training strategy spec; ``'mixed'``/``'auto'`` lets every
        replan re-mix from measured skew, any other spec is re-resolved
        against each new revision (a broadcast name stays broadcast — the
        replan then only retunes tier budgets).
    hot_bytes/l2_bytes: byte envelopes for the re-budget; ``None`` re-splits
        the envelope recorded on the plan. Pass explicit values to retune
        tier capacity at runtime.
    rebudget: ``False`` keeps ``cache_rows``/``l2_rows`` exactly (the replan
        then only re-mixes strategy) — with pinned ``overrides`` this forces
        the recompile to be a no-op, which the parity tests exploit.
    use_cache/use_l2/cache_update: MUST mirror the TrainConfig flags the
        train engine runs with (same contract as ``make_flush_fn``).
    per_device_batch/overrides: forwarded to ``compile_assignment``.
    cost_model: optional calibrated ``repro.perf.CostModel``. When set, every
        recompile prices candidates from its curves, and the online feedback
        loop engages: per-step wall times fed through ``observe_timing`` are
        compared against ``cost_model.predict_step_us`` at each replan and
        the measured/predicted ratio is blended into ``cost_model.correction``
        (geometric EMA) so the *next* window's scores self-correct.
    pin_l2: mirrors the trainer's ``--pin-l2``: migrated state is re-placed
        with memory-kind-aware shardings so the L2 tier / narrow masters stay
        in pinned host memory across replans (no-op on backends without one).
    """

    def __init__(self, plan: PicassoPlan, mesh, axes, *,
                 strategy: Any = "auto",
                 hot_bytes: Optional[int] = None,
                 l2_bytes: Optional[int] = None,
                 rebudget: bool = True,
                 use_cache: bool = True, use_l2: bool = True,
                 cache_update: str = "psum",
                 per_device_batch: Optional[int] = None,
                 overrides: Optional[Mapping[Union[int, str], str]] = None,
                 cost_model=None,
                 pin_l2: bool = False,
                 log: Optional[Callable[[str], None]] = None):
        self.plan = plan
        self.mesh = mesh
        self.axes = axes
        self.strategy = strategy
        self.hot_bytes = hot_bytes
        self.l2_bytes = l2_bytes
        self.rebudget = rebudget
        self.use_cache = use_cache
        self.use_l2 = use_l2
        self.cache_update = cache_update
        self.per_device_batch = per_device_batch
        self.overrides = overrides
        self.cost_model = cost_model
        self.pin_l2 = pin_l2
        self.log = log or (lambda s: None)
        self.events: List[ReplanEvent] = []
        self._window: Dict[str, Any] = {}  # device-scalar running sums
        self._timings_us: List[float] = []  # measured step wall times (host)
        self._auto = isinstance(strategy, str) and strategy in ("mixed", "auto")
        if not plan.strategy:
            # record the run's assignment so tier gating (migration + the
            # host flush) sees the same per-group strategy classes the train
            # engine dispatches on
            apply_assignment(plan, resolve_assignment(
                plan, strategy, use_cache=use_cache))

    # ------------------------------------------------------------- observe
    def observe(self, metrics: Mapping[str, Any]) -> None:
        """Fold one step's engine metrics into the current replan window
        (``overflow*`` / ``cache_hits*`` counters).

        The running sums stay as device scalars (an async add per step, no
        host sync — ``int()`` here would block the dispatch pipeline every
        step); they are materialized once per window in ``maybe_replan``.
        """
        for k, v in metrics.items():
            if k.startswith("overflow") or k.startswith("cache_hits"):
                self._window[k] = self._window.get(k, 0) + v

    def observe_timing(self, step_us: float) -> None:
        """Record one measured step wall time (host float, us) for the cost
        model's feedback loop. Cheap and safe to call every step; ignored
        when no calibrated cost model is attached."""
        if self.cost_model is not None and step_us > 0.0:
            self._timings_us.append(float(step_us))

    def _close_window(self) -> Dict[str, int]:
        window = {k: int(v) for k, v in self._window.items()}
        self._window = {}
        return window

    def _feedback(self, stats: Dict[int, np.ndarray]
                  ) -> Tuple[Optional[float], Optional[float], Optional[float]]:
        """Blend this window's measured-vs-predicted ratio into the cost
        model's correction. The prediction is made with the correction the
        window's scores actually used (pre-update), so the EMA converges to
        the fixed point where corrected prediction == measurement. The first
        steps of a window include compile time — the median is robust to
        that outlier."""
        if self.cost_model is None or not self._timings_us:
            self._timings_us = []
            return None, None, None
        measured = float(np.median(self._timings_us))
        self._timings_us = []
        predicted = self.cost_model.predict_step_us(
            self.plan, stats, per_device_batch=self.per_device_batch)
        corr = self.cost_model.observe_measured(measured, predicted)
        return measured, predicted, corr

    # -------------------------------------------------------------- replan
    def _recompile(self, stats: Dict[int, np.ndarray]) -> PicassoPlan:
        """Measured stats -> candidate plan revision (budgets + assignment)."""
        new_plan = revise_plan(
            self.plan, stats if self.rebudget else None,
            hot_bytes=(self.hot_bytes if self.rebudget else self.plan.hot_bytes),
            l2_bytes=(self.l2_bytes if self.rebudget else self.plan.l2_bytes),
            enable_cache=self.use_cache)
        if not self.rebudget:
            # keep the current split bit-for-bit (only the strategy re-mixes)
            new_plan.cache_rows = dict(self.plan.cache_rows)
            new_plan.l2_rows = dict(self.plan.l2_rows)
        if self._auto:
            asg = compile_assignment(
                new_plan, stats=stats,
                per_device_batch=self.per_device_batch,
                overrides=self.overrides, enable_cache=self.use_cache,
                cost_model=self.cost_model)
            apply_assignment(new_plan, asg)
        else:
            apply_assignment(new_plan, resolve_assignment(
                new_plan, self.strategy, use_cache=self.use_cache))
        return new_plan

    def maybe_replan(self, state: Dict[str, Any], step: int = -1
                     ) -> Optional[Tuple[PicassoPlan, Dict[str, Any]]]:
        """Harvest -> recompile -> (maybe) migrate.

        Returns ``None`` when the recompiled revision equals the live plan
        (state untouched — training continues bitwise-identically on the
        existing jitted step), else ``(new_plan, new_state)`` with the state
        migrated and re-placed on the mesh; the caller must rebuild its
        jitted step/flush against ``new_plan`` and adopt both.
        """
        stats = export_stats(self.plan, state["emb"])
        # feedback first: the correction lands in the cost model BEFORE the
        # recompile below prices this revision's candidates
        measured, predicted, corr = self._feedback(stats)
        new_plan = self._recompile(stats)
        changed = plan_delta(self.plan, new_plan)
        window = self._close_window()
        if not changed:
            ev = ReplanEvent(step=step, old_rev=self.plan.rev,
                             new_rev=self.plan.rev, changed={}, window=window,
                             measured_us=measured, predicted_us=predicted,
                             correction=corr)
            self.events.append(ev)
            self.log(ev.describe())
            return None
        migrated = migrate_state(self.plan, new_plan, state,
                                 use_cache=self.use_cache, use_l2=self.use_l2,
                                 cache_update=self.cache_update)
        shardings = emb_shardings(new_plan, self.mesh, self.axes,
                                  pin_l2=self.pin_l2)
        new_state = {**migrated,
                     "emb": jax.device_put(migrated["emb"], shardings)}
        ev = ReplanEvent(step=step, old_rev=self.plan.rev,
                         new_rev=new_plan.rev, changed=changed, window=window,
                         measured_us=measured, predicted_us=predicted,
                         correction=corr)
        self.events.append(ev)
        self.log(ev.describe())
        self.plan = new_plan
        return new_plan, new_state
