"""Runtime adaptation subsystem: the plan in motion.

``repro.core`` compiles a ``PicassoPlan`` once from structural priors;
``repro.runtime`` closes the loop at runtime — harvest the engine's live
frequency statistics, recompile the plan's revisable decisions (tier
budgets, per-group strategy mix), and migrate live training state across
plan revisions. See ``replanner`` for the full loop contract.
"""
from repro.runtime.replanner import (ReplanEvent, Replanner, apply_plan_meta,
                                     plan_delta, plan_meta)

__all__ = [
    "ReplanEvent",
    "Replanner",
    "apply_plan_meta",
    "plan_delta",
    "plan_meta",
]
