"""Runtime adaptation subsystem: the plan in motion.

``repro.core`` compiles a ``PicassoPlan`` once from structural priors;
``repro.runtime`` closes the loop at runtime — harvest the engine's live
frequency statistics, recompile the plan's revisable decisions (tier
budgets, per-group strategy mix), and migrate live training state across
plan revisions. See ``replanner`` for the full loop contract, ``elastic``
for world-size resharding (plan recut + exact state permutation + elastic
checkpoint restore), ``stream`` for the segmented streaming driver with
publish/pickup train-to-serve handoff, ``guard`` for numeric anomaly
detection/rejection, and ``chaos`` for the deterministic fault-injection
harness that proves the recovery paths.
"""
from repro.runtime.chaos import (ChaosController, ChaosFailure, ChaosStream,
                                 FaultPlan, parse_fault_plan)
from repro.runtime.elastic import (make_submesh, parse_mesh_shape,
                                   place_state, reshard_live,
                                   restore_elastic)
from repro.runtime.guard import AnomalyGuard, AnomalyRollback, GuardConfig
from repro.runtime.replanner import (ReplanEvent, Replanner, apply_plan_meta,
                                     plan_delta, plan_meta)
from repro.runtime.stream import (PublishPoller, load_published,
                                  poll_published, publish_state, run_stream)

__all__ = [
    "AnomalyGuard",
    "AnomalyRollback",
    "ChaosController",
    "ChaosFailure",
    "ChaosStream",
    "FaultPlan",
    "GuardConfig",
    "PublishPoller",
    "ReplanEvent",
    "Replanner",
    "apply_plan_meta",
    "load_published",
    "make_submesh",
    "parse_fault_plan",
    "parse_mesh_shape",
    "place_state",
    "plan_delta",
    "plan_meta",
    "poll_published",
    "publish_state",
    "reshard_live",
    "restore_elastic",
    "run_stream",
]
