"""Minimal streaming driver: segments over an unbounded batch stream.

The continuous-delivery loop PICASSO motivates (daily retrains racing the
clock) never sees a fixed ``--steps``: batches arrive indefinitely, the
trainer consumes them in *segments*, and at every segment boundary it

1. checkpoints incrementally (the segment is the failure/restart unit),
2. publishes a model delta (``publish_state``) a RUNNING serve process picks
   up without restart (``poll_published`` + ``load_published`` — the
   Merlin/HugeCTR train-to-serve handoff pattern), and
3. offers the caller a resize hook (``on_segment``) that may swap in a new
   ``(state, step_fn, stream)`` triple — the in-place elastic reshard
   (``runtime.elastic``) plugs in here, so a world-size change is just
   another segment boundary, not a restart.

Publication layout: ``publish_dir/step_<n>/`` is an ordinary checkpoint of
the serveable subset (``{"emb", "dense"}``) plus an atomically-renamed
``LATEST`` pointer file, so a poller never reads a half-written delta.
"""
from __future__ import annotations

import os
from pathlib import Path
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

from repro.embedding.state import reshard_state
from repro.train.checkpoint import (CheckpointCorrupt, available_steps,
                                    restore_checkpoint, save_checkpoint)


def run_stream(state: Any, step_fn: Callable, batches: Iterable, *,
               segment_steps: int, n_segments: int, start_step: int = 0,
               checkpointer=None, meta_fn: Optional[Callable] = None,
               publisher: Optional[Callable] = None,
               on_metrics: Optional[Callable] = None,
               on_segment: Optional[Callable] = None,
               log: Optional[Callable] = None) -> Tuple[Any, int]:
    """Consume ``batches`` in ``n_segments`` segments of ``segment_steps``.

    Per segment boundary (in order): ``checkpointer.save(step, state,
    meta=meta_fn())`` (an ``AsyncCheckpointer`` or anything with its
    ``save`` signature), ``publisher(step, state)``, a ``[stream] segment``
    log line, then ``on_segment(seg, step, state)`` — which may return a
    replacement ``(state, step_fn, batches)`` triple to adopt (the elastic
    reshard path) or ``None`` to continue unchanged.

    A drained source ends the run early (graceful, like the launchers).
    Returns ``(state, final_step)``.
    """
    log = log or (lambda s: print(s, flush=True))
    it = iter(batches)
    step = start_step
    for seg in range(1, n_segments + 1):
        done = 0
        for _ in range(segment_steps):
            try:
                batch = next(it)
            except StopIteration:
                break
            state, m = step_fn(state, batch)
            step += 1
            done += 1
            if on_metrics is not None:
                on_metrics(step, m)
        if checkpointer is not None:
            checkpointer.save(step, state,
                              meta=meta_fn() if meta_fn is not None else None)
        if publisher is not None:
            publisher(step, state)
        log(f"[stream] segment {seg}/{n_segments}: +{done} steps -> "
            f"step {step}")
        if on_segment is not None:
            out = on_segment(seg, step, state)
            if out is not None:
                state, step_fn, batches = out
                it = iter(batches)
        if done < segment_steps:
            log(f"[stream] source drained at step {step}; stopping")
            break
    return state, step


def publish_state(publish_dir: str, step: int, state: Dict[str, Any],
                  meta: Optional[Dict[str, Any]] = None, keep: int = 2
                  ) -> str:
    """Publish the serveable subset of ``state`` as an atomic model delta.

    Writes ``publish_dir/step_<n>/`` ({"emb", "dense"} — no optimizer, no
    step counter) via ``save_checkpoint`` (atomic rename), then atomically
    replaces the ``LATEST`` pointer. ``meta`` is typically ``plan_meta(plan)``
    so a consumer can detect the revision/world the delta was shaped by.
    """
    doc = {"emb": state["emb"], "dense": state["dense"]}
    path = save_checkpoint(publish_dir, step, doc, keep=keep, meta=meta)
    d = Path(publish_dir)
    tmp = d / ".LATEST.tmp"
    tmp.write_text(f"{step}\n")
    os.replace(tmp, d / "LATEST")
    return path


def poll_published(publish_dir: str, last_step: int = -1) -> Optional[int]:
    """Newest published step strictly after ``last_step``, else ``None``.

    Cheap enough to call before every serve request: one small file read,
    no directory scan.
    """
    p = Path(publish_dir) / "LATEST"
    if not p.exists():
        return None
    try:
        s = int(p.read_text().strip())
    except (ValueError, OSError):
        s = None
    if s is not None and s > last_step:
        # LATEST may name a step whose directory was already pruned: the
        # publisher GCs old deltas (keep=) *then* swings the pointer, so a
        # poller racing a rapid double-publish can read a stale LATEST.
        if (Path(publish_dir) / f"step_{s:08d}" / "manifest.json").exists():
            return s
        s = None
    if s is None:
        # torn/stale pointer: fall back to the newest delta actually on disk
        fresh = [x for x in available_steps(publish_dir) if x > last_step]
        return fresh[-1] if fresh else None
    return None


def load_published(publish_dir: str, template: Any,
                   plan=None, step: Optional[int] = None) -> Tuple[Any, int]:
    """Load one published delta into ``template`` (the serve {"emb","dense"}
    subset). With ``plan``, a delta published at a different world size is
    resharded onto the consumer's row padding (``reshard_state``) — the
    cross-world train-to-serve handoff; without it a row mismatch raises.
    Returns host arrays — callers place them (``elastic.place_state``).
    """
    state, s = restore_checkpoint(
        publish_dir, template, step=step,
        on_row_mismatch="keep" if plan is not None else "error")
    if plan is not None:
        state = reshard_state(plan, state)
    return state, s


class PublishPoller:
    """Degraded-mode delta consumption for a serving process.

    ``poll(template)`` returns ``(host_state, step)`` when a *verified* new
    delta loaded cleanly, else ``None`` — and a serving loop that only swaps
    on a non-None result keeps answering from its last good state through
    every failure mode a publisher can throw at it: torn LATEST pointer,
    pruned step directory, corrupt/truncated leaf files, deltas shaped by a
    different world (when ``plan`` is None), or a publish stall.

    Failed loads back off by *skipping polls* (capped exponential: after f
    consecutive failures, ``min(2**f, max_backoff)`` calls return early
    without touching the filesystem), so a wedged publisher can't turn the
    request path into a disk-scan loop. A clean load resets the backoff. A
    corrupt delta's step is remembered so the poller re-considers the same
    LATEST only after the backoff window, not on every request.
    """

    def __init__(self, publish_dir: str, plan=None, *, max_backoff: int = 8,
                 log: Optional[Callable[[str], None]] = None):
        self.publish_dir = publish_dir
        self.plan = plan
        self.max_backoff = max_backoff
        self.log = log or (lambda s: None)
        self.last_step = -1      # newest step successfully swapped in
        self.failures = 0        # consecutive failed load attempts
        self.skips_left = 0      # polls to skip before retrying
        self.loads = 0           # successful hot-swaps (observability)

    def poll(self, template: Any) -> Optional[Tuple[Any, int]]:
        if self.skips_left > 0:
            self.skips_left -= 1
            return None
        step = poll_published(self.publish_dir, self.last_step)
        if step is None:
            return None
        try:
            state, s = load_published(self.publish_dir, template,
                                      plan=self.plan, step=step)
        except (CheckpointCorrupt, ValueError, KeyError,
                FileNotFoundError) as e:
            self.failures += 1
            self.skips_left = min(2 ** self.failures, self.max_backoff)
            self.log(f"[serve] delta step {step} failed verification "
                     f"({type(e).__name__}: {e}); keeping last good state "
                     f"(step {self.last_step}), backing off "
                     f"{self.skips_left} polls")
            return None
        self.failures = 0
        self.skips_left = 0
        self.last_step = s
        self.loads += 1
        return state, s
