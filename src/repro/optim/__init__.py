from repro.optim.optimizers import adam_init, adam_update, lamb_update, sgd_update

__all__ = ["adam_init", "adam_update", "lamb_update", "sgd_update"]
