"""Dense-parameter optimizers (replicated DP side of the hybrid strategy).

Sparse embedding rows use the row-wise Adagrad fused into the MP engine
(core/packed_embedding._dedup_apply). Here: SGD / Adam / LAMB (the paper's
§IV discussion points at LAMB for super-large batches).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


def adam_init(params: Any) -> Dict:
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def _adam_moments(opt, grads, b1, b2):
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, opt["v"], grads)
    return m, v


def adam_update(params: Any, grads: Any, opt: Dict, lr: float, b1: float = 0.9,
                b2: float = 0.999, eps: float = 1e-8, wd: float = 0.0
                ) -> Tuple[Any, Dict]:
    t = opt["t"] + 1
    m, v = _adam_moments(opt, grads, b1, b2)
    tf = t.astype(jnp.float32)
    c1, c2 = 1 - b1 ** tf, 1 - b2 ** tf

    def upd(p, m, v):
        if p.dtype not in (jnp.float32, jnp.bfloat16, jnp.float16):
            return p
        step = lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
        if wd:
            step = step + lr * wd * p
        return (p - step).astype(p.dtype)

    return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "t": t}


def lamb_update(params: Any, grads: Any, opt: Dict, lr: float, b1: float = 0.9,
                b2: float = 0.999, eps: float = 1e-6, wd: float = 0.01
                ) -> Tuple[Any, Dict]:
    t = opt["t"] + 1
    m, v = _adam_moments(opt, grads, b1, b2)
    tf = t.astype(jnp.float32)
    c1, c2 = 1 - b1 ** tf, 1 - b2 ** tf

    def upd(p, m, v):
        if p.dtype not in (jnp.float32, jnp.bfloat16, jnp.float16):
            return p
        r = (m / c1) / (jnp.sqrt(v / c2) + eps) + wd * p
        pn = jnp.linalg.norm(p.astype(jnp.float32))
        rn = jnp.linalg.norm(r.astype(jnp.float32))
        trust = jnp.where((pn > 0) & (rn > 0), pn / rn, 1.0)
        return (p - lr * trust * r).astype(p.dtype)

    return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "t": t}


def sgd_update(params: Any, grads: Any, opt: Dict, lr: float) -> Tuple[Any, Dict]:
    return jax.tree.map(lambda p, g: (p - lr * g).astype(p.dtype), params, grads), opt
