"""Gradient compression (paper §V: 'quantitative communication' [50]).

Two wire paths, two APIs:

**Dense DP all-reduce** (``compressed_psum``): round the psum payload to a
narrow dtype (bf16 / fp16 / f8_e4m3) with *error feedback* (the residual is
carried in optimizer state so the compression bias cancels over steps).
Halves / quarters the all-reduce wire bytes of the dense layers — visible
directly in the dry-run collective term.

**Routed sparse gradients** (``compress_rows`` / ``decompress_rows`` and the
collective wrappers below): the transposed Shuffle moves ``[world*cap, D]``
gradient rows over ICI every step — the dominant backward payload of a
wide-and-deep model. ``grad_compress`` modes shrink that wire payload and
expand it on the owner side:

``'none'``  — passthrough (the default; bitwise-identical training).
``'fp16'``  — per-row amax scale + float16 cast (Tensor Casting style):
              ~half the wire bytes, relative error ~2^-11 of the row max.
``'topk'``  — per-row magnitude top-k (k = D / TOPK_FRACTION): only the
              heaviest coordinates travel; the rest are dropped (biased,
              but sparse-gradient rows concentrate mass in few coordinates).

Both modes compress all-zero rows to exact zeros, so padded / dropped bucket
slots survive the roundtrip bitwise — the dedup+adagrad scatter behind the
all_to_all relies on that. The per-row kernels are Pallas-fused on the
Pallas branch (``repro.kernels.grad_compress``) and pure-jnp references on
CPU (``fused=`` follows the same resolved switch as the sparse hot path).
Tier-maintenance traffic (hot-tier psums, flush reloads) deliberately stays
exact: compression is applied to the per-step routed payload only.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops

_DTYPES = {"none": None, "bf16": jnp.bfloat16, "fp16": jnp.float16,
           "f8": jnp.float8_e4m3fn}

# routed-path (sparse) modes; 'topk' keeps d // TOPK_FRACTION coords per row
ROUTED_MODES = ("none", "fp16", "topk")
TOPK_FRACTION = 4


def compressed_psum(grads: Any, axes, mode: str = "none",
                    residual: Optional[Any] = None) -> Tuple[Any, Any]:
    """psum with payload rounded to a narrow dtype + error feedback.

    Returns (summed grads fp32, new residual).
    """
    dt = _DTYPES[mode]
    if dt is None:
        return jax.tree.map(lambda g: lax.psum(g, axes), grads), residual

    def one(g, r):
        x = g + (r if r is not None else 0.0)
        q = x.astype(dt)
        new_r = x - q.astype(x.dtype)              # error feedback residual
        s = lax.psum(q, axes).astype(jnp.float32)  # narrow dtype on the wire
        return s, new_r

    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g), grads)
    out = jax.tree.map(one, grads, residual)
    summed = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return summed, new_res


# ---------------------------------------------------------------------------
# routed sparse-gradient payloads
# ---------------------------------------------------------------------------


class Fp16Rows(NamedTuple):
    """fp16 wire payload: scaled rows + their per-row fp32 scales."""

    q: jnp.ndarray      # [m, D] float16, values in [-1, 1]
    scale: jnp.ndarray  # [m, 1] float32 row amax


class TopkRows(NamedTuple):
    """topk wire payload: the k heaviest signed values + their columns."""

    vals: jnp.ndarray  # [m, k]
    idx: jnp.ndarray   # [m, k] int32


def topk_k(d: int) -> int:
    """Static per-row budget of the 'topk' mode."""
    return max(1, d // TOPK_FRACTION)


def validate_routed_mode(mode: str) -> str:
    if mode not in ROUTED_MODES:
        raise ValueError(
            f"grad_compress must be one of {ROUTED_MODES}; got {mode!r}")
    return mode


def compress_rows(g: jnp.ndarray, mode: str,
                  fused: Optional[bool] = None) -> Any:
    """``[m, D]`` gradient rows -> wire payload pytree for ``mode``.

    The payload's leaves all keep the leading ``m`` dimension, so callers
    can reshape/shuffle them through any row-preserving collective
    (``jax.tree.map`` over the payload) and ``decompress_rows`` after.
    """
    if mode == "none":
        return g
    if mode == "fp16":
        q, scale = ops.compress_fp16(g, fused=fused)
        return Fp16Rows(q=q, scale=scale)
    if mode == "topk":
        vals, idx = ops.compress_topk(g, topk_k(g.shape[-1]), fused=fused)
        return TopkRows(vals=vals, idx=idx)
    raise ValueError(validate_routed_mode(mode))


def decompress_rows(payload: Any, d: int, mode: str,
                    fused: Optional[bool] = None) -> jnp.ndarray:
    """Inverse of ``compress_rows``: wire payload -> ``[m, D]`` fp32 rows."""
    if mode == "none":
        return payload
    if mode == "fp16":
        return ops.decompress_fp16(payload.q, payload.scale, fused=fused)
    if mode == "topk":
        return ops.decompress_topk(payload.vals, payload.idx, d, fused=fused)
    raise ValueError(validate_routed_mode(mode))


def compressed_all_gather(g: jnp.ndarray, axes, mode: str = "none",
                          fused: Optional[bool] = None) -> jnp.ndarray:
    """all_gather of gradient rows with the payload compressed on the wire.

    Every shard gathers the same compressed payload and decompresses it
    identically, so replica-consistent consumers (the PS / allgather_rows
    backward scatters) stay replica-consistent under compression.
    """
    if mode == "none":
        return lax.all_gather(g, axes, tiled=True)
    payload = compress_rows(g, mode, fused=fused)
    payload = jax.tree.map(lambda x: lax.all_gather(x, axes, tiled=True),
                           payload)
    return decompress_rows(payload, g.shape[-1], mode, fused=fused)
