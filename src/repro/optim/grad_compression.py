"""Gradient compression for the dense DP all-reduce (paper §V: 'quantitative
communication' [50]).

On TPU the practical lever is payload dtype: round the psum payload to
bf16 / f8_e4m3 with *error feedback* (the residual is carried in optimizer
state so the compression bias cancels over steps). Halves / quarters the
all-reduce wire bytes of the dense layers — visible directly in the dry-run
collective term.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

_DTYPES = {"none": None, "bf16": jnp.bfloat16, "f8": jnp.float8_e4m3fn}


def compressed_psum(grads: Any, axes, mode: str = "none",
                    residual: Optional[Any] = None) -> Tuple[Any, Any]:
    """psum with payload rounded to a narrow dtype + error feedback.

    Returns (summed grads fp32, new residual).
    """
    dt = _DTYPES[mode]
    if dt is None:
        return jax.tree.map(lambda g: lax.psum(g, axes), grads), residual

    def one(g, r):
        x = g + (r if r is not None else 0.0)
        q = x.astype(dt)
        new_r = x - q.astype(x.dtype)              # error feedback residual
        s = lax.psum(q, axes).astype(jnp.float32)  # narrow dtype on the wire
        return s, new_r

    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g), grads)
    out = jax.tree.map(one, grads, residual)
    summed = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return summed, new_res
