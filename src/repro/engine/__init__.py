"""Unified embedding engine: one sparse path for train / serve / retrieval.

``EmbeddingEngine`` executes a ``PicassoPlan`` with per-group pluggable
``LookupStrategy``s (``'picasso' | 'hybrid' | 'ps' | 'picasso_l2' |
'picasso_narrow'`` plus the ``'mp_nodedup' | 'allgather_rows'`` benchmark
baselines, see ``strategies``):
a single name broadcasts, ``'mixed'``/``'auto'`` uses the plan's assignment
or compiles one with the ``repro.core.assign`` cost model.

This package re-exports the full public surface of the subsystem — the
engine, every registry strategy class and helper, and the assignment
compiler — so launchers, benchmarks, and docs examples import from one
place (``from repro.engine import ...``).
"""
from repro.core.assign import (AUTO_NAMES, GroupScore, StrategyAssignment,
                               apply_assignment, compile_assignment,
                               estimate_l2_gain, estimate_narrow_gain,
                               estimate_skew, maybe_compile,
                               resolve_assignment)
from repro.engine.engine import EmbeddingEngine, EngineContext, export_stats
from repro.engine.strategies import (AllGatherRowsStrategy, HybridStrategy,
                                     LookupStrategy, MPNoDedupStrategy,
                                     PicassoL2Strategy, PicassoNarrowStrategy,
                                     PicassoStrategy, PSStrategy,
                                     available_strategies, get_strategy,
                                     register_strategy)

__all__ = [
    "AUTO_NAMES",
    "AllGatherRowsStrategy",
    "EmbeddingEngine",
    "EngineContext",
    "GroupScore",
    "HybridStrategy",
    "LookupStrategy",
    "MPNoDedupStrategy",
    "PSStrategy",
    "PicassoL2Strategy",
    "PicassoNarrowStrategy",
    "PicassoStrategy",
    "StrategyAssignment",
    "apply_assignment",
    "available_strategies",
    "compile_assignment",
    "estimate_l2_gain",
    "estimate_narrow_gain",
    "estimate_skew",
    "export_stats",
    "get_strategy",
    "maybe_compile",
    "register_strategy",
    "resolve_assignment",
]
