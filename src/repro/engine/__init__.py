"""Unified embedding engine: one sparse path for train / serve / retrieval.

``EmbeddingEngine`` executes a ``PicassoPlan`` with per-group pluggable
``LookupStrategy``s (``'picasso' | 'hybrid' | 'ps'``, see ``strategies``):
a single name broadcasts, ``'mixed'``/``'auto'`` uses the plan's assignment
or compiles one with the ``repro.core.assign`` cost model.
"""
from repro.core.assign import (StrategyAssignment, apply_assignment,
                               compile_assignment, resolve_assignment)
from repro.engine.engine import EmbeddingEngine, EngineContext
from repro.engine.strategies import (HybridStrategy, LookupStrategy, PicassoStrategy,
                                     PSStrategy, available_strategies, get_strategy,
                                     register_strategy)

__all__ = [
    "EmbeddingEngine",
    "EngineContext",
    "HybridStrategy",
    "LookupStrategy",
    "PSStrategy",
    "PicassoStrategy",
    "StrategyAssignment",
    "apply_assignment",
    "available_strategies",
    "compile_assignment",
    "get_strategy",
    "register_strategy",
    "resolve_assignment",
]
