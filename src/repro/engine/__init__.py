"""Unified embedding engine: one sparse path for train / serve / retrieval.

``EmbeddingEngine`` executes a ``PicassoPlan`` with a pluggable
``LookupStrategy`` (``'picasso' | 'hybrid' | 'ps'``, see ``strategies``).
"""
from repro.engine.engine import EmbeddingEngine, EngineContext
from repro.engine.strategies import (HybridStrategy, LookupStrategy, PicassoStrategy,
                                     PSStrategy, available_strategies, get_strategy,
                                     register_strategy)

__all__ = [
    "EmbeddingEngine",
    "EngineContext",
    "HybridStrategy",
    "LookupStrategy",
    "PSStrategy",
    "PicassoStrategy",
    "available_strategies",
    "get_strategy",
    "register_strategy",
]
