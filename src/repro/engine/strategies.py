"""Pluggable lookup strategies for the EmbeddingEngine (paper §II-C, §IV).

A ``LookupStrategy`` owns the per-group sparse hot path: how packed IDs turn
into rows (forward) and how row gradients turn into table updates (backward).
The engine is strategy-agnostic; everything below the ``lookup`` /
``apply_grads`` boundary — collectives, dedup, caching — is a strategy detail.

Strategies bind to *groups*, not to the whole engine: the plan carries a
``gid -> name`` assignment (``PicassoPlan.strategy``, compiled by the
``repro.core.assign`` cost model or spelled out by the user) and the engine
dispatches each packed group to its own instance. A plan can therefore
PS-replicate its tiny tables while routing + caching the big skewed ones in
the same step — every strategy here must stay exact under that mixing (the
parity suite trains mixed and pure engines against each other).

Concrete strategies (selected by name through the registry):

``picasso``
    The full system: K-Packed Unique&Partition, fixed-capacity all_to_all
    Shuffle, HybridHash hot tier on the read and update paths.
``hybrid``
    MP all_to_all routing per group, but no HybridHash tier: same Shuffle,
    every unique goes to its owner shard every step. Packing is a *plan*
    choice, not a strategy choice — the paper's full intermediate baseline
    ("MP without packing or cache", §II-C) is this strategy on a plan built
    with ``enable_packing=False`` (one fragmentary op per table).
``ps``
    PS-style all_gather + psum lookups (the fragmentary baseline): no routing,
    no dedup, no cache; communication O(world * n * D).
``picasso_l2``
    The picasso path with a second, host-memory cache tier (HugeCTR-style
    hierarchical parameter cache) behind the hot tier: unique ids probe L1
    (device-resident top-H1 rows), then L2 (host-resident next-H2 rows), and
    only the remainder rides the all_to_all Shuffle. Write-back and re-rank
    happen at flush time for both tiers at once. Cold or absent L2 is
    bitwise-identical to ``picasso``.
``picasso_narrow``
    The picasso_l2 path with frequency-adaptive widths: tier-resident (hot)
    ids are served full-width ``D`` rows on device while the cold master
    stores/routes narrow ``d = plan.narrow_dim`` rows, projected up at
    lookup by a learned per-group ``[d, D]`` map. With no narrow budget the
    strategy is bitwise-identical to ``picasso_l2``.
``mp_nodedup``
    The Shuffle *without* K-Packed dedup: every raw id (duplicates included)
    rides the all_to_all. Prices the Unique&Partition fusion itself; exact
    vs ``picasso`` under ``exact_capacity`` plans.
``allgather_rows``
    Dedup'd replication baseline: unique ids are served by ``ps_lookup`` and
    row grads ride one (optionally compressed) all_gather back. Sits between
    ``ps`` (no dedup) and the routed strategies in wire cost.

Every MP strategy's routed gradient hop honours ``grad_compress``
('none' | 'fp16' | 'topk', see ``repro.optim.grad_compression``): the
all_to_all / all_gather payload is compressed on the wire and expanded on
the owner side; 'none' keeps training bitwise-identical.

New workloads (multi-task serving, frequency-adaptive dims, other baselines)
land as one ``@register_strategy`` class instead of a new copy of the loop.
A strategy advertises its cache behaviour through class attributes the
engine gates on per group: ``uses_cache`` (L1 participates where the plan
budgets ``cache_rows``), ``uses_l2`` (L2 participates where the plan budgets
``l2_rows`` *and* L1 is active), and ``extra_metric_keys`` (extra static
metric names ``tier_metrics`` reports, e.g. per-tier hit counters).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple, Type, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import packed_embedding as pe
from repro.embedding.state import EmbeddingState
from repro.optim import grad_compression as gcomp

Axes = Union[str, Tuple[str, ...]]

_REGISTRY: Dict[str, Type["LookupStrategy"]] = {}


def register_strategy(name: str):
    """Class decorator: make a LookupStrategy selectable by name."""

    def deco(cls: Type["LookupStrategy"]) -> Type["LookupStrategy"]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_strategy(name: str) -> Type["LookupStrategy"]:
    """Resolve a strategy class by name; unknown names raise with the menu."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown lookup strategy {name!r}; available: "
            f"{', '.join(available_strategies())}") from None


class LookupStrategy:
    """Base class: per-group sparse forward/backward, parameterized once.

    Subclasses implement ``lookup`` and ``apply_grads``; both receive the
    group's EmbeddingState and a group id (to index the static plan data) and
    must keep all shapes static — they run inside ``shard_map``.
    """

    name = "base"
    uses_cache = False        # whether the HybridHash hot tier participates
    uses_l2 = False           # whether the L2 host tier participates
    uses_routing_ctx = True   # ctx carries Shuffle routing (MP strategies)
    extra_metric_keys: Tuple[str, ...] = ()  # keys tier_metrics reports

    def __init__(self, *, axes: Axes, world: int, capacity: Dict[int, int],
                 lr: float = 0.05, eps: float = 1e-8,
                 cache_update: str = "psum", use_fused: bool = False,
                 grad_compress: str = "none"):
        self.axes = axes
        self.world = world
        self.capacity = capacity
        self.lr = lr
        self.eps = eps
        self.cache_update = cache_update
        # static (resolved) switch: True routes every hot-path op this
        # strategy issues — tier probes, the dedup+adagrad scatter — through
        # the fused Pallas kernels (see repro.kernels.ops.resolve_fused)
        self.use_fused = use_fused
        # wire compression of the routed sparse-gradient payload
        self.grad_compress = gcomp.validate_routed_mode(grad_compress)

    # ----------------------------------------------------------------- fwd
    def lookup(self, st: EmbeddingState, gid: int, ids: jnp.ndarray,
               *, cache_on: bool = False, l2_on: bool = False
               ) -> Tuple[jnp.ndarray, Any]:
        """ids [n] -> (rows [n, D], ctx). ``ctx.inv`` maps positions to rows."""
        raise NotImplementedError

    # ----------------------------------------------------------------- bwd
    def apply_grads(self, st: EmbeddingState, gid: int, ctx: Any,
                    g_rows: jnp.ndarray, *, cache_on: bool = False,
                    l2_on: bool = False
                    ) -> Tuple[EmbeddingState, jnp.ndarray, jnp.ndarray]:
        """Row grads -> updated state. Returns (state, overflow, cache_hits).

        ``cache_hits`` counts ids served by *any* cache tier (L1 + L2 for
        two-tier strategies); ``tier_metrics`` breaks it down.
        """
        raise NotImplementedError

    # ------------------------------------------------------------- metrics
    def tier_metrics(self, ctx: Any) -> Dict[str, jnp.ndarray]:
        """Per-tier counters for this lookup, keyed by ``extra_metric_keys``.

        Must return exactly ``extra_metric_keys`` (int32 scalars) for every
        ctx this strategy produced — the keys are static metric pytree
        entries, so they cannot depend on whether a tier was warm.
        """
        return {}


@register_strategy("picasso")
class PicassoStrategy(LookupStrategy):
    """Full packed/interleaved/cached path (paper §III-B/D).

    Forward: fixed-shape unique -> cache probe -> partition -> all_to_all
    Shuffle -> local gather -> Shuffle back -> Stitch (+ hot-tier merge).
    Backward: transposed Shuffle for miss grads; hit grads psum'd into the
    replicated hot tier ('psum') or routed to owners ('stale'); FCounter
    update on the owner side.
    """

    uses_cache = True

    def lookup(self, st, gid, ids, *, cache_on=False, l2_on=False):
        return pe.mp_lookup(
            st.w, ids, axes=self.axes, world=self.world,
            capacity=self.capacity[gid],
            hot_keys=st.cache.keys if cache_on else None,
            hot_rows=st.cache.rows if cache_on else None,
            fused=self.use_fused)

    def apply_grads(self, st, gid, ctx, g_rows, *, cache_on=False, l2_on=False):
        w2, acc2, cache2 = pe.apply_sparse_grads(
            st.w, st.acc, st.cache if cache_on else None, ctx, g_rows,
            axes=self.axes, world=self.world, lr=self.lr, eps=self.eps,
            cache_update=self.cache_update, fused=self.use_fused,
            compress=self.grad_compress)
        counts2 = pe.count_frequencies(st.counts, ctx)
        st2 = EmbeddingState(w=w2, acc=acc2, counts=counts2,
                             cache=cache2 if cache2 is not None else st.cache,
                             l2=st.l2)  # preserve an (unused) L2 tier as-is
        return (st2, ctx.routing.overflow.astype(jnp.int32),
                pe.cache_hit_count(ctx).astype(jnp.int32))


@register_strategy("hybrid")
class HybridStrategy(PicassoStrategy):
    """MP all_to_all routing without the HybridHash tier (paper §II-C).

    Same Shuffle/Stitch machinery as PICASSO, but the hot tier never
    participates: every unique id is routed to its owner shard every step.
    Isolates the cache's contribution in ablations; pair with a plan built
    with ``enable_packing=False`` to reproduce the paper's full "MP without
    packing or cache" intermediate baseline.
    """

    uses_cache = False

    def lookup(self, st, gid, ids, *, cache_on=False, l2_on=False):
        return super().lookup(st, gid, ids, cache_on=False)

    def apply_grads(self, st, gid, ctx, g_rows, *, cache_on=False, l2_on=False):
        return super().apply_grads(st, gid, ctx, g_rows, cache_on=False)


@register_strategy("picasso_l2")
class PicassoL2Strategy(PicassoStrategy):
    """PICASSO with a hierarchical parameter cache: L1 hot tier + L2 host tier.

    HugeCTR-style multi-level caching behind the replicated hot tier: the
    fixed-shape unique set probes the device-resident L1 first, L1 misses
    probe the (much larger) host-memory L2, and only ids absent from both
    tiers ride the all_to_all Shuffle. On TPU the L2 leaves live in pinned
    host memory (``pin_l2_to_host``) — a hit costs one host DMA instead of
    an ICI round trip; the repro keeps the arrays replicated so the math is
    identical either way.

    Backward follows ``cache_update`` exactly like the L1 tier: 'psum' keeps
    both replicated tiers authoritative between flushes (tier-hit grads are
    all-reduced into their own tier); 'stale' routes the union of tier hits
    to the owner shards and leaves both tiers read-only. The two-tier flush
    (``pe.flush_cache_l2``) writes both tiers back (psum mode), re-ranks one
    global frequency top-(H1+H2), and splits it: hottest H1 rows -> L1,
    next H2 -> L2 — the tiers stay disjoint by construction.

    With ``l2_on=False`` (no plan budget / ``use_l2=False`` / L1 disabled)
    every path is bitwise-identical to ``picasso``. With the tier on but
    cold, lookups, pooled outputs, and sparse updates are still bitwise
    identical — but the FCounter is intentionally NOT: this strategy also
    counts tier-served hits (``count_hit_frequencies``, the anti-churn
    correction), so once L1 warms, flush rankings — and through them later
    numerics — may diverge from plain picasso by design.
    """

    uses_l2 = True
    extra_metric_keys = ("cache_hits/l1", "cache_hits/l2")

    def lookup(self, st, gid, ids, *, cache_on=False, l2_on=False):
        if not l2_on or st.l2 is None:
            return super().lookup(st, gid, ids, cache_on=cache_on)
        return pe.mp_lookup(
            st.w, ids, axes=self.axes, world=self.world,
            capacity=self.capacity[gid],
            hot_keys=st.cache.keys if cache_on else None,
            hot_rows=st.cache.rows if cache_on else None,
            l2_keys=st.l2.keys, l2_rows=st.l2.rows,
            fused=self.use_fused)

    def apply_grads(self, st, gid, ctx, g_rows, *, cache_on=False, l2_on=False):
        if not l2_on or st.l2 is None or ctx.l2_hit is None:
            return super().apply_grads(st, gid, ctx, g_rows, cache_on=cache_on)
        w2, acc2, cache2, l22 = pe.apply_sparse_grads_l2(
            st.w, st.acc, st.cache if cache_on else None, st.l2, ctx, g_rows,
            axes=self.axes, world=self.world, lr=self.lr, eps=self.eps,
            cache_update=self.cache_update, fused=self.use_fused,
            compress=self.grad_compress)
        counts2 = pe.count_frequencies(st.counts, ctx)
        # tier-served ids never route, so they must be counted explicitly or
        # the flush ranking churn-evicts the resident (hottest) rows
        counts2 = pe.count_hit_frequencies(counts2, ctx, ctx.hit | ctx.l2_hit,
                                           axes=self.axes, world=self.world)
        st2 = EmbeddingState(w=w2, acc=acc2, counts=counts2,
                             cache=cache2 if cache2 is not None else st.cache,
                             l2=l22)
        hits = pe.cache_hit_count(ctx) + pe.l2_hit_count(ctx)
        return (st2, ctx.routing.overflow.astype(jnp.int32),
                hits.astype(jnp.int32))

    def tier_metrics(self, ctx):
        return {"cache_hits/l1": pe.cache_hit_count(ctx).astype(jnp.int32),
                "cache_hits/l2": pe.l2_hit_count(ctx).astype(jnp.int32)}


@register_strategy("picasso_narrow")
class PicassoNarrowStrategy(PicassoL2Strategy):
    """Frequency-adaptive embedding widths: hot ids wide, cold ids narrow.

    The two-tier picasso_l2 machinery with a heterogeneous-width master: ids
    resident in either cache tier are served full-width ``D`` rows exactly as
    in ``picasso_l2``, while the cold remainder rides the Shuffle at the
    planned narrow width ``d = plan.narrow_dim`` — the master shard stores
    ``[rows, d]``, both routed hops carry ``d``-wide payloads, and one fused
    ``ops.gather_project`` pass stitches the routed-back narrow rows up
    through a learned per-group ``[d, D]`` projection (``st.proj``).

    Backward mirrors the forward wire: the wide cotangent folds through
    ``proj^T`` once, routed grads travel narrow, tier-hit grads update the
    wide tiers, and the projection trains from the lookup's narrow residual
    (psum'd, adagrad'd) — see ``pe.apply_sparse_grads_narrow``. The flush
    (``pe.flush_cache_narrow``) implements the re-widening lifecycle: ids
    heating into a tier are widened ``narrow @ P``, ids staying resident
    keep their exact wide rows, cooling ids narrow through the projection's
    pseudo-inverse.

    Degenerate case: a plan that doesn't actually narrow this group
    (``narrow_dim >= D``, or the assignment routed it elsewhere) initializes
    no projection (``st.proj is None``) and every path below delegates to
    ``PicassoL2Strategy`` — bitwise-identical to ``picasso_l2``.
    """

    def lookup(self, st, gid, ids, *, cache_on=False, l2_on=False):
        if st.proj is None:  # not narrowed on this plan: exact L2 path
            return super().lookup(st, gid, ids, cache_on=cache_on, l2_on=l2_on)
        with_l2 = l2_on and st.l2 is not None
        return pe.mp_lookup_narrow(
            st.w, ids, proj=st.proj.kernel, axes=self.axes, world=self.world,
            capacity=self.capacity[gid],
            hot_keys=st.cache.keys if cache_on else None,
            hot_rows=st.cache.rows if cache_on else None,
            l2_keys=st.l2.keys if with_l2 else None,
            l2_rows=st.l2.rows if with_l2 else None,
            fused=self.use_fused)

    def apply_grads(self, st, gid, ctx, g_rows, *, cache_on=False, l2_on=False):
        if st.proj is None:
            return super().apply_grads(st, gid, ctx, g_rows,
                                       cache_on=cache_on, l2_on=l2_on)
        with_l2 = l2_on and st.l2 is not None and ctx.l2_hit is not None
        w2, acc2, cache2, l22, proj2 = pe.apply_sparse_grads_narrow(
            st.w, st.acc, st.cache if cache_on else None,
            st.l2 if with_l2 else None, st.proj, ctx, g_rows,
            axes=self.axes, world=self.world, lr=self.lr, eps=self.eps,
            cache_update=self.cache_update, fused=self.use_fused,
            compress=self.grad_compress)
        counts2 = pe.count_frequencies(st.counts, ctx)
        if cache_on or with_l2:
            both = (ctx.hit if ctx.l2_hit is None
                    else ctx.hit | ctx.l2_hit)
            counts2 = pe.count_hit_frequencies(counts2, ctx, both,
                                               axes=self.axes,
                                               world=self.world)
        st2 = EmbeddingState(w=w2, acc=acc2, counts=counts2,
                             cache=cache2 if cache2 is not None else st.cache,
                             l2=l22 if with_l2 else st.l2,
                             proj=proj2)
        hits = pe.cache_hit_count(ctx) + pe.l2_hit_count(ctx)
        return (st2, ctx.routing.overflow.astype(jnp.int32),
                hits.astype(jnp.int32))


class PSCtx(NamedTuple):
    """Context of a PS lookup: rows are per-id, so ``inv`` is the identity."""

    inv: jnp.ndarray   # [n] == arange(n)
    ids: jnp.ndarray   # [n] original packed ids (backward needs them)


@register_strategy("ps")
class PSStrategy(LookupStrategy):
    """PS/DP-style baseline (paper §II-C): all_gather ids, psum partial rows.

    No routing, no dedup, no cache — the fragmentary pattern PICASSO beats.
    Backward all_gathers per-id grads and scatters into the local shard.
    """

    uses_cache = False
    uses_routing_ctx = False

    def lookup(self, st, gid, ids, *, cache_on=False, l2_on=False):
        rows = pe.ps_lookup(st.w, ids, axes=self.axes, world=self.world)
        n = ids.shape[0]
        return rows, PSCtx(inv=jnp.arange(n, dtype=jnp.int32), ids=ids)

    def apply_grads(self, st, gid, ctx, g_rows, *, cache_on=False, l2_on=False):
        rps = st.w.shape[0]
        my = lax.axis_index(self.axes).astype(jnp.int32)
        base = my * rps
        all_ids = lax.all_gather(ctx.ids, self.axes, tiled=True)
        all_g = gcomp.compressed_all_gather(g_rows, self.axes,
                                            mode=self.grad_compress,
                                            fused=self.use_fused)
        local = all_ids - base
        ok = (local >= 0) & (local < rps)
        w2, acc2 = pe._dedup_apply(st.w, st.acc, jnp.clip(local, 0, rps - 1),
                                   all_g, ok, self.lr, self.eps,
                                   fused=self.use_fused)
        zero = jnp.zeros((), jnp.int32)
        return st._replace(w=w2, acc=acc2), zero, zero


@register_strategy("mp_nodedup")
class MPNoDedupStrategy(LookupStrategy):
    """Model-parallel Shuffle without K-Packed dedup (paper §II-C baseline).

    Every raw id — duplicates included — consumes a Shuffle bucket slot, so
    the wire payload scales with the batch's id count rather than its unique
    count. Exists to price the Unique&Partition fusion in benchmarks; exact
    vs ``picasso`` when nothing overflows (plan with ``exact_capacity=True``
    for parity runs: duplicate grads are summed by the owner-side
    dedup+adagrad scatter, recovering the deduped math).
    """

    uses_cache = False

    def lookup(self, st, gid, ids, *, cache_on=False, l2_on=False):
        return pe.mp_lookup_nodedup(
            st.w, ids, axes=self.axes, world=self.world,
            capacity=self.capacity[gid])

    def apply_grads(self, st, gid, ctx, g_rows, *, cache_on=False, l2_on=False):
        w2, acc2 = pe._apply_miss_grads(
            st.w, st.acc, ctx, g_rows, self.axes, self.world, self.lr,
            self.eps, self.use_fused, self.grad_compress)
        counts2 = pe.count_frequencies(st.counts, ctx)
        st2 = st._replace(w=w2, acc=acc2, counts=counts2)
        return (st2, ctx.routing.overflow.astype(jnp.int32),
                jnp.zeros((), jnp.int32))


class AllGatherCtx(NamedTuple):
    """Context of an allgather_rows lookup: rows are per-unique-slot."""

    inv: jnp.ndarray    # [n] position -> unique slot
    uniq: jnp.ndarray   # [n] sorted unique ids (sentinel-padded)


@register_strategy("allgather_rows")
class AllGatherRowsStrategy(LookupStrategy):
    """Dedup'd replication baseline: unique rows via all_gather+psum.

    Forward dedups the batch (fixed-shape unique), then serves the unique set
    with the PS machinery — sentinel slots gather exact zero rows. Backward
    all_gathers every shard's unique ids plus their row grads (the grads hop
    honours ``grad_compress``) and applies them locally on the owner shard.
    Wire cost sits between ``ps`` (no dedup at all) and the routed paths
    (O(world * uniq * D) vs O(uniq * D)); no routing ctx, no cache tiers.
    """

    uses_cache = False
    uses_routing_ctx = False

    def lookup(self, st, gid, ids, *, cache_on=False, l2_on=False):
        rps = st.w.shape[0]
        u = pe.fixed_unique(ids, sentinel=rps * self.world)
        rows = pe.ps_lookup(st.w, u.uniq, axes=self.axes, world=self.world)
        return rows, AllGatherCtx(inv=u.inv, uniq=u.uniq)

    def apply_grads(self, st, gid, ctx, g_rows, *, cache_on=False, l2_on=False):
        rps = st.w.shape[0]
        my = lax.axis_index(self.axes).astype(jnp.int32)
        base = my * rps
        all_ids = lax.all_gather(ctx.uniq, self.axes, tiled=True)
        all_g = gcomp.compressed_all_gather(g_rows, self.axes,
                                            mode=self.grad_compress,
                                            fused=self.use_fused)
        local = all_ids.astype(jnp.int32) - base
        ok = (local >= 0) & (local < rps)
        w2, acc2 = pe._dedup_apply(st.w, st.acc, jnp.clip(local, 0, rps - 1),
                                   all_g, ok, self.lr, self.eps,
                                   fused=self.use_fused)
        zero = jnp.zeros((), jnp.int32)
        return st._replace(w=w2, acc=acc2), zero, zero
