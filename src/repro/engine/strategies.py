"""Pluggable lookup strategies for the EmbeddingEngine (paper §II-C, §IV).

A ``LookupStrategy`` owns the per-group sparse hot path: how packed IDs turn
into rows (forward) and how row gradients turn into table updates (backward).
The engine is strategy-agnostic; everything below the ``lookup`` /
``apply_grads`` boundary — collectives, dedup, caching — is a strategy detail.

Strategies bind to *groups*, not to the whole engine: the plan carries a
``gid -> name`` assignment (``PicassoPlan.strategy``, compiled by the
``repro.core.assign`` cost model or spelled out by the user) and the engine
dispatches each packed group to its own instance. A plan can therefore
PS-replicate its tiny tables while routing + caching the big skewed ones in
the same step — every strategy here must stay exact under that mixing (the
parity suite trains mixed and pure engines against each other).

Concrete strategies (selected by name through the registry):

``picasso``
    The full system: K-Packed Unique&Partition, fixed-capacity all_to_all
    Shuffle, HybridHash hot tier on the read and update paths.
``hybrid``
    MP all_to_all routing per group, but no HybridHash tier: same Shuffle,
    every unique goes to its owner shard every step. Packing is a *plan*
    choice, not a strategy choice — the paper's full intermediate baseline
    ("MP without packing or cache", §II-C) is this strategy on a plan built
    with ``enable_packing=False`` (one fragmentary op per table).
``ps``
    PS-style all_gather + psum lookups (the fragmentary baseline): no routing,
    no dedup, no cache; communication O(world * n * D).

New workloads (multi-task serving, frequency-adaptive dims, other baselines)
land as one ``@register_strategy`` class instead of a new copy of the loop.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple, Type, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import packed_embedding as pe
from repro.embedding.state import EmbeddingState

Axes = Union[str, Tuple[str, ...]]

_REGISTRY: Dict[str, Type["LookupStrategy"]] = {}


def register_strategy(name: str):
    """Class decorator: make a LookupStrategy selectable by name."""

    def deco(cls: Type["LookupStrategy"]) -> Type["LookupStrategy"]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available_strategies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_strategy(name: str) -> Type["LookupStrategy"]:
    """Resolve a strategy class by name; unknown names raise with the menu."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown lookup strategy {name!r}; available: "
            f"{', '.join(available_strategies())}") from None


class LookupStrategy:
    """Base class: per-group sparse forward/backward, parameterized once.

    Subclasses implement ``lookup`` and ``apply_grads``; both receive the
    group's EmbeddingState and a group id (to index the static plan data) and
    must keep all shapes static — they run inside ``shard_map``.
    """

    name = "base"
    uses_cache = False        # whether the HybridHash hot tier participates
    uses_routing_ctx = True   # ctx carries Shuffle routing (MP strategies)

    def __init__(self, *, axes: Axes, world: int, capacity: Dict[int, int],
                 lr: float = 0.05, eps: float = 1e-8,
                 cache_update: str = "psum"):
        self.axes = axes
        self.world = world
        self.capacity = capacity
        self.lr = lr
        self.eps = eps
        self.cache_update = cache_update

    # ----------------------------------------------------------------- fwd
    def lookup(self, st: EmbeddingState, gid: int, ids: jnp.ndarray,
               *, cache_on: bool = False) -> Tuple[jnp.ndarray, Any]:
        """ids [n] -> (rows [n, D], ctx). ``ctx.inv`` maps positions to rows."""
        raise NotImplementedError

    # ----------------------------------------------------------------- bwd
    def apply_grads(self, st: EmbeddingState, gid: int, ctx: Any,
                    g_rows: jnp.ndarray, *, cache_on: bool = False
                    ) -> Tuple[EmbeddingState, jnp.ndarray, jnp.ndarray]:
        """Row grads -> updated state. Returns (state, overflow, cache_hits)."""
        raise NotImplementedError


@register_strategy("picasso")
class PicassoStrategy(LookupStrategy):
    """Full packed/interleaved/cached path (paper §III-B/D).

    Forward: fixed-shape unique -> cache probe -> partition -> all_to_all
    Shuffle -> local gather -> Shuffle back -> Stitch (+ hot-tier merge).
    Backward: transposed Shuffle for miss grads; hit grads psum'd into the
    replicated hot tier ('psum') or routed to owners ('stale'); FCounter
    update on the owner side.
    """

    uses_cache = True

    def lookup(self, st, gid, ids, *, cache_on=False):
        return pe.mp_lookup(
            st.w, ids, axes=self.axes, world=self.world,
            capacity=self.capacity[gid],
            hot_keys=st.cache.keys if cache_on else None,
            hot_rows=st.cache.rows if cache_on else None)

    def apply_grads(self, st, gid, ctx, g_rows, *, cache_on=False):
        w2, acc2, cache2 = pe.apply_sparse_grads(
            st.w, st.acc, st.cache if cache_on else None, ctx, g_rows,
            axes=self.axes, world=self.world, lr=self.lr, eps=self.eps,
            cache_update=self.cache_update)
        counts2 = pe.count_frequencies(st.counts, ctx)
        st2 = EmbeddingState(w=w2, acc=acc2, counts=counts2,
                             cache=cache2 if cache2 is not None else st.cache)
        return (st2, ctx.routing.overflow.astype(jnp.int32),
                pe.cache_hit_count(ctx).astype(jnp.int32))


@register_strategy("hybrid")
class HybridStrategy(PicassoStrategy):
    """MP all_to_all routing without the HybridHash tier (paper §II-C).

    Same Shuffle/Stitch machinery as PICASSO, but the hot tier never
    participates: every unique id is routed to its owner shard every step.
    Isolates the cache's contribution in ablations; pair with a plan built
    with ``enable_packing=False`` to reproduce the paper's full "MP without
    packing or cache" intermediate baseline.
    """

    uses_cache = False

    def lookup(self, st, gid, ids, *, cache_on=False):
        return super().lookup(st, gid, ids, cache_on=False)

    def apply_grads(self, st, gid, ctx, g_rows, *, cache_on=False):
        return super().apply_grads(st, gid, ctx, g_rows, cache_on=False)


class PSCtx(NamedTuple):
    """Context of a PS lookup: rows are per-id, so ``inv`` is the identity."""

    inv: jnp.ndarray   # [n] == arange(n)
    ids: jnp.ndarray   # [n] original packed ids (backward needs them)


@register_strategy("ps")
class PSStrategy(LookupStrategy):
    """PS/DP-style baseline (paper §II-C): all_gather ids, psum partial rows.

    No routing, no dedup, no cache — the fragmentary pattern PICASSO beats.
    Backward all_gathers per-id grads and scatters into the local shard.
    """

    uses_cache = False
    uses_routing_ctx = False

    def lookup(self, st, gid, ids, *, cache_on=False):
        rows = pe.ps_lookup(st.w, ids, axes=self.axes, world=self.world)
        n = ids.shape[0]
        return rows, PSCtx(inv=jnp.arange(n, dtype=jnp.int32), ids=ids)

    def apply_grads(self, st, gid, ctx, g_rows, *, cache_on=False):
        rps = st.w.shape[0]
        my = lax.axis_index(self.axes).astype(jnp.int32)
        base = my * rps
        all_ids = lax.all_gather(ctx.ids, self.axes, tiled=True)
        all_g = lax.all_gather(g_rows, self.axes, tiled=True)
        local = all_ids - base
        ok = (local >= 0) & (local < rps)
        w2, acc2 = pe._dedup_apply(st.w, st.acc, jnp.clip(local, 0, rps - 1),
                                   all_g, ok, self.lr, self.eps)
        zero = jnp.zeros((), jnp.int32)
        return st._replace(w=w2, acc=acc2), zero, zero
