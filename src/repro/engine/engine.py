"""EmbeddingEngine: the one owner of PICASSO's packed sparse path.

Architecture (engine layer)
---------------------------

Every workload — hybrid MP/DP training, online/bulk serving, two-tower
retrieval, and the dry-run cells — consumes the *same* engine instead of
re-implementing the ``pack_group -> lookup -> pool`` loop:

    EmbeddingEngine(plan, axes, world, strategy=<spec>)
        .forward(emb, packed)          -> (pooled, ctx)     # K-interleaved
        .backward(emb, ctx, g_pooled)  -> (emb', metrics)   # transposed path
        .flush(emb)                    -> emb'              # HybridHash flush
        .lookup_rows(emb, gid, ids)    -> rows              # raw per-id rows

``forward`` runs the planner's K-Interleaving waves (lookups of wave k+1 are
pinned behind a barrier with wave k's outputs, Fig. 8c) and pools each packed
group into ``pooled[gid]: [B, n_bags, D]``. ``backward`` takes the loss
gradient w.r.t. those pooled tensors, applies the (linear) SegmentReduction
transpose to recover per-row gradients, and hands them to each group's
strategy update path; it also folds cache hit / bucket overflow counters into
metrics. ``ctx`` is a pytree, so engine calls compose with
``jax.value_and_grad``, ``lax.cond`` and the D-Interleaving micro-batch
pipeline in the train step.

Strategy is a **per-packed-group property of the plan**, not an engine-wide
flag: the engine owns a ``Dict[gid, LookupStrategy]`` and dispatches per
group in every entry point. The ``strategy=`` argument accepts

- a registry name (``'picasso' | 'hybrid' | 'ps' | 'picasso_l2' |
  'picasso_narrow'``) — broadcast to every group (the original
  single-strategy constructor, kept as sugar);
- ``'mixed'`` / ``'auto'`` — use ``plan.strategy`` when the planner recorded
  an assignment, else compile one with the ``repro.core.assign`` cost model
  (tiny tables PS-replicated, big skewed tables routed + cached);
- an explicit ``{gid: name}`` dict or a ``StrategyAssignment``.

Invariants the engine maintains (and the tests pin down):

* **Per-group cache gating.** The HybridHash hot tier (L1) participates only
  where the assigned strategy has ``uses_cache`` AND the plan budgets
  ``cache_rows`` for that gid. The L2 host tier sits strictly *behind* L1:
  it participates only where the strategy has ``uses_l2``, the plan budgets
  ``l2_rows``, the engine's ``use_l2`` flag is on, AND L1 itself is active
  for the group (``--no-cache`` therefore disables both tiers).
* **Flush skips uncached groups.** ``flush`` touches exactly the groups
  whose tiers participate: L1+L2 groups get the two-tier flush (one global
  frequency ranking split top-H1 / next-H2), L1-only groups the single-tier
  flush, and every other group — including PS-assigned groups whose budgeted
  tier the training path never populated — passes through untouched.
* **Assignment resolution order** (``repro.core.assign.resolve_assignment``):
  an explicit ``StrategyAssignment``/dict is taken as-is (validated for
  exact gid coverage); ``'mixed'``/``'auto'`` uses ``plan.strategy`` when
  the plan carries one, else compiles a fresh assignment and records it on
  the plan; any other registry name broadcasts to every group.

Metrics are per-strategy-class sums (``overflow/<name>``,
``cache_hits/<name>``) when a plan mixes classes, plus any strategy-declared
per-tier keys (``cache_hits/l1`` / ``cache_hits/l2`` for ``picasso_l2``) —
``metric_keys`` is static so callers can build shard_map out_specs from it.

All shapes are static: the engine runs inside ``shard_map`` on TPU meshes.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packed_embedding as pe
from repro.core.assign import StrategySpec, resolve_assignment
from repro.kernels import ops
from repro.core.features import PackedBatch
from repro.core.interleaving import wave_barrier
from repro.core.packing import PicassoPlan
from repro.embedding.state import EmbeddingState
from repro.engine.strategies import LookupStrategy, get_strategy
from repro.optim import grad_compression as gcomp

Axes = Union[str, Tuple[str, ...]]


def export_stats(plan: PicassoPlan, emb: Dict[str, EmbeddingState]
                 ) -> Dict[int, np.ndarray]:
    """Harvest the live FCounter off-device: ``gid -> counts`` (full logical
    array, host numpy).

    This is the measurement half of the replanning loop (repro.runtime):
    the counts feed ``compile_assignment(plan, stats=...)`` and the
    stats-driven ``plan_cache``/``plan_l2`` re-budget. Reading a sharded
    array through ``device_get`` materializes the logical (mesh-wide) value,
    so the result is shard-layout independent — exactly what the planners
    expect. Call between steps (off the jitted hot path).
    """
    return {g.gid: np.asarray(jax.device_get(emb[str(g.gid)].counts))
            for g in plan.groups}


class EngineContext(NamedTuple):
    """Everything ``backward`` needs from a ``forward`` call (a pytree)."""

    ctxs: Dict[int, Any]            # gid -> strategy lookup ctx
    packed: Dict[int, PackedBatch]  # gid -> the packed batch it served


class EmbeddingEngine:
    """Owns the full sparse path for one PicassoPlan on one mesh.

    Parameters
    ----------
    plan: the planner output (groups, capacities, waves, cache budget, and
        optionally a per-group strategy assignment).
    axes/world: mesh axes the engine's collectives run over, and their size.
    strategy: a registry name (broadcast), ``'mixed'``/``'auto'`` (use or
        compile a per-group assignment), a ``{gid: name}`` dict, or a
        ``StrategyAssignment`` — see ``repro.core.assign``.
    use_cache: enable the HybridHash hot tier (honoured per group: only
        where the assigned strategy has ``uses_cache=True`` and the plan
        budgets a non-zero cache for that gid).
    use_l2: enable the L2 host-memory tier behind the hot tier (honoured
        per group: strategy has ``uses_l2=True``, the plan budgets
        ``l2_rows``, and the group's L1 tier is itself active).
    use_interleave: issue lookups in the planner's K-Interleaving waves;
        ``False`` collapses to a single wave.
    lr_emb/eps: row-wise adagrad hyperparameters for the sparse update.
    cache_update: ``'psum'`` (exact, replica-consistent hot tier) or
        ``'stale'`` (Algorithm 1 bounded-staleness semantics).
    use_fused_kernels: ``'auto'`` (fused Pallas sparse kernels on TPU or
        under ``REPRO_FORCE_PALLAS_INTERPRET``, jnp reference on CPU),
        ``'on'``/``True`` (force the kernels; interpreted off-TPU) or
        ``'off'``/``False`` (force the reference chains). Resolved ONCE here
        (``repro.kernels.ops.resolve_fused``) to a static bool every
        strategy and the pool/transpose below carry through their traces.
    grad_compress: wire compression of the routed sparse-gradient payload
        (``'none' | 'fp16' | 'topk'``, see ``repro.optim.grad_compression``)
        — applied by every strategy's backward collective; ``'none'`` keeps
        training bitwise-identical. Tier-maintenance traffic stays exact.
    capacity: optional per-gid override of the all_to_all bucket capacity
        (e.g. retrieval candidate towers that look up far more ids per shard
        than the training batch the plan was sized for).
    """

    def __init__(self, plan: PicassoPlan, axes: Axes, world: int, *,
                 strategy: StrategySpec = "picasso", use_cache: bool = True,
                 use_l2: bool = True, use_interleave: bool = True,
                 lr_emb: float = 0.05, eps: float = 1e-8,
                 cache_update: str = "psum",
                 use_fused_kernels: Any = "auto",
                 grad_compress: str = "none",
                 capacity: Optional[Dict[int, int]] = None):
        if int(plan.world) != int(world):
            # a stale engine after an elastic reshard: the plan's padded row
            # counts and capacities derive from plan.world, so collectives
            # built for `world` shards would mis-route rows silently
            raise ValueError(
                f"plan was compiled for world={plan.world} but the engine is "
                f"built for world={world} — after a reshard, rebuild the "
                "engine/step from the resharded plan (core.packing."
                "reshard_plan), not the stale one")
        self.plan = plan
        self.axes = axes
        self.world = world
        self.cache_update = cache_update
        self.use_fused = ops.resolve_fused(use_fused_kernels)
        self.grad_compress = gcomp.validate_routed_mode(grad_compress)
        # gid -> registry name; raises on unknown names / partial coverage
        # (an auto-compiled assignment is recorded on the plan, so the
        # host-flush engine and later call sites gate caches identically)
        self.assignment: Dict[int, str] = resolve_assignment(
            plan, strategy, world=world, use_cache=use_cache)
        # narrow masters are only readable through picasso_narrow: a plan
        # that narrows a group (recorded assignment + narrow budget) cannot
        # be driven by an engine assigning that group elsewhere — the master
        # shard is [rows, d], every other strategy expects [rows, D]
        for g in plan.groups:
            if (plan.narrow_width(g.gid) < g.dim
                    and self.assignment.get(g.gid) != "picasso_narrow"):
                raise ValueError(
                    f"g{g.gid}: the plan narrows this group's master to "
                    f"width {plan.narrow_width(g.gid)} (< dim {g.dim}), but "
                    f"this engine assigns {self.assignment.get(g.gid)!r}; "
                    "narrow state is only readable through 'picasso_narrow' "
                    "— keep the recorded assignment or re-plan without "
                    "narrow_dim")
        names = tuple(sorted(set(self.assignment.values())))
        self.strategy_names = names
        self.strategy_name = names[0] if len(names) == 1 else "mixed"
        cap = dict(capacity if capacity is not None else plan.capacity)
        # one instance per distinct name (they are stateless per-call), one
        # dispatch-map entry per group
        insts: Dict[str, LookupStrategy] = {
            name: get_strategy(name)(
                axes=axes, world=world, capacity=cap, lr=lr_emb, eps=eps,
                cache_update=cache_update, use_fused=self.use_fused,
                grad_compress=self.grad_compress)
            for name in names}
        self.strategies: Dict[int, LookupStrategy] = {
            gid: insts[name] for gid, name in self.assignment.items()}
        # per-group cache gating: strategy must use the tier AND the plan
        # must budget rows for this gid
        self.cache_on: Dict[int, bool] = {
            g.gid: bool(use_cache
                        and self.strategies[g.gid].uses_cache
                        and plan.cache_rows.get(g.gid, 0) > 0)
            for g in plan.groups}
        # L2 sits strictly behind L1: an inactive hot tier turns it off too
        self.l2_on: Dict[int, bool] = {
            g.gid: bool(use_l2
                        and self.cache_on[g.gid]
                        and self.strategies[g.gid].uses_l2
                        and plan.l2_rows.get(g.gid, 0) > 0)
            for g in plan.groups}
        self.any_cache = any(self.cache_on.values())
        self._extra_keys = tuple(sorted(
            {k for n in names for k in get_strategy(n).extra_metric_keys}))
        self.waves = (plan.interleave if use_interleave
                      else [[g.gid for g in plan.groups]])

    def export_stats(self, emb: Dict[str, EmbeddingState]
                     ) -> Dict[int, np.ndarray]:
        """Module-level ``export_stats`` bound to this engine's plan."""
        return export_stats(self.plan, emb)

    @property
    def metric_keys(self) -> Tuple[str, ...]:
        """Static metric pytree keys ``backward`` emits (callers build
        shard_map out_specs from this)."""
        keys = ["overflow", "cache_hits"]
        if len(self.strategy_names) > 1:
            keys += [f"overflow/{n}" for n in self.strategy_names]
            keys += [f"cache_hits/{n}" for n in self.strategy_names]
        keys += list(self._extra_keys)
        return tuple(keys)

    # ------------------------------------------------------------- forward
    def _wave_lookups(self, emb: Dict[str, EmbeddingState],
                      packed: Dict[int, PackedBatch]
                      ) -> Tuple[Dict[int, jnp.ndarray], Dict[int, Any]]:
        """Per-group lookups in K-Interleaving waves (Fig. 8c), each group
        through its own assigned strategy."""
        rows: Dict[int, jnp.ndarray] = {}
        ctxs: Dict[int, Any] = {}
        ids_in = {g.gid: packed[g.gid].ids for g in self.plan.groups}
        for wi, wave in enumerate(self.waves):
            if wi > 0:
                # wave wi's inputs pass through one barrier with wave wi-1's
                # outputs -> a real control boundary between the all_to_alls.
                prev = self.waves[wi - 1]
                flat = wave_barrier([rows[g] for g in prev]
                                    + [ids_in[g] for g in wave])
                for g, v in zip(prev, flat[: len(prev)]):
                    rows[g] = v
                for j, g in enumerate(wave):
                    ids_in[g] = flat[len(prev) + j]
            for gid in wave:
                rows[gid], ctxs[gid] = self.strategies[gid].lookup(
                    emb[str(gid)], gid, ids_in[gid],
                    cache_on=self.cache_on[gid], l2_on=self.l2_on[gid])
        return rows, ctxs

    def forward(self, emb: Dict[str, EmbeddingState],
                packed: Dict[int, PackedBatch]
                ) -> Tuple[Dict[int, jnp.ndarray], EngineContext]:
        """Packed batch -> pooled group outputs ``[B, n_bags, D]`` + ctx."""
        rows, ctxs = self._wave_lookups(emb, packed)
        pooled = {}
        for gid, pb in packed.items():
            g = self.plan.group(gid)
            b = pb.ids.shape[0] // g.ids_per_sample
            p = pe.pool(rows[gid], ctxs[gid].inv, pb.weights, pb.seg,
                        b * g.n_bags, fused=self.use_fused)
            pooled[gid] = p.reshape(b, g.n_bags, g.dim)
        return pooled, EngineContext(ctxs=ctxs, packed=dict(packed))

    def lookup_rows(self, emb: Dict[str, EmbeddingState], gid: int,
                    ids: jnp.ndarray) -> jnp.ndarray:
        """Raw per-id rows ``[n, D]`` for one group (retrieval towers)."""
        rows_u, ctx = self.strategies[gid].lookup(
            emb[str(gid)], gid, ids, cache_on=self.cache_on[gid],
            l2_on=self.l2_on[gid])
        return jnp.take(rows_u, ctx.inv, axis=0)

    # ------------------------------------------------------------ backward
    def backward(self, emb: Dict[str, EmbeddingState], ctx: EngineContext,
                 g_pooled: Dict[int, jnp.ndarray]
                 ) -> Tuple[Dict[str, EmbeddingState], Dict[str, jnp.ndarray]]:
        """Pooled grads -> sparse updates. Returns (emb', local metrics).

        The SegmentReduction of ``forward`` is linear in the looked-up rows,
        so its transpose is explicit: ``g_rows[u] = sum_{i: inv[i]=u} w[i] *
        g_pooled[seg[i]]``. Metrics are per-shard sums; callers psum them.
        With a mixed assignment, ``overflow/<name>`` and ``cache_hits/<name>``
        break the totals down per strategy class (see ``metric_keys``).
        """
        emb = dict(emb)
        zero = jnp.zeros((), jnp.int32)
        ovf = {n: zero for n in self.strategy_names}
        hits = {n: zero for n in self.strategy_names}
        extra = {k: zero for k in self._extra_keys}
        for gid, g_p in g_pooled.items():
            pb = ctx.packed[gid]
            gctx = ctx.ctxs[gid]
            name = self.assignment[gid]
            g_flat = g_p.reshape(-1, g_p.shape[-1])
            # transpose of the pool: one fused segment-grad pass produces the
            # [n_unique, D] row grads directly (no [n, D] per-id intermediate
            # when fused — see ops.segment_grad)
            g_rows = ops.segment_grad(g_flat, pb.seg, pb.weights, gctx.inv,
                                      pb.ids.shape[0], fused=self.use_fused)
            st2, o, h = self.strategies[gid].apply_grads(
                emb[str(gid)], gid, gctx, g_rows, cache_on=self.cache_on[gid],
                l2_on=self.l2_on[gid])
            emb[str(gid)] = st2
            ovf[name] = ovf[name] + o
            hits[name] = hits[name] + h
            for k, v in self.strategies[gid].tier_metrics(gctx).items():
                extra[k] = extra[k] + v
        metrics = {"overflow": sum(ovf.values(), zero),
                   "cache_hits": sum(hits.values(), zero)}
        if len(self.strategy_names) > 1:
            for n in self.strategy_names:
                metrics[f"overflow/{n}"] = ovf[n]
                metrics[f"cache_hits/{n}"] = hits[n]
        metrics.update(extra)
        return emb, metrics

    # --------------------------------------------------------------- flush
    def flush(self, emb: Dict[str, EmbeddingState]) -> Dict[str, EmbeddingState]:
        """HybridHash flush (Algorithm 1 L23-26) for every *cached* group —
        groups whose assigned strategy never reads a tier are skipped even
        when the plan budgets rows for them. Groups with an active L2 host
        tier get the two-tier flush: both tiers written back (psum mode),
        then one global frequency ranking refills L1 (top-H1) and L2
        (next-H2) disjointly."""
        out = dict(emb)
        for g in self.plan.groups:
            if not self.cache_on.get(g.gid, False):
                continue
            st = out[str(g.gid)]
            wb = self.cache_update == "psum"
            if st.proj is not None:
                # narrow master: heterogeneous-width flush (write-back via
                # the projection pseudo-inverse, widened reload, exact carry
                # for ids staying tier-resident). A missing L2 tier flushes
                # as an empty wide tier and stays absent.
                l2t = st.l2
                if l2t is None:
                    l2t = pe.CacheState(
                        keys=jnp.full((0,), g.rows, jnp.int32),
                        rows=jnp.zeros((0, g.dim), st.cache.rows.dtype),
                        acc=jnp.zeros((0, 1), st.cache.acc.dtype))
                w2, acc2, counts2, cache2, l22 = pe.flush_cache_narrow(
                    st.w, st.acc, st.counts, st.cache, l2t,
                    st.proj.kernel, axes=self.axes, world=self.world,
                    write_back=wb)
                out[str(g.gid)] = EmbeddingState(
                    w2, acc2, counts2, cache2,
                    l22 if st.l2 is not None else None, st.proj)
            elif self.l2_on.get(g.gid, False) and st.l2 is not None:
                w2, acc2, counts2, cache2, l22 = pe.flush_cache_l2(
                    st.w, st.acc, st.counts, st.cache, st.l2, axes=self.axes,
                    world=self.world, write_back=wb)
                out[str(g.gid)] = EmbeddingState(w2, acc2, counts2, cache2, l22)
            else:
                w2, acc2, counts2, cache2 = pe.flush_cache(
                    st.w, st.acc, st.counts, st.cache, axes=self.axes,
                    world=self.world, write_back=wb)
                out[str(g.gid)] = EmbeddingState(w2, acc2, counts2, cache2,
                                                 st.l2)
        return out
