"""EmbeddingEngine: the one owner of PICASSO's packed sparse path.

Architecture (engine layer)
---------------------------

Every workload — hybrid MP/DP training, online/bulk serving, two-tower
retrieval, and the dry-run cells — consumes the *same* engine instead of
re-implementing the ``pack_group -> lookup -> pool`` loop:

    EmbeddingEngine(plan, axes, world, strategy=<spec>)
        .forward(emb, packed)          -> (pooled, ctx)     # K-interleaved
        .backward(emb, ctx, g_pooled)  -> (emb', metrics)   # transposed path
        .flush(emb)                    -> emb'              # HybridHash flush
        .lookup_rows(emb, gid, ids)    -> rows              # raw per-id rows

``forward`` runs the planner's K-Interleaving waves (lookups of wave k+1 are
pinned behind a barrier with wave k's outputs, Fig. 8c) and pools each packed
group into ``pooled[gid]: [B, n_bags, D]``. ``backward`` takes the loss
gradient w.r.t. those pooled tensors, applies the (linear) SegmentReduction
transpose to recover per-row gradients, and hands them to each group's
strategy update path; it also folds cache hit / bucket overflow counters into
metrics. ``ctx`` is a pytree, so engine calls compose with
``jax.value_and_grad``, ``lax.cond`` and the D-Interleaving micro-batch
pipeline in the train step.

Strategy is a **per-packed-group property of the plan**, not an engine-wide
flag: the engine owns a ``Dict[gid, LookupStrategy]`` and dispatches per
group in every entry point. The ``strategy=`` argument accepts

- a registry name (``'picasso' | 'hybrid' | 'ps'``) — broadcast to every
  group (the original single-strategy constructor, kept as sugar);
- ``'mixed'`` / ``'auto'`` — use ``plan.strategy`` when the planner recorded
  an assignment, else compile one with the ``repro.core.assign`` cost model
  (tiny tables PS-replicated, big skewed tables routed + cached);
- an explicit ``{gid: name}`` dict or a ``StrategyAssignment``.

Cache gating is per group: the HybridHash hot tier participates only where
the assigned strategy has ``uses_cache`` AND the plan budgets rows for that
gid; ``flush`` skips every other group. Metrics are per-strategy-class sums
(``overflow/<name>``, ``cache_hits/<name>``) so overflow and hit counters
stay meaningful when a plan mixes routed and PS groups.

All shapes are static: the engine runs inside ``shard_map`` on TPU meshes.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import packed_embedding as pe
from repro.core.assign import StrategySpec, resolve_assignment
from repro.core.features import PackedBatch
from repro.core.interleaving import wave_barrier
from repro.core.packing import PicassoPlan
from repro.embedding.state import EmbeddingState
from repro.engine.strategies import LookupStrategy, get_strategy

Axes = Union[str, Tuple[str, ...]]


class EngineContext(NamedTuple):
    """Everything ``backward`` needs from a ``forward`` call (a pytree)."""

    ctxs: Dict[int, Any]            # gid -> strategy lookup ctx
    packed: Dict[int, PackedBatch]  # gid -> the packed batch it served


class EmbeddingEngine:
    """Owns the full sparse path for one PicassoPlan on one mesh.

    Parameters
    ----------
    plan: the planner output (groups, capacities, waves, cache budget, and
        optionally a per-group strategy assignment).
    axes/world: mesh axes the engine's collectives run over, and their size.
    strategy: a registry name (broadcast), ``'mixed'``/``'auto'`` (use or
        compile a per-group assignment), a ``{gid: name}`` dict, or a
        ``StrategyAssignment`` — see ``repro.core.assign``.
    use_cache: enable the HybridHash hot tier (honoured per group: only
        where the assigned strategy has ``uses_cache=True`` and the plan
        budgets a non-zero cache for that gid).
    use_interleave: issue lookups in the planner's K-Interleaving waves;
        ``False`` collapses to a single wave.
    lr_emb/eps: row-wise adagrad hyperparameters for the sparse update.
    cache_update: ``'psum'`` (exact, replica-consistent hot tier) or
        ``'stale'`` (Algorithm 1 bounded-staleness semantics).
    capacity: optional per-gid override of the all_to_all bucket capacity
        (e.g. retrieval candidate towers that look up far more ids per shard
        than the training batch the plan was sized for).
    """

    def __init__(self, plan: PicassoPlan, axes: Axes, world: int, *,
                 strategy: StrategySpec = "picasso", use_cache: bool = True,
                 use_interleave: bool = True, lr_emb: float = 0.05,
                 eps: float = 1e-8, cache_update: str = "psum",
                 capacity: Optional[Dict[int, int]] = None):
        self.plan = plan
        self.axes = axes
        self.world = world
        self.cache_update = cache_update
        # gid -> registry name; raises on unknown names / partial coverage
        # (an auto-compiled assignment is recorded on the plan, so the
        # host-flush engine and later call sites gate caches identically)
        self.assignment: Dict[int, str] = resolve_assignment(
            plan, strategy, world=world, use_cache=use_cache)
        names = tuple(sorted(set(self.assignment.values())))
        self.strategy_names = names
        self.strategy_name = names[0] if len(names) == 1 else "mixed"
        cap = dict(capacity if capacity is not None else plan.capacity)
        # one instance per distinct name (they are stateless per-call), one
        # dispatch-map entry per group
        insts: Dict[str, LookupStrategy] = {
            name: get_strategy(name)(
                axes=axes, world=world, capacity=cap, lr=lr_emb, eps=eps,
                cache_update=cache_update)
            for name in names}
        self.strategies: Dict[int, LookupStrategy] = {
            gid: insts[name] for gid, name in self.assignment.items()}
        # per-group cache gating: strategy must use the tier AND the plan
        # must budget rows for this gid
        self.cache_on: Dict[int, bool] = {
            g.gid: bool(use_cache
                        and self.strategies[g.gid].uses_cache
                        and plan.cache_rows.get(g.gid, 0) > 0)
            for g in plan.groups}
        self.any_cache = any(self.cache_on.values())
        self.waves = (plan.interleave if use_interleave
                      else [[g.gid for g in plan.groups]])

    @property
    def metric_keys(self) -> Tuple[str, ...]:
        """Static metric pytree keys ``backward`` emits (callers build
        shard_map out_specs from this)."""
        keys = ["overflow", "cache_hits"]
        if len(self.strategy_names) > 1:
            keys += [f"overflow/{n}" for n in self.strategy_names]
            keys += [f"cache_hits/{n}" for n in self.strategy_names]
        return tuple(keys)

    # ------------------------------------------------------------- forward
    def _wave_lookups(self, emb: Dict[str, EmbeddingState],
                      packed: Dict[int, PackedBatch]
                      ) -> Tuple[Dict[int, jnp.ndarray], Dict[int, Any]]:
        """Per-group lookups in K-Interleaving waves (Fig. 8c), each group
        through its own assigned strategy."""
        rows: Dict[int, jnp.ndarray] = {}
        ctxs: Dict[int, Any] = {}
        ids_in = {g.gid: packed[g.gid].ids for g in self.plan.groups}
        for wi, wave in enumerate(self.waves):
            if wi > 0:
                # wave wi's inputs pass through one barrier with wave wi-1's
                # outputs -> a real control boundary between the all_to_alls.
                prev = self.waves[wi - 1]
                flat = wave_barrier([rows[g] for g in prev]
                                    + [ids_in[g] for g in wave])
                for g, v in zip(prev, flat[: len(prev)]):
                    rows[g] = v
                for j, g in enumerate(wave):
                    ids_in[g] = flat[len(prev) + j]
            for gid in wave:
                rows[gid], ctxs[gid] = self.strategies[gid].lookup(
                    emb[str(gid)], gid, ids_in[gid],
                    cache_on=self.cache_on[gid])
        return rows, ctxs

    def forward(self, emb: Dict[str, EmbeddingState],
                packed: Dict[int, PackedBatch]
                ) -> Tuple[Dict[int, jnp.ndarray], EngineContext]:
        """Packed batch -> pooled group outputs ``[B, n_bags, D]`` + ctx."""
        rows, ctxs = self._wave_lookups(emb, packed)
        pooled = {}
        for gid, pb in packed.items():
            g = self.plan.group(gid)
            b = pb.ids.shape[0] // g.ids_per_sample
            p = pe.pool(rows[gid], ctxs[gid].inv, pb.weights, pb.seg,
                        b * g.n_bags)
            pooled[gid] = p.reshape(b, g.n_bags, g.dim)
        return pooled, EngineContext(ctxs=ctxs, packed=dict(packed))

    def lookup_rows(self, emb: Dict[str, EmbeddingState], gid: int,
                    ids: jnp.ndarray) -> jnp.ndarray:
        """Raw per-id rows ``[n, D]`` for one group (retrieval towers)."""
        rows_u, ctx = self.strategies[gid].lookup(
            emb[str(gid)], gid, ids, cache_on=self.cache_on[gid])
        return jnp.take(rows_u, ctx.inv, axis=0)

    # ------------------------------------------------------------ backward
    def backward(self, emb: Dict[str, EmbeddingState], ctx: EngineContext,
                 g_pooled: Dict[int, jnp.ndarray]
                 ) -> Tuple[Dict[str, EmbeddingState], Dict[str, jnp.ndarray]]:
        """Pooled grads -> sparse updates. Returns (emb', local metrics).

        The SegmentReduction of ``forward`` is linear in the looked-up rows,
        so its transpose is explicit: ``g_rows[u] = sum_{i: inv[i]=u} w[i] *
        g_pooled[seg[i]]``. Metrics are per-shard sums; callers psum them.
        With a mixed assignment, ``overflow/<name>`` and ``cache_hits/<name>``
        break the totals down per strategy class (see ``metric_keys``).
        """
        emb = dict(emb)
        zero = jnp.zeros((), jnp.int32)
        ovf = {n: zero for n in self.strategy_names}
        hits = {n: zero for n in self.strategy_names}
        for gid, g_p in g_pooled.items():
            pb = ctx.packed[gid]
            gctx = ctx.ctxs[gid]
            name = self.assignment[gid]
            g_flat = g_p.reshape(-1, g_p.shape[-1])
            per_id = (jnp.take(g_flat, pb.seg, axis=0)
                      * pb.weights[:, None].astype(g_flat.dtype))
            g_rows = jax.ops.segment_sum(per_id, gctx.inv,
                                         num_segments=pb.ids.shape[0])
            st2, o, h = self.strategies[gid].apply_grads(
                emb[str(gid)], gid, gctx, g_rows, cache_on=self.cache_on[gid])
            emb[str(gid)] = st2
            ovf[name] = ovf[name] + o
            hits[name] = hits[name] + h
        metrics = {"overflow": sum(ovf.values(), zero),
                   "cache_hits": sum(hits.values(), zero)}
        if len(self.strategy_names) > 1:
            for n in self.strategy_names:
                metrics[f"overflow/{n}"] = ovf[n]
                metrics[f"cache_hits/{n}"] = hits[n]
        return emb, metrics

    # --------------------------------------------------------------- flush
    def flush(self, emb: Dict[str, EmbeddingState]) -> Dict[str, EmbeddingState]:
        """HybridHash flush (Algorithm 1 L23-26) for every *cached* group —
        groups whose assigned strategy never reads the tier are skipped even
        when the plan budgets rows for them."""
        out = dict(emb)
        for g in self.plan.groups:
            if not self.cache_on.get(g.gid, False):
                continue
            st = out[str(g.gid)]
            w2, acc2, counts2, cache2 = pe.flush_cache(
                st.w, st.acc, st.counts, st.cache, axes=self.axes,
                world=self.world, write_back=self.cache_update == "psum")
            out[str(g.gid)] = EmbeddingState(w2, acc2, counts2, cache2)
        return out
