"""EmbeddingEngine: the one owner of PICASSO's packed sparse path.

Architecture (engine layer)
---------------------------

Every workload — hybrid MP/DP training, online/bulk serving, two-tower
retrieval, and the dry-run cells — consumes the *same* engine instead of
re-implementing the ``pack_group -> lookup -> pool`` loop:

    EmbeddingEngine(plan, axes, world, strategy=<name>)
        .forward(emb, packed)          -> (pooled, ctx)     # K-interleaved
        .backward(emb, ctx, g_pooled)  -> (emb', metrics)   # transposed path
        .flush(emb)                    -> emb'              # HybridHash flush
        .lookup_rows(emb, gid, ids)    -> rows              # raw per-id rows

``forward`` runs the planner's K-Interleaving waves (lookups of wave k+1 are
pinned behind a barrier with wave k's outputs, Fig. 8c) and pools each packed
group into ``pooled[gid]: [B, n_bags, D]``. ``backward`` takes the loss
gradient w.r.t. those pooled tensors, applies the (linear) SegmentReduction
transpose to recover per-row gradients, and hands them to the strategy's
update path; it also folds cache hit / bucket overflow counters into metrics.
``ctx`` is a pytree, so engine calls compose with ``jax.value_and_grad``,
``lax.cond`` and the D-Interleaving micro-batch pipeline in the train step.

The sparse *mechanism* (which collectives move ids and gradients, whether a
hot tier absorbs the skew head) is a ``LookupStrategy`` selected by name from
the registry in ``repro.engine.strategies`` — ``'picasso'``, ``'hybrid'``,
``'ps'``. Scenario PRs add strategies; they do not touch this file's callers.

All shapes are static: the engine runs inside ``shard_map`` on TPU meshes.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import packed_embedding as pe
from repro.core.features import PackedBatch
from repro.core.interleaving import wave_barrier
from repro.core.packing import PicassoPlan
from repro.embedding.state import EmbeddingState
from repro.engine.strategies import LookupStrategy, get_strategy

Axes = Union[str, Tuple[str, ...]]


class EngineContext(NamedTuple):
    """Everything ``backward`` needs from a ``forward`` call (a pytree)."""

    ctxs: Dict[int, Any]            # gid -> strategy lookup ctx
    packed: Dict[int, PackedBatch]  # gid -> the packed batch it served


class EmbeddingEngine:
    """Owns the full sparse path for one PicassoPlan on one mesh.

    Parameters
    ----------
    plan: the planner output (groups, capacities, waves, cache budget).
    axes/world: mesh axes the engine's collectives run over, and their size.
    strategy: registry name — ``'picasso' | 'hybrid' | 'ps'`` (see
        ``repro.engine.strategies.available_strategies()``).
    use_cache: enable the HybridHash hot tier (only honoured by strategies
        with ``uses_cache=True`` and plans with a non-zero cache budget).
    use_interleave: issue lookups in the planner's K-Interleaving waves;
        ``False`` collapses to a single wave.
    lr_emb/eps: row-wise adagrad hyperparameters for the sparse update.
    cache_update: ``'psum'`` (exact, replica-consistent hot tier) or
        ``'stale'`` (Algorithm 1 bounded-staleness semantics).
    capacity: optional per-gid override of the all_to_all bucket capacity
        (e.g. retrieval candidate towers that look up far more ids per shard
        than the training batch the plan was sized for).
    """

    def __init__(self, plan: PicassoPlan, axes: Axes, world: int, *,
                 strategy: str = "picasso", use_cache: bool = True,
                 use_interleave: bool = True, lr_emb: float = 0.05,
                 eps: float = 1e-8, cache_update: str = "psum",
                 capacity: Optional[Dict[int, int]] = None):
        cls = get_strategy(strategy)   # raises on unknown names
        self.plan = plan
        self.axes = axes
        self.world = world
        self.strategy_name = strategy
        self.cache_update = cache_update
        self.strategy: LookupStrategy = cls(
            axes=axes, world=world,
            capacity=dict(capacity if capacity is not None else plan.capacity),
            lr=lr_emb, eps=eps, cache_update=cache_update)
        self.cache_on = (use_cache and cls.uses_cache
                         and any(plan.cache_rows.get(g.gid, 0) > 0
                                 for g in plan.groups))
        self.waves = (plan.interleave if use_interleave
                      else [[g.gid for g in plan.groups]])

    # ------------------------------------------------------------- forward
    def _wave_lookups(self, emb: Dict[str, EmbeddingState],
                      packed: Dict[int, PackedBatch]
                      ) -> Tuple[Dict[int, jnp.ndarray], Dict[int, Any]]:
        """Per-group lookups in K-Interleaving waves (Fig. 8c)."""
        rows: Dict[int, jnp.ndarray] = {}
        ctxs: Dict[int, Any] = {}
        ids_in = {g.gid: packed[g.gid].ids for g in self.plan.groups}
        for wi, wave in enumerate(self.waves):
            if wi > 0:
                # wave wi's inputs pass through one barrier with wave wi-1's
                # outputs -> a real control boundary between the all_to_alls.
                prev = self.waves[wi - 1]
                flat = wave_barrier([rows[g] for g in prev]
                                    + [ids_in[g] for g in wave])
                for g, v in zip(prev, flat[: len(prev)]):
                    rows[g] = v
                for j, g in enumerate(wave):
                    ids_in[g] = flat[len(prev) + j]
            for gid in wave:
                rows[gid], ctxs[gid] = self.strategy.lookup(
                    emb[str(gid)], gid, ids_in[gid], cache_on=self.cache_on)
        return rows, ctxs

    def forward(self, emb: Dict[str, EmbeddingState],
                packed: Dict[int, PackedBatch]
                ) -> Tuple[Dict[int, jnp.ndarray], EngineContext]:
        """Packed batch -> pooled group outputs ``[B, n_bags, D]`` + ctx."""
        rows, ctxs = self._wave_lookups(emb, packed)
        pooled = {}
        for gid, pb in packed.items():
            g = self.plan.group(gid)
            b = pb.ids.shape[0] // g.ids_per_sample
            p = pe.pool(rows[gid], ctxs[gid].inv, pb.weights, pb.seg,
                        b * g.n_bags)
            pooled[gid] = p.reshape(b, g.n_bags, g.dim)
        return pooled, EngineContext(ctxs=ctxs, packed=dict(packed))

    def lookup_rows(self, emb: Dict[str, EmbeddingState], gid: int,
                    ids: jnp.ndarray) -> jnp.ndarray:
        """Raw per-id rows ``[n, D]`` for one group (retrieval towers)."""
        rows_u, ctx = self.strategy.lookup(emb[str(gid)], gid, ids,
                                           cache_on=self.cache_on)
        return jnp.take(rows_u, ctx.inv, axis=0)

    # ------------------------------------------------------------ backward
    def backward(self, emb: Dict[str, EmbeddingState], ctx: EngineContext,
                 g_pooled: Dict[int, jnp.ndarray]
                 ) -> Tuple[Dict[str, EmbeddingState], Dict[str, jnp.ndarray]]:
        """Pooled grads -> sparse updates. Returns (emb', local metrics).

        The SegmentReduction of ``forward`` is linear in the looked-up rows,
        so its transpose is explicit: ``g_rows[u] = sum_{i: inv[i]=u} w[i] *
        g_pooled[seg[i]]``. Metrics are per-shard sums; callers psum them.
        """
        emb = dict(emb)
        ovf = jnp.zeros((), jnp.int32)
        hits = jnp.zeros((), jnp.int32)
        for gid, g_p in g_pooled.items():
            pb = ctx.packed[gid]
            gctx = ctx.ctxs[gid]
            g_flat = g_p.reshape(-1, g_p.shape[-1])
            per_id = (jnp.take(g_flat, pb.seg, axis=0)
                      * pb.weights[:, None].astype(g_flat.dtype))
            g_rows = jax.ops.segment_sum(per_id, gctx.inv,
                                         num_segments=pb.ids.shape[0])
            st2, o, h = self.strategy.apply_grads(
                emb[str(gid)], gid, gctx, g_rows, cache_on=self.cache_on)
            emb[str(gid)] = st2
            ovf = ovf + o
            hits = hits + h
        return emb, {"overflow": ovf, "cache_hits": hits}

    # --------------------------------------------------------------- flush
    def flush(self, emb: Dict[str, EmbeddingState]) -> Dict[str, EmbeddingState]:
        """HybridHash flush (Algorithm 1 L23-26) for every cached group."""
        out = dict(emb)
        for g in self.plan.groups:
            if self.plan.cache_rows.get(g.gid, 0) == 0:
                continue
            st = out[str(g.gid)]
            w2, acc2, counts2, cache2 = pe.flush_cache(
                st.w, st.acc, st.counts, st.cache, axes=self.axes,
                world=self.world, write_back=self.cache_update == "psum")
            out[str(g.gid)] = EmbeddingState(w2, acc2, counts2, cache2)
        return out
