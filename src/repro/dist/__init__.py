"""Distributed execution utilities: sharding specs + jax version compat."""
from repro.dist.compat import make_mesh_compat, shard_map
from repro.dist.sharding import (batch_specs, emb_specs, emb_state_specs,
                                 replicated, state_specs, to_named)

__all__ = [
    "batch_specs",
    "emb_specs",
    "emb_state_specs",
    "make_mesh_compat",
    "replicated",
    "shard_map",
    "state_specs",
    "to_named",
]
