"""Version shims so the repo runs on any jax from 0.4.3x to current.

Two API drifts are absorbed here:

* ``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
  and its replication-check kwarg was renamed ``check_rep`` -> ``check_vma``.
* ``jax.make_mesh`` grew an ``axis_types`` kwarg (and ``jax.sharding.AxisType``)
  that older versions reject.

Every shard_map/make_mesh call in the repo goes through these wrappers; no
other module should touch the raw jax entry points.
"""
from __future__ import annotations

import inspect
from typing import Any, Optional, Sequence, Tuple

import jax

if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
else:  # jax < 0.6: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_SHARD_MAP_KWARGS = frozenset(inspect.signature(_shard_map_impl).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None):
    """``jax.shard_map`` with the replication check disabled portably.

    ``check_vma=False`` (new name) / ``check_rep=False`` (old name) is required
    because the engine's collectives produce values jax cannot prove replicated.
    """
    kw = {}
    if check_vma is not None:
        if "check_vma" in _SHARD_MAP_KWARGS:
            kw["check_vma"] = check_vma
        elif "check_rep" in _SHARD_MAP_KWARGS:
            kw["check_rep"] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_mesh_compat(shape: Sequence[int], axes: Sequence[str]):
    """``jax.make_mesh`` with Auto axis types where the version supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_submesh_compat(shape: Sequence[int], axes: Sequence[str]):
    """A mesh over the FIRST ``prod(shape)`` available devices.

    ``jax.make_mesh`` insists on using every device in the process, so an
    elastic scale-down (world 8 -> 4 within one process) needs the raw
    ``jax.sharding.Mesh`` constructor over a device-array subset. When the
    shape covers all devices this defers to ``make_mesh_compat`` (identical
    mesh, best available axis types / device order heuristics).
    """
    import numpy as np

    n = 1
    for s in shape:
        n *= int(s)
    devs = jax.devices()
    if n > len(devs):
        raise ValueError(
            f"mesh shape {tuple(shape)} needs {n} devices but only "
            f"{len(devs)} are available")
    if n == len(devs):
        return make_mesh_compat(shape, axes)
    arr = np.asarray(devs[:n]).reshape(tuple(int(s) for s in shape))
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.sharding.Mesh(
                arr, tuple(axes), axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:  # older Mesh without axis_types
            pass
    return jax.sharding.Mesh(arr, tuple(axes))
