"""PartitionSpec builders for the hybrid MP/DP layout (paper §III-A).

One convention everywhere:

* embedding tables / adagrad accs / FCounters — row-sharded over the *whole*
  mesh (every chip is a model-parallel shard);
* the HybridHash hot tier — replicated (a hit is a local gather);
* dense params + optimizer moments — replicated (DP side of the hybrid);
* batches — leading dim sharded over the whole mesh (every chip also holds a
  data shard: that is PICASSO's "hybrid" placement).

These spec pytrees mirror the state pytrees exactly (same dict keys, same
NamedTuple containers), so they serve as ``shard_map`` in/out specs and — via
``to_named`` — as ``jit`` in/out shardings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.packed_embedding import CacheState, ProjState
from repro.core.packing import PicassoPlan
from repro.embedding.state import EmbeddingState

Axes = Union[str, Tuple[str, ...]]


def _is_spec(x: Any) -> bool:
    return isinstance(x, P)


def replicated(tree: Any) -> Any:
    """Fully-replicated specs matching ``tree``'s structure (rank-aware)."""
    return jax.tree.map(lambda x: P(*((None,) * len(x.shape))), tree)


def to_named(mesh, specs: Any) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs, is_leaf=_is_spec)


def batch_specs(batch: Any, axes: Axes) -> Any:
    """Shard every batch leaf's leading dim over the full mesh (hybrid DP)."""
    return jax.tree.map(
        lambda x: P(axes, *((None,) * (len(x.shape) - 1))), batch)


def emb_state_specs(axes: Axes, with_l2: bool = False,
                    with_proj: bool = False) -> EmbeddingState:
    """Specs for one packed group's EmbeddingState (table MP, tiers DP).

    ``with_l2`` mirrors whether the group's state carries an L2 host tier
    (``plan.l2_rows[gid] > 0``); like the hot tier it is replicated across
    the mesh — on TPU its leaves additionally live in pinned host memory,
    which PartitionSpecs cannot express: use ``emb_shardings(pin_l2=True)``
    for the memory-kind-aware NamedShardings. ``with_proj`` mirrors a narrow
    master
    (``plan.narrow_width(gid) < dim``): the learned ``[d, D]`` up-projection
    is replicated — its gradient is psum'd so replicas stay bit-identical.
    """
    return EmbeddingState(
        w=P(axes, None),
        acc=P(axes, None),
        counts=P(axes),
        cache=CacheState(keys=P(), rows=P(), acc=P()),
        l2=CacheState(keys=P(), rows=P(), acc=P()) if with_l2 else None,
        proj=ProjState(kernel=P(None, None), acc=P(None, None))
        if with_proj else None,
    )


def emb_specs(plan: PicassoPlan, axes: Axes) -> Dict[str, EmbeddingState]:
    """Specs for the full per-group embedding dict (the ``"emb"`` subtree)."""
    return {str(g.gid): emb_state_specs(
        axes, with_l2=plan.l2_rows.get(g.gid, 0) > 0,
        with_proj=plan.narrow_width(g.gid) < g.dim)
            for g in plan.groups}


def host_memory_kind() -> Optional[str]:
    """The backend's pinned-host memory kind, or ``None`` where there is no
    addressable host memory space (the CPU rig) — the capability check every
    memory-kind-aware builder gates on."""
    try:
        return jax.local_devices()[0].memory("pinned_host").kind
    except Exception:
        return None


def emb_shardings(plan: PicassoPlan, mesh, axes: Axes, *,
                  pin_l2: bool = False) -> Dict[str, EmbeddingState]:
    """``emb_specs`` as NamedShardings — optionally memory-kind-aware.

    PartitionSpecs cannot express a memory space, so ``--pin-l2`` placement
    used to be undone by the first jitted step (its in/out shardings re-staged
    the L2 tier into device memory). With ``pin_l2=True`` — and only where
    the backend actually exposes a ``pinned_host`` memory kind
    (``host_memory_kind``) — the cold-side leaves get host-memory
    NamedShardings instead: every L2 tier leaf, and the narrow master
    (``w``/``acc``, still row-sharded over ``axes``) of groups whose planned
    width is narrowed — exactly the state the cost model prices as
    host-resident. Everything else keeps its device placement, and on
    backends without host memory kinds the result is bit-identical to
    ``to_named(mesh, emb_specs(...))``.
    """
    named = to_named(mesh, emb_specs(plan, axes))
    kind = host_memory_kind() if pin_l2 else None
    if kind is None:
        return named

    def pin(s: NamedSharding) -> NamedSharding:
        return NamedSharding(mesh, s.spec, memory_kind=kind)

    out: Dict[str, EmbeddingState] = {}
    for g in plan.groups:
        st = named[str(g.gid)]
        if st.l2 is not None:
            st = st._replace(l2=jax.tree.map(pin, st.l2))
        if plan.narrow_width(g.gid) < g.dim:
            st = st._replace(w=pin(st.w), acc=pin(st.acc))
        out[str(g.gid)] = st
    return out


def state_shardings(plan: PicassoPlan, mesh, axes: Axes, dense: Any,
                    opt: Optional[Any] = None, *,
                    pin_l2: bool = False) -> Dict[str, Any]:
    """``state_specs`` as NamedShardings, with ``emb_shardings``' optional
    host-memory placement for the cold tiers (jit in/out shardings for the
    train/serve steps — this is what keeps a pinned L2 tier pinned *across*
    steps instead of being silently re-staged onto device)."""
    named = to_named(mesh, state_specs(plan, axes, dense, opt))
    if pin_l2:
        named["emb"] = emb_shardings(plan, mesh, axes, pin_l2=True)
    return named


def state_specs(plan: PicassoPlan, axes: Axes, dense: Any,
                opt: Optional[Any] = None) -> Dict[str, Any]:
    """Specs for the full train/serve state pytree.

    ``opt=None`` builds the serve-time subset (no optimizer, no step counter);
    callers then index ``["emb"]`` / ``["dense"]`` as needed.
    """
    specs: Dict[str, Any] = {
        "emb": emb_specs(plan, axes),
        "dense": replicated(dense),
    }
    if opt is not None:
        specs["opt"] = replicated(opt)
        specs["step"] = P()
    return specs
