"""PartitionSpec builders for the hybrid MP/DP layout (paper §III-A).

One convention everywhere:

* embedding tables / adagrad accs / FCounters — row-sharded over the *whole*
  mesh (every chip is a model-parallel shard);
* the HybridHash hot tier — replicated (a hit is a local gather);
* dense params + optimizer moments — replicated (DP side of the hybrid);
* batches — leading dim sharded over the whole mesh (every chip also holds a
  data shard: that is PICASSO's "hybrid" placement).

These spec pytrees mirror the state pytrees exactly (same dict keys, same
NamedTuple containers), so they serve as ``shard_map`` in/out specs and — via
``to_named`` — as ``jit`` in/out shardings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.packed_embedding import CacheState, ProjState
from repro.core.packing import PicassoPlan
from repro.embedding.state import EmbeddingState

Axes = Union[str, Tuple[str, ...]]


def _is_spec(x: Any) -> bool:
    return isinstance(x, P)


def replicated(tree: Any) -> Any:
    """Fully-replicated specs matching ``tree``'s structure (rank-aware)."""
    return jax.tree.map(lambda x: P(*((None,) * len(x.shape))), tree)


def to_named(mesh, specs: Any) -> Any:
    """PartitionSpec pytree -> NamedSharding pytree on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs, is_leaf=_is_spec)


def batch_specs(batch: Any, axes: Axes) -> Any:
    """Shard every batch leaf's leading dim over the full mesh (hybrid DP)."""
    return jax.tree.map(
        lambda x: P(axes, *((None,) * (len(x.shape) - 1))), batch)


def emb_state_specs(axes: Axes, with_l2: bool = False,
                    with_proj: bool = False) -> EmbeddingState:
    """Specs for one packed group's EmbeddingState (table MP, tiers DP).

    ``with_l2`` mirrors whether the group's state carries an L2 host tier
    (``plan.l2_rows[gid] > 0``); like the hot tier it is replicated across
    the mesh — on TPU its leaves additionally live in pinned host memory
    (see ``repro.embedding.state.pin_l2_to_host``), which PartitionSpecs do
    not express. ``with_proj`` mirrors a narrow master
    (``plan.narrow_width(gid) < dim``): the learned ``[d, D]`` up-projection
    is replicated — its gradient is psum'd so replicas stay bit-identical.
    """
    return EmbeddingState(
        w=P(axes, None),
        acc=P(axes, None),
        counts=P(axes),
        cache=CacheState(keys=P(), rows=P(), acc=P()),
        l2=CacheState(keys=P(), rows=P(), acc=P()) if with_l2 else None,
        proj=ProjState(kernel=P(None, None), acc=P(None, None))
        if with_proj else None,
    )


def emb_specs(plan: PicassoPlan, axes: Axes) -> Dict[str, EmbeddingState]:
    """Specs for the full per-group embedding dict (the ``"emb"`` subtree)."""
    return {str(g.gid): emb_state_specs(
        axes, with_l2=plan.l2_rows.get(g.gid, 0) > 0,
        with_proj=plan.narrow_width(g.gid) < g.dim)
            for g in plan.groups}


def state_specs(plan: PicassoPlan, axes: Axes, dense: Any,
                opt: Optional[Any] = None) -> Dict[str, Any]:
    """Specs for the full train/serve state pytree.

    ``opt=None`` builds the serve-time subset (no optimizer, no step counter);
    callers then index ``["emb"]`` / ``["dense"]`` as needed.
    """
    specs: Dict[str, Any] = {
        "emb": emb_specs(plan, axes),
        "dense": replicated(dense),
    }
    if opt is not None:
        specs["opt"] = replicated(opt)
        specs["step"] = P()
    return specs
