"""The paper's own evaluation models (§IV): W&D, DLRM, DIN, DIEN, MMoE, CAN.

``full()`` variants approximate the production field statistics of Tab. II
(Product-1/2/3 / Criteo / Alibaba); ``bench()`` variants are CPU-sized siblings
used by benchmarks/ so the paper's tables can be exercised on this container.
These are *baselines the paper compares against / trains* — not part of the ten
assigned architectures, but required because "if the paper compares against a
baseline, implement the baseline too".
"""
from repro.configs.base import FeatureField, InteractionSpec, WDLConfig
from repro.configs.criteo import CRITEO_VOCABS, N_DENSE


def _seq_fields(prefix, n, vocab, dim, max_len, group):
    return [
        FeatureField(f"{prefix}_{i}", vocab=vocab, dim=dim, max_len=max_len, pooling="sum", group=group)
        for i in range(n)
    ]


def widedeep(scale: float = 1.0, dims=(8, 16, 32)) -> WDLConfig:
    """W&D on Product-1: 10 numeric + 204 sparse fields, emb dims 8~32."""
    n = max(1, int(204 * scale))
    fields = []
    for i in range(n):
        dim = dims[i % len(dims)]
        vocab = int((10_000 + 997 * i * 31) * max(scale, 0.01)) + 64
        fields.append(FeatureField(f"f{i}", vocab=vocab, dim=dim, max_len=1, pooling="sum"))
    return WDLConfig(
        name="widedeep",
        fields=tuple(fields),
        n_dense=10,
        interactions=(InteractionSpec("linear"),),
        mlp_dims=(512, 256, 128) if scale >= 1 else (32, 16),
    )


def dlrm(criteo: bool = True, scale: float = 1.0) -> WDLConfig:
    """DLRM on Criteo, emb dim 128 (Tab. II)."""
    if criteo and scale >= 1:
        vocabs = CRITEO_VOCABS
        dim, mlp, bot = 128, (1024, 1024, 512, 256), (512, 256, 128)
    else:
        vocabs = tuple(int(500 + 61 * i) for i in range(26))
        dim, mlp, bot = 16, (64, 32), (32, 16)
    fields = tuple(
        FeatureField(f"cat_{i}", vocab=int(v), dim=dim, max_len=1, pooling="sum") for i, v in enumerate(vocabs)
    )
    return WDLConfig(
        name="dlrm",
        fields=fields,
        n_dense=N_DENSE,
        interactions=(InteractionSpec("dot"),),
        mlp_dims=mlp,
        dense_arch=bot,
    )


def din(scale: float = 1.0) -> WDLConfig:
    """DIN on Alibaba: 1207 fields = 7 one-hot + 12 behaviour seqs x ~100, dim 4."""
    big = scale >= 1
    n_seq = 12 if big else 3
    seq_len = 100 if big else 8
    vocab = 2_000_000 if big else 3000
    dim = 4 if big else 8
    fields = [FeatureField(f"prof_{i}", vocab=10_000 if big else 500, dim=dim) for i in range(7)]
    for i in range(n_seq):
        fields.append(
            FeatureField(f"hist_{i}", vocab=vocab, dim=dim, max_len=seq_len, pooling="none", group="seq")
        )
    fields.append(FeatureField("target_item", vocab=vocab, dim=dim, group="target", shared_table="hist_0"))
    return WDLConfig(
        name="din",
        fields=tuple(fields),
        n_dense=0,
        interactions=(
            InteractionSpec("target_attn", fields=tuple(f"hist_{i}" for i in range(n_seq)) + ("target_item",),
                            kwargs={"seq_len": seq_len}),
        ),
        mlp_dims=(200, 80) if big else (32, 16),
    )


def mmoe(scale: float = 1.0) -> WDLConfig:
    """MMoE variant of §II-D: 94 fields (84 one-hot + 10 seqs x 50), 71 experts."""
    big = scale >= 1
    n_onehot, n_seq, seq_len = (84, 10, 50) if big else (12, 2, 6)
    n_experts, n_tasks = (71, 4) if big else (5, 2)
    dims = (12, 32, 64, 128) if big else (8, 16)
    fields = [
        FeatureField(f"f{i}", vocab=(50_000 if big else 700) + 13 * i, dim=dims[i % len(dims)])
        for i in range(n_onehot)
    ]
    fields += _seq_fields("hist", n_seq, 1_000_000 if big else 900, dims[0], seq_len, "seq")
    return WDLConfig(
        name="mmoe",
        fields=tuple(fields),
        n_dense=0,
        interactions=(InteractionSpec("mmoe", kwargs={"n_experts": n_experts, "expert_dim": 256 if big else 16}),),
        mlp_dims=(512, 256) if big else (16,),
        n_tasks=n_tasks,
    )


def can(scale: float = 1.0) -> WDLConfig:
    """CAN on Product-2: 1834 fields = 334 one-hot + 30 seqs x 50, dims 8~200."""
    big = scale >= 1
    n_onehot, n_seq, seq_len = (334, 30, 50) if big else (10, 3, 6)
    dims = (8, 16, 64, 200) if big else (8, 16)
    fields = [
        FeatureField(f"f{i}", vocab=(100_000 if big else 800) + 17 * i, dim=dims[i % len(dims)])
        for i in range(n_onehot)
    ]
    for i in range(n_seq):
        fields.append(
            FeatureField(f"hist_{i}", vocab=5_000_000 if big else 1200, dim=dims[0],
                         max_len=seq_len, pooling="none", group="seq")
        )
    fields.append(FeatureField("target_item", vocab=5_000_000 if big else 1200, dim=dims[0],
                               group="target", shared_table="hist_0"))
    # CAN = co-action (target x history MLP-as-weights) + DIN-style attention branches
    return WDLConfig(
        name="can",
        fields=tuple(fields),
        n_dense=0,
        interactions=(
            InteractionSpec("target_attn", fields=tuple(f"hist_{i}" for i in range(n_seq)) + ("target_item",),
                            kwargs={"seq_len": seq_len}),
            InteractionSpec("coaction", fields=("hist_0", "target_item"), kwargs={"seq_len": seq_len}),
        ),
        mlp_dims=(512, 256, 128) if big else (32, 16),
    )


PAPER_MODELS = {"widedeep": widedeep, "dlrm": dlrm, "din": din, "mmoe": mmoe, "can": can}
