"""mind [arXiv:1904.08030].

embed_dim=64 n_interests=4 capsule_iters=3 — multi-interest extraction via
dynamic-routing capsules over the user behaviour sequence, then label-aware
attention against the target item. Industrial item catalogue (20M items).
"""
from repro.configs.base import RECSYS_SHAPES, FeatureField, InteractionSpec, WDLConfig, register_arch

ITEM_VOCAB = 20_000_000
SEQ_LEN = 50


def _cfg(item_vocab, dim, seq_len, mlp) -> WDLConfig:
    return WDLConfig(
        name="mind",
        fields=(
            FeatureField("hist_items", vocab=item_vocab, dim=dim, max_len=seq_len, pooling="none", group="seq"),
            FeatureField("target_item", vocab=item_vocab, dim=dim, max_len=1, pooling="sum",
                         group="target", shared_table="hist_items"),
            # user profile fields (gender / age-bucket / city), concatenated to interests
            FeatureField("user_gender", vocab=4, dim=dim, max_len=1, pooling="sum", group="profile"),
            FeatureField("user_age", vocab=16, dim=dim, max_len=1, pooling="sum", group="profile"),
            FeatureField("user_city", vocab=2048, dim=dim, max_len=1, pooling="sum", group="profile"),
        ),
        n_dense=0,
        interactions=(
            InteractionSpec(
                "capsule",
                fields=("hist_items", "target_item"),
                kwargs={"n_interests": 4, "routing_iters": 3, "seq_len": seq_len},
            ),
        ),
        mlp_dims=mlp,
    )


def full() -> WDLConfig:
    return _cfg(ITEM_VOCAB, 64, SEQ_LEN, (256, 64))


def smoke() -> WDLConfig:
    c = _cfg(4000, 16, 8, (32,))
    return WDLConfig(**{**c.__dict__, "name": "mind-smoke"})


register_arch("mind", full, smoke, RECSYS_SHAPES)
