"""schnet [arXiv:1706.08566].

n_interactions=3 d_hidden=64 rbf=300 cutoff=10.
"""
from repro.configs.base import GNN_SHAPES, SchNetConfig, register_arch


def full() -> SchNetConfig:
    return SchNetConfig(
        name="schnet",
        n_interactions=3,
        d_hidden=64,
        n_rbf=300,
        cutoff=10.0,
    )


def smoke() -> SchNetConfig:
    return SchNetConfig(
        name="schnet-smoke",
        n_interactions=2,
        d_hidden=16,
        n_rbf=8,
        cutoff=5.0,
    )


register_arch("schnet", full, smoke, GNN_SHAPES)
