"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16 experts top-2.
"""
from repro.configs.base import LM_SHAPES, LMConfig, MoESpec, register_arch
from repro.configs.lm_family import FULL_ATTN_SKIP, smoke_of


def full() -> LMConfig:
    return LMConfig(
        name="phi3.5-moe-42b-a6.6b",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6400,
        vocab=32064,
        moe=MoESpec(n_experts=16, top_k=2, d_ff=6400),
        rope_theta=10000.0,
    )


def smoke() -> LMConfig:
    return smoke_of(full())


register_arch(
    "phi3.5-moe-42b-a6.6b", full, smoke, LM_SHAPES, skip_shapes=("long_500k",), skip_reason=FULL_ATTN_SKIP
)
