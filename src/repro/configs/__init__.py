from repro.configs.base import (
    FeatureField,
    InteractionSpec,
    LMConfig,
    MoESpec,
    SchNetConfig,
    ShapeSpec,
    WDLConfig,
    get_config,
    get_shapes,
    list_archs,
    register_arch,
    skipped_shapes,
)

__all__ = [
    "FeatureField",
    "InteractionSpec",
    "LMConfig",
    "MoESpec",
    "SchNetConfig",
    "ShapeSpec",
    "WDLConfig",
    "get_config",
    "get_shapes",
    "list_archs",
    "register_arch",
    "skipped_shapes",
]
