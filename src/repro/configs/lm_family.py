"""Shared helpers for the five assigned LM-family architectures."""
from repro.configs.base import LMConfig, MoESpec

FULL_ATTN_SKIP = (
    "long_500k requires sub-quadratic attention; this arch uses pure full "
    "(GQA) attention, so the 524288-token decode cell is skipped per the "
    "assignment note (see DESIGN.md §6)."
)


def smoke_of(cfg: LMConfig) -> LMConfig:
    """Reduced same-family config: tiny widths, few layers, same structure."""
    moe = None
    if cfg.moe is not None:
        moe = MoESpec(n_experts=min(4, cfg.moe.n_experts), top_k=min(2, cfg.moe.top_k), d_ff=64)
    return LMConfig(
        name=cfg.name + "-smoke",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128,
        vocab=256,
        moe=moe,
        swa_window=16 if cfg.swa_window else None,
        rope_theta=cfg.rope_theta,
        dtype="float32",
    )
