"""sasrec [arXiv:1808.09781].

embed_dim=50 n_blocks=2 n_heads=1 seq_len=50, self-attention over the user's
behaviour sequence. Industrial-scale item catalogue (10M items).
"""
from repro.configs.base import RECSYS_SHAPES, FeatureField, InteractionSpec, WDLConfig, register_arch

ITEM_VOCAB = 10_000_000
SEQ_LEN = 50


def _cfg(item_vocab, dim, mlp, seq_len) -> WDLConfig:
    return WDLConfig(
        name="sasrec",
        fields=(
            # behaviour history: sequence kept un-pooled, consumed by self-attn
            FeatureField("hist_items", vocab=item_vocab, dim=dim, max_len=seq_len, pooling="none", group="seq"),
            # positional embedding for the sequence
            FeatureField("pos", vocab=seq_len, dim=dim, max_len=seq_len, pooling="none", group="seq"),
            # target item shares the item table
            FeatureField("target_item", vocab=item_vocab, dim=dim, max_len=1, pooling="sum",
                         group="target", shared_table="hist_items"),
        ),
        n_dense=0,
        interactions=(
            InteractionSpec(
                "self_attn_seq",
                fields=("hist_items", "pos", "target_item"),
                kwargs={"n_blocks": 2, "n_heads": 1, "seq_len": seq_len, "causal": True},
            ),
        ),
        mlp_dims=mlp,
    )


def full() -> WDLConfig:
    return _cfg(ITEM_VOCAB, 50, (64,), SEQ_LEN)


def smoke() -> WDLConfig:
    c = _cfg(5000, 16, (16,), 10)
    return WDLConfig(**{**c.__dict__, "name": "sasrec-smoke"})


register_arch("sasrec", full, smoke, RECSYS_SHAPES)
