"""Criteo Terabyte per-field cardinalities (MLPerf DLRM reference list).

Used by deepfm / dcn-v2 (both are Criteo CTR models in their papers) and by the
paper-baseline DLRM config.
"""

# 26 categorical fields, Criteo 1TB (MLPerf reference preprocessing)
CRITEO_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)
N_DENSE = 13


def smoke_vocabs(n: int = 26, base: int = 1000):
    """Reduced-cardinality sibling for CPU smoke tests (same field count)."""
    return tuple(base + 37 * i for i in range(n))
