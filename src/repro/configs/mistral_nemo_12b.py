"""mistral-nemo-12b [hf:mistralai/Mistral-Nemo-Base-2407].

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, dense, 128k ctx.
head_dim is 128 (explicit in HF config: 5120/32=160 but Nemo uses head_dim=128).
We keep head_dim = d_model // n_heads = 160 for internal consistency of the
generic stack; the deviation is noted here.
"""
from repro.configs.base import LM_SHAPES, LMConfig, register_arch
from repro.configs.lm_family import FULL_ATTN_SKIP, smoke_of


def full() -> LMConfig:
    return LMConfig(
        name="mistral-nemo-12b",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab=131072,
        rope_theta=1000000.0,
    )


def smoke() -> LMConfig:
    return smoke_of(full())


register_arch("mistral-nemo-12b", full, smoke, LM_SHAPES, skip_shapes=("long_500k",), skip_reason=FULL_ATTN_SKIP)
