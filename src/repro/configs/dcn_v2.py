"""dcn-v2 [arXiv:2008.13535].

n_dense=13 n_sparse=26 embed_dim=16 n_cross_layers=3 mlp=1024-1024-512,
cross interaction (stacked structure: cross net -> deep net).
"""
from repro.configs.base import RECSYS_SHAPES, FeatureField, InteractionSpec, WDLConfig, register_arch
from repro.configs.criteo import CRITEO_VOCABS, N_DENSE, smoke_vocabs


def _fields(vocabs, dim):
    return tuple(
        FeatureField(name=f"cat_{i}", vocab=int(v), dim=dim, max_len=1, pooling="sum")
        for i, v in enumerate(vocabs)
    )


def full() -> WDLConfig:
    return WDLConfig(
        name="dcn-v2",
        fields=_fields(CRITEO_VOCABS, dim=16),
        n_dense=N_DENSE,
        interactions=(InteractionSpec("cross", kwargs={"n_layers": 3}),),
        mlp_dims=(1024, 1024, 512),
    )


def smoke() -> WDLConfig:
    return WDLConfig(
        name="dcn-v2-smoke",
        fields=_fields(smoke_vocabs(26), dim=16),
        n_dense=N_DENSE,
        interactions=(InteractionSpec("cross", kwargs={"n_layers": 3}),),
        mlp_dims=(64, 32),
    )


register_arch("dcn-v2", full, smoke, RECSYS_SHAPES)
