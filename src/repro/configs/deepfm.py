"""deepfm [arXiv:1703.04247].

n_sparse=39 embed_dim=10 mlp=400-400-400, FM interaction. In the paper the 13
Criteo numeric features are discretised into categorical fields, giving 39
sparse fields total (26 categorical + 13 bucketised numeric).
"""
from repro.configs.base import RECSYS_SHAPES, FeatureField, InteractionSpec, WDLConfig, register_arch
from repro.configs.criteo import CRITEO_VOCABS, smoke_vocabs

_NUMERIC_BUCKETS = 1024  # bucketised numeric fields


def _fields(vocabs, num_buckets, dim):
    fields = [
        FeatureField(name=f"cat_{i}", vocab=int(v), dim=dim, max_len=1, pooling="sum")
        for i, v in enumerate(vocabs)
    ]
    fields += [
        FeatureField(name=f"numb_{i}", vocab=num_buckets, dim=dim, max_len=1, pooling="sum")
        for i in range(13)
    ]
    return tuple(fields)


def full() -> WDLConfig:
    return WDLConfig(
        name="deepfm",
        fields=_fields(CRITEO_VOCABS, _NUMERIC_BUCKETS, dim=10),
        n_dense=0,
        interactions=(
            InteractionSpec("fm"),           # FM 2nd-order over all 39 fields
            InteractionSpec("linear"),       # FM 1st-order (wide part)
        ),
        mlp_dims=(400, 400, 400),
    )


def smoke() -> WDLConfig:
    return WDLConfig(
        name="deepfm-smoke",
        fields=_fields(smoke_vocabs(26), 32, dim=10),
        n_dense=0,
        interactions=(InteractionSpec("fm"), InteractionSpec("linear")),
        mlp_dims=(32, 32),
    )


register_arch("deepfm", full, smoke, RECSYS_SHAPES)
