"""stablelm-1.6b [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (GQA kv=32 => MHA) d_ff=5632 vocab=100352, dense.
"""
from repro.configs.base import LM_SHAPES, LMConfig, register_arch
from repro.configs.lm_family import FULL_ATTN_SKIP, smoke_of


def full() -> LMConfig:
    return LMConfig(
        name="stablelm-1.6b",
        n_layers=24,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=5632,
        vocab=100352,
        rope_theta=10000.0,
    )


def smoke() -> LMConfig:
    return smoke_of(full())


register_arch("stablelm-1.6b", full, smoke, LM_SHAPES, skip_shapes=("long_500k",), skip_reason=FULL_ATTN_SKIP)
