"""yi-34b [arXiv:2403.04652] — llama-arch GQA.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000, dense.
"""
from repro.configs.base import LM_SHAPES, LMConfig, register_arch
from repro.configs.lm_family import FULL_ATTN_SKIP, smoke_of


def full() -> LMConfig:
    return LMConfig(
        name="yi-34b",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20480,
        vocab=64000,
        rope_theta=5000000.0,
    )


def smoke() -> LMConfig:
    return smoke_of(full())


register_arch("yi-34b", full, smoke, LM_SHAPES, skip_shapes=("long_500k",), skip_reason=FULL_ATTN_SKIP)
