"""Config dataclasses + arch/shape registry.

Every assigned architecture registers a ``full()`` (exact public config) and a
``smoke()`` (reduced same-family config for CPU tests) plus its shape set.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# feature fields (recsys / WDL)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FeatureField:
    """One sparse categorical feature field.

    vocab:    number of rows in this field's embedding table
    dim:      embedding dimension
    max_len:  ids per sample (1 = one-hot; >1 = multi-hot/behaviour sequence)
    pooling:  'sum' | 'mean' | 'none' (none keeps the sequence, e.g. DIN/SASRec)
    """

    name: str
    vocab: int
    dim: int
    max_len: int = 1
    pooling: str = "sum"
    group: str = "default"  # interaction-module group this field feeds
    shared_table: str = ""  # if set, this field reads another field's table


@dataclass(frozen=True)
class InteractionSpec:
    """One feature-interaction submodule (paper Fig. 2)."""

    kind: str  # 'fm' | 'cross' | 'dot' | 'self_attn' | 'target_attn' | 'gru' | 'capsule' | 'mlp' | 'cin'
    fields: Tuple[str, ...] = ()  # field names it consumes ('' = all)
    kwargs: Dict[str, Any] = field(default_factory=dict)


@dataclass(frozen=True)
class WDLConfig:
    """Wide-and-Deep Learning model (the paper's target family)."""

    name: str
    fields: Tuple[FeatureField, ...]
    n_dense: int  # numeric features
    interactions: Tuple[InteractionSpec, ...]
    mlp_dims: Tuple[int, ...]
    dense_arch: Tuple[int, ...] = ()  # bottom MLP for numeric features (DLRM-style)
    n_tasks: int = 1
    dtype: str = "float32"

    @property
    def kind(self) -> str:
        return "wdl"

    def field_by_name(self, name: str) -> FeatureField:
        for f in self.fields:
            if f.name == name:
                return f
        raise KeyError(name)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff: int


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    moe: Optional[MoESpec] = None
    swa_window: Optional[int] = None  # sliding-window attention (sub-quadratic)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    tie_embeddings: bool = False

    @property
    def kind(self) -> str:
        return "lm"

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def param_count(self) -> int:
        """Total parameter count N (for 6*N*D model flops)."""
        d, h = self.d_model, self.head_dim
        attn = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) + (self.n_heads * h) * d
        if self.moe is not None:
            ff = self.moe.n_experts * 3 * d * self.moe.d_ff + d * self.moe.n_experts
        else:
            ff = 3 * d * self.d_ff
        norms = 2 * d
        per_layer = attn + ff + norms
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE top-k)."""
        if self.moe is None:
            return self.param_count()
        d, h = self.d_model, self.head_dim
        attn = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) + (self.n_heads * h) * d
        ff = self.moe.top_k * 3 * d * self.moe.d_ff + d * self.moe.n_experts
        per_layer = attn + ff + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d


# ---------------------------------------------------------------------------
# GNN (SchNet)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SchNetConfig:
    name: str
    n_interactions: int
    d_hidden: int
    n_rbf: int
    cutoff: float
    d_feat: int = 0  # input node feature dim (0 -> learned species embedding)
    n_species: int = 100
    dtype: str = "float32"

    @property
    def kind(self) -> str:
        return "gnn"


# ---------------------------------------------------------------------------
# shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell. ``kind`` selects which step gets lowered."""

    name: str
    kind: str  # 'train' | 'prefill' | 'decode' | 'serve' | 'retrieval' | 'graph_full' | 'graph_minibatch' | 'graph_batched'
    dims: Dict[str, int] = field(default_factory=dict)

    def __getitem__(self, k: str) -> int:
        return self.dims[k]


LM_SHAPES = (
    ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
    ShapeSpec("prefill_32k", "prefill", {"seq_len": 32768, "global_batch": 32}),
    ShapeSpec("decode_32k", "decode", {"seq_len": 32768, "global_batch": 128}),
    ShapeSpec("long_500k", "decode", {"seq_len": 524288, "global_batch": 1}),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "train", {"batch": 65536}),
    ShapeSpec("serve_p99", "serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
    ShapeSpec("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "graph_full", {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}),
    ShapeSpec(
        "minibatch_lg",
        "graph_minibatch",
        {"n_nodes": 232965, "n_edges": 114615892, "batch_nodes": 1024, "fanout0": 15, "fanout1": 10},
    ),
    ShapeSpec("ogb_products", "graph_full", {"n_nodes": 2449029, "n_edges": 61859140, "d_feat": 100}),
    ShapeSpec("molecule", "graph_batched", {"n_nodes": 30, "n_edges": 64, "batch": 128}),
)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Dict[str, Any]] = {}


def register_arch(
    arch_id: str,
    full: Callable[[], Any],
    smoke: Callable[[], Any],
    shapes: Sequence[ShapeSpec],
    skip_shapes: Sequence[str] = (),
    skip_reason: str = "",
) -> None:
    _REGISTRY[arch_id] = {
        "full": full,
        "smoke": smoke,
        "shapes": tuple(shapes),
        "skip_shapes": tuple(skip_shapes),
        "skip_reason": skip_reason,
    }


def get_config(arch_id: str, smoke: bool = False) -> Any:
    _ensure_loaded()
    entry = _REGISTRY[arch_id]
    return entry["smoke"]() if smoke else entry["full"]()


def get_shapes(arch_id: str, include_skipped: bool = False) -> Tuple[ShapeSpec, ...]:
    _ensure_loaded()
    entry = _REGISTRY[arch_id]
    if include_skipped:
        return entry["shapes"]
    return tuple(s for s in entry["shapes"] if s.name not in entry["skip_shapes"])


def skipped_shapes(arch_id: str) -> Tuple[Tuple[str, str], ...]:
    _ensure_loaded()
    e = _REGISTRY[arch_id]
    return tuple((s, e["skip_reason"]) for s in e["skip_shapes"])


def list_archs() -> List[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # import triggers register_arch calls
    from repro.configs import (  # noqa: F401
        dcn_v2,
        deepfm,
        mind,
        mistral_nemo_12b,
        mixtral_8x22b,
        phi35_moe,
        sasrec,
        schnet,
        stablelm_16b,
        yi_34b,
    )
