"""mixtral-8x22b [arXiv:2401.04088].

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8 experts top-2,
sliding-window attention (sub-quadratic => long_500k supported).
"""
from repro.configs.base import LM_SHAPES, LMConfig, MoESpec, register_arch
from repro.configs.lm_family import smoke_of


def full() -> LMConfig:
    return LMConfig(
        name="mixtral-8x22b",
        n_layers=56,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab=32768,
        moe=MoESpec(n_experts=8, top_k=2, d_ff=16384),
        swa_window=4096,
        rope_theta=1000000.0,
    )


def smoke() -> LMConfig:
    return smoke_of(full())


register_arch("mixtral-8x22b", full, smoke, LM_SHAPES)
