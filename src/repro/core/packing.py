"""PICASSO Packing (paper §III-B).

D-Packing: feature fields whose embedding tables share a dimension are packed
into one table / one lookup op. Groups whose estimated parameter volume
(``CalcVParam``, Eq. 1) exceeds the group mean are split into shards for load
balance, exactly as the paper prescribes ("for embedding tables with a
dimension of 32, create four shards, each with a quarter of these tables").

This module is pure planning (numpy / python): it maps a WDLConfig + optional
warm-up frequency statistics to a ``PicassoPlan`` the engine executes.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import FeatureField, WDLConfig


@dataclass(frozen=True)
class TableSpec:
    """One logical embedding table (fields may share via shared_table)."""

    name: str
    vocab: int
    dim: int
    ids_per_sample: int  # expected lookups/sample across all fields reading it


@dataclass(frozen=True)
class FieldSlot:
    """Where a field's bags land inside its packed group's output."""

    field: FeatureField
    table: str
    bag_offset: int  # first bag index within the group (per sample)
    n_bags: int      # 1 if pooled, max_len if pooling == 'none'


@dataclass(frozen=True)
class PackedGroup:
    """One packed lookup op (paper: 'packed embedding')."""

    gid: int
    dim: int
    tables: Tuple[TableSpec, ...]
    table_offsets: Dict[str, int]   # table name -> row offset in packed space
    rows: int                       # padded total rows (multiple of world size)
    slots: Tuple[FieldSlot, ...]
    vparam: float                   # CalcVParam estimate (Eq. 1)

    @property
    def n_bags(self) -> int:
        return sum(s.n_bags for s in self.slots)

    @property
    def ids_per_sample(self) -> int:
        return sum(s.field.max_len for s in self.slots)


@dataclass
class PicassoPlan:
    groups: List[PackedGroup]
    world: int                       # total model-parallel shards
    capacity: Dict[int, int]         # gid -> all_to_all bucket capacity (per peer)
    interleave: List[List[int]]      # K-interleave groups: lists of gids
    microbatch: int                  # D-interleave micro-batch (per device)
    cache_rows: Dict[int, int]       # gid -> hot-storage rows (0 = no cache)
    flush_iters: int = 100
    warmup_iters: int = 100
    # ---- plan revision ----------------------------------------------------
    # A plan is a *versioned* artifact, not a compile-once constant: the
    # runtime Replanner (repro.runtime) recompiles tier budgets and the
    # strategy assignment from measured FCounter skew and hands live state
    # across revisions (embedding.state.migrate_state). ``rev`` counts
    # revisions of one structural plan (groups / capacity / interleave /
    # microbatch never change across revisions — only cache_rows, l2_rows,
    # and strategy do); ``hot_bytes``/``l2_bytes`` record the byte budgets
    # the current tier split was computed from, so a re-budget without an
    # explicit override re-splits the same envelope by measured mass.
    rev: int = 0
    hot_bytes: int = 0
    l2_bytes: int = 0
    # gid -> L2 host-memory tier rows (0 = no L2). The L2 tier sits *behind*
    # the hot tier: it only ever participates for groups that also have a
    # cache_rows budget, and the flush keeps the two key sets disjoint
    # (top-H1 rows device-resident, next-H2 host-resident).
    l2_rows: Dict[int, int] = field(default_factory=dict)
    # gid -> LookupStrategy registry name. Empty = unassigned: engines built
    # with a single strategy name broadcast it; engines built with
    # 'mixed'/'auto' compile an assignment (repro.core.assign) and record
    # it here so later engines/flushes see the same mixing.
    strategy: Dict[int, str] = field(default_factory=dict)
    # gid -> narrow master width d for the frequency-adaptive hot/cold split
    # (picasso_narrow): cold ids live at width d in the sharded master and
    # are projected up to the model dim at lookup; hot ids stay full-width
    # in the tiers. Only *engaged* for groups whose recorded strategy is
    # 'picasso_narrow' (see ``narrow_width``) — the budget can be planned
    # ahead for every group and only bites where the assignment routes.
    narrow_dim: Dict[int, int] = field(default_factory=dict)
    # Device-mesh shape the plan was compiled for, e.g. (4, 2) for 8 shards
    # on a data=4 x model=2 mesh. Empty = unrecorded (pre-elastic plans and
    # host-only tests). ``plan_meta`` persists it into the checkpoint sidecar
    # so a restore at a different world size is *detected* and routed through
    # ``reshard_plan`` + ``embedding.state.reshard_state`` instead of
    # shape-erroring against stale templates.
    mesh_shape: Tuple[int, ...] = ()
    _by_gid: Dict[int, PackedGroup] = field(init=False, repr=False)

    def __post_init__(self):
        self._by_gid = {g.gid: g for g in self.groups}

    @property
    def n_interleave(self) -> int:
        return len(self.interleave)

    def narrow_width(self, gid: int) -> int:
        """Master-table width for one group: the planned narrow dim when the
        recorded strategy is 'picasso_narrow' and the planned dim actually
        narrows, else the full model dim. This is THE gating rule — state
        init, sharding specs, migration, and the engine all consult it, so
        a plan whose assignment routes a group elsewhere keeps it wide even
        if a narrow budget was planned."""
        dim = self.group(gid).dim
        nd = int(self.narrow_dim.get(gid, dim))
        if self.strategy.get(gid) == "picasso_narrow" and 0 < nd < dim:
            return nd
        return dim

    def group(self, gid: int) -> PackedGroup:
        """Resolve a group by its gid (NOT by list position: plans sliced or
        re-planned per tower may hold non-contiguous gids)."""
        try:
            return self._by_gid[gid]
        except KeyError:
            raise KeyError(
                f"no packed group with gid={gid}; plan has "
                f"{sorted(self._by_gid)}") from None


def build_tables(cfg: WDLConfig) -> Tuple[Dict[str, TableSpec], Dict[str, str]]:
    """Resolve fields -> logical tables (handling shared_table)."""
    ids_per: Dict[str, int] = {}
    owner_field: Dict[str, FeatureField] = {}
    field_table: Dict[str, str] = {}
    for f in cfg.fields:
        tname = f.shared_table or f.name
        field_table[f.name] = tname
        ids_per[tname] = ids_per.get(tname, 0) + f.max_len
        if not f.shared_table:
            owner_field[tname] = f
    tables = {}
    for tname, f in owner_field.items():
        tables[tname] = TableSpec(name=tname, vocab=f.vocab, dim=f.dim, ids_per_sample=ids_per[tname])
    # sanity: shared fields must match dim
    for f in cfg.fields:
        if f.shared_table and tables[f.shared_table].dim != f.dim:
            raise ValueError(f"field {f.name} shares table {f.shared_table} with mismatched dim")
    return tables, field_table


def calc_vparam(tables: Sequence[TableSpec], freq_share: Optional[Dict[str, float]] = None) -> float:
    """Eq. 1: N * sum_t (t_dim * sum_{ID in t} ID_freq).

    With warm-up stats, ``freq_share[t]`` is the measured fraction of lookups
    hitting table t; without stats we use the structural expectation
    ids_per_sample_t / N (uniform-over-configured-lookups prior).
    """
    n_total = sum(t.ids_per_sample for t in tables)
    v = 0.0
    for t in tables:
        share = freq_share.get(t.name, 0.0) if freq_share else t.ids_per_sample / max(n_total, 1)
        v += t.dim * share
    return n_total * v


def _pad_to(x: int, mult: int) -> int:
    return int(math.ceil(x / mult) * mult) if mult > 1 else x


def plan_packing(
    cfg: WDLConfig,
    world: int,
    freq_share: Optional[Dict[str, float]] = None,
    split_factor: float = 2.0,
    enable_packing: bool = True,
) -> List[PackedGroup]:
    """D-Packing: group tables by dim; split oversized groups (Eq. 1)."""
    tables, field_table = build_tables(cfg)

    # ---- initial grouping --------------------------------------------------
    if enable_packing:
        by_dim: Dict[int, List[TableSpec]] = {}
        for t in tables.values():
            by_dim.setdefault(t.dim, []).append(t)
        raw_groups = [sorted(ts, key=lambda t: -t.vocab) for _, ts in sorted(by_dim.items())]
    else:
        # no packing: one table per group (the paper's fragmented baseline)
        raw_groups = [[t] for t in sorted(tables.values(), key=lambda t: t.name)]

    # ---- CalcVParam splitting ---------------------------------------------
    if enable_packing and len(raw_groups) > 0:
        vparams = [calc_vparam(g, freq_share) for g in raw_groups]
        mean_v = float(np.mean(vparams)) if vparams else 0.0
        split: List[List[TableSpec]] = []
        for g, v in zip(raw_groups, vparams):
            n_shards = 1
            if mean_v > 0 and v > split_factor * mean_v and len(g) > 1:
                n_shards = min(len(g), int(math.ceil(v / mean_v)))
            if n_shards == 1:
                split.append(g)
            else:
                # greedy balance tables into shards by vparam contribution
                buckets: List[List[TableSpec]] = [[] for _ in range(n_shards)]
                loads = [0.0] * n_shards
                for t in sorted(g, key=lambda t: -(t.dim * t.ids_per_sample)):
                    j = int(np.argmin(loads))
                    buckets[j].append(t)
                    loads[j] += t.dim * t.ids_per_sample
                split.extend(b for b in buckets if b)
        raw_groups = split

    # ---- materialize PackedGroups ------------------------------------------
    groups: List[PackedGroup] = []
    for gid, ts in enumerate(raw_groups):
        table_set = {t.name for t in ts}
        offsets, off = {}, 0
        for t in ts:
            offsets[t.name] = off
            off += t.vocab
        rows = _pad_to(off, world)
        slots: List[FieldSlot] = []
        bag_off = 0
        for f in cfg.fields:
            if field_table[f.name] in table_set:
                nb = 1 if f.pooling != "none" else f.max_len
                slots.append(FieldSlot(field=f, table=field_table[f.name], bag_offset=bag_off, n_bags=nb))
                bag_off += nb
        groups.append(
            PackedGroup(
                gid=gid,
                dim=ts[0].dim,
                tables=tuple(ts),
                table_offsets=offsets,
                rows=rows,
                slots=tuple(slots),
                vparam=calc_vparam(ts, freq_share),
            )
        )
    return groups


def plan_capacity(
    group: PackedGroup,
    local_ids: int,
    world: int,
    slack: float = 2.0,
    cache_hit_ratio: float = 0.0,
    exact: bool = False,
) -> int:
    """All-to-all bucket capacity per peer shard.

    Expected uniques routed to each peer ~= local_ids*(1-hit)/world; ``slack``
    covers residual skew (the zipf head is absorbed by the cache + scramble).
    ``exact`` mode uses capacity = local_ids (provably lossless; tests).
    """
    if exact:
        return max(1, local_ids)
    per_peer = local_ids * max(0.0, 1.0 - cache_hit_ratio) / max(world, 1)
    cap = int(math.ceil(slack * max(per_peer, 1.0)))
    return max(4, _pad_to(cap, 4))


def plan_microbatch(
    per_device_batch: int,
    act_bytes_per_sample: float,
    mem_budget_bytes: float = 8 * 2**30,
    n_micro: Optional[int] = None,
) -> int:
    """Eq. 2: BS_micro = min_op(RBound_op / RInstance_op).

    The dominant bound for the dense stage is device memory for activations;
    RInstance is activation bytes/sample. Explicit ``n_micro`` overrides.
    """
    if n_micro is not None:
        return max(1, per_device_batch // max(1, n_micro))
    if act_bytes_per_sample <= 0:
        return per_device_batch
    bs = int(mem_budget_bytes / act_bytes_per_sample)
    bs = max(1, min(per_device_batch, bs))
    # round down to a divisor of per_device_batch for a static scan
    while per_device_batch % bs:
        bs -= 1
    return bs


def plan_interleave(groups: Sequence[PackedGroup], n_groups: Optional[int] = None,
                    capacity_vparam: Optional[float] = None) -> List[List[int]]:
    """Eq. 3: bound each K-interleave group's parameter volume by Capacity_g.

    Greedy balance of packed groups into interleave groups so that each stays
    under Capacity_g (when given) or so that ``n_groups`` groups are balanced.
    """
    if not groups:
        return []
    if n_groups is None:
        if capacity_vparam is None:
            capacity_vparam = max(g.vparam for g in groups)
        n_groups = max(1, int(math.ceil(sum(g.vparam for g in groups) / capacity_vparam)))
    n_groups = min(n_groups, len(groups))
    buckets: List[List[int]] = [[] for _ in range(n_groups)]
    loads = [0.0] * n_groups
    for g in sorted(groups, key=lambda g: -g.vparam):
        j = int(np.argmin(loads))
        buckets[j].append(g.gid)
        loads[j] += g.vparam
    return [sorted(b) for b in buckets if b]


def _budget_weights(groups: Sequence[PackedGroup],
                    stats: Optional[Dict[int, np.ndarray]] = None
                    ) -> Dict[int, float]:
    """Per-group tier-budget weight: measured traffic volume when FCounter
    ``stats`` are given (total lookups served x dim — the byte volume the
    tier can actually absorb), else the structural ``vparam`` prior.

    Falls back to vparam wholesale when stats are missing or empty for every
    group (a cold counter carries no signal), so a warm-start replan before
    any step behaves exactly like the compile-time split.
    """
    if stats:
        w = {g.gid: float(np.asarray(stats[g.gid], np.float64).sum()) * g.dim
             for g in groups if g.gid in stats}
        if len(w) == len(list(groups)) and sum(w.values()) > 0:
            return w
    return {g.gid: g.vparam for g in groups}


def plan_cache(
    groups: Sequence[PackedGroup],
    hot_bytes: int,
    world: int,
    dtype_bytes: int = 4,
    stats: Optional[Dict[int, np.ndarray]] = None,
) -> Dict[int, int]:
    """Split the hot-storage budget across packed groups ∝ vparam share —
    or, with measured FCounter ``stats``, ∝ measured lookup mass x dim
    (the runtime re-budget path: skew the tier toward the groups that are
    actually being queried, not the ones the structural prior expected).

    Returns rows per group, padded to a multiple of 8 (sublane) with a small
    floor so tiny-but-hot tables (e.g. vocab<=64 fields queried every sample)
    are always resident. A non-positive ``hot_bytes`` drops the tier outright
    (no floor): that is how a runtime re-budget turns the cache path off.
    """
    if hot_bytes <= 0:
        return {g.gid: 0 for g in groups}
    weights = _budget_weights(groups, stats)
    total_v = sum(weights.values()) or 1.0
    out: Dict[int, int] = {}
    for g in groups:
        budget = hot_bytes * (weights[g.gid] / total_v)
        rows = int(budget / ((g.dim + 1) * dtype_bytes))  # +1 for adagrad acc
        tiny = sum(t.vocab for t in g.tables if t.vocab <= 64)
        rows = max(rows, tiny, 8)
        # a cache above ~1/8 of the table (or 4M rows) has no marginal hits
        # (paper Tab. VI: hit ratio saturates) and bloats the flush top-k.
        rows = min(rows, g.rows, max(g.rows // 8, 8), 4_194_304)
        out[g.gid] = _pad_to(rows, 8)
    return out


def plan_l2(
    groups: Sequence[PackedGroup],
    l2_bytes: int,
    cache_rows: Dict[int, int],
    dtype_bytes: int = 4,
    stats: Optional[Dict[int, np.ndarray]] = None,
) -> Dict[int, int]:
    """Split the L2 host-memory budget across packed groups ∝ vparam share —
    or ∝ measured lookup mass x dim when FCounter ``stats`` are given (the
    same re-budget rule as ``plan_cache``, so one replan re-splits both
    tiers consistently).

    The L2 tier backs the hot tier with host (CPU/pinned) memory, so its
    budget is typically 10-100x ``hot_bytes``. Per group the tier is capped
    at the rows *not* already covered by the hot tier (the flush assigns the
    top-H1 rows to L1 and the next H2 to L2, so overlapping budget would be
    dead memory), and rounded down to the 8-row sublane multiple. Groups
    without a hot-tier budget get no L2: the tier sits strictly behind L1.
    """
    weights = _budget_weights(groups, stats)
    total_v = sum(weights.values()) or 1.0
    out: Dict[int, int] = {}
    for g in groups:
        h1 = cache_rows.get(g.gid, 0)
        if l2_bytes <= 0 or h1 <= 0:
            out[g.gid] = 0
            continue
        budget = l2_bytes * (weights[g.gid] / total_v)
        rows = int(budget / ((g.dim + 1) * dtype_bytes))  # +1 for adagrad acc
        rows = min(rows, max(g.rows - h1, 0))
        out[g.gid] = (rows // 8) * 8
    return out


def plan_narrow(
    groups: Sequence[PackedGroup],
    narrow_dim: int,
    min_dim: int = 4,
) -> Dict[int, int]:
    """gid -> narrow master width for the picasso_narrow hot/cold split.

    Clamps the requested width per group: rounded down to the ``min_dim``
    (sublane) multiple with a floor of ``min_dim``, and groups whose model
    dim is already at or below the request keep their full dim (recording
    ``dim`` means "no narrowing" under ``PicassoPlan.narrow_width``). The
    budget is recorded for every group — it only engages where the strategy
    assignment routes a group to 'picasso_narrow'.
    """
    out: Dict[int, int] = {}
    for g in groups:
        nd = int(narrow_dim)
        if nd <= 0 or nd >= g.dim:
            out[g.gid] = g.dim
        else:
            out[g.gid] = min(g.dim, max(min_dim, (nd // min_dim) * min_dim))
    return out


def make_plan(
    cfg: WDLConfig,
    world: int,
    per_device_batch: int,
    *,
    enable_packing: bool = True,
    enable_cache: bool = True,
    n_interleave: Optional[int] = None,
    n_micro: Optional[int] = None,
    hot_bytes: int = 1 << 30,
    l2_bytes: int = 0,
    narrow_dim: Optional[int] = None,
    capacity_slack: float = 2.0,
    exact_capacity: bool = False,
    freq_share: Optional[Dict[str, float]] = None,
    flush_iters: int = 100,
    warmup_iters: int = 100,
    mem_budget_bytes: float = 8 * 2**30,
    mesh_shape: Optional[Sequence[int]] = None,
) -> PicassoPlan:
    if mesh_shape is not None and int(np.prod(mesh_shape)) != world:
        raise ValueError(
            f"mesh_shape {tuple(mesh_shape)} has {int(np.prod(mesh_shape))} "
            f"devices but world={world}")
    groups = plan_packing(cfg, world, freq_share=freq_share, enable_packing=enable_packing)
    cache_rows = plan_cache(groups, hot_bytes, world) if enable_cache else {g.gid: 0 for g in groups}
    l2_rows = plan_l2(groups, l2_bytes if enable_cache else 0, cache_rows)
    capacity = {}
    for g in groups:
        local_ids = per_device_batch * g.ids_per_sample
        hit = 0.2 if cache_rows.get(g.gid, 0) else 0.0  # paper: >=20% hit at 1GB
        capacity[g.gid] = plan_capacity(g, local_ids, world, slack=capacity_slack,
                                        cache_hit_ratio=hit, exact=exact_capacity)
    act_bytes = 4.0 * (sum(g.n_bags * g.dim for g in groups) + sum(cfg.mlp_dims) * 4)
    micro = plan_microbatch(per_device_batch, act_bytes, mem_budget_bytes=mem_budget_bytes, n_micro=n_micro)
    ilv = plan_interleave(groups, n_groups=n_interleave)
    return PicassoPlan(
        groups=groups,
        world=world,
        capacity=capacity,
        interleave=ilv,
        microbatch=micro,
        cache_rows=cache_rows,
        flush_iters=flush_iters,
        warmup_iters=warmup_iters,
        l2_rows=l2_rows,
        hot_bytes=hot_bytes if enable_cache else 0,
        l2_bytes=l2_bytes if enable_cache else 0,
        narrow_dim=(plan_narrow(groups, narrow_dim)
                    if narrow_dim is not None else {}),
        mesh_shape=tuple(int(x) for x in mesh_shape) if mesh_shape else (),
    )


def revise_plan(
    plan: PicassoPlan,
    stats: Optional[Dict[int, np.ndarray]] = None,
    *,
    hot_bytes: Optional[int] = None,
    l2_bytes: Optional[int] = None,
    enable_cache: bool = True,
) -> PicassoPlan:
    """Recompile the plan's *revisable* decisions into revision ``rev+1``.

    The structural plan — groups, all_to_all capacities, interleave waves,
    micro-batch — is carried over untouched (it derives from the config and
    mesh, which do not change at runtime). What gets recompiled is the tier
    split: ``cache_rows``/``l2_rows`` are re-budgeted by ``plan_cache``/
    ``plan_l2`` with the measured FCounter ``stats`` (∝ measured lookup
    mass) instead of the structural warm prior.

    ``hot_bytes``/``l2_bytes``: byte envelopes for the re-split; ``None``
    re-splits the envelope recorded on the plan (``plan.hot_bytes`` /
    ``plan.l2_bytes``) — pass an explicit value to retune tier *capacity*
    at runtime (HugeCTR-style), including 0 to drop a tier.

    ``enable_cache=False`` (the engine runs with ``use_cache=False``)
    zeroes both tiers like ``make_plan``.

    The returned plan carries **no strategy assignment**: callers re-run
    ``repro.core.assign.compile_assignment(new_plan, stats=...)`` so the
    strategy mix is scored against the *new* budgets, then record it with
    ``apply_assignment``. ``repro.runtime.Replanner`` packages that loop,
    plus the live-state migration between revisions.
    """
    hb = int(plan.hot_bytes if hot_bytes is None else hot_bytes)
    lb = int(plan.l2_bytes if l2_bytes is None else l2_bytes)
    if enable_cache:
        cache_rows = plan_cache(plan.groups, hb, plan.world, stats=stats)
        l2_rows = plan_l2(plan.groups, lb, cache_rows, stats=stats)
    else:
        cache_rows = {g.gid: 0 for g in plan.groups}
        l2_rows = {g.gid: 0 for g in plan.groups}
    # dataclasses.replace: any future PicassoPlan field is carried over by
    # construction instead of silently resetting to its default here
    return dataclasses.replace(
        plan,
        capacity=dict(plan.capacity),
        interleave=[list(w) for w in plan.interleave],
        cache_rows=cache_rows,
        l2_rows=l2_rows,
        rev=plan.rev + 1,
        hot_bytes=hb,
        l2_bytes=lb,
        strategy={},  # deliberately unassigned: callers re-compile vs stats
    )


def reshard_plan(
    plan: PicassoPlan,
    new_world: int,
    per_device_batch: int,
    *,
    mesh_shape: Optional[Sequence[int]] = None,
    capacity_slack: float = 2.0,
    exact_capacity: bool = False,
) -> PicassoPlan:
    """Recut the SAME plan revision for a different world size.

    Unlike ``revise_plan`` (tier re-budget within one mesh), a reshard is a
    pure permutation of the existing state: every revisable decision —
    ``cache_rows``/``l2_rows`` budgets, the strategy mix, narrow widths,
    ``rev`` itself — is carried over verbatim, because the migrated state
    must stay bitwise-identical row for row. What changes is only what
    *derives from the mesh*:

    - each group's padded ``rows`` is recut to the new world multiple
      (``_pad_to(logical_rows, new_world)`` — logical rows, i.e. the packed
      table vocabs, never change);
    - per-peer all_to_all ``capacity`` is re-planned for the new shard count
      (fewer peers => more uniques per peer);
    - ``microbatch`` is clamped to a divisor of the new per-device batch
      (a world change at fixed global batch changes the local batch);
    - ``mesh_shape``/``world`` record the new mesh.

    ``embedding.state.reshard_state`` performs the matching state-side
    permutation (pad/truncate padding rows, remap tier sentinel keys).
    """
    new_world = int(new_world)
    if new_world <= 0:
        raise ValueError(f"new_world must be positive, got {new_world}")
    if mesh_shape is not None and int(np.prod(mesh_shape)) != new_world:
        raise ValueError(
            f"mesh_shape {tuple(mesh_shape)} has {int(np.prod(mesh_shape))} "
            f"devices but new_world={new_world}")
    groups = []
    for g in plan.groups:
        logical = max(g.table_offsets[t.name] + t.vocab for t in g.tables)
        groups.append(dataclasses.replace(g, rows=_pad_to(logical, new_world)))
    capacity = {}
    for g in groups:
        local_ids = per_device_batch * g.ids_per_sample
        hit = 0.2 if plan.cache_rows.get(g.gid, 0) else 0.0
        capacity[g.gid] = plan_capacity(g, local_ids, new_world,
                                        slack=capacity_slack,
                                        cache_hit_ratio=hit,
                                        exact=exact_capacity)
    micro = max(1, min(int(plan.microbatch), int(per_device_batch)))
    while per_device_batch % micro:
        micro -= 1
    if mesh_shape is not None:
        shape = tuple(int(x) for x in mesh_shape)
    elif plan.mesh_shape and int(np.prod(plan.mesh_shape)) == new_world:
        shape = tuple(plan.mesh_shape)
    else:
        shape = ()
    return dataclasses.replace(
        plan,
        groups=groups,
        world=new_world,
        capacity=capacity,
        interleave=[list(w) for w in plan.interleave],
        microbatch=micro,
        cache_rows=dict(plan.cache_rows),
        l2_rows=dict(plan.l2_rows),
        strategy=dict(plan.strategy),
        narrow_dim=dict(plan.narrow_dim),
        mesh_shape=shape,
    )
