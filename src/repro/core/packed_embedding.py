"""PICASSO packed-embedding primitives (paper §III-B, §III-D).

This is the kernel layer beneath ``repro.engine.EmbeddingEngine``: stateless,
fixed-shape collective building blocks. Workloads never call these directly —
they go through the engine's ``LookupStrategy`` classes, which compose them.

Executes one *packed* lookup per D-packed group, model-parallel over the whole
mesh, inside ``shard_map``:

    ids -> [K-Packed Unique&Partition] -> all_to_all (Shuffle) -> local Gather
        -> all_to_all back -> Stitch -> (hot-cache merge) -> unique rows

and the exact transposed path for sparse gradients. All shapes are static
(TPU collectives require it): ``unique`` is sort-based with a fixed output
size, the Shuffle uses fixed-capacity per-peer buckets sized by the planner
(Eq. 1 statistics), and the HybridHash hot tier absorbs the skew head that
would otherwise overflow the buckets.

HybridHash on TPU (see DESIGN.md §2): hot rows are replicated per chip; a hit
is a local gather with zero ICI traffic. Hit gradients are psum'd (replicas
stay bit-identical) and applied to the replicated hot tier; the hot tier is
the authoritative storage for its rows between flushes, so training stays
*exact* synchronous SGD — flush writes rows+optimizer state back to the owner
shard and reloads the new top-k set.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels import ops
from repro.optim import grad_compression as gcomp

Axes = Union[str, Tuple[str, ...]]


# ---------------------------------------------------------------------------
# fixed-shape building blocks (K-Packing: Unique&Partition fused)
# ---------------------------------------------------------------------------


class UniqueResult(NamedTuple):
    uniq: jnp.ndarray      # [n] ascending; slots >= n_uniq hold ``sentinel``
    inv: jnp.ndarray       # [n] original position -> unique slot
    n_uniq: jnp.ndarray    # scalar
    uvalid: jnp.ndarray    # [n] bool, slot validity


def fixed_unique(ids: jnp.ndarray, sentinel: int) -> UniqueResult:
    """Sort-based unique with static output size == input size."""
    n = ids.shape[0]
    order = jnp.argsort(ids)
    s = ids[order]
    is_first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    slot_sorted = (jnp.cumsum(is_first) - 1).astype(jnp.int32)
    inv = jnp.zeros((n,), jnp.int32).at[order].set(slot_sorted)
    uniq = jnp.full((n,), sentinel, ids.dtype).at[slot_sorted].set(s)
    n_uniq = jnp.sum(is_first).astype(jnp.int32)
    uvalid = jnp.arange(n, dtype=jnp.int32) < n_uniq
    return UniqueResult(uniq, inv, n_uniq, uvalid)


class Routing(NamedTuple):
    """Unique&Partition output: where each unique slot goes in the Shuffle."""

    owner: jnp.ndarray    # [n] destination shard (== world for drop)
    pos: jnp.ndarray      # [n] position within the per-peer bucket
    send_slot: jnp.ndarray  # [n] flattened owner*cap + pos (world*cap = drop)
    kept: jnp.ndarray     # [n] routed (miss & under capacity)
    overflow: jnp.ndarray  # scalar count of dropped uniques


def partition(uniq: jnp.ndarray, miss: jnp.ndarray, rows_per_shard: int, world: int,
              capacity: int) -> Routing:
    """Partition sorted unique ids into fixed-capacity per-owner buckets.

    ``uniq`` ascending => block owner ids are monotone, so the rank of a miss
    within its owner's bucket is a cumsum difference (no extra sort).
    """
    n = uniq.shape[0]
    owner = jnp.minimum(uniq // rows_per_shard, world).astype(jnp.int32)
    prefix = jnp.cumsum(miss.astype(jnp.int32)) - miss.astype(jnp.int32)  # exclusive
    start = jnp.searchsorted(owner, owner, side="left").astype(jnp.int32)
    pos = prefix - prefix[start]
    kept = miss & (pos < capacity) & (owner < world)
    send_slot = jnp.where(kept, owner * capacity + pos, world * capacity).astype(jnp.int32)
    overflow = jnp.sum(miss & (pos >= capacity))
    return Routing(owner, pos, send_slot, kept, overflow)


def _a2a(x: jnp.ndarray, axes: Axes) -> jnp.ndarray:
    """all_to_all over (possibly multiple) mesh axes; [world, ...] layout."""
    return lax.all_to_all(x, axes, split_axis=0, concat_axis=0, tiled=True)


def _compressed_a2a_rows(send_g: jnp.ndarray, axes: Axes, world: int,
                         cap: int, compress: str = "none",
                         fused: bool = False) -> jnp.ndarray:
    """all_to_all ``[world*cap, D]`` gradient rows, compressed on the wire.

    ``compress='none'`` is the exact legacy hop (bitwise-identical bytes and
    math). Otherwise the rows are compressed *before* the collective (so only
    the narrow payload crosses ICI), every payload leaf rides its own
    all_to_all (leaves keep the leading row dim, so the [world, cap, ...]
    reshape is payload-shape agnostic), and owners decompress after. Zero
    rows — padded bucket slots — survive every mode bitwise, which the
    dedup+adagrad scatter's validity masking relies on.
    """
    d = send_g.shape[-1]
    if compress == "none":
        return _a2a(send_g.reshape(world, cap, d), axes).reshape(world * cap, d)
    payload = gcomp.compress_rows(send_g, compress, fused=fused)
    payload = jax.tree.map(
        lambda x: _a2a(x.reshape(world, cap, *x.shape[1:]), axes)
        .reshape(world * cap, *x.shape[1:]),
        payload)
    return gcomp.decompress_rows(payload, d, compress, fused=fused)


# ---------------------------------------------------------------------------
# forward: Shuffle & Stitch (+ HybridHash read path)
# ---------------------------------------------------------------------------


class LookupCtx(NamedTuple):
    """Everything the backward/statistics passes need (all static shapes).

    ``l2_hit``/``l2_slot`` are ``None`` unless the lookup probed an L2 host
    tier (``mp_lookup(..., l2_keys=, l2_rows=)``); ``None`` collapses to an
    empty pytree node, so plain-picasso contexts keep their PR-2 structure.
    """

    uniq: jnp.ndarray
    inv: jnp.ndarray
    uvalid: jnp.ndarray
    hit: jnp.ndarray        # [n] served by hot tier
    cache_slot: jnp.ndarray  # [n] clamped position in hot_keys
    routing: Routing
    recv_ids: jnp.ndarray   # [world, cap] ids this shard served (owner side)
    recv_local: jnp.ndarray  # [world, cap] local row idx (clamped)
    recv_valid: jnp.ndarray  # [world, cap]
    l2_hit: Optional[jnp.ndarray] = None   # [n] served by L2 host tier
    l2_slot: Optional[jnp.ndarray] = None  # [n] clamped position in l2_keys
    narrow_rows: Optional[jnp.ndarray] = None  # [n, d] routed narrow rows
    #   (picasso_narrow only: the gather_project residual — zero at tier-hit
    #   and padded positions — from which the projection gradient is one
    #   ``narrow^T @ g_u`` matmul in the backward)


def cache_probe(uniq: jnp.ndarray, uvalid: jnp.ndarray,
                hot_keys: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if hot_keys is None or hot_keys.shape[0] == 0:
        z = jnp.zeros(uniq.shape, bool)
        return z, jnp.zeros(uniq.shape, jnp.int32)
    p = jnp.searchsorted(hot_keys, uniq).astype(jnp.int32)
    p_c = jnp.clip(p, 0, hot_keys.shape[0] - 1)
    hit = (hot_keys[p_c] == uniq) & uvalid
    return hit, p_c


def mp_lookup(
    table_shard: jnp.ndarray,      # [rows_per_shard, D]
    ids: jnp.ndarray,              # [n] packed global row ids
    *,
    axes: Axes,
    world: int,
    capacity: int,
    hot_keys: Optional[jnp.ndarray] = None,   # [H] replicated, sorted
    hot_rows: Optional[jnp.ndarray] = None,   # [H, D] replicated
    l2_keys: Optional[jnp.ndarray] = None,    # [H2] L2 host tier, sorted
    l2_rows: Optional[jnp.ndarray] = None,    # [H2, D] L2 host tier
    fused: bool = False,                      # fused tier-probe kernels
) -> Tuple[jnp.ndarray, LookupCtx]:
    """Forward packed lookup. Returns unique rows [n, D] + routing context.

    Probe order is strictly tiered: L1 (``hot_keys``, device-resident hot
    tier) first, then — only for L1 misses — the L2 host tier (``l2_keys``),
    and only the remaining misses ride the all_to_all Shuffle. The two tiers
    are disjoint by flush construction (top-H1 / next-H2 by frequency), and
    the L2 probe additionally masks out L1 hits so an overlapping user-built
    tier can never serve one id twice. With ``l2_keys=None`` (no L2 tier)
    the math — including every intermediate — is bitwise-identical to the
    PR-2 path, and ``ctx.l2_hit`` stays ``None``.

    ``fused=True`` replaces each tier's searchsorted/take/where chain with
    one ``ops.tier_probe`` kernel pass (binary search + hit-masked row
    gather); the probed rows come back zero-masked, so the Stitch below is a
    single ``where`` per tier and hit values are identical either way.
    """
    rps, d = table_shard.shape
    rows_padded = rps * world
    n = ids.shape[0]

    u = fixed_unique(ids, sentinel=rows_padded)
    probe_l1 = (fused and hot_keys is not None and hot_keys.shape[0] > 0
                and hot_rows is not None)
    if probe_l1:
        hit, cache_slot, l1_probe_rows = ops.tier_probe(
            u.uniq, u.uvalid, hot_keys, hot_rows, fused=True)
    else:
        hit, cache_slot = cache_probe(u.uniq, u.uvalid, hot_keys)
    use_l2 = l2_keys is not None and l2_keys.shape[0] > 0
    if use_l2:
        if fused:
            l2_hit, l2_slot, l2_probe_rows = ops.tier_probe(
                u.uniq, u.uvalid & ~hit, l2_keys, l2_rows, fused=True)
        else:
            l2_hit, l2_slot = cache_probe(u.uniq, u.uvalid & ~hit, l2_keys)
        miss = u.uvalid & ~hit & ~l2_hit
    else:
        l2_hit, l2_slot = None, None
        miss = u.uvalid & ~hit
    r = partition(u.uniq, miss, rps, world, capacity)

    # ---- Shuffle: route miss ids to owners --------------------------------
    send_ids = jnp.full((world * capacity,), -1, jnp.int32)
    send_ids = send_ids.at[r.send_slot].set(u.uniq.astype(jnp.int32), mode="drop")
    recv_ids = _a2a(send_ids.reshape(world, capacity), axes)  # [world, cap]

    my = lax.axis_index(axes)
    base = my.astype(jnp.int32) * rps
    recv_valid = recv_ids >= 0
    recv_local = jnp.clip(recv_ids - base, 0, rps - 1)

    # ---- local Gather ------------------------------------------------------
    served = jnp.take(table_shard, recv_local.reshape(-1), axis=0)
    served = served * recv_valid.reshape(-1, 1).astype(served.dtype)

    # ---- Shuffle back + Stitch ---------------------------------------------
    back = _a2a(served.reshape(world, capacity, d), axes).reshape(world * capacity, d)
    take_idx = jnp.minimum(r.send_slot, world * capacity - 1)
    miss_rows = jnp.take(back, take_idx, axis=0) * r.kept[:, None].astype(served.dtype)

    if use_l2:
        l2 = l2_probe_rows if fused else jnp.take(l2_rows, l2_slot, axis=0)
        miss_rows = jnp.where(l2_hit[:, None], l2.astype(miss_rows.dtype), miss_rows)
    if probe_l1:
        rows_u = jnp.where(hit[:, None], l1_probe_rows.astype(miss_rows.dtype),
                           miss_rows)
    elif hot_rows is not None and hot_rows.shape[0] > 0:
        hot = jnp.take(hot_rows, cache_slot, axis=0)
        rows_u = jnp.where(hit[:, None], hot.astype(miss_rows.dtype), miss_rows)
    else:
        rows_u = miss_rows

    ctx = LookupCtx(
        uniq=u.uniq, inv=u.inv, uvalid=u.uvalid, hit=hit, cache_slot=cache_slot,
        routing=r, recv_ids=recv_ids, recv_local=recv_local, recv_valid=recv_valid,
        l2_hit=l2_hit, l2_slot=l2_slot,
    )
    return rows_u, ctx


def mp_lookup_narrow(
    table_shard: jnp.ndarray,      # [rows_per_shard, d] NARROW master shard
    ids: jnp.ndarray,              # [n] packed global row ids
    *,
    proj: jnp.ndarray,             # [d, D] learned up-projection (replicated)
    axes: Axes,
    world: int,
    capacity: int,
    hot_keys: Optional[jnp.ndarray] = None,   # [H1] sorted; tier rows are WIDE
    hot_rows: Optional[jnp.ndarray] = None,   # [H1, D]
    l2_keys: Optional[jnp.ndarray] = None,    # [H2] sorted
    l2_rows: Optional[jnp.ndarray] = None,    # [H2, D]
    fused: bool = False,
) -> Tuple[jnp.ndarray, LookupCtx]:
    """``mp_lookup`` with hot/cold heterogeneous widths: tier-resident (hot)
    ids are served full-width ``D`` rows exactly as in the L2 path, while the
    misses ride the Shuffle at the narrow width ``d`` — the owner gathers
    ``[d]`` rows from the narrow master shard, the return hop carries
    ``world*cap*d`` elements, and the Stitch is one fused
    ``ops.gather_project`` pass that projects the routed-back narrow rows up
    through ``proj`` (no ``[n, d]``-then-``[n, D]`` op chain). The narrow
    rows land in ``ctx.narrow_rows`` (zeros at tier-hit/padded positions) as
    the residual for the projection's gradient.

    Probe order, overflow accounting, and the returned routing context are
    identical to ``mp_lookup``; only the wire width and the Stitch differ.
    """
    rps, nd = table_shard.shape
    rows_padded = rps * world

    u = fixed_unique(ids, sentinel=rows_padded)
    probe_l1 = (fused and hot_keys is not None and hot_keys.shape[0] > 0
                and hot_rows is not None)
    if probe_l1:
        hit, cache_slot, l1_probe_rows = ops.tier_probe(
            u.uniq, u.uvalid, hot_keys, hot_rows, fused=True)
    else:
        hit, cache_slot = cache_probe(u.uniq, u.uvalid, hot_keys)
    use_l2 = l2_keys is not None and l2_keys.shape[0] > 0
    if use_l2:
        if fused:
            l2_hit, l2_slot, l2_probe_rows = ops.tier_probe(
                u.uniq, u.uvalid & ~hit, l2_keys, l2_rows, fused=True)
        else:
            l2_hit, l2_slot = cache_probe(u.uniq, u.uvalid & ~hit, l2_keys)
        miss = u.uvalid & ~hit & ~l2_hit
    else:
        l2_hit, l2_slot = None, None
        miss = u.uvalid & ~hit
    r = partition(u.uniq, miss, rps, world, capacity)

    # ---- Shuffle: route miss ids to owners --------------------------------
    send_ids = jnp.full((world * capacity,), -1, jnp.int32)
    send_ids = send_ids.at[r.send_slot].set(u.uniq.astype(jnp.int32), mode="drop")
    recv_ids = _a2a(send_ids.reshape(world, capacity), axes)

    my = lax.axis_index(axes)
    base = my.astype(jnp.int32) * rps
    recv_valid = recv_ids >= 0
    recv_local = jnp.clip(recv_ids - base, 0, rps - 1)

    # ---- local Gather (narrow width on the wire) ---------------------------
    served = jnp.take(table_shard, recv_local.reshape(-1), axis=0)
    served = served * recv_valid.reshape(-1, 1).astype(served.dtype)

    # ---- Shuffle back + fused gather+project Stitch ------------------------
    back = _a2a(served.reshape(world, capacity, nd), axes).reshape(
        world * capacity, nd)
    take_idx = jnp.minimum(r.send_slot, world * capacity - 1)
    miss_rows, narrow = ops.gather_project(back, take_idx, r.kept, proj,
                                           fused=fused)

    if use_l2:
        l2v = l2_probe_rows if fused else jnp.take(l2_rows, l2_slot, axis=0)
        miss_rows = jnp.where(l2_hit[:, None], l2v.astype(miss_rows.dtype),
                              miss_rows)
    if probe_l1:
        rows_u = jnp.where(hit[:, None], l1_probe_rows.astype(miss_rows.dtype),
                           miss_rows)
    elif hot_rows is not None and hot_rows.shape[0] > 0:
        hot = jnp.take(hot_rows, cache_slot, axis=0)
        rows_u = jnp.where(hit[:, None], hot.astype(miss_rows.dtype), miss_rows)
    else:
        rows_u = miss_rows

    ctx = LookupCtx(
        uniq=u.uniq, inv=u.inv, uvalid=u.uvalid, hit=hit, cache_slot=cache_slot,
        routing=r, recv_ids=recv_ids, recv_local=recv_local, recv_valid=recv_valid,
        l2_hit=l2_hit, l2_slot=l2_slot, narrow_rows=narrow,
    )
    return rows_u, ctx


def pool(
    rows_u: jnp.ndarray,    # [n, D] unique rows (differentiation leaf)
    ctx_inv: jnp.ndarray,   # [n]
    weights: jnp.ndarray,   # [n] (0 for padding; 1/len for mean pooling)
    seg: jnp.ndarray,       # [n] bag index (sorted; packed layout covers all)
    n_bags: int,
    fused: bool = False,
) -> jnp.ndarray:
    """SegmentReduction: ids -> bags. Differentiable wrt rows_u.

    Routed through ``ops.gather_pool`` (a ``jax.custom_vjp`` whose backward
    is the fused transpose); with ``fused=True`` neither direction
    materializes the ``[n, D]`` per-id intermediate."""
    return ops.gather_pool(rows_u, ctx_inv, weights, seg, n_bags, fused=fused)


# ---------------------------------------------------------------------------
# backward: transposed Shuffle + row-wise adagrad (sparse-exact)
# ---------------------------------------------------------------------------


def _dedup_apply(w_shard: jnp.ndarray, acc_shard: jnp.ndarray,
                 idx: jnp.ndarray, g: jnp.ndarray, valid: jnp.ndarray,
                 lr: float, eps: float, fused: bool = False
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sum duplicate row grads, then row-wise adagrad on touched rows only.

    ``fused=True`` runs the one-pass Pallas kernel (sorted-run detection +
    adagrad + in-place scatter; reference accumulation order, ~1 ULP);
    ``False`` the argsort/segment_sum/scatter chain — both via
    ``ops.dedup_adagrad``."""
    return ops.dedup_adagrad(w_shard, acc_shard, idx, g, valid, lr, eps,
                             fused=fused)


class CacheState(NamedTuple):
    keys: jnp.ndarray   # [H] sorted global row ids (sentinel = rows_padded)
    rows: jnp.ndarray   # [H, D]
    acc: jnp.ndarray    # [H, 1] adagrad accumulator


class ProjState(NamedTuple):
    """Learned per-group up-projection for hot/cold heterogeneous placement
    (``picasso_narrow``): cold ids live as ``[d]``-narrow master rows and are
    projected to the model width ``D`` at lookup. Replicated (like the tiers);
    its gradient is psum'd, so replicas stay bit-identical."""

    kernel: jnp.ndarray  # [d, D]
    acc: jnp.ndarray     # [d, 1] row-wise adagrad accumulator


def init_cache(h: int, d: int, rows_padded: int, dtype=jnp.float32) -> CacheState:
    return CacheState(
        keys=jnp.full((h,), rows_padded, jnp.int32),
        rows=jnp.zeros((h, d), dtype),
        acc=jnp.zeros((h, 1), dtype),
    )


def apply_sparse_grads(
    w_shard: jnp.ndarray,
    acc_shard: jnp.ndarray,
    cache: Optional[CacheState],
    ctx: LookupCtx,
    g_u: jnp.ndarray,    # [n, D] grad wrt unique rows
    *,
    axes: Axes,
    world: int,
    lr: float,
    eps: float = 1e-8,
    cache_update: str = "psum",   # 'psum' (replica-consistent exact) | 'stale'
    fused: bool = False,          # fused dedup+adagrad scatter kernels
    compress: str = "none",       # routed-grad wire compression (grad_compression)
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[CacheState]]:
    """Transposed path: miss grads -> owners; hit grads -> hot tier or owners.

    'psum'  — hit grads are psum'd into the replicated hot tier; the hot tier
              is authoritative between flushes (exact training, but the
              all-reduce is O(H*D) per step — expensive for large H).
    'stale' — hit grads are routed to the *owner* shards through a second
              small all_to_all (O(hits*D)); the hot tier is read-only between
              flushes (paper Algorithm 1 semantics: bounded read staleness of
              flush_iters, master always exact).

    ``compress`` shrinks the routed all_to_all payloads ('none'|'fp16'|'topk',
    see ``repro.optim.grad_compression``). It covers the per-step routed hops
    only — tier-maintenance traffic (hot-tier psums, flush reloads) stays
    exact, since its cost is amortized and its consumers assume bitwise
    replica consistency.
    """
    # ---- miss gradients: transposed Shuffle --------------------------------
    w_shard, acc_shard = _apply_miss_grads(w_shard, acc_shard, ctx, g_u,
                                           axes, world, lr, eps, fused,
                                           compress)

    if cache is None or cache.keys.shape[0] == 0:
        return w_shard, acc_shard, cache

    if cache_update == "stale":
        # ---- hit gradients: route to owners (cache stays read-only) --------
        w_shard, acc_shard = _route_hit_grads(w_shard, acc_shard, ctx, ctx.hit,
                                              g_u, axes, world, lr, eps, fused,
                                              compress)
        return w_shard, acc_shard, cache

    # ---- 'psum': hit grads into the replicated hot tier --------------------
    cache = _psum_into_tier(cache, ctx.hit, ctx.cache_slot, g_u, axes, lr, eps,
                            fused)
    return w_shard, acc_shard, cache


def _apply_miss_grads(w_shard, acc_shard, ctx: LookupCtx, g_u, axes: Axes,
                      world: int, lr: float, eps: float, fused: bool = False,
                      compress: str = "none"):
    """Transposed Shuffle: route miss grads to owner shards and apply."""
    d = w_shard.shape[1]
    cap = ctx.recv_ids.shape[1]  # static block shape
    send_g = jnp.zeros((world * cap, d), g_u.dtype)
    send_g = send_g.at[ctx.routing.send_slot].set(
        g_u * ctx.routing.kept[:, None].astype(g_u.dtype), mode="drop")
    recv_g = _compressed_a2a_rows(send_g, axes, world, cap, compress, fused)
    return _dedup_apply(
        w_shard, acc_shard,
        ctx.recv_local.reshape(-1), recv_g, ctx.recv_valid.reshape(-1), lr, eps,
        fused)


def _route_hit_grads(w_shard, acc_shard, ctx: LookupCtx, hit_mask, g_u,
                     axes: Axes, world: int, lr: float, eps: float,
                     fused: bool = False, compress: str = "none"):
    """'stale' mode: grads of tier-served ids ride a second small all_to_all
    to the owner shards; the tier itself stays read-only between flushes."""
    rps, d = w_shard.shape
    cap = ctx.recv_ids.shape[1]
    r = partition(ctx.uniq, hit_mask, rps, world, cap)
    send_ids = jnp.full((world * cap,), -1, jnp.int32)
    send_ids = send_ids.at[r.send_slot].set(ctx.uniq.astype(jnp.int32), mode="drop")
    send_hg = jnp.zeros((world * cap, d), g_u.dtype)
    send_hg = send_hg.at[r.send_slot].set(
        g_u * r.kept[:, None].astype(g_u.dtype), mode="drop")
    recv_ids = _a2a(send_ids.reshape(world, cap), axes).reshape(-1)
    recv_hg = _compressed_a2a_rows(send_hg, axes, world, cap, compress, fused)
    my = lax.axis_index(axes).astype(jnp.int32)
    local = jnp.clip(recv_ids - my * rps, 0, rps - 1)
    return _dedup_apply(
        w_shard, acc_shard, local, recv_hg, recv_ids >= 0, lr, eps, fused)


def _tier_adagrad(tier: CacheState, g_hot: jnp.ndarray, lr: float,
                  eps: float) -> CacheState:
    """Row-wise adagrad on a replicated tier from a replica-consistent
    per-slot gradient (rows without gradient stay bit-identical)."""
    gsq = jnp.mean(jnp.square(g_hot), axis=-1, keepdims=True)
    touched = (jnp.abs(g_hot).max(axis=-1, keepdims=True) > 0).astype(gsq.dtype)
    acc_new = tier.acc + gsq * touched
    upd = lr * g_hot / jnp.sqrt(acc_new + eps)
    return CacheState(tier.keys, tier.rows - upd.astype(tier.rows.dtype),
                      acc_new.astype(tier.acc.dtype))


def _psum_into_tier(tier: CacheState, hit_mask, slot, g_u, axes: Axes,
                    lr: float, eps: float, fused: bool = False) -> CacheState:
    """'psum' mode: all-reduce tier-hit grads and adagrad the replicated tier
    in place (replicas stay bit-identical; the tier is authoritative for its
    rows between flushes). Comm is O(H*D) per step — right for the small
    device-resident hot tier.

    Deliberately NOT routed through the dedup+adagrad kernel even when
    ``fused``: the psum forces the dense ``[H, D]`` buffer into existence
    anyway, after which the dense row-wise adagrad is a single fused
    elementwise pass — a per-row scatter kernel over the identity index
    would only serialize it. Fusion pays where it removes the dense buffer
    (``_allgather_into_tier``) or the scatter chain (``_dedup_apply``)."""
    del fused
    h = tier.keys.shape[0]
    d = g_u.shape[1]
    g_hit = g_u * hit_mask[:, None].astype(g_u.dtype)
    g_hot = jnp.zeros((h, d), g_u.dtype).at[slot].add(g_hit)
    g_hot = lax.psum(g_hot, axes)
    return _tier_adagrad(tier, g_hot, lr, eps)


def _allgather_into_tier(tier: CacheState, hit_mask, slot, g_u, axes: Axes,
                         lr: float, eps: float, fused: bool = False
                         ) -> CacheState:
    """Exact replicated-tier update with comm independent of the tier size:
    all_gather every shard's (masked) hit grads + slots, scatter-add them
    locally on each replica. The gathered order is identical everywhere, so
    replicas stay consistent like the psum path, but the wire cost is
    O(world * n * D) instead of O(H * D) — the right trade for the L2 host
    tier, whose H2 is 10-100x the hot tier while n stays batch-sized.

    When fused, the gathered grads feed the dedup+adagrad kernel directly —
    the dense ``[H2, D]`` scatter buffer is never materialized (within-row
    accumulation happens in sorted-slot order, replica-identical)."""
    h = tier.keys.shape[0]
    d = g_u.shape[1]
    g_hit = g_u * hit_mask[:, None].astype(g_u.dtype)
    slots = jnp.where(hit_mask, slot, h).astype(jnp.int32)  # h = drop
    all_slots = lax.all_gather(slots, axes, tiled=True)      # [world*n]
    all_g = lax.all_gather(g_hit, axes, tiled=True)          # [world*n, D]
    if fused:
        rows2, acc2 = ops.dedup_adagrad(
            tier.rows, tier.acc, all_slots, all_g, all_slots < h, lr, eps,
            fused=True)
        return CacheState(tier.keys, rows2, acc2)
    g_hot = jnp.zeros((h, d), g_u.dtype).at[all_slots].add(all_g, mode="drop")
    return _tier_adagrad(tier, g_hot, lr, eps)


def apply_sparse_grads_l2(
    w_shard: jnp.ndarray,
    acc_shard: jnp.ndarray,
    cache: Optional[CacheState],
    l2: CacheState,
    ctx: LookupCtx,
    g_u: jnp.ndarray,
    *,
    axes: Axes,
    world: int,
    lr: float,
    eps: float = 1e-8,
    cache_update: str = "psum",
    fused: bool = False,
    compress: str = "none",
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[CacheState], CacheState]:
    """Two-tier transposed path (L1 hot tier + L2 host tier).

    Misses (neither tier) ride the transposed Shuffle exactly as in
    ``apply_sparse_grads``. Tier-hit grads follow ``cache_update``:

    'psum'  — both tiers stay authoritative between flushes (exact). L1 hit
              grads are psum'd as usual (O(H1*D), small tier). For L2 the
              update picks the cheaper of two exact, replica-consistent
              reductions by *static* shapes: the dense O(H2*D) psum, or an
              all_gather of the batch's hit grads + slots applied locally
              (O(world*n*D)) — for a host tier 10-100x the hot tier, the
              gather is what keeps per-step comm proportional to the batch
              rather than the tier.
    'stale' — the union of L1 and L2 hits rides one second all_to_all to the
              owner shards; both tiers are read-only between flushes
              (Algorithm 1 bounded-staleness, master always exact).

    ``ctx`` must come from an L2-probing ``mp_lookup`` (``ctx.l2_hit`` set).
    """
    w_shard, acc_shard = _apply_miss_grads(w_shard, acc_shard, ctx, g_u,
                                           axes, world, lr, eps, fused,
                                           compress)
    if cache_update == "stale":
        both = ctx.hit | ctx.l2_hit
        w_shard, acc_shard = _route_hit_grads(w_shard, acc_shard, ctx, both,
                                              g_u, axes, world, lr, eps, fused,
                                              compress)
        return w_shard, acc_shard, cache, l2
    if cache is not None and cache.keys.shape[0] > 0:
        cache = _psum_into_tier(cache, ctx.hit, ctx.cache_slot, g_u, axes,
                                lr, eps, fused)
    h2 = l2.keys.shape[0]
    if h2 > 0:
        n, d = g_u.shape
        gather_elems = (world - 1) * n * (d + 1)   # hit grads + slots
        if gather_elems < h2 * d:
            l2 = _allgather_into_tier(l2, ctx.l2_hit, ctx.l2_slot, g_u,
                                      axes, lr, eps, fused)
        else:
            l2 = _psum_into_tier(l2, ctx.l2_hit, ctx.l2_slot, g_u, axes,
                                 lr, eps, fused)
    return w_shard, acc_shard, cache, l2


def _proj_adagrad(proj: ProjState, g_proj: jnp.ndarray, lr: float,
                  eps: float) -> ProjState:
    """Row-wise adagrad on the replicated projection from a psum'd (replica-
    consistent) gradient — the same update rule the tiers use, so the
    projection trains in lockstep with the rows it serves."""
    gsq = jnp.mean(jnp.square(g_proj), axis=-1, keepdims=True)
    acc_new = proj.acc + gsq
    upd = lr * g_proj / jnp.sqrt(acc_new + eps)
    return ProjState(proj.kernel - upd.astype(proj.kernel.dtype),
                     acc_new.astype(proj.acc.dtype))


def apply_sparse_grads_narrow(
    w_shard: jnp.ndarray,       # [rps, d] narrow master shard
    acc_shard: jnp.ndarray,
    cache: Optional[CacheState],  # L1 (wide rows)
    l2: Optional[CacheState],     # L2 (wide rows); None = narrow w/o L2 tier
    proj: ProjState,
    ctx: LookupCtx,               # from mp_lookup_narrow (narrow_rows set)
    g_u: jnp.ndarray,             # [n, D] grad wrt the (wide) unique rows
    *,
    axes: Axes,
    world: int,
    lr: float,
    eps: float = 1e-8,
    cache_update: str = "psum",
    fused: bool = False,
    compress: str = "none",
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[CacheState],
           Optional[CacheState], ProjState]:
    """Two-tier transposed path at heterogeneous widths.

    The wide cotangent is folded through ``proj^T`` ONCE (``g_n = g_u @
    proj.kernel.T``, one MXU pass); routed hops then carry the narrow
    gradient — the same ``world*cap*d`` wire the forward used — through the
    unchanged (compressible) ``_apply_miss_grads`` / ``_route_hit_grads``
    machinery, and the owner-side dedup+adagrad updates the narrow master.
    Tier-hit grads update the WIDE tiers exactly as in
    ``apply_sparse_grads_l2`` (the tiers are authoritative full-width rows in
    'psum' mode). The projection's own gradient is one ``narrow^T @ g_u``
    matmul off the lookup's residual (only routed positions contribute — the
    chain rule: tier hits never passed through ``proj``), psum'd so replicas
    stay bit-identical, then adagrad'd.
    """
    g_n = (g_u @ proj.kernel.T).astype(g_u.dtype)   # [n, d]
    w_shard, acc_shard = _apply_miss_grads(w_shard, acc_shard, ctx, g_n,
                                           axes, world, lr, eps, fused,
                                           compress)
    if cache_update == "stale":
        both = ctx.hit if ctx.l2_hit is None else (ctx.hit | ctx.l2_hit)
        w_shard, acc_shard = _route_hit_grads(w_shard, acc_shard, ctx, both,
                                              g_n, axes, world, lr, eps, fused,
                                              compress)
    else:
        if cache is not None and cache.keys.shape[0] > 0:
            cache = _psum_into_tier(cache, ctx.hit, ctx.cache_slot, g_u, axes,
                                    lr, eps, fused)
        h2 = 0 if l2 is None else l2.keys.shape[0]
        if h2 > 0 and ctx.l2_hit is not None:
            n, d = g_u.shape
            gather_elems = (world - 1) * n * (d + 1)
            if gather_elems < h2 * d:
                l2 = _allgather_into_tier(l2, ctx.l2_hit, ctx.l2_slot, g_u,
                                          axes, lr, eps, fused)
            else:
                l2 = _psum_into_tier(l2, ctx.l2_hit, ctx.l2_slot, g_u, axes,
                                     lr, eps, fused)
    g_proj = lax.psum(ctx.narrow_rows.T @ g_u, axes)   # [d, D]
    proj = _proj_adagrad(proj, g_proj, lr, eps)
    return w_shard, acc_shard, cache, l2, proj


# ---------------------------------------------------------------------------
# frequency statistics + HybridHash flush (Algorithm 1)
# ---------------------------------------------------------------------------


def count_frequencies(counts_shard: jnp.ndarray, ctx: LookupCtx) -> jnp.ndarray:
    """Owner-side FCounter update from the ids received this step.

    Counts *routed* queries; for the single-tier path, cache hits are counted
    via their last routed appearance before entering the hot set (good enough
    for top-k drift on a small L1, and the decay in ``flush_cache`` re-ranks
    over time). Two-tier strategies must additionally count tier hits
    (``count_hit_frequencies``): with an L2 covering a large table fraction,
    the uncounted resident mass would otherwise decay below the routed tail
    and the flush would churn-evict genuinely hot rows.
    """
    return counts_shard.at[ctx.recv_local.reshape(-1)].add(
        ctx.recv_valid.reshape(-1).astype(counts_shard.dtype))


def count_hit_frequencies(counts_shard: jnp.ndarray, ctx: LookupCtx,
                          hit_mask: jnp.ndarray, *, axes: Axes,
                          world: int) -> jnp.ndarray:
    """FCounter update for tier-served lookups, with zero communication.

    Tier hits never ride the Shuffle, so the owner shard does not observe
    them. Instead of psum'ing per-slot hit counts (O(H) ints per step — the
    very cost the tier avoids), each shard scatters the hits *it* issued into
    its own slice of the FCounter, weighted by ``world``: a shard owns a
    scrambled row with probability 1/world, so the weighted local sample is
    an unbiased (Horvitz-Thompson) estimate of the global hit count — exact
    at world=1, ranking-preserving in expectation at scale.
    """
    rps = counts_shard.shape[0]
    my = lax.axis_index(axes).astype(jnp.int32)
    local = ctx.uniq.astype(jnp.int32) - my * rps
    ok = hit_mask & (local >= 0) & (local < rps)
    safe = jnp.where(ok, jnp.clip(local, 0, rps - 1), rps)
    inc = jnp.asarray(world, counts_shard.dtype) * ok.astype(counts_shard.dtype)
    return counts_shard.at[safe].add(inc, mode="drop")


def cache_hit_count(ctx: LookupCtx) -> jnp.ndarray:
    return jnp.sum(ctx.hit)


def l2_hit_count(ctx: LookupCtx) -> jnp.ndarray:
    if ctx.l2_hit is None:
        return jnp.zeros((), jnp.int32)
    return jnp.sum(ctx.l2_hit)


def flush_cache(
    w_shard: jnp.ndarray,
    acc_shard: jnp.ndarray,
    counts_shard: jnp.ndarray,
    cache: CacheState,
    *,
    axes: Axes,
    world: int,
    decay: float = 0.5,
    write_back: bool = True,   # False for cache_update='stale' (master is exact)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, CacheState]:
    """Periodic HybridHash flush (Algorithm 1 L23-26), replica-consistent.

    1. write back hot rows + optimizer state to owner shards (no comm: the
       hot tier is replicated, owners take their slice) — 'psum' mode only;
    2. select the new global top-H by frequency (all_gather of local top-H);
    3. load the new hot set (psum of owner contributions).
    """
    rps, d = w_shard.shape
    h = cache.keys.shape[0]
    rows_padded = rps * world
    my = lax.axis_index(axes).astype(jnp.int32)
    base = my * rps

    # ---- 1. write back ------------------------------------------------------
    if write_back:
        w_shard, acc_shard = _write_back_tier(w_shard, acc_shard, cache,
                                              base, rps, rows_padded)

    # ---- 2. global top-H ----------------------------------------------------
    # scrambled ids spread the hot set ~uniformly over shards, so the global
    # top-H is inside the union of per-shard top-(4H/world) w.h.p. — keeps the
    # all_gather at 4H instead of world*H.
    k_local = min(rps, max(32, (4 * h + world - 1) // world))
    lvals, lidx = lax.top_k(counts_shard, k_local)
    gids = base + lidx.astype(jnp.int32)
    all_vals = lax.all_gather(lvals, axes, tiled=True)   # [world*k_local]
    all_ids = lax.all_gather(gids, axes, tiled=True)
    tvals, tidx = lax.top_k(all_vals, h)
    new_keys = jnp.sort(jnp.where(tvals > 0, all_ids[tidx], rows_padded))

    # ---- 3. load new hot set ------------------------------------------------
    new_cache = _load_tier(w_shard, acc_shard, new_keys, base, rps,
                           rows_padded, axes)

    counts_shard = (counts_shard.astype(jnp.float32) * decay).astype(counts_shard.dtype)
    return w_shard, acc_shard, counts_shard, new_cache


def _write_back_tier(w_shard, acc_shard, tier: CacheState, base, rps: int,
                     rows_padded: int):
    """Owner shards take their slice of a replicated tier (no comm)."""
    local = tier.keys - base
    mine = (local >= 0) & (local < rps) & (tier.keys < rows_padded)
    safe_idx = jnp.where(mine, jnp.clip(local, 0, rps - 1), rps)
    w_shard = w_shard.at[safe_idx].set(tier.rows.astype(w_shard.dtype), mode="drop")
    acc_shard = acc_shard.at[safe_idx].set(tier.acc.astype(acc_shard.dtype), mode="drop")
    return w_shard, acc_shard


def _load_tier(w_shard, acc_shard, keys, base, rps: int, rows_padded: int,
               axes: Axes) -> CacheState:
    """psum of owner contributions: master rows -> a fresh replicated tier."""
    nlocal = keys - base
    nmine = (nlocal >= 0) & (nlocal < rps) & (keys < rows_padded)
    nclip = jnp.clip(nlocal, 0, rps - 1)
    contrib_w = jnp.take(w_shard, nclip, axis=0) * nmine[:, None].astype(w_shard.dtype)
    contrib_a = jnp.take(acc_shard, nclip, axis=0) * nmine[:, None].astype(acc_shard.dtype)
    return CacheState(keys, lax.psum(contrib_w, axes), lax.psum(contrib_a, axes))


def flush_cache_l2(
    w_shard: jnp.ndarray,
    acc_shard: jnp.ndarray,
    counts_shard: jnp.ndarray,
    cache: CacheState,
    l2: CacheState,
    *,
    axes: Axes,
    world: int,
    decay: float = 0.5,
    write_back: bool = True,   # False for cache_update='stale'
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, CacheState, CacheState]:
    """Two-tier HybridHash flush: one global frequency ranking fills both tiers.

    1. write back L1 and L2 rows + optimizer state to owner shards ('psum'
       mode only — in 'stale' mode the master is already exact);
    2. select the global top-(H1+H2) rows by FCounter frequency; the hottest
       H1 become the new L1 hot tier, the next H2 the new L2 host tier — so
       the tiers are disjoint by construction and L2 holds exactly the skew
       tail that overflows the device-resident budget;
    3. reload both tiers from the (just-synced) master shards.

    Degenerate tiers (0 rows) are handled: an empty L1 makes this equivalent
    to a single-tier flush of L2 and vice versa.
    """
    rps, d = w_shard.shape
    h1, h2 = cache.keys.shape[0], l2.keys.shape[0]
    h = h1 + h2
    rows_padded = rps * world
    my = lax.axis_index(axes).astype(jnp.int32)
    base = my * rps

    # ---- 1. write back ------------------------------------------------------
    if write_back:
        w_shard, acc_shard = _write_back_tier(w_shard, acc_shard, cache,
                                              base, rps, rows_padded)
        w_shard, acc_shard = _write_back_tier(w_shard, acc_shard, l2,
                                              base, rps, rows_padded)

    # ---- 2. one global top-(H1+H2), split by rank ---------------------------
    k_local = min(rps, max(32, (4 * h + world - 1) // world))
    lvals, lidx = lax.top_k(counts_shard, k_local)
    gids = base + lidx.astype(jnp.int32)
    all_vals = lax.all_gather(lvals, axes, tiled=True)
    all_ids = lax.all_gather(gids, axes, tiled=True)
    tvals, tidx = lax.top_k(all_vals, h)
    keys_ranked = jnp.where(tvals > 0, all_ids[tidx], rows_padded)
    keys1 = jnp.sort(keys_ranked[:h1])   # hottest H1 -> device tier
    keys2 = jnp.sort(keys_ranked[h1:])   # next H2    -> host tier

    # ---- 3. reload both tiers from master -----------------------------------
    new_l1 = _load_tier(w_shard, acc_shard, keys1, base, rps, rows_padded, axes)
    new_l2 = _load_tier(w_shard, acc_shard, keys2, base, rps, rows_padded, axes)

    counts_shard = (counts_shard.astype(jnp.float32) * decay).astype(counts_shard.dtype)
    return w_shard, acc_shard, counts_shard, new_l1, new_l2


def proj_pinv(proj_kernel: jnp.ndarray, ridge: float = 1e-6) -> jnp.ndarray:
    """Regularized right pseudo-inverse of the ``[d, D]`` up-projection:
    ``pinv = P^T (P P^T + ridge*I)^{-1}``, a ``[D, d]`` map with
    ``narrow @ P @ pinv ~= narrow``. At init the projection's rows are
    orthonormal, so ``pinv ~= P^T`` exactly; the ridge keeps the ``[d, d]``
    solve well-posed as the kernel trains away from orthonormality. Used to
    *narrow* wide rows (tier write-back, wide->narrow migration)."""
    nd = proj_kernel.shape[0]
    gram = proj_kernel @ proj_kernel.T
    eye = jnp.eye(nd, dtype=proj_kernel.dtype)
    return proj_kernel.T @ jnp.linalg.solve(gram + ridge * eye, eye)


def _write_back_tier_narrow(w_shard, acc_shard, tier: CacheState, pinv,
                            base, rps: int, rows_padded: int):
    """Owner shards take their slice of a replicated WIDE tier, narrowed
    through the projection's pseudo-inverse into the narrow master."""
    local = tier.keys - base
    mine = (local >= 0) & (local < rps) & (tier.keys < rows_padded)
    safe_idx = jnp.where(mine, jnp.clip(local, 0, rps - 1), rps)
    nrows = tier.rows @ pinv                                  # [H, d]
    w_shard = w_shard.at[safe_idx].set(nrows.astype(w_shard.dtype), mode="drop")
    acc_shard = acc_shard.at[safe_idx].set(tier.acc.astype(acc_shard.dtype),
                                           mode="drop")
    return w_shard, acc_shard


def _load_tier_widened(w_shard, acc_shard, keys, proj_kernel, base, rps: int,
                       rows_padded: int, axes: Axes) -> CacheState:
    """psum of owner contributions at the narrow width, then ONE widening
    matmul on the assembled tier — narrow master rows -> a fresh replicated
    wide tier (never a per-id widen)."""
    nlocal = keys - base
    nmine = (nlocal >= 0) & (nlocal < rps) & (keys < rows_padded)
    nclip = jnp.clip(nlocal, 0, rps - 1)
    contrib_n = jnp.take(w_shard, nclip, axis=0) * nmine[:, None].astype(w_shard.dtype)
    contrib_a = jnp.take(acc_shard, nclip, axis=0) * nmine[:, None].astype(acc_shard.dtype)
    narrow = lax.psum(contrib_n, axes)
    return CacheState(keys, (narrow @ proj_kernel).astype(w_shard.dtype),
                      lax.psum(contrib_a, axes))


def _carry_exact_rows(tier: CacheState, old1: CacheState, old2: CacheState,
                      rows_padded: int) -> CacheState:
    """Keep ids that stayed tier-resident at their EXACT wide rows: a hot id
    that survives the re-rank must not round-trip through the rank-``d``
    projection (which would crush the component of its row orthogonal to the
    projection's span every flush). Freshly promoted ids keep their widened
    (``narrow @ P``) reload."""
    rows, acc = tier.rows, tier.acc
    for old in (old1, old2):
        if old.keys.shape[0] == 0:
            continue
        p = jnp.searchsorted(old.keys, tier.keys).astype(jnp.int32)
        pc = jnp.clip(p, 0, old.keys.shape[0] - 1)
        found = (old.keys[pc] == tier.keys) & (tier.keys < rows_padded)
        rows = jnp.where(found[:, None], jnp.take(old.rows, pc, axis=0), rows)
        acc = jnp.where(found[:, None], jnp.take(old.acc, pc, axis=0), acc)
    return CacheState(tier.keys, rows, acc)


def flush_cache_narrow(
    w_shard: jnp.ndarray,       # [rps, d] narrow master shard
    acc_shard: jnp.ndarray,
    counts_shard: jnp.ndarray,
    cache: CacheState,          # L1 (wide)
    l2: CacheState,             # L2 (wide)
    proj_kernel: jnp.ndarray,   # [d, D]
    *,
    axes: Axes,
    world: int,
    decay: float = 0.5,
    write_back: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, CacheState, CacheState]:
    """Two-tier flush at heterogeneous widths — the re-widening lifecycle:

    1. write back both WIDE tiers into the narrow master through the
       projection's pseudo-inverse ('psum' mode; adagrad scalars pass through
       exactly);
    2. one global top-(H1+H2) frequency ranking, split hottest-H1 / next-H2
       (identical to ``flush_cache_l2``);
    3. reload both tiers *widened* (``narrow @ P``, one matmul per tier) —
       but ids that stayed tier-resident keep their exact pre-flush wide rows
       (``_carry_exact_rows``): only ids crossing the hot/cold boundary pass
       through the projection, so a persistently hot id trains at the full
       width indefinitely while a cooled id is narrowed to its best
       rank-``d`` approximation.

    In 'stale' mode (``write_back=False``) the narrow master is already
    exact and the tiers are read-only widened copies — no write-back, and no
    exact-carry either (the master is the single source of truth).
    """
    rps, nd = w_shard.shape
    h1, h2 = cache.keys.shape[0], l2.keys.shape[0]
    h = h1 + h2
    rows_padded = rps * world
    my = lax.axis_index(axes).astype(jnp.int32)
    base = my * rps

    if write_back:
        pinv = proj_pinv(proj_kernel)
        w_shard, acc_shard = _write_back_tier_narrow(w_shard, acc_shard, cache,
                                                     pinv, base, rps, rows_padded)
        w_shard, acc_shard = _write_back_tier_narrow(w_shard, acc_shard, l2,
                                                     pinv, base, rps, rows_padded)

    k_local = min(rps, max(32, (4 * h + world - 1) // world))
    lvals, lidx = lax.top_k(counts_shard, k_local)
    gids = base + lidx.astype(jnp.int32)
    all_vals = lax.all_gather(lvals, axes, tiled=True)
    all_ids = lax.all_gather(gids, axes, tiled=True)
    tvals, tidx = lax.top_k(all_vals, h)
    keys_ranked = jnp.where(tvals > 0, all_ids[tidx], rows_padded)
    keys1 = jnp.sort(keys_ranked[:h1])
    keys2 = jnp.sort(keys_ranked[h1:])

    new_l1 = _load_tier_widened(w_shard, acc_shard, keys1, proj_kernel,
                                base, rps, rows_padded, axes)
    new_l2 = _load_tier_widened(w_shard, acc_shard, keys2, proj_kernel,
                                base, rps, rows_padded, axes)
    if write_back:
        new_l1 = _carry_exact_rows(new_l1, cache, l2, rows_padded)
        new_l2 = _carry_exact_rows(new_l2, cache, l2, rows_padded)

    counts_shard = (counts_shard.astype(jnp.float32) * decay).astype(counts_shard.dtype)
    return w_shard, acc_shard, counts_shard, new_l1, new_l2


# ---------------------------------------------------------------------------
# baseline strategies (paper §II-C) for comparison benchmarks
# ---------------------------------------------------------------------------


def ps_lookup(table_shard: jnp.ndarray, ids: jnp.ndarray, *, axes: Axes, world: int
              ) -> jnp.ndarray:
    """PS/DP-style lookup: all_gather ids, psum partial rows (no routing, no
    dedup, no cache). Communication O(world * n * D) vs O(n * D) for the
    PICASSO path — this is the fragmentary baseline the paper beats."""
    rps, d = table_shard.shape
    my = lax.axis_index(axes).astype(jnp.int32)
    base = my * rps
    all_ids = lax.all_gather(ids, axes, tiled=True)         # [world*n]
    local = all_ids - base
    ok = (local >= 0) & (local < rps)
    part = jnp.take(table_shard, jnp.clip(local, 0, rps - 1), axis=0)
    part = part * ok[:, None].astype(part.dtype)
    full = lax.psum(part, axes)                              # [world*n, D]
    n = ids.shape[0]
    return lax.dynamic_slice_in_dim(full, my * n, n, axis=0)


def mp_lookup_nodedup(
    table_shard: jnp.ndarray,
    ids: jnp.ndarray,
    *,
    axes: Axes,
    world: int,
    capacity: int,
) -> Tuple[jnp.ndarray, LookupCtx]:
    """Model-parallel Shuffle *without* K-Packed dedup (paper §II-C baseline).

    Every raw id rides the all_to_all — duplicates each consume their own
    bucket slot, so the wire payload is O(n) rows instead of O(uniq). This is
    the 'fragmentary op sequence' PICASSO's Unique&Partition fusion beats; it
    exists so ``bench_throughput`` can price the dedup itself.

    Returns the same ``(rows, LookupCtx)`` contract as ``mp_lookup`` (ids are
    sorted, not uniqued — ``inv`` maps original positions to sorted slots, so
    pooling and the transposed gradient path compose unchanged; the owner-side
    dedup+adagrad scatter sums the duplicate rows' grads, keeping training
    math identical to the deduped path whenever nothing overflows). Needs
    ``capacity >= n`` per owner in the worst case — plan with
    ``exact_capacity=True`` for lossless parity runs.
    """
    rps, d = table_shard.shape
    n = ids.shape[0]
    order = jnp.argsort(ids)
    s = ids[order]                                  # sorted, duplicates kept
    inv = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    every = jnp.ones((n,), bool)
    r = partition(s, every, rps, world, capacity)

    send_ids = jnp.full((world * capacity,), -1, jnp.int32)
    send_ids = send_ids.at[r.send_slot].set(s.astype(jnp.int32), mode="drop")
    recv_ids = _a2a(send_ids.reshape(world, capacity), axes)

    my = lax.axis_index(axes)
    base = my.astype(jnp.int32) * rps
    recv_valid = recv_ids >= 0
    recv_local = jnp.clip(recv_ids - base, 0, rps - 1)

    served = jnp.take(table_shard, recv_local.reshape(-1), axis=0)
    served = served * recv_valid.reshape(-1, 1).astype(served.dtype)
    back = _a2a(served.reshape(world, capacity, d), axes).reshape(
        world * capacity, d)
    take_idx = jnp.minimum(r.send_slot, world * capacity - 1)
    rows = jnp.take(back, take_idx, axis=0) * r.kept[:, None].astype(served.dtype)

    ctx = LookupCtx(
        uniq=s, inv=inv, uvalid=every,
        hit=jnp.zeros((n,), bool), cache_slot=jnp.zeros((n,), jnp.int32),
        routing=r, recv_ids=recv_ids, recv_local=recv_local,
        recv_valid=recv_valid,
    )
    return rows, ctx
