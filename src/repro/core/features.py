"""D-Packing of the input batch (paper Fig. 7a -> 7b).

Turns the per-field batch dict {field: ids [B, L], weights [B, L]} into one
packed (ids, weights, seg) triple per PackedGroup — the single packed ID
tensor the paper feeds to each packed operation. Scrambling + table offsets
map raw per-table IDs into the packed global row space.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.hashing import scramble
from repro.core.packing import PackedGroup, PicassoPlan


class PackedBatch(NamedTuple):
    ids: jnp.ndarray      # [B * ids_per_sample]
    weights: jnp.ndarray  # [B * ids_per_sample]
    seg: jnp.ndarray      # [B * ids_per_sample] bag index in [0, B*n_bags)
    n_bags: int           # per sample


class FieldView(NamedTuple):
    gid: int
    bag_offset: int
    n_bags: int
    dim: int


def field_index(plan: PicassoPlan) -> Dict[str, FieldView]:
    out = {}
    for g in plan.groups:
        for s in g.slots:
            out[s.field.name] = FieldView(g.gid, s.bag_offset, s.n_bags, g.dim)
    return out


def pack_group(group: PackedGroup, batch: Dict[str, Dict[str, jnp.ndarray]]) -> PackedBatch:
    """Build the packed ID tensor for one group (jit-traceable)."""
    ids_l: List[jnp.ndarray] = []
    w_l: List[jnp.ndarray] = []
    seg_l: List[np.ndarray] = []
    b = next(iter(batch.values()))["ids"].shape[0]
    n_bags = group.n_bags
    for s in group.slots:
        f = s.field
        raw = batch[f.name]["ids"]            # [B, L]
        w = batch[f.name]["weights"]          # [B, L]
        table = next(t for t in group.tables if t.name == s.table)
        packed = scramble(raw, table.vocab, salt=hash(s.table) % 10007) + group.table_offsets[s.table]
        ids_l.append(packed.astype(jnp.int32))
        if f.pooling == "mean":
            denom = jnp.clip(w.sum(axis=1, keepdims=True), 1e-9, None)
            w = w / denom
        w_l.append(w)
        # bag index per position (static per config)
        if f.pooling == "none":
            bag = s.bag_offset + np.arange(f.max_len, dtype=np.int32)
        else:
            bag = np.full((f.max_len,), s.bag_offset, dtype=np.int32)
        seg_l.append(bag)
    ids = jnp.concatenate(ids_l, axis=1).reshape(-1)
    weights = jnp.concatenate(w_l, axis=1).reshape(-1).astype(jnp.float32)
    per_sample = np.concatenate(seg_l)                       # [ids_per_sample]
    seg = (np.arange(b, dtype=np.int32)[:, None] * n_bags + per_sample[None, :]).reshape(-1)
    return PackedBatch(ids=ids, weights=weights, seg=jnp.asarray(seg), n_bags=n_bags)


def pack_all(plan: PicassoPlan, batch: Dict[str, Dict[str, jnp.ndarray]]) -> Dict[int, PackedBatch]:
    return {g.gid: pack_group(g, batch) for g in plan.groups}
