"""ID scrambling for shard load-balance.

Paper §II-D(3): skewed ID distributions unbalance shards. Real pipelines apply
the hashing trick when assigning raw IDs to table rows; we make that explicit
with a fixed bijective affine scramble per table so the zipf head spreads
uniformly over row blocks (and therefore over model-parallel shards), while
per-row frequency skew (what HybridHash exploits) is preserved.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_KNUTH = 2654435761  # odd => bijective mod 2^k; good mixing constant


def _coprime_mult(vocab: int) -> int:
    """A multiplier coprime with ``vocab`` (bijective affine map mod vocab)."""
    a = _KNUTH % vocab
    if a == 0:
        a = 1
    while np.gcd(a, vocab) != 1:
        a += 1
    return int(a)


def scramble(ids: jnp.ndarray, vocab: int, salt: int = 0) -> jnp.ndarray:
    """Affine scramble of ids into [0, vocab) (uint32 hashing trick).

    Bijective mod 2^32 (odd multiplier); the final ``% vocab`` is the standard
    hashing-trick fold — near-uniform spread of the zipf head across shards.
    """
    a = jnp.uint32(_coprime_mult(vocab) & 0xFFFFFFFF)
    return ((ids.astype(jnp.uint32) * a + jnp.uint32(salt)) % jnp.uint32(vocab)).astype(jnp.int32)


def scramble_np(ids: np.ndarray, vocab: int, salt: int = 0) -> np.ndarray:
    a = _coprime_mult(vocab)
    return ((ids.astype(np.uint64) * a + salt) % vocab).astype(np.int32)
