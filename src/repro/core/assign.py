"""Per-group strategy assignment: the cost model behind 'mixed' engines.

PICASSO's packing analysis (paper §III-B) treats every packed group the same
way, but embedding tables are wildly heterogeneous: a handful of huge skewed
tables dominate ``CalcVParam`` while hundreds of tiny tables cost more in
all_to_all routing overhead than MP sharding saves in memory. The winning
layout is *mixed* (HugeCTR hybrid embedding; Meta's DLRM efficiency study):
PS-replicate the tiny tables, model-parallel-shard the big ones, cache only
where the skew pays for the hot tier.

This module is pure planning (numpy / python, like ``repro.core.packing``).
``compile_assignment`` scores each packed group's per-step communication
volume under every registered strategy and emits a ``StrategyAssignment``:

``ps``
    all_gather ids + psum partial rows: O(world * n * D) elements but no
    routing machinery — wins for tiny/replicable groups where n is small and
    the fixed Shuffle overhead dominates.
``picasso``
    MP routing with the HybridHash hot tier absorbing the skew head: misses
    only through the Shuffle, plus the per-step psum of hot-row grads — wins
    for large groups whose FCounter skew gives a real hit ratio.
``hybrid``
    MP routing, no cache — the middle ground when a group is too big to
    replicate but too flat (or unbudgeted) to cache.
``picasso_l2``
    The picasso path with an L2 host-memory tier behind the hot tier
    (HugeCTR-style hierarchical parameter cache). Scored only for groups the
    plan gives an ``l2_rows`` budget: the candidate wins over plain picasso
    when the frequency mass ranked just below the L1 set (the working set
    that *overflows* the device-resident budget) clears the same
    profitability gate as the hot tier itself — a host read is charged at
    ``L2_HOST_FACTOR`` of a network element, so L2 pays off exactly where
    skew extends past the constricted L1.
``picasso_narrow``
    The picasso_l2 path with a frequency-adaptive narrow master: cold ids
    (the lookup mass neither tier absorbs, ``estimate_narrow_gain``) are
    stored and routed at the planned narrow width ``d = plan.narrow_dim``
    and projected up to the model dim at lookup, so both the cold miss wire
    and the master's parameter bytes shrink ~``D/d``-fold. Scored only for
    groups the plan gives a narrow budget, and gated to vparam-dominated
    cold-heavy groups (``NARROW_MIN_ROWS`` rows, ``NARROW_COLD_MIN`` cold
    mass) — hot-headed groups keep full width everywhere.

The engine consumes the result through ``resolve_assignment``, which also
normalizes the user-facing spellings (the **assignment resolution order**):
an explicit ``StrategyAssignment`` / ``{gid: name}`` dict is taken as-is
(validated for exact coverage), ``'mixed'``/``'auto'`` uses the plan's
recorded assignment or compiles one and records it, and any other single
registry name broadcasts.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from fnmatch import fnmatchcase
from typing import Dict, Mapping, Optional, Union

import numpy as np

from repro.core.packing import PackedGroup, PicassoPlan

# Fixed per-group cost (in "row elements") of launching the Shuffle machinery:
# unique/partition kernels plus two all_to_all dispatches. Tiny groups whose
# whole PS transfer is below this are cheaper off the routed path entirely.
ROUTE_OVERHEAD_ELEMS = 4096.0

# Cache hit ratio assumed for a budgeted group with no measured stats
# (paper Tab. VI: >=20% at a 1 GB hot tier on production skew).
DEFAULT_HIT_RATIO = 0.2

# A group is "replicable" (eligible for the PS path) only below this many
# packed rows: the PS pattern effectively replicates the lookup work on
# every shard, which is only acceptable for tiny tables.
PS_MAX_ROWS = 8192

# Minimum hot-tier hit ratio for the cache's psum/flush machinery to pay
# for itself; flatter groups stay on the plain routed path.
SKEW_MIN = 0.05

# Cost of serving one row element from the L2 host tier, relative to moving
# it over the network: a pinned-host DMA is cheaper than an all_to_all round
# trip but not free (PCIe/DMA bandwidth + the probe).
L2_HOST_FACTOR = 0.5

# The narrow (hot/cold heterogeneous width) master only pays off for groups
# whose parameter volume dominates the budget: below this many packed rows
# the k-fold vparam saving is noise while the projection still costs a
# matmul + psum per step.
NARROW_MIN_ROWS = 65536

# Minimum cold lookup mass (the share neither tier absorbs) for the narrow
# wire to matter: a hot-headed group serves almost everything full-width
# from the tiers, so narrowing its master mostly adds projection error.
NARROW_COLD_MIN = 0.3


@dataclass(frozen=True)
class GroupScore:
    """Cost-model inputs and per-candidate scores for one packed group."""

    gid: int
    vparam: float
    ids_per_shard: int          # expected ids per step per shard
    rows: int
    skew: float                 # estimated hot-tier hit ratio in [0, 1]
    costs: Dict[str, float]     # candidate name -> estimated cost / step
    choice: str
    reason: str
    units: str = "elems"        # "elems" (constants) | "us" (calibrated)


@dataclass(frozen=True)
class StrategyAssignment:
    """Plan-level strategy map plus the cost-model evidence behind it."""

    strategy: Dict[int, str]            # gid -> registry name
    scores: Dict[int, GroupScore] = field(default_factory=dict)

    def describe(self) -> str:
        """Human-readable per-group table (launchers print this)."""
        lines = []
        for gid in sorted(self.strategy):
            s = self.scores.get(gid)
            if s is None:
                lines.append(f"  g{gid}: {self.strategy[gid]}")
            else:
                lines.append(f"  g{gid}: {s.choice:8s} rows={s.rows:<9d} "
                             f"ids/shard={s.ids_per_shard:<6d} "
                             f"skew={s.skew:.2f}  ({s.reason})")
        return "\n".join(lines)


def _validate_name(name: str) -> str:
    # engine.strategies imports jax; keep this module importable without it
    # except when a name actually needs resolving against the registry.
    from repro.engine.strategies import get_strategy

    get_strategy(name)  # raises with the registry menu on unknown names
    return name


def _ranked(counts: Optional[np.ndarray], ranked: bool) -> Optional[np.ndarray]:
    """Counts as a descending frequency ranking (sorted once per caller)."""
    if counts is None:
        return None
    c = np.asarray(counts, np.float64).reshape(-1)
    return c if ranked else np.sort(c)[::-1]


def estimate_skew(group: PackedGroup, cache_rows: int,
                  counts: Optional[np.ndarray] = None, *,
                  ranked: bool = False, cost_model=None) -> float:
    """Expected hot-tier hit ratio for ``group`` given ``cache_rows`` slots.

    With measured FCounter ``counts`` (the engine's per-row frequency stats,
    any shard layout — only the distribution matters), the hit ratio is the
    lookup share of the ``cache_rows`` hottest rows. Without stats we fall
    back to the paper's warm-skew prior for budgeted groups — except when
    the tier covers the whole table, where every lookup hits.
    ``ranked=True`` promises ``counts`` is already sorted descending (so a
    caller scoring several tiers sorts the multi-million-row array once).
    A calibrated ``cost_model`` replaces the structural prior with its
    measured ``hit_prior`` (``repro.perf.CostModel``).
    """
    cache_rows = min(int(cache_rows), group.rows)
    if cache_rows <= 0:
        return 0.0
    c = _ranked(counts, ranked)
    if c is not None:
        total = float(c.sum())
        if total > 0:
            return float(c[:cache_rows].sum() / total)
    if cache_rows >= group.rows:
        return 1.0
    return float(cost_model.hit_prior) if cost_model is not None \
        else DEFAULT_HIT_RATIO


def estimate_l2_gain(group: PackedGroup, cache_rows: int, l2_rows: int,
                     counts: Optional[np.ndarray] = None, *,
                     ranked: bool = False, cost_model=None) -> float:
    """Extra hit ratio an L2 tier of ``l2_rows`` slots adds behind an L1 of
    ``cache_rows`` slots.

    With measured FCounter ``counts`` this is exact: the lookup share of the
    rows frequency-ranked in ``[cache_rows, cache_rows + l2_rows)`` — the
    band the two-tier flush actually loads into L2 (``ranked=True`` as in
    ``estimate_skew``). Without stats: full coverage (L1+L2 >= the whole
    table) absorbs everything L1 misses; else the warm-skew prior scaled by
    how much the host tier out-sizes the (constricted) device tier — an L2
    smaller than L1 adds proportionally less, matching the zipf tail
    flattening past the head.
    """
    cache_rows = min(int(cache_rows), group.rows)
    l2_rows = min(int(l2_rows), group.rows - cache_rows)
    if l2_rows <= 0:
        return 0.0
    c = _ranked(counts, ranked)
    if c is not None:
        total = float(c.sum())
        if total > 0:
            return float(c[cache_rows:cache_rows + l2_rows].sum() / total)
    l1 = estimate_skew(group, cache_rows, cost_model=cost_model)
    if cache_rows + l2_rows >= group.rows:
        return 1.0 - l1
    prior = (float(cost_model.hit_prior) if cost_model is not None
             else DEFAULT_HIT_RATIO)
    return (1.0 - l1) * prior * min(1.0, l2_rows / max(cache_rows, 1))


def estimate_narrow_gain(group: PackedGroup, cache_rows: int, l2_rows: int,
                         counts: Optional[np.ndarray] = None, *,
                         ranked: bool = False, cost_model=None) -> float:
    """Cold lookup mass: the fraction of lookups served by NEITHER tier —
    exactly the traffic (and, weighted by residency, the parameter bytes)
    that the picasso_narrow candidate moves to the narrow width. With
    measured FCounter ``counts`` this is the lookup share of the rows ranked
    below ``cache_rows + l2_rows``; without stats, the complement of the
    warm-skew priors. ``ranked=True`` as in ``estimate_skew``."""
    skew = estimate_skew(group, cache_rows, counts, ranked=ranked,
                         cost_model=cost_model)
    l2 = estimate_l2_gain(group, cache_rows, l2_rows, counts, ranked=ranked,
                          cost_model=cost_model)
    return float(max(0.0, 1.0 - skew - l2))


def _score_group(group: PackedGroup, world: int, ids_per_shard: int,
                 cache_rows: int, skew: float, *,
                 l2_rows: int = 0, l2_gain: float = 0.0,
                 narrow_dim: int = 0, narrow_gain: float = 0.0,
                 ps_max_rows: int = PS_MAX_ROWS,
                 skew_min: float = SKEW_MIN,
                 narrow_min_rows: int = NARROW_MIN_ROWS,
                 narrow_cold_min: float = NARROW_COLD_MIN,
                 cost_model=None) -> GroupScore:
    """Score one group: comm-volume estimates plus the replicability /
    skew gates that pick ps for tiny groups, picasso for large skewed
    ones, hybrid for the middle — picasso_l2 where an L2 budget captures
    working set that overflows the hot tier, and picasso_narrow where a
    vparam-dominated group's cold tail can ride the narrow wire.

    With a calibrated ``cost_model`` (``repro.perf.CostModel``) the candidate
    prices come from measured per-op curves (microseconds) instead of the
    abstract element-volume constants below; the candidate set and every
    decision gate are identical either way — only the prices change."""
    n, d = float(max(ids_per_shard, 1)), float(group.dim)
    narrow_ok = (0 < narrow_dim < group.dim
                 and group.rows >= narrow_min_rows
                 and narrow_gain >= narrow_cold_min)
    if cost_model is not None:
        costs = cost_model.score_candidates(
            world=world, n=n, d=d, skew=skew,
            l2_rows=l2_rows, l2_gain=l2_gain,
            narrow_dim=narrow_dim if narrow_ok else 0,
            narrow_gain=narrow_gain)
        units = "us"
    else:
        # ps: all_gather n ids from every shard, psum [world*n, D] partials.
        ps = world * n * (d + 1.0)
        # hybrid: route ids out (n) and rows back (n*D), twice (fwd + bwd),
        # plus the fixed dispatch overhead of the Shuffle machinery.
        hybrid = 2.0 * n * (1.0 + d) + ROUTE_OVERHEAD_ELEMS
        # picasso: only misses ride the Shuffle; hit-grad handling is
        # amortized over flush_iters (psum mode) or rides a small second
        # a2a (stale mode).
        picasso = 2.0 * n * (1.0 - skew) * (1.0 + d) + ROUTE_OVERHEAD_ELEMS
        costs = {"ps": ps, "hybrid": hybrid, "picasso": picasso}
        l2_maint = 0.0
        if l2_rows > 0:
            # picasso_l2: L2 hits leave the network entirely but pay a
            # host-DMA read charged at L2_HOST_FACTOR of a network element,
            # plus the tier's exact-update maintenance in 'psum' mode — the
            # cheaper of the dense tier psum (O(H2*D)) and the gathered
            # hit-grad update (O((world-1)*n*D)); see
            # packed_embedding.apply_sparse_grads_l2.
            l2_maint = min((world - 1) * n * (1.0 + d), float(l2_rows) * d)
            costs["picasso_l2"] = (
                2.0 * n * (1.0 - skew - l2_gain) * (1.0 + d)
                + L2_HOST_FACTOR * 2.0 * n * l2_gain * (1.0 + d)
                + l2_maint
                + ROUTE_OVERHEAD_ELEMS)
        if narrow_ok:
            # picasso_narrow: the cold tail (neither tier) routes at width
            # nd instead of D — both back-a2a directions shrink — while tier
            # hits cost what they cost under picasso_l2; the learned
            # projection adds a per-step nd x D grad psum. Tier maintenance
            # matches picasso_l2 (the tiers themselves stay full-width).
            nd = float(narrow_dim)
            costs["picasso_narrow"] = (
                2.0 * n * narrow_gain * (1.0 + nd)
                + L2_HOST_FACTOR * 2.0 * n * l2_gain * (1.0 + d)
                + l2_maint
                + nd * d
                + ROUTE_OVERHEAD_ELEMS)
        units = "elems"
    if group.rows <= ps_max_rows and costs["ps"] <= costs["hybrid"]:
        choice, reason = "ps", "tiny/replicable: PS transfer under routing overhead"
    elif cache_rows > 0 and skew >= skew_min:
        if (narrow_ok and costs["picasso_narrow"]
                <= min(costs["picasso"], costs.get("picasso_l2", np.inf))):
            choice = "picasso_narrow"
            reason = (f"cold tail (~{narrow_gain:.2f} of lookups) rides the "
                      f"narrow wire at d={narrow_dim}")
        elif (l2_rows > 0 and l2_gain >= skew_min
                and costs["picasso_l2"] <= costs["picasso"]):
            choice = "picasso_l2"
            reason = (f"working set overflows L1 (hit~{skew:.2f}); host tier "
                      f"absorbs ~{l2_gain:.2f} more")
        else:
            choice, reason = "picasso", f"skew head (hit~{skew:.2f}) pays for the hot tier"
    else:
        choice, reason = "hybrid", "too big to replicate, too flat to cache"
    return GroupScore(gid=group.gid, vparam=group.vparam,
                      ids_per_shard=ids_per_shard, rows=group.rows, skew=skew,
                      costs=costs, choice=choice, reason=reason, units=units)


def _apply_overrides(plan: PicassoPlan, strategy: Dict[int, str],
                     overrides: Mapping[Union[int, str], str]) -> None:
    """User override path: keys are gids (int or digit-string) or fnmatch
    globs over the table names a group packs. Unknown strategy names and
    globs matching nothing fail fast."""
    for key, name in overrides.items():
        _validate_name(name)
        if isinstance(key, int) or (isinstance(key, str) and key.isdigit()):
            gid = int(key)
            plan.group(gid)  # KeyError on unknown gid
            strategy[gid] = name
            continue
        hit = False
        for g in plan.groups:
            if any(fnmatchcase(t.name, key) for t in g.tables):
                strategy[g.gid] = name
                hit = True
        if not hit:
            raise ValueError(
                f"strategy override {key!r} matches no table; tables: "
                f"{sorted(t.name for g in plan.groups for t in g.tables)}")


def compile_assignment(
    plan: PicassoPlan,
    stats: Optional[Dict[int, np.ndarray]] = None,
    world: Optional[int] = None,
    *,
    per_device_batch: Optional[int] = None,
    overrides: Optional[Mapping[Union[int, str], str]] = None,
    ps_max_rows: int = PS_MAX_ROWS,
    skew_min: float = SKEW_MIN,
    enable_cache: bool = True,
    cost_model=None,
) -> StrategyAssignment:
    """Score every packed group and pick its cheapest lookup strategy.

    Parameters
    ----------
    plan: the planner output; ``plan.cache_rows`` feeds the hot-tier terms,
        ``plan.l2_rows`` the host-tier (picasso_l2) candidate — groups
        without an L2 budget are never offered that candidate, so plans
        built with ``l2_bytes=0`` score exactly as before — and
        ``plan.microbatch`` sizes the default per-step id volume.
    stats: optional gid -> FCounter counts array (measured skew); groups
        without stats use the structural prior.
    world: mesh size override (defaults to ``plan.world``).
    per_device_batch: per-shard batch the id volume is scaled to (defaults
        to the plan's micro-batch, the unit the engine actually issues).
    overrides: ``{gid_or_table_glob: name}`` forced picks applied after the
        cost model (so a glob can pin e.g. ``"user_*": "picasso"``).
    ps_max_rows/skew_min: replicability and hot-tier profitability gates
        (see the module constants).
    enable_cache: pass False when the engine will run with the hot tier
        disabled (``use_cache=False``), so the model scores groups with
        skew=0 instead of crediting a tier that never participates.
    cost_model: optional calibrated ``repro.perf.CostModel``; when set, the
        candidate prices come from measured per-op curves (in us) and the
        no-stats tier estimates use its measured ``hit_prior``. ``None``
        keeps the constant model byte-for-byte.
    """
    world = int(world if world is not None else plan.world)
    batch = int(per_device_batch if per_device_batch is not None
                else max(plan.microbatch, 1))
    strategy: Dict[int, str] = {}
    scores: Dict[int, GroupScore] = {}
    for g in plan.groups:
        cache_rows = plan.cache_rows.get(g.gid, 0) if enable_cache else 0
        # the L2 tier sits behind L1, so a disabled hot tier disables it too
        l2_rows = plan.l2_rows.get(g.gid, 0) if (enable_cache and cache_rows) else 0
        # rank the (potentially multi-million-row) stats once per group,
        # shared by both tier estimators
        counts = _ranked(stats.get(g.gid) if stats else None, False)
        skew = estimate_skew(g, cache_rows, counts, ranked=True,
                             cost_model=cost_model)
        l2_gain = estimate_l2_gain(g, cache_rows, l2_rows, counts, ranked=True,
                                   cost_model=cost_model)
        # the narrow candidate is only offered where the plan budgets an
        # actually-narrowing width (plan_narrow records dim = "no narrowing")
        nd = int(plan.narrow_dim.get(g.gid, g.dim))
        narrow_gain = (estimate_narrow_gain(g, cache_rows, l2_rows, counts,
                                            ranked=True, cost_model=cost_model)
                       if 0 < nd < g.dim else 0.0)
        sc = _score_group(g, world, batch * g.ids_per_sample, cache_rows, skew,
                          l2_rows=l2_rows, l2_gain=l2_gain,
                          narrow_dim=nd if nd < g.dim else 0,
                          narrow_gain=narrow_gain,
                          ps_max_rows=ps_max_rows, skew_min=skew_min,
                          cost_model=cost_model)
        strategy[g.gid] = sc.choice
        scores[g.gid] = sc
    if overrides:
        _apply_overrides(plan, strategy, overrides)
        scores = {gid: s for gid, s in scores.items()
                  if strategy[gid] == s.choice}
    return StrategyAssignment(strategy=strategy, scores=scores)


def apply_assignment(plan: PicassoPlan,
                     assignment: Union[StrategyAssignment, Dict[int, str]]
                     ) -> PicassoPlan:
    """Record an assignment on the plan (``plan.strategy``) and return it."""
    mapping = (assignment.strategy if isinstance(assignment, StrategyAssignment)
               else dict(assignment))
    plan.strategy = {int(k): _validate_name(v) for k, v in mapping.items()}
    return plan


# spellings accepted by resolve_assignment for "compile it for me"
AUTO_NAMES = ("mixed", "auto")


def maybe_compile(plan: PicassoPlan, spec: "StrategySpec", *,
                  stats: Optional[Dict[int, np.ndarray]] = None,
                  per_device_batch: Optional[int] = None,
                  use_cache: bool = True,
                  overrides: Optional[Mapping[Union[int, str], str]] = None,
                  cost_model=None,
                  log=None) -> "StrategySpec":
    """Launcher-side 'mixed'/'auto' handling: compile the assignment once,
    record it on the plan (so every engine built from the plan — train step,
    host flush, serve — sees the same mixing), and optionally log it.
    Any other spec passes through untouched.

    ``stats`` is the optional gid -> measured FCounter counts map: the
    compile-time call passes None (structural prior); the runtime Replanner
    passes the harvested live counters so the re-mix scores *measured* skew
    (the full stats path: harvest -> revise_plan -> maybe_compile(stats=)).
    ``per_device_batch`` must match the id volume the engine actually issues
    per step: leave it None (-> ``plan.microbatch``) for training, pass the
    per-shard batch for serving (no micro pipeline there). ``use_cache``
    must match the engine flag so the model never credits a disabled tier.
    ``overrides`` forwards user ``{gid_or_glob: name}`` pins. ``cost_model``
    forwards a calibrated ``repro.perf.CostModel`` (None = constants).
    """
    if isinstance(spec, str) and spec in AUTO_NAMES:
        asg = compile_assignment(plan, stats=stats,
                                 per_device_batch=per_device_batch,
                                 overrides=overrides,
                                 enable_cache=use_cache,
                                 cost_model=cost_model)
        apply_assignment(plan, asg)
        if log is not None:
            src = "measured skew" if stats else "cost model"
            if cost_model is not None:
                src += f", calibrated curves ({cost_model.backend})"
            log(f"strategy assignment ({src}, plan rev {plan.rev}):\n"
                f"{asg.describe()}")
    return spec

StrategySpec = Union[str, Dict[int, str], "StrategyAssignment"]


def resolve_assignment(plan: PicassoPlan, spec: StrategySpec,
                       world: Optional[int] = None,
                       use_cache: bool = True) -> Dict[int, str]:
    """Normalize any user-facing strategy spelling into a full gid -> name map.

    - a registry name broadcasts to every group (the PR 1 constructor
      sugar); a ``'picasso_narrow'`` broadcast is additionally **recorded**
      on the plan, because the narrow master widths
      (``PicassoPlan.narrow_width``) gate on ``plan.strategy``;
    - ``'mixed'`` / ``'auto'`` uses ``plan.strategy`` when the plan carries
      one, else compiles a fresh assignment from the plan's own statistics
      (``plan.microbatch`` id volume — the training unit; callers issuing a
      different per-step volume, e.g. un-pipelined serving, should compile
      with the right ``per_device_batch`` and record it via
      ``maybe_compile``/``apply_assignment`` first) and **records it on the
      plan**, so every later engine built from the same plan — including the
      host-scheduled flush — sees one consistent mixing;
    - a ``StrategyAssignment`` or ``{gid: name}`` dict is taken as-is but
      must cover exactly the plan's gids (typos and gaps fail fast here,
      not deep inside a shard_map trace).

    ``world``/``use_cache`` are the engine's actual mesh size and cache flag
    (defaults: ``plan.world``, on); they feed the fallback compile's PS cost
    term and hot-tier credit.
    """
    if isinstance(spec, StrategyAssignment):
        mapping = dict(spec.strategy)
    elif isinstance(spec, dict):
        mapping = {int(k): v for k, v in spec.items()}
    elif spec in AUTO_NAMES:
        if plan.strategy:
            mapping = dict(plan.strategy)
        else:
            mapping = compile_assignment(plan, world=world,
                                         enable_cache=use_cache).strategy
            apply_assignment(plan, mapping)
    else:
        _validate_name(spec)
        mapping = {g.gid: spec for g in plan.groups}
        if spec == "picasso_narrow":
            # narrow gating (PicassoPlan.narrow_width) reads plan.strategy:
            # record the broadcast so state init, sharding specs, and the
            # migration see the narrow master widths this engine runs with.
            apply_assignment(plan, mapping)
        return mapping

    gids = {g.gid for g in plan.groups}
    missing = sorted(gids - set(mapping))
    extra = sorted(set(mapping) - gids)
    if missing or extra:
        raise ValueError(
            f"strategy assignment must cover exactly the plan's groups; "
            f"missing gids {missing}, unknown gids {extra}")
    for name in set(mapping.values()):
        _validate_name(name)
    return mapping
