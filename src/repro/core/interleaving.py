"""PICASSO Interleaving (paper §III-C).

K-Interleaving: packed lookups are issued in planner-assigned waves with
``optimization_barrier`` pinning wave boundaries, so comm-bound Shuffle ops of
wave k+1 can overlap the memory/compute-bound Gather+SegmentReduction of wave
k instead of all all_to_alls racing for ICI at once (Fig. 8c). The wave loop
lives in ``repro.engine.EmbeddingEngine._wave_lookups`` — one place, shared by
train, serve, retrieval, and the dry-run cells.

D-Interleaving: the train step processes micro-batches in a software pipeline
where the (comm-bound) ``EmbeddingEngine.forward`` of micro-batch i+1 is
issued before the (compute-bound) dense stage of micro-batch i (Fig. 8b); see
repro/train/train_step.py. Sparse updates of micro-batch i land after the
lookup of i+1 was issued — the same bounded-staleness-within-a-batch the
paper's pipeline has; n_micro=1 recovers exact semantics.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence, Tuple

import jax


def wave_barrier(values: Sequence[Any]) -> List[Any]:
    """Pin completion of a K-interleave wave (control-dependency boundary)."""
    if not values:
        return []
    flat, tree = jax.tree.flatten(tuple(values))
    flat = jax.lax.optimization_barrier(tuple(flat))
    return list(jax.tree.unflatten(tree, flat))
