"""PICASSO Interleaving (paper §III-C): wave barriers + the step pipeline.

K-Interleaving: packed lookups are issued in planner-assigned waves with
``optimization_barrier`` pinning wave boundaries, so comm-bound Shuffle ops of
wave k+1 can overlap the memory/compute-bound Gather+SegmentReduction of wave
k instead of all all_to_alls racing for ICI at once (Fig. 8c). The wave loop
lives in ``repro.engine.EmbeddingEngine._wave_lookups`` — one place, shared by
train, serve, retrieval, and the dry-run cells.

D-Interleaving: the train step processes micro-batches in a software pipeline
where the (comm-bound) ``EmbeddingEngine.forward`` of micro-batch i+1 is
issued before the (compute-bound) dense stage of micro-batch i (Fig. 8b).
This module owns the *scheduling* primitives of that pipeline:

``resolve_overlap``
    maps the ``TrainConfig.overlap`` spelling (``'off' | 'on' | 'auto'`` or a
    bool) to one static decision per step build — ``'auto'`` engages the
    pipeline exactly when there is more than one micro-batch to overlap.
``pipeline_handoff``
    the two-slot prefetch boundary: the in-flight lookup of chunk i+1 (its
    dedup + all_to_all Shuffle) is tied to chunk i's dense-stage inputs
    through one ``optimization_barrier``, so the scheduler must issue the
    collective *before* the dense stage and may await it only *after* — the
    double-buffered lookup state of the overlapped step. Barriers are
    identity functions on values: overlap-on is numerically the same program
    as overlap-off with ``pipeline_micro`` order, just with its schedule
    pinned (the parity tests assert the trajectories match).
``barrier``
    the shared pytree-flattening ``optimization_barrier`` wrapper both hooks
    (and the K-interleave ``wave_barrier``) are built on.

Sparse updates of micro-batch i land after the lookup of i+1 was issued —
the same bounded-staleness-within-a-batch the paper's pipeline has;
n_micro=1 recovers exact semantics.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Sequence, Tuple, Union

import jax


def barrier(tree: Any) -> Any:
    """Pass an arbitrary pytree through one ``optimization_barrier``.

    Identity on values; on the schedule it forces everything *feeding* the
    tree to be issued before anything that *consumes* it runs.
    """
    flat, treedef = jax.tree.flatten(tree)
    if not flat:
        return tree
    flat = jax.lax.optimization_barrier(tuple(flat))
    return jax.tree.unflatten(treedef, list(flat))


def wave_barrier(values: Sequence[Any]) -> List[Any]:
    """Pin completion of a K-interleave wave (control-dependency boundary)."""
    if not values:
        return []
    return list(barrier(tuple(values)))


def pipeline_handoff(current: Any, prefetch: Any) -> Tuple[Any, Any]:
    """Two-slot D-Interleaving boundary (Fig. 8b).

    ``current`` is chunk i's dense-stage input (the pooled rows + lookup
    ctx); ``prefetch`` is the just-issued forward of chunk i+1 whose Shuffle
    should be in flight while the dense stage of i runs. Tying both through
    one barrier makes the i+1 collective issue *before* the dense compute
    that reads ``current`` and lets it complete *behind* it.

    Returns the same (current, prefetch) values.
    """
    return barrier((current, prefetch))


def resolve_overlap(spec: Union[str, bool, None], n_micro: int) -> bool:
    """Map a ``TrainConfig.overlap`` spelling to a static bool, once.

    ``'auto'``/``None`` engage the software pipeline exactly when the step
    has more than one micro-batch (a single chunk has nothing to double-
    buffer); ``'on'``/``'off'``/bools force it. Raises on anything else so
    config typos fail at step construction, not silently at dispatch.
    """
    if spec is None or spec == "auto":
        return n_micro > 1
    if isinstance(spec, bool):
        return spec
    if spec == "on":
        return True
    if spec == "off":
        return False
    raise ValueError(
        f"overlap must be 'auto', 'on', 'off' or a bool; got {spec!r}")
