"""MoE dispatch/combine vs a dense mixture reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers.moe import moe_dispatch, moe_combine, moe_ffn

RNG = np.random.default_rng(0)


def _dense_moe_ref(x, router_w, w1, w2, w3, top_k):
    """Every expert computes every token; combine by renormalized top-k gate."""
    probs = jax.nn.softmax((x @ router_w).astype(jnp.float32), -1)
    gate, expert = jax.lax.top_k(probs, top_k)
    gate = gate / gate.sum(-1, keepdims=True)
    h = jnp.einsum("nd,edf->enf", x, w1)
    g = jnp.einsum("nd,edf->enf", x, w3)
    y_all = jnp.einsum("enf,efd->end", jax.nn.silu(g) * h, w2)  # [E, N, D]
    out = jnp.zeros_like(x)
    for k in range(top_k):
        sel = jnp.take_along_axis(y_all.transpose(1, 0, 2),
                                  expert[:, k][:, None, None], axis=1)[:, 0]
        out = out + gate[:, k][:, None].astype(x.dtype) * sel
    return out


@pytest.mark.parametrize("n,d,f,e,k", [(32, 16, 32, 4, 2), (64, 8, 16, 8, 2),
                                       (16, 8, 8, 4, 1)])
def test_moe_matches_dense_reference(n, d, f, e, k):
    x = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    rw = jnp.asarray(RNG.normal(size=(d, e)).astype(np.float32))
    w1 = jnp.asarray(RNG.normal(size=(e, d, f)).astype(np.float32) / np.sqrt(d))
    w3 = jnp.asarray(RNG.normal(size=(e, d, f)).astype(np.float32) / np.sqrt(d))
    w2 = jnp.asarray(RNG.normal(size=(e, f, d)).astype(np.float32) / np.sqrt(f))
    got = moe_ffn(x, rw, w1, w2, w3, k, capacity_factor=float(e) / k)  # no drops
    ref = _dense_moe_ref(x, rw, w1, w2, w3, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_capacity_drops_bounded():
    """With cf=1.0, drops happen but outputs stay finite and bounded."""
    n, d, f, e, k = 64, 8, 16, 4, 2
    x = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    rw = jnp.zeros((d, e), jnp.float32)  # uniform router: heavy collisions
    w1 = jnp.asarray(RNG.normal(size=(e, d, f)).astype(np.float32) / np.sqrt(d))
    w3 = jnp.asarray(RNG.normal(size=(e, d, f)).astype(np.float32) / np.sqrt(d))
    w2 = jnp.asarray(RNG.normal(size=(e, f, d)).astype(np.float32) / np.sqrt(f))
    y = moe_ffn(x, rw, w1, w2, w3, k, capacity_factor=1.0)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_grouped_dispatch_matches_ungrouped():
    """groups>1 (shard-local dispatch) == groups=1 when nothing drops."""
    n, d, f, e, k = 64, 8, 16, 4, 2
    x = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    rw = jnp.asarray(RNG.normal(size=(d, e)).astype(np.float32))
    w1 = jnp.asarray(RNG.normal(size=(e, d, f)).astype(np.float32) / np.sqrt(d))
    w3 = jnp.asarray(RNG.normal(size=(e, d, f)).astype(np.float32) / np.sqrt(d))
    w2 = jnp.asarray(RNG.normal(size=(e, f, d)).astype(np.float32) / np.sqrt(f))
    cf = float(e) / k
    y1 = moe_ffn(x, rw, w1, w2, w3, k, capacity_factor=cf, groups=1)
    y4 = moe_ffn(x, rw, w1, w2, w3, k, capacity_factor=cf, groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), atol=1e-5)


def test_dispatch_inverse():
    """dispatch followed by identity-expert combine reproduces gate-weighted x."""
    n, d, e, k = 32, 8, 4, 2
    x = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    logits = jnp.asarray(RNG.normal(size=(n, e)).astype(np.float32))
    xe, info, gate, cap = moe_dispatch(x, logits, e, k, capacity_factor=float(e) / k)
    y = moe_combine(xe, info, gate, n, k)  # identity experts
    # sum_k gate_k * x == x (gates renormalized to 1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=1e-5)
