"""Adaptive replanning runtime (repro.runtime + plan revision + migration).

Pins the three contracts of the replanning loop:

1. a recompile that lands on an identical plan is a *no-op*: training with
   the Replanner in the loop is bitwise-equal to training without it;
2. a forced tier-resize migration preserves every master row and optimizer
   slot exactly while re-ranking tier residency by measured frequency;
3. checkpoint round-trip of the plan revision: resume after a replan
   rebuilds the *current* plan (rev, budgets, strategy), not the seed one,
   and restores the state bitwise under it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FeatureField, InteractionSpec, WDLConfig
from repro.core.assign import apply_assignment, resolve_assignment
from repro.core.packing import make_plan, plan_cache, plan_l2, revise_plan
from repro.data.synthetic import batch_stream
from repro.dist.sharding import batch_specs, to_named
from repro.embedding.state import migrate_state, tier_gates
from repro.engine.engine import export_stats
from repro.models.wdl import WDLModel
from repro.runtime import (Replanner, apply_plan_meta, plan_delta, plan_meta)
from repro.train.checkpoint import (load_checkpoint_meta, restore_checkpoint,
                                    save_checkpoint)
from repro.train.train_step import TrainConfig, init_state, make_train_step

GB = 64
PLAN_KW = dict(hot_bytes=1 << 14, l2_bytes=1 << 16, flush_iters=5,
               warmup_iters=2)


def _put(mesh, axes, batch):
    return jax.device_put(batch, to_named(mesh, batch_specs(batch, axes)))


def _setup(mesh1, axes, strategy="picasso_l2", **plan_kw):
    cfg = get_config("deepfm", smoke=True)
    kw = dict(PLAN_KW)
    kw.update(plan_kw)
    plan = make_plan(cfg, world=1, per_device_batch=GB, **kw)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1, axes=axes)
    step, _ = make_train_step(model, plan, mesh1, axes, GB,
                              TrainConfig(strategy=strategy))
    return cfg, plan, model, state, step


def _train(state, step, mesh1, axes, cfg, n, seed=3, hook=None):
    stream = batch_stream(cfg, GB, seed=seed)
    for i in range(n):
        state, m = step(state, _put(mesh1, axes, next(stream)))
        if hook is not None:
            state, step = hook(i + 1, state, step, m)
    return state


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------- revision


def test_make_plan_records_budgets_and_rev():
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, **PLAN_KW)
    assert plan.rev == 0
    assert plan.hot_bytes == PLAN_KW["hot_bytes"]
    assert plan.l2_bytes == PLAN_KW["l2_bytes"]
    # cache disabled -> no envelope recorded (a replan must not resurrect it)
    off = make_plan(cfg, world=1, per_device_batch=GB, enable_cache=False,
                    hot_bytes=1 << 20)
    assert off.hot_bytes == 0 and all(v == 0 for v in off.cache_rows.values())


def test_revise_plan_bumps_rev_and_keeps_structure():
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, **PLAN_KW)
    new = revise_plan(plan)  # same envelopes, no stats -> same split
    assert new.rev == 1
    assert new.cache_rows == plan.cache_rows and new.l2_rows == plan.l2_rows
    assert new.capacity == plan.capacity
    assert new.microbatch == plan.microbatch
    assert [g.gid for g in new.groups] == [g.gid for g in plan.groups]
    assert not plan_delta(plan, new)
    # explicit envelope retune -> tier resize -> a real delta
    shrunk = revise_plan(plan, l2_bytes=1 << 15)
    assert shrunk.rev == 1 and plan_delta(plan, shrunk)


def test_stats_driven_budget_follows_measured_mass():
    """Two same-vparam groups: measured traffic skewed onto one of them must
    pull the tier budget toward it (the re-budget rule the Replanner runs)."""
    fields = [FeatureField("a", 4096, 8, max_len=1, pooling="sum"),
              FeatureField("b", 4096, 16, max_len=1, pooling="sum")]
    cfg = WDLConfig(name="t", fields=tuple(fields), n_dense=0,
                    interactions=(InteractionSpec("fm"),), mlp_dims=(8,))
    plan = make_plan(cfg, world=1, per_device_batch=16, hot_bytes=1 << 13)
    gids = sorted(g.gid for g in plan.groups)
    assert len(gids) == 2
    hot, cold = gids[0], gids[1]
    stats = {hot: np.full(plan.group(hot).rows, 50, np.int32),
             cold: np.zeros(plan.group(cold).rows, np.int32)}
    rows = plan_cache(plan.groups, 1 << 13, plan.world, stats=stats)
    base = plan_cache(plan.groups, 1 << 13, plan.world)
    assert rows[hot] >= base[hot]   # measured mass pulls budget in
    assert rows[cold] <= base[cold]
    # all-cold stats carry no signal -> identical to the structural prior
    cold_stats = {g.gid: np.zeros(g.rows, np.int32) for g in plan.groups}
    assert plan_cache(plan.groups, 1 << 13, plan.world,
                      stats=cold_stats) == base
    assert plan_l2(plan.groups, 1 << 15, rows,
                   stats=cold_stats) == plan_l2(plan.groups, 1 << 15, rows)


# ------------------------------------------------- no-op replan == bitwise


def test_replan_noop_is_bitwise_equal(mesh1, axes):
    """Forced-identical recompiles (budgets frozen, strategy pinned) through
    the full Replanner path leave training bitwise-identical to a run that
    never replans — migration is a no-op on a no-change plan."""
    cfg, plan_a, _, state_a, step_a = _setup(mesh1, axes)
    state_a = _train(state_a, step_a, mesh1, axes, cfg, 12)

    cfg, plan_b, model_b, state_b, step_b = _setup(mesh1, axes)
    rp = Replanner(plan_b, mesh1, axes, strategy="picasso_l2",
                   rebudget=False)  # freeze budgets; broadcast pin strategy

    def hook(i, state, step, m):
        rp.observe(m)
        if i % 4 == 0:
            out = rp.maybe_replan(state, step=i)
            assert out is None, plan_delta(plan_b, rp._recompile(
                export_stats(plan_b, state["emb"])))
        return state, step

    state_b = _train(state_b, step_b, mesh1, axes, cfg, 12, hook=hook)
    assert len(rp.events) == 3 and not any(e.migrated for e in rp.events)
    # the metric harvest saw live counters (tier warm after the first flush)
    assert rp.events[-1].window["cache_hits"] > 0
    _leaves_equal(state_a, state_b)


def test_migrate_state_passthrough_identity(mesh1, axes):
    """migrate_state across a no-change revision returns the very same
    arrays (no copy, no device round-trip)."""
    cfg, plan, _, state, step = _setup(mesh1, axes)
    state = _train(state, step, mesh1, axes, cfg, 6)
    new = revise_plan(plan)
    new.cache_rows, new.l2_rows = dict(plan.cache_rows), dict(plan.l2_rows)
    apply_assignment(plan, resolve_assignment(plan, "picasso_l2"))
    apply_assignment(new, resolve_assignment(new, "picasso_l2"))
    out = migrate_state(plan, new, state)
    for k, st in state["emb"].items():
        assert out["emb"][k] is st


# -------------------------------------------------- forced-resize migration


def test_forced_resize_migration_preserves_master_exactly(mesh1, axes):
    """Shrink L1 + L2 after real training steps: every master row and
    adagrad slot must survive exactly (via the write-back of the
    authoritative 'psum' tiers), the FCounter must be untouched, and the new
    tiers must hold exactly the measured top-H1 / next-H2 rows."""
    cfg, plan, _, state, step = _setup(mesh1, axes)
    apply_assignment(plan, resolve_assignment(plan, "picasso_l2"))
    state = _train(state, step, mesh1, axes, cfg, 9)

    new = revise_plan(plan, hot_bytes=1 << 10, l2_bytes=1 << 15)
    apply_assignment(new, resolve_assignment(new, "picasso_l2"))
    assert plan_delta(plan, new)

    gid = plan.groups[0].gid
    g = plan.group(gid)
    st = state["emb"][str(gid)]
    # expected master = old master overwritten with the authoritative tiers
    w_exp = np.array(jax.device_get(st.w))
    acc_exp = np.array(jax.device_get(st.acc))
    for tier in (st.cache, st.l2):
        keys = np.asarray(jax.device_get(tier.keys))
        mine = keys < g.rows
        w_exp[keys[mine]] = np.asarray(jax.device_get(tier.rows))[mine]
        acc_exp[keys[mine]] = np.asarray(jax.device_get(tier.acc))[mine]
    counts = np.asarray(jax.device_get(st.counts))

    out = migrate_state(plan, new, state)
    mg = out["emb"][str(gid)]
    np.testing.assert_array_equal(np.asarray(mg.w), w_exp)
    np.testing.assert_array_equal(np.asarray(mg.acc), acc_exp)
    np.testing.assert_array_equal(np.asarray(mg.counts), counts)

    # tier residency re-ranked by measured frequency, disjoint split
    h1, h2 = new.cache_rows[gid], new.l2_rows[gid]
    assert (h1, h2) != (plan.cache_rows[gid], plan.l2_rows[gid])
    order = np.argsort(-counts.astype(np.int64), kind="stable")
    ranked = order[counts[order] > 0][:h1 + h2]
    exp1 = np.sort(ranked[:h1])
    exp2 = np.sort(ranked[h1:])
    k1 = np.asarray(mg.cache.keys)
    k2 = np.asarray(mg.l2.keys)
    np.testing.assert_array_equal(k1[k1 < g.rows], exp1)
    np.testing.assert_array_equal(k2[k2 < g.rows], exp2)
    assert not set(k1[k1 < g.rows]) & set(k2[k2 < g.rows])
    # tier payloads loaded from the just-synced master (rows + adagrad)
    np.testing.assert_array_equal(np.asarray(mg.cache.rows)[k1 < g.rows],
                                  w_exp[k1[k1 < g.rows]])
    np.testing.assert_array_equal(np.asarray(mg.l2.acc)[k2 < g.rows],
                                  acc_exp[k2[k2 < g.rows]])


def test_migration_to_uncached_strategy_writes_back(mesh1, axes):
    """Re-assigning a cached group to 'hybrid' must not lose the tier's
    authoritative updates: they land in the master, tiers come back empty."""
    cfg, plan, _, state, step = _setup(mesh1, axes)
    apply_assignment(plan, resolve_assignment(plan, "picasso_l2"))
    state = _train(state, step, mesh1, axes, cfg, 7)
    gid = plan.groups[0].gid
    g = plan.group(gid)
    st = state["emb"][str(gid)]
    keys = np.asarray(jax.device_get(st.cache.keys))
    live = keys[keys < g.rows]
    assert live.size  # the hot tier actually held rows
    tier_rows = np.asarray(jax.device_get(st.cache.rows))[keys < g.rows]

    new = revise_plan(plan)
    new.cache_rows, new.l2_rows = dict(plan.cache_rows), dict(plan.l2_rows)
    apply_assignment(new, {g2.gid: "hybrid" for g2 in plan.groups})
    assert tier_gates(new, gid) == (False, False)
    out = migrate_state(plan, new, state)
    mg = out["emb"][str(gid)]
    np.testing.assert_array_equal(np.asarray(mg.w)[live], tier_rows)
    assert (np.asarray(mg.cache.keys) == g.rows).all()   # cleared
    assert (np.asarray(mg.l2.keys) == g.rows).all()


def test_replanner_live_migration_trains_on(mesh1, axes):
    """Full loop: Replanner harvest -> recompile (L2 envelope halved) ->
    migrate -> rebuilt step keeps training with per-tier hits flowing."""
    cfg, plan, model, state, step = _setup(mesh1, axes)
    rp = Replanner(plan, mesh1, axes, strategy="picasso_l2",
                   l2_bytes=1 << 15)
    state = _train(state, step, mesh1, axes, cfg, 8)
    out = rp.maybe_replan(state, step=8)
    assert out is not None
    plan2, state2 = out
    assert plan2.rev == 1 and rp.events[-1].migrated
    step2, _ = make_train_step(model, plan2, mesh1, axes, GB,
                               TrainConfig(strategy="mixed"))
    state2 = _train(state2, step2, mesh1, axes, cfg, 4, seed=11)
    assert np.isfinite(float(jax.device_get(state2["emb"]["0"].w).sum()))


# ------------------------------------------------- checkpoint plan-rev meta


def test_checkpoint_roundtrip_restores_current_plan(mesh1, axes, tmp_path):
    """Resume after a replan must rebuild the *replanned* plan (rev 1 tier
    shapes + strategy) from the checkpoint meta, restore bitwise, and step."""
    cfg, plan, model, state, step = _setup(mesh1, axes)
    rp = Replanner(plan, mesh1, axes, strategy="picasso_l2",
                   l2_bytes=1 << 15)
    state = _train(state, step, mesh1, axes, cfg, 8)
    plan2, state2 = rp.maybe_replan(state, step=8)
    save_checkpoint(str(tmp_path), 8, state2, meta=plan_meta(plan2))

    # ---- simulated fresh process: recompile the structural seed plan ------
    meta = load_checkpoint_meta(str(tmp_path))
    assert meta is not None and meta["plan_rev"] == 1
    seed_plan = make_plan(cfg, world=1, per_device_batch=GB, **PLAN_KW)
    assert seed_plan.l2_rows != plan2.l2_rows  # seed would mis-shape tiers
    planR = apply_plan_meta(seed_plan, meta)
    assert planR.rev == 1
    assert planR.cache_rows == plan2.cache_rows
    assert planR.l2_rows == plan2.l2_rows
    assert planR.strategy == plan2.strategy

    modelR = WDLModel(cfg, planR)
    template = init_state(modelR, planR, jax.random.PRNGKey(0), mesh=mesh1,
                          axes=axes)
    restored, s = restore_checkpoint(str(tmp_path), template)
    assert s == 8
    _leaves_equal(jax.device_get(state2), restored)
    # the harvested FCounter rides in the state: a resumed replan sees the
    # measured skew, not a cold counter
    assert np.asarray(restored["emb"]["0"].counts).sum() > 0
    stepR, _ = make_train_step(modelR, planR, mesh1, axes, GB,
                               TrainConfig(strategy="mixed"))
    _train(restored, stepR, mesh1, axes, cfg, 2, seed=12)


def test_checkpoint_meta_absent_is_none(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"x": jnp.zeros((2,))})
    assert load_checkpoint_meta(str(tmp_path)) is None
    assert load_checkpoint_meta(str(tmp_path / "nope")) is None


def test_apply_plan_meta_rejects_mismatched_groups():
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, **PLAN_KW)
    meta = plan_meta(plan)
    meta["cache_rows"] = {"0": 8, "7": 8}  # gid 7 does not exist
    with pytest.raises(ValueError, match="config/mesh changed"):
        apply_plan_meta(plan, meta)
