# NOTE: no XLA_FLAGS here on purpose — tests run on the single real CPU
# device; multi-device behaviour is covered by subprocess tests
# (test_distributed.py) which set --xla_force_host_platform_device_count
# in the child process only.
import pytest

from repro.launch.mesh import make_test_mesh


@pytest.fixture(scope="session")
def mesh1():
    """1x1 mesh: exercises the full shard_map/collective code path on one
    device (all_to_all over a size-1 axis is identity)."""
    return make_test_mesh(1, 1)


AXES = ("data", "model")


@pytest.fixture(scope="session")
def axes():
    return AXES
