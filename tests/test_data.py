"""Data layer: zipf skew (paper Fig. 3), pipeline stragglers, graph sampler."""
import time

import numpy as np
import pytest

from repro.configs import get_config
from repro.data.graph import molecule_batch, pad_subgraph, sample_neighbors, synthetic_graph
from repro.data.pipeline import Prefetcher
from repro.data.synthetic import make_batch, zipf_ids


def test_zipf_head_mass():
    """Paper §II-B: top 20% of ids must cover the majority of queries."""
    rng = np.random.default_rng(0)
    ids = zipf_ids(rng, 10_000, 200_000, a=1.2)
    counts = np.bincount(ids, minlength=10_000)
    top20 = np.sort(counts)[::-1][:2000].sum() / counts.sum()
    assert top20 > 0.5


def test_make_batch_shapes():
    cfg = get_config("sasrec", smoke=True)
    b = make_batch(cfg, 16)
    for f in cfg.fields:
        assert b["fields"][f.name]["ids"].shape == (16, f.max_len)
        w = b["fields"][f.name]["weights"]
        assert w.shape == (16, f.max_len)
        if f.max_len > 1 and f.name != "pos":
            assert (w.sum(1) >= 1).all()  # at least one valid position
        assert (b["fields"][f.name]["ids"] < f.vocab).all()
    assert b["labels"].shape == (16,)


def test_prefetcher_backup_on_straggle():
    def gen():
        yield 1
        yield 2
        time.sleep(10)  # straggler
        yield 3

    pf = Prefetcher(gen(), depth=2, timeout_s=0.3)
    assert next(pf) == 1
    assert next(pf) == 2
    got = next(pf)  # generator is stuck -> backup batch served
    assert got == 2
    assert pf.stats["backup_served"] == 1
    pf.close()


def test_prefetcher_close_with_full_queue_reaps_worker():
    """A worker blocked on a full queue must observe close() and exit — the
    old blocking q.put() would hang the thread forever after close()."""
    def gen():
        i = 0
        while True:  # endless producer: guaranteed to fill the queue
            yield i
            i += 1

    pf = Prefetcher(gen(), depth=2, timeout_s=0.5)
    assert next(pf) == 0
    deadline = time.monotonic() + 2.0  # let the worker block in put()
    while pf.q.full() is False and time.monotonic() < deadline:
        time.sleep(0.01)
    pf.close()
    assert not pf._thread.is_alive()
    pf.close()  # idempotent


def test_prefetcher_close_unblocks_immediately_when_idle():
    def gen():
        yield from range(3)

    pf = Prefetcher(gen(), depth=8, timeout_s=0.5)
    assert [next(pf) for _ in range(3)] == [0, 1, 2]
    pf.close()
    assert not pf._thread.is_alive()


def test_neighbor_sampler_valid():
    g = synthetic_graph(500, 4000, d_feat=8, seed=1)
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, 500, 32)
    sub = sample_neighbors(g, seeds, (5, 3), rng)
    n = len(sub["node_ids"])
    assert sub["src"].max() < n and sub["dst"].max() < n
    # sampled edges correspond to real graph edges
    gid = sub["node_ids"]
    real = set(zip(g["src"].tolist(), g["dst"].tolist()))
    for s, d in zip(sub["src"][:50], sub["dst"][:50]):
        assert (gid[d], gid[s]) in real  # message dst<-src == edge dst->nbr
    padded = pad_subgraph(sub, g, max_nodes=n + 16, max_edges=len(sub["src"]) + 8)
    assert padded["nodes"].shape[0] == n + 16
    assert padded["edge_w"].sum() == len(sub["src"])


def test_molecule_batch_offsets():
    b = molecule_batch(4, 6, 10)
    assert b["src"].max() < 24 and b["graph_ids"].shape == (24,)
    # edges stay within their own molecule
    assert (b["src"] // 6 == b["dst"] // 6).all()
