"""Deterministic miniature stand-in for hypothesis when it isn't installed.

The container this repo targets has no ``hypothesis`` wheel (and nothing may
be pip-installed), so property tests import through::

    try:
        import hypothesis.strategies as st
        from hypothesis import given, settings
    except ImportError:
        from hypothesis_fallback import given, settings, st

The fallback draws ``max_examples`` pseudo-random samples from a fixed seed —
no shrinking, no database, but the same property gets exercised on every run
with reproducible inputs. Only the strategy combinators the test-suite uses
are implemented (integers / lists / tuples / sampled_from).
"""
from __future__ import annotations

import functools
from types import SimpleNamespace
from typing import Any, Callable, List

import numpy as np

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, sample: Callable[[np.random.Generator], Any]):
        self.sample = sample


def _integers(lo: int, hi: int) -> _Strategy:
    return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))


def _sampled_from(seq) -> _Strategy:
    items = list(seq)
    return _Strategy(lambda rng: items[int(rng.integers(len(items)))])


def _tuples(*strats: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(s.sample(rng) for s in strats))


def _lists(strat: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    return _Strategy(lambda rng: [strat.sample(rng)
                                  for _ in range(int(rng.integers(min_size,
                                                                  max_size + 1)))])


st = SimpleNamespace(integers=_integers, sampled_from=_sampled_from,
                     tuples=_tuples, lists=_lists)


def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
            rng = np.random.default_rng(0)
            for _ in range(n):
                fn(*(s.sample(rng) for s in strats))
        # pytest resolves fixtures through __wrapped__'s signature; the
        # original fn's params are strategy draws, not fixtures — hide it.
        del wrapper.__wrapped__
        return wrapper
    return deco
