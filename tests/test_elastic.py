"""Elastic resharding: plan recut, exact state permutation, elastic
checkpoint restore, publish/pickup handoff — plus the property harness
(random chains of plan revisions: tier resize, narrow<->wide, strategy
re-mix, world resize) that proves every migration exact.

Everything here is host-side (no mesh): ``init_state`` without a mesh builds
plain arrays, migrations run in numpy, and the multi-device placement is
covered by the subprocess parity test in test_distributed.py.
"""
import jax
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:
    from hypothesis_fallback import given, settings, st

from repro.configs.base import FeatureField, InteractionSpec, WDLConfig
from repro.core.assign import apply_assignment
from repro.core.packing import make_plan, reshard_plan, revise_plan
from repro.embedding.state import migrate_state, reshard_state
from repro.models.wdl import WDLModel
from repro.runtime import (apply_plan_meta, load_published, plan_meta,
                           poll_published, publish_state, restore_elastic)
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.train_step import init_state

PDB = 8  # per-device batch used for capacity planning in every recut
PLAN_KW = dict(hot_bytes=1 << 12, l2_bytes=1 << 13, narrow_dim=4,
               flush_iters=5, warmup_iters=2)


def _cfg():
    """Three packed groups with three distinct dims (4 / 8 / 16)."""
    fields = (FeatureField("a", 1001, 8, max_len=2),
              FeatureField("b", 515, 16, max_len=1),
              FeatureField("c", 259, 4, max_len=3))
    return WDLConfig(name="elastic3", fields=fields, n_dense=0,
                     interactions=(InteractionSpec("fm"),), mlp_dims=(16, 8))


def _plan(world, **kw):
    merged = dict(PLAN_KW)
    merged.update(kw)
    return make_plan(_cfg(), world=world, per_device_batch=PDB, **merged)


def _logical(g):
    """Row count of the packed vocabs (the world-independent part)."""
    return max(g.table_offsets[t.name] + t.vocab for t in g.tables)


def _seed_counts(plan, state, seed=123):
    """Surgically seed the FCounter on logical rows (padding stays zero)."""
    rng = np.random.default_rng(seed)
    emb = dict(state["emb"])
    for g in plan.groups:
        st_g = emb[str(g.gid)]
        counts = np.zeros(g.rows, np.int32)
        n = _logical(g)
        counts[:n] = rng.integers(0, 50, size=n).astype(np.int32)
        emb[str(g.gid)] = st_g._replace(counts=counts)
    return {**state, "emb": emb}


def _host_state(plan, seed=0):
    model = WDLModel(_cfg(), plan)
    return _seed_counts(plan, init_state(model, plan, jax.random.PRNGKey(seed)))


# ----------------------------------------------------------- reshard_plan


def test_reshard_plan_recuts_rows_and_carries_revisables():
    plan = _plan(4)
    apply_assignment(plan, {0: "picasso", 1: "picasso_l2",
                            2: "picasso_narrow"})
    new = reshard_plan(plan, 3, PDB, mesh_shape=(3, 1))
    assert new.world == 3 and new.mesh_shape == (3, 1)
    for g in new.groups:
        logical = _logical(g)
        assert g.rows % 3 == 0 and 0 <= g.rows - logical < 3
        assert g.dim == plan.group(g.gid).dim
    # every revisable decision carries verbatim — the reshard is the SAME
    # plan revision, permuted
    assert new.rev == plan.rev
    assert new.cache_rows == plan.cache_rows
    assert new.l2_rows == plan.l2_rows
    assert new.strategy == plan.strategy
    assert new.narrow_dim == plan.narrow_dim
    assert new.hot_bytes == plan.hot_bytes
    # capacities re-planned for the new peer count
    assert set(new.capacity) == set(plan.capacity)
    # roundtrip lands on the original row cuts
    back = reshard_plan(new, 4, PDB)
    assert {g.gid: g.rows for g in back.groups} == \
        {g.gid: g.rows for g in plan.groups}


def test_reshard_plan_validates():
    plan = _plan(2)
    with pytest.raises(ValueError, match="positive"):
        reshard_plan(plan, 0, PDB)
    with pytest.raises(ValueError, match="devices"):
        reshard_plan(plan, 4, PDB, mesh_shape=(3, 1))
    with pytest.raises(ValueError, match="devices"):
        make_plan(_cfg(), world=2, per_device_batch=PDB, mesh_shape=(4, 1))
    assert _plan(2, mesh_shape=(2, 1)).mesh_shape == (2, 1)


def test_plan_meta_records_world():
    plan = _plan(2, mesh_shape=(2, 1))
    meta = plan_meta(plan)
    assert meta["world"] == 2 and meta["mesh_shape"] == [2, 1]
    # apply_plan_meta keeps the TARGET plan's structural world
    revived = apply_plan_meta(_plan(4), meta)
    assert revived.world == 4


# ---------------------------------------------------------- reshard_state


def test_reshard_state_roundtrip_bitwise():
    """4 -> 3 -> 4 devices: every logical row, optimizer slot, counter, and
    tier resident survives bitwise; sentinel keys remap both directions."""
    plan4 = _plan(4)
    apply_assignment(plan4, {g.gid: "picasso_l2" for g in plan4.groups})
    state = _host_state(plan4)
    # populate the tiers from the seeded counts (tier resize -> re-rank)
    bud = revise_plan(plan4, hot_bytes=1 << 11, l2_bytes=1 << 12)
    apply_assignment(bud, {g.gid: "picasso_l2" for g in bud.groups})
    state = migrate_state(plan4, bud, state)
    plan4 = bud

    plan3 = reshard_plan(plan4, 3, PDB)
    s3 = reshard_state(plan3, state)
    plan4b = reshard_plan(plan3, 4, PDB)
    s4 = reshard_state(plan4b, s3)

    for g in plan4.groups:
        a, b = state["emb"][str(g.gid)], s4["emb"][str(g.gid)]
        n = _logical(g)
        np.testing.assert_array_equal(np.asarray(a.w)[:n], np.asarray(b.w)[:n])
        np.testing.assert_array_equal(np.asarray(a.acc)[:n],
                                      np.asarray(b.acc)[:n])
        np.testing.assert_array_equal(np.asarray(a.counts)[:n],
                                      np.asarray(b.counts)[:n])
        for ta, tb in ((a.cache, b.cache), (a.l2, b.l2)):
            if ta is None:
                assert tb is None
                continue
            np.testing.assert_array_equal(np.asarray(ta.keys),
                                          np.asarray(tb.keys))
            np.testing.assert_array_equal(np.asarray(ta.rows),
                                          np.asarray(tb.rows))
            np.testing.assert_array_equal(np.asarray(ta.acc),
                                          np.asarray(tb.acc))
        # the intermediate world actually remapped sentinels (no stale
        # old-world sentinel survives as a valid-looking key)
        g3 = plan3.group(g.gid)
        k3 = np.asarray(s3["emb"][str(g.gid)].cache.keys)
        assert ((k3 == g3.rows) | (k3 < _logical(g3))).all()


def test_reshard_state_refuses_to_drop_live_rows():
    plan2 = _plan(2)
    state = _host_state(plan2)
    gid = max(g.gid for g in plan2.groups)
    g = plan2.group(gid)
    st_g = state["emb"][str(gid)]
    counts = np.asarray(st_g.counts).copy()
    counts[-1] = 7  # pretend the padding row carries live mass
    state["emb"][str(gid)] = st_g._replace(counts=counts)
    target = reshard_plan(plan2, 3, PDB)
    if target.group(gid).rows < g.rows:
        with pytest.raises(ValueError, match="nonzero FCounter"):
            reshard_state(target, state)
    else:  # direction grew this group: shrink instead
        target = reshard_plan(plan2, 1, PDB)
        assert target.group(gid).rows < g.rows
        with pytest.raises(ValueError, match="nonzero FCounter"):
            reshard_state(target, state)


def test_migrate_state_rejects_dim_change():
    plan = _plan(2)
    other = _plan(2)
    object.__setattr__(other.groups[0], "dim", other.groups[0].dim * 2)
    with pytest.raises(ValueError, match="packed dim changed"):
        migrate_state(plan, other, _host_state(plan))


def test_engine_rejects_stale_world():
    from repro.engine.engine import EmbeddingEngine
    plan = _plan(2)
    with pytest.raises(ValueError, match="world"):
        EmbeddingEngine(plan, ("data", "model"), 1)


# ------------------------------------------------ property harness (chains)


_OPS = st.lists(
    st.tuples(st.sampled_from(["rebudget", "strategy", "world"]),
              st.integers(0, 5)),
    min_size=1, max_size=4)
_WORLDS = (1, 2, 3, 4, 8)
_BUDGETS = ((1 << 11, 1 << 12), (1 << 12, 1 << 13), (1 << 13, 0),
            (1 << 10, 1 << 14), (0, 0), (1 << 12, 0))
_MIXES = (
    {0: "picasso", 1: "picasso", 2: "picasso"},
    {0: "picasso_l2", 1: "picasso_l2", 2: "picasso_l2"},
    {0: "picasso", 1: "picasso_l2", 2: "picasso_narrow"},
    {0: "picasso_narrow", 1: "picasso", 2: "picasso_l2"},
    {0: "picasso_l2", 1: "picasso_narrow", 2: "picasso"},
    {0: "picasso_narrow", 1: "picasso_narrow", 2: "picasso_narrow"},
)


def _check_invariants(plan, state):
    for g in plan.groups:
        st_g = state["emb"][str(g.gid)]
        nd = plan.narrow_width(g.gid)
        assert np.shape(st_g.w) == (g.rows, nd)
        assert np.shape(st_g.acc) == (g.rows, 1)
        assert np.shape(st_g.counts) == (g.rows,)
        h1 = plan.cache_rows.get(g.gid, 0)
        h2 = plan.l2_rows.get(g.gid, 0)
        assert np.shape(st_g.cache.keys) == (h1,)
        assert (st_g.l2 is None) == (h2 == 0)
        if h2:  # L2 sits strictly behind L1 (plan invariant)
            assert h1 > 0
            k1 = np.asarray(st_g.cache.keys)
            k2 = np.asarray(st_g.l2.keys)
            live1 = set(k1[k1 < g.rows].tolist())
            live2 = set(k2[k2 < g.rows].tolist())
            assert not live1 & live2, "L1/L2 key sets must stay disjoint"
        assert (st_g.proj is None) == (nd == g.dim)


@settings(max_examples=6, deadline=None)
@given(_OPS)
def test_property_random_revision_chains_preserve_state(ops):
    """Any chain of {tier resize, strategy re-mix, world resize} preserves
    the FCounter and adagrad slots bitwise on every logical row, preserves
    masters bitwise for groups narrow never touched, and never violates the
    plan invariants (shape agreement, L1/L2 disjoint, narrow gating)."""
    plan = _plan(4)
    apply_assignment(plan, dict(_MIXES[0]))  # start wide: no narrow masters
    state = _host_state(plan)
    ref = {g.gid: (np.asarray(state["emb"][str(g.gid)].w).copy(),
                   np.asarray(state["emb"][str(g.gid)].acc).copy(),
                   np.asarray(state["emb"][str(g.gid)].counts).copy())
           for g in plan.groups}
    narrow_touched = {g.gid: False for g in plan.groups}

    for kind, pick in ops:
        if kind == "rebudget":
            hot, l2b = _BUDGETS[pick % len(_BUDGETS)]
            new = revise_plan(plan, hot_bytes=hot, l2_bytes=l2b)
            apply_assignment(new, dict(plan.strategy))
        elif kind == "strategy":
            new = revise_plan(plan)
            new.cache_rows = dict(plan.cache_rows)
            new.l2_rows = dict(plan.l2_rows)
            apply_assignment(new, dict(_MIXES[pick % len(_MIXES)]))
        else:  # world resize
            new = reshard_plan(plan, _WORLDS[pick % len(_WORLDS)], PDB)
        for g in new.groups:
            if plan.narrow_width(g.gid) != new.narrow_width(g.gid):
                narrow_touched[g.gid] = True
        state = migrate_state(plan, new, state)
        plan = new
        _check_invariants(plan, state)

    for g in plan.groups:
        st_g = state["emb"][str(g.gid)]
        n = _logical(g)
        w0, acc0, counts0 = ref[g.gid]
        np.testing.assert_array_equal(np.asarray(st_g.counts)[:n],
                                      counts0[:n])
        np.testing.assert_array_equal(np.asarray(st_g.acc)[:n], acc0[:n])
        if not narrow_touched[g.gid] and plan.narrow_width(g.gid) == g.dim:
            # narrow never engaged for this group: with no training between
            # revisions every tier load/write-back is an identity, so the
            # master survives the whole chain bitwise
            np.testing.assert_array_equal(np.asarray(st_g.w)[:n], w0[:n])


# ------------------------------------------------- checkpoint portability


def _portability_roundtrip(tmp_path, w_from, w_to):
    src_plan = _plan(w_from, mesh_shape=(w_from, 1))
    apply_assignment(src_plan, dict(_MIXES[2]))  # mixed incl. a narrow group
    state = _host_state(src_plan)
    bud = revise_plan(src_plan, hot_bytes=1 << 11, l2_bytes=1 << 12)
    apply_assignment(bud, dict(src_plan.strategy))
    state = migrate_state(src_plan, bud, state)
    src_plan = bud
    save_checkpoint(str(tmp_path), 5, state, meta=plan_meta(src_plan))

    # --- fresh process at the other world size -----------------------------
    dst_plan = apply_plan_meta(_plan(w_to, mesh_shape=(w_to, 1)),
                               plan_meta(src_plan))
    template = _host_state(dst_plan, seed=9)
    restored, step = restore_elastic(str(tmp_path), dst_plan, template)
    assert step == 5
    for g in dst_plan.groups:
        a = state["emb"][str(g.gid)]
        b = restored["emb"][str(g.gid)]
        n = _logical(g)
        assert np.shape(b.w)[0] == g.rows
        np.testing.assert_array_equal(np.asarray(a.w)[:n], np.asarray(b.w)[:n])
        np.testing.assert_array_equal(np.asarray(a.acc)[:n],
                                      np.asarray(b.acc)[:n])
        np.testing.assert_array_equal(np.asarray(a.counts)[:n],
                                      np.asarray(b.counts)[:n])
        k = np.asarray(b.cache.keys)
        assert ((k == g.rows) | (k < n)).all()  # sentinels remapped
        if a.proj is not None:
            np.testing.assert_array_equal(np.asarray(a.proj.kernel),
                                          np.asarray(b.proj.kernel))


def test_checkpoint_portable_scale_down(tmp_path):
    _portability_roundtrip(tmp_path, 8, 3)


def test_checkpoint_portable_scale_up(tmp_path):
    _portability_roundtrip(tmp_path, 2, 8)


def test_stale_meta_checkpoint(tmp_path):
    """A checkpoint without a recorded world (pre-elastic meta) restores at
    the matching world and fails with the elastic diagnosis — not a bare
    shape error — on a mismatch."""
    plan2 = _plan(2)
    state = _host_state(plan2)
    meta = plan_meta(plan2)
    del meta["world"], meta["mesh_shape"]  # simulate a pre-elastic sidecar
    save_checkpoint(str(tmp_path), 3, state, meta=meta)

    same = apply_plan_meta(_plan(2), meta)
    restored, _ = restore_elastic(str(tmp_path), same, _host_state(same, 1))
    np.testing.assert_array_equal(np.asarray(restored["emb"]["1"].w),
                                  np.asarray(state["emb"]["1"].w))

    other = apply_plan_meta(_plan(3), meta)
    with pytest.raises(ValueError, match="different world size"):
        restore_elastic(str(tmp_path), other, _host_state(other, 1))


# --------------------------------------------------- publish/pickup handoff


def test_publish_poll_load_roundtrip(tmp_path):
    plan = _plan(2, mesh_shape=(2, 1))
    state = _host_state(plan)
    pub = str(tmp_path / "pub")
    assert poll_published(pub) is None  # nothing there yet
    publish_state(pub, 10, state, meta=plan_meta(plan))
    assert poll_published(pub) == 10
    assert poll_published(pub, last_step=10) is None  # already consumed
    tmpl = {"emb": state["emb"], "dense": state["dense"]}
    loaded, s = load_published(pub, tmpl)
    assert s == 10 and set(loaded) == {"emb", "dense"}
    np.testing.assert_array_equal(np.asarray(loaded["emb"]["0"].w),
                                  np.asarray(state["emb"]["0"].w))

    # newer delta supersedes; the pointer moves atomically
    publish_state(pub, 20, state, meta=plan_meta(plan))
    assert poll_published(pub, last_step=10) == 20

    # cross-world pickup: a consumer at world 3 reshards the delta on load
    plan3 = reshard_plan(plan, 3, PDB)
    tmpl3 = {"emb": _host_state(plan3, seed=4)["emb"],
             "dense": state["dense"]}
    loaded3, _ = load_published(pub, tmpl3, plan=plan3)
    g = plan3.groups[0]
    n = _logical(g)
    assert np.shape(loaded3["emb"][str(g.gid)].w)[0] == g.rows
    np.testing.assert_array_equal(
        np.asarray(loaded3["emb"][str(g.gid)].w)[:n],
        np.asarray(state["emb"][str(g.gid)].w)[:n])
    # without a plan the row mismatch must raise, not silently re-pad
    with pytest.raises(ValueError, match="different world size"):
        load_published(pub, tmpl3)
