"""Gradient compression: bf16/f8 psum payloads + error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map
from repro.optim.grad_compression import compressed_psum

AXES = ("data", "model")


def _psum1(mesh, grads, mode, residual=None):
    def f(g, r):
        out, res = compressed_psum(g, AXES, mode=mode, residual=r)
        return out, res

    r0 = residual if residual is not None else jax.tree.map(jnp.zeros_like, grads)
    return jax.jit(shard_map(f, mesh=mesh, in_specs=(P(), P()),
                             out_specs=(P(), P()), check_vma=False))(grads, r0)


def test_bf16_close(mesh1):
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)).astype(np.float32))}
    exact, _ = _psum1(mesh1, g, "none")
    comp, res = _psum1(mesh1, g, "bf16")
    rel = float(jnp.abs(comp["w"] - exact["w"]).max() / jnp.abs(exact["w"]).max())
    assert rel < 1e-2
    # error feedback residual holds the rounding error
    np.testing.assert_allclose(np.asarray(comp["w"] + res["w"]),
                               np.asarray(exact["w"]), atol=1e-6)


def test_error_feedback_accumulates(mesh1):
    """Over repeated steps with the same gradient, EF makes the *mean*
    compressed update converge to the true gradient."""
    g = {"w": jnp.full((32,), 0.001, jnp.float32)}  # tiny: heavy f8 rounding
    res = None
    total = jnp.zeros((32,))
    for _ in range(64):
        out, res = _psum1(mesh1, g, "f8", residual=res)
        total = total + out["w"]
    mean_err = float(jnp.abs(total / 64 - 0.001).max() / 0.001)
    assert mean_err < 0.05
