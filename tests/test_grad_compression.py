"""Gradient compression: bf16/f8 psum payloads + error feedback, plus the
routed sparse-gradient path (PR 6) — per-mode compress/decompress roundtrips
('none' | 'fp16' | 'topk'), fused-kernel vs reference parity, zero-row
exactness (the dedup scatter's padded-slot contract), and the compressed
all_gather collective wrapper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map
from repro.kernels import ref
from repro.optim.grad_compression import (ROUTED_MODES, compress_rows,
                                          compressed_all_gather,
                                          compressed_psum, decompress_rows,
                                          topk_k, validate_routed_mode)

AXES = ("data", "model")


def _psum1(mesh, grads, mode, residual=None):
    def f(g, r):
        out, res = compressed_psum(g, AXES, mode=mode, residual=r)
        return out, res

    r0 = residual if residual is not None else jax.tree.map(jnp.zeros_like, grads)
    return jax.jit(shard_map(f, mesh=mesh, in_specs=(P(), P()),
                             out_specs=(P(), P()), check_vma=False))(grads, r0)


def test_bf16_close(mesh1):
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)).astype(np.float32))}
    exact, _ = _psum1(mesh1, g, "none")
    comp, res = _psum1(mesh1, g, "bf16")
    rel = float(jnp.abs(comp["w"] - exact["w"]).max() / jnp.abs(exact["w"]).max())
    assert rel < 1e-2
    # error feedback residual holds the rounding error
    np.testing.assert_allclose(np.asarray(comp["w"] + res["w"]),
                               np.asarray(exact["w"]), atol=1e-6)


def test_error_feedback_accumulates(mesh1):
    """Over repeated steps with the same gradient, EF makes the *mean*
    compressed update converge to the true gradient."""
    g = {"w": jnp.full((32,), 0.001, jnp.float32)}  # tiny: heavy f8 rounding
    res = None
    total = jnp.zeros((32,))
    for _ in range(64):
        out, res = _psum1(mesh1, g, "f8", residual=res)
        total = total + out["w"]
    mean_err = float(jnp.abs(total / 64 - 0.001).max() / 0.001)
    assert mean_err < 0.05


# ------------------------------------------------ routed-path roundtrips
def _rows(m=23, d=16, zero_rows=(3, 11)):
    g = np.random.default_rng(0).normal(size=(m, d)).astype(np.float32)
    for r in zero_rows:
        g[r] = 0.0
    return jnp.asarray(g)


def test_validate_routed_mode():
    for m in ROUTED_MODES:
        assert validate_routed_mode(m) == m
    with pytest.raises(ValueError):
        validate_routed_mode("bf16")  # a psum mode, not a routed mode


@pytest.mark.parametrize("fused", [False, True])
def test_none_roundtrip_is_identity(fused):
    g = _rows()
    out = decompress_rows(compress_rows(g, "none", fused=fused),
                          g.shape[-1], "none", fused=fused)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(g))


@pytest.mark.parametrize("fused", [False, True])
def test_fp16_roundtrip(fused):
    g = _rows()
    out = decompress_rows(compress_rows(g, "fp16", fused=fused),
                          g.shape[-1], "fp16", fused=fused)
    # per-row amax scaling: error bounded by fp16 eps of the row max
    scale = np.abs(np.asarray(g)).max(axis=-1, keepdims=True)
    err = np.abs(np.asarray(out) - np.asarray(g))
    assert (err <= scale * 2 ** -10 + 1e-8).all()
    # all-zero rows (padded / dropped bucket slots) roundtrip bitwise
    np.testing.assert_array_equal(np.asarray(out[3]), 0.0)
    np.testing.assert_array_equal(np.asarray(out[11]), 0.0)


@pytest.mark.parametrize("fused", [False, True])
def test_topk_roundtrip_keeps_heaviest(fused):
    g = _rows()
    d = g.shape[-1]
    out = decompress_rows(compress_rows(g, "topk", fused=fused),
                          d, "topk", fused=fused)
    # exact on the kept coordinates, zero elsewhere == mask reference
    k = topk_k(d)
    order = np.argsort(-np.abs(np.asarray(g)), axis=-1, kind="stable")
    mask = np.zeros(g.shape, bool)
    np.put_along_axis(mask, order[:, :k], True, axis=-1)
    np.testing.assert_allclose(np.asarray(out),
                               np.where(mask, np.asarray(g), 0.0),
                               atol=1e-6)
    np.testing.assert_array_equal(np.asarray(out[3]), 0.0)


def test_topk_full_budget_is_exact():
    """k == d degenerates to a lossless permutation roundtrip."""
    g = _rows(m=7, d=4, zero_rows=())
    v, i = ref.topk_compress_ref(g, 4)
    out = ref.topk_decompress_ref(v, i, 4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=1e-7)


def test_fused_payloads_match_reference():
    """The Pallas (interpret) compressors produce byte-identical payloads to
    the jnp references — owners decompress the same numbers regardless of
    which side compressed."""
    g = _rows()
    qf, sf = compress_rows(g, "fp16", fused=True)
    qr, sr = compress_rows(g, "fp16", fused=False)
    np.testing.assert_array_equal(np.asarray(qf), np.asarray(qr))
    np.testing.assert_array_equal(np.asarray(sf), np.asarray(sr))
    vf, idf = compress_rows(g, "topk", fused=True)
    vr, idr = compress_rows(g, "topk", fused=False)
    np.testing.assert_array_equal(np.asarray(vf), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(idf), np.asarray(idr))


@pytest.mark.parametrize("mode", ["none", "fp16", "topk"])
def test_compressed_all_gather(mesh1, mode):
    """world=1 all_gather: the compressed wrapper must equal decompress
    (compress (g)) exactly — the collective is identity, so any difference
    is the wrapper mishandling the payload tree."""
    g = _rows()

    def f(x):
        return compressed_all_gather(x, AXES, mode=mode)

    got = jax.jit(shard_map(f, mesh=mesh1, in_specs=(P(),), out_specs=P(),
                            check_vma=False))(g)
    want = decompress_rows(compress_rows(g, mode), g.shape[-1], mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-7)
