"""LM stack correctness: chunked==full attention, SWA masking, GQA,
prefill/decode consistency vs the full forward, RoPE properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.layers.attention import (apply_rope, chunked_causal_attention,
                                    decode_attention)
from repro.layers.transformer import (init_kv_cache, init_lm_params,
                                      lm_decode_step, lm_forward, lm_loss,
                                      lm_prefill)

RNG = np.random.default_rng(0)


def _qkv(b, s, h, g, hd):
    q = jnp.asarray(RNG.normal(size=(b, s, h, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, s, g, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, s, g, hd)).astype(np.float32))
    return q, k, v


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_chunked_equals_full(chunk):
    q, k, v = _qkv(2, 32, 4, 2, 8)
    full = chunked_causal_attention(q, k, v, chunk=32)
    got = chunked_causal_attention(q, k, v, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), atol=2e-5)


@pytest.mark.parametrize("window", [4, 8, 16])
def test_swa_equals_masked_full(window):
    q, k, v = _qkv(1, 32, 2, 2, 8)
    got = chunked_causal_attention(q, k, v, chunk=8, window=window)
    ref = chunked_causal_attention(q, k, v, chunk=32, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
    # window=seq == plain causal
    allw = chunked_causal_attention(q, k, v, chunk=8, window=32)
    now = chunked_causal_attention(q, k, v, chunk=8)
    np.testing.assert_allclose(np.asarray(allw), np.asarray(now), atol=2e-5)


def test_decode_matches_train_attention():
    """Decode at position t == row t of full causal attention."""
    b, s, h, g, hd = 2, 16, 4, 2, 8
    q, k, v = _qkv(b, s, h, g, hd)
    full = chunked_causal_attention(q, k, v, chunk=s)
    t = s - 1
    out = decode_attention(q[:, t:t + 1], k, v, jnp.int32(s))
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, t]), atol=2e-5)


def test_rope_relative_property():
    """RoPE: <q_m, k_n> depends only on m - n (per head-dim pair)."""
    hd = 16
    q = jnp.asarray(RNG.normal(size=(1, 1, 1, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(1, 1, 1, hd)).astype(np.float32))

    def dot(m, n):
        qr = apply_rope(q, jnp.array([m]), 10000.0)
        kr = apply_rope(k, jnp.array([n]), 10000.0)
        return float(jnp.sum(qr * kr))

    assert abs(dot(5, 3) - dot(12, 10)) < 1e-4
    assert abs(dot(7, 0) - dot(107, 100)) < 1e-4


def test_gqa_head_grouping():
    """With kv replicated per group, GQA == MHA on the repeated kv."""
    b, s, h, g, hd = 1, 8, 4, 2, 8
    q, k, v = _qkv(b, s, h, g, hd)
    out_gqa = chunked_causal_attention(q, k, v, chunk=s)
    k_rep = jnp.repeat(k, h // g, axis=2)
    v_rep = jnp.repeat(v, h // g, axis=2)
    out_mha = chunked_causal_attention(q, k_rep, v_rep, chunk=s)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha), atol=2e-5)


def test_prefill_decode_consistency_moe():
    """Prefill(32) + decode(1) == forward(33) (MoE drops disabled)."""
    cfg = get_config("mixtral-8x22b", smoke=True)
    mc = float(cfg.moe.n_experts) / cfg.moe.top_k
    p = init_lm_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    logits, cache = jax.jit(lambda p, t: lm_prefill(cfg, p, t, 16, mc))(p, toks)
    toks33 = jnp.concatenate([toks, toks[:, -1:]], axis=1)
    full = jax.jit(lambda p, t: lm_forward(cfg, p, t, 16, True, mc))(p, toks33)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, 31]),
                               atol=2e-5, rtol=1e-4)
    c2 = init_kv_cache(cfg, 2, 64)
    c2 = jax.tree.map(lambda c, n: c.at[:, :, :32].set(n), c2, cache)
    lg, _ = jax.jit(lambda p, c, t, l: lm_decode_step(cfg, p, c, t, l, mc))(
        p, c2, toks[:, -1:], jnp.int32(32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 32]),
                               atol=2e-5, rtol=1e-4)


def test_loss_chunking_invariant():
    """Chunked CE == unchunked CE."""
    cfg = get_config("stablelm-1.6b", smoke=True)
    p = init_lm_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab)
    l0 = float(lm_loss(cfg, p, toks, attn_chunk=16, loss_chunk=0))
    l8 = float(lm_loss(cfg, p, toks, attn_chunk=16, loss_chunk=8))
    assert abs(l0 - l8) < 1e-4
