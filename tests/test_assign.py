"""Strategy-assignment compiler (repro.core.assign): cost-model picks,
override path, spec normalization, and launcher-side validation."""
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.configs.base import FeatureField, InteractionSpec, WDLConfig
from repro.core.assign import (AUTO_NAMES, StrategyAssignment, apply_assignment,
                               compile_assignment, estimate_narrow_gain,
                               estimate_skew, resolve_assignment)
from repro.core.packing import make_plan


def _cfg(fields):
    return WDLConfig(name="t", fields=tuple(fields), n_dense=0,
                     interactions=(InteractionSpec("fm"),), mlp_dims=(8,))


def _mixed_plan(world=1, per_device_batch=16, **kw):
    """One tiny group (dim 8) + one large budgeted group (dim 16)."""
    fields = [FeatureField("tiny", 64, 8, max_len=1, pooling="sum"),
              FeatureField("big", 50_000, 16, max_len=1, pooling="sum")]
    kw.setdefault("hot_bytes", 1 << 14)
    return make_plan(_cfg(fields), world=world,
                     per_device_batch=per_device_batch, **kw)


# ------------------------------------------------------------- cost model
def test_cost_model_mixes_ps_picasso_hybrid():
    plan = _mixed_plan()
    asg = compile_assignment(plan)
    by_name = {plan.group(g).tables[0].name: s for g, s in asg.strategy.items()}
    assert by_name["tiny"] == "ps"       # replicable: under routing overhead
    assert by_name["big"] == "picasso"   # large + budgeted + skewed

    # no cache budget -> the big group degrades to the plain routed path
    flat = compile_assignment(_mixed_plan(enable_cache=False))
    by_name = {plan.group(g).tables[0].name: s for g, s in flat.strategy.items()}
    assert by_name == {"tiny": "ps", "big": "hybrid"}


def test_cost_model_reports_scores_and_reasons():
    asg = compile_assignment(_mixed_plan())
    for gid, s in asg.scores.items():
        assert s.choice == asg.strategy[gid]
        assert {"ps", "hybrid", "picasso"} == set(s.costs)
        assert s.reason
    assert "ps" in asg.describe() and "picasso" in asg.describe()


def test_measured_stats_override_the_prior():
    plan = _mixed_plan()
    gid_big = next(g.gid for g in plan.groups if g.tables[0].name == "big")
    rows = plan.group(gid_big).rows
    # perfectly flat counts on a table whose cache covers ~1/8 of the rows
    # still clear SKEW_MIN; concentrate everything on one row to test the
    # measured path properly: skew -> 1.0
    hot = np.zeros(rows)
    hot[3] = 100.0
    asg = compile_assignment(plan, stats={gid_big: hot})
    assert asg.scores[gid_big].skew == pytest.approx(1.0)
    assert asg.strategy[gid_big] == "picasso"


def test_estimate_skew():
    plan = _mixed_plan()
    g = plan.groups[0]
    assert estimate_skew(g, 0) == 0.0                       # no budget, no tier
    assert estimate_skew(g, 8) > 0.0                        # structural prior
    counts = np.r_[np.full(8, 10.0), np.zeros(56)]
    assert estimate_skew(g, 8, counts) == pytest.approx(1.0)
    assert estimate_skew(g, 4, counts) == pytest.approx(0.5)


# -------------------------------------------------------------- overrides
def test_overrides_by_gid_and_table_glob():
    plan = _mixed_plan()
    asg = compile_assignment(plan, overrides={"big": "hybrid", 0: "ps"})
    by_name = {plan.group(g).tables[0].name: s for g, s in asg.strategy.items()}
    assert by_name["big"] == "hybrid"
    asg2 = compile_assignment(plan, overrides={"*i*": "hybrid"})  # both match
    assert set(asg2.strategy.values()) == {"hybrid"}


def test_overrides_fail_fast():
    plan = _mixed_plan()
    with pytest.raises(ValueError, match="matches no table"):
        compile_assignment(plan, overrides={"nope*": "ps"})
    with pytest.raises(ValueError, match="unknown lookup strategy"):
        compile_assignment(plan, overrides={"big": "not-a-strategy"})
    with pytest.raises(KeyError):
        compile_assignment(plan, overrides={99: "ps"})


# ---------------------------------------------------------- normalization
def test_cost_model_routes_cold_heavy_group_to_narrow():
    """A big group with a skewed head but a dominant cold tail goes to
    picasso_narrow when the plan records a narrow budget — and only then."""
    fields = [FeatureField("big", 200_000, 16, max_len=1, pooling="sum")]
    kw = dict(world=1, per_device_batch=64, hot_bytes=1 << 13,
              l2_bytes=1 << 14)
    plan = make_plan(_cfg(fields), narrow_dim=4, **kw)
    gid = plan.groups[0].gid
    g = plan.group(gid)
    # zipf head (caches well) + a long cold tail (dominates lookups)
    counts = np.maximum(
        (1e5 / np.arange(1, g.rows + 1) ** 0.7).astype(np.int32), 1)
    gain = estimate_narrow_gain(g, plan.cache_rows[gid], plan.l2_rows[gid],
                                counts=counts, ranked=True)
    assert gain > 0.5  # the tail really is most of the traffic
    asg = compile_assignment(plan, stats={gid: counts})
    assert asg.strategy[gid] == "picasso_narrow"
    # same traffic, no narrow budget recorded -> the candidate is not offered
    base = compile_assignment(make_plan(_cfg(fields), **kw),
                              stats={gid: counts})
    assert base.strategy[gid] != "picasso_narrow"


def test_resolve_broadcast_and_auto():
    plan = _mixed_plan()
    gids = {g.gid for g in plan.groups}
    assert resolve_assignment(plan, "ps") == {g: "ps" for g in gids}
    assert plan.strategy == {}  # broadcast never records
    for name in AUTO_NAMES:
        auto = resolve_assignment(plan, name)
        assert set(auto) == gids  # compiled on the fly (plan.strategy empty)
        # ... and recorded, so every later engine/flush sees the same mixing
        assert plan.strategy == auto
    # a recorded plan assignment wins over recompilation
    apply_assignment(plan, {g: "hybrid" for g in gids})
    assert resolve_assignment(plan, "mixed") == {g: "hybrid" for g in gids}


def test_resolve_auto_honours_use_cache():
    """use_cache=False must reach the fallback compile: no picasso picks
    (and no hot-tier credit) when the engine disables the tier."""
    plan = _mixed_plan()
    auto = resolve_assignment(plan, "mixed", use_cache=False)
    assert "picasso" not in set(auto.values())
    assert compile_assignment(_mixed_plan(), enable_cache=False).strategy == auto


def test_resolve_validates_coverage_and_names():
    plan = _mixed_plan()
    gids = sorted(g.gid for g in plan.groups)
    with pytest.raises(ValueError, match="unknown lookup strategy"):
        resolve_assignment(plan, "typo")
    with pytest.raises(ValueError, match="missing gids"):
        resolve_assignment(plan, {gids[0]: "ps"})
    with pytest.raises(ValueError, match="unknown gids"):
        resolve_assignment(plan, {**{g: "ps" for g in gids}, 99: "ps"})
    with pytest.raises(ValueError, match="unknown lookup strategy"):
        resolve_assignment(plan, {g: "typo" for g in gids})
    asg = StrategyAssignment(strategy={g: "ps" for g in gids})
    assert resolve_assignment(plan, asg) == {g: "ps" for g in gids}


def test_apply_assignment_records_on_plan():
    plan = _mixed_plan()
    asg = compile_assignment(plan)
    assert apply_assignment(plan, asg) is plan
    assert plan.strategy == asg.strategy
    with pytest.raises(ValueError, match="unknown lookup strategy"):
        apply_assignment(plan, {0: "typo"})


# ------------------------------------------------------------- launch CLI
def test_launch_cli_rejects_unknown_strategy():
    """--strategy is validated at argparse time (choices=), so typos exit 2
    before any engine construction; mixed/auto are accepted spellings."""
    root = Path(__file__).resolve().parent.parent
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--strategy", "nope"],
        capture_output=True, text=True, timeout=300,
        env={"PYTHONPATH": str(root / "src"), "PATH": "/usr/bin:/bin"},
        cwd=str(root))
    assert out.returncode == 2
    assert "invalid choice" in out.stderr and "mixed" in out.stderr
