"""Checkpoint/restore: exact roundtrip, elastic re-pad, async writer,
failure-injected resume via the Supervisor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packed_embedding import CacheState
from repro.train.checkpoint import (AsyncCheckpointer, latest_step,
                                    load_checkpoint_meta, restore_checkpoint,
                                    save_checkpoint)
from repro.train.fault_tolerance import Supervisor


def _state(rows=16):
    return {
        "emb": {"0": {"w": jnp.arange(rows * 4, dtype=jnp.float32).reshape(rows, 4),
                      "cache": CacheState(jnp.arange(4, dtype=jnp.int32),
                                          jnp.ones((4, 4)), jnp.zeros((4, 1)))}},
        "dense": {"l0": {"w": jnp.ones((3, 3)), "b": jnp.zeros((3,))}},
        "step": jnp.int32(7),
    }


def test_roundtrip_exact(tmp_path):
    s = _state()
    save_checkpoint(str(tmp_path), 7, s)
    r, step = restore_checkpoint(str(tmp_path), s)
    assert step == 7
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_repad(tmp_path):
    """A row-count (world-padding) mismatch is detected: the default restore
    raises with the elastic-path pointer (the old silent zero-extend
    corrupted tier sentinel keys), 'keep' hands back the stored rows for
    resharding, and 'repad' opts into the legacy zero-extend/truncate."""
    save_checkpoint(str(tmp_path), 1, _state(rows=16))
    template = _state(rows=24)
    with pytest.raises(ValueError, match="different world size"):
        restore_checkpoint(str(tmp_path), template)
    # 'keep': stored leading dims come back untouched (reshard-side input)
    r, _ = restore_checkpoint(str(tmp_path), template, on_row_mismatch="keep")
    assert np.asarray(r["emb"]["0"]["w"]).shape == (16, 4)
    # 'repad': the legacy behavior, now opt-in (tier-free states only)
    r, _ = restore_checkpoint(str(tmp_path), template, on_row_mismatch="repad")
    w = np.asarray(r["emb"]["0"]["w"])
    assert w.shape == (24, 4)
    np.testing.assert_array_equal(w[:16], np.arange(64, dtype=np.float32).reshape(16, 4))
    np.testing.assert_array_equal(w[16:], 0)
    # shrink direction
    template = _state(rows=8)
    r, _ = restore_checkpoint(str(tmp_path), template, on_row_mismatch="repad")
    assert np.asarray(r["emb"]["0"]["w"]).shape == (8, 4)
    with pytest.raises(ValueError, match="on_row_mismatch"):
        restore_checkpoint(str(tmp_path), template, on_row_mismatch="bogus")


def test_keep_gc(tmp_path):
    for i in range(5):
        save_checkpoint(str(tmp_path), i, _state(), keep=2)
    assert latest_step(str(tmp_path)) == 4
    steps = sorted(p.name for p in tmp_path.iterdir() if p.name.startswith("step_"))
    assert len(steps) == 2


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(3, _state())
    ck.wait()
    assert latest_step(str(tmp_path)) == 3


def test_meta_sidecar_roundtrip(tmp_path):
    """The plan-revision sidecar rides the manifest and comes back verbatim;
    checkpoints without one read as None (backward compatible)."""
    meta = {"plan_rev": 2, "cache_rows": {"0": 16}, "strategy": {"0": "ps"}}
    save_checkpoint(str(tmp_path), 1, _state())            # no meta
    save_checkpoint(str(tmp_path), 2, _state(), meta=meta)
    assert load_checkpoint_meta(str(tmp_path), step=1) is None
    assert load_checkpoint_meta(str(tmp_path), step=2) == meta
    assert load_checkpoint_meta(str(tmp_path)) == meta     # latest
    # async writer threads the sidecar through too
    ck = AsyncCheckpointer(str(tmp_path))
    ck.save(3, _state(), meta=meta)
    ck.wait()
    assert load_checkpoint_meta(str(tmp_path)) == meta
    # restore is meta-agnostic
    r, step = restore_checkpoint(str(tmp_path), _state(), step=2)
    assert step == 2


def test_supervisor_failure_resume(tmp_path):
    """Inject a failure mid-run; the loop restores and completes."""
    state = {"x": jnp.zeros(()), "step": jnp.int32(0)}

    def step_fn(s, batch):
        return {"x": s["x"] + batch, "step": s["step"] + 1}, {"loss": s["x"]}

    def batches():
        while True:
            yield jnp.float32(1.0)

    fails = {"armed": True}

    def inject(step):
        if step == 5 and fails["armed"]:
            fails["armed"] = False
            raise RuntimeError("simulated node failure")

    sup = Supervisor(str(tmp_path), ckpt_every=2, max_retries=2, backoff_s=0.0)
    out = sup.run(state, step_fn, batches(), n_steps=8, fail_injector=inject)
    assert int(out["step"]) == 8
    assert sup.total_failures == 1
    # density counter reset by the clean stretch after the rollback
    assert sup.failures == 0
    # checkpoint at step 8 exists (durable final state)
    sup.ckpt.wait()
    assert latest_step(str(tmp_path)) == 8
