"""PICASSO planner unit + property tests (Eq. 1/2/3 logic)."""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:
    from hypothesis_fallback import given, settings, st

from repro.configs.base import FeatureField, InteractionSpec, WDLConfig
from repro.core.packing import (PackedGroup, PicassoPlan, build_tables, calc_vparam,
                                make_plan, plan_capacity, plan_interleave,
                                plan_microbatch, plan_packing)


def _cfg(fields):
    return WDLConfig(name="t", fields=tuple(fields), n_dense=0,
                     interactions=(InteractionSpec("fm"),), mlp_dims=(8,))


def test_groups_by_dim():
    fields = [FeatureField("a", 100, 8), FeatureField("b", 200, 8),
              FeatureField("c", 300, 16)]
    groups = plan_packing(_cfg(fields), world=4)
    dims = sorted(g.dim for g in groups)
    assert dims == [8, 16]
    g8 = next(g for g in groups if g.dim == 8)
    assert {t.name for t in g8.tables} == {"a", "b"}


def test_no_packing_mode():
    fields = [FeatureField(f"f{i}", 100, 8) for i in range(5)]
    groups = plan_packing(_cfg(fields), world=2, enable_packing=False)
    assert len(groups) == 5  # one fragmentary op per table (baseline)


def test_vparam_split():
    # one dominant group (many tables, big dim) must split into shards
    fields = [FeatureField(f"big{i}", 10_000, 32) for i in range(8)]
    fields += [FeatureField("small", 100, 8)]
    groups = plan_packing(_cfg(fields), world=2, split_factor=1.1)
    g32 = [g for g in groups if g.dim == 32]
    assert len(g32) > 1  # split happened
    names = sorted(t.name for g in g32 for t in g.tables)
    assert names == sorted(f"big{i}" for i in range(8))  # no loss, no dup


def test_shared_table():
    fields = [FeatureField("hist", 1000, 8, max_len=10, pooling="none"),
              FeatureField("tgt", 1000, 8, shared_table="hist")]
    tables, f2t = build_tables(_cfg(fields))
    assert list(tables) == ["hist"]
    assert tables["hist"].ids_per_sample == 11
    groups = plan_packing(_cfg(fields), world=4)
    assert len(groups) == 1
    assert groups[0].n_bags == 11  # 10 un-pooled positions + 1 pooled bag


def test_rows_padded_to_world():
    fields = [FeatureField("a", 1001, 8)]
    for world in (1, 2, 64, 512):
        g = plan_packing(_cfg(fields), world)[0]
        assert g.rows % world == 0 and g.rows >= 1001


def test_capacity_exact_and_planned():
    g = plan_packing(_cfg([FeatureField("a", 10_000, 8)]), 8)[0]
    assert plan_capacity(g, local_ids=64, world=8, exact=True) == 64
    cap = plan_capacity(g, local_ids=1024, world=8, slack=2.0)
    assert 4 <= cap <= 1024
    assert plan_capacity(g, 1024, 8, slack=2.0, cache_hit_ratio=0.5) <= cap


def test_microbatch_divides():
    for b in (8, 48, 128):
        bs = plan_microbatch(b, act_bytes_per_sample=1 << 20,
                             mem_budget_bytes=16 << 20)
        assert b % bs == 0
    assert plan_microbatch(64, 1.0, n_micro=4) == 16


def test_interleave_partition():
    fields = [FeatureField(f"f{i}", 1000 * (i + 1), 2 ** (2 + i % 3)) for i in range(9)]
    groups = plan_packing(_cfg(fields), 4)
    ilv = plan_interleave(groups, n_groups=2)
    flat = sorted(g for wave in ilv for g in wave)
    assert flat == sorted(g.gid for g in groups)  # exact partition


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(10, 50_000),       # vocab
                          st.sampled_from([4, 8, 16, 32]),  # dim
                          st.integers(1, 20)),            # max_len
                min_size=1, max_size=25),
       st.sampled_from([1, 4, 8, 512]))
def test_plan_properties(specs, world):
    fields = [FeatureField(f"f{i}", v, d, max_len=m,
                           pooling="sum" if m == 1 else "none")
              for i, (v, d, m) in enumerate(specs)]
    plan = make_plan(_cfg(fields), world=world, per_device_batch=8)
    # every field appears in exactly one group slot
    seen = [s.field.name for g in plan.groups for s in g.slots]
    assert sorted(seen) == sorted(f.name for f in fields)
    for g in plan.groups:
        assert g.rows % world == 0
        assert all(t.dim == g.dim for t in g.tables)
        # table offsets are disjoint
        spans = sorted((off, off + next(t.vocab for t in g.tables if t.name == n))
                       for n, off in g.table_offsets.items())
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert a1 <= b0
        assert plan.capacity[g.gid] >= 4
    flat = sorted(g for wave in plan.interleave for g in wave)
    assert flat == sorted(g.gid for g in plan.groups)


def test_group_resolves_by_gid_not_list_index():
    """group(gid) must resolve by the group's actual gid: plans sliced per
    tower (or re-planned) hold non-contiguous gids, where positional
    indexing silently returns the wrong group."""
    fields = [FeatureField("a", 100, 8), FeatureField("b", 300, 16)]
    plan = make_plan(_cfg(fields), world=1, per_device_batch=4)
    assert sorted(g.gid for g in plan.groups) == [0, 1]
    # non-contiguous: drop gid 0, keep gid 1 at list position 0
    sub = PicassoPlan(groups=[g for g in plan.groups if g.gid == 1],
                      world=plan.world, capacity=dict(plan.capacity),
                      interleave=[[1]], microbatch=plan.microbatch,
                      cache_rows=dict(plan.cache_rows))
    assert sub.group(1).gid == 1
    with pytest.raises(KeyError, match="gid=0"):
        sub.group(0)


def test_plan_strategy_field_defaults_empty():
    plan = make_plan(_cfg([FeatureField("a", 100, 8)]), world=1, per_device_batch=4)
    assert plan.strategy == {}  # unassigned until compiled / broadcast


def test_calc_vparam_monotone():
    t1 = plan_packing(_cfg([FeatureField("a", 100, 8)]), 1)[0]
    t2 = plan_packing(_cfg([FeatureField("a", 100, 16)]), 1)[0]
    assert calc_vparam(t2.tables) > calc_vparam(t1.tables)
