"""Fused sparse hot-path kernels (PR 5): parity of the Pallas kernels
(interpret mode) against the jnp reference chains, the gather+pool custom
VJP, bitwise dedup+adagrad, the narrow-row gather+project stitch (forward,
custom VJP, and standalone transpose), tier probes, per-strategy
fused-vs-reference
engine parity (incl. the picasso_l2 tiers), the no-[n,D]-intermediate
guarantee, a fused train smoke against the reference loss trajectory, and
the chunked/streaming retrieval top-k.

Every fused call here passes ``fused=True`` explicitly, so the file is
meaningful both in a normal CPU run and under the CI soak
(``REPRO_FORCE_PALLAS_INTERPRET=1``), where the 'reference' engine rows also
route their dense interaction kernels through the interpreter.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FeatureField, InteractionSpec, WDLConfig
from repro.core import packed_embedding as pe
from repro.core.features import pack_group
from repro.core.packing import make_plan
from repro.data.synthetic import make_batch
from repro.dist.compat import shard_map
from repro.dist.sharding import batch_specs, emb_specs, replicated, to_named
from repro.embedding.state import EmbeddingState, init_embedding_state
from repro.engine import EmbeddingEngine
from repro.kernels import ops, ref

AXES = ("data", "model")
GB = 16
RNG = np.random.default_rng(0)


# ---------------------------------------------------------------- kernels
def _pool_args(rng, n, d, n_bags, n_uniq=None):
    n_uniq = n_uniq or n
    rows_u = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    # surjective onto [0, n_uniq): every unique slot has >= 1 position
    inv = np.concatenate([np.arange(n_uniq), rng.integers(0, n_uniq, n - n_uniq)])
    inv = jnp.asarray(inv[rng.permutation(n)].astype(np.int32))
    w = jnp.asarray(rng.normal(size=n).astype(np.float32))
    seg = np.sort(np.concatenate(
        [np.arange(n_bags), rng.integers(0, n_bags, n - n_bags)]))
    return rows_u, inv, w, jnp.asarray(seg.astype(np.int32))


@pytest.mark.parametrize("n,d,n_bags,n_uniq", [(24, 8, 6, 24), (40, 16, 10, 17),
                                               (64, 4, 64, 30)])
def test_gather_pool_fused_matches_ref(n, d, n_bags, n_uniq):
    rng = np.random.default_rng(n)
    rows_u, inv, w, seg = _pool_args(rng, n, d, n_bags, n_uniq)
    got = ops.gather_pool(rows_u, inv, w, seg, n_bags, fused=True)
    exp = ref.gather_pool_ref(rows_u, inv, w, seg, n_bags)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               atol=1e-5, rtol=1e-5)


def test_gather_pool_fused_uncovered_bag_is_zero():
    """A bag no position maps to must come out exactly zero in the fused
    path too (ghost coverage), not as an unwritten (garbage) output block —
    pinned because pool() is a public helper and the packed layout's
    every-bag-covered guarantee does not extend to future callers."""
    rng = np.random.default_rng(42)
    n, d, n_bags = 20, 8, 6
    rows_u, inv, w, _ = _pool_args(rng, n, d, n_bags)
    seg = jnp.asarray(np.sort(np.where(rng.integers(0, n_bags, n) == 3, 0,
                                       rng.integers(0, n_bags, n))
                              ).astype(np.int32))
    seg = jnp.where(seg == 3, 2, seg)    # bag 3 is empty
    got = ops.gather_pool(rows_u, inv, w, seg, n_bags, fused=True)
    exp = ref.gather_pool_ref(rows_u, inv, w, seg, n_bags)
    np.testing.assert_array_equal(np.asarray(got[3]), 0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("n,d,n_bags,n_uniq", [(24, 8, 6, 24), (40, 16, 10, 17)])
def test_gather_pool_custom_vjp_parity(n, d, n_bags, n_uniq):
    """jax.grad through the fused custom VJP == jax.grad of the raw
    reference chain (no custom VJP at all)."""
    rng = np.random.default_rng(100 + n)
    rows_u, inv, w, seg = _pool_args(rng, n, d, n_bags, n_uniq)
    tgt = jnp.asarray(rng.normal(size=(n_bags, d)).astype(np.float32))

    def loss_fused(r):
        return jnp.sum((ops.gather_pool(r, inv, w, seg, n_bags, fused=True)
                        - tgt) ** 2)

    def loss_raw(r):
        return jnp.sum((ref.gather_pool_ref(r, inv, w, seg, n_bags) - tgt) ** 2)

    g_fused = jax.grad(loss_fused)(rows_u)
    g_raw = jax.grad(loss_raw)(rows_u)
    np.testing.assert_allclose(np.asarray(g_fused), np.asarray(g_raw),
                               atol=1e-4, rtol=1e-4)
    # slots past n_uniq receive no positions: their grad must be EXACT zero
    # (the ghost rows of the fused transpose, not masked garbage)
    if n_uniq < n:
        np.testing.assert_array_equal(np.asarray(g_fused[n_uniq:]), 0.0)


def test_segment_grad_bitwise():
    rng = np.random.default_rng(5)
    n, d, n_bags, n_uniq = 48, 8, 12, 19
    _, inv, w, seg = _pool_args(rng, n, d, n_bags, n_uniq)
    g_bags = jnp.asarray(rng.normal(size=(n_bags, d)).astype(np.float32))
    got = ops.segment_grad(g_bags, seg, w, inv, n, fused=True)
    exp = ref.segment_grad_ref(g_bags, seg, w, inv, n)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


@pytest.mark.parametrize("rows,d,m,hot", [(37, 8, 50, 37), (64, 16, 96, 5),
                                          (16, 4, 64, 2)])
def test_dedup_adagrad_matches_reference(rows, d, m, hot):
    """Duplicate-heavy id sets (m >> hot): the fused one-pass kernel against
    the argsort/segment_sum/scatter reference.

    The duplicate-grad accumulation order is identical (stable sort, run-
    sequential adds — pinned bitwise on the gsum in the kernel prototype),
    so UNTOUCHED rows must be bitwise-identical; touched rows are compared
    to 1-2 ULP because XLA fuses the final adagrad arithmetic
    (``acc + mean(square(gsum))``) with different reassociation inside the
    kernel graph than in the reference graph."""
    rng = np.random.default_rng(rows * m)
    w = jnp.asarray(rng.normal(size=(rows, d)).astype(np.float32))
    acc = jnp.asarray(np.abs(rng.normal(size=(rows, 1))).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, hot, m).astype(np.int32))
    g = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    valid = jnp.asarray(rng.random(m) < 0.8)
    w2, acc2 = ops.dedup_adagrad(w, acc, idx, g, valid, 0.05, 1e-8, fused=True)
    wr, accr = ref.dedup_adagrad_ref(w, acc, idx, g, valid, 0.05, 1e-8)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(wr),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(acc2), np.asarray(accr),
                               rtol=1e-6, atol=1e-6)
    untouched = np.ones(rows, bool)
    touched = np.asarray(idx)[np.asarray(valid)]
    untouched[touched[touched < rows]] = False
    assert untouched.any()
    np.testing.assert_array_equal(np.asarray(w2)[untouched],
                                  np.asarray(w)[untouched])
    np.testing.assert_array_equal(np.asarray(acc2)[untouched],
                                  np.asarray(acc)[untouched])


def test_dedup_adagrad_all_invalid_is_identity():
    rng = np.random.default_rng(9)
    w = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))
    acc = jnp.asarray(np.abs(rng.normal(size=(8, 1))).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 8, 12).astype(np.int32))
    g = jnp.asarray(rng.normal(size=(12, 4)).astype(np.float32))
    w2, acc2 = ops.dedup_adagrad(w, acc, idx, g, jnp.zeros((12,), bool),
                                 0.05, 1e-8, fused=True)
    np.testing.assert_array_equal(np.asarray(w2), np.asarray(w))
    np.testing.assert_array_equal(np.asarray(acc2), np.asarray(acc))


@pytest.mark.parametrize("m,n,nd,d", [(24, 16, 4, 8), (40, 64, 8, 16),
                                      (7, 5, 3, 10)])
def test_gather_project_fused_matches_ref(m, n, nd, d):
    """The narrow-row stitch (picasso_narrow): gather [nd]-rows out of the
    routed buffer + up-project through the learned [nd, d] kernel, fused vs
    the take/matmul reference; not-kept positions exact zeros in both
    outputs."""
    rng = np.random.default_rng(200 + m)
    back = jnp.asarray(rng.normal(size=(m, nd)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, m, n).astype(np.int32))
    kept = jnp.asarray(rng.random(n) < 0.8)
    proj = jnp.asarray(rng.normal(size=(nd, d)).astype(np.float32))
    wf, nf = ops.gather_project(back, idx, kept, proj, fused=True)
    wr, nr = ref.gather_project_ref(back, idx, kept, proj)
    np.testing.assert_allclose(np.asarray(wf), np.asarray(wr),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(nf), np.asarray(nr),
                               atol=1e-6, rtol=1e-6)
    drop = ~np.asarray(kept)
    np.testing.assert_array_equal(np.asarray(wf)[drop], 0.0)
    np.testing.assert_array_equal(np.asarray(nf)[drop], 0.0)


@pytest.mark.parametrize("m,n,nd,d", [(24, 16, 4, 8), (13, 40, 8, 16)])
def test_gather_project_custom_vjp_parity(m, n, nd, d):
    """jax.grad through the fused custom VJP (w.r.t. the routed buffer AND
    the projection) == jax.grad of the raw reference chain; duplicate idx
    accumulate."""
    rng = np.random.default_rng(300 + m)
    back = jnp.asarray(rng.normal(size=(m, nd)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, m, n).astype(np.int32))
    kept = jnp.asarray(rng.random(n) < 0.8)
    proj = jnp.asarray(rng.normal(size=(nd, d)).astype(np.float32))
    tgt = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(n, nd)).astype(np.float32))

    def loss(fn):
        def f(b, p):
            wide, narrow = fn(b, p)
            return jnp.sum((wide - tgt) ** 2) + jnp.sum(narrow * c)
        return f

    g_fused = jax.grad(loss(lambda b, p: ops.gather_project(
        b, idx, kept, p, fused=True)), argnums=(0, 1))(back, proj)
    g_raw = jax.grad(loss(lambda b, p: ref.gather_project_ref(
        b, idx, kept, p)), argnums=(0, 1))(back, proj)
    for gf, gr in zip(g_fused, g_raw):
        np.testing.assert_allclose(np.asarray(gf), np.asarray(gr),
                                   atol=1e-4, rtol=1e-4)
    # buffer slots no kept position indexes get EXACT zero grad
    touched = np.zeros(m, bool)
    touched[np.asarray(idx)[np.asarray(kept)]] = True
    if (~touched).any():
        np.testing.assert_array_equal(np.asarray(g_fused[0])[~touched], 0.0)


def test_gather_project_grad_matches_ref():
    """The standalone transpose (the engine's explicit backward): fused vs
    the segment_sum reference, duplicate-heavy."""
    rng = np.random.default_rng(77)
    m, n, nd, d = 12, 48, 4, 8
    idx = jnp.asarray(rng.integers(0, m, n).astype(np.int32))
    kept = jnp.asarray(rng.random(n) < 0.8)
    proj = jnp.asarray(rng.normal(size=(nd, d)).astype(np.float32))
    g_wide = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    g_narrow = jnp.asarray(rng.normal(size=(n, nd)).astype(np.float32))
    got = ops.gather_project_grad(g_wide, g_narrow, idx, kept, proj, m,
                                  fused=True)
    exp = ref.gather_project_grad_ref(g_wide, g_narrow, idx, kept, proj, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)


def test_tier_probe_matches_cache_probe():
    rng = np.random.default_rng(3)
    h, d, n = 16, 8, 40
    keys = jnp.asarray(np.sort(rng.choice(200, h, replace=False)).astype(np.int32))
    rows = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))
    uniq = jnp.sort(jnp.asarray(
        np.concatenate([np.asarray(keys)[:6], rng.integers(0, 200, n - 6)])
        .astype(np.int32)))
    uvalid = jnp.asarray(np.arange(n) < n - 4)
    hit, slot, prows = ops.tier_probe(uniq, uvalid, keys, rows, fused=True)
    hr, sr = pe.cache_probe(uniq, uvalid, keys)
    np.testing.assert_array_equal(np.asarray(hit), np.asarray(hr))
    np.testing.assert_array_equal(np.asarray(slot), np.asarray(sr))
    exp = jnp.where(hr[:, None], jnp.take(rows, sr, axis=0), 0.0)
    np.testing.assert_array_equal(np.asarray(prows), np.asarray(exp))
    assert int(jnp.sum(hit)) >= 6 - 4  # the planted keys actually hit


# ------------------------------------------- no [n, D] per-id intermediate
def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for sub in vs:
                core = getattr(sub, "jaxpr", None)
                if core is None and hasattr(sub, "eqns"):
                    core = sub
                if core is not None and hasattr(core, "eqns"):
                    yield from _walk_eqns(core)


def _has_sub_jaxpr(eqn):
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else [v]
        for sub in vs:
            if hasattr(sub, "eqns") or hasattr(getattr(sub, "jaxpr", None),
                                               "eqns"):
                return True
    return False


def _per_id_intermediates(jaxpr, shape):
    """LEAF eqns (outside pallas_call) producing an array of the per-id
    shape. Call wrappers (pjit / custom_vjp) merely forward their body's
    result — the body's own eqns are already checked by the recursion."""
    bad = []
    for eqn in _walk_eqns(jaxpr):
        if eqn.primitive.name == "pallas_call" or _has_sub_jaxpr(eqn):
            continue  # kernel-internal blocks are [1, D], not [n, D]
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and getattr(aval, "shape", None) == shape:
                bad.append(eqn.primitive.name)
    return bad


def test_fused_pool_has_no_per_id_intermediate():
    """Acceptance: neither the fused forward nor the fused backward builds a
    [n, D] per-id array — the reference chains do (take/segment_sum), the
    pallas_call pipelines rows block-by-block. Asserted on the jaxpr: in the
    fused trace the only [n, D] values are the rows_u input and the [n, D]
    row-grad *output* of the backward pallas_call."""
    rng = np.random.default_rng(11)
    n, d, n_bags = 32, 8, 8
    rows_u, inv, w, seg = _pool_args(rng, n, d, n_bags, 20)

    fwd = jax.make_jaxpr(
        lambda r: ops.gather_pool(r, inv, w, seg, n_bags, fused=True))(rows_u)
    assert any(e.primitive.name == "pallas_call" for e in _walk_eqns(fwd.jaxpr))
    assert _per_id_intermediates(fwd.jaxpr, (n, d)) == []

    bwd = jax.make_jaxpr(jax.grad(
        lambda r: jnp.sum(
            ops.gather_pool(r, inv, w, seg, n_bags, fused=True) ** 2)))(rows_u)
    assert _per_id_intermediates(bwd.jaxpr, (n, d)) == []

    # the reference chain DOES materialize it (the thing being fused away)
    fwd_ref = jax.make_jaxpr(
        lambda r: ref.gather_pool_ref(r, inv, w, seg, n_bags))(rows_u)
    assert _per_id_intermediates(fwd_ref.jaxpr, (n, d)) != []


# --------------------------------------------- per-strategy engine parity
def _roundtrip(mesh, strategy, fused, cfg=None, **plan_kw):
    """forward + backward of one batch; returns (pooled, state leaves)."""
    cfg = cfg or get_config("deepfm", smoke=True)
    plan_kw.setdefault("enable_cache", False)
    plan_kw.setdefault("exact_capacity", True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, **plan_kw)
    emb0 = {str(g): s for g, s in
            init_embedding_state(jax.random.PRNGKey(0), plan).items()}
    batch = make_batch(cfg, GB, np.random.default_rng(3))
    fields = jax.tree.map(jnp.asarray, batch["fields"])
    engine = EmbeddingEngine(plan, AXES, 1, strategy=strategy,
                             use_cache=plan_kw.get("enable_cache", False),
                             lr_emb=0.1, use_fused_kernels=fused)
    especs = emb_specs(plan, AXES)

    def f(emb, fields):
        packed = {g.gid: pack_group(g, fields) for g in plan.groups}
        pooled, ctx = engine.forward(emb, packed)
        emb2, _m = engine.backward(emb, ctx, pooled)
        return pooled, emb2

    pooled_specs = {g.gid: jax.sharding.PartitionSpec(AXES, None, None)
                    for g in plan.groups}
    g = jax.jit(shard_map(f, mesh=mesh, in_specs=(especs, replicated(fields)),
                          out_specs=(pooled_specs, especs), check_vma=False))
    pooled, emb2 = g(emb0, fields)
    return (jax.tree.map(np.asarray, pooled),
            jax.tree.map(np.asarray, emb2))


@pytest.mark.parametrize("strategy", ["picasso", "hybrid", "ps"])
def test_strategy_fused_roundtrip_parity(mesh1, strategy):
    """Grad-parity per registry strategy: a full forward+backward with the
    fused kernels matches the reference engine (pooled outputs AND every
    post-update state leaf)."""
    p_ref, e_ref = _roundtrip(mesh1, strategy, False)
    p_fus, e_fus = _roundtrip(mesh1, strategy, True)
    for gid in p_ref:
        np.testing.assert_allclose(p_fus[gid], p_ref[gid], atol=1e-5,
                                   err_msg=f"{strategy}/pooled/{gid}")
    for (ka, a), (kb, b) in zip(
            jax.tree_util.tree_flatten_with_path(e_ref)[0],
            jax.tree_util.tree_flatten_with_path(e_fus)[0]):
        np.testing.assert_allclose(b, a, atol=1e-5,
                                   err_msg=f"{strategy}/state/{ka}")


def _l2_engine_step(mesh, fused, cache_update="psum"):
    """picasso_l2 with BOTH tiers pre-warmed from master rows, so the fused
    tier probes, the psum L1 update, and the L2 update path all engage."""
    cfg = WDLConfig(name="l2f", fields=(FeatureField("a", 64, 4),), n_dense=0,
                    interactions=(InteractionSpec("fm"),), mlp_dims=(8,))
    plan = make_plan(cfg, world=1, per_device_batch=GB, hot_bytes=1 << 14,
                     l2_bytes=320)
    (gid,) = [g.gid for g in plan.groups]
    h1, h2 = plan.cache_rows[gid], plan.l2_rows[gid]
    assert h1 > 0 and h2 > 0
    st = init_embedding_state(jax.random.PRNGKey(1), plan)[gid]
    batch = make_batch(cfg, GB, np.random.default_rng(2))
    fields = jax.tree.map(jnp.asarray, batch["fields"])
    # warm the tiers with ids the batch actually queries: pack_group's
    # scramble salt is hash()-based (randomized per process), so fixed key
    # ranges would only hit by luck of PYTHONHASHSEED
    pb = pack_group(plan.groups[0], fields)
    uids = np.unique(np.asarray(pb.ids))
    rows_padded = st.w.shape[0]
    split = max(1, len(uids) // 2)

    def tier(vals, cap):
        keys = np.full((cap,), rows_padded, np.int32)
        keys[:min(len(vals), cap)] = vals[:cap]
        keys = jnp.asarray(np.sort(keys))
        ok = (keys < rows_padded)[:, None]
        safe = jnp.clip(keys, 0, rows_padded - 1)
        return pe.CacheState(
            keys,
            jnp.take(st.w, safe, axis=0) * ok.astype(st.w.dtype),
            jnp.take(st.acc, safe, axis=0) * ok.astype(st.acc.dtype))

    st = EmbeddingState(w=st.w, acc=st.acc, counts=st.counts,
                        cache=tier(uids[:split], h1),
                        l2=tier(uids[split:], h2))
    emb0 = {str(gid): st}
    engine = EmbeddingEngine(plan, AXES, 1, strategy="picasso_l2",
                             lr_emb=0.1, cache_update=cache_update,
                             use_fused_kernels=fused)
    especs = emb_specs(plan, AXES)

    def f(emb, fields):
        packed = {g.gid: pack_group(g, fields) for g in plan.groups}
        pooled, ctx = engine.forward(emb, packed)
        emb2, m = engine.backward(emb, ctx, pooled)
        return pooled, emb2, m

    pooled_specs = {g.gid: jax.sharding.PartitionSpec(AXES, None, None)
                    for g in plan.groups}
    mspecs = {k: jax.sharding.PartitionSpec() for k in engine.metric_keys}
    g = jax.jit(shard_map(f, mesh=mesh, in_specs=(especs, replicated(fields)),
                          out_specs=(pooled_specs, especs, mspecs),
                          check_vma=False))
    pooled, emb2, m = g(emb0, fields)
    return (jax.tree.map(np.asarray, pooled), jax.tree.map(np.asarray, emb2),
            {k: int(v) for k, v in m.items()})


@pytest.mark.parametrize("cache_update", ["psum", "stale"])
def test_picasso_l2_fused_tier_parity(mesh1, cache_update):
    """Fused vs reference through warm L1+L2 tiers: identical pooled rows,
    identical tier/master updates, identical per-tier hit counters — in both
    tier-update modes (psum tier adagrad / stale routed-to-owner)."""
    p_ref, e_ref, m_ref = _l2_engine_step(mesh1, False, cache_update)
    p_fus, e_fus, m_fus = _l2_engine_step(mesh1, True, cache_update)
    assert m_ref["cache_hits/l1"] > 0 and m_ref["cache_hits/l2"] > 0
    assert m_fus == m_ref
    for gid in p_ref:
        np.testing.assert_allclose(p_fus[gid], p_ref[gid], atol=1e-5)
    for (ka, a), (kb, b) in zip(
            jax.tree_util.tree_flatten_with_path(e_ref)[0],
            jax.tree_util.tree_flatten_with_path(e_fus)[0]):
        np.testing.assert_allclose(b, a, atol=1e-5,
                                   err_msg=f"l2/{cache_update}/state/{ka}")


# ------------------------------------------------------------ train smoke
def test_train_smoke_fused_matches_reference_loss(mesh1, axes):
    """End-to-end acceptance: a train smoke forced through the (interpreted)
    Pallas kernels reproduces the reference loss trajectory step for step."""
    from repro.train.train_step import TrainConfig, init_state, make_train_step

    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, hot_bytes=1 << 14,
                     flush_iters=3, warmup_iters=2)
    from repro.models.wdl import WDLModel
    model = WDLModel(cfg, plan)

    def run(fused):
        state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1,
                           axes=axes)
        step, _ = make_train_step(model, plan, mesh1, axes, GB,
                                  TrainConfig(strategy="picasso",
                                              use_fused_kernels=fused))
        rng = np.random.default_rng(0)
        losses, hits = [], 0
        for _ in range(8):
            b = make_batch(cfg, GB, rng)
            b = jax.device_put(b, to_named(mesh1, batch_specs(b, axes)))
            state, m = step(state, b)
            losses.append(float(m["loss"]))
            hits += int(m["cache_hits"])
        return np.asarray(losses), hits

    l_ref, _ = run(False)
    l_fus, hits_fus = run(True)
    assert hits_fus > 0  # the warm hot tier exercised the fused probe
    np.testing.assert_allclose(l_fus, l_ref, rtol=1e-4, atol=1e-5)


# ------------------------------------------------- chunked retrieval top-k
def test_retrieval_streaming_topk_matches_unchunked(mesh1, axes):
    """n_candidates beyond the per-shard chunk capacity: scoring in
    fixed-size chunks with the streaming top-k merge returns exactly the
    single-shot result (scores AND ids)."""
    from repro.models.wdl import WDLModel
    from repro.serve.serve_step import make_retrieval_step
    from repro.train.train_step import init_state

    cfg = get_config("sasrec", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=1, enable_cache=False,
                     exact_capacity=True)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1,
                       axes=axes)
    nc = 256
    user = make_batch(cfg, 1, np.random.default_rng(1))
    item_vocab = max(f.vocab for f in cfg.fields)
    cand = jnp.asarray(np.arange(nc, dtype=np.int32) % item_vocab)

    full = make_retrieval_step(model, plan, mesh1, axes, nc, top_k=10)
    sv_full, ids_full = full(state, user, cand)
    # chunk of 32 ids: 8 streamed merges; the engine capacity is sized to
    # the CHUNK, so nc strictly exceeds what one unchunked lookup could hold
    chunked = make_retrieval_step(model, plan, mesh1, axes, nc, top_k=10,
                                  score_chunk=32)
    sv_c, ids_c = chunked(state, user, cand)
    np.testing.assert_allclose(np.asarray(sv_c), np.asarray(sv_full),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ids_c), np.asarray(ids_full))
    # a non-divisible chunk exercises the pad/mask tail
    ragged = make_retrieval_step(model, plan, mesh1, axes, nc, top_k=10,
                                 score_chunk=48)
    sv_r, ids_r = ragged(state, user, cand)
    np.testing.assert_allclose(np.asarray(sv_r), np.asarray(sv_full),
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ids_r), np.asarray(ids_full))


# ------------------------------------------------------- dispatch caching
def test_backend_dispatch_cached_and_resettable(monkeypatch):
    tpu = jax.default_backend() == "tpu"
    try:
        # start from a known state regardless of how this run was launched
        # (the CI soak sets REPRO_FORCE_PALLAS_INTERPRET for the whole file)
        monkeypatch.delenv("REPRO_FORCE_PALLAS_INTERPRET", raising=False)
        ops.reset_backend_cache()
        assert ops._use_pallas() == tpu
        # cached: setting the env var mid-process has NO effect...
        monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
        assert ops._use_pallas() == tpu
        # ...until the cache is reset (what a fresh process does)
        ops.reset_backend_cache()
        assert ops._use_pallas() is True
        assert ops.resolve_fused("auto") is True
    finally:
        ops.reset_backend_cache()  # monkeypatch restores the env at teardown
    assert ops.resolve_fused(True) is True
    assert ops.resolve_fused("off") is False
    with pytest.raises(ValueError, match="use_fused_kernels"):
        ops.resolve_fused("definitely-not-a-mode")
