"""End-to-end behaviour: the full PICASSO system learns a learnable synthetic
CTR task, and training resumes bit-exactly from a checkpoint."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.packing import make_plan
from repro.data.synthetic import batch_stream, make_batch
from repro.dist.sharding import batch_specs, to_named
from repro.models.wdl import WDLModel
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.train_step import TrainConfig, init_state, make_train_step

GB = 64


def _put(mesh, axes, batch):
    return jax.device_put(batch, to_named(mesh, batch_specs(batch, axes)))


def _setup(mesh1, axes, arch="deepfm", **plan_kw):
    cfg = get_config(arch, smoke=True)
    plan_kw.setdefault("hot_bytes", 1 << 14)
    plan_kw.setdefault("flush_iters", 5)
    plan_kw.setdefault("warmup_iters", 2)
    plan = make_plan(cfg, world=1, per_device_batch=GB, **plan_kw)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1, axes=axes)
    step, _ = make_train_step(model, plan, mesh1, axes, GB,
                              TrainConfig(lr_emb=0.1, lr_dense=3e-3))
    return cfg, state, step


def test_loss_decreases_on_learnable_task(mesh1, axes):
    cfg, state, step = _setup(mesh1, axes)
    losses = []
    # 100 steps, not 40: XLA-CPU reduction ordering is nondeterministic, and
    # over a 40-step horizon the adagrad trajectory's run-to-run spread was
    # as large as the learning signal (observed end/start ratios 0.80-1.02
    # across identical runs). At 100 steps the signal dominates (0.79-0.90).
    for i, batch in zip(range(100), batch_stream(cfg, GB, seed=0, learnable=True)):
        state, m = step(state, _put(mesh1, axes, batch))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    # medians: a single adagrad spike in either window must not flip the test
    first, last = np.median(losses[:10]), np.median(losses[-20:])
    assert last < first * 0.95, (first, last)


def test_checkpoint_resume_exact(mesh1, axes, tmp_path):
    cfg, state, step = _setup(mesh1, axes, arch="dcn-v2")
    stream = batch_stream(cfg, GB, seed=1)
    batches = [next(stream) for _ in range(6)]
    # run 3 steps, checkpoint, run 3 more
    for b in batches[:3]:
        state, _ = step(state, _put(mesh1, axes, b))
    save_checkpoint(str(tmp_path), 3, state)
    for b in batches[3:]:
        state, mA = step(state, _put(mesh1, axes, b))

    # restore at step 3, replay the same data -> identical metrics
    template = jax.tree.map(lambda x: x, state)
    restored, s = restore_checkpoint(str(tmp_path), template)
    assert s == 3
    for b in batches[3:]:
        restored, mB = step(restored, _put(mesh1, axes, b))
    assert float(mA["loss"]) == pytest.approx(float(mB["loss"]), abs=1e-6)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_microbatch_pipeline_equivalence(mesh1, axes):
    """n_micro=2 pipelined vs n_micro=1: same data, losses stay close (the
    pipeline's bounded staleness is within-batch only)."""
    cfg = get_config("deepfm", smoke=True)
    traj = {}
    for n_micro in (1, 2):
        plan = make_plan(cfg, world=1, per_device_batch=GB, enable_cache=False,
                         n_micro=n_micro, exact_capacity=True)
        model = WDLModel(cfg, plan)
        state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1, axes=axes)
        step, _ = make_train_step(model, plan, mesh1, axes, GB,
                                  TrainConfig(use_cache=False))
        ls = []
        for i, batch in zip(range(5), batch_stream(cfg, GB, seed=2)):
            state, m = step(state, _put(mesh1, axes, batch))
            ls.append(float(m["loss"]))
        traj[n_micro] = ls
    # same first-step loss (no updates applied yet when fwd of chunk 0 ran)
    assert traj[1][0] == pytest.approx(traj[2][0], rel=1e-5)
    # trajectories stay in the same regime
    assert abs(traj[1][-1] - traj[2][-1]) < 0.2


def test_retrieval_topk(mesh1, axes):
    """Retrieval returns the true argmax candidates of the dot scores."""
    from repro.serve.serve_step import make_retrieval_step
    cfg = get_config("sasrec", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=1, enable_cache=False,
                     exact_capacity=True)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1, axes=axes)
    nc = 512
    step = make_retrieval_step(model, plan, mesh1, axes, nc, top_k=8)
    user = make_batch(cfg, 1, np.random.default_rng(5))
    cand = jnp.arange(nc, dtype=jnp.int32)
    scores, ids = step(state, user, cand)
    assert scores.shape == (8,) and ids.shape == (8,)
    # monotone non-increasing scores
    s = np.asarray(scores)
    assert (np.diff(s) <= 1e-6).all()
