"""Interleaved train step (PR 6): the software-pipelined loop and the fused
interaction backwards.

Covers four acceptance surfaces:

* the scheduling primitives (``resolve_overlap`` / ``wave_barrier`` /
  ``pipeline_handoff``) are value-identity and resolve statically;
* overlap='on' and overlap='off' train bit-identical loss trajectories
  (barriers only pin the schedule);
* the synchronous path is PINNED: with overlap off (and K-Interleaving off,
  which owns the only other barriers) the traced step contains ZERO
  optimization_barrier equations — i.e. it is the pre-refactor step — and
  the overlap='on' trace differs from it ONLY by barrier insertion (same
  primitive histogram otherwise);
* ``jax.grad`` through ``fm_interaction`` / ``dot_interaction`` /
  ``cross_layer`` on the Pallas branch runs the fused backward kernels
  (pallas_call in the grad jaxpr) instead of the reference transpose, with
  gradient parity against ``jax.vjp`` of the references.
"""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.interleaving import (barrier, pipeline_handoff,
                                     resolve_overlap, wave_barrier)
from repro.core.packing import make_plan
from repro.data.synthetic import batch_stream
from repro.kernels import ops, ref
from repro.kernels.interaction_bwd import (cross_layer_bwd_pallas,
                                           dot_interaction_bwd_pallas,
                                           fm_interaction_bwd_pallas)
from repro.models.wdl import WDLModel
from repro.train.train_step import TrainConfig, init_state, make_train_step

AXES = ("data", "model")

# names the optimization-barrier primitive goes by across jax versions
_BARRIER_NAMES = {"optimization_barrier", "opt_barrier"}
_p = getattr(jax.lax, "optimization_barrier_p", None)
if _p is not None:
    _BARRIER_NAMES.add(_p.name)


# ------------------------------------------------------------- primitives
def test_resolve_overlap():
    assert resolve_overlap("on", 1) is True
    assert resolve_overlap("off", 4) is False
    assert resolve_overlap("auto", 1) is False
    assert resolve_overlap("auto", 2) is True
    assert resolve_overlap(None, 2) is True
    assert resolve_overlap(True, 1) is True
    assert resolve_overlap(False, 4) is False
    with pytest.raises(ValueError):
        resolve_overlap("sometimes", 2)


def test_barriers_are_value_identity():
    rng = np.random.default_rng(0)
    tree = {"a": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
            "b": (jnp.arange(5), jnp.ones(()))}
    out = barrier(tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    vals = [jnp.arange(3), jnp.ones((2, 2))]
    wb = wave_barrier(vals)
    assert isinstance(wb, list) and len(wb) == 2
    np.testing.assert_array_equal(np.asarray(wb[0]), np.asarray(vals[0]))

    cur, nxt = pipeline_handoff({"x": jnp.arange(4)}, jnp.zeros((2,)))
    np.testing.assert_array_equal(np.asarray(cur["x"]), np.arange(4))
    np.testing.assert_array_equal(np.asarray(nxt), np.zeros((2,)))

    assert barrier(()) == ()


def test_pipeline_handoff_emits_one_barrier():
    jx = jax.make_jaxpr(lambda a, b: pipeline_handoff(a, b))(
        jnp.ones((3,)), jnp.zeros((2,)))
    names = [e.primitive.name for e in jx.jaxpr.eqns]
    assert sum(n in _BARRIER_NAMES for n in names) == 1


# -------------------------------------------------------- step-level pins
def _walk_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else [v]
            for sub in vs:
                core = getattr(sub, "jaxpr", None)
                if core is None and hasattr(sub, "eqns"):
                    core = sub
                if core is not None and hasattr(core, "eqns"):
                    yield from _walk_eqns(core)


def _prim_histogram(jaxpr):
    return collections.Counter(e.primitive.name for e in _walk_eqns(jaxpr))


def _step_jaxpr(mesh1, overlap, n_micro=2, use_interleave=False):
    cfg = get_config("deepfm", smoke=True)
    gb = 16
    plan = make_plan(cfg, world=1, per_device_batch=gb, n_micro=n_micro,
                     enable_cache=False)
    model = WDLModel(cfg, plan)
    tcfg = TrainConfig(overlap=overlap, use_cache=False,
                       use_interleave=use_interleave)
    step, _ = make_train_step(model, plan, mesh1, AXES, gb, tcfg)
    state = init_state(model, plan, jax.random.PRNGKey(0))
    batch = next(iter(batch_stream(cfg, gb, seed=0)))
    batch = jax.tree.map(jnp.asarray, batch)
    return jax.make_jaxpr(step)(state, batch)


def test_overlap_off_is_the_synchronous_step(mesh1):
    """Regression pin for the refactored loop: with overlap off (and the
    K-Interleaving waves off — they own the only other barrier source) the
    traced step contains ZERO optimization_barrier eqns, i.e. the exact
    pre-refactor synchronous program; overlap on differs from it ONLY by
    inserting barriers (identical histogram otherwise)."""
    off = _prim_histogram(_step_jaxpr(mesh1, "off").jaxpr)
    on = _prim_histogram(_step_jaxpr(mesh1, "on").jaxpr)
    n_barrier_off = sum(off[n] for n in _BARRIER_NAMES)
    n_barrier_on = sum(on[n] for n in _BARRIER_NAMES)
    assert n_barrier_off == 0
    assert n_barrier_on >= 1  # one handoff per pipelined micro-batch pair
    for n in _BARRIER_NAMES:
        off.pop(n, None)
        on.pop(n, None)
    assert off == on


def test_overlap_auto_single_micro_is_off(mesh1):
    """auto with n_micro=1 must resolve to the synchronous step."""
    auto = _prim_histogram(_step_jaxpr(mesh1, "auto", n_micro=1).jaxpr)
    assert sum(auto[n] for n in _BARRIER_NAMES) == 0


def _train_losses(mesh1, overlap, steps=4, grad_compress="none"):
    cfg = get_config("deepfm", smoke=True)
    gb = 16
    plan = make_plan(cfg, world=1, per_device_batch=gb, n_micro=2,
                     hot_bytes=1 << 14, flush_iters=3, warmup_iters=1)
    model = WDLModel(cfg, plan)
    tcfg = TrainConfig(overlap=overlap, grad_compress=grad_compress,
                       lr_emb=0.1)
    step, _ = make_train_step(model, plan, mesh1, AXES, gb, tcfg)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1,
                       axes=AXES)
    out = []
    for _, b in zip(range(steps),
                    batch_stream(cfg, gb, seed=0, learnable=True)):
        state, m = step(state, b)
        out.append(float(m["loss"]))
    return out


def test_overlap_on_off_loss_parity(mesh1):
    """Barriers are value-identity: the pipelined and synchronous steps must
    produce bit-identical loss trajectories (flush included)."""
    assert _train_losses(mesh1, "off") == _train_losses(mesh1, "on")


def test_compressed_training_stays_close(mesh1):
    """fp16 routed-grad compression perturbs the trajectory only at fp16
    rounding scale; topk (a biased sparsifier) must at least stay finite —
    its loss-decrease behaviour is pinned at the CI smoke's gentler lr, not
    here at the parity harness's deliberately aggressive one."""
    base = _train_losses(mesh1, "on")
    fp16 = _train_losses(mesh1, "on", grad_compress="fp16")
    assert np.allclose(base, fp16, rtol=1e-2, atol=1e-2)
    topk = _train_losses(mesh1, "on", grad_compress="topk")
    assert all(np.isfinite(topk))


# ------------------------------------------- fused interaction backwards
@pytest.fixture
def pallas_branch(monkeypatch):
    """Force the Pallas (interpret) branch of ops for one test."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS_INTERPRET", "1")
    ops.reset_backend_cache()
    yield
    monkeypatch.delenv("REPRO_FORCE_PALLAS_INTERPRET", raising=False)
    ops.reset_backend_cache()


@pytest.mark.parametrize("op,make_args", [
    ("fm", lambda rng: (jnp.asarray(
        rng.normal(size=(9, 5, 8)).astype(np.float32)),)),
    ("dot", lambda rng: (jnp.asarray(
        rng.normal(size=(9, 5, 8)).astype(np.float32)),)),
    ("cross", lambda rng: tuple(jnp.asarray(a.astype(np.float32)) for a in (
        rng.normal(size=(9, 12)), rng.normal(size=(9, 12)),
        rng.normal(size=(12, 12)), rng.normal(size=(12,))))),
])
def test_interaction_grad_uses_fused_bwd_kernel(pallas_branch, op, make_args):
    """Acceptance: on the Pallas branch, jax.grad of each interaction op runs
    fused Pallas kernels both directions (>= 2 pallas_calls in the grad
    jaxpr: forward + fused backward, no reference-transpose fallback), and
    the gradients match jax.vjp of the jnp reference."""
    rng = np.random.default_rng(7)
    args = make_args(rng)
    fn = {"fm": ops.fm_interaction, "dot": ops.dot_interaction,
          "cross": ops.cross_layer}[op]
    refn = {"fm": ref.fm_interaction_ref, "dot": ref.dot_interaction_ref,
            "cross": ref.cross_layer_ref}[op]

    def loss(*a):
        return jnp.sum(fn(*a) ** 2)

    jx = jax.make_jaxpr(jax.grad(loss, argnums=tuple(range(len(args)))))(*args)
    n_pallas = sum(e.primitive.name == "pallas_call" for e in _walk_eqns(jx.jaxpr))
    assert n_pallas >= 2, f"{op}: expected fwd+bwd pallas_calls, got {n_pallas}"

    got = jax.grad(loss, argnums=tuple(range(len(args))))(*args)
    out, vjp = jax.vjp(refn, *args)
    want = vjp(2.0 * out)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("b", [17, 64, 130])  # non-multiples of block_b too
def test_interaction_bwd_kernels_match_vjp(b):
    """Direct kernel parity (interpret mode) against jax.vjp of the refs,
    including batch sizes that force zero-padding to the block multiple."""
    rng = np.random.default_rng(b)
    f, d = 6, 8
    fields = jnp.asarray(rng.normal(size=(b, f, d)).astype(np.float32))

    g1 = jnp.asarray(rng.normal(size=(b, 1)).astype(np.float32))
    _, vjp = jax.vjp(ref.fm_interaction_ref, fields)
    np.testing.assert_allclose(
        np.asarray(fm_interaction_bwd_pallas(fields, g1, block_b=64,
                                             interpret=True)),
        np.asarray(vjp(g1)[0]), atol=1e-4, rtol=1e-4)

    p = f * (f - 1) // 2
    g2 = jnp.asarray(rng.normal(size=(b, p)).astype(np.float32))
    _, vjp = jax.vjp(ref.dot_interaction_ref, fields)
    np.testing.assert_allclose(
        np.asarray(dot_interaction_bwd_pallas(fields, g2, block_b=64,
                                              interpret=True)),
        np.asarray(vjp(g2)[0]), atol=1e-4, rtol=1e-4)

    x0, x = fields[:, 0, :], fields[:, 1, :]
    w = jnp.asarray(rng.normal(size=(d, d)).astype(np.float32))
    bias = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    g3 = jnp.asarray(rng.normal(size=(b, d)).astype(np.float32))
    _, vjp = jax.vjp(ref.cross_layer_ref, x0, x, w, bias)
    got = cross_layer_bwd_pallas(x0, x, w, bias, g3, block_b=64,
                                 interpret=True)
    for gg, ww in zip(got, vjp(g3)):
        np.testing.assert_allclose(np.asarray(gg), np.asarray(ww),
                                   atol=1e-4, rtol=1e-4)


def test_cpu_branch_keeps_reference_transpose():
    """Off the Pallas branch the dispatchers keep the jax.vjp-of-reference
    backward — no pallas_call anywhere in the grad jaxpr (the CPU path must
    stay bitwise what it was)."""
    ops.reset_backend_cache()
    if ops._backend() == "tpu":  # real TPU: the fused branch is the default
        pytest.skip("CPU-branch pin only meaningful off-TPU")
    rng = np.random.default_rng(3)
    fields = jnp.asarray(rng.normal(size=(8, 4, 8)).astype(np.float32))
    jx = jax.make_jaxpr(jax.grad(
        lambda f: jnp.sum(ops.fm_interaction(f) ** 2)))(fields)
    assert not any(e.primitive.name == "pallas_call"
                   for e in _walk_eqns(jx.jaxpr))
