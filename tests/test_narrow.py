"""Frequency-adaptive embedding dims (the 'picasso_narrow' hot/cold split).

Pins the contracts of the narrow master:

1. degenerate parity — ``narrow_dim == dim`` records no narrowing, and a
   'picasso_narrow' run is bitwise-identical to 'picasso_l2' on the same
   plan (same state pytree, same flush, same tier gating);
2. the narrow master actually narrows ([rows, d] + a learned orthonormal
   [d, D] projection) and still learns, with projection gradients flowing;
3. migration tier transitions: no-change pass-through returns the same
   arrays; a forced tier resize on a narrow group preserves the FCounter,
   the adagrad slots, and the learned projection exactly; a full
   wide -> narrow -> wide round trip re-widens tier-resident rows exactly
   (they travel full-width in the tiers) and keeps FCounter/adagrad intact;
4. the revision plumbing: ``plan_delta`` reports narrow-width changes,
   ``plan_meta``/``apply_plan_meta`` round-trip the narrow budget, and an
   engine driving a narrowed group with any other strategy fails fast.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.assign import apply_assignment, resolve_assignment
from repro.core.packing import make_plan, plan_narrow, revise_plan
from repro.data.synthetic import batch_stream
from repro.dist.sharding import batch_specs, to_named
from repro.embedding.state import migrate_state
from repro.engine import EmbeddingEngine
from repro.models.wdl import WDLModel
from repro.runtime import apply_plan_meta, plan_delta, plan_meta
from repro.train.train_step import TrainConfig, init_state, make_train_step

GB = 64
ND = 4
PLAN_KW = dict(hot_bytes=1 << 14, l2_bytes=1 << 16, flush_iters=5,
               warmup_iters=2)


def _put(mesh, axes, batch):
    return jax.device_put(batch, to_named(mesh, batch_specs(batch, axes)))


def _setup(mesh1, axes, strategy="picasso_narrow", narrow_dim=ND, **plan_kw):
    cfg = get_config("deepfm", smoke=True)
    kw = dict(PLAN_KW)
    kw.update(plan_kw)
    plan = make_plan(cfg, world=1, per_device_batch=GB, narrow_dim=narrow_dim,
                     **kw)
    # record the broadcast before init_state: narrow master widths gate on
    # the plan's strategy assignment (the launchers do the same)
    apply_assignment(plan, resolve_assignment(plan, strategy))
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1, axes=axes)
    step, _ = make_train_step(model, plan, mesh1, axes, GB,
                              TrainConfig(strategy=strategy))
    return cfg, plan, model, state, step


def _train(state, step, mesh1, axes, cfg, n, seed=3):
    stream = batch_stream(cfg, GB, seed=seed)
    for _ in range(n):
        state, m = step(state, _put(mesh1, axes, next(stream)))
    return state


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------------- plan


def test_plan_narrow_clamps_per_group():
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB)
    dims = {g.gid: g.dim for g in plan.groups}
    # 0 / >= dim -> full dim (recorded as "no narrowing")
    assert plan_narrow(plan.groups, 0) == dims
    assert plan_narrow(plan.groups, max(dims.values())) == dims
    # a small request rounds to the min_dim quantum with a floor
    w = plan_narrow(plan.groups, 1)
    assert all(0 < v <= dims[g] and v % 4 == 0 for g, v in w.items()
               if v < dims[g])


def test_narrow_width_gates_on_strategy():
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, narrow_dim=ND)
    gid = plan.groups[0].gid
    dim = plan.group(gid).dim
    # budget recorded but no picasso_narrow assignment -> full width
    assert plan.narrow_dim[gid] == ND
    assert plan.narrow_width(gid) == dim
    apply_assignment(plan, resolve_assignment(plan, "picasso_narrow"))
    assert plan.narrow_width(gid) == ND
    # revise_plan carries the budget (strategy resets, so width gates off
    # again until the new revision's assignment is recorded)
    new = revise_plan(plan)
    assert new.narrow_dim == plan.narrow_dim
    assert new.narrow_width(gid) == dim


# ------------------------------------------- degenerate parity (nd == dim)


def test_full_width_narrow_is_bitwise_picasso_l2(mesh1, axes):
    """narrow_dim == dim records no narrowing: the picasso_narrow run is
    bitwise-identical to picasso_l2 on the same plan — same state pytree
    (no projection leaf), same lookup, same flush."""
    cfg, plan_a, _, state_a, step_a = _setup(mesh1, axes,
                                             strategy="picasso_l2",
                                             narrow_dim=None)
    dim = plan_a.groups[0].dim
    cfg, plan_b, _, state_b, step_b = _setup(mesh1, axes,
                                             strategy="picasso_narrow",
                                             narrow_dim=dim)
    assert all(plan_b.narrow_width(g.gid) == g.dim for g in plan_b.groups)
    assert all(st.proj is None for st in state_b["emb"].values())
    _leaves_equal(state_a, state_b)
    # through a flush boundary (flush_iters=5) and beyond
    state_a = _train(state_a, step_a, mesh1, axes, cfg, 7)
    state_b = _train(state_b, step_b, mesh1, axes, cfg, 7)
    _leaves_equal(state_a, state_b)


# ------------------------------------------------------ the narrow master


def test_narrow_master_shapes_and_learning(mesh1, axes):
    cfg, plan, _, state, step = _setup(mesh1, axes)
    gid = plan.groups[0].gid
    g = plan.group(gid)
    st = state["emb"][str(gid)]
    assert st.w.shape == (g.rows, ND)
    assert st.proj is not None and st.proj.kernel.shape == (ND, g.dim)
    # deterministic orthonormal-row init: P @ P^T == I (so the pseudo-inverse
    # used at re-widen time starts as the exact transpose)
    k = np.asarray(st.proj.kernel)
    np.testing.assert_allclose(k @ k.T, np.eye(ND), atol=1e-5)
    # tiers stay full-width: hot rows are exact wide rows
    assert st.cache.rows.shape[1] == g.dim
    k0 = np.array(k)
    state = _train(state, step, mesh1, axes, cfg, 7)
    st = state["emb"][str(gid)]
    # projection gradient flowed (learned through the routed wire)
    assert not np.array_equal(np.asarray(st.proj.kernel), k0)
    assert np.isfinite(np.asarray(st.w)).all()
    # the flush at step 5 warmed the tier from the live FCounter
    assert np.asarray(st.counts).sum() > 0


# -------------------------------------------------------------- migration


def test_migrate_passthrough_identity_narrow(mesh1, axes):
    """A no-change revision of a narrow plan passes every array through
    untouched (same objects — projection included)."""
    cfg, plan, _, state, step = _setup(mesh1, axes)
    state = _train(state, step, mesh1, axes, cfg, 6)
    new = revise_plan(plan)
    new.cache_rows, new.l2_rows = dict(plan.cache_rows), dict(plan.l2_rows)
    apply_assignment(new, resolve_assignment(new, "picasso_narrow"))
    assert not plan_delta(plan, new)
    out = migrate_state(plan, new, state)
    for k, st in state["emb"].items():
        assert out["emb"][k] is st


def test_forced_narrow_resize_preserves_fcounter_adagrad_and_proj(mesh1, axes):
    """Shrinking both tiers under a narrow group: the FCounter and the
    learned projection survive bitwise, adagrad slots survive exactly (tier
    slots via write-back, the rest untouched), and master rows outside the
    old tiers are not perturbed."""
    cfg, plan, _, state, step = _setup(mesh1, axes)
    state = _train(state, step, mesh1, axes, cfg, 9)
    gid = plan.groups[0].gid
    g = plan.group(gid)
    st = state["emb"][str(gid)]
    counts = np.asarray(jax.device_get(st.counts))
    kern = np.asarray(jax.device_get(st.proj.kernel))
    pacc = np.asarray(jax.device_get(st.proj.acc))
    w_old = np.asarray(jax.device_get(st.w))
    acc_exp = np.array(jax.device_get(st.acc))
    tier_keys = []
    for tier in (st.cache, st.l2):
        keys = np.asarray(jax.device_get(tier.keys))
        mine = keys < g.rows
        acc_exp[keys[mine]] = np.asarray(jax.device_get(tier.acc))[mine]
        tier_keys.append(keys[mine])
    in_tier = np.zeros(g.rows, bool)
    in_tier[np.concatenate(tier_keys)] = True

    new = revise_plan(plan, hot_bytes=1 << 10, l2_bytes=1 << 15)
    apply_assignment(new, resolve_assignment(new, "picasso_narrow"))
    assert plan_delta(plan, new)
    out = migrate_state(plan, new, state)
    mg = out["emb"][str(gid)]
    assert mg.w.shape == (g.rows, ND)
    np.testing.assert_array_equal(np.asarray(mg.counts), counts)
    np.testing.assert_array_equal(np.asarray(mg.proj.kernel), kern)
    np.testing.assert_array_equal(np.asarray(mg.proj.acc), pacc)
    np.testing.assert_array_equal(np.asarray(mg.acc), acc_exp)
    # same-width re-master: rows the tiers never shadowed pass through
    np.testing.assert_array_equal(np.asarray(mg.w)[~in_tier], w_old[~in_tier])
    # resized tiers stay full-width and disjoint
    h1, h2 = new.cache_rows[gid], new.l2_rows[gid]
    k1 = np.asarray(mg.cache.keys)
    k2 = np.asarray(mg.l2.keys)
    assert k1.shape[0] == h1 and k2.shape[0] == h2
    assert mg.cache.rows.shape[1] == g.dim
    assert not set(k1[k1 < g.rows]) & set(k2[k2 < g.rows])


def test_wide_narrow_wide_round_trip(mesh1, axes):
    """Strategy-driven width transitions across revisions: a wide group is
    narrowed (rows projected down through the fresh deterministic kernel)
    and re-widened (projected back up); tier-resident ids travel full-width
    in the tiers and come back exactly; FCounter and adagrad survive the
    whole trip."""
    cfg, plan, _, state, step = _setup(mesh1, axes, strategy="picasso_l2")
    gid = plan.groups[0].gid
    g = plan.group(gid)
    # the budget is recorded but gated off under picasso_l2
    assert state["emb"][str(gid)].w.shape == (g.rows, g.dim)
    assert state["emb"][str(gid)].proj is None
    state = _train(state, step, mesh1, axes, cfg, 7)
    st = state["emb"][str(gid)]
    counts = np.asarray(jax.device_get(st.counts))
    acc_exp = np.array(jax.device_get(st.acc))
    w_exp = np.array(jax.device_get(st.w))
    for tier in (st.cache, st.l2):
        keys = np.asarray(jax.device_get(tier.keys))
        mine = keys < g.rows
        w_exp[keys[mine]] = np.asarray(jax.device_get(tier.rows))[mine]
        acc_exp[keys[mine]] = np.asarray(jax.device_get(tier.acc))[mine]
    live1 = np.asarray(jax.device_get(st.cache.keys))
    live1 = live1[live1 < g.rows]

    # ---- narrow: rev 1 assigns picasso_narrow ----------------------------
    p2 = revise_plan(plan)
    p2.cache_rows, p2.l2_rows = dict(plan.cache_rows), dict(plan.l2_rows)
    apply_assignment(p2, resolve_assignment(p2, "picasso_narrow"))
    delta = plan_delta(plan, p2)
    assert f"narrow {g.dim}->{ND}" in delta[gid]
    s2 = migrate_state(plan, p2, state)
    st2 = s2["emb"][str(gid)]
    assert st2.w.shape == (g.rows, ND) and st2.proj is not None
    np.testing.assert_array_equal(np.asarray(st2.counts), counts)
    np.testing.assert_array_equal(np.asarray(st2.acc), acc_exp)
    # tiers hold the exact wide rows for the ids they kept
    k1 = np.asarray(st2.cache.keys)
    np.testing.assert_array_equal(
        np.asarray(st2.cache.rows)[k1 < g.rows], w_exp[k1[k1 < g.rows]])
    k2 = np.asarray(st2.l2.keys)
    tier2 = np.concatenate([k1[k1 < g.rows], k2[k2 < g.rows]])
    # rev-0 hot ids that stayed tier-resident through the narrow revision
    survivors = np.intersect1d(live1, tier2)
    assert survivors.size  # the head of the skew does stay resident

    # ---- widen back: rev 2 returns to picasso_l2 -------------------------
    p3 = revise_plan(p2)
    p3.cache_rows, p3.l2_rows = dict(p2.cache_rows), dict(p2.l2_rows)
    apply_assignment(p3, resolve_assignment(p3, "picasso_l2"))
    assert f"narrow {ND}->{g.dim}" in plan_delta(p2, p3)[gid]
    s3 = migrate_state(p2, p3, s2)
    st3 = s3["emb"][str(gid)]
    assert st3.w.shape == (g.rows, g.dim) and st3.proj is None
    np.testing.assert_array_equal(np.asarray(st3.counts), counts)
    np.testing.assert_array_equal(np.asarray(st3.acc), acc_exp)
    assert np.isfinite(np.asarray(st3.w)).all()
    # ids that stayed tier-resident across both hops round-trip exactly:
    # the tiers carried their full-width rows, no projection loss
    np.testing.assert_array_equal(np.asarray(st3.w)[survivors],
                                  w_exp[survivors])


# ------------------------------------------------------- revision plumbing


def test_plan_meta_roundtrips_narrow_dim():
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, narrow_dim=ND,
                     **PLAN_KW)
    apply_assignment(plan, resolve_assignment(plan, "picasso_narrow"))
    meta = json.loads(json.dumps(plan_meta(plan)))  # survives JSON
    seed = make_plan(cfg, world=1, per_device_batch=GB, **PLAN_KW)
    gid = plan.groups[0].gid
    assert seed.narrow_width(gid) == plan.group(gid).dim
    planR = apply_plan_meta(seed, meta)
    assert planR.narrow_dim == plan.narrow_dim
    assert planR.strategy == plan.strategy
    assert planR.narrow_width(gid) == ND
    # legacy meta without the key keeps the structural plan's budget
    legacy = {k: v for k, v in meta.items() if k != "narrow_dim"}
    planL = apply_plan_meta(make_plan(cfg, world=1, per_device_batch=GB,
                                      narrow_dim=ND, **PLAN_KW), legacy)
    assert planL.narrow_dim == plan.narrow_dim


def test_engine_rejects_non_narrow_assignment_on_narrow_plan(mesh1, axes):
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, narrow_dim=ND,
                     **PLAN_KW)
    apply_assignment(plan, resolve_assignment(plan, "picasso_narrow"))
    with pytest.raises(ValueError, match="picasso_narrow"):
        EmbeddingEngine(plan, ("data", "model"), 1,
                        strategy={g.gid: "picasso" for g in plan.groups})
