"""Measured cost model (repro.perf): curve fits, calibration-file lifecycle,
constants-parity when off, calibrated decision flips, and the Replanner's
online measured-vs-predicted correction loop."""
import json

import numpy as np
import pytest

from repro.configs.base import FeatureField, InteractionSpec, WDLConfig
from repro.core import assign
from repro.core.assign import compile_assignment, estimate_l2_gain, estimate_skew
from repro.core.packing import make_plan
from repro.perf import (CORRECTION_BOUNDS, PRICED_OPS, CostCurve, CostModel,
                        backend_stamp, fit_cost_model, get_cost_model,
                        load_calibration, load_samples, run_calibration,
                        save_calibration, synthetic_cost_model)


def _cfg(fields):
    return WDLConfig(name="t", fields=tuple(fields), n_dense=0,
                     interactions=(InteractionSpec("fm"),), mlp_dims=(8,))


def _mixed_plan(world=1, per_device_batch=16, **kw):
    """Same fixture shape as tests/test_assign.py: one tiny replicable group
    (dim 8) + one large budgeted group (dim 16)."""
    fields = [FeatureField("tiny", 64, 8, max_len=1, pooling="sum"),
              FeatureField("big", 50_000, 16, max_len=1, pooling="sum")]
    kw.setdefault("hot_bytes", 1 << 14)
    return make_plan(_cfg(fields), world=world,
                     per_device_batch=per_device_batch, **kw)


def _synth_samples(per_elem=1e-3, fixed=1.0):
    return {op: [(1.0, fixed + per_elem), (1e6, fixed + per_elem * 1e6)]
            for op in PRICED_OPS}


# ----------------------------------------------------------------- curves


def test_curve_fit_is_monotone_even_on_noisy_samples():
    # measured: bigger work came out CHEAPER at one grid point (jit noise)
    c = CostCurve.fit([(100, 50.0), (200, 30.0), (400, 80.0)])
    xs = np.linspace(0, 1000, 200)
    ys = np.array([c(x) for x in xs])
    assert np.all(np.diff(ys) >= -1e-12)          # monotone everywhere
    assert c(200) >= c(100)                        # the noisy dip is repaired
    # duplicate work sizes collapse to their median
    d = CostCurve.fit([(10, 1.0), (10, 100.0), (10, 3.0)])
    assert d(10) == pytest.approx(3.0)


def test_curve_clamps_left_and_extrapolates_right():
    c = CostCurve.fit([(100, 10.0), (200, 30.0)])
    assert c(1) == pytest.approx(10.0)             # launch-overhead floor
    assert c(0) == pytest.approx(10.0)
    assert c(300) == pytest.approx(50.0)           # last-segment slope
    one = CostCurve.fit([(100, 10.0)])             # degenerate single point
    assert one(5) == one(100) == one(1e9) == pytest.approx(10.0)


def test_curve_json_round_trip():
    c = CostCurve.fit([(100, 10.0), (200, 30.0), (400, 31.0)])
    c2 = CostCurve.from_json(json.loads(json.dumps(c.to_json())))
    for x in (0, 50, 150, 350, 1e4):
        assert c2(x) == pytest.approx(c(x))


def test_scores_monotone_in_rows_and_dim():
    m = synthetic_cost_model()
    base = m.score_candidates(world=4, n=256, d=16, skew=0.3,
                              l2_rows=100, l2_gain=0.2,
                              narrow_dim=4, narrow_gain=0.5)
    more_n = m.score_candidates(world=4, n=512, d=16, skew=0.3,
                                l2_rows=100, l2_gain=0.2,
                                narrow_dim=4, narrow_gain=0.5)
    more_d = m.score_candidates(world=4, n=256, d=32, skew=0.3,
                                l2_rows=100, l2_gain=0.2,
                                narrow_dim=4, narrow_gain=0.5)
    assert set(base) == {"ps", "hybrid", "picasso", "picasso_l2",
                         "picasso_narrow"}
    for k in base:
        assert more_n[k] >= base[k], k            # more ids never cheaper
        assert more_d[k] >= base[k], k            # wider rows never cheaper


def test_model_requires_every_priced_op():
    curves = {op: CostCurve.fit([(1, 1.0)]) for op in PRICED_OPS[:-1]}
    with pytest.raises(ValueError, match="missing curves"):
        CostModel(curves=curves)


# ---------------------------------------------------------- file lifecycle


def test_calibration_file_round_trip(tmp_path):
    samples = _synth_samples()
    model = fit_cost_model(samples, hit_prior=0.31)
    p = tmp_path / "calib.json"
    save_calibration(p, samples, model)
    loaded = load_calibration(p)
    assert loaded is not None
    assert loaded.backend == backend_stamp()["backend"]
    assert loaded.hit_prior == pytest.approx(0.31)
    for op in PRICED_OPS:
        for x in (1.0, 123.0, 5e5, 2e6):
            assert loaded.op_us(op, x) == pytest.approx(model.op_us(op, x))
    # raw samples persist next to the fit (residual reporting)
    assert load_samples(p) == {op: [(x, y) for x, y in pts]
                               for op, pts in samples.items()}


def test_backend_stamp_mismatch_forces_refit(tmp_path, monkeypatch):
    samples = _synth_samples()
    p = tmp_path / "calib.json"
    save_calibration(p, samples, fit_cost_model(samples))
    data = json.loads(p.read_text())
    data["backend"] = "tpu-v99"                   # calibrated elsewhere
    p.write_text(json.dumps(data))
    assert load_calibration(p) is None            # stale stamp -> no reuse

    # get_cost_model('auto') must therefore re-bench and overwrite the file
    calls = {"n": 0}

    def fake_run(grid="small", log=None):
        calls["n"] += 1
        return _synth_samples()
    monkeypatch.setattr("repro.perf.calibration.run_calibration", fake_run)
    m = get_cost_model("auto", p, grid="tiny")
    assert calls["n"] == 1 and m is not None
    assert load_calibration(p) is not None        # re-stamped for us
    # ... and with a valid file, 'auto' loads without re-benching
    m2 = get_cost_model("auto", p, grid="tiny")
    assert calls["n"] == 1 and m2 is not None
    # 'force' always re-benches
    get_cost_model("force", p, grid="tiny")
    assert calls["n"] == 2
    assert get_cost_model("off", p) is None


def test_corrupt_calibration_file_is_ignored(tmp_path):
    p = tmp_path / "calib.json"
    p.write_text("{not json")
    assert load_calibration(p) is None
    assert load_samples(p) is None


def test_real_calibration_tiny_grid_fits_all_ops(tmp_path):
    """One real microbench pass on the tiny grid: every priced op gets a
    positive, finite, monotone curve and the file round-trips."""
    samples = run_calibration("tiny")
    assert set(samples) == set(PRICED_OPS)
    model = fit_cost_model(samples)
    p = tmp_path / "calib.json"
    save_calibration(p, samples, model)
    loaded = load_calibration(p)
    for op in PRICED_OPS:
        lo, hi = loaded.op_us(op, 1.0), loaded.op_us(op, 1e8)
        assert 0.0 < lo <= hi < 1e12


# ------------------------------------------------- assignment integration


def test_cost_model_off_is_bitwise_constants_assignment():
    """cost_model=None must be byte-for-byte today's constant model: same
    picks, same scores, same formulas."""
    plan = _mixed_plan()
    asg = compile_assignment(plan, cost_model=None)
    base = compile_assignment(plan)
    assert asg.strategy == base.strategy
    for gid, s in asg.scores.items():
        b = base.scores[gid]
        assert s.units == b.units == "elems"
        assert s.costs == b.costs
        g = plan.group(gid)
        n, d = float(max(s.ids_per_shard, 1)), float(g.dim)
        # the constants formulas, verbatim
        assert s.costs["ps"] == pytest.approx(1 * n * (d + 1.0))
        assert s.costs["hybrid"] == pytest.approx(
            2.0 * n * (1.0 + d) + assign.ROUTE_OVERHEAD_ELEMS)
        assert s.costs["picasso"] == pytest.approx(
            2.0 * n * (1.0 - s.skew) * (1.0 + d)
            + assign.ROUTE_OVERHEAD_ELEMS)


def test_synthetic_calibration_flips_a_known_groups_strategy():
    """The fixture's tiny group is 'ps' under constants; a calibration where
    the all_gather wire is measured catastrophically slow must flip it off
    the PS path — decisions now come from the curves."""
    plan = _mixed_plan()
    tiny_gid = next(g.gid for g in plan.groups
                    if g.tables[0].name == "tiny")
    base = compile_assignment(plan)
    assert base.strategy[tiny_gid] == "ps"
    slow_ag = synthetic_cost_model({"wire_ag": 1e3})
    asg = compile_assignment(plan, cost_model=slow_ag)
    assert asg.scores[tiny_gid].units == "us"
    assert asg.scores[tiny_gid].costs["ps"] > asg.scores[tiny_gid].costs["hybrid"]
    assert asg.strategy[tiny_gid] != "ps"
    # and a model where routing dispatch is the expensive part keeps ps
    slow_route = synthetic_cost_model({"wire_a2a": 1e3})
    asg2 = compile_assignment(plan, cost_model=slow_route)
    assert asg2.strategy[tiny_gid] == "ps"


def test_hit_prior_threads_through_estimators():
    plan = _mixed_plan()
    big = next(g for g in plan.groups if g.tables[0].name == "big")
    cache_rows = plan.cache_rows[big.gid]
    assert 0 < cache_rows < big.rows
    m = synthetic_cost_model(hit_prior=0.37)
    assert estimate_skew(big, cache_rows) == pytest.approx(
        assign.DEFAULT_HIT_RATIO)
    assert estimate_skew(big, cache_rows, cost_model=m) == pytest.approx(0.37)
    # the L2 prior branch scales by the same measured prior
    l2 = estimate_l2_gain(big, cache_rows, cache_rows, cost_model=m)
    assert l2 == pytest.approx((1.0 - 0.37) * 0.37 * 1.0)


def test_predict_step_prices_the_recorded_strategy():
    plan = _mixed_plan(l2_bytes=1 << 15)
    m = synthetic_cost_model()
    asg = compile_assignment(plan, cost_model=m)
    plan.strategy = dict(asg.strategy)
    total = m.predict_step_us(plan)
    assert total > 0.0
    # doubling the correction doubles the (uniformly scaled) prediction
    m.correction = 2.0
    assert m.predict_step_us(plan) == pytest.approx(2.0 * total)


# ------------------------------------------------------- online correction


def test_correction_converges_on_synthetic_misprediction():
    """The hardware is consistently 3x slower than calibration says: the
    geometric EMA must converge to corr ~= 3 and the corrected prediction
    to the measurement."""
    m = synthetic_cost_model()
    base = m.score_candidates(world=1, n=1024, d=16)["picasso"] / m.correction
    measured = 3.0 * base
    for _ in range(40):
        predicted = base * m.correction
        m.observe_measured(measured, predicted)
    assert m.correction == pytest.approx(3.0, rel=0.02)
    assert base * m.correction == pytest.approx(measured, rel=0.02)
    # degenerate inputs are ignored, bounds are enforced
    c = m.correction
    assert m.observe_measured(0.0, 100.0) == c
    assert m.observe_measured(100.0, 0.0) == c
    for _ in range(300):
        m.observe_measured(1e12, 1.0)
    assert m.correction == CORRECTION_BOUNDS[1]


def test_replanner_feedback_end_to_end(mesh1, axes):
    """Replanner + calibrated model on a real (tiny) train loop: step
    timings observed, prediction made from harvested stats, correction
    blended and reported on the ReplanEvent."""
    import jax

    from repro.configs import get_config
    from repro.data.synthetic import batch_stream
    from repro.dist.sharding import batch_specs, to_named
    from repro.models.wdl import WDLModel
    from repro.runtime import Replanner
    from repro.train.train_step import TrainConfig, init_state, make_train_step

    gb = 32
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=gb, hot_bytes=1 << 14,
                     flush_iters=5, warmup_iters=2)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1,
                       axes=axes)
    step, _ = make_train_step(model, plan, mesh1, axes, gb,
                              TrainConfig(strategy="auto"))
    cm = synthetic_cost_model()
    rp = Replanner(plan, mesh1, axes, strategy="auto", cost_model=cm)
    stream = batch_stream(cfg, gb, seed=1)
    for _ in range(4):
        raw = next(stream)
        batch = jax.device_put(raw, to_named(mesh1, batch_specs(raw, axes)))
        state, m = step(state, batch)
        rp.observe(m)
        rp.observe_timing(5_000.0)               # 5ms measured walls
    out = rp.maybe_replan(state, step=4)
    if out is not None:                           # migration may or may not fire
        _, state = out
    ev = rp.events[-1]
    assert ev.measured_us == pytest.approx(5_000.0)
    assert ev.predicted_us is not None and ev.predicted_us > 0.0
    assert ev.correction is not None
    assert cm.correction == ev.correction != 1.0
    assert "corr=" in ev.describe()
    # the blend moved toward the measurement: corrected prediction for the
    # same window sits between the raw prediction and the measured wall
    raw_pred = ev.predicted_us
    corrected = raw_pred * ev.correction / 1.0    # corr started at 1.0
    lo, hi = sorted((raw_pred, ev.measured_us))
    assert lo <= corrected <= hi
    # a window with no timings leaves the correction untouched (None fields)
    rp.maybe_replan(state, step=8)
    ev2 = rp.events[-1]
    assert ev2.correction is None and cm.correction == ev.correction


# --------------------------------------------------- memory-kind shardings


def test_pin_l2_shardings_inert_without_host_memory():
    """On backends without a pinned_host space (the CPU rig) the pin-aware
    builders must be bit-identical to the plain ones, and the capability
    probe must say so."""
    from repro.dist.sharding import (emb_shardings, emb_specs,
                                     host_memory_kind, to_named)
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(1, 1)
    plan = _mixed_plan(l2_bytes=1 << 15)
    axes = ("data", "model")
    if host_memory_kind() is None:
        assert emb_shardings(plan, mesh, axes, pin_l2=True) == \
            to_named(mesh, emb_specs(plan, axes))
    else:  # a real host memory space: L2 leaves must carry it
        pinned = emb_shardings(plan, mesh, axes, pin_l2=True)
        for g in plan.groups:
            st = pinned[str(g.gid)]
            if st.l2 is not None:
                assert st.l2.rows.memory_kind == host_memory_kind()
    assert emb_shardings(plan, mesh, axes, pin_l2=False) == \
        to_named(mesh, emb_specs(plan, axes))
