"""Per-arch smoke tests: every assigned architecture instantiates its REDUCED
config and runs one forward/train step on CPU, asserting output shapes and
finite values. (Full configs are exercised only by the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_shapes, list_archs
from repro.core.packing import make_plan
from repro.data.synthetic import make_batch
from repro.dist.sharding import batch_specs, to_named
from repro.models.wdl import WDLModel
from repro.train.train_step import TrainConfig, init_state, make_train_step

RECSYS = ["deepfm", "dcn-v2", "sasrec", "mind"]
LMS = ["phi3.5-moe-42b-a6.6b", "mixtral-8x22b", "stablelm-1.6b",
       "mistral-nemo-12b", "yi-34b"]


def test_all_archs_registered():
    assert len(list_archs()) == 10
    # 40 declared cells; sub-quadratic skips are annotated, not silent
    total = sum(len(get_shapes(a, include_skipped=True)) for a in list_archs())
    assert total == 40


@pytest.mark.parametrize("arch", RECSYS)
def test_recsys_train_smoke(arch, mesh1, axes):
    gb = 8
    cfg = get_config(arch, smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=gb, hot_bytes=1 << 12,
                     flush_iters=2, warmup_iters=1)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1, axes=axes)
    step, _ = make_train_step(model, plan, mesh1, axes, gb, TrainConfig())
    batch = make_batch(cfg, gb, np.random.default_rng(0))
    batch = jax.device_put(batch, to_named(mesh1, batch_specs(batch, axes)))
    for _ in range(3):
        state, m = step(state, batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert int(m["step"]) == 3


@pytest.mark.parametrize("arch", RECSYS)
def test_recsys_serve_smoke(arch, mesh1, axes):
    from repro.serve.serve_step import make_serve_step
    gb = 8
    cfg = get_config(arch, smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=gb, enable_cache=False)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1, axes=axes)
    serve = make_serve_step(model, plan, mesh1, axes, gb)
    batch = make_batch(cfg, gb, np.random.default_rng(1))
    batch = jax.device_put(batch, to_named(mesh1, batch_specs(batch, axes)))
    probs = serve(state, batch)
    assert probs.shape == (gb, cfg.n_tasks)
    assert bool(jnp.all((probs >= 0) & (probs <= 1)))


@pytest.mark.parametrize("arch", LMS)
def test_lm_train_smoke(arch):
    from repro.layers.transformer import init_lm_params, lm_loss
    cfg = get_config(arch, smoke=True)
    p = init_lm_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    loss, g = jax.jit(jax.value_and_grad(
        lambda p: lm_loss(cfg, p, toks, attn_chunk=8)))(p)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
                for x in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", LMS)
def test_lm_decode_smoke(arch):
    from repro.layers.transformer import (init_kv_cache, init_lm_params,
                                          lm_decode_step, lm_prefill)
    cfg = get_config(arch, smoke=True)
    p = init_lm_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits, cache = jax.jit(lambda p, t: lm_prefill(cfg, p, t, 8))(p, toks)
    assert logits.shape == (2, cfg.vocab)
    cache2 = init_kv_cache(cfg, 2, 16)
    cache2 = jax.tree.map(lambda c, n: c.at[:, :, :8].set(n), cache2, cache)
    lg, cache3 = jax.jit(lambda p, c, t, l: lm_decode_step(cfg, p, c, t, l))(
        p, cache2, toks[:, -1:], jnp.int32(8))
    assert lg.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))


def test_schnet_smoke():
    from repro.data.graph import synthetic_graph
    from repro.models.schnet import init_schnet, schnet_forward, schnet_loss
    cfg = get_config("schnet", smoke=True)
    g = synthetic_graph(100, 400, d_feat=16, seed=0)
    p = init_schnet(cfg, jax.random.PRNGKey(0), d_feat=16)
    e = schnet_forward(cfg, p, jnp.asarray(g["nodes"]), jnp.asarray(g["src"]),
                       jnp.asarray(g["dst"]), jnp.asarray(g["dist"]),
                       jnp.ones(400))
    assert e.shape == (100,)
    batch = {k: jnp.asarray(v) for k, v in g.items() if k not in ("indptr", "indices")}
    batch["edge_w"] = jnp.ones(400)
    loss, grads = jax.value_and_grad(lambda p: schnet_loss(cfg, p, batch))(p)
    assert bool(jnp.isfinite(loss))


def test_schnet_molecule_batch():
    from repro.data.graph import molecule_batch
    from repro.models.schnet import init_schnet, schnet_loss
    cfg = get_config("schnet", smoke=True)
    b = molecule_batch(4, 6, 10)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    p = init_schnet(cfg, jax.random.PRNGKey(0), d_feat=0)
    loss = schnet_loss(cfg, p, batch)
    assert bool(jnp.isfinite(loss))


def test_paper_models_smoke(mesh1, axes):
    """The paper's own models (W&D / DLRM / DIN / MMoE / CAN) train a step."""
    from repro.configs.paper_models import PAPER_MODELS
    gb = 4
    for name, builder in PAPER_MODELS.items():
        cfg = builder(scale=0.01)
        plan = make_plan(cfg, world=1, per_device_batch=gb, enable_cache=False)
        model = WDLModel(cfg, plan)
        state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1, axes=axes)
        step, _ = make_train_step(model, plan, mesh1, axes, gb,
                                  TrainConfig(use_cache=False))
        batch = make_batch(cfg, gb, np.random.default_rng(2))
        batch = jax.device_put(batch, to_named(mesh1, batch_specs(batch, axes)))
        state, m = step(state, batch)
        assert bool(jnp.isfinite(m["loss"])), name
