"""L2 host-memory cache tier (picasso_l2): planning, probe order, hit/miss/
write-back correctness, bitwise parity with plain picasso when the tier is
disabled or cold, two-tier flush (psum + stale), the cost-model routing that
sends L1-overflowing groups to the tier, and end-to-end train/serve with the
per-tier metric breakdown."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.engine as E
from repro.configs import get_config
from repro.configs.base import FeatureField, InteractionSpec, WDLConfig
from repro.core import packed_embedding as pe
from repro.core.features import pack_group
from repro.core.packing import make_plan
from repro.data.synthetic import make_batch
from repro.dist.compat import shard_map
from repro.dist.sharding import batch_specs, emb_specs, replicated, to_named
from repro.embedding.state import EmbeddingState, init_embedding_state
from repro.engine import (EmbeddingEngine, PicassoL2Strategy, PicassoStrategy,
                          PSStrategy, available_strategies,
                          compile_assignment, estimate_l2_gain, get_strategy)

AXES = ("data", "model")
GB = 16


def _cfg64():
    """One 64-row dim-4 table: hot tier 8 rows, L2 sized by l2_bytes."""
    return WDLConfig(name="l2", fields=(FeatureField("a", 64, 4),), n_dense=0,
                     interactions=(InteractionSpec("fm"),), mlp_dims=(8,))


def _mixed_cfg():
    """Tiny (ps) + big (cacheable) groups, as in test_strategies."""
    fields = (FeatureField("tiny", 64, 8, max_len=1, pooling="sum"),
              FeatureField("big", 50_000, 16, max_len=1, pooling="sum"))
    return WDLConfig(name="mixl2", fields=fields, n_dense=0,
                     interactions=(InteractionSpec("fm"),), mlp_dims=(8,))


# ----------------------------------------------------------- registry/plan
def test_registry_and_package_exports():
    assert "picasso_l2" in available_strategies()
    assert get_strategy("picasso_l2") is PicassoL2Strategy
    assert PicassoL2Strategy.uses_cache and PicassoL2Strategy.uses_l2
    assert PicassoL2Strategy.extra_metric_keys == ("cache_hits/l1",
                                                   "cache_hits/l2")
    assert not PicassoStrategy.uses_l2
    # repro.engine re-exports the full launcher surface from one place
    for name in ("AUTO_NAMES", "available_strategies", "maybe_compile",
                 "compile_assignment", "PicassoL2Strategy", "EmbeddingEngine"):
        assert name in E.__all__ and hasattr(E, name)


def test_plan_l2_budget_sits_behind_hot_tier():
    plan = make_plan(_cfg64(), world=1, per_device_batch=GB,
                     hot_bytes=1 << 14, l2_bytes=320)
    (gid,) = [g.gid for g in plan.groups]
    assert plan.cache_rows[gid] == 8
    assert plan.l2_rows[gid] == 16          # 320 B / ((4+1)*4 B/row)
    # no budget -> no tier (and the state keeps the legacy pytree structure)
    assert make_plan(_cfg64(), 1, GB, hot_bytes=1 << 14).l2_rows[gid] == 0
    # L2 is strictly behind L1: no hot tier, no L2 either
    flat = make_plan(_cfg64(), 1, GB, enable_cache=False, l2_bytes=1 << 20)
    assert flat.l2_rows[gid] == 0
    # an over-generous budget cannot overlap the L1 rows
    big = make_plan(_cfg64(), 1, GB, hot_bytes=1 << 14, l2_bytes=1 << 20)
    assert big.cache_rows[gid] + big.l2_rows[gid] <= 64


def test_state_structure_with_and_without_l2():
    plan_l2 = make_plan(_cfg64(), 1, GB, hot_bytes=1 << 14, l2_bytes=320)
    plan_no = make_plan(_cfg64(), 1, GB, hot_bytes=1 << 14)
    (gid,) = [g.gid for g in plan_l2.groups]
    st = init_embedding_state(jax.random.PRNGKey(0), plan_l2)[gid]
    assert st.l2 is not None and st.l2.keys.shape == (16,)
    assert st.l2.rows.shape == (16, 4)
    st0 = init_embedding_state(jax.random.PRNGKey(0), plan_no)[gid]
    assert st0.l2 is None
    # None collapses: unbudgeted states keep the pre-L2 leaf count
    assert len(jax.tree.leaves(st0)) == 6
    assert len(jax.tree.leaves(st)) == 9
    # specs mirror the state structure leaf-for-leaf (shard_map requires it)
    from jax.sharding import PartitionSpec as P
    is_spec = lambda x: isinstance(x, P)  # noqa: E731
    assert len(jax.tree.leaves(emb_specs(plan_l2, AXES)[str(gid)],
                               is_leaf=is_spec)) == 9
    assert len(jax.tree.leaves(emb_specs(plan_no, AXES)[str(gid)],
                               is_leaf=is_spec)) == 6


# ------------------------------------------------------------- probe order
def test_l2_lookup_tier_provenance(mesh1):
    """L1 hits come from the hot tier, L1-misses that hit L2 come from the
    host tier, the rest from the sharded table — with disjoint masks."""
    rng = np.random.default_rng(7)
    v, d = 32, 4
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    s = 32  # sentinel = rows_padded
    l1_keys = jnp.asarray(np.array([2, 5, 9, s, s, s, s, s], np.int32))
    l1_rows = jnp.where((l1_keys < v)[:, None],
                        jnp.full((8, d), 100.0), 0.0).astype(jnp.float32)
    l2_keys = jnp.asarray(np.array([0, 1, 3, 4, 12, 13, s, s], np.int32))
    l2_rows = jnp.where((l2_keys < v)[:, None],
                        jnp.full((8, d), 200.0), 0.0).astype(jnp.float32)
    ids = jnp.asarray(np.array([2, 0, 12, 20, 5, 21, 3, 2], np.int32))
    strat = PicassoL2Strategy(axes=AXES, world=1, capacity={0: ids.shape[0]})

    def f(tsh, ids_l):
        st = EmbeddingState(
            w=tsh, acc=jnp.zeros((v, 1)), counts=jnp.zeros((v,), jnp.int32),
            cache=pe.CacheState(l1_keys, l1_rows, jnp.zeros((8, 1))),
            l2=pe.CacheState(l2_keys, l2_rows, jnp.zeros((8, 1))))
        rows_u, ctx = strat.lookup(st, 0, ids_l, cache_on=True, l2_on=True)
        per_id = jnp.take(rows_u, ctx.inv, axis=0)
        n_l1 = jnp.sum(ctx.hit)
        n_l2 = jnp.sum(ctx.l2_hit)
        overlap = jnp.sum(ctx.hit & ctx.l2_hit)
        return per_id, n_l1, n_l2, overlap

    from jax.sharding import PartitionSpec as P
    per_id, n_l1, n_l2, overlap = jax.jit(shard_map(
        f, mesh=mesh1, in_specs=(P(AXES, None), P()),
        out_specs=(P(), P(), P(), P()), check_vma=False))(table, ids)
    per_id = np.asarray(per_id)
    exp = {2: 100.0, 5: 100.0, 0: 200.0, 3: 200.0, 12: 200.0}
    for i, idv in enumerate(np.asarray(ids)):
        if int(idv) in exp:
            np.testing.assert_allclose(per_id[i], exp[int(idv)])
        else:  # 20, 21: miss both tiers -> real table row via the Shuffle
            np.testing.assert_allclose(per_id[i], np.asarray(table)[int(idv)],
                                       atol=1e-6)
    assert int(n_l1) == 2       # uniques {2, 5}
    assert int(n_l2) == 3       # uniques {0, 3, 12}
    assert int(overlap) == 0    # tiers never serve the same id


# ----------------------------------------------------------------- parity
def _roundtrip(mesh, strategy, *, l2_bytes=0, use_l2=True, use_cache=True):
    """forward + backward of one synthetic batch through the bare engine."""
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, hot_bytes=1 << 14,
                     l2_bytes=l2_bytes, exact_capacity=True)
    emb0 = {str(g): s for g, s in
            init_embedding_state(jax.random.PRNGKey(0), plan).items()}
    batch = make_batch(cfg, GB, np.random.default_rng(3))
    fields = jax.tree.map(jnp.asarray, batch["fields"])
    engine = EmbeddingEngine(plan, AXES, 1, strategy=strategy,
                             use_cache=use_cache, use_l2=use_l2, lr_emb=0.1)
    especs = emb_specs(plan, AXES)

    def f(emb, fields):
        packed = {g.gid: pack_group(g, fields) for g in plan.groups}
        pooled, ctx = engine.forward(emb, packed)
        emb2, _m = engine.backward(emb, ctx, pooled)
        return pooled, emb2

    pooled_specs = {g.gid: jax.sharding.PartitionSpec(AXES, None, None)
                    for g in plan.groups}
    g = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(especs, replicated(fields)),
        out_specs=(pooled_specs, especs), check_vma=False))
    pooled, emb2 = g(emb0, fields)
    tables = {k: np.asarray(v.w) for k, v in emb2.items()}
    return jax.tree.map(np.asarray, pooled), tables


def test_l2_cold_or_disabled_is_bitwise_picasso(mesh1):
    """Acceptance: picasso_l2 with a cold L2 tier — and with the tier
    disabled (use_l2=False / no budget) — produces pooled outputs and
    post-update tables bitwise identical to plain picasso."""
    ref_pooled, ref_tables = _roundtrip(mesh1, "picasso")
    for kw in (dict(l2_bytes=1 << 16),               # budgeted, cold tier
               dict(l2_bytes=1 << 16, use_l2=False),  # tier switched off
               dict(l2_bytes=0)):                     # no budget at all
        pooled, tables = _roundtrip(mesh1, "picasso_l2", **kw)
        for gid in ref_pooled:
            np.testing.assert_array_equal(pooled[gid], ref_pooled[gid],
                                          err_msg=f"pooled/{gid}/{kw}")
        for k in ref_tables:
            np.testing.assert_array_equal(tables[k], ref_tables[k],
                                          err_msg=f"table/{k}/{kw}")


# ------------------------------------------------------- backward / tiers
def test_l2_psum_hit_grads_update_tier_not_master(mesh1):
    """'psum' mode: grads of L2-served ids are adagrad-applied to the L2
    tier (authoritative between flushes); the master rows stay untouched."""
    rng = np.random.default_rng(11)
    v, d, n = 32, 4, 8
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    s = 32
    l2_keys = jnp.asarray(np.array([4, 7, s, s, s, s, s, s], np.int32))
    l2_rows0 = jnp.asarray(rng.normal(size=(8, d)).astype(np.float32))
    ids = jnp.asarray(np.array([4, 7, 4, 20, 21, 22, 23, 19], np.int32))
    g_per_id = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    strat = PicassoL2Strategy(axes=AXES, world=1, capacity={0: n}, lr=0.1)

    def f(tsh, ids_l, g):
        st = EmbeddingState(
            w=tsh, acc=jnp.zeros((v, 1)), counts=jnp.zeros((v,), jnp.int32),
            cache=pe.init_cache(4, d, v),
            l2=pe.CacheState(l2_keys, l2_rows0, jnp.zeros((8, 1))))
        rows_u, ctx = strat.lookup(st, 0, ids_l, cache_on=True, l2_on=True)
        g_u = jax.ops.segment_sum(g, ctx.inv, num_segments=n)
        st2, _, hits = strat.apply_grads(st, 0, ctx, g_u, cache_on=True,
                                         l2_on=True)
        return st2.w, st2.l2.rows, st2.l2.acc, hits, st2.counts

    from jax.sharding import PartitionSpec as P
    w2, l2r, l2a, hits, counts = jax.jit(shard_map(
        f, mesh=mesh1, in_specs=(P(AXES, None), P(), P()),
        out_specs=(P(AXES, None), P(), P(), P(), P(AXES)), check_vma=False))(
            table, ids, g_per_id)
    assert int(hits) == 2  # uniques {4, 7} served by L2
    # tier-served ids feed the FCounter too (anti-churn): one count each at
    # world=1, alongside the routed-miss counts
    counts = np.asarray(counts)
    assert counts[4] == 1 and counts[7] == 1
    assert counts[20] == 1  # routed miss counted on the owner as before
    w2, l2r, l2a = np.asarray(w2), np.asarray(l2r), np.asarray(l2a)
    # master rows 4 and 7 untouched (the tier owns them between flushes)
    np.testing.assert_array_equal(w2[4], np.asarray(table)[4])
    np.testing.assert_array_equal(w2[7], np.asarray(table)[7])
    # tier slots 0 (id 4) and 1 (id 7) moved by row-wise adagrad
    gnp = np.asarray(g_per_id)
    idnp = np.asarray(ids)
    for slot, idv in ((0, 4), (1, 7)):
        gsum = gnp[idnp == idv].sum(0)
        acc = (gsum ** 2).mean(keepdims=True)
        exp = np.asarray(l2_rows0)[slot] - 0.1 * gsum / np.sqrt(acc + 1e-8)
        np.testing.assert_allclose(l2r[slot], exp, atol=1e-5)
        np.testing.assert_allclose(l2a[slot], acc, atol=1e-6)
    # untouched tier slots stay put
    np.testing.assert_array_equal(l2r[2:], np.asarray(l2_rows0)[2:])
    # miss ids updated the master as usual
    assert not np.allclose(w2[20], np.asarray(table)[20])


# ------------------------------------------------------------------ flush
def _two_tier_state(plan, gid):
    """Markers: L1 = rows 0..7 @777, L2 = rows 8..23 @888, counts make
    rows 40..63 the hottest (63 hottest)."""
    st = init_embedding_state(jax.random.PRNGKey(1), plan)[gid]
    h1, h2 = plan.cache_rows[gid], plan.l2_rows[gid]
    assert (h1, h2) == (8, 16)
    return EmbeddingState(
        w=st.w, acc=st.acc,
        counts=jnp.arange(64, dtype=jnp.int32),
        cache=pe.CacheState(keys=jnp.arange(h1, dtype=jnp.int32),
                            rows=jnp.full((h1, 4), 777.0),
                            acc=jnp.ones((h1, 1))),
        l2=pe.CacheState(keys=jnp.arange(h1, h1 + h2, dtype=jnp.int32),
                         rows=jnp.full((h2, 4), 888.0),
                         acc=jnp.full((h2, 1), 2.0)))


def _flush(mesh1, cache_update):
    plan = make_plan(_cfg64(), world=1, per_device_batch=GB,
                     hot_bytes=1 << 14, l2_bytes=320)
    (gid,) = [g.gid for g in plan.groups]
    st = _two_tier_state(plan, gid)
    eng = EmbeddingEngine(plan, AXES, 1, strategy="picasso_l2",
                          cache_update=cache_update)
    assert eng.l2_on[gid]
    especs = emb_specs(plan, AXES)
    out = jax.jit(shard_map(eng.flush, mesh=mesh1, in_specs=(especs,),
                            out_specs=especs, check_vma=False))(
        {str(gid): st})
    return np.asarray(st.w), out[str(gid)]


def test_two_tier_flush_psum_write_back_and_split(mesh1):
    """psum flush: both tiers written back to master, then one global
    frequency ranking refills L1 (top-8) and L2 (next-16) disjointly."""
    w0, st2 = _flush(mesh1, "psum")
    w2 = np.asarray(st2.w)
    np.testing.assert_allclose(w2[:8], 777.0)    # L1 write-back
    np.testing.assert_allclose(w2[8:24], 888.0)  # L2 write-back
    np.testing.assert_allclose(w2[24:], w0[24:], atol=1e-6)
    k1 = np.asarray(st2.cache.keys)
    k2 = np.asarray(st2.l2.keys)
    np.testing.assert_array_equal(np.sort(k1), np.arange(56, 64))  # top-8
    np.testing.assert_array_equal(np.sort(k2), np.arange(40, 56))  # next-16
    assert not set(k1) & set(k2)
    for i, k in enumerate(k1):
        np.testing.assert_allclose(np.asarray(st2.cache.rows)[i], w2[k],
                                   atol=1e-6)
    for i, k in enumerate(k2):
        np.testing.assert_allclose(np.asarray(st2.l2.rows)[i], w2[k],
                                   atol=1e-6)


def test_two_tier_flush_stale_master_stays_exact(mesh1):
    """'stale' mode: neither (read-only) tier is written back — the master
    is authoritative; both tiers are re-ranked and reloaded from it."""
    w0, st2 = _flush(mesh1, "stale")
    w2 = np.asarray(st2.w)
    np.testing.assert_allclose(w2, w0, atol=1e-6)  # no write-back at all
    np.testing.assert_array_equal(np.sort(np.asarray(st2.cache.keys)),
                                  np.arange(56, 64))
    np.testing.assert_array_equal(np.sort(np.asarray(st2.l2.keys)),
                                  np.arange(40, 56))
    for i, k in enumerate(np.asarray(st2.l2.keys)):
        np.testing.assert_allclose(np.asarray(st2.l2.rows)[i], w0[k],
                                   atol=1e-6)


def test_stale_flush_with_mixed_l1_l2_assignment(mesh1):
    """A mixed plan (ps tiny group + picasso_l2 big group), stale mode:
    flush leaves the ps group fully untouched AND the big group's master
    exact, while both of the big group's tiers are re-ranked."""
    plan = make_plan(_mixed_cfg(), world=1, per_device_batch=GB,
                     hot_bytes=1 << 14, l2_bytes=1 << 18)
    asg = compile_assignment(plan)
    by_name = {plan.group(g).tables[0].name: s for g, s in asg.strategy.items()}
    assert by_name == {"tiny": "ps", "big": "picasso_l2"}
    gid_tiny = next(g.gid for g in plan.groups if g.tables[0].name == "tiny")
    gid_big = next(g.gid for g in plan.groups if g.tables[0].name == "big")

    eng = EmbeddingEngine(plan, AXES, 1, strategy=asg, cache_update="stale")
    assert eng.l2_on == {gid_tiny: False, gid_big: True}
    emb0 = {str(g): s for g, s in
            init_embedding_state(jax.random.PRNGKey(0), plan).items()}
    # make some big-group rows hot so the re-rank has a real signal
    big = emb0[str(gid_big)]
    emb0[str(gid_big)] = big._replace(
        counts=jnp.arange(big.counts.shape[0], dtype=jnp.int32))
    before_tiny = [np.asarray(x) for x in jax.tree.leaves(emb0[str(gid_tiny)])]
    before_big_w = np.asarray(big.w)
    especs = emb_specs(plan, AXES)
    out = jax.jit(shard_map(eng.flush, mesh=mesh1, in_specs=(especs,),
                            out_specs=especs, check_vma=False))(emb0)
    for a, b in zip(before_tiny, jax.tree.leaves(out[str(gid_tiny)])):
        np.testing.assert_array_equal(a, np.asarray(b))
    big2 = out[str(gid_big)]
    np.testing.assert_allclose(np.asarray(big2.w), before_big_w, atol=1e-6)
    k1, k2 = np.asarray(big2.cache.keys), np.asarray(big2.l2.keys)
    rows = plan.group(gid_big).rows
    assert (k1 < rows).all() and (k2 < rows).all()  # both tiers warmed
    assert not set(k1.tolist()) & set(k2.tolist())


# --------------------------------------------------------------- cost model
def test_estimate_l2_gain():
    plan = make_plan(_mixed_cfg(), world=1, per_device_batch=GB,
                     hot_bytes=1 << 14, l2_bytes=1 << 18)
    g = next(gr for gr in plan.groups if gr.tables[0].name == "big")
    assert estimate_l2_gain(g, 0, 0) == 0.0
    assert estimate_l2_gain(g, 8, 0) == 0.0
    # measured stats: exact share of the [h1, h1+h2) frequency band
    counts = np.zeros(g.rows)
    counts[:4] = 100.0   # L1 band
    counts[4:8] = 10.0   # L2 band
    assert estimate_l2_gain(g, 4, 4, counts) == pytest.approx(40.0 / 440.0)
    # full coverage absorbs everything L1 misses
    assert estimate_l2_gain(g, 8, g.rows) == pytest.approx(
        1.0 - 0.2)  # 1 - DEFAULT_HIT_RATIO prior for L1


def test_auto_routes_overflowing_groups_to_l2():
    """Acceptance: on the default synthetic workload with a constricted hot
    tier and an L2 budget, 'auto' assigns at least one group to picasso_l2
    — and only budgeted groups are ever offered the candidate."""
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB,
                     hot_bytes=1 << 14, l2_bytes=1 << 22)
    asg = compile_assignment(plan)
    assert "picasso_l2" in set(asg.strategy.values())
    for gid, sc in asg.scores.items():
        if plan.l2_rows.get(gid, 0) > 0:
            assert "picasso_l2" in sc.costs
            assert sc.costs["picasso_l2"] <= sc.costs["picasso"]
        else:
            assert "picasso_l2" not in sc.costs
    # without an L2 budget the scores are exactly the PR-2 candidates
    asg0 = compile_assignment(make_plan(cfg, world=1, per_device_batch=GB,
                                        hot_bytes=1 << 14))
    assert "picasso_l2" not in set(asg0.strategy.values())
    for sc in asg0.scores.values():
        assert set(sc.costs) == {"ps", "hybrid", "picasso"}
    # the engine resolves 'auto' straight onto the tier
    eng = EmbeddingEngine(plan, AXES, 1, strategy="auto")
    assert any(eng.l2_on.values())
    assert plan.strategy == eng.assignment  # recorded for later engines


# ------------------------------------------------------------- end to end
def test_l2_trains_and_serves_with_tier_metrics(mesh1, axes):
    """picasso_l2 end-to-end: train_step warms both tiers through the
    two-tier flush, per-tier counters reconcile with the total, and
    serve_step reads through the same tiers."""
    from repro.models.wdl import WDLModel
    from repro.serve.serve_step import ServeConfig, make_serve_step
    from repro.train.train_step import TrainConfig, init_state, make_train_step

    plan = make_plan(_cfg64(), world=1, per_device_batch=GB,
                     hot_bytes=1 << 14, l2_bytes=320,
                     flush_iters=2, warmup_iters=1)
    model = WDLModel(_cfg64(), plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1,
                       axes=axes)
    step, _ = make_train_step(model, plan, mesh1, axes, GB,
                              TrainConfig(strategy="picasso_l2"))
    rng = np.random.default_rng(0)
    l1_hits = l2_hits = 0
    for i in range(8):
        b = make_batch(_cfg64(), GB, rng)
        b = jax.device_put(b, to_named(mesh1, batch_specs(b, axes)))
        state, m = step(state, b)
        assert bool(jnp.isfinite(m["loss"]))
        assert set(m) >= {"cache_hits", "cache_hits/l1", "cache_hits/l2"}
        assert int(m["cache_hits"]) == (int(m["cache_hits/l1"])
                                        + int(m["cache_hits/l2"]))
        l1_hits += int(m["cache_hits/l1"])
        l2_hits += int(m["cache_hits/l2"])
    # after the flush both tiers hold 8+16 of the 64 rows: uniform synthetic
    # ids must hit each tier
    assert l1_hits > 0 and l2_hits > 0

    serve = make_serve_step(model, plan, mesh1, axes, GB,
                            scfg=ServeConfig(strategy="picasso_l2"))
    b = make_batch(_cfg64(), GB, rng)
    b = jax.device_put(b, to_named(mesh1, batch_specs(b, axes)))
    probs = serve(state, b)
    assert bool(jnp.isfinite(probs).all())


def test_pin_l2_to_host_is_safe_noop_on_cpu(mesh1):
    """The experimental host-placement hook: no mesh or no pinned_host
    memory kind (the CPU rig) -> state returned unchanged, never an error."""
    from repro.embedding.state import pin_l2_to_host
    plan = make_plan(_cfg64(), world=1, per_device_batch=GB,
                     hot_bytes=1 << 14, l2_bytes=320)
    emb = {str(g): s for g, s in
           init_embedding_state(jax.random.PRNGKey(0), plan).items()}
    state = {"emb": emb}
    assert pin_l2_to_host(state) is state          # no mesh -> untouched
    out = pin_l2_to_host(state, mesh=mesh1)        # CPU: no pinned_host
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_metric_keys_static_and_mixed(mesh1):
    plan = make_plan(_cfg64(), world=1, per_device_batch=GB,
                     hot_bytes=1 << 14, l2_bytes=320)
    eng = EmbeddingEngine(plan, AXES, 1, strategy="picasso_l2")
    assert eng.metric_keys == ("overflow", "cache_hits",
                               "cache_hits/l1", "cache_hits/l2")
    mixed_plan = make_plan(_mixed_cfg(), world=1, per_device_batch=GB,
                           hot_bytes=1 << 14, l2_bytes=1 << 18)
    meng = EmbeddingEngine(mixed_plan, AXES, 1, strategy="mixed")
    assert set(meng.metric_keys) == {
        "overflow", "cache_hits",
        "overflow/ps", "overflow/picasso_l2",
        "cache_hits/ps", "cache_hits/picasso_l2",
        "cache_hits/l1", "cache_hits/l2"}
