"""Fault-tolerance subsystem: the full recovery matrix.

Every failure mode the runtime claims to survive is injected here and the
recovery is asserted *exactly* (bitwise where the contract says bitwise):

- numeric anomalies: guarded-vs-unguarded parity on clean data, NaN-batch
  rejection (state untouched, batch skipped), spike rejection, rollback
  after K consecutive rejections;
- checkpoint corruption: checksum detection, quarantine rename, fallback
  to the previous good snapshot, Supervisor replay exactness through it;
- supervisor policy: failure-density reset on sustained progress, fatal
  classification short-circuits retries;
- publish/serve: pruned-LATEST race returns the newest real delta, the
  poller keeps the last good state through a torn delta and recovers;
- streaming: a chaos crash mid-segment resumes bitwise-exactly from the
  segment checkpoint.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import ReplayableStream
from repro.data.synthetic import batch_stream
from repro.dist.sharding import batch_specs, to_named
from repro.models.wdl import WDLModel
from repro.core.packing import make_plan
from repro.runtime.chaos import (ChaosController, ChaosFailure, ChaosStream,
                                 FaultPlan, corrupt_checkpoint_file,
                                 parse_fault_plan, poison_batch,
                                 tear_published)
from repro.runtime.guard import AnomalyGuard, AnomalyRollback, GuardConfig
from repro.runtime.stream import (PublishPoller, poll_published,
                                  publish_state, run_stream)
from repro.train.checkpoint import (AsyncCheckpointer, CheckpointCorrupt,
                                    available_steps, latest_step,
                                    restore_checkpoint, restore_verified,
                                    save_checkpoint)
from repro.train.fault_tolerance import Supervisor, classify_failure
from repro.train.train_step import TrainConfig, init_state, make_train_step

GB = 64
PLAN_KW = dict(hot_bytes=1 << 14, l2_bytes=1 << 16, flush_iters=5,
               warmup_iters=2)


def _put(mesh, axes, batch):
    return jax.device_put(batch, to_named(mesh, batch_specs(batch, axes)))


def _setup(mesh1, axes, strategy="picasso", donate=True, **plan_kw):
    cfg = get_config("deepfm", smoke=True)
    kw = dict(PLAN_KW)
    kw.update(plan_kw)
    plan = make_plan(cfg, world=1, per_device_batch=GB, **kw)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1,
                       axes=axes)
    step, _ = make_train_step(model, plan, mesh1, axes, GB,
                              TrainConfig(strategy=strategy), donate=donate)
    return cfg, plan, model, state, step


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------- toy guarded loop
# A scalar-ish step with a controllable gradient norm: batch "x" drives the
# update, so NaN/spike injection is exact and cheap.


def _toy_step():
    # non-donating, like any guard-compatible step (see runtime/guard.py)
    def raw(state, batch):
        g = jnp.mean(batch["x"]) * jnp.ones_like(state["w"])
        new = {"w": state["w"] - 0.1 * g, "step": state["step"] + 1}
        return new, {"loss": jnp.mean(batch["x"]) ** 2,
                     "grad_norm": jnp.sqrt(jnp.vdot(g, g))}
    return jax.jit(raw)


def _toy_state():
    return {"w": jnp.ones((3,), jnp.float32), "step": jnp.int32(0)}


def _toy_batch(i, poison=False):
    v = float("nan") if poison else 0.1 + 0.01 * (i % 7)
    return {"x": jnp.full((4,), v, jnp.float32)}


def _toy_stream(n=10_000, poison_at=()):
    def make(start):
        def gen():
            i = start
            while i < n:
                yield _toy_batch(i, poison=i in poison_at)
                i += 1
        return gen()
    return ReplayableStream(make)


# ------------------------------------------------------------ anomaly guard


def test_guard_clean_parity(mesh1, axes):
    """On clean data a guarded run is bitwise identical to the default
    (donating, unguarded) step: the guard runs the same executable modulo
    buffer donation, which affects aliasing but never values."""
    cfg, plan, model, state_a, step = _setup(mesh1, axes)  # donating ref
    _, _, _, state_b, gstep = _setup(mesh1, axes, donate=False)
    guard = AnomalyGuard(gstep)
    sa, sb = state_a, state_b
    for i, batch in zip(range(5), batch_stream(cfg, GB, seed=3)):
        b = _put(mesh1, axes, batch)
        sa, _ = step(sa, b)
        sb, m = guard(sb, b)
        assert m["anomalous"] == 0
    _leaves_equal(sa, sb)
    assert guard.accepted == 5 and guard.rejected == 0


def test_guard_nan_batch_rejected(mesh1, axes):
    """A poisoned batch is rejected (state untouched) and the run converges
    to the exact state of a run that never saw that batch."""
    cfg, plan, model, state_g, gstep = _setup(mesh1, axes, donate=False)
    _, _, _, state_r, step = _setup(mesh1, axes)
    guard = AnomalyGuard(gstep)
    batches = [b for _, b in zip(range(6), batch_stream(cfg, GB, seed=3))]
    for i, batch in enumerate(batches):
        b = _put(mesh1, axes, batch)
        if i == 3:
            b = poison_batch(b)
        state_g, m = guard(state_g, b)
        assert bool(m["anomalous"]) == (i == 3)
    # reference: same batches minus the poisoned index
    for i, batch in enumerate(batches):
        if i == 3:
            continue
        state_r, _ = step(state_r, _put(mesh1, axes, batch))
    _leaves_equal(state_g, state_r)
    assert guard.rejected == 1 and len(guard.events) == 1
    assert guard.events[0].kind == "nonfinite"


def test_guard_spike_rejection_and_threshold():
    step = _toy_step()
    guard = AnomalyGuard(step, GuardConfig(warmup_steps=3, spike_factor=10.0,
                                           k_rollback=99))
    s = _toy_state()
    for i in range(5):
        s, m = guard(s, _toy_batch(i))
    assert guard.threshold > 0
    before = np.asarray(s["w"]).copy()
    s, m = guard(s, {"x": jnp.full((4,), 1e6, jnp.float32)})
    assert bool(m["anomalous"])
    np.testing.assert_array_equal(np.asarray(s["w"]), before)
    assert guard.events[-1].kind == "spike"
    # accepted steps resume and the streak counter resets
    s, m = guard(s, _toy_batch(9))
    assert not bool(m["anomalous"]) and guard.consecutive == 0


def test_guard_rollback_after_k_carries_state():
    guard = AnomalyGuard(_toy_step(), GuardConfig(k_rollback=3))
    s = _toy_state()
    for i in range(4):
        s, _ = guard(s, _toy_batch(i))
    w_ok = np.asarray(s["w"]).copy()
    with pytest.raises(AnomalyRollback) as ei:
        for _ in range(3):
            s, _ = guard(s, _toy_batch(0, poison=True))
    # the exception carries the rejection-preserved state (the caller's
    # input buffers were donated): still exactly the pre-anomaly state
    np.testing.assert_array_equal(np.asarray(ei.value.state["w"]), w_ok)
    assert ei.value.rejects == 3
    assert classify_failure(ei.value) == "transient"


def test_guard_rebind_keeps_history():
    guard = AnomalyGuard(_toy_step(), GuardConfig(warmup_steps=2))
    s = _toy_state()
    for i in range(4):
        s, _ = guard(s, _toy_batch(i))
    ema = guard.ema
    guard.rebind(_toy_step())  # e.g. after a replan rebuild
    assert guard.ema == ema and guard.accepted == 4
    s, m = guard(s, _toy_batch(4))
    assert not bool(m["anomalous"])


# ------------------------------------------- supervisor rollback exactness


def test_supervisor_rollback_replay_exact(tmp_path):
    """Three consecutive transient NaN batches trigger the guard's rollback;
    the Supervisor restores the verified checkpoint and rewinds the stream;
    because the fault was transient (one-shot), the replay is clean and the
    final state is bitwise identical to a never-faulted run.

    (ckpt_every=5 keeps the checkpoint boundary out of the rejection streak
    at batches 5-7: a checkpoint taken *mid-streak* would legitimately pin
    the earlier rejections' skips — rejected batches behind the rollback
    target stay skipped by design.)"""
    def run(poison):
        guard = AnomalyGuard(_toy_step(), GuardConfig(k_rollback=3))
        stream = _toy_stream()
        if poison:
            stream = ChaosStream(stream, frozenset({5, 6, 7}))
        d = tmp_path / ("faulty" if poison else "clean")
        sup = Supervisor(str(d), ckpt_every=5, max_retries=3, backoff_s=0.0)
        out = sup.run(_toy_state(), guard, stream, n_steps=12)
        sup.ckpt.wait()
        return out, sup, guard

    clean, _, _ = run(poison=False)
    faulty, sup, guard = run(poison=True)
    _leaves_equal(clean, faulty)
    assert guard.rejected == 3
    assert sup.total_failures == 1  # one rollback, classified transient


def test_supervisor_restores_through_corrupt_checkpoint(tmp_path):
    """The newest checkpoint is corrupted on disk before the crash: restore
    must quarantine it, fall back to the previous good one, and the rewound
    replay still converges to the clean run's exact state."""
    def run(chaos):
        step = _toy_step()
        stream = _toy_stream()
        d = tmp_path / ("faulty" if chaos else "clean")
        sup = Supervisor(str(d), ckpt_every=2, max_retries=3, backoff_s=0.0)
        fired = set()

        def inject(i):
            if chaos and i == 7 and "crash" not in fired:
                fired.add("crash")
                # newest checkpoint (step 6) gets torn right before the crash
                sup.ckpt.wait()
                corrupt_checkpoint_file(str(d))
                raise ChaosFailure("injected crash at step 7")

        out = sup.run(_toy_state(), step, stream, n_steps=12,
                      fail_injector=inject)
        sup.ckpt.wait()
        return out, sup, d

    clean, _, _ = run(chaos=False)
    faulty, sup, d = run(chaos=True)
    _leaves_equal(clean, faulty)
    # the corrupt step-6 snapshot was quarantined, restore fell back to 4
    assert list(d.glob("step_*.corrupt"))
    assert sup.total_failures == 1


def test_supervisor_failure_counter_resets_on_progress(tmp_path):
    """Transient faults spread across a long run never exhaust max_retries:
    the density counter resets after reset_after clean steps."""
    sup = Supervisor(str(tmp_path), ckpt_every=2, max_retries=2,
                     reset_after=4, backoff_s=0.0)
    fired = set()

    def inject(i):
        # 3 transient faults, each separated by >= reset_after clean steps
        if i in (3, 9, 15) and i not in fired:
            fired.add(i)
            raise ChaosFailure(f"fault at {i}")

    out = sup.run(_toy_state(), _toy_step(), _toy_stream(), n_steps=20,
                  fail_injector=inject)
    assert int(out["step"]) == 20
    assert sup.total_failures == 3
    assert sup.failures <= 1  # density reset between faults


def test_supervisor_fatal_classification_short_circuits(tmp_path):
    """A deterministic bug (TypeError) must re-raise immediately instead of
    burning the retry budget on a restore loop."""
    assert classify_failure(TypeError("tracer leak")) == "fatal"
    assert classify_failure(ChaosFailure("node loss")) == "transient"
    sup = Supervisor(str(tmp_path), ckpt_every=2, max_retries=3,
                     backoff_s=0.0)

    def inject(i):
        if i == 3:
            raise TypeError("deterministic bug")

    with pytest.raises(TypeError):
        sup.run(_toy_state(), _toy_step(), _toy_stream(), n_steps=10,
                fail_injector=inject)
    assert sup.total_failures == 0  # never entered the retry path


# --------------------------------------------------- checkpoint corruption


def test_corrupt_checkpoint_quarantine_and_fallback(tmp_path):
    d = str(tmp_path)
    s4 = {"w": np.arange(4, dtype=np.float32)}
    s8 = {"w": np.arange(4, dtype=np.float32) * 2}
    save_checkpoint(d, 4, s4)
    save_checkpoint(d, 8, s8)
    corrupt_checkpoint_file(d)  # tears the newest (step 8)
    # direct restore of the torn step reports corruption, not garbage
    with pytest.raises(CheckpointCorrupt):
        restore_checkpoint(d, s8, step=8)
    # the verified walk quarantines step 8 and falls back to step 4
    state, step = restore_verified(d, s4)
    assert step == 4
    np.testing.assert_array_equal(state["w"], s4["w"])
    assert (tmp_path / "step_00000008.corrupt").exists()
    # quarantined snapshots are invisible to every reader
    assert latest_step(d) == 4
    assert available_steps(d) == [4]


def test_restore_verified_exhausted_raises(tmp_path):
    d = str(tmp_path)
    s = {"w": np.ones(3, np.float32)}
    save_checkpoint(d, 2, s)
    corrupt_checkpoint_file(d)
    with pytest.raises(FileNotFoundError):
        restore_verified(d, s)
    assert (tmp_path / "step_00000002.corrupt").exists()


# ------------------------------------------------------ publish/serve side


def _pub_state(k=1.0):
    return {"emb": {"t": np.full((4, 2), k, np.float32)},
            "dense": {"w": np.full((3,), k, np.float32)}}


def test_poll_published_pruned_latest_falls_back(tmp_path):
    d = str(tmp_path)
    publish_state(d, 10, _pub_state(1.0), keep=2)
    publish_state(d, 20, _pub_state(2.0), keep=2)
    # simulate the keep= race: LATEST names a step that was already pruned
    (tmp_path / "LATEST").write_text("99\n")
    assert poll_published(d) == 20  # newest delta actually on disk
    # garbage pointer: same fallback
    (tmp_path / "LATEST").write_text("not-a-step\n")
    assert poll_published(d) == 20
    # nothing newer than last_step -> None, not a crash
    assert poll_published(d, last_step=20) is None


def test_publish_poller_survives_torn_delta(tmp_path):
    d = str(tmp_path)
    template = _pub_state(0.0)
    poller = PublishPoller(d, max_backoff=4)
    assert poller.poll(template) is None  # nothing published yet

    publish_state(d, 10, _pub_state(1.0), keep=3)
    out = poller.poll(template)
    assert out is not None and out[1] == 10

    publish_state(d, 20, _pub_state(2.0), keep=3)
    tear_published(d)  # truncate a leaf of the step-20 delta
    assert poller.poll(template) is None  # torn delta skipped, not crashed
    assert poller.last_step == 10 and poller.failures == 1
    assert poller.skips_left > 0  # backoff armed

    publish_state(d, 30, _pub_state(3.0), keep=3)
    got = None
    for _ in range(6):  # a few polls burn the backoff window, then load
        got = poller.poll(template)
        if got is not None:
            break
    assert got is not None and got[1] == 30
    np.testing.assert_array_equal(got[0]["dense"]["w"],
                                  np.full((3,), 3.0, np.float32))
    assert poller.failures == 0  # clean load resets the backoff


# ------------------------------------------------------------- stream mode


def test_stream_crash_mid_segment_resumes_exact(tmp_path):
    """A chaos crash mid-segment kills the streaming driver; restarting from
    the segment checkpoint with the stream rewound reproduces the clean
    run's final state bitwise."""
    step = _toy_step()

    def clean_run():
        s, last = run_stream(_toy_state(), step, _toy_stream(),
                             segment_steps=5, n_segments=4,
                             log=lambda s: None)
        return s, last

    want, want_last = clean_run()
    assert want_last == 20

    d = str(tmp_path / "ckpt")
    ckpt = AsyncCheckpointer(d)
    chaos = ChaosController(FaultPlan(crash=frozenset({12})))
    stream = _toy_stream()
    with pytest.raises(ChaosFailure):
        run_stream(_toy_state(), step, stream, segment_steps=5, n_segments=4,
                   checkpointer=ckpt,
                   on_metrics=lambda i, m: chaos.injector(i),
                   log=lambda s: None)
    ckpt.wait()
    assert latest_step(d) == 10  # segment-2 boundary was durable

    # "process restart": restore the checkpoint, rewind the stream, finish
    state, start = restore_verified(d, _toy_state())
    stream.seek(start)
    got, last = run_stream(state, step, stream, segment_steps=5,
                           n_segments=2, start_step=start,
                           checkpointer=ckpt, log=lambda s: None)
    ckpt.wait()
    assert last == want_last
    _leaves_equal(want, got)


# -------------------------------------------------------- chaos primitives


def test_parse_fault_plan():
    p = parse_fault_plan("nan@7,nan@8,crash@13,ckpt@20,torn@45")
    assert p.nan_batch == frozenset({7, 8})
    assert p.crash == frozenset({13})
    assert p.corrupt_ckpt == frozenset({20})
    assert p.torn_publish == frozenset({45})
    assert bool(p) and not bool(FaultPlan())
    with pytest.raises(ValueError):
        parse_fault_plan("explode@3")
    with pytest.raises(ValueError):
        parse_fault_plan("nan@x")


def test_chaos_stream_one_shot_across_seek():
    """Poison fires once per index and does NOT re-fire on replay — the
    transient-corruption semantics that make rollback converge."""
    stream = ChaosStream(_toy_stream(), frozenset({2}))
    got = [next(stream) for _ in range(4)]
    assert np.isnan(np.asarray(got[2]["x"])).all()
    stream.seek(0)
    replay = [next(stream) for _ in range(4)]
    assert not any(np.isnan(np.asarray(b["x"])).any() for b in replay)


def test_batch_stream_start_is_positional(mesh1, axes):
    cfg = get_config("deepfm", smoke=True)
    a = [b for _, b in zip(range(6), batch_stream(cfg, GB, seed=7))]
    tail = [b for _, b in zip(range(2), batch_stream(cfg, GB, seed=7,
                                                     start=4))]
    for got, want in zip(tail, a[4:]):
        _leaves_equal(got, want)


def test_replayable_stream_seek_and_rewrap():
    def make(start):
        def gen():
            i = start
            while True:
                yield i
                i += 1
        return gen()

    rs = ReplayableStream(make)
    assert [next(rs) for _ in range(3)] == [0, 1, 2]
    rs.seek(1)
    assert next(rs) == 1 and rs.pos == 2
    rs.rewrap(lambda start: iter(range(start, start + 100)))
    assert next(rs) == 2  # same position, new factory
