"""Multi-device correctness via subprocesses (the parent pytest process keeps
the default 1-device backend; children force 8 host devices)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


def _run(code: str, timeout=900) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


HEADER = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.compat import shard_map
from repro.launch.mesh import make_test_mesh
"""


def test_mp_lookup_8dev_exact():
    out = _run(HEADER + """
from repro.embedding.state import EmbeddingState
from repro.core import packed_embedding as pe
from repro.engine import PicassoStrategy
mesh = make_test_mesh(4, 2)
AXES=("data","model"); W, RPS, D, N = 8, 16, 5, 24
rng = np.random.default_rng(0)
table = jnp.asarray(rng.normal(size=(RPS*W, D)).astype(np.float32))
ids = jnp.asarray(rng.integers(0, RPS*W, size=(W, N)).astype(np.int32))
strat = PicassoStrategy(axes=AXES, world=W, capacity={0: N})
def f(tsh, ids_l):
    st = EmbeddingState(w=tsh, acc=jnp.zeros((RPS, 1)),
                        counts=jnp.zeros((RPS,), jnp.int32),
                        cache=pe.init_cache(0, D, RPS*W))
    rows_u, ctx = strat.lookup(st, 0, ids_l.reshape(-1))
    return jnp.take(rows_u, ctx.inv, axis=0).reshape(1, N, D)
got = jax.jit(shard_map(f, mesh=mesh, in_specs=(P(AXES,None),P(AXES,None)),
                        out_specs=P(AXES,None,None), check_vma=False))(table, ids)
exp = np.asarray(table)[np.asarray(ids)]
print("MATCH", np.allclose(np.asarray(got), exp, atol=1e-6))
""")
    assert "MATCH True" in out


def test_train_converges_and_cache_kicks_in():
    out = _run(HEADER + """
from repro.configs import get_config
from repro.core.packing import make_plan
from repro.data.synthetic import make_batch
from repro.dist.sharding import batch_specs, to_named
from repro.models.wdl import WDLModel
from repro.train.train_step import TrainConfig, init_state, make_train_step
mesh = make_test_mesh(4, 2); axes=("data","model"); GB=64
cfg = get_config("deepfm", smoke=True)
plan = make_plan(cfg, world=8, per_device_batch=8, hot_bytes=1<<14,
                 flush_iters=3, warmup_iters=2)
model = WDLModel(cfg, plan)
state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh, axes=axes)
step, _ = make_train_step(model, plan, mesh, axes, GB, TrainConfig())
rng = np.random.default_rng(0)
hits = []
for i in range(6):
    b = make_batch(cfg, GB, rng)
    b = jax.device_put(b, to_named(mesh, batch_specs(b, axes)))
    state, m = step(state, b)
    hits.append(int(m["cache_hits"]))
    assert bool(jnp.isfinite(m["loss"]))
print("HITS_BEFORE", hits[0], "HITS_AFTER", hits[-1])
""")
    toks = out.split()
    assert int(toks[1]) == 0 and int(toks[3]) > 0  # cache warms up after flush


def test_strategy_parity_8dev():
    """All registry strategies are exact with the cache off and exact
    capacity: identical pooled outputs, loss trajectories, and post-update
    embedding tables on a 4x2 mesh (up to fp reassociation in the routed
    collectives). Includes the PR-6 decomposition baselines: 'mp_nodedup'
    (no K-Packed dedup — owner-side grad summation must recover the deduped
    math) and 'allgather_rows' (dedup'd replication)."""
    out = _run(HEADER + """
from repro.configs import get_config
from repro.core.packing import make_plan
from repro.data.synthetic import make_batch
from repro.dist.sharding import batch_specs, to_named
from repro.models.wdl import WDLModel
from repro.train.train_step import TrainConfig, init_state, make_train_step
mesh = make_test_mesh(4, 2); axes=("data","model"); GB=32
cfg = get_config("dcn-v2", smoke=True)
BASELINES = ("hybrid", "ps", "mp_nodedup", "allgather_rows")
losses, tables = {}, {}
for strat in ("picasso",) + BASELINES:
    plan = make_plan(cfg, world=8, per_device_batch=4, enable_cache=False,
                     exact_capacity=True, n_micro=1)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh, axes=axes)
    step, _ = make_train_step(model, plan, mesh, axes, GB,
                              TrainConfig(strategy=strat, use_cache=False))
    rng = np.random.default_rng(1)
    ls = []
    for i in range(3):
        b = make_batch(cfg, GB, rng)
        b = jax.device_put(b, to_named(mesh, batch_specs(b, axes)))
        state, m = step(state, b)
        ls.append(float(m["loss"]))
    losses[strat] = ls
    tables[strat] = {k: np.asarray(jax.device_get(v.w))
                     for k, v in state["emb"].items()}
ldiff = max(abs(a-b) for base in BASELINES
            for a, b in zip(losses["picasso"], losses[base]))
wdiff = max(float(np.abs(tables["picasso"][k] - tables[base][k]).max())
            for base in BASELINES for k in tables["picasso"])
print("LDIFF", ldiff, "WDIFF", wdiff)
""")
    toks = out.split()
    assert float(toks[1]) < 1e-4 and float(toks[3]) < 1e-4


def test_overlap_parity_8dev():
    """The software-pipelined step (overlap='on') trains the identical loss
    trajectory as the synchronous step (overlap='off') on a 4x2 mesh with a
    real multi-chunk micro-batch pipeline and a warm hot tier — the handoff
    barriers only pin the schedule, never the values. Also pins that fp16
    routed-grad compression stays finite and fp16-close under overlap."""
    out = _run(HEADER + """
from repro.configs import get_config
from repro.core.packing import make_plan
from repro.data.synthetic import make_batch
from repro.dist.sharding import batch_specs, to_named
from repro.models.wdl import WDLModel
from repro.train.train_step import TrainConfig, init_state, make_train_step
mesh = make_test_mesh(4, 2); axes=("data","model"); GB=64
cfg = get_config("deepfm", smoke=True)
plan = make_plan(cfg, world=8, per_device_batch=8, n_micro=2,
                 hot_bytes=1<<14, flush_iters=3, warmup_iters=2)
model = WDLModel(cfg, plan)
traj = {}
for mode, compress in (("off", "none"), ("on", "none"), ("on", "fp16")):
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh, axes=axes)
    step, _ = make_train_step(model, plan, mesh, axes, GB,
                              TrainConfig(overlap=mode, grad_compress=compress))
    rng = np.random.default_rng(0)
    ls = []
    for i in range(5):
        b = make_batch(cfg, GB, rng)
        b = jax.device_put(b, to_named(mesh, batch_specs(b, axes)))
        state, m = step(state, b)
        ls.append(float(m["loss"]))
    traj[(mode, compress)] = ls
exact = max(abs(a-b) for a, b in zip(traj[("off","none")], traj[("on","none")]))
comp = max(abs(a-b) for a, b in zip(traj[("on","none")], traj[("on","fp16")]))
finite = all(np.isfinite(traj[("on","fp16")]))
print("EXACT", exact, "COMP", comp, "FINITE", finite)
""")
    toks = out.split()
    assert float(toks[1]) == 0.0      # barriers are value-identity
    assert float(toks[3]) < 5e-2      # fp16 wire rounding only
    assert toks[5] == "True"


def test_cache_mode_is_exact():
    """HybridHash on (flush every step) == cache off: identical losses."""
    out = _run(HEADER + """
from repro.configs import get_config
from repro.core.packing import make_plan
from repro.data.synthetic import make_batch
from repro.dist.sharding import batch_specs, to_named
from repro.models.wdl import WDLModel
from repro.train.train_step import TrainConfig, init_state, make_train_step
mesh = make_test_mesh(4, 2); axes=("data","model"); GB=32
cfg = get_config("deepfm", smoke=True)
traj = {}
for use_cache in (True, False):
    plan = make_plan(cfg, world=8, per_device_batch=4,
                     enable_cache=use_cache, exact_capacity=True,
                     hot_bytes=1<<14, flush_iters=1, warmup_iters=1, n_micro=1)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh, axes=axes)
    step, _ = make_train_step(model, plan, mesh, axes, GB,
                              TrainConfig(use_cache=use_cache))
    rng = np.random.default_rng(2)
    ls = []
    for i in range(5):
        b = make_batch(cfg, GB, rng)
        b = jax.device_put(b, to_named(mesh, batch_specs(b, axes)))
        state, m = step(state, b)
        ls.append(float(m["loss"]))
    traj[use_cache] = ls
print("DIFF", max(abs(a-b) for a,b in zip(traj[True], traj[False])))
""")
    diff = float(out.split()[-1])
    assert diff < 1e-3  # exact up to fp reassociation in the routed path


def test_fused_kernels_parity_8dev():
    """Fused Pallas sparse kernels == reference chains on a real 4x2 mesh
    (regression: jax-0.4.37 interpret-mode prefetch-gather index maps
    combined with aliased ANY operands mis-gathered on devices > 0 — the
    dedup kernel now pre-sorts its grads outside the kernel, and this test
    pins multi-device parity end to end, warm hot tier included)."""
    out = _run(HEADER + """
from repro.configs import get_config
from repro.core.packing import make_plan
from repro.data.synthetic import make_batch
from repro.dist.sharding import batch_specs, to_named
from repro.models.wdl import WDLModel
from repro.train.train_step import TrainConfig, init_state, make_train_step
mesh = make_test_mesh(4, 2); axes=("data","model"); GB=64
cfg = get_config("deepfm", smoke=True)
plan = make_plan(cfg, world=8, per_device_batch=8, hot_bytes=1<<14,
                 l2_bytes=4096, flush_iters=3, warmup_iters=2)
model = WDLModel(cfg, plan)
traj = {}
for fused in (False, True):
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh, axes=axes)
    step, _ = make_train_step(model, plan, mesh, axes, GB,
                              TrainConfig(strategy="picasso_l2",
                                          use_fused_kernels=fused))
    rng = np.random.default_rng(0)
    ls, hits = [], 0
    for i in range(6):
        b = make_batch(cfg, GB, rng)
        b = jax.device_put(b, to_named(mesh, batch_specs(b, axes)))
        state, m = step(state, b)
        ls.append(float(m["loss"]))
        hits += int(m["cache_hits"])
    traj[fused] = (ls, hits)
ldiff = max(abs(a-b) for a, b in zip(traj[True][0], traj[False][0]))
print("LDIFF", ldiff, "HITS", traj[True][1], traj[False][1])
""")
    toks = out.split()
    assert float(toks[1]) < 1e-4          # fused == reference trajectories
    assert int(toks[3]) > 0 and int(toks[3]) == int(toks[4])


def test_elastic_reshard_parity_8dev():
    """Elastic parity on 8 host devices with all three PICASSO tiers in one
    mixed plan (picasso / picasso_l2 / picasso_narrow): train at world=8,
    reshard live to 4 and then 2 mid-run. The continued loss trajectory must
    be bit-identical to a fresh "process" that restores the world-4 host
    snapshot, rebuilds its own step, and replays the same batches through
    the same reshard sequence — and the final masters, slots, and counters
    must agree bitwise on every logical row."""
    out = _run(HEADER + """
from repro.configs.base import FeatureField, InteractionSpec, WDLConfig
from repro.core.assign import apply_assignment
from repro.core.packing import make_plan
from repro.data.synthetic import make_batch
from repro.dist.sharding import batch_specs, to_named
from repro.models.wdl import WDLModel
from repro.runtime import make_submesh, place_state, reshard_live
from repro.train.train_step import TrainConfig, init_state, make_train_step
axes = ("data", "model"); GB = 32
fields = (FeatureField("a", 1001, 8, max_len=2),
          FeatureField("b", 515, 16, max_len=1),
          FeatureField("c", 259, 4, max_len=3))
cfg = WDLConfig(name="elastic3", fields=fields, n_dense=0,
                interactions=(InteractionSpec("fm"),), mlp_dims=(16, 8))
MIX = {0: "picasso", 1: "picasso_l2", 2: "picasso_narrow"}
TCFG = TrainConfig(strategy="mixed")

def build(plan, mesh):
    model = WDLModel(cfg, plan)
    step, _ = make_train_step(model, plan, mesh, axes, GB, TCFG)
    return step

def seg(step, state, mesh, seed, n):
    rng = np.random.default_rng(seed)
    ls = []
    for _ in range(n):
        b = make_batch(cfg, GB, rng)
        b = jax.device_put(b, to_named(mesh, batch_specs(b, axes)))
        state, m = step(state, b)
        ls.append(float(m["loss"]))
    return state, ls

mesh8 = make_test_mesh(4, 2)
plan8 = make_plan(cfg, world=8, per_device_batch=GB // 8, hot_bytes=1 << 12,
                  l2_bytes=1 << 13, narrow_dim=4, flush_iters=2,
                  warmup_iters=1, mesh_shape=(4, 2))
apply_assignment(plan8, dict(MIX))
state = init_state(WDLModel(cfg, plan8), plan8, jax.random.PRNGKey(0),
                   mesh=mesh8, axes=axes)
state, _ = seg(build(plan8, mesh8), state, mesh8, seed=10, n=4)

# ---- live reshard 8 -> 4 and snapshot the migrated state to host --------
mesh4 = make_submesh((2, 2), axes)
plan4, state = reshard_live(plan8, state, 4, GB // 4, mesh=mesh4, axes=axes,
                            mesh_shape=(2, 2))
assert plan4.strategy == MIX, plan4.strategy
snap = jax.device_get(state)

# ---- continued run: 3 steps at 4, live reshard 4 -> 2, 3 steps at 2 -----
mesh2 = make_submesh((1, 2), axes)
state, ls_b = seg(build(plan4, mesh4), state, mesh4, seed=11, n=3)
plan2, state = reshard_live(plan4, state, 2, GB // 2, mesh=mesh2, axes=axes,
                            mesh_shape=(1, 2))
state, ls_c = seg(build(plan2, mesh2), state, mesh2, seed=12, n=3)

# ---- fresh "process": restore the snapshot, rebuild, replay -------------
fstate = place_state(snap, plan4, mesh4, axes)
fstate, fl_b = seg(build(plan4, mesh4), fstate, mesh4, seed=11, n=3)
fplan2, fstate = reshard_live(plan4, fstate, 2, GB // 2, mesh=mesh2,
                              axes=axes, mesh_shape=(1, 2))
fstate, fl_c = seg(build(fplan2, mesh2), fstate, mesh2, seed=12, n=3)

bitwise = (ls_b + ls_c) == (fl_b + fl_c)
wdiff = 0.0
for g in plan2.groups:
    a, b = state["emb"][str(g.gid)], fstate["emb"][str(g.gid)]
    n = max(g.table_offsets[t.name] + t.vocab for t in g.tables)
    for la, lb in ((a.w, b.w), (a.acc, b.acc), (a.counts, b.counts)):
        wdiff = max(wdiff, float(np.abs(np.asarray(la)[:n].astype(np.float64)
                                        - np.asarray(lb)[:n].astype(np.float64)).max()))
print("BITWISE", bitwise, "WDIFF", wdiff, "ROWS2",
      sum(g.rows for g in plan2.groups) % 2)
""", timeout=1200)
    toks = out.split()
    assert toks[1] == "True"            # loss trajectories bit-identical
    assert float(toks[3]) == 0.0        # masters/slots/counters bitwise
    assert int(toks[5]) == 0            # world-2 row cuts actually re-padded


def test_mini_dryrun_lowers_and_compiles():
    """Small-mesh dry-run: one cell per family lowers + compiles + reports
    roofline terms (the 512-device version runs in launch/dryrun.py)."""
    out = _run(HEADER + """
from pathlib import Path
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import run_cell
mesh = make_mesh((4,2), ("data","model"))
for arch, shape in [("dcn-v2","serve_p99"), ("yi-34b","decode_32k"),
                    ("schnet","minibatch_lg")]:
    rec = run_cell(arch, shape, False, Path("/tmp/repro_test_dryrun"),
                   mesh=mesh, smoke=True)
    print(arch, rec["ok"], rec.get("bound"), rec.get("error",""))
""", timeout=1200)
    lines = [l for l in out.splitlines() if l.strip()]
    for l in lines:
        assert " True " in l, l
