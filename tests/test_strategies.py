"""EmbeddingEngine strategy registry + parity (single-device, in-process),
including per-group strategy mixing: broadcast-assignment parity with the
single-strategy engine, mixed ps+picasso training/serving, per-group cache
gating, and the stale-mode flush.

Multi-device parity of the same strategies lives in
test_distributed.py::test_strategy_parity_8dev.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import FeatureField, InteractionSpec, WDLConfig
from repro.core import packed_embedding as pe
from repro.core.features import pack_group
from repro.core.packing import make_plan
from repro.data.synthetic import make_batch
from repro.dist.compat import shard_map
from repro.dist.sharding import emb_specs, replicated
from repro.embedding.state import EmbeddingState, init_embedding_state
from repro.engine import (EmbeddingEngine, HybridStrategy, LookupStrategy,
                          PicassoStrategy, PSStrategy, available_strategies,
                          compile_assignment, get_strategy, register_strategy)

AXES = ("data", "model")
GB = 16


def _mixed_cfg():
    """One tiny table (dim 8) + one large table (dim 16): two packed groups
    the cost model assigns to different strategies."""
    fields = (FeatureField("tiny", 64, 8, max_len=1, pooling="sum"),
              FeatureField("big", 50_000, 16, max_len=1, pooling="sum"))
    return WDLConfig(name="mix", fields=fields, n_dense=0,
                     interactions=(InteractionSpec("fm"),), mlp_dims=(8,))


# --------------------------------------------------------------- registry
def test_registry_contents():
    names = available_strategies()
    assert {"picasso", "hybrid", "ps"} <= set(names)
    assert get_strategy("picasso") is PicassoStrategy
    assert get_strategy("hybrid") is HybridStrategy
    assert get_strategy("ps") is PSStrategy


def test_unknown_strategy_raises_with_menu():
    with pytest.raises(ValueError, match="picasso"):
        get_strategy("does-not-exist")


def test_train_step_validates_strategy_name(mesh1, axes):
    from repro.models.wdl import WDLModel
    from repro.train.train_step import TrainConfig, make_train_step
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, enable_cache=False)
    model = WDLModel(cfg, plan)
    with pytest.raises(ValueError, match="unknown lookup strategy"):
        make_train_step(model, plan, mesh1, axes, GB,
                        TrainConfig(strategy="nope"))


def test_custom_strategy_registers_and_resolves():
    @register_strategy("_test_dummy")
    class DummyStrategy(PicassoStrategy):
        pass

    try:
        assert get_strategy("_test_dummy") is DummyStrategy
        assert DummyStrategy.name == "_test_dummy"
    finally:
        from repro.engine import strategies as S
        S._REGISTRY.pop("_test_dummy", None)


# ----------------------------------------------------------------- parity
def _engine_roundtrip(mesh, strategy: str):
    """forward + backward of one synthetic batch through the bare engine."""
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, enable_cache=False,
                     exact_capacity=True)
    emb0 = {str(g): s for g, s in
            init_embedding_state(jax.random.PRNGKey(0), plan).items()}
    batch = make_batch(cfg, GB, np.random.default_rng(3))
    fields = jax.tree.map(jnp.asarray, batch["fields"])
    engine = EmbeddingEngine(plan, AXES, 1, strategy=strategy,
                             use_cache=False, lr_emb=0.1)
    especs = emb_specs(plan, AXES)

    def f(emb, fields):
        packed = {g.gid: pack_group(g, fields) for g in plan.groups}
        pooled, ctx = engine.forward(emb, packed)
        # deterministic synthetic loss grad: d(0.5*sum(pooled^2)) = pooled
        emb2, _m = engine.backward(emb, ctx, pooled)
        return pooled, emb2

    pooled_specs = {g.gid: jax.sharding.PartitionSpec(AXES, None, None)
                    for g in plan.groups}
    g = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(especs, replicated(fields)),
        out_specs=(pooled_specs, especs), check_vma=False))
    pooled, emb2 = g(emb0, fields)
    tables = {k: np.asarray(v.w) for k, v in emb2.items()}
    return jax.tree.map(np.asarray, pooled), tables


def test_strategy_parity_forward_and_update(mesh1):
    """With exact capacity and no cache, all strategies produce matching
    pooled outputs and post-update embedding tables."""
    ref_pooled, ref_tables = _engine_roundtrip(mesh1, "picasso")
    for name in ("hybrid", "ps"):
        pooled, tables = _engine_roundtrip(mesh1, name)
        for gid in ref_pooled:
            np.testing.assert_allclose(pooled[gid], ref_pooled[gid],
                                       atol=1e-5, err_msg=f"{name}/pooled/{gid}")
        for k in ref_tables:
            np.testing.assert_allclose(tables[k], ref_tables[k],
                                       atol=1e-5, err_msg=f"{name}/table/{k}")


def test_broadcast_assignment_parity_bitwise(mesh1):
    """A {gid: name} assignment giving every group the *same* name must be
    bitwise-identical to the single-name engine (constructor sugar)."""
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, enable_cache=False,
                     exact_capacity=True)
    broadcast = {g.gid: "picasso" for g in plan.groups}
    ref_pooled, ref_tables = _engine_roundtrip(mesh1, "picasso")
    pooled, tables = _engine_roundtrip(mesh1, broadcast)
    for gid in ref_pooled:
        np.testing.assert_array_equal(pooled[gid], ref_pooled[gid])
    for k in ref_tables:
        np.testing.assert_array_equal(tables[k], ref_tables[k])


# ------------------------------------------------------------------ mixed
def test_mixed_engine_per_group_dispatch_and_gating(mesh1):
    """ps + picasso in one plan: per-group strategies, per-group cache
    gating (the tier participates only where the strategy uses it AND the
    plan budgets rows)."""
    cfg = _mixed_cfg()
    plan = make_plan(cfg, world=1, per_device_batch=GB, hot_bytes=1 << 14)
    asg = compile_assignment(plan)
    gid_tiny = next(g.gid for g in plan.groups if g.tables[0].name == "tiny")
    gid_big = next(g.gid for g in plan.groups if g.tables[0].name == "big")
    assert asg.strategy == {gid_tiny: "ps", gid_big: "picasso"}

    eng = EmbeddingEngine(plan, AXES, 1, strategy=asg)
    assert eng.strategy_name == "mixed"
    assert isinstance(eng.strategies[gid_tiny], PSStrategy)
    assert isinstance(eng.strategies[gid_big], PicassoStrategy)
    # both groups have a cache budget, but only picasso's tier participates
    assert plan.cache_rows[gid_tiny] > 0 and plan.cache_rows[gid_big] > 0
    assert eng.cache_on == {gid_tiny: False, gid_big: True}
    assert eng.any_cache
    assert set(eng.metric_keys) == {"overflow", "cache_hits",
                                    "overflow/ps", "overflow/picasso",
                                    "cache_hits/ps", "cache_hits/picasso"}
    # single-strategy engines keep the lean metric pytree
    assert EmbeddingEngine(plan, AXES, 1).metric_keys == ("overflow",
                                                          "cache_hits")


def test_mixed_flush_skips_uncached_groups(mesh1):
    """flush must not touch groups whose assigned strategy never reads the
    tier, even when the plan budgets cache rows for them."""
    cfg = _mixed_cfg()
    plan = make_plan(cfg, world=1, per_device_batch=GB, hot_bytes=1 << 14)
    asg = compile_assignment(plan)
    gid_tiny = next(g.gid for g in plan.groups if g.tables[0].name == "tiny")
    eng = EmbeddingEngine(plan, AXES, 1, strategy=asg)
    emb0 = {str(g): s for g, s in
            init_embedding_state(jax.random.PRNGKey(0), plan).items()}
    especs = emb_specs(plan, AXES)
    out = jax.jit(shard_map(eng.flush, mesh=mesh1, in_specs=(especs,),
                            out_specs=especs, check_vma=False))(emb0)
    for leaf_a, leaf_b in zip(jax.tree.leaves(emb0[str(gid_tiny)]),
                              jax.tree.leaves(out[str(gid_tiny)])):
        np.testing.assert_array_equal(np.asarray(leaf_a), np.asarray(leaf_b))


def test_make_flush_fn_follows_plan_assignment(mesh1, axes):
    """A host-scheduled flush built without an explicit strategy must pick
    up the plan's recorded assignment — not broadcast picasso gating over
    PS groups whose (budgeted) tier the training path never populated."""
    from repro.train.train_step import make_flush_fn

    cfg = _mixed_cfg()
    plan = make_plan(cfg, world=1, per_device_batch=GB, hot_bytes=1 << 14)
    # an engine built with 'mixed' records its compiled assignment on the
    # plan (the bench path: TrainConfig(strategy='mixed'), no launcher)
    eng = EmbeddingEngine(plan, AXES, 1, strategy="mixed")
    gid_tiny = next(g.gid for g in plan.groups if g.tables[0].name == "tiny")
    assert plan.strategy == eng.assignment
    assert plan.strategy[gid_tiny] == "ps" and plan.cache_rows[gid_tiny] > 0

    emb0 = {str(g): s for g, s in
            init_embedding_state(jax.random.PRNGKey(0), plan).items()}
    # snapshot before the call: the flush fn donates its input buffers
    before = [np.asarray(x) for x in jax.tree.leaves(emb0[str(gid_tiny)])]
    state = {"emb": emb0, "step": jnp.zeros((), jnp.int32)}
    out = make_flush_fn(plan, mesh1, axes)(state)
    for a, b in zip(before, jax.tree.leaves(out["emb"][str(gid_tiny)])):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_mixed_assignment_trains_and_serves(mesh1, axes):
    """Acceptance: a mixed plan (one ps group + one cached picasso group)
    trains end-to-end via train_step and serves via serve_step, with the
    per-strategy-class metric breakdown attributing hits to picasso only."""
    from repro.core.assign import apply_assignment
    from repro.dist.sharding import batch_specs, to_named
    from repro.models.wdl import WDLModel
    from repro.serve.serve_step import ServeConfig, make_serve_step
    from repro.train.train_step import TrainConfig, init_state, make_train_step

    cfg = _mixed_cfg()
    plan = make_plan(cfg, world=1, per_device_batch=GB, hot_bytes=1 << 14,
                     flush_iters=2, warmup_iters=1)
    asg = compile_assignment(plan)
    assert set(asg.strategy.values()) == {"ps", "picasso"}
    apply_assignment(plan, asg)

    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1, axes=axes)
    step, _ = make_train_step(model, plan, mesh1, axes, GB,
                              TrainConfig(strategy="mixed"))
    rng = np.random.default_rng(0)
    hits = 0
    for i in range(5):
        b = make_batch(cfg, GB, rng)
        b = jax.device_put(b, to_named(mesh1, batch_specs(b, axes)))
        state, m = step(state, b)
        assert bool(jnp.isfinite(m["loss"]))
        # class totals reconcile, and the ps class never touches the tier
        assert int(m["cache_hits"]) == (int(m["cache_hits/ps"])
                                        + int(m["cache_hits/picasso"]))
        assert int(m["cache_hits/ps"]) == 0
        hits += int(m["cache_hits/picasso"])
    assert hits > 0  # the picasso group's tier warmed up after the flush

    serve = make_serve_step(model, plan, mesh1, axes, GB,
                            scfg=ServeConfig(strategy="mixed"))
    b = make_batch(cfg, GB, rng)
    b = jax.device_put(b, to_named(mesh1, batch_specs(b, axes)))
    probs = serve(state, b)
    assert bool(jnp.isfinite(probs).all())


# ------------------------------------------------------------------ flush
def _flush_fixture(mesh1, cache_update):
    """One 64-row cached group with marker rows in the tier and counts
    making rows 56..63 the hottest; returns (w0, flushed state)."""
    cfg = WDLConfig(name="f", fields=(FeatureField("a", 64, 4),), n_dense=0,
                    interactions=(InteractionSpec("fm"),), mlp_dims=(8,))
    plan = make_plan(cfg, world=1, per_device_batch=GB, hot_bytes=1 << 14)
    (gid,) = [g.gid for g in plan.groups]
    h = plan.cache_rows[gid]
    assert h == 8
    st = init_embedding_state(jax.random.PRNGKey(1), plan)[gid]
    st = EmbeddingState(
        w=st.w, acc=st.acc,
        counts=jnp.arange(64, dtype=jnp.int32),        # row 63 hottest
        cache=pe.CacheState(keys=jnp.arange(h, dtype=jnp.int32),  # rows 0..7
                            rows=jnp.full((h, 4), 777.0),         # marker
                            acc=jnp.ones((h, 1))))
    eng = EmbeddingEngine(plan, AXES, 1, cache_update=cache_update)
    especs = emb_specs(plan, AXES)
    emb = {str(gid): st}
    out = jax.jit(shard_map(eng.flush, mesh=mesh1, in_specs=(especs,),
                            out_specs=especs, check_vma=False))(emb)
    return np.asarray(st.w), out[str(gid)]


def test_flush_psum_writes_back_and_reloads(mesh1):
    w0, st2 = _flush_fixture(mesh1, "psum")
    w2 = np.asarray(st2.w)
    np.testing.assert_allclose(w2[:8], 777.0)          # hot rows written back
    np.testing.assert_allclose(w2[8:], w0[8:], atol=1e-6)
    keys = np.sort(np.asarray(st2.cache.keys))
    np.testing.assert_array_equal(keys, np.arange(56, 64))  # new top-8
    for i, k in enumerate(np.asarray(st2.cache.keys)):
        np.testing.assert_allclose(np.asarray(st2.cache.rows)[i], w2[k],
                                   atol=1e-6)


def test_flush_stale_master_stays_exact(mesh1):
    """cache_update='stale': the master table is authoritative — flush must
    NOT write the (read-only, stale) tier back, only re-rank + reload it."""
    w0, st2 = _flush_fixture(mesh1, "stale")
    w2 = np.asarray(st2.w)
    np.testing.assert_allclose(w2, w0, atol=1e-6)      # no write-back at all
    keys = np.sort(np.asarray(st2.cache.keys))
    np.testing.assert_array_equal(keys, np.arange(56, 64))
    for i, k in enumerate(np.asarray(st2.cache.keys)):
        np.testing.assert_allclose(np.asarray(st2.cache.rows)[i], w0[k],
                                   atol=1e-6)          # reloaded from master


def test_hybrid_selectable_by_name_end_to_end(mesh1, axes):
    """'hybrid' resolves from the registry through TrainConfig and trains."""
    from repro.dist.sharding import batch_specs, to_named
    from repro.models.wdl import WDLModel
    from repro.train.train_step import TrainConfig, init_state, make_train_step
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, enable_cache=False,
                     exact_capacity=True)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1, axes=axes)
    step, _ = make_train_step(model, plan, mesh1, axes, GB,
                              TrainConfig(strategy="hybrid", use_cache=False))
    b = make_batch(cfg, GB, np.random.default_rng(0))
    b = jax.device_put(b, to_named(mesh1, batch_specs(b, axes)))
    state, m = step(state, b)
    assert bool(jnp.isfinite(m["loss"]))
    # hybrid never touches the hot tier
    assert int(m["cache_hits"]) == 0
