"""EmbeddingEngine strategy registry + parity (single-device, in-process).

Multi-device parity of the same strategies lives in
test_distributed.py::test_strategy_parity_8dev.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.features import pack_group
from repro.core.packing import make_plan
from repro.data.synthetic import make_batch
from repro.dist.compat import shard_map
from repro.dist.sharding import emb_specs, replicated
from repro.embedding.state import init_embedding_state
from repro.engine import (EmbeddingEngine, HybridStrategy, LookupStrategy,
                          PicassoStrategy, PSStrategy, available_strategies,
                          get_strategy, register_strategy)

AXES = ("data", "model")
GB = 16


# --------------------------------------------------------------- registry
def test_registry_contents():
    names = available_strategies()
    assert {"picasso", "hybrid", "ps"} <= set(names)
    assert get_strategy("picasso") is PicassoStrategy
    assert get_strategy("hybrid") is HybridStrategy
    assert get_strategy("ps") is PSStrategy


def test_unknown_strategy_raises_with_menu():
    with pytest.raises(ValueError, match="picasso"):
        get_strategy("does-not-exist")


def test_train_step_validates_strategy_name(mesh1, axes):
    from repro.models.wdl import WDLModel
    from repro.train.train_step import TrainConfig, make_train_step
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, enable_cache=False)
    model = WDLModel(cfg, plan)
    with pytest.raises(ValueError, match="unknown lookup strategy"):
        make_train_step(model, plan, mesh1, axes, GB,
                        TrainConfig(strategy="nope"))


def test_custom_strategy_registers_and_resolves():
    @register_strategy("_test_dummy")
    class DummyStrategy(PicassoStrategy):
        pass

    try:
        assert get_strategy("_test_dummy") is DummyStrategy
        assert DummyStrategy.name == "_test_dummy"
    finally:
        from repro.engine import strategies as S
        S._REGISTRY.pop("_test_dummy", None)


# ----------------------------------------------------------------- parity
def _engine_roundtrip(mesh, strategy: str):
    """forward + backward of one synthetic batch through the bare engine."""
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, enable_cache=False,
                     exact_capacity=True)
    emb0 = {str(g): s for g, s in
            init_embedding_state(jax.random.PRNGKey(0), plan).items()}
    batch = make_batch(cfg, GB, np.random.default_rng(3))
    fields = jax.tree.map(jnp.asarray, batch["fields"])
    engine = EmbeddingEngine(plan, AXES, 1, strategy=strategy,
                             use_cache=False, lr_emb=0.1)
    especs = emb_specs(plan, AXES)

    def f(emb, fields):
        packed = {g.gid: pack_group(g, fields) for g in plan.groups}
        pooled, ctx = engine.forward(emb, packed)
        # deterministic synthetic loss grad: d(0.5*sum(pooled^2)) = pooled
        emb2, _m = engine.backward(emb, ctx, pooled)
        return pooled, emb2

    pooled_specs = {g.gid: jax.sharding.PartitionSpec(AXES, None, None)
                    for g in plan.groups}
    g = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(especs, replicated(fields)),
        out_specs=(pooled_specs, especs), check_vma=False))
    pooled, emb2 = g(emb0, fields)
    tables = {k: np.asarray(v.w) for k, v in emb2.items()}
    return jax.tree.map(np.asarray, pooled), tables


def test_strategy_parity_forward_and_update(mesh1):
    """With exact capacity and no cache, all strategies produce matching
    pooled outputs and post-update embedding tables."""
    ref_pooled, ref_tables = _engine_roundtrip(mesh1, "picasso")
    for name in ("hybrid", "ps"):
        pooled, tables = _engine_roundtrip(mesh1, name)
        for gid in ref_pooled:
            np.testing.assert_allclose(pooled[gid], ref_pooled[gid],
                                       atol=1e-5, err_msg=f"{name}/pooled/{gid}")
        for k in ref_tables:
            np.testing.assert_allclose(tables[k], ref_tables[k],
                                       atol=1e-5, err_msg=f"{name}/table/{k}")


def test_hybrid_selectable_by_name_end_to_end(mesh1, axes):
    """'hybrid' resolves from the registry through TrainConfig and trains."""
    from repro.dist.sharding import batch_specs, to_named
    from repro.models.wdl import WDLModel
    from repro.train.train_step import TrainConfig, init_state, make_train_step
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=GB, enable_cache=False,
                     exact_capacity=True)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh1, axes=axes)
    step, _ = make_train_step(model, plan, mesh1, axes, GB,
                              TrainConfig(strategy="hybrid", use_cache=False))
    b = make_batch(cfg, GB, np.random.default_rng(0))
    b = jax.device_put(b, to_named(mesh1, batch_specs(b, axes)))
    state, m = step(state, b)
    assert bool(jnp.isfinite(m["loss"]))
    # hybrid never touches the hot tier
    assert int(m["cache_hits"]) == 0
