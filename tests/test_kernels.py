"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:
    from hypothesis_fallback import given, settings, st

from repro.kernels import ref
from repro.kernels.cross_layer import cross_layer_pallas
from repro.kernels.dot_interaction import dot_interaction_pallas
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.fm_interaction import fm_interaction_pallas

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == np.float32 else dict(atol=1e-1, rtol=1e-1)


@pytest.mark.parametrize("v,d,n,nb", [(32, 8, 20, 5), (128, 16, 64, 16),
                                      (64, 50, 40, 8), (256, 128, 100, 10)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_embedding_bag_sweep(v, d, n, nb, dtype):
    table = RNG.normal(size=(v, d)).astype(dtype)
    ids = RNG.integers(0, v, n).astype(np.int32)
    # sorted segments covering every bag at least once
    seg = np.sort(np.concatenate([np.arange(nb), RNG.integers(0, nb, n - nb)])).astype(np.int32)
    w = RNG.normal(size=n).astype(dtype)
    got = embedding_bag_pallas(jnp.asarray(table), jnp.asarray(ids), jnp.asarray(seg),
                               jnp.asarray(w), nb, interpret=True)
    exp = ref.embedding_bag_ref(jnp.asarray(table), jnp.asarray(ids), jnp.asarray(seg),
                                nb, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), **_tol(dtype))


@pytest.mark.parametrize("b,f,d", [(8, 4, 8), (33, 7, 12), (128, 26, 10), (65, 13, 16)])
def test_fm_sweep(b, f, d):
    x = jnp.asarray(RNG.normal(size=(b, f, d)).astype(np.float32))
    got = fm_interaction_pallas(x, block_b=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.fm_interaction_ref(x)),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("b,f,d", [(8, 4, 8), (32, 27, 16), (65, 13, 16)])
def test_dot_sweep(b, f, d):
    x = jnp.asarray(RNG.normal(size=(b, f, d)).astype(np.float32))
    got = dot_interaction_pallas(x, block_b=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.dot_interaction_ref(x)),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("b,d,bb,bd", [(16, 16, 8, 8), (50, 24, 16, 8),
                                       (128, 130, 32, 64), (33, 7, 16, 8)])
def test_cross_sweep(b, d, bb, bd):
    x0 = jnp.asarray(RNG.normal(size=(b, d)).astype(np.float32))
    x = jnp.asarray(RNG.normal(size=(b, d)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(d, d)).astype(np.float32) / np.sqrt(d))
    bias = jnp.asarray(RNG.normal(size=(d,)).astype(np.float32))
    got = cross_layer_pallas(x0, x, w, bias, block_b=bb, block_d=bd, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.cross_layer_ref(x0, x, w, bias)),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 40), st.integers(2, 12), st.integers(2, 24), st.integers(1, 9))
def test_embedding_bag_property(v, d, n, nb):
    """Property: kernel == take+segment_sum for any sorted covering seg."""
    nb = min(nb, n)
    rng = np.random.default_rng(v * 1000 + n)
    table = rng.normal(size=(v, d)).astype(np.float32)
    ids = rng.integers(0, v, n).astype(np.int32)
    seg = np.sort(np.concatenate([np.arange(nb), rng.integers(0, nb, n - nb)])).astype(np.int32)
    w = rng.normal(size=n).astype(np.float32)
    got = embedding_bag_pallas(jnp.asarray(table), jnp.asarray(ids), jnp.asarray(seg),
                               jnp.asarray(w), nb, interpret=True)
    exp = ref.embedding_bag_ref(jnp.asarray(table), jnp.asarray(ids), jnp.asarray(seg),
                                nb, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=1e-4, rtol=1e-4)


def test_bf16_dtype():
    x = jnp.asarray(RNG.normal(size=(16, 8, 8))).astype(jnp.bfloat16)
    got = fm_interaction_pallas(x, block_b=8, interpret=True)
    exp = ref.fm_interaction_ref(x)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(exp, np.float32),
                                atol=1.0, rtol=0.1)
