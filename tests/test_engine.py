"""Engine strategy layer on a 1x1 mesh: the full shard_map path (unique,
partition, Shuffle/Stitch, pooling, sparse adagrad, HybridHash) vs the dense
EmbeddingBag oracle, exercised through ``repro.engine`` strategies.
Multi-device equivalence is in test_distributed.py."""
import functools

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:
    from hypothesis_fallback import given, settings, st

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import packed_embedding as pe
from repro.core.hashing import scramble, scramble_np
from repro.dist.compat import shard_map
from repro.embedding.bag import embedding_bag
from repro.embedding.state import EmbeddingState
from repro.engine import PicassoStrategy

AXES = ("data", "model")


def _group_state(table, hot_keys=None, hot_rows=None) -> EmbeddingState:
    """Single-group EmbeddingState around a dense table (tests only)."""
    v, d = table.shape
    if hot_keys is not None:
        cache = pe.CacheState(keys=hot_keys, rows=hot_rows,
                              acc=jnp.zeros((hot_keys.shape[0], 1), jnp.float32))
    else:
        cache = pe.init_cache(0, d, v)
    return EmbeddingState(w=table, acc=jnp.zeros((v, 1), jnp.float32),
                          counts=jnp.zeros((v,), jnp.int32), cache=cache)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=1, max_size=64))
def test_fixed_unique_property(ids):
    ids = jnp.asarray(np.array(ids, np.int32))
    u = pe.fixed_unique(ids, sentinel=1 << 20)
    ref = np.unique(np.asarray(ids))
    n_u = int(u.n_uniq)
    assert n_u == len(ref)
    np.testing.assert_array_equal(np.asarray(u.uniq)[:n_u], ref)
    # inverse mapping reconstructs the input
    np.testing.assert_array_equal(np.asarray(u.uniq)[np.asarray(u.inv)], np.asarray(ids))


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 10_000))
def test_scramble_bijective(vocab):
    ids = np.arange(min(vocab, 2048), dtype=np.int32)
    s = scramble_np(ids, vocab)
    assert len(np.unique(s)) == len(ids)
    assert s.min() >= 0 and s.max() < vocab


def _lookup1(mesh, table, ids, cap, hot_keys=None, hot_rows=None):
    strat = PicassoStrategy(axes=AXES, world=1, capacity={0: cap})

    def f(tsh, ids_l):
        gst = _group_state(tsh, hot_keys, hot_rows)
        rows_u, ctx = strat.lookup(gst, 0, ids_l, cache_on=hot_keys is not None)
        return jnp.take(rows_u, ctx.inv, axis=0)

    return jax.jit(shard_map(f, mesh=mesh, in_specs=(P(AXES, None), P()),
                             out_specs=P(), check_vma=False))(table, ids)


def test_lookup_matches_gather(mesh1):
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(64, 7)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 64, 40).astype(np.int32))
    got = _lookup1(mesh1, table, ids, cap=40)
    np.testing.assert_allclose(np.asarray(got), np.asarray(table)[np.asarray(ids)],
                               atol=1e-6)


def test_lookup_with_cache(mesh1):
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.normal(size=(32, 4)).astype(np.float32))
    hot_keys = jnp.asarray(np.array([2, 5, 9, 32, 32, 32, 32, 32], np.int32))
    hot_rows = jnp.where((hot_keys < 32)[:, None],
                         table[jnp.clip(hot_keys, 0, 31)], 0.0)
    ids = jnp.asarray(rng.integers(0, 32, 24).astype(np.int32))
    got = _lookup1(mesh1, table, ids, cap=24, hot_keys=hot_keys, hot_rows=hot_rows)
    np.testing.assert_allclose(np.asarray(got), np.asarray(table)[np.asarray(ids)],
                               atol=1e-6)


def test_pool_matches_embedding_bag(mesh1):
    rng = np.random.default_rng(2)
    v, d, n, nb = 50, 6, 30, 8
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, v, n).astype(np.int32))
    seg = jnp.asarray(np.sort(rng.integers(0, nb, n)).astype(np.int32))
    w = jnp.asarray(rng.normal(size=n).astype(np.float32))
    strat = PicassoStrategy(axes=AXES, world=1, capacity={0: n})

    def f(tsh, ids_l, w_l, seg_l):
        rows_u, ctx = strat.lookup(_group_state(tsh), 0, ids_l)
        return pe.pool(rows_u, ctx.inv, w_l, seg_l, nb)

    got = jax.jit(shard_map(f, mesh=mesh1,
                            in_specs=(P(AXES, None), P(), P(), P()),
                            out_specs=P(), check_vma=False))(table, ids, w, seg)
    exp = embedding_bag(table, ids, seg, nb, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=1e-5)


def test_sparse_adagrad_matches_dense(mesh1):
    rng = np.random.default_rng(3)
    v, d, n = 40, 5, 25
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, v, n).astype(np.int32))
    g_per_id = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    strat = PicassoStrategy(axes=AXES, world=1, capacity={0: n}, lr=0.1)

    def f(tsh, ids_l, g):
        gst = _group_state(tsh)
        rows_u, ctx = strat.lookup(gst, 0, ids_l)
        g_u = jax.ops.segment_sum(g, ctx.inv, num_segments=n)
        st2, _, _ = strat.apply_grads(gst, 0, ctx, g_u)
        return st2.w, st2.acc

    w2, a2 = jax.jit(shard_map(
        f, mesh=mesh1, in_specs=(P(AXES, None), P(), P()),
        out_specs=(P(AXES, None), P(AXES, None)), check_vma=False))(table, ids, g_per_id)

    gref = np.zeros((v, d), np.float32)
    np.add.at(gref, np.asarray(ids), np.asarray(g_per_id))
    accref = (gref ** 2).mean(-1, keepdims=True)
    wref = np.asarray(table) - 0.1 * gref / np.sqrt(accref + 1e-8)
    touched = np.abs(gref).max(-1) > 0
    np.testing.assert_allclose(np.asarray(w2)[touched], wref[touched], atol=1e-5)


def test_overflow_counted(mesh1):
    rng = np.random.default_rng(4)
    table = jnp.asarray(rng.normal(size=(64, 4)).astype(np.float32))
    ids = jnp.asarray(np.arange(32, dtype=np.int32))  # 32 distinct ids
    strat = PicassoStrategy(axes=AXES, world=1, capacity={0: 8})

    def f(tsh, ids_l):
        _, ctx = strat.lookup(_group_state(tsh), 0, ids_l)
        return ctx.routing.overflow.reshape(())

    ovf = jax.jit(shard_map(f, mesh=mesh1, in_specs=(P(AXES, None), P()),
                            out_specs=P(), check_vma=False))(table, ids)
    assert int(ovf) == 32 - 8  # uniques beyond capacity dropped & counted


def test_flush_cache_roundtrip(mesh1):
    """Flush writes hot rows back and reloads the top-k set consistently."""
    rng = np.random.default_rng(5)
    v, d, h = 32, 4, 8
    w = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    acc = jnp.zeros((v, 1), jnp.float32)
    counts = jnp.asarray(np.arange(v, dtype=np.int32))  # row 31 hottest
    cache = pe.init_cache(h, d, v)

    def f(w, acc, counts, ck, cr, ca):
        return pe.flush_cache(w, acc, counts, pe.CacheState(ck, cr, ca),
                              axes=AXES, world=1)

    w2, acc2, counts2, cache2 = jax.jit(shard_map(
        f, mesh=mesh1,
        in_specs=(P(AXES, None), P(AXES, None), P(AXES), P(), P(), P()),
        out_specs=(P(AXES, None), P(AXES, None), P(AXES),
                   pe.CacheState(P(), P(), P())),
        check_vma=False))(
        w, acc, counts, *cache)
    cache2 = pe.CacheState(*cache2)
    keys = np.asarray(cache2.keys)
    assert set(keys[keys < v]) == set(range(v - h, v))  # top-8 hottest rows
    rows = np.asarray(cache2.rows)
    for i, k in enumerate(keys):
        if k < v:
            np.testing.assert_allclose(rows[i], np.asarray(w)[k], atol=1e-6)
