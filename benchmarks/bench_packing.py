"""Paper Tab. V: number of operations with/without D-Packing.

Reproduced at the HLO level: lower the train step with packing on/off and
count optimized-HLO ops + packed-embedding group counts (the paper's
'# of packed embedding')."""
import jax

from repro.configs.paper_models import can, mmoe, widedeep
from repro.core.packing import make_plan
from repro.launch.roofline import count_ops

from benchmarks.common import AXES, emit, mesh1, train_setup


def run():
    models = {"wd": widedeep(scale=0.05), "can": can(scale=0.01),
              "mmoe": mmoe(scale=0.05)}
    for name, cfg in models.items():
        counts = {}
        for packed in (False, True):
            stepper, state, plan, _ = train_setup(cfg, 32, enable_packing=packed,
                                                  enable_cache=False)
            # stepper closure: rebuild raw jit to lower
            from repro.data.synthetic import make_batch
            import numpy as np
            from repro.dist.sharding import batch_specs, to_named
            from repro.train.train_step import TrainConfig, make_train_step
            from repro.models.wdl import WDLModel
            m = mesh1()
            model = WDLModel(cfg, plan)
            step, _ = make_train_step(model, plan, m, AXES, 32, TrainConfig(use_cache=False))
            batch = make_batch(cfg, 32, np.random.default_rng(0))
            hlo = step.lower(state, batch).compile().as_text()
            counts[packed] = (count_ops(hlo)["_total"], len(plan.groups))
        n_tables = counts[False][1]
        emit(f"packing/{name}/ops_baseline", 0.0, f"n={counts[False][0]}")
        emit(f"packing/{name}/ops_picasso", 0.0,
             f"n={counts[True][0]};ratio={counts[True][0]/counts[False][0]:.2f}")
        emit(f"packing/{name}/groups", 0.0,
             f"{n_tables}->{counts[True][1]} packed")


if __name__ == "__main__":
    run()
