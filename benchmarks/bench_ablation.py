"""Paper Tab. IV: ablation — PICASSO vs w/o Packing, w/o Interleaving,
w/o Caching on the paper's production-style models (W&D / CAN / MMoE),
CPU-scaled."""
from repro.configs.paper_models import can, mmoe, widedeep
from repro.train.train_step import TrainConfig

from benchmarks.common import bench_train_ips, emit

GB = 128


def run():
    models = {"wd": widedeep(scale=0.05), "can": can(scale=0.01),
              "mmoe": mmoe(scale=0.05)}
    for name, cfg in models.items():
        rows = {
            "picasso": bench_train_ips(cfg, GB, TrainConfig()),
            "no_packing": bench_train_ips(cfg, GB, TrainConfig(),
                                          enable_packing=False),
            "no_interleaving": bench_train_ips(
                cfg, GB, TrainConfig(use_interleave=False, pipeline_micro=False),
                n_interleave=1),
            "no_caching": bench_train_ips(cfg, GB, TrainConfig(use_cache=False),
                                          enable_cache=False),
        }
        base = rows["picasso"]["ips"]
        for variant, r in rows.items():
            emit(f"ablation/{name}/{variant}", r["us_per_call"],
                 f"ips={r['ips']:.0f};rel={r['ips']/base:.2f};hits={r['hits']}")


if __name__ == "__main__":
    run()
