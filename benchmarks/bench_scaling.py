"""Paper Fig. 15: scale-out 1..128 executors.

No real cluster here, so scaling is evaluated on the dry-run cost model: a
subprocess per world size lowers the deepfm train step on w emulated devices
and reports the roofline step time; near-flat step time with growing world ==
near-linear throughput scaling (IPS = global_batch / step)."""
import json
import os
import subprocess
import sys

from benchmarks.common import emit

SCRIPT = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={W}"
from pathlib import Path
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import run_cell
mesh = make_mesh((max({W}//2,1), min({W},2)), ("data","model"))
rec = run_cell("deepfm", "train_batch", False, Path("results/bench_scaling"),
               mesh=mesh, smoke=False, tag="_w{W}")
print(json.dumps({{"world": {W}, "step_s": rec.get("step_s"),
                   "bound": rec.get("bound"), "ok": rec.get("ok")}}))
"""


def run():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    for w in (1, 2, 8, 32, 128):
        out = subprocess.run([sys.executable, "-c", SCRIPT.replace("{W}", str(w))
                              .replace("{{", "@@").replace("}}", "%%")
                              .replace("@@", "{").replace("%%", "}")],
                             capture_output=True, text=True, env=env, timeout=1800)
        line = [l for l in out.stdout.splitlines() if l.startswith("{")]
        if not line:
            emit(f"scaling/world={w}", 0.0, f"error={out.stderr[-200:]}")
            continue
        rec = json.loads(line[-1])
        ips = 65536 / rec["step_s"] if rec.get("step_s") else 0
        emit(f"scaling/world={w}", rec.get("step_s", 0) * 1e6,
             f"ips_model={ips:.0f};bound={rec.get('bound')}")


if __name__ == "__main__":
    run()
