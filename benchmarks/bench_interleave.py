"""Paper Fig. 14: throughput vs number of K-interleaving groups, and vs
number of D-interleaving micro-batches."""
from repro.configs.paper_models import can, mmoe
from repro.train.train_step import TrainConfig

from benchmarks.common import bench_train_ips, emit

GB = 128


def run():
    models = {"can": can(scale=0.01), "mmoe": mmoe(scale=0.05)}
    for name, cfg in models.items():
        for n_ilv in (1, 2, 4):
            r = bench_train_ips(cfg, GB, TrainConfig(), n_interleave=n_ilv)
            emit(f"interleave/{name}/k_groups={n_ilv}", r["us_per_call"],
                 f"ips={r['ips']:.0f}")
        for n_micro in (1, 2, 4):
            r = bench_train_ips(cfg, GB, TrainConfig(), n_micro=n_micro)
            emit(f"interleave/{name}/micro={n_micro}", r["us_per_call"],
                 f"ips={r['ips']:.0f}")


if __name__ == "__main__":
    run()
