"""Paper Tab. VI: HybridHash hit ratio and IPS vs hot-storage size."""
import jax

from repro.configs.paper_models import widedeep
from repro.train.train_step import TrainConfig

from benchmarks.common import bench_train_ips, emit

GB = 128


def run():
    cfg = widedeep(scale=0.05)
    base_ips = None
    for hot_bytes in (0, 1 << 12, 1 << 14, 1 << 16, 1 << 18):
        if hot_bytes == 0:
            r = bench_train_ips(cfg, GB, TrainConfig(use_cache=False),
                                enable_cache=False, iters=8)
        else:
            r = bench_train_ips(cfg, GB, TrainConfig(), hot_bytes=hot_bytes,
                                flush_iters=4, warmup_iters=2, iters=8)
        ids_per_batch = GB * sum(f.max_len for f in cfg.fields)
        hit_ratio = r["hits"] / ids_per_batch
        if base_ips is None:
            base_ips = r["ips"]
        emit(f"cache/hot={hot_bytes}", r["us_per_call"],
             f"ips={r['ips']:.0f};rel={r['ips']/base_ips:+.2f};hit={hit_ratio:.2f}")


if __name__ == "__main__":
    run()
