"""Shared benchmark harness utilities (CPU-scaled paper-table analogues)."""
from __future__ import annotations

import json
import pathlib
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import WDLConfig
from repro.core.assign import AUTO_NAMES, resolve_assignment
from repro.core.packing import make_plan
from repro.kernels import ops
from repro.data.synthetic import make_batch
from repro.dist.sharding import batch_specs, to_named
from repro.launch.mesh import make_mesh
from repro.models.wdl import WDLModel
from repro.train.train_step import TrainConfig, init_state, make_train_step

AXES = ("data", "model")


def mesh1():
    return make_mesh((1, 1), AXES)


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def train_setup(cfg: WDLConfig, gb: int, mesh=None, tcfg: Optional[TrainConfig] = None,
                seed: int = 0, donate: bool = True, **plan_kw):
    mesh = mesh or mesh1()
    world = int(mesh.devices.size)
    plan_kw.setdefault("hot_bytes", 1 << 16)
    plan_kw.setdefault("flush_iters", 10)
    plan_kw.setdefault("warmup_iters", 5)
    plan = make_plan(cfg, world=world, per_device_batch=gb // world, **plan_kw)
    if tcfg is not None and isinstance(tcfg.strategy, str) \
            and tcfg.strategy not in AUTO_NAMES:
        # record broadcast assignments before init_state sizes the masters
        # (a 'picasso_narrow' broadcast gates plan.narrow_width; other names
        # pass through unrecorded)
        resolve_assignment(plan, tcfg.strategy, world=world,
                           use_cache=tcfg.use_cache)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(seed), mesh=mesh, axes=AXES)
    step, _ = make_train_step(model, plan, mesh, AXES, gb, tcfg or TrainConfig(),
                              donate=donate)
    batch = make_batch(cfg, gb, np.random.default_rng(seed))
    batch = jax.device_put(batch, to_named(mesh, batch_specs(batch, AXES)))

    def stepper(state):
        s, m = step(state, batch)
        return s, m

    return stepper, state, plan, model


def bench_train_ips(cfg: WDLConfig, gb: int, tcfg: Optional[TrainConfig] = None,
                    iters: int = 5, **plan_kw) -> Dict[str, float]:
    stepper, state, plan, _ = train_setup(cfg, gb, tcfg=tcfg, **plan_kw)
    state, m = stepper(state)  # compile + warm
    state, m = stepper(state)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        state, m = stepper(state)
        jax.block_until_ready(m["loss"])
        ts.append(time.perf_counter() - t0)
    us = float(np.median(ts) * 1e6)
    return {"us_per_call": us, "ips": gb / (us / 1e6),
            "hits": int(m["cache_hits"]), "overflow": int(m["overflow"])}


def bench_guard_ips(cfg: WDLConfig, gb: int, iters: int = 5,
                    **plan_kw) -> Dict[str, float]:
    """The guard-overhead row: ips with the anomaly guard in the loop
    (non-donating step + per-step host sync of loss/grad_norm) vs the
    default donating unguarded step. The overhead is the honest price of
    per-step numeric detection; the computed values are bitwise identical
    (tests/test_faults.py)."""
    from repro.runtime.guard import AnomalyGuard

    stepper, state, plan, _ = train_setup(cfg, gb, donate=False, **plan_kw)
    # train_setup returns a stepper closed over its fixed batch; the guard
    # only needs the (state, batch)->(state, metrics) shape, so wrap it
    guard = AnomalyGuard(lambda s, _b: stepper(s))
    state, m = guard(state, None)  # compile + warm
    state, m = guard(state, None)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        state, m = guard(state, None)
        ts.append(time.perf_counter() - t0)
    us = float(np.median(ts) * 1e6)
    return {"us_per_call": us, "ips": gb / (us / 1e6),
            "accepted": guard.accepted, "rejected": guard.rejected}


def bench_replan_ips(cfg: WDLConfig, gb: int, iters: int = 5,
                     warm_steps: int = 6,
                     replan_hot_bytes: Optional[int] = None,
                     replan_l2_bytes: Optional[int] = None,
                     **plan_kw) -> Dict[str, float]:
    """The 'auto+replan' row: train under the auto (cost model) assignment,
    then run one full replan cycle — harvest the measured FCounter, recompile
    budgets + assignment, migrate live state, rebuild the jitted step — and
    time the post-replan plan revision. ``replan_hot_bytes``/``replan_l2_bytes``
    retune the tier envelopes at replan time (pass values different from the
    plan's to force a migration, exercising the full path)."""
    from repro.runtime import Replanner

    mesh = mesh1()
    world = int(mesh.devices.size)
    plan_kw.setdefault("hot_bytes", 1 << 16)
    plan_kw.setdefault("flush_iters", 10)
    plan_kw.setdefault("warmup_iters", 5)
    plan = make_plan(cfg, world=world, per_device_batch=gb // world, **plan_kw)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh, axes=AXES)
    step, _ = make_train_step(model, plan, mesh, AXES, gb,
                              TrainConfig(strategy="auto"))
    batch = make_batch(cfg, gb, np.random.default_rng(0))
    batch = jax.device_put(batch, to_named(mesh, batch_specs(batch, AXES)))
    rp = Replanner(plan, mesh, AXES, strategy="auto",
                   hot_bytes=replan_hot_bytes, l2_bytes=replan_l2_bytes)
    for _ in range(warm_steps):
        state, m = step(state, batch)
        rp.observe(m)
    out = rp.maybe_replan(state, step=warm_steps)
    migrated = int(out is not None)
    if out is not None:
        plan, state = out
        step, _ = make_train_step(model, plan, mesh, AXES, gb,
                                  TrainConfig(strategy="mixed"))
    state, m = step(state, batch)  # compile + warm the (possibly new) step
    state, m = step(state, batch)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        ts.append(time.perf_counter() - t0)
    us = float(np.median(ts) * 1e6)
    return {"us_per_call": us, "ips": gb / (us / 1e6),
            "migrated": migrated, "rev": int(plan.rev)}


def bench_reshard(cfg: WDLConfig, gb: int, world_from: int = 8,
                  world_to: int = 4, **plan_kw) -> Dict[str, float]:
    """The elastic-reshard cost row: how long a W -> W' migration stalls
    training. State is built host-side at ``world_from`` row cuts (the same
    arrays an elastic restore hands the permutation), the plan is recut to
    ``world_to``, and the stall is the pure row permutation
    (``reshard_state``) plus re-placement under the new plan's specs —
    exactly the two steps ``runtime.reshard_live`` pays mid-run."""
    from repro.core.packing import reshard_plan
    from repro.embedding.state import reshard_state
    from repro.runtime import place_state

    plan_kw.setdefault("hot_bytes", 1 << 16)
    plan_kw.setdefault("l2_bytes", 1 << 17)
    plan = make_plan(cfg, world=world_from,
                     per_device_batch=max(1, gb // world_from), **plan_kw)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0))  # host-side rows
    new_plan = reshard_plan(plan, world_to, max(1, gb // world_to))
    t0 = time.perf_counter()
    migrated = reshard_state(new_plan, state)
    placed = place_state(migrated, new_plan, mesh1(), AXES)
    jax.block_until_ready(placed)
    stall = time.perf_counter() - t0
    rows = sum(g.rows for g in new_plan.groups)
    return {"us_per_call": stall * 1e6, "stall_ms": stall * 1e3,
            "rows": rows, "rows_per_s": rows / stall}


# every emit() lands here too, so drivers can persist the run as one JSON
# artifact (the repo-root perf trajectory: BENCH_<pr>.json)
_ROWS: List[Dict[str, Any]] = []
BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent / "BENCH_10.json"


def emit(name: str, us: float, derived: str, *,
         interpreted: bool = False) -> None:
    # backend + interpret recorded per row: merged artifacts can mix runs
    # from the CPU rig (interpreter timings) and TPU (real kernels) without
    # mislabeling — an interpret=true row must never be read as silicon.
    # ``interpreted=True`` additionally flags a DERIVED row (a ratio) whose
    # inputs ran on the Pallas interpreter: the ratio is honest about this
    # rig but says nothing about silicon and must never be quoted as such.
    row = {"name": name, "us_per_call": float(us), "derived": derived,
           "backend": str(jax.default_backend()),
           "interpret": bool(ops.interpret_mode())}
    if interpreted:
        row["interpreted"] = True
    _ROWS.append(row)
    tag = ",interpreted" if interpreted else ""
    print(f"{name},{us:.1f},{derived}{tag}", flush=True)


def write_bench_json(path: Optional[pathlib.Path] = None) -> pathlib.Path:
    """Persist every row emitted so far to the repo-root trajectory file.

    Called by the drivers (``benchmarks.run``, ``bench_throughput --smoke``,
    ``bench_kernels``) after their suites finish. Rows MERGE by name with an
    existing artifact (this run's value wins), so separate driver processes
    compose into one trajectory file instead of clobbering each other."""
    path = pathlib.Path(path) if path else BENCH_JSON
    rows: List[Dict[str, Any]] = []
    if path.exists():
        try:
            rows = [r for r in json.loads(path.read_text()).get("rows", [])
                    if isinstance(r, dict) and "name" in r]
        except (json.JSONDecodeError, AttributeError):
            rows = []
    fresh = {r["name"] for r in _ROWS}
    rows = [r for r in rows if r["name"] not in fresh] + _ROWS
    payload = {
        "bench": ("PR10: fault-tolerant runtime (anomaly guard, verified "
                  "checkpoints, chaos harness, degraded-mode serving) with "
                  "the guard_overhead cost pinned, on top of the PR9 "
                  "measured cost model"),
        "rows": rows,
    }
    path.write_text(json.dumps(payload, indent=1) + "\n")
    print(f"[bench] wrote {len(_ROWS)} rows ({len(rows)} total) to {path}",
          flush=True)
    return path
