"""Calibration suite: run the cost-model microbench grid, write the
backend-stamped calibration file, and report the curve fits.

Rows land in the BENCH_<n>.json trajectory:

``calibrate/<op>``
    the fitted cost at the op's largest measured grid point (us), with the
    curve-fit quality in ``derived``: ``pts`` = grid points measured,
    ``resid`` = median relative residual |measured - predicted| / measured
    over the RAW samples (duplicate-x medians + the monotonicity projection
    make this nonzero exactly where the microbench was noisy or measured a
    non-monotone artifact — an honest fit-quality number, not a tautology).
``calibrate/predict_step``
    the fitted model's predicted sparse-path us/step for the smoke plan
    under its auto assignment — the number the Replanner's feedback loop
    compares against measured step walltime.

The calibration file itself goes to ``--calib-file`` (default: the repo-root
``calibration.json`` next to the BENCH artifact so CI can assert on it
without touching ``~/.cache``).
"""
import argparse
import pathlib

from benchmarks.common import emit


def run(smoke: bool = False, calib_file=None):
    import numpy as np

    from repro.configs import get_config
    from repro.core.assign import compile_assignment
    from repro.core.packing import make_plan
    from repro.perf import fit_cost_model, run_calibration, save_calibration

    grid = "tiny" if smoke else "small"
    samples = run_calibration(grid, log=lambda s: print(f"[calib] {s}",
                                                        flush=True))
    model = fit_cost_model(samples)
    path = pathlib.Path(calib_file) if calib_file else (
        pathlib.Path(__file__).resolve().parent.parent / "calibration.json")
    save_calibration(path, samples, model)
    print(f"[calib] wrote {path}", flush=True)

    for op, pts in samples.items():
        curve = model.curves[op]
        resid = np.median([abs(y - curve(x)) / max(y, 1e-9) for x, y in pts])
        x_max = max(x for x, _ in pts)
        emit(f"calibrate/{op}", curve(x_max),
             f"pts={len(pts)},resid={resid:.3f},x_max={x_max:.0f}")

    # end-to-end query: price the smoke plan's auto assignment from the fit
    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=1, per_device_batch=32, hot_bytes=1 << 16,
                     l2_bytes=1 << 17)
    asg = compile_assignment(plan, cost_model=model)
    plan.strategy = dict(asg.strategy)
    emit("calibrate/predict_step", model.predict_step_us(plan),
         "strategies=" + "+".join(sorted(set(asg.strategy.values()))))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid (CI fast pass)")
    ap.add_argument("--calib-file", default="",
                    help="calibration file destination (default: repo-root "
                         "calibration.json)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, calib_file=args.calib_file or None)
    from benchmarks.common import write_bench_json
    write_bench_json()
