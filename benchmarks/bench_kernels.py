"""Microbench of the fused sparse hot-path kernels in isolation: fused
(Pallas; interpreted off-TPU) vs the pure-jnp reference for gather+pool
(forward + VJP), dedup+adagrad scatter-update, the narrow-row
gather+project stitch (forward + VJP), and the cache tier probe.

On the CPU rig the fused rows time the *interpreted* kernels — uninteresting
absolute numbers (interpret mode is a correctness soak, not a fast path) but
they populate the perf trajectory and pin the harness; on TPU the same rows
time the real kernels. The reference rows are the production CPU path.

``--smoke`` shrinks sizes/iters for CI.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

from benchmarks.common import emit, time_fn


def _gather_pool_args(rng, n, d, n_bags):
    rows_u = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    inv = jnp.asarray(rng.integers(0, n, n).astype(np.int32))
    w = jnp.asarray(rng.normal(size=n).astype(np.float32))
    seg = np.sort(np.concatenate(
        [np.arange(n_bags), rng.integers(0, n_bags, n - n_bags)]))
    return rows_u, inv, w, jnp.asarray(seg.astype(np.int32))


def bench_gather_pool(n=512, d=32, n_bags=64, iters=3):
    rng = np.random.default_rng(0)
    rows_u, inv, w, seg = _gather_pool_args(rng, n, d, n_bags)
    for fused in (False, True):
        fn = jax.jit(lambda r: ops.gather_pool(r, inv, w, seg, n_bags,
                                               fused=fused))
        us = time_fn(fn, rows_u, iters=iters)
        emit(f"kernels/gather_pool/{'fused' if fused else 'ref'}", us,
             f"ips={n / (us / 1e6):.0f}")
        g = jax.jit(jax.grad(lambda r: jnp.sum(
            ops.gather_pool(r, inv, w, seg, n_bags, fused=fused) ** 2)))
        us = time_fn(g, rows_u, iters=iters)
        emit(f"kernels/gather_pool_vjp/{'fused' if fused else 'ref'}", us,
             f"ips={n / (us / 1e6):.0f}")


def bench_dedup_adagrad(rows=2048, d=32, m=512, hot=64, iters=3):
    """Duplicate-heavy: m grads over `hot` distinct rows (the skew head)."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(rows, d)).astype(np.float32))
    acc = jnp.asarray(np.abs(rng.normal(size=(rows, 1))).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, hot, m).astype(np.int32))
    g = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
    valid = jnp.asarray(rng.random(m) < 0.9)
    for fused in (False, True):
        fn = jax.jit(lambda w, a: ops.dedup_adagrad(w, a, idx, g, valid,
                                                    0.05, 1e-8, fused=fused))
        us = time_fn(fn, w, acc, iters=iters)
        emit(f"kernels/dedup_adagrad/{'fused' if fused else 'ref'}", us,
             f"ips={m / (us / 1e6):.0f}")


def bench_gather_project(m=512, n=256, nd=8, d=32, iters=3):
    """Narrow-row stitch (picasso_narrow): gather [nd]-rows out of the routed
    buffer and up-project through the learned [nd, d] kernel in one pass,
    forward + VJP, fused vs reference."""
    rng = np.random.default_rng(3)
    back = jnp.asarray(rng.normal(size=(m, nd)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, m, n).astype(np.int32))
    kept = jnp.asarray(rng.random(n) < 0.9)
    proj = jnp.asarray(rng.normal(size=(nd, d)).astype(np.float32))
    for fused in (False, True):
        fn = jax.jit(lambda b, p: ops.gather_project(b, idx, kept, p,
                                                     fused=fused))
        us = time_fn(fn, back, proj, iters=iters)
        emit(f"kernels/gather_project/{'fused' if fused else 'ref'}", us,
             f"ips={n / (us / 1e6):.0f}")
        g = jax.jit(jax.grad(lambda b, p: sum(
            jnp.sum(o ** 2) for o in ops.gather_project(b, idx, kept, p,
                                                        fused=fused)),
            argnums=(0, 1)))
        us = time_fn(g, back, proj, iters=iters)
        emit(f"kernels/gather_project_vjp/{'fused' if fused else 'ref'}", us,
             f"ips={n / (us / 1e6):.0f}")


def bench_tier_probe(n=512, h=256, d=32, iters=3):
    rng = np.random.default_rng(2)
    keys = jnp.asarray(np.sort(rng.choice(10 * h, h, replace=False))
                       .astype(np.int32))
    rows = jnp.asarray(rng.normal(size=(h, d)).astype(np.float32))
    uniq = jnp.sort(jnp.asarray(rng.integers(0, 10 * h, n).astype(np.int32)))
    uvalid = jnp.asarray(np.arange(n) < int(0.9 * n))
    for fused in (False, True):
        fn = jax.jit(lambda u: ops.tier_probe(u, uvalid, keys, rows,
                                              fused=fused))
        us = time_fn(fn, uniq, iters=iters)
        emit(f"kernels/tier_probe/{'fused' if fused else 'ref'}", us,
             f"ips={n / (us / 1e6):.0f}")


def run(smoke: bool = False):
    if smoke:
        bench_gather_pool(n=128, d=16, n_bags=16, iters=2)
        bench_dedup_adagrad(rows=256, d=16, m=128, hot=16, iters=2)
        bench_gather_project(m=128, n=64, nd=4, d=16, iters=2)
        bench_tier_probe(n=128, h=64, d=16, iters=2)
    else:
        bench_gather_pool()
        bench_dedup_adagrad()
        bench_gather_project()
        bench_tier_probe()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small sizes (CI)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
    from benchmarks.common import write_bench_json
    write_bench_json()
