"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. CPU-scaled configs: the *ratios*
(PICASSO vs baseline, ablation deltas, cache hit curves) are the reproduced
quantities; absolute TPU numbers come from the dry-run roofline
(EXPERIMENTS.md §Roofline), not from this container.
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: throughput,kernels,calibrate,ablation,"
                         "packing,interleave,cache,fields,scaling")
    args = ap.parse_args()

    from benchmarks import (bench_ablation, bench_cache, bench_calibrate,
                            bench_fields, bench_interleave, bench_kernels,
                            bench_packing, bench_scaling, bench_throughput,
                            common)

    suites = {
        "throughput": bench_throughput.run,   # paper Tab. III / Fig. 10
        "kernels": bench_kernels.run,         # fused sparse-kernel microbench
        "calibrate": bench_calibrate.run,     # cost-model curve fits + file
        "ablation": bench_ablation.run,       # paper Tab. IV
        "packing": bench_packing.run,         # paper Tab. V
        "interleave": bench_interleave.run,   # paper Fig. 14
        "cache": bench_cache.run,             # paper Tab. VI
        "fields": bench_fields.run,           # paper Tab. VIII
        "scaling": bench_scaling.run,         # paper Fig. 15
    }
    only = [s for s in args.only.split(",") if s] or list(suites)
    print("name,us_per_call,derived")
    failed = []
    for name in only:
        try:
            suites[name]()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    common.write_bench_json()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
