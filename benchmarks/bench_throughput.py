"""Paper Tab. III / Fig. 10: training throughput of the benchmark models
(DLRM / DeepFM / DIN / DCN-v2) under the EmbeddingEngine's registry
strategies — 'picasso' vs the 'hybrid' (MP, no cache) and 'ps' baselines,
plus 'mixed' (the repro.core.assign cost model picking a strategy per packed
group) and 'picasso_l2' (the L2 host-memory tier behind the hot tier).
CPU-scaled smoke configs; the *ratio* is the reproduced quantity.

PR6 rows: the software-pipelined step ('overlap=on' vs the jaxpr-pinned
'overlap=off' loop, both with >1 micro-batch so the double-buffered prefetch
actually engages), routed-gradient wire compression ('grad_compress=fp16' /
'grad_compress=topk'), and the two §II-C decomposition baselines the
registry gained ('mp_nodedup' — the Shuffle without K-Packed dedup — and
'allgather_rows' — dedup'd replication).

PR7 rows: 'picasso_narrow' (frequency-adaptive dims — hot ids full-width in
the tiers, the cold master stored at d = D // 4 and up-projected through a
learned [d, D] kernel at lookup) and 'narrow_vs_full' (the derived per-group
vparam-bytes reduction: narrow master + projection vs the full master).

PR8 row: 'reshard_8to4' — the elastic-reshard stall (host-side world=8 state
permuted onto world=4 row cuts and re-placed), reported as rows/sec migrated
plus the stall walltime a live ``--reshard-to`` pays mid-run.

PR10 rows: 'guard=on' (the anomaly guard in the loop: a non-donating step
plus one host sync of loss/grad_norm per step) and 'guard_overhead' (the
guarded/unguarded time ratio — the honest price of per-step numeric
anomaly detection; the computed values are bitwise identical on clean
data, pinned by tests/test_faults.py).

``--smoke`` runs one model at a reduced batch with fewer timing iters — the
fast CI pass wired into scripts/ci.sh (and the only place the auto-assignment
and two-tier cache paths are executed on every CI run)."""
import argparse

from repro.configs import get_config
from repro.configs.paper_models import din, dlrm
from repro.core.packing import make_plan, plan_narrow
from repro.kernels import ops
from repro.train.train_step import TrainConfig

from benchmarks.common import (bench_guard_ips, bench_replan_ips,
                               bench_reshard, bench_train_ips, emit)

GB = 128


def models(smoke: bool = False):
    if smoke:
        return {"deepfm": get_config("deepfm", smoke=True)}
    return {
        "dlrm": dlrm(criteo=False, scale=0.01),
        "deepfm": get_config("deepfm", smoke=True),
        "dcn-v2": get_config("dcn-v2", smoke=True),
        "din": din(scale=0.01),
    }


def run(smoke: bool = False):
    gb = 32 if smoke else GB
    iters = 2 if smoke else 5
    # honesty flags for the DERIVED ratio rows: a ratio whose inputs ran on
    # the Pallas *interpreter* (any non-TPU backend, or the force env var)
    # measures the interpreter, not silicon, and is flagged interpreted=True
    # so BENCH_<n>.json readers never quote it as a real-kernel ratio.
    # fused_vs_ref forces the fused path ON, so it hits the interpreter on
    # any interpret-mode rig; the auto-resolved rows (overlap, narrow) only
    # engage Pallas when resolve_fused('auto') says so.
    interp = ops.interpret_mode()
    auto_interp = bool(ops.resolve_fused("auto") and interp)
    for name, cfg in models(smoke).items():
        pic = bench_train_ips(cfg, gb, TrainConfig(strategy="picasso"), iters=iters)
        ps = bench_train_ips(cfg, gb, TrainConfig(strategy="ps", use_cache=False),
                             iters=iters, enable_cache=False)
        # per-group cost-model assignment (tiny tables PS, big skewed ones
        # routed + cached); the engine compiles it from the plan on the fly
        mix = bench_train_ips(cfg, gb, TrainConfig(strategy="mixed"), iters=iters)
        # hierarchical parameter cache: L2 host tier (4x the hot-tier bytes)
        # behind the hot tier, exercised end-to-end incl. the two-tier flush
        l2 = bench_train_ips(cfg, gb, TrainConfig(strategy="picasso_l2"),
                             iters=iters, l2_bytes=1 << 18)
        # frequency-adaptive dims: hot ids keep full-width rows in the
        # tiers, the cold master is stored at d = D // 4 and up-projected
        # at lookup (picasso_narrow); narrow_vs_full derives the per-group
        # vparam-bytes reduction the narrow master buys (master + learned
        # projection vs the full-width master)
        probe = make_plan(cfg, world=1, per_device_batch=gb)
        nd_req = max(1, min(g.dim for g in probe.groups) // 4)
        widths = plan_narrow(probe.groups, nd_req)
        nar = bench_train_ips(cfg, gb,
                              TrainConfig(strategy="picasso_narrow"),
                              iters=iters, l2_bytes=1 << 18,
                              narrow_dim=nd_req)
        full_elems = sum(g.rows * g.dim for g in probe.groups)
        nar_elems = sum(
            g.rows * widths[g.gid]
            + (widths[g.gid] * g.dim if widths[g.gid] < g.dim else 0)
            for g in probe.groups)
        # adaptive replanning: warm steps under 'auto', then one full
        # harvest -> recompile -> migrate -> rebuild cycle; the halved L2
        # envelope forces a tier-resize migration so the row exercises the
        # whole runtime path on every CI run
        rep = bench_replan_ips(cfg, gb, iters=iters, l2_bytes=1 << 18,
                               replan_l2_bytes=1 << 17)
        # fused sparse hot path (gather+pool VJP, dedup+adagrad scatter,
        # tier probes) vs the reference chain above: on TPU this times the
        # real Pallas kernels, off-TPU the interpreted soak path — either
        # way the row pins the fused path end-to-end in the trajectory
        fus = bench_train_ips(cfg, gb,
                              TrainConfig(strategy="picasso",
                                          use_fused_kernels=True),
                              iters=iters)
        # software-pipelined step: both rows run >1 micro-batch so the
        # prefetch has something to overlap; 'off' is the legacy loop
        ov_off = bench_train_ips(cfg, gb,
                                 TrainConfig(strategy="picasso", overlap="off"),
                                 iters=iters, n_micro=2)
        ov_on = bench_train_ips(cfg, gb,
                                TrainConfig(strategy="picasso", overlap="on"),
                                iters=iters, n_micro=2)
        # routed-gradient wire compression on the transposed Shuffle
        cmp_fp16 = bench_train_ips(cfg, gb,
                                   TrainConfig(strategy="picasso",
                                               grad_compress="fp16"),
                                   iters=iters)
        cmp_topk = bench_train_ips(cfg, gb,
                                   TrainConfig(strategy="picasso",
                                               grad_compress="topk"),
                                   iters=iters)
        # §II-C decomposition baselines: no-dedup Shuffle (prices K-Packed
        # Unique&Partition; exact_capacity so duplicates never overflow) and
        # dedup'd replication (prices the routing itself)
        nod = bench_train_ips(cfg, gb,
                              TrainConfig(strategy="mp_nodedup",
                                          use_cache=False),
                              iters=iters, enable_cache=False,
                              exact_capacity=True)
        agr = bench_train_ips(cfg, gb,
                              TrainConfig(strategy="allgather_rows",
                                          use_cache=False),
                              iters=iters, enable_cache=False)
        # the anomaly guard in the loop: non-donating step + one host sync
        # of loss/grad_norm per step; the ratio vs the plain picasso row is
        # the whole detection price (the numerics are bitwise identical)
        grd = bench_guard_ips(cfg, gb, iters=iters)
        speedup = ps["us_per_call"] / pic["us_per_call"]
        emit(f"throughput/{name}/picasso", pic["us_per_call"], f"ips={pic['ips']:.0f}")
        emit(f"throughput/{name}/picasso+fused", fus["us_per_call"],
             f"ips={fus['ips']:.0f}")
        emit(f"throughput/{name}/fused_vs_ref", 0.0,
             "x{:.2f}".format(pic["us_per_call"] / fus["us_per_call"]),
             interpreted=interp)
        emit(f"throughput/{name}/ps", ps["us_per_call"], f"ips={ps['ips']:.0f}")
        emit(f"throughput/{name}/mixed", mix["us_per_call"], f"ips={mix['ips']:.0f}")
        emit(f"throughput/{name}/picasso_l2", l2["us_per_call"],
             f"ips={l2['ips']:.0f}")
        emit(f"throughput/{name}/picasso_narrow", nar["us_per_call"],
             f"ips={nar['ips']:.0f}")
        emit(f"throughput/{name}/narrow_vs_full", 0.0,
             "vparam_bytes x{:.2f},d={}".format(
                 full_elems / max(nar_elems, 1),
                 min(widths.values())),
             interpreted=auto_interp)
        emit(f"throughput/{name}/auto+replan", rep["us_per_call"],
             f"ips={rep['ips']:.0f},rev={rep['rev']},migrated={rep['migrated']}")
        emit(f"throughput/{name}/overlap=off", ov_off["us_per_call"],
             f"ips={ov_off['ips']:.0f}")
        emit(f"throughput/{name}/overlap=on", ov_on["us_per_call"],
             f"ips={ov_on['ips']:.0f}")
        emit(f"throughput/{name}/overlap_on_vs_off", 0.0,
             "x{:.2f}".format(ov_off["us_per_call"] / ov_on["us_per_call"]),
             interpreted=auto_interp)
        emit(f"throughput/{name}/grad_compress=fp16", cmp_fp16["us_per_call"],
             f"ips={cmp_fp16['ips']:.0f}")
        emit(f"throughput/{name}/grad_compress=topk", cmp_topk["us_per_call"],
             f"ips={cmp_topk['ips']:.0f}")
        emit(f"throughput/{name}/guard=on", grd["us_per_call"],
             f"ips={grd['ips']:.0f}")
        emit(f"throughput/{name}/guard_overhead", 0.0,
             "x{:.2f}".format(grd["us_per_call"] / pic["us_per_call"]))
        emit(f"throughput/{name}/mp_nodedup", nod["us_per_call"],
             f"ips={nod['ips']:.0f}")
        emit(f"throughput/{name}/allgather_rows", agr["us_per_call"],
             f"ips={agr['ips']:.0f}")
        emit(f"throughput/{name}/speedup", 0.0, f"x{speedup:.2f}")
        # elastic-reshard cost: world=8 state permuted to world=4 row cuts
        # (the stall a live --reshard-to pays before training resumes)
        rsh = bench_reshard(cfg, gb, world_from=8, world_to=4,
                            l2_bytes=1 << 17)
        emit(f"throughput/{name}/reshard_8to4", rsh["us_per_call"],
             f"rows_per_s={rsh['rows_per_s']:.0f},stall_ms={rsh['stall_ms']:.1f}")
        if not smoke:
            # paper §II-C intermediate baseline: MP routing, but neither
            # D-Packing nor the HybridHash tier
            hyb = bench_train_ips(cfg, gb,
                                  TrainConfig(strategy="hybrid", use_cache=False),
                                  iters=iters, enable_cache=False,
                                  enable_packing=False)
            emit(f"throughput/{name}/hybrid", hyb["us_per_call"],
                 f"ips={hyb['ips']:.0f}")
            emit(f"throughput/{name}/mixed_vs_best_pure", 0.0,
                 "x{:.2f}".format(min(pic["us_per_call"], ps["us_per_call"],
                                      hyb["us_per_call"]) / mix["us_per_call"]))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one model, small batch, 2 iters (CI fast pass)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
    from benchmarks.common import write_bench_json
    write_bench_json()
