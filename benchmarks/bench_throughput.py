"""Paper Tab. III / Fig. 10: training throughput of the benchmark models
(DLRM / DeepFM / DIN / DCN-v2) under PICASSO vs the PS baseline strategy.
CPU-scaled smoke configs; the *ratio* is the reproduced quantity."""
from repro.configs import get_config
from repro.configs.paper_models import din, dlrm
from repro.train.train_step import TrainConfig

from benchmarks.common import bench_train_ips, emit

GB = 128


def models():
    return {
        "dlrm": dlrm(criteo=False, scale=0.01),
        "deepfm": get_config("deepfm", smoke=True),
        "dcn-v2": get_config("dcn-v2", smoke=True),
        "din": din(scale=0.01),
    }


def run():
    for name, cfg in models().items():
        pic = bench_train_ips(cfg, GB, TrainConfig(strategy="picasso"))
        ps = bench_train_ips(cfg, GB, TrainConfig(strategy="ps", use_cache=False),
                             enable_cache=False)
        speedup = ps["us_per_call"] / pic["us_per_call"]
        emit(f"throughput/{name}/picasso", pic["us_per_call"], f"ips={pic['ips']:.0f}")
        emit(f"throughput/{name}/ps", ps["us_per_call"], f"ips={ps['ips']:.0f}")
        emit(f"throughput/{name}/speedup", 0.0, f"x{speedup:.2f}")


if __name__ == "__main__":
    run()
