"""Paper Tab. VIII: IPS vs number of feature fields (synthetic duplication).

The paper duplicates Product-2's fields k times and checks whether IPS decays
slower than the arithmetic-progression (AP) prediction IPS_1/k thanks to
packing. We duplicate the W&D field set."""
import dataclasses

from repro.configs.base import FeatureField
from repro.configs.paper_models import widedeep
from repro.train.train_step import TrainConfig

from benchmarks.common import bench_train_ips, emit

GB = 64


def dup_fields(cfg, k):
    fields = []
    for j in range(k):
        for f in cfg.fields:
            fields.append(dataclasses.replace(f, name=f"{f.name}_x{j}"))
    return dataclasses.replace(cfg, fields=tuple(fields), name=f"{cfg.name}x{k}")


def run():
    cfg = widedeep(scale=0.02)
    ips1 = None
    for k in (1, 2, 4, 8):
        r = bench_train_ips(dup_fields(cfg, k), GB, TrainConfig())
        if ips1 is None:
            ips1 = r["ips"]
        ap = ips1 / k
        emit(f"fields/x{k}", r["us_per_call"],
             f"ips={r['ips']:.0f};ap={ap:.0f};vs_ap={(r['ips']-ap)/ap:+.1%}")


if __name__ == "__main__":
    run()
