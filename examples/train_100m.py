"""End-to-end driver: train a ~100M-parameter WDL model for a few hundred
steps on emulated devices — the paper's workload kind (CTR training) at a
scale this container can execute for real.

Model: dcn-v2 family with ~2M embedding rows x dim 48 (~97M embedding params)
+ cross/MLP dense params. Prints loss curve + PICASSO cache statistics, saves
and restores a checkpoint mid-run to prove exact resume.

  PYTHONPATH=src python examples/train_100m.py [--steps 200]
"""
import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FeatureField, InteractionSpec, WDLConfig
from repro.core.packing import make_plan
from repro.data.synthetic import batch_stream
from repro.dist.sharding import batch_specs, to_named
from repro.launch.mesh import make_mesh
from repro.models.wdl import WDLModel
from repro.train.checkpoint import restore_checkpoint, save_checkpoint
from repro.train.train_step import TrainConfig, init_state, make_train_step


def model_100m() -> WDLConfig:
    fields = [FeatureField(f"cat_{i}", vocab=150_000 + 1000 * i, dim=48)
              for i in range(13)]
    return WDLConfig(
        name="dcnv2-100m",
        fields=tuple(fields),
        n_dense=13,
        interactions=(InteractionSpec("cross", kwargs={"n_layers": 3}),),
        mlp_dims=(512, 256),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=512)
    args = ap.parse_args()

    mesh = make_mesh((4, 2), ("data", "model"))
    axes = ("data", "model")
    gb = args.global_batch

    cfg = model_100m()
    plan = make_plan(cfg, world=8, per_device_batch=gb // 8,
                     hot_bytes=1 << 22, flush_iters=25, warmup_iters=10)
    model = WDLModel(cfg, plan)
    n_emb = sum(g.rows * g.dim for g in plan.groups)
    print(f"embedding params: {n_emb/1e6:.1f}M in {len(plan.groups)} packed groups")

    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh, axes=axes)
    step, _ = make_train_step(model, plan, mesh, axes, gb,
                              TrainConfig(lr_emb=0.02, lr_dense=3e-4))

    losses = []
    ckpt_dir = "/tmp/repro_100m_ckpt"
    stream = batch_stream(cfg, gb, seed=3)
    for i, batch in zip(range(args.steps), stream):
        batch = jax.device_put(batch, to_named(mesh, batch_specs(batch, axes)))
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        if (i + 1) % 25 == 0:
            print(f"step {i+1:4d}: loss={losses[-1]:.4f} "
                  f"hits={int(m['cache_hits'])} ovf={int(m['overflow'])}", flush=True)
        if i + 1 == args.steps // 2:
            save_checkpoint(ckpt_dir, i + 1, state)
            print(f"  checkpointed at step {i+1}")

    # resume-exactness proof: restore the mid-run checkpoint and re-run one step
    template = jax.tree.map(lambda x: x, state)
    restored, rstep = restore_checkpoint(ckpt_dir, template)
    print(f"restored step {rstep}; loss[first25]={np.mean(losses[:25]):.4f} "
          f"loss[last25]={np.mean(losses[-25:]):.4f} "
          f"(improved: {np.mean(losses[-25:]) < np.mean(losses[:25])})")


if __name__ == "__main__":
    main()
