"""Retrieval serving example: SASRec two-tower — encode one user's behaviour
sequence, score 100k candidate items mesh-sharded, return the global top-10.

  PYTHONPATH=src python examples/serve_retrieval.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.packing import make_plan
from repro.data.synthetic import make_batch
from repro.launch.mesh import make_mesh
from repro.models.wdl import WDLModel
from repro.serve.serve_step import make_retrieval_step
from repro.train.train_step import init_state

N_CAND = 102_400


def main():
    mesh = make_mesh((4, 2), ("data", "model"))
    axes = ("data", "model")
    cfg = get_config("sasrec", smoke=True)
    plan = make_plan(cfg, world=8, per_device_batch=1, enable_cache=False,
                     exact_capacity=True)
    model = WDLModel(cfg, plan)
    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh, axes=axes)

    step = make_retrieval_step(model, plan, mesh, axes, N_CAND, top_k=10)
    user = make_batch(cfg, 1, np.random.default_rng(5))
    cand = jnp.arange(N_CAND, dtype=jnp.int32) % cfg.fields[0].vocab
    from repro.dist.sharding import to_named
    from jax.sharding import PartitionSpec as P
    cand = jax.device_put(cand, jax.sharding.NamedSharding(mesh, P(axes)))

    scores, ids = step(state, user, cand)
    print("top-10 candidate ids:", np.asarray(ids))
    print("scores:", np.round(np.asarray(scores), 3))


if __name__ == "__main__":
    main()
