"""Quickstart: train DeepFM with the full PICASSO stack (packing +
interleaving + HybridHash) on 8 emulated devices, then serve it.

  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.configs import get_config
from repro.core.packing import make_plan
from repro.data.synthetic import batch_stream, make_batch
from repro.dist.sharding import batch_specs, to_named
from repro.launch.mesh import make_mesh
from repro.models.wdl import WDLModel
from repro.serve.serve_step import make_serve_step
from repro.train.train_step import TrainConfig, init_state, make_train_step


def main():
    mesh = make_mesh((4, 2), ("data", "model"))
    axes = ("data", "model")
    gb = 128

    cfg = get_config("deepfm", smoke=True)
    plan = make_plan(cfg, world=8, per_device_batch=gb // 8,
                     hot_bytes=1 << 16, flush_iters=10, warmup_iters=5)
    model = WDLModel(cfg, plan)
    print(f"PICASSO plan: {len(plan.groups)} packed groups "
          f"(from {len(cfg.fields)} fields), capacities={plan.capacity}, "
          f"hot rows={plan.cache_rows}")

    state = init_state(model, plan, jax.random.PRNGKey(0), mesh=mesh, axes=axes)
    step, _ = make_train_step(model, plan, mesh, axes, gb, TrainConfig())

    for i, batch in zip(range(30), batch_stream(cfg, gb, seed=1)):
        batch = jax.device_put(batch, to_named(mesh, batch_specs(batch, axes)))
        state, m = step(state, batch)
        if (i + 1) % 10 == 0:
            print(f"step {i+1}: loss={float(m['loss']):.4f} "
                  f"cache_hits={int(m['cache_hits'])} overflow={int(m['overflow'])}")

    serve = make_serve_step(model, plan, mesh, axes, gb)
    batch = make_batch(cfg, gb, np.random.default_rng(7))
    batch = jax.device_put(batch, to_named(mesh, batch_specs(batch, axes)))
    probs = serve(state, batch)
    print(f"served {gb} requests; p(click) mean={float(probs.mean()):.4f}")


if __name__ == "__main__":
    main()
